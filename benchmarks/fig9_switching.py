"""Paper Fig. 9: average context-switching latency, LLMS vs baselines
(LMK / Swapping / VLLM-S / VLLM-SQ) across switching patterns.

Scaled to the CPU container: reduced smollm, 6 active contexts, tight
memory budget (~35% of the fp16 working-set) so swapping actually
happens, markov + random patterns.
"""
from __future__ import annotations

from benchmarks.common import bench_events, csv_line, make_service, replay

POLICIES = ("llms", "vllm_sq", "vllm_s", "swap", "lmk")


def run(quick: bool = False):
    n_ctx, n_calls = (4, 10) if quick else (6, 26)
    budget = 1_200_000        # bytes: ~25% of the fp16 working set
    rows = {}
    for pattern in ("markov",) if quick else ("markov", "random"):
        events = bench_events(n_ctx, n_calls, pattern=pattern)
        for policy in POLICIES:
            svc = make_service(policy, budget)
            st = replay(svc, events)
            svc.close()
            rows[(pattern, policy)] = st
            csv_line(f"fig9/{pattern}/{policy}",
                     st["switch_mean_s"] * 1e6,
                     f"p99_us={st['switch_p99_s']*1e6:.0f};"
                     f"mem={st['mem_used']}")
    return rows


if __name__ == "__main__":
    run()
