"""Paper Fig. 12 (+ §3.2 micro-experiment): compression ratio vs accuracy
— static whole-cache quantization (INT8/INT4/INT2) vs LLMS's
tolerance-aware chunk-wise mix at a 50% global ratio.

A ~2M-param llama-style model is TRAINED from scratch on a
signal/filler COPY language: each sequence is
    [bos | filler(15) | SIGNAL(16) | filler(64) | SIGNAL(16) | filler...]
The continuation must copy the SIGNAL chunk from the cache (KV is
load-bearing for exactly one of six prefill chunks) while filler is
constant junk — the heterogeneous-information-density regime the paper's
tolerance-aware compression targets.  Per scheme:
  prefill 96 tokens -> quantize+dequantize the KV cache -> teacher-forced
  continuation NLL on the copied SIGNAL tokens via the extend path.
LLMS assigns chunk levels from the Eq.-1 density accumulated over the
context's PAST invocations (prefill + one earlier continuation round) —
exactly the service lifecycle: compression happens at AoT swap-out using
the attention record so far, and persistent contexts are re-invoked with
similar query patterns (the paper's heavy-hitter premise).  The signal
chunk measures dense and keeps high precision; filler drops to 2 bits.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_line
from repro.configs import get_config
from repro.core import compression as comp
from repro.core.chunks import ChunkCodec
from repro.launch.train import make_train_step
from repro.models.registry import build_model
from repro.train.optimizer import OptConfig, init_state

CS = 16


FILL = 5                      # constant filler token
PREFILL = 96                  # 6 chunks of 16
SIG = 16
TOTAL = 120                   # prefill + [signal copy + filler tail]


def make_tokens(rng: np.random.RandomState, batch: int, vocab: int
                ) -> np.ndarray:
    sig = rng.randint(8, vocab, size=(batch, SIG)).astype(np.int32)
    # note: FILL/bos below 8 so the signal alphabet never collides
    bos = np.zeros((batch, 1), np.int32)
    f = lambda n: np.full((batch, n), FILL, np.int32)
    # [bos | f63 | SIG | f16 | SIG | f...]: signal occupies exactly chunk 4
    # of the 6-chunk prefill; copy distance fixed at 32 (trainable fast)
    toks = np.concatenate([bos, f(63), sig, f(16), sig,
                           f(TOTAL - 96 - SIG + 1)], axis=1)
    return toks[:, :TOTAL + 1]


def copy_batch(rng: np.random.RandomState, batch: int, vocab: int) -> dict:
    toks = make_tokens(rng, batch, vocab)
    mask = np.zeros((batch, TOTAL), np.float32)
    mask[:, PREFILL:] = 1.0                 # loss on the continuation
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:], "mask": mask}


_PARAM_CACHE = "/tmp/fig12_params_{steps}.pkl"


def _train_model(steps: int = 300):
    import os, pickle
    cache = _PARAM_CACHE.format(steps=steps)
    cfg = get_config("llama2-7b").with_overrides(
        name="fig12-model", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=512, vocab=256, max_seq=512)
    model = build_model(cfg)
    if os.path.exists(cache):
        with open(cache, "rb") as f:
            params, loss = pickle.load(f)
        import jax.numpy as jnp
        params = jax.tree.map(jnp.asarray, params)
        return cfg, model, params, loss
    cfg2, model2, params, loss = _train_model_fresh(steps, cfg, model)
    with open(cache, "wb") as f:
        pickle.dump((jax.tree.map(lambda a: np.asarray(a), params), loss), f)
    return cfg, model, params, loss


def _train_model_fresh(steps, cfg, model):
    params = model.init(jax.random.PRNGKey(0))
    opt = OptConfig(lr=2e-3, warmup_steps=30)
    step_fn = jax.jit(make_train_step(model, opt))
    state = init_state(params, opt)
    rng = np.random.RandomState(0)
    for step in range(steps):
        state, metrics = step_fn(state, copy_batch(rng, 8, cfg.vocab))
    return cfg, model, state["params"], float(metrics["loss"])


def _eval_scheme(model, params, codec, toks, scheme: str,
                 ratio_global: float = 0.5) -> Dict[str, float]:
    """toks: (B, S).  Returns copied-signal NLL + compressed bytes."""
    B, S = toks.shape
    half = PREFILL                       # chunk-aligned prefill boundary
    pf = jax.jit(functools.partial(model.prefill, want_density=True))(
        params, {"tokens": jnp.asarray(toks[:, :half])})
    cache = pf.cache
    n_chunks = half // CS
    # per-chunk bit plan
    if scheme == "fp16":
        bits = None
    elif scheme.startswith("int"):
        bits = np.full(n_chunks, int(scheme[3:]), np.int64)
    else:
        # llms tolerance-aware: density accumulated over the context's
        # invocation history — prefill AND one earlier round of this
        # continuation (the service's AoT-time knowledge)
        padded = {**cache,
                  "k": jnp.pad(cache["k"],
                               ((0, 0), (0, 0), (0, S - half), (0, 0),
                                (0, 0))),
                  "v": jnp.pad(cache["v"],
                               ((0, 0), (0, 0), (0, S - half), (0, 0),
                                (0, 0)))}
        pos0 = jnp.arange(half, S, dtype=jnp.int32)
        _, _, dens1 = jax.jit(functools.partial(
            model.recompute, want_density=True))(
            params, jnp.asarray(toks[:, half:]), pos0, padded,
            jnp.int32(S))
        # steady state: a persistent context is re-invoked many times —
        # its accumulated record holds n use-rounds per prefill (n=3 here)
        dens = (np.asarray(pf.density, np.float64).mean(0)
                + 3.0 * np.asarray(dens1, np.float64).mean(0)[:half])
        D = comp.chunk_density(dens, np.full(half, 4.0), half, CS)
        bits = comp.plan_buckets(D, ratio_global)
    nbytes = 0
    if bits is not None:
        for i in range(n_chunks):
            cc = codec.compress(cache, i * CS, (i + 1) * CS, int(bits[i]))
            nbytes += cc.nbytes
            cache = codec.insert(cache, i * CS, codec.decompress(cc))
    else:
        nbytes = sum(int(np.prod(codec.leaf_slice_shape(
            {k: v.shape for k, v in cache.items() if k in codec.leaves},
            k, half))) * 2 for k in codec.leaves)
    # teacher-forced continuation through the recompute/extend path
    pos = jnp.arange(half, S, dtype=jnp.int32)
    cache = {**cache, "k": jnp.pad(cache["k"], ((0,0),(0,0),(0,S-half),(0,0),(0,0))),
             "v": jnp.pad(cache["v"], ((0,0),(0,0),(0,S-half),(0,0),(0,0)))}
    _, hidden, _ = jax.jit(model.recompute)(
        params, jnp.asarray(toks[:, half:]), pos, cache, jnp.int32(S))
    logits = (hidden[:, :-1] @ model.head_weight(params)).astype(jnp.float32)
    targets = jnp.asarray(toks[:, half + 1:])
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
    # score only the copied-signal region (position 96 predicts toks[97],
    # the first signal token, through position 96+SIG-1)
    nll = float(jnp.mean((logz - ll)[:, :SIG]))
    return {"nll": nll, "bytes": nbytes}


def run(quick: bool = False):
    steps = 400 if quick else 1400
    cfg, model, params, train_loss = _train_model(steps)
    codec = ChunkCodec("dense", CS)
    rng = np.random.RandomState(99)
    B = 4 if quick else 8
    toks = make_tokens(rng, B, cfg.vocab)
    rows = {}
    base = None
    schemes = (("fp16", None), ("int8", None), ("int4", None),
               ("int2", None), ("llms", 0.5), ("llms", 0.3))
    for scheme, ratio in schemes:
        r = _eval_scheme(model, params, codec, toks, scheme,
                         ratio_global=ratio or 0.5)
        tag = scheme if ratio is None else f"llms{int(ratio*100)}"
        if base is None:
            base = r
        rows[tag] = r
        csv_line(f"fig12/{tag}", r["nll"] * 1e6,
                 f"nll={r['nll']:.4f};dNLL={r['nll']-base['nll']:.4f};"
                 f"bytes={r['bytes']};ratio={r['bytes']/base['bytes']:.3f}")
    rows["train_loss"] = train_loss
    return rows


if __name__ == "__main__":
    run()
