"""Scenario benchmark runner: drive named loadgen scenarios through the
virtual-clock harness and emit BENCH_scenarios.json.

Every gated metric is VIRTUAL-time (deterministic in the scenario seed)
or a pure counter, so the JSON is machine-portable — unlike the other
bench kinds no A/B ratio is needed.  The ``reduced`` section runs the
CI-sized ``smoke_ci`` scenario TWICE and records whether the two runs
were identical (event-log sha256 + every deterministic metric): the
regression gate checks that bit, so CI re-proves determinism on every
push.

The ``faults`` subsection (DESIGN.md §6) runs the CI-sized fault
scenarios: ``flaky_disk`` twice at a fixed fault seed (overridable via
``LLMS_FAULT_SEED``) plus once FAULT-FREE on the same workload — the
gate asserts same-seed determinism, zero failed foreground calls,
faults actually injected/recovered, and that the recovered run's
decoded tokens are byte-identical to the fault-free run's; and
``disk_full_churn`` once — the gate asserts degraded mode was entered,
exited, and no foreground call failed.

  PYTHONPATH=src:. python benchmarks/scenarios.py --reduced \
      --out bench_scenarios_fresh.json
"""
from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks.common import bench_model

from repro.loadgen import (SCENARIOS, build_service, gate_metrics,
                           get_scenario, run_scenario, write_bench)
from repro.loadgen.driver import (bind_apps_by_ctx, build_zoo_service,
                                  make_events)
from repro.loadgen.metrics import deterministic_view

FULL_SET = ("steady_poisson", "fg_burst_over_bg", "diurnal_ramp",
            "herd_restore", "eviction_churn", "flaky_disk",
            "disk_full_churn", "scale_10k")

_MODELS = {}


def profile_model(profile: str):
    """Resolve a spec's ``model_profile`` to (cfg, model, params):
    ``bench`` is the ~8M-param harness model every other bench uses;
    ``reduced`` is the tiny smoke config — the 10^4-context soak
    measures the SCHEDULER at scale, not the model."""
    if profile not in _MODELS:
        if profile == "bench":
            _MODELS[profile] = bench_model()
        else:
            import jax
            from repro.configs import get_config, reduced
            from repro.models.registry import build_model
            cfg = reduced(get_config("llama2-7b"))
            model = build_model(cfg)
            _MODELS[profile] = (cfg, model,
                                model.init(jax.random.PRNGKey(0)))
    return _MODELS[profile]


def run_one(spec, events=None):
    cfg, model, params = profile_model(spec.model_profile)
    svc = build_service(spec, model, params)
    with svc:
        return run_scenario(spec, svc, cfg.vocab, events=events)


def reduced_section() -> dict:
    """smoke_ci twice; gate metrics + the determinism probe."""
    spec = get_scenario("smoke_ci")
    events = make_events(spec, profile_model(spec.model_profile)[0].vocab)
    a = run_one(spec, events=events)
    b = run_one(spec, events=events)
    out = gate_metrics(a)
    out["determinism_holds"] = (
        deterministic_view(a) == deterministic_view(b))
    out["wall_s"] = a["wall_s"]
    return out


# model zoo (mixed_zoo scenario): one reduced model per family, served
# together behind ONE router against one byte budget + swap tier.
ZOO_ARCHS = {"dense": "llama2-7b",
             "mla_moe": "deepseek-v2-lite-16b",
             "rwkv6": "rwkv6-1.6b"}
_ZOO_MODELS = {}


def zoo_models():
    if not _ZOO_MODELS:
        import jax
        from repro.configs import get_config, reduced
        from repro.models.registry import build_model
        for fam, arch in ZOO_ARCHS.items():
            cfg = reduced(get_config(arch))
            model = build_model(cfg)
            _ZOO_MODELS[fam] = (cfg, model,
                                model.init(jax.random.PRNGKey(0)))
    return _ZOO_MODELS


def zoo_section() -> dict:
    """The heterogeneous-zoo leg: mixed_zoo twice (determinism), each
    family once SOLO on its share of the same events (per-family token
    identity: the shared-substrate routing must not change a single
    decoded token), and an MLA quant-resident A/B (8-bit latent-chunk
    token identity vs full dequant + resident-bytes drop vs bf16)."""
    spec = get_scenario("mixed_zoo")
    models = zoo_models()
    vocab = min(cfg.vocab for cfg, _, _ in models.values())
    events = bind_apps_by_ctx(make_events(spec, vocab), spec)
    fam_by_app = {a["name"]: a["family"] for a in spec.apps}

    def run(sp, fams, evs, force_dequant=False):
        svc = build_zoo_service(
            sp, {f: (models[f][1], models[f][2]) for f in fams})
        with svc:
            if force_dequant:
                for m in svc.members.values():
                    m.res.force_dequant = True
            rep = run_scenario(sp, svc, vocab, events=evs)
            stats = svc.stats()
        return rep, stats

    a, stats_a = run(spec, list(fam_by_app.values()), events)
    b, _ = run(spec, list(fam_by_app.values()), events)
    out = gate_metrics(a)
    out["determinism_holds"] = (
        deterministic_view(a) == deterministic_view(b))
    out["families_served"] = {
        fam: st["total_calls"] for fam, st in stats_a["families"].items()}
    out["quant_resident_chunks"] = a["service"].get(
        "quant_resident_chunks", 0)

    # per-family solo legs on the SAME bound events, filtered by app
    solo_sha = {}
    for app, fam in fam_by_app.items():
        sub = [ev for ev in events if ev.app == app]
        rep, _ = run(spec, [fam], sub)
        solo_sha[app] = rep["tokens_sha_by_app"][app]
    out["solo_tokens_sha_by_app"] = solo_sha
    out["solo_vs_mixed_identical"] = all(
        solo_sha[app] == a["tokens_sha_by_app"][app] for app in solo_sha)

    # MLA quant-resident A/B: same 8-bit latent payloads decoded as
    # scattered int8 codes (quant leg) vs materialized bf16 (dequant
    # leg) must be token-identical; resident bytes per context vs the
    # 16-bit llms_nocomp payload must drop.
    mla_app = next(app for app, f in fam_by_app.items() if f == "mla_moe")
    sub = [ev for ev in events if ev.app == mla_app]
    q, q_stats = run(spec, ["mla_moe"], sub)
    d, _ = run(spec, ["mla_moe"], sub, force_dequant=True)
    bf, bf_stats = run(spec.override(policy="llms_nocomp",
                                     quant_resident=False),
                       ["mla_moe"], sub)
    rb_q = q_stats["families"]["mla_moe"]["resident_bytes"]
    rb_bf = bf_stats["families"]["mla_moe"]["resident_bytes"]
    nctx = max(1, q_stats["families"]["mla_moe"]["contexts"])
    out["mla"] = {
        "token_identical_8bit": (q["tokens_sha_by_app"][mla_app]
                                 == d["tokens_sha_by_app"][mla_app]),
        "resident_bytes_quant": int(rb_q),
        "resident_bytes_bf16": int(rb_bf),
        "resident_bytes_per_ctx_quant": rb_q // nctx,
        "resident_bytes_per_ctx_bf16": rb_bf // nctx,
        "bytes_ratio_bf16_over_quant": (rb_bf / rb_q) if rb_q else 0.0,
    }
    out["wall_s"] = a["wall_s"]
    return out


# CI-sized overlays for the fault scenarios (full-size specs stay in
# the library); the reduced flaky workload keeps the eviction pressure
# (small budget, sweep pattern) so the injected sites actually fire.
_FLAKY_CI = dict(n_contexts=12, n_calls=64, memory_budget=12_000)
_DISKFULL_CI = dict(n_contexts=16, n_calls=96, memory_budget=12_000,
                    faults={"disk_full_windows": [[5.0, 14.0]],
                            "seed": 4321})


def fault_section() -> dict:
    """The fault-injection leg (DESIGN.md §6).

    flaky_disk runs TWICE at one fault seed (determinism) and once with
    faults stripped on the SAME synthesized workload: under the 16-bit
    ``llms_nocomp`` policy recompute recovery is bit-exact, so the
    faulted runs' decoded tokens must hash identically to the clean
    run's.  disk_full_churn must enter AND exit degraded mode with zero
    failed foreground calls."""
    fseed = int(os.environ.get("LLMS_FAULT_SEED", "1234"))
    spec = get_scenario("flaky_disk", **_FLAKY_CI)
    spec = spec.override(faults={**dict(spec.faults), "seed": fseed})
    events = make_events(spec, profile_model(spec.model_profile)[0].vocab)
    a = run_one(spec, events=events)
    b = run_one(spec, events=events)
    clean = run_one(spec.override(faults={}), events=events)
    flaky = gate_metrics(a)
    flaky["fault_seed"] = fseed
    flaky["determinism_holds"] = (
        deterministic_view(a) == deterministic_view(b))
    flaky["recovery_token_identical"] = (
        a["tokens_sha256"] == clean["tokens_sha256"])
    flaky["wall_s"] = a["wall_s"]

    dspec = get_scenario("disk_full_churn", **_DISKFULL_CI)
    d = run_one(dspec)
    disk_full = gate_metrics(d)
    disk_full["wall_s"] = d["wall_s"]
    return {"flaky": flaky, "disk_full": disk_full}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", action="append", default=None,
                    choices=sorted(SCENARIOS),
                    help="run only these scenarios (repeatable)")
    ap.add_argument("--reduced", action="store_true",
                    help="CI mode: only the reduced determinism pair")
    ap.add_argument("--out", default=None, help="write JSON here")
    args = ap.parse_args()

    doc: dict = {"kind": "scenario"}

    t0 = time.time()
    doc["reduced"] = reduced_section()
    print(f"reduced pair: determinism_holds="
          f"{doc['reduced']['determinism_holds']} "
          f"({time.time() - t0:.1f}s)")

    t0 = time.time()
    doc["reduced"]["zoo"] = zoo_section()
    z = doc["reduced"]["zoo"]
    print(f"zoo leg: determinism={z['determinism_holds']} "
          f"solo_vs_mixed_identical={z['solo_vs_mixed_identical']} "
          f"families={sorted(z['families_served'])} "
          f"mla_8bit_identical={z['mla']['token_identical_8bit']} "
          f"mla_bytes_ratio={z['mla']['bytes_ratio_bf16_over_quant']:.2f} "
          f"({time.time() - t0:.1f}s)")

    t0 = time.time()
    doc["reduced"]["faults"] = fault_section()
    fl = doc["reduced"]["faults"]["flaky"]
    df = doc["reduced"]["faults"]["disk_full"]
    print(f"fault leg: flaky determinism={fl['determinism_holds']} "
          f"token_identical={fl['recovery_token_identical']} "
          f"injected={fl.get('faults_injected_total', 0)} "
          f"recovered={fl.get('chunks_recovered_recompute', 0)} "
          f"errors_fg={fl.get('errors_fg', 0)}; disk_full "
          f"entries={df.get('degraded_entries', 0)} "
          f"exits={df.get('degraded_exits', 0)} "
          f"errors_fg={df.get('errors_fg', 0)} "
          f"({time.time() - t0:.1f}s)")

    if not args.reduced:
        names = args.scenario or list(FULL_SET)
        doc["scenarios"] = {}
        for name in names:
            spec = get_scenario(name)
            t0 = time.time()
            rep = run_one(spec)
            doc["scenarios"][name] = rep
            r = rep["router"]
            print(f"{name:18s} wall {rep['wall_s']:7.1f}s  virtual "
                  f"{rep['virtual_duration_s']:9.1f}s  calls "
                  f"{rep['n_calls']:6d}  preempts {r['preemptions']:4d}  "
                  f"stuck {rep['streams']['stuck']}")

    if args.out:
        write_bench(args.out, doc)
        print(f"wrote {args.out}")
    else:
        print(json.dumps(doc.get("reduced", doc), indent=1))


if __name__ == "__main__":
    main()
