"""Scenario benchmark runner: drive named loadgen scenarios through the
virtual-clock harness and emit BENCH_scenarios.json.

Every gated metric is VIRTUAL-time (deterministic in the scenario seed)
or a pure counter, so the JSON is machine-portable — unlike the other
bench kinds no A/B ratio is needed.  The ``reduced`` section runs the
CI-sized ``smoke_ci`` scenario TWICE and records whether the two runs
were identical (event-log sha256 + every deterministic metric): the
regression gate checks that bit, so CI re-proves determinism on every
push.

The ``faults`` subsection (DESIGN.md §6) runs the CI-sized fault
scenarios: ``flaky_disk`` twice at a fixed fault seed (overridable via
``LLMS_FAULT_SEED``) plus once FAULT-FREE on the same workload — the
gate asserts same-seed determinism, zero failed foreground calls,
faults actually injected/recovered, and that the recovered run's
decoded tokens are byte-identical to the fault-free run's; and
``disk_full_churn`` once — the gate asserts degraded mode was entered,
exited, and no foreground call failed.

  PYTHONPATH=src:. python benchmarks/scenarios.py --reduced \
      --out bench_scenarios_fresh.json
"""
from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks.common import bench_model

from repro.loadgen import (SCENARIOS, build_service, gate_metrics,
                           get_scenario, run_scenario, write_bench)
from repro.loadgen.driver import make_events
from repro.loadgen.metrics import deterministic_view

FULL_SET = ("steady_poisson", "fg_burst_over_bg", "diurnal_ramp",
            "herd_restore", "eviction_churn", "flaky_disk",
            "disk_full_churn", "scale_10k")

_MODELS = {}


def profile_model(profile: str):
    """Resolve a spec's ``model_profile`` to (cfg, model, params):
    ``bench`` is the ~8M-param harness model every other bench uses;
    ``reduced`` is the tiny smoke config — the 10^4-context soak
    measures the SCHEDULER at scale, not the model."""
    if profile not in _MODELS:
        if profile == "bench":
            _MODELS[profile] = bench_model()
        else:
            import jax
            from repro.configs import get_config, reduced
            from repro.models.registry import build_model
            cfg = reduced(get_config("llama2-7b"))
            model = build_model(cfg)
            _MODELS[profile] = (cfg, model,
                                model.init(jax.random.PRNGKey(0)))
    return _MODELS[profile]


def run_one(spec, events=None):
    cfg, model, params = profile_model(spec.model_profile)
    svc = build_service(spec, model, params)
    with svc:
        return run_scenario(spec, svc, cfg.vocab, events=events)


def reduced_section() -> dict:
    """smoke_ci twice; gate metrics + the determinism probe."""
    spec = get_scenario("smoke_ci")
    events = make_events(spec, profile_model(spec.model_profile)[0].vocab)
    a = run_one(spec, events=events)
    b = run_one(spec, events=events)
    out = gate_metrics(a)
    out["determinism_holds"] = (
        deterministic_view(a) == deterministic_view(b))
    out["wall_s"] = a["wall_s"]
    return out


# CI-sized overlays for the fault scenarios (full-size specs stay in
# the library); the reduced flaky workload keeps the eviction pressure
# (small budget, sweep pattern) so the injected sites actually fire.
_FLAKY_CI = dict(n_contexts=12, n_calls=64, memory_budget=12_000)
_DISKFULL_CI = dict(n_contexts=16, n_calls=96, memory_budget=12_000,
                    faults={"disk_full_windows": [[5.0, 14.0]],
                            "seed": 4321})


def fault_section() -> dict:
    """The fault-injection leg (DESIGN.md §6).

    flaky_disk runs TWICE at one fault seed (determinism) and once with
    faults stripped on the SAME synthesized workload: under the 16-bit
    ``llms_nocomp`` policy recompute recovery is bit-exact, so the
    faulted runs' decoded tokens must hash identically to the clean
    run's.  disk_full_churn must enter AND exit degraded mode with zero
    failed foreground calls."""
    fseed = int(os.environ.get("LLMS_FAULT_SEED", "1234"))
    spec = get_scenario("flaky_disk", **_FLAKY_CI)
    spec = spec.override(faults={**dict(spec.faults), "seed": fseed})
    events = make_events(spec, profile_model(spec.model_profile)[0].vocab)
    a = run_one(spec, events=events)
    b = run_one(spec, events=events)
    clean = run_one(spec.override(faults={}), events=events)
    flaky = gate_metrics(a)
    flaky["fault_seed"] = fseed
    flaky["determinism_holds"] = (
        deterministic_view(a) == deterministic_view(b))
    flaky["recovery_token_identical"] = (
        a["tokens_sha256"] == clean["tokens_sha256"])
    flaky["wall_s"] = a["wall_s"]

    dspec = get_scenario("disk_full_churn", **_DISKFULL_CI)
    d = run_one(dspec)
    disk_full = gate_metrics(d)
    disk_full["wall_s"] = d["wall_s"]
    return {"flaky": flaky, "disk_full": disk_full}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", action="append", default=None,
                    choices=sorted(SCENARIOS),
                    help="run only these scenarios (repeatable)")
    ap.add_argument("--reduced", action="store_true",
                    help="CI mode: only the reduced determinism pair")
    ap.add_argument("--out", default=None, help="write JSON here")
    args = ap.parse_args()

    doc: dict = {"kind": "scenario"}

    t0 = time.time()
    doc["reduced"] = reduced_section()
    print(f"reduced pair: determinism_holds="
          f"{doc['reduced']['determinism_holds']} "
          f"({time.time() - t0:.1f}s)")

    t0 = time.time()
    doc["reduced"]["faults"] = fault_section()
    fl = doc["reduced"]["faults"]["flaky"]
    df = doc["reduced"]["faults"]["disk_full"]
    print(f"fault leg: flaky determinism={fl['determinism_holds']} "
          f"token_identical={fl['recovery_token_identical']} "
          f"injected={fl.get('faults_injected_total', 0)} "
          f"recovered={fl.get('chunks_recovered_recompute', 0)} "
          f"errors_fg={fl.get('errors_fg', 0)}; disk_full "
          f"entries={df.get('degraded_entries', 0)} "
          f"exits={df.get('degraded_exits', 0)} "
          f"errors_fg={df.get('errors_fg', 0)} "
          f"({time.time() - t0:.1f}s)")

    if not args.reduced:
        names = args.scenario or list(FULL_SET)
        doc["scenarios"] = {}
        for name in names:
            spec = get_scenario(name)
            t0 = time.time()
            rep = run_one(spec)
            doc["scenarios"][name] = rep
            r = rep["router"]
            print(f"{name:18s} wall {rep['wall_s']:7.1f}s  virtual "
                  f"{rep['virtual_duration_s']:9.1f}s  calls "
                  f"{rep['n_calls']:6d}  preempts {r['preemptions']:4d}  "
                  f"stuck {rep['streams']['stuck']}")

    if args.out:
        write_bench(args.out, doc)
        print(f"wrote {args.out}")
    else:
        print(json.dumps(doc.get("reduced", doc), indent=1))


if __name__ == "__main__":
    main()
