"""Paper Fig. 13: ablation — LLMS without each of its three techniques
(tolerance-aware compression / swapping-recompute pipeline / chunk
lifecycle management) on the same trace."""
from __future__ import annotations

from benchmarks.common import bench_events, csv_line, make_service, replay

VARIANTS = ("llms", "llms_nocomp", "llms_nopipe", "llms_nolife")


def run(quick: bool = False):
    n_ctx, n_calls = (4, 12) if quick else (8, 28)
    budget = 500_000            # tight enough that llms itself swaps
    events = bench_events(n_ctx, n_calls, pattern="markov", seed=3)
    rows = {}
    for policy in VARIANTS:
        svc = make_service(policy, budget)
        st = replay(svc, events)
        svc.close()
        rows[policy] = st
        csv_line(f"fig13/{policy}", st["switch_mean_s"] * 1e6,
                 f"p99_us={st['switch_p99_s']*1e6:.0f};mem={st['mem_used']}")
    return rows


if __name__ == "__main__":
    run()
