"""A/B: quant-resident decode vs the full-dequant baseline.

Same llms policy, same trace, same byte budget — the only difference is
whether switch-in materializes compressed chunks into the bf16 working
cache (baseline) or leaves them int8 behind the fused decode-attention
kernel (``quant_resident=True``).  Reports:

  * switch-in latency (timed restore + resident-chunk assembly) — the
    Fig. 9 QoS metric this PR attacks: assembly of a quant-resident
    context is an int8 scatter (8-bit chunks: a pure memcpy of their
    payload bytes), not a dequantization pass,
  * decode-ready contexts at the fixed budget: contexts switchable
    without dequantization or disk I/O.  The baseline is warm only up
    to its parked bf16 slots; the quant tier keeps every fully
    in-memory context decode-ready,
  * contexts fully in memory (the byte-budget-driven count; decode-grid
    payloads are slightly smaller than the storage codec, so the same
    budget holds at least as many),
  * a token-identity probe at 8-bit (static8): fused in-place decode
    must emit exactly the full-dequant leg's tokens.

  PYTHONPATH=src:. python benchmarks/quant_resident.py \
      [--out BENCH_quant_resident.json]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import DISK_BW, DISK_LAT, bench_model, make_service
from repro.core.restore import set_disk_throttle

N_CTX = 12
ROUNDS = 3
PROMPT = 48
MAX_NEW = 8
BUDGET = 2 << 20


def run_leg(quant_resident: bool, force_dequant: bool = False,
            budget: int = BUDGET, policy: str = "llms"):
    cfg, _, _ = bench_model()
    svc = make_service(policy, budget, quant_resident=quant_resident,
                       profile=policy == "llms")
    if force_dequant:
        svc.res.force_dequant = True
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab, PROMPT).tolist()
               for _ in range(N_CTX)]
    with svc:
        stubs = [svc.newLLMCtx() for _ in range(N_CTX)]

        def one_round(r, max_new=MAX_NEW):
            toks = []
            for stub, p in zip(stubs, prompts):
                toks.append(svc.callLLM(stub, p[r:r + 8], max_new)[1])
            return toks

        set_disk_throttle(None)             # warm pass: compile everything
        one_round(0)
        # drive two throwaway contexts through the same growth pattern
        # so every chunk-count/bucket shape the measured rounds will hit
        # is already traced (compiles must not land in the QoS numbers)
        wstubs = [svc.newLLMCtx() for _ in range(2)]
        for r in range(2 * ROUNDS + 1):
            for stub in wstubs:
                svc.callLLM(stub, prompts[0][r:r + (8 if r else PROMPT)],
                            MAX_NEW)
        for stub in wstubs:
            svc.delLLMCtx(stub)
        # first measured-shape pass is discarded: the steady-state
        # rounds are the regime the QoS metric is about (every context
        # has a full chunk set; switch-ins dominate)
        for r in range(ROUNDS):
            one_round(1 + r)
        svc.records.clear()
        set_disk_throttle(DISK_BW, DISK_LAT)

        t0 = time.perf_counter()
        all_toks = [one_round(1 + ROUNDS + r) for r in range(ROUNDS)]
        wall = time.perf_counter() - t0

        recs = svc.records
        sw = [r["switch_s"] + r["assemble_s"] for r in recs]
        gen = sum(len(t) for toks in all_toks for t in toks)
        in_mem = sum(
            1 for c in svc.contexts.values()
            if c.chunks and all(m.in_memory for m in c.chunks.values()))
        out = {
            "quant_resident": quant_resident and not force_dequant,
            "budget_bytes": budget,
            "calls": len(recs),
            "switch_in_mean_ms": round(float(np.mean(sw)) * 1e3, 4),
            "switch_in_median_ms": round(
                float(np.median(sw)) * 1e3, 4),
            "switch_in_p95_ms": round(
                float(np.percentile(sw, 95)) * 1e3, 4),
            "restore_mean_ms": round(
                float(np.mean([r["switch_s"] for r in recs])) * 1e3, 4),
            "assemble_mean_ms": round(
                float(np.mean([r["assemble_s"] for r in recs])) * 1e3, 4),
            "decode_ready_contexts": svc.decode_ready_contexts(),
            "contexts_fully_in_memory": in_mem,
            "quant_resident_chunks": svc.stats()["quant_resident_chunks"],
            "mem_used": svc.mem.used,
            "generated_tokens": gen,
            "decode_tokens_per_s": round(gen / wall, 2),
        }
    return out, all_toks


def token_identity_probe():
    """static8 (every chunk 8-bit): fused in-place decode vs the same
    payloads materialized to bf16 — must be token-identical."""
    set_disk_throttle(None)
    _, toks_q = run_leg(True, policy="vllm_sq", budget=64 << 20)
    _, toks_d = run_leg(True, force_dequant=True, policy="vllm_sq",
                        budget=64 << 20)
    return toks_q == toks_d


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_quant_resident.json")
    args = ap.parse_args()

    baseline, _ = run_leg(False)
    quant, _ = run_leg(True)
    identical = token_identity_probe()

    report = {
        "trace": {"contexts": N_CTX, "rounds": ROUNDS,
                  "prompt_tokens": PROMPT, "max_new": MAX_NEW,
                  "policy": "llms", "budget_bytes": BUDGET,
                  "decode_batch": 1},
        "full_dequant_baseline": baseline,
        "quant_resident": quant,
        "switch_in_speedup": round(
            baseline["switch_in_mean_ms"]
            / max(quant["switch_in_mean_ms"], 1e-9), 2),
        "extra_decode_ready_contexts": (
            quant["decode_ready_contexts"]
            - baseline["decode_ready_contexts"]),
        "token_identical_8bit": bool(identical),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(json.dumps(report, indent=1))
    assert identical, "8-bit quant-resident decode diverged from bf16 path"


if __name__ == "__main__":
    main()
