"""A/B: quant-resident decode vs the full-dequant baseline.

Same llms policy, same trace, same byte budget — the only difference is
whether switch-in materializes compressed chunks into the bf16 working
cache (baseline) or leaves them int8 behind the fused decode-attention
kernel (``quant_resident=True``).  Reports:

  * switch-in latency (timed restore + resident-chunk assembly) — the
    Fig. 9 QoS metric this PR attacks: assembly of a quant-resident
    context is an int8 scatter (8-bit chunks: a pure memcpy of their
    payload bytes), not a dequantization pass,
  * decode-ready contexts at the fixed budget: contexts switchable
    without dequantization or disk I/O.  The baseline is warm only up
    to its parked bf16 slots; the quant tier keeps every fully
    in-memory context decode-ready,
  * contexts fully in memory (the byte-budget-driven count; decode-grid
    payloads are slightly smaller than the storage codec, so the same
    budget holds at least as many),
  * a token-identity probe at 8-bit (static8): fused in-place decode
    must emit exactly the full-dequant leg's tokens.

Both legs pin ``paged_pool=False``: this A/B isolates the slot-path
assembly mechanism (see run_leg); the paged engine has its own A/B in
``benchmarks/paged_pool.py``.  ``--reduced`` runs the CI-sized trace
only; the full run embeds a ``reduced`` section for the regression
gate.

  PYTHONPATH=src:. python benchmarks/quant_resident.py \
      [--out BENCH_quant_resident.json] [--reduced]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from benchmarks.common import DISK_BW, DISK_LAT, bench_model, make_service
from repro.core.restore import set_disk_throttle

N_CTX = 12
ROUNDS = 3
PROMPT = 48
MAX_NEW = 8
BUDGET = 2 << 20


def run_leg(quant_resident: bool, force_dequant: bool = False,
            budget: int = BUDGET, policy: str = "llms",
            n_ctx: int = N_CTX, rounds: int = ROUNDS):
    cfg, _, _ = bench_model()
    # paged_pool=False: this A/B measures the SLOT-path assembly
    # mechanism (int8 scatter vs dequant pass at switch-in) — on the
    # paged pool both legs' switch-ins are page-table reads and the
    # ratio collapses; benchmarks/paged_pool.py covers that engine
    svc = make_service(policy, budget, quant_resident=quant_resident,
                       profile=policy == "llms", paged_pool=False)
    if force_dequant:
        svc.res.force_dequant = True
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab, PROMPT).tolist()
               for _ in range(n_ctx)]
    with svc:
        stubs = [svc.newLLMCtx() for _ in range(n_ctx)]

        def one_round(r, max_new=MAX_NEW):
            toks = []
            for stub, p in zip(stubs, prompts):
                toks.append(svc.callLLM(stub, p[r:r + 8], max_new)[1])
            return toks

        set_disk_throttle(None)             # warm pass: compile everything
        one_round(0)
        # drive two throwaway contexts through the same growth pattern
        # so every chunk-count/bucket shape the measured rounds will hit
        # is already traced (compiles must not land in the QoS numbers)
        wstubs = [svc.newLLMCtx() for _ in range(2)]
        for r in range(2 * rounds + 1):
            for stub in wstubs:
                svc.callLLM(stub, prompts[0][r:r + (8 if r else PROMPT)],
                            MAX_NEW)
        for stub in wstubs:
            svc.delLLMCtx(stub)
        # first measured-shape pass is discarded: the steady-state
        # rounds are the regime the QoS metric is about (every context
        # has a full chunk set; switch-ins dominate)
        for r in range(rounds):
            one_round(1 + r)
        svc.records.clear()
        set_disk_throttle(DISK_BW, DISK_LAT)

        t0 = time.perf_counter()
        all_toks = [one_round(1 + rounds + r) for r in range(rounds)]
        wall = time.perf_counter() - t0

        recs = svc.records
        sw = [r["switch_s"] + r["assemble_s"] for r in recs]
        gen = sum(len(t) for toks in all_toks for t in toks)
        in_mem = sum(
            1 for c in svc.contexts.values()
            if c.chunks and all(m.in_memory for m in c.chunks.values()))
        out = {
            "quant_resident": quant_resident and not force_dequant,
            "budget_bytes": budget,
            "calls": len(recs),
            "switch_in_mean_ms": round(float(np.mean(sw)) * 1e3, 4),
            "switch_in_median_ms": round(
                float(np.median(sw)) * 1e3, 4),
            "switch_in_p95_ms": round(
                float(np.percentile(sw, 95)) * 1e3, 4),
            "restore_mean_ms": round(
                float(np.mean([r["switch_s"] for r in recs])) * 1e3, 4),
            "assemble_mean_ms": round(
                float(np.mean([r["assemble_s"] for r in recs])) * 1e3, 4),
            "decode_ready_contexts": svc.decode_ready_contexts(),
            "contexts_fully_in_memory": in_mem,
            "quant_resident_chunks": svc.stats()["quant_resident_chunks"],
            "mem_used": svc.mem.used,
            "generated_tokens": gen,
            "decode_tokens_per_s": round(gen / wall, 2),
        }
    return out, all_toks


def token_identity_probe(n_ctx: int = N_CTX, rounds: int = ROUNDS):
    """static8 (every chunk 8-bit): fused in-place decode vs the same
    payloads materialized to bf16 — must be token-identical."""
    set_disk_throttle(None)
    _, toks_q = run_leg(True, policy="vllm_sq", budget=64 << 20,
                        n_ctx=n_ctx, rounds=rounds)
    _, toks_d = run_leg(True, force_dequant=True, policy="vllm_sq",
                        budget=64 << 20, n_ctx=n_ctx, rounds=rounds)
    return toks_q == toks_d


REDUCED_N_CTX = 6
REDUCED_ROUNDS = 2


def run_ab(n_ctx: int, rounds: int):
    baseline, _ = run_leg(False, n_ctx=n_ctx, rounds=rounds)
    quant, _ = run_leg(True, n_ctx=n_ctx, rounds=rounds)
    identical = token_identity_probe(n_ctx=n_ctx, rounds=rounds)
    return {
        "trace": {"contexts": n_ctx, "rounds": rounds,
                  "prompt_tokens": PROMPT, "max_new": MAX_NEW,
                  "policy": "llms", "budget_bytes": BUDGET,
                  "decode_batch": 1},
        "full_dequant_baseline": baseline,
        "quant_resident": quant,
        "switch_in_speedup": round(
            baseline["switch_in_mean_ms"]
            / max(quant["switch_in_mean_ms"], 1e-9), 2),
        "extra_decode_ready_contexts": (
            quant["decode_ready_contexts"]
            - baseline["decode_ready_contexts"]),
        "token_identical_8bit": bool(identical),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_quant_resident.json")
    ap.add_argument("--reduced", action="store_true",
                    help="CI-sized trace only (the regression-gate A/B)")
    args = ap.parse_args()

    if args.reduced:
        report = run_ab(REDUCED_N_CTX, REDUCED_ROUNDS)
    else:
        report = run_ab(N_CTX, ROUNDS)
        # the CI regression gate replays the reduced A/B on a different
        # machine; only ratio metrics are portable, so record them here
        report["reduced"] = run_ab(REDUCED_N_CTX, REDUCED_ROUNDS)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(json.dumps(report, indent=1))
    assert report["token_identical_8bit"], \
        "8-bit quant-resident decode diverged from bf16 path"


if __name__ == "__main__":
    main()
