"""CI bench regression gate: compare a fresh A/B against the committed
baseline and fail on a >30% regression.

Only RATIO metrics are compared — both sides of each ratio are measured
on the SAME machine in the same process, so the ratios are portable
between this repo's container and a CI runner, unlike absolute
tokens/s.  Three bench kinds are gated (``--kind``):

  * ``batched`` (default, BENCH_batched_decode.json):
    ``aggregate_decode_speedup`` must not fall more than ``--tol``
    below the baseline's, ``fg_ttft_ratio_batch4_vs_serial`` must not
    rise more than ``--tol`` above it.
  * ``quant`` (BENCH_quant_resident.json): ``switch_in_speedup``
    (full-dequant over quant-resident switch-in) must not fall more
    than ``--tol`` below the baseline's, and the 8-bit token-identity
    probe must still hold.
  * ``paged`` (BENCH_paged_pool.json): ``switch_in_speedup`` (slot
    over paged switch-in) must not fall below the floor, the
    join/leave ``change_round_cost_ratio`` must not rise above the
    ceiling, and both token-identity probes must hold.
  * ``scenario`` (BENCH_scenarios.json): the loadgen smoke scenario's
    VIRTUAL-time QoS (deterministic in the seed, so portable like the
    ratios): the same-seed determinism probe must hold, no stream may
    be stuck, the budget invariant must hold, foreground TTFT p95 and
    bytes-moved-per-token must not rise above the ceiling, and
    tokens-per-round must not fall below the floor.  The ``faults``
    subsection (DESIGN.md §6) gates the fault-injection leg on the
    FRESH run alone (pure identity checks, no baseline ratio):
    flaky_disk must be same-seed deterministic with faults actually
    injected and recovered, zero failed foreground calls, and decoded
    tokens byte-identical to the fault-free run; disk_full_churn must
    enter AND exit degraded mode with zero failed foreground calls.
    The ``zoo`` subsection gates the heterogeneous model zoo: the
    mixed_zoo scenario must be same-seed deterministic with >= 3
    families served and zero failed calls, every family's decoded
    tokens identical to that family served solo, the MLA member's
    8-bit quant-resident latent chunks token-identical to the
    full-dequant leg, and its resident bytes well below the bf16
    payload's.

The committed JSONs carry a ``reduced`` section recorded with the CI
trace size; the gate compares like against like.

  PYTHONPATH=src:. python benchmarks/check_regression.py \
      --fresh /tmp/fresh.json [--kind batched] \
      [--baseline BENCH_batched_decode.json]
"""
from __future__ import annotations

import argparse
import json
import sys

DEFAULT_BASELINES = {
    "batched": "BENCH_batched_decode.json",
    "quant": "BENCH_quant_resident.json",
    "paged": "BENCH_paged_pool.json",
    "scenario": "BENCH_scenarios.json",
}


def section(doc: dict) -> dict:
    """The comparable metrics of a bench JSON (reduced section if the
    file is a full run that embeds one)."""
    return doc.get("reduced", doc)


def _floor(failures, name, base, new, tol):
    floor = base * (1.0 - tol)
    if new < floor:
        failures.append(
            f"{name} regressed: {new:.2f} vs baseline {base:.2f} "
            f"(floor {floor:.2f} at tol {tol:.0%})")


def _ceiling(failures, name, base, new, tol):
    ceil = base * (1.0 + tol)
    if new > ceil:
        failures.append(
            f"{name} regressed: {new:.3f} vs baseline {base:.3f} "
            f"(ceiling {ceil:.3f} at tol {tol:.0%})")


def _identity(failures, name, new):
    if not new.get(name, False):
        failures.append(f"{name} no longer holds")


def _check_faults(failures: list, report: dict, faults: dict | None):
    """Fault-leg assertions (fresh run only — identity checks, not
    ratios).  A fresh JSON without the section fails: the leg must run."""
    if not faults:
        failures.append("fault section missing from fresh scenario bench")
        return
    fl, df = faults.get("flaky", {}), faults.get("disk_full", {})
    _identity(failures, "determinism_holds", fl)
    _identity(failures, "recovery_token_identical", fl)
    if not fl.get("faults_injected_total", 0):
        failures.append("flaky_disk injected zero faults (dead failpoints)")
    if not fl.get("chunks_recovered_recompute", 0):
        failures.append("flaky_disk recovered zero chunks (recovery "
                        "path never exercised)")
    if fl.get("errors_fg", 0):
        failures.append(f"flaky_disk failed {fl['errors_fg']} "
                        "foreground call(s)")
    if fl.get("recover_failed", 0):
        failures.append(f"flaky_disk recover_failed={fl['recover_failed']}")
    if not df.get("degraded_entries", 0):
        failures.append("disk_full_churn never entered degraded mode")
    if not df.get("degraded_exits", 0):
        failures.append("disk_full_churn never exited degraded mode")
    if df.get("degraded_mode", False):
        failures.append("disk_full_churn finished still degraded")
    if df.get("errors_fg", 0):
        failures.append(f"disk_full_churn failed {df['errors_fg']} "
                        "foreground call(s)")
    report.update(
        flaky_injected=fl.get("faults_injected_total", 0),
        flaky_recovered=fl.get("chunks_recovered_recompute", 0),
        flaky_token_identical=fl.get("recovery_token_identical", False),
        disk_full_entries=df.get("degraded_entries", 0),
        disk_full_exits=df.get("degraded_exits", 0))


def _check_mixed_zoo(failures: list, report: dict, zoo: dict | None):
    """Zoo-leg assertions (fresh run only — identity checks).  A fresh
    JSON without the section fails: the heterogeneous-zoo leg must run."""
    if not zoo:
        failures.append("zoo section missing from fresh scenario bench")
        return
    _identity(failures, "determinism_holds", zoo)
    _identity(failures, "solo_vs_mixed_identical", zoo)
    served = zoo.get("families_served", {})
    if len(served) < 3:
        failures.append(f"mixed_zoo served {len(served)} families "
                        f"({sorted(served)}); need >= 3")
    if not all(served.values()):
        failures.append(f"mixed_zoo has idle families: {served}")
    if zoo.get("errors", 0) or zoo.get("errors_fg", 0):
        failures.append(f"mixed_zoo failed calls: errors="
                        f"{zoo.get('errors', 0)} "
                        f"errors_fg={zoo.get('errors_fg', 0)}")
    if zoo.get("stuck_streams", 0):
        failures.append(f"mixed_zoo stuck_streams={zoo['stuck_streams']}")
    _identity(failures, "budget_ok", zoo)
    mla = zoo.get("mla") or {}
    _identity(failures, "token_identical_8bit", mla)
    ratio = mla.get("bytes_ratio_bf16_over_quant", 0.0)
    if ratio < 1.2:
        failures.append(
            f"MLA quant-resident latent chunks no longer shrink resident "
            f"bytes: bf16/quant ratio {ratio:.2f} < 1.2")
    report.update(
        zoo_families=sorted(served),
        zoo_solo_vs_mixed_identical=zoo.get("solo_vs_mixed_identical",
                                            False),
        zoo_mla_token_identical=mla.get("token_identical_8bit", False),
        zoo_mla_bytes_ratio=ratio)


def check(kind: str, baseline: dict, fresh: dict, tol: float):
    base, new = section(baseline), section(fresh)
    failures: list = []
    report = {"kind": kind, "tolerance": tol}

    if kind == "batched":
        _floor(failures, "aggregate decode speedup",
               base["aggregate_decode_speedup"],
               new["aggregate_decode_speedup"], tol)
        _ceiling(failures, "foreground TTFT ratio",
                 base["fg_ttft_ratio_batch4_vs_serial"],
                 new["fg_ttft_ratio_batch4_vs_serial"], tol)
        report.update(
            baseline_speedup=base["aggregate_decode_speedup"],
            fresh_speedup=new["aggregate_decode_speedup"],
            baseline_fg_ttft_ratio=base["fg_ttft_ratio_batch4_vs_serial"],
            fresh_fg_ttft_ratio=new["fg_ttft_ratio_batch4_vs_serial"])
    elif kind == "quant":
        _floor(failures, "quant-resident switch-in speedup",
               base["switch_in_speedup"], new["switch_in_speedup"], tol)
        _identity(failures, "token_identical_8bit", new)
        report.update(baseline_speedup=base["switch_in_speedup"],
                      fresh_speedup=new["switch_in_speedup"])
    elif kind == "paged":
        _floor(failures, "paged-pool switch-in speedup",
               base["switch_in_speedup"], new["switch_in_speedup"], tol)
        _ceiling(failures, "join/leave round cost ratio",
                 base["join_leave"]["change_round_cost_ratio"],
                 new["join_leave"]["change_round_cost_ratio"], tol)
        _identity(failures, "token_identical_batch1", new)
        _identity(failures, "token_identical_batch4", new)
        report.update(
            baseline_speedup=base["switch_in_speedup"],
            fresh_speedup=new["switch_in_speedup"],
            baseline_join_ratio=base["join_leave"][
                "change_round_cost_ratio"],
            fresh_join_ratio=new["join_leave"]["change_round_cost_ratio"])
    elif kind == "scenario":
        _identity(failures, "determinism_holds", new)
        _identity(failures, "budget_ok", new)
        if new.get("stuck_streams", 0):
            failures.append(
                f"stuck_streams: {new['stuck_streams']} generations "
                f"never finished")
        _ceiling(failures, "foreground TTFT p95 (virtual)",
                 base["fg_ttft_p95_s"], new["fg_ttft_p95_s"], tol)
        _ceiling(failures, "bytes moved per token",
                 base["bytes_moved_per_token"],
                 new["bytes_moved_per_token"], tol)
        _floor(failures, "tokens per round",
               base["tokens_per_round"], new["tokens_per_round"], tol)
        report.update(
            baseline_fg_ttft_p95=base["fg_ttft_p95_s"],
            fresh_fg_ttft_p95=new["fg_ttft_p95_s"],
            baseline_bytes_per_token=base["bytes_moved_per_token"],
            fresh_bytes_per_token=new["bytes_moved_per_token"],
            baseline_tokens_per_round=base["tokens_per_round"],
            fresh_tokens_per_round=new["tokens_per_round"])
        _check_faults(failures, report, new.get("faults"))
        _check_mixed_zoo(failures, report, new.get("zoo"))
    else:
        raise SystemExit(f"unknown bench kind: {kind}")

    report["failures"] = failures
    return failures, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default="batched",
                    choices=sorted(DEFAULT_BASELINES))
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--tol", type=float, default=0.30)
    args = ap.parse_args()
    with open(args.baseline or DEFAULT_BASELINES[args.kind]) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    failures, report = check(args.kind, baseline, fresh, args.tol)
    print(json.dumps(report, indent=1))
    if failures:
        for msg in failures:
            print(f"REGRESSION: {msg}", file=sys.stderr)
        sys.exit(1)
    print("bench regression gate: OK")


if __name__ == "__main__":
    main()
