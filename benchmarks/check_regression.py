"""CI bench regression gate: compare a fresh batched-decode A/B against
the committed baseline and fail on a >30% regression.

Only RATIO metrics are compared — both are measured serial-vs-batch on
the SAME machine in the same process, so they are portable between this
repo's container and a CI runner, unlike absolute tokens/s:

  * ``aggregate_decode_speedup`` (batch-4 over serial throughput) must
    not fall more than ``--tol`` below the baseline's,
  * ``fg_ttft_ratio_batch4_vs_serial`` (lower = batching protects
    foreground TTFT) must not rise more than ``--tol`` above it.

The committed BENCH_batched_decode.json carries a ``reduced`` section
recorded with the CI trace size; the gate compares like against like.

  PYTHONPATH=src:. python benchmarks/check_regression.py \
      --fresh /tmp/fresh.json [--baseline BENCH_batched_decode.json]
"""
from __future__ import annotations

import argparse
import json
import sys


def section(doc: dict) -> dict:
    """The comparable metrics of a bench JSON (reduced section if the
    file is a full run that embeds one)."""
    return doc.get("reduced", doc)


def check(baseline: dict, fresh: dict, tol: float):
    base, new = section(baseline), section(fresh)
    failures = []

    b_sp = base["aggregate_decode_speedup"]
    f_sp = new["aggregate_decode_speedup"]
    floor = b_sp * (1.0 - tol)
    if f_sp < floor:
        failures.append(
            f"aggregate decode speedup regressed: {f_sp:.2f}x vs baseline "
            f"{b_sp:.2f}x (floor {floor:.2f}x at tol {tol:.0%})")

    b_tt = base["fg_ttft_ratio_batch4_vs_serial"]
    f_tt = new["fg_ttft_ratio_batch4_vs_serial"]
    ceil = b_tt * (1.0 + tol)
    if f_tt > ceil:
        failures.append(
            f"foreground TTFT ratio regressed: {f_tt:.3f} vs baseline "
            f"{b_tt:.3f} (ceiling {ceil:.3f} at tol {tol:.0%})")

    report = {
        "baseline_speedup": b_sp, "fresh_speedup": f_sp,
        "baseline_fg_ttft_ratio": b_tt, "fresh_fg_ttft_ratio": f_tt,
        "tolerance": tol, "failures": failures,
    }
    return failures, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_batched_decode.json")
    ap.add_argument("--fresh", required=True)
    ap.add_argument("--tol", type=float, default=0.30)
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)
    failures, report = check(baseline, fresh, args.tol)
    print(json.dumps(report, indent=1))
    if failures:
        for msg in failures:
            print(f"REGRESSION: {msg}", file=sys.stderr)
        sys.exit(1)
    print("bench regression gate: OK")


if __name__ == "__main__":
    main()
