"""Paper Fig. 11: max active contexts under a 0.5 ms switching constraint
across maximal context lengths (fixed budget)."""
from __future__ import annotations

from benchmarks.common import csv_line
from benchmarks.fig10_budgets import max_from_sweep, sweep

LENGTHS = (128, 256, 512)


def run(quick: bool = False):
    rows = {}
    lens = LENGTHS[:2] if quick else LENGTHS
    counts = (2, 4) if quick else (2, 6, 12)
    for policy in ("llms", "vllm_sq"):
        for max_ctx in lens:
            xs, ys = sweep(policy, 1_200_000, counts=counts,
                           max_ctx=max_ctx, scale=0.04 * max_ctx / 256)
            n = max_from_sweep(xs, ys, 0.5)
            rows[(policy, max_ctx)] = n
            csv_line(f"fig11/{policy}/ctx{max_ctx}", n * 1e6,
                     f"max_contexts={n:.2f}")
    return rows


if __name__ == "__main__":
    run()
