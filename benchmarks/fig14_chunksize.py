"""Paper Fig. 14: influence of chunk size on switching latency (too small
wastes I/O bandwidth per op; too large swaps redundant data)."""
from __future__ import annotations

from benchmarks.common import bench_events, csv_line, make_service, replay

SIZES = (4, 8, 16, 32, 64)


def run(quick: bool = False):
    sizes = (8, 16, 32) if quick else SIZES
    n_ctx, n_calls = (4, 12) if quick else (6, 22)
    budget = 500_000
    events = bench_events(n_ctx, n_calls, pattern="markov", seed=5)
    rows = {}
    for cs in sizes:
        svc = make_service("llms", budget, chunk_tokens=cs)
        st = replay(svc, events)
        svc.close()
        rows[cs] = st
        csv_line(f"fig14/chunk{cs}", st["switch_mean_s"] * 1e6,
                 f"p99_us={st['switch_p99_s']*1e6:.0f}")
    return rows


if __name__ == "__main__":
    run()
