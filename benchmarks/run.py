"""Benchmark entry point: one module per paper figure + the roofline
table from the dry-run artifacts.

  PYTHONPATH=src:. python -m benchmarks.run [--quick] [--only fig9,...]

Every line printed by a figure module is ``name,us_per_call,derived``.
Results are also written to reports/bench_results.json.
"""
from __future__ import annotations

import argparse
import json
import os
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig9,fig12")
    args = ap.parse_args()

    from benchmarks import (fig9_switching, fig10_budgets, fig11_ctxlen,
                            fig12_compression, fig13_ablation,
                            fig14_chunksize, fig15_stability)
    modules = {
        "fig9": fig9_switching, "fig10": fig10_budgets,
        "fig11": fig11_ctxlen, "fig12": fig12_compression,
        "fig13": fig13_ablation, "fig14": fig14_chunksize,
        "fig15": fig15_stability,
    }
    only = set(args.only.split(",")) if args.only else None
    results, failures = {}, []
    for name, mod in modules.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# === {name} ({mod.__doc__.splitlines()[0].strip()}) ===")
        try:
            mod.run(quick=args.quick)
            results[name] = {"wall_s": round(time.time() - t0, 1)}
        except Exception as e:            # noqa: BLE001
            failures.append((name, repr(e)))
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.0f}s")

    # roofline table (from dry-run artifacts, if present)
    try:
        from benchmarks.roofline import load_all
        rows = load_all("16x16")
        if rows:
            print("# === roofline (16x16, from reports/) ===")
            for r in rows:
                print(f"roofline/{r['arch']}/{r['shape']},"
                      f"{r['bound_s']*1e6:.1f},"
                      f"dominant={r['dominant']};"
                      f"frac={r['roofline_frac']:.3f}")
            results["roofline_cells"] = len(rows)
    except Exception as e:                # noqa: BLE001
        failures.append(("roofline", repr(e)))

    # §Perf hillclimb variants: before/after HLO collective bytes
    try:
        import glob as _glob
        import json as _json
        for vf in sorted(_glob.glob("reports/dryrun_*_16x16_*.json")):
            v = _json.load(open(vf))
            base_f = vf.replace(f"_{v['variant']}", "")
            if v["status"] != "ok" or not os.path.exists(base_f):
                continue
            b = _json.load(open(base_f))
            print(f"perf/{v['arch']}/{v['shape']}/{v['variant']},"
                  f"{v['collectives']['total']/2**20*1e3:.0f},"
                  f"coll_MiB={v['collectives']['total']/2**20:.1f};"
                  f"baseline_MiB={b['collectives']['total']/2**20:.1f};"
                  f"bytes={v['bytes_accessed']:.3g}")
    except Exception as e:                # noqa: BLE001
        failures.append(("perf-variants", repr(e)))

    os.makedirs("reports", exist_ok=True)
    with open("reports/bench_results.json", "w") as f:
        json.dump({"results": results, "failures": failures}, f, indent=1)
    if failures:
        print("# FAILURES:", failures)
        raise SystemExit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
