"""Paper Fig. 10: max active contexts under a switching-latency
constraint, across memory budgets.  We sweep context counts per budget
and report the largest count whose mean switch latency meets the
constraint (linear interpolation between sweep points)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_events, csv_line, make_service, replay

BUDGETS = (600_000, 1_200_000, 2_400_000)
COUNTS = (2, 6, 12, 18)
LIMITS_MS = (0.5, 2.0)


def sweep(policy: str, budget: int, counts=COUNTS, max_ctx: int = 256,
          scale: float = 0.06):
    xs, ys = [], []
    for n in counts:
        events = bench_events(n, 3 * n, pattern="random", seed=n,
                              scale=scale)
        svc = make_service(policy, budget, max_ctx=max_ctx)
        st = replay(svc, events)
        svc.close()
        xs.append(n)
        ys.append(st["switch_mean_s"] * 1e3)
    return np.asarray(xs, float), np.asarray(ys, float)


def max_from_sweep(xs, ys, limit_ms: float) -> float:
    if ys[0] > limit_ms:
        return 0.0
    ok = ys <= limit_ms
    if ok.all():
        return float(xs[-1])
    i = int(np.argmax(~ok))
    x0, x1, y0, y1 = xs[i - 1], xs[i], ys[i - 1], ys[i]
    return float(x0 + (limit_ms - y0) * (x1 - x0) / max(y1 - y0, 1e-9))


def run(quick: bool = False):
    budgets = BUDGETS[:2] if quick else BUDGETS
    counts = (2, 4, 8) if quick else COUNTS
    rows = {}
    for policy in ("llms", "vllm_sq"):
        for budget in budgets:
            xs, ys = sweep(policy, budget, counts)
            for limit in LIMITS_MS[:1] if quick else LIMITS_MS:
                n = max_from_sweep(xs, ys, limit)
                rows[(policy, budget, limit)] = n
                csv_line(f"fig10/{policy}/budget{budget}/limit{limit}ms",
                         n * 1e6, f"max_contexts={n:.2f}")
    return rows


if __name__ == "__main__":
    run()
