"""A/B: continuous batching over the paged, unified KV pool vs the
per-slot working caches.

Same llms policy, same byte budget, same trace — the only difference
is ``paged_pool``.  The slot baseline pays a scatter into a per-slot
working cache at EVERY switch-in (quant-resident: int8 memcpy for
8-bit chunks, a dequant pass for the rest); the pool keeps chunks
resident in one global page arena, so a steady-state switch-in is a
page-table read — admissions happen once per chunk lifetime, and
re-encoded tail chunks re-admit ahead of time at swap-out.  Reports:

  * steady-state switch-in latency per leg (timed restore + assembly),
    the in-process speedup between the legs, and the speedup against
    the COMMITTED quant-resident slot baseline in
    BENCH_quant_resident.json (the ~7 ms this change attacks; must
    come out >= 5x),
  * join/leave decode-round cost: per-round batched-decode wall time
    in rounds whose batch membership just changed vs steady-membership
    rounds — a join/leave only rewrites page-table rows, so the ratio
    must stay ~1 (the previous engine paid a cache merge/split here),
  * token identity probes: the paged path must emit exactly the slot
    path's tokens at decode_batch=1 and decode_batch=4.

  PYTHONPATH=src:. python benchmarks/paged_pool.py \
      [--out BENCH_paged_pool.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import DISK_BW, DISK_LAT, bench_model, make_service
from repro.core.restore import set_disk_throttle
from repro.core.scheduler import ServiceRouter

N_CTX = 12
ROUNDS = 3
PROMPT = 48
MAX_NEW = 8
BUDGET = 2 << 20
COMMITTED_BASELINE = "BENCH_quant_resident.json"


def run_leg(paged: bool, budget: int = BUDGET):
    """One steady-state switch-in measurement (the quant_resident.py
    protocol: warm + shape-trace passes, then ROUNDS measured rounds
    over N_CTX interleaved contexts)."""
    cfg, _, _ = bench_model()
    svc = make_service("llms", budget, quant_resident=True,
                       paged_pool=paged)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab, PROMPT).tolist()
               for _ in range(N_CTX)]
    with svc:
        stubs = [svc.newLLMCtx() for _ in range(N_CTX)]

        def one_round(r, max_new=MAX_NEW):
            toks = []
            for stub, p in zip(stubs, prompts):
                toks.append(svc.callLLM(stub, p[r:r + 8], max_new)[1])
            return toks

        set_disk_throttle(None)             # warm pass: compile everything
        one_round(0)
        wstubs = [svc.newLLMCtx() for _ in range(2)]
        for r in range(2 * ROUNDS + 1):
            for stub in wstubs:
                svc.callLLM(stub, prompts[0][r:r + (8 if r else PROMPT)],
                            MAX_NEW)
        for stub in wstubs:
            svc.delLLMCtx(stub)
        for r in range(ROUNDS):
            one_round(1 + r)
        svc.records.clear()
        set_disk_throttle(DISK_BW, DISK_LAT)

        t0 = time.perf_counter()
        all_toks = [one_round(1 + ROUNDS + r) for r in range(ROUNDS)]
        wall = time.perf_counter() - t0

        recs = svc.records
        sw = [r["switch_s"] + r["assemble_s"] for r in recs]
        gen = sum(len(t) for toks in all_toks for t in toks)
        out = {
            "paged_pool": paged,
            "budget_bytes": budget,
            "calls": len(recs),
            "switch_in_mean_ms": round(float(np.mean(sw)) * 1e3, 4),
            "switch_in_median_ms": round(float(np.median(sw)) * 1e3, 4),
            "switch_in_p95_ms": round(
                float(np.percentile(sw, 95)) * 1e3, 4),
            "generated_tokens": gen,
            "decode_tokens_per_s": round(gen / wall, 2),
        }
        if paged:
            out.update({k: v for k, v in svc.stats().items()
                        if k.startswith("pool_")})
    return out, all_toks


def join_leave_probe():
    """Continuous batching: time every batched decode round of a mixed
    short/long routed workload at decode_batch=4 and compare rounds
    whose membership just changed against steady-membership rounds."""
    cfg, _, _ = bench_model()
    svc = make_service("llms", 64 << 20, decode_batch=4,
                       quant_resident=True, profile=False)
    set_disk_throttle(None)
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, cfg.vocab, 24).tolist() for _ in range(12)]
    rounds = []                             # (seconds, member cid set)
    orig = svc.decode_step_batch

    def timed(states):
        t0 = time.perf_counter()
        out = orig(states)
        rounds.append((time.perf_counter() - t0,
                       frozenset(s.ctx.cid for s in states)))
        return out

    with svc:
        def one_pass(measure):
            svc.decode_step_batch = timed if measure else orig
            with ServiceRouter(svc, predict=False,
                               slice_steps=4) as router:
                app = router.register_app("a", "fg")
                streams = [app.stream(app.new_ctx(), p,
                                      max_new_tokens=6 + 10 * (i % 2))
                           for i, p in enumerate(prompts)]
                router.drain()
                for s in streams:
                    s.result()
            return router

        one_pass(False)                     # warm: compile every bucket
        rounds.clear()
        router = one_pass(True)

    steady, changed = [], []
    for i, (dt, members) in enumerate(rounds):
        if i == 0:
            continue
        (changed if members != rounds[i - 1][1] else steady).append(dt)
    return {
        "decode_rounds": len(rounds),
        "membership_change_rounds": len(changed),
        "joins_mid_slice": router.joins_mid_slice,
        "steady_round_mean_ms": round(float(np.mean(steady)) * 1e3, 4),
        "change_round_mean_ms": round(float(np.mean(changed)) * 1e3, 4),
        "change_round_cost_ratio": round(
            float(np.median(changed) / np.median(steady)), 3),
    }


def identity_probe(decode_batch: int) -> bool:
    """Paged vs slot tokens, greedy, same prompts/trace."""
    cfg, _, _ = bench_model()
    rng = np.random.RandomState(5)
    prompts = [rng.randint(1, cfg.vocab, 24).tolist() for _ in range(6)]
    toks = {}
    for paged in (True, False):
        svc = make_service("llms", 64 << 20, decode_batch=decode_batch,
                           quant_resident=True, paged_pool=paged,
                           profile=False)
        set_disk_throttle(None)
        with svc:
            if decode_batch == 1:
                out = []
                stubs = [svc.newLLMCtx() for _ in prompts]
                for r in range(2):          # round 2 = switch-in path
                    for stub, p in zip(stubs, prompts):
                        out.append(svc.callLLM(stub, p[r:], 6)[1])
            else:
                with ServiceRouter(svc, predict=False,
                                   slice_steps=2) as router:
                    app = router.register_app("a", "fg")
                    streams = [app.stream(app.new_ctx(), p,
                                          max_new_tokens=6)
                               for p in prompts]
                    router.drain()
                    out = [s.result() for s in streams]
        toks[paged] = out
    return toks[True] == toks[False]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_paged_pool.json")
    args = ap.parse_args()

    slot, _ = run_leg(False)
    paged, _ = run_leg(True)
    join_leave = join_leave_probe()
    ident1 = identity_probe(1)
    ident4 = identity_probe(4)

    committed_ms = None
    if os.path.exists(COMMITTED_BASELINE):
        with open(COMMITTED_BASELINE) as f:
            committed_ms = json.load(f)["quant_resident"][
                "switch_in_mean_ms"]

    paged_ms = paged["switch_in_mean_ms"]
    report = {
        "trace": {"contexts": N_CTX, "rounds": ROUNDS,
                  "prompt_tokens": PROMPT, "max_new": MAX_NEW,
                  "policy": "llms", "quant_resident": True,
                  "budget_bytes": BUDGET},
        "slot_baseline": slot,
        "paged_pool": paged,
        "switch_in_speedup": round(
            slot["switch_in_mean_ms"] / max(paged_ms, 1e-9), 2),
        "committed_quant_baseline_ms": committed_ms,
        "switch_in_speedup_vs_committed": (
            round(committed_ms / max(paged_ms, 1e-9), 2)
            if committed_ms is not None else None),
        "join_leave": join_leave,
        "token_identical_batch1": bool(ident1),
        "token_identical_batch4": bool(ident4),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(json.dumps(report, indent=1))

    assert ident1, "paged decode diverged from slot path at batch 1"
    assert ident4, "paged decode diverged from slot path at batch 4"
    assert join_leave["change_round_cost_ratio"] < 1.5, \
        "membership-change rounds pay a merge-like cost"
    if committed_ms is not None:
        assert committed_ms / max(paged_ms, 1e-9) >= 5.0, \
            f"steady-state switch-in {paged_ms} ms is not >=5x faster " \
            f"than the committed {committed_ms} ms slot baseline"


if __name__ == "__main__":
    main()
