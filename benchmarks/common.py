"""Shared benchmark harness.

Regime calibration: the paper's effects need ``recompute >> per-chunk
I/O >> free`` (their phone: 22.9 s context recompute vs ~100 MB/s-class
storage).  On this container we (a) use a ~8M-param llama-style bench
model so a full-context recompute costs ~0.4 s, and (b) throttle the
swap tier to 25 MB/s + 0.2 ms/op (the paper's SATA/UFS class) — without
the throttle the page cache would make every policy look identical.

Replays are compressed-time (arrival gaps bookkept, not slept); gaps
longer than ``idle_flush_s`` let the async AoT writes complete, which is
how calling-rate sensitivity (fig15) manifests.  A full warm pass runs
first so jit compilation never lands in the measured pass.
"""
from __future__ import annotations

import tempfile
from typing import Dict, Optional

import jax

from repro.configs import get_config
from repro.core.restore import set_disk_throttle
from repro.core.service import LLMSConfig, LLMService
from repro.loadgen import replay_trace
from repro.models.registry import build_model
from repro.trace.synth import synthesize

DISK_BW = 25e6          # bytes/s (SATA/UFS class, paper Table 2)
DISK_LAT = 2e-4

_MODEL_CACHE = {}


def bench_model(arch: str = "llama2-7b"):
    """~8M-param llama-architecture model (the paper's model, scaled)."""
    if arch not in _MODEL_CACHE:
        cfg = get_config(arch).with_overrides(
            name=arch + "-bench", n_layers=6, d_model=256, n_heads=8,
            n_kv_heads=4, head_dim=32, d_ff=1024, vocab=4096, max_seq=1024)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _MODEL_CACHE[arch] = (cfg, model, params)
    return _MODEL_CACHE[arch]


def make_service(policy: str, budget: int, max_ctx: int = 256,
                 chunk_tokens: int = 16, arch: str = "llama2-7b",
                 profile: bool = True, ratio_global: float = 0.5,
                 decode_batch: int = 1, quant_resident: bool = False,
                 paged_pool: bool = True) -> LLMService:
    cfg, model, params = bench_model(arch)
    set_disk_throttle(DISK_BW, DISK_LAT)
    sc = LLMSConfig(policy=policy, max_ctx_len=max_ctx,
                    chunk_tokens=chunk_tokens, memory_budget=budget,
                    ratio_global=ratio_global, decode_batch=decode_batch,
                    quant_resident=quant_resident, paged_pool=paged_pool,
                    swap_dir=tempfile.mkdtemp(prefix=f"llms_{policy}_"))
    svc = LLMService(model, params, sc)
    if profile and sc.use_pipeline:
        set_disk_throttle(DISK_BW, DISK_LAT)
        svc.profile_pipeline()
    return svc


def replay(svc: LLMService, events, max_new: int = 4,
           idle_flush_s: Optional[float] = 60.0, warm: bool = True,
           predict: bool = False) -> Dict[str, float]:
    """Replay through a single-app ServiceRouter session (inline dispatch:
    events stay in strict trace order, so records are like-for-like with
    the pre-router harness).  ``predict=True`` additionally enables the
    router's next-context prediction -> AoT swap-out hints.

    Thin wrapper over ``repro.loadgen.replay_trace`` — the repo's single
    replay implementation — pinned to this harness's throttle regime."""
    return replay_trace(svc, events, mode="serial", max_new=max_new,
                        idle_flush_s=idle_flush_s, warm=warm,
                        predict=predict,
                        measured_throttle=(DISK_BW, DISK_LAT))


def bench_events(n_contexts: int, n_calls: int, pattern: str = "markov",
                 seed: int = 0, scale: float = 0.06,
                 rate_per_s: float = 1 / 300.0,
                 arch: str = "llama2-7b"):
    cfg, _, _ = bench_model(arch)
    return synthesize(n_contexts, n_calls, cfg.vocab, pattern=pattern,
                      scale=scale, seed=seed, rate_per_s=rate_per_s)


def csv_line(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line)
    return line
