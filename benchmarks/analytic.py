"""Analytic roofline cost model (primary source for §Roofline).

Why analytic: XLA-CPU ``cost_analysis()`` counts while-loop bodies ONCE,
so any scanned model (all of ours — layers, microbatches, CE blocks,
flash blocks are lax.scan) is undercounted by the trip product.  The
dry-run JSONs keep the HLO per-iteration numbers for reference; the
roofline terms below are computed from the architecture math and the
sharding design — every formula is stated here and checkable.

Conventions (per chip, per step):
  FLOPs:
    matmul fwd        2 * N_active * tokens
    attention         4 * B * S * ctx_avg * H * hd * L_attn  (QK^T + PV)
    train multiplier  fwd(1) + remat replays (1 for 1-level, 2 for 2-level)
                      + bwd(2) -> 4x or 5x the fwd matmul term
  HBM bytes:
    weights           params_bytes / chips, read once per fwd replay
    KV cache (decode) full cache read per emitted token (+ write of 1 tok)
    activations       2 bytes * tokens * d * L * rw_factor
    optimizer (train) read+write moments and params
  Collective bytes (from the sharding design, ring algorithms):
    FSDP all-gather   params_bytes / tp  per fwd replay
    grad reduce+param scatter (train)  2 * params_bytes_fp32 / tp
    MoE a2a           2 * 2bytes * tokens_local * d * topk (dispatch+combine)
    decode seq-shard  per-layer (B_loc, H, hd) partial-softmax all-reduce
"""
from __future__ import annotations

from typing import Dict

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeSpec

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
BF16 = 2


def _mesh_dims(mesh: str):
    if mesh == "2x16x16":
        return 512, 32, 16          # chips, dp, tp
    return 256, 16, 16


def _attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "rwkv6":
        return 0
    if cfg.family == "rglru_hybrid":
        return cfg.n_layers // 3
    if cfg.family == "encdec":
        return cfg.n_layers * 2 + cfg.encoder.n_layers   # self+cross+enc
    return cfg.n_layers


def _ctx_avg(cfg: ModelConfig, shape: ShapeSpec, window: int) -> float:
    S = min(shape.seq_len, cfg.max_seq) if cfg.family == "encdec" \
        else shape.seq_len
    if shape.kind == "decode":
        return min(S, window) if window else S
    half = S / 2
    return min(half, window) if window else half


def _window(cfg: ModelConfig, shape: ShapeSpec) -> int:
    if cfg.family == "rglru_hybrid":
        return cfg.rglru.window
    if shape.name == "long_500k" and cfg.family in ("dense", "moe",
                                                    "mla_moe", "vlm"):
        return 8192
    return 0


def kv_bytes_total(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Whole decode-cache bytes (bf16) across chips."""
    S = min(shape.seq_len, cfg.max_seq) if cfg.family == "encdec" \
        else shape.seq_len
    per_tok = cfg.kv_bytes_per_token(BF16)
    state = 0.0
    if cfg.family == "rwkv6":
        r = cfg.rwkv
        state = cfg.n_layers * (cfg.n_heads * r.head_dim ** 2 * 4
                                + 2 * cfg.d_model * BF16)
    if cfg.family == "rglru_hybrid":
        g = cfg.rglru
        n_rec = cfg.n_layers - cfg.n_layers // 3
        state = n_rec * (g.lru_width * 4 + (g.conv_width - 1)
                         * g.lru_width * BF16)
        S = min(S, g.window)  # ring cache is window-sized... full alloc:
        S = shape.seq_len     # we allocate full length (spec-faithful)
    return shape.global_batch * (S * per_tok + state)


def analytic_cell(arch: str, shape_name: str, mesh: str = "16x16",
                  n_micro: int = 1, remat_replays: int = 2) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips, dp, tp = _mesh_dims(mesh)
    B = shape.global_batch
    S = min(shape.seq_len, cfg.max_seq) if cfg.family == "encdec" \
        else shape.seq_len
    window = _window(cfg, shape)
    N = cfg.active_param_count()
    pbytes = cfg.param_count() * BF16

    tokens = B if shape.kind == "decode" else B * S
    # batch shards over dp; every tp chip sees its full dp-shard of tokens
    tok_chip = tokens / dp

    ctx = _ctx_avg(cfg, shape, window)
    La = _attn_layers(cfg)
    attn_fwd = 4.0 * tokens * ctx * cfg.n_heads * cfg.head_dim * La
    mat_fwd = 2.0 * N * tokens

    if shape.kind == "train":
        mult = 1 + remat_replays + 2              # fwd + replays + bwd
        flops_tot = (mat_fwd + attn_fwd) * mult
        n_fwd_passes = (1 + remat_replays) * n_micro
    else:
        flops_tot = mat_fwd + attn_fwd
        n_fwd_passes = 1
    flops_chip = flops_tot / chips

    # ---- HBM bytes / chip ------------------------------------------- #
    w_chip = pbytes / chips
    act = 2.0 * tok_chip / tp * cfg.d_model * max(cfg.n_layers, 1) * BF16
    bytes_chip = w_chip * max(n_fwd_passes, 1) + act
    if shape.kind == "decode":
        bytes_chip += kv_bytes_total(cfg, shape) / chips
    if shape.kind == "train":
        opt_bytes = cfg.param_count() * (2 if True else 8)  # int8 m+v rw
        bytes_chip += 2 * (opt_bytes + pbytes) / chips

    # ---- collective bytes / chip -------------------------------------- #
    coll = pbytes / tp * max(n_fwd_passes, 1) * (dp - 1) / dp   # FSDP AG
    if shape.kind == "train":
        coll += 2.0 * cfg.param_count() * 4 / tp                # grad RS+AG
    if cfg.moe is not None:
        coll += 2 * 2 * BF16 * tok_chip * cfg.d_model * cfg.moe.top_k \
            * max(n_fwd_passes, 1)
    if shape.kind == "decode":
        # seq-sharded cache: per-layer partial-softmax combine
        coll += La * (B / dp) * cfg.n_heads * cfg.head_dim * 4 * 2

    t_comp = flops_chip / PEAK_FLOPS
    t_mem = bytes_chip / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    model_f = (6.0 if shape.kind == "train" else 2.0) * N * tokens
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom, "bound_s": terms[dom],
        "roofline_frac": t_comp / terms[dom] if terms[dom] else 0.0,
        "model_flops": model_f,
        "useful_ratio": model_f / (flops_tot or 1),
        "flops_chip": flops_chip, "bytes_chip": bytes_chip,
        "coll_chip": coll,
    }
