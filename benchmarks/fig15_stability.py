"""Paper Fig. 15: service stability.
(a) influence on LLM inference: per-call inference time with LLMS
    managing contexts vs the uncompressed baseline (expect within ~5%).
(b) sensitivity to calling frequency: switch latency at high vs low
    Poisson rates — fast arrivals leave no idle time for the async AoT
    writes to drain before the next switch-in contends for the disk.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_events, csv_line, make_service, replay

def run(quick: bool = False):
    n_ctx, n_calls = (3, 10) if quick else (4, 20)
    rows = {}

    # (a) inference-time influence, generous budget (no swapping at all)
    events = bench_events(n_ctx, n_calls, pattern="markov", seed=7)
    infer = {}
    for policy in ("llms", "vllm_s"):
        svc = make_service(policy, budget=64 << 20)
        replay(svc, events)
        infer[policy] = float(np.mean([r["infer_s"] for r in svc.records]))
        svc.close()
    delta = (infer["llms"] - infer["vllm_s"]) / infer["vllm_s"]
    rows["infer_delta"] = delta
    csv_line("fig15a/llms_infer", infer["llms"] * 1e6,
             f"vs_unmanaged={infer['vllm_s']*1e6:.0f}us;delta={delta:+.2%}")

    # (b) calling-rate sensitivity under pressure
    for rate, tag in ((1 / 300.0, "slow_5min"), (1 / 2.0, "fast_2s")):
        events = bench_events(n_ctx, n_calls, pattern="markov", seed=8,
                              rate_per_s=rate)
        svc = make_service("llms", budget=500_000)
        st = replay(svc, events, idle_flush_s=60.0)
        svc.close()
        rows[tag] = st
        csv_line(f"fig15b/{tag}", st["switch_mean_s"] * 1e6,
                 f"p99_us={st['switch_p99_s']*1e6:.0f}")
    return rows


if __name__ == "__main__":
    run()
