"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) cell, from reports/dryrun_*_16x16.json:

  compute term    = HLO_FLOPs_per_chip / 197e12        (bf16 peak / chip)
  memory term     = HLO_bytes_per_chip / 819e9         (HBM bw / chip)
  collective term = collective_bytes_per_chip / 50e9   (ICI / link)

cost_analysis() on the post-SPMD module reports PER-PARTITION flops and
bytes; collective bytes come from the HLO parse (ring multipliers, see
launch/dryrun.py).  MODEL_FLOPS = 6*N(_active)*D for train (fwd+bwd) and
2*N*D for inference cells; the ratio MODEL_FLOPS / (chips * HLO_FLOPs)
flags remat/redundancy waste (>1x expected under 2-level remat: the
recompute factor is visible, not hidden).

Usage: PYTHONPATH=src:. python -m benchmarks.roofline [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link
CHIPS = {"16x16": 256, "2x16x16": 512}


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * min(shape.seq_len,
                                        cfg.max_seq if cfg.family == "encdec"
                                        else shape.seq_len)
        return 2.0 * n_active * toks
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze(rec: Dict) -> Optional[Dict]:
    """Merge the analytic model (primary; see analytic.py for why) with
    the HLO-derived reference numbers (per-scan-iteration on XLA-CPU)."""
    if rec["status"] != "ok":
        return None
    from benchmarks.analytic import analytic_cell
    from repro.launch.dryrun import micro_steps
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    n_micro = micro_steps(cfg, shape, rec["mesh"] == "2x16x16") \
        if shape.kind == "train" else 1
    replays = 2 if cfg.n_layers >= 16 else 1
    row = analytic_cell(rec["arch"], rec["shape"], rec["mesh"],
                        n_micro=n_micro, remat_replays=replays)
    row["hlo_flops_periter"] = rec["flops"]
    row["hlo_bytes_periter"] = rec["bytes_accessed"]
    row["hlo_coll_periter"] = rec["collectives"]["total"]
    row["temp_gib"] = rec["memory"]["temp_size_in_bytes"] / 2**30
    row["args_gib"] = rec["memory"]["argument_size_in_bytes"] / 2**30
    return row


def load_all(mesh: str = "16x16", out_dir: str = "reports") -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir,
                                           f"dryrun_*_{mesh}.json"))):
        r = analyze(json.load(open(f)))
        if r:
            rows.append(r)
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| roofline frac | useful FLOP ratio | temp GiB/chip |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        body += (f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} "
                 f"| {r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} "
                 f"| **{r['dominant']}** | {r['roofline_frac']:.3f} "
                 f"| {r['useful_ratio']:.2f} | {r['temp_gib']:.2f} |\n")
    return hdr + body


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--write", default=None,
                    help="write markdown table to this file")
    args = ap.parse_args()
    rows = load_all(args.mesh)
    table = markdown_table(rows)
    print(table)
    by_dom = {}
    for r in rows:
        by_dom.setdefault(r["dominant"], []).append(r)
    for k, v in by_dom.items():
        print(f"# {k}-bound cells: {len(v)}")
    if args.write:
        with open(args.write, "w") as f:
            f.write(table)


if __name__ == "__main__":
    main()
