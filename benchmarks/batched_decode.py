"""A/B: serial (decode_batch=1) vs batched (decode_batch=4) decode.

Replays the same 4-app trace (1 foreground + 3 background sessions,
all submitted up front, inline dispatch for determinism) through the
ServiceRouter at both batch widths and reports AGGREGATE decode
throughput (generated tokens per wall second of the drain) plus the
per-priority TTFT numbers — the acceptance gate is >= 2x aggregate
throughput at batch 4 with foreground TTFT no worse than the sliced
serial path.

  PYTHONPATH=src:. python benchmarks/batched_decode.py \
      [--out BENCH_batched_decode.json] [--reduced]

``--reduced`` shrinks the trace (8 calls x 24 new tokens) for the CI
bench-regression smoke: ratios (speedup, fg-TTFT ratio) are
machine-portable, absolute tok/s are not — the committed baseline keeps
a ``reduced`` section recorded with the same settings for
``benchmarks/check_regression.py`` to gate against.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time


from benchmarks.common import bench_events, bench_model, make_service
from repro.core.restore import set_disk_throttle
from repro.core.scheduler import ServiceRouter

N_APPS = 4
N_CALLS = 24
MAX_NEW = 80
BUDGET = 4 << 20
SLICE_STEPS = 4


def run_pass(router, apps, events, stubs, session_of, max_new):
    streams = []
    t0 = time.perf_counter()
    for ev in events:
        sess = session_of[ev.ctx_id]
        streams.append(sess.stream(stubs[ev.ctx_id], ev.prompt.tolist(),
                                   max_new_tokens=max_new))
    router.drain()
    wall = time.perf_counter() - t0
    for s in streams:
        s.result()                      # surface failures
    return streams, wall


def bench(decode_batch: int, n_calls: int = N_CALLS, max_new: int = MAX_NEW):
    cfg, _, _ = bench_model()
    svc = make_service("llms", BUDGET, decode_batch=decode_batch)
    # one conversation per context, one context per call: N_CALLS
    # independent app conversations spread over N_APPS sessions — the
    # LLMaaS many-apps shape where batched decode has distinct contexts
    # to fill its slots with (same-context calls can never share a
    # batch, so a ctx-clustered trace measures the scheduler, not the
    # engine)
    events = [dataclasses.replace(ev, ctx_id=i) for i, ev in enumerate(
        bench_events(n_calls, n_calls, pattern="random", seed=0,
                     scale=0.03))]
    with svc, ServiceRouter(svc, predict=True, start=False,
                            slice_steps=SLICE_STEPS) as router:
        prios = ["foreground"] + ["background"] * (N_APPS - 1)
        apps = [router.register_app(f"app{i}", p)
                for i, p in enumerate(prios)]
        session_of = {ev.ctx_id: apps[ev.ctx_id % N_APPS] for ev in events}
        stubs = {cid: sess.new_ctx() for cid, sess in session_of.items()}

        set_disk_throttle(None)             # warm pass: compile everything
        run_pass(router, apps, events, stubs, session_of, max_new)
        svc.records.clear()
        router.call_records.clear()
        router.decode_rounds = router.decoded_tokens = 0
        set_disk_throttle(25e6, 2e-4)

        streams, wall = run_pass(router, apps, events, stubs, session_of,
                                 max_new)
        gen_tokens = sum(len(s.tokens) for s in streams)
        rst = router.stats()
        out = {
            "decode_batch": decode_batch,
            "wall_s": round(wall, 4),
            "generated_tokens": gen_tokens,
            "aggregate_tokens_per_s": round(gen_tokens / wall, 2),
            "decode_rounds": rst["decode_rounds"],
            "tokens_per_round": round(rst["tokens_per_round"], 3),
            "preemptions": rst["preemptions"],
        }
        for prio in ("foreground", "background"):
            if prio in rst:
                out[f"{prio}_ttft_mean_s"] = round(
                    rst[prio]["ttft_mean_s"], 4)
                out[f"{prio}_latency_mean_s"] = round(
                    rst[prio]["latency_mean_s"], 4)
    return out


REDUCED_CALLS = 8
REDUCED_MAX_NEW = 24


def run_ab(n_calls: int, max_new: int):
    serial = bench(1, n_calls, max_new)
    batched = bench(4, n_calls, max_new)
    speedup = (batched["aggregate_tokens_per_s"]
               / serial["aggregate_tokens_per_s"])
    return {
        "trace": {"apps": N_APPS, "contexts": n_calls, "calls": n_calls,
                  "max_new": max_new, "slice_steps": SLICE_STEPS,
                  "priority_mix": "1 fg : 3 bg"},
        "serial": serial,
        "batch4": batched,
        "aggregate_decode_speedup": round(speedup, 2),
        "fg_ttft_ratio_batch4_vs_serial": round(
            batched["foreground_ttft_mean_s"]
            / max(serial["foreground_ttft_mean_s"], 1e-9), 3),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_batched_decode.json")
    ap.add_argument("--reduced", action="store_true",
                    help="CI-sized trace only (the regression-gate A/B)")
    ap.add_argument("--calls", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    args = ap.parse_args()
    if args.reduced:
        n_calls = args.calls or REDUCED_CALLS
        max_new = args.max_new or REDUCED_MAX_NEW
        report = run_ab(n_calls, max_new)
    else:
        report = run_ab(args.calls or N_CALLS, args.max_new or MAX_NEW)
        # the CI regression gate replays the reduced A/B on a different
        # machine; only ratio metrics are portable, so record them here
        report["reduced"] = run_ab(REDUCED_CALLS, REDUCED_MAX_NEW)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
