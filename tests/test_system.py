"""End-to-end system behaviour: a short training run LEARNS, and the
serving stack replays a trace through the full LLMS lifecycle."""
import tempfile

import jax
import numpy as np

from conftest import tiny_model
from repro.core.service import LLMSConfig, LLMService
from repro.data.pipeline import SyntheticLM
from repro.launch.train import make_train_step
from repro.train.optimizer import OptConfig, init_state
from repro.trace.synth import synthesize


def test_training_reduces_loss():
    cfg, model, params = tiny_model("smollm-360m")
    opt = OptConfig(lr=5e-3, warmup_steps=5)
    step_fn = jax.jit(make_train_step(model, opt))
    data = SyntheticLM(vocab=cfg.vocab, seq=32, batch=8)
    state = init_state(params, opt)
    first = last = None
    for step in range(120):
        state, metrics = step_fn(state, data.batch_for_step(step))
        if step == 0:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first - 0.3, (first, last)


def test_microbatched_step_matches_plain():
    cfg, model, params = tiny_model("smollm-360m")
    opt = OptConfig(lr=1e-3, warmup_steps=1)
    data = SyntheticLM(vocab=cfg.vocab, seq=16, batch=4)
    batch = data.batch_for_step(0)
    s1 = init_state(params, opt)
    s2 = init_state(params, opt)
    out1, m1 = jax.jit(make_train_step(model, opt, n_micro=1))(s1, batch)
    out2, m2 = jax.jit(make_train_step(model, opt, n_micro=2))(s2, batch)
    d = jax.tree.map(lambda a, b: float(np.max(np.abs(
        np.asarray(a, np.float32) - np.asarray(b, np.float32)))),
        out1["params"], out2["params"])
    assert max(jax.tree.leaves(d)) < 2e-2


def test_serve_trace_end_to_end():
    cfg, model, params = tiny_model("smollm-360m")
    sc = LLMSConfig(policy="llms", max_ctx_len=128, memory_budget=40_000,
                    swap_dir=tempfile.mkdtemp())
    svc = LLMService(model, params, sc)
    svc.profile_pipeline()
    events = synthesize(3, 10, cfg.vocab, pattern="markov", scale=0.03,
                        seed=2)
    stubs = {}
    for ev in events:
        if ev.ctx_id not in stubs:
            stubs[ev.ctx_id] = svc.newLLMCtx()
        _, gen = svc.callLLM(stubs[ev.ctx_id], ev.prompt.tolist(),
                             max_new_tokens=3)
        assert len(gen) == 3
    st = svc.stats()
    assert st["calls"] == 10
    assert st["mem_used"] <= sc.memory_budget
    svc.close()
