"""Multi-context batched decode: decode_batch=1 token-identity with the
serial path, deterministic batch interleaving via pump(), slot
eviction under memory pressure, single-slot preemption while the rest
of the batch keeps decoding, and the router stop-check satellite."""
import tempfile
import time

import numpy as np
import pytest

from conftest import tiny_model
from repro.core.requests import GenerationRequest
from repro.core.scheduler import ServiceRouter
from repro.core.service import LLMSConfig, LLMService


def make_svc(decode_batch=1, policy="llms", budget=10_000_000, max_ctx=128):
    cfg, model, params = tiny_model("smollm-360m")
    sc = LLMSConfig(policy=policy, max_ctx_len=max_ctx,
                    memory_budget=budget, decode_batch=decode_batch,
                    swap_dir=tempfile.mkdtemp())
    return LLMService(model, params, sc), cfg


def prompts_for(cfg, n, length=10, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, cfg.vocab, length).tolist() for _ in range(n)]


# --------------------------------------------------------------------- #
# decode_batch=1 ≡ the serial seed path (the required invariant)
# --------------------------------------------------------------------- #
def test_batch1_token_identical_to_serial_path():
    """With decode_batch=1 and default SamplingParams the routed batch
    engine emits exactly the serial path's tokens (the singleton round
    routes through the very same jitted ``decode`` callable)."""
    svc_a, cfg = make_svc(decode_batch=1)
    svc_b, _ = make_svc(decode_batch=1)
    ps = prompts_for(cfg, 4, seed=3)
    with svc_a, svc_b:
        direct = []
        stubs_a = [svc_a.newLLMCtx() for _ in ps]
        for stub, p in zip(stubs_a, ps):
            direct.append(svc_a.callLLM(stub, p, max_new_tokens=5)[1])
        with ServiceRouter(svc_b, predict=False, slice_steps=2) as router:
            app = router.register_app("a", "fg")
            stubs_b = [app.new_ctx() for _ in ps]
            streams = [app.stream(st, p, max_new_tokens=5)
                       for st, p in zip(stubs_b, ps)]
            router.drain()
            routed = [s.result() for s in streams]
    assert routed == direct
    # the engine really was batch-1: every round emitted one token
    assert router.stats()["tokens_per_round"] == 1.0


def test_batched_output_matches_serial_reference():
    """Greedy decode at decode_batch=4 produces the same tokens as four
    independent serial generations (slots are independent rows)."""
    svc_a, cfg = make_svc(decode_batch=1)
    svc_b, _ = make_svc(decode_batch=4)
    ps = prompts_for(cfg, 4, seed=7)
    with svc_a, svc_b:
        ref = []
        for p in ps:
            stub = svc_a.newLLMCtx()
            ref.append(svc_a.callLLM(stub, p, max_new_tokens=6)[1])
        with ServiceRouter(svc_b, predict=False, slice_steps=2) as router:
            app = router.register_app("a", "fg")
            streams = [app.stream(app.new_ctx(), p, max_new_tokens=6)
                       for p in ps]
            router.drain()
            out = [s.result() for s in streams]
    assert out == ref
    st = router.stats()
    assert st["decode_batch"] == 4
    assert st["tokens_per_round"] > 1.0     # generations actually shared steps


def test_paged_decode_matches_serial_decode():
    """The executor's paged [B, 1] entry — per-row page-table gather,
    one jitted step, tail-page scatter-back — produces the same
    logits-argmax and density mass as stepping each context's slot
    cache serially through the singleton ``decode`` entry."""
    svc, cfg = make_svc(decode_batch=4)
    with svc:
        exe, pool = svc.exe, svc.res.pool
        assert svc.paged
        rng = np.random.RandomState(43)
        slot_caches, toks, pos, ctxs = [], [], [], []
        for i in range(3):                  # deliberately a non-bucket n
            prompt = rng.randint(1, cfg.vocab, 6 + i).astype(np.int32)
            cache = exe.fresh_cache(0)      # slot-path reference
            cache, logits, _ = exe.extend(cache, prompt, 0)
            slot_caches.append(cache)
            ctx = svc.ctxs.create()         # paged twin of the same ctx
            svc.res.ensure_extend_range(ctx, 0, (len(prompt) - 1) // exe.cs)
            pt16, pt8, qmask = pool.rows([ctx.cid])
            pool.arenas, plogits, _ = exe.paged_extend(
                pool.arenas, prompt, 0, pt16, pt8, qmask)
            assert int(np.argmax(logits)) == int(np.argmax(plogits))
            ctxs.append(ctx)
            toks.append(int(np.argmax(logits)))
            pos.append(len(prompt))
        serial = [exe.decode(c, t) for c, t in zip(slot_caches, toks)]
        pt16, pt8, qmask = pool.rows([c.cid for c in ctxs])
        pool.arenas, blogits, bmass = exe.paged_decode(
            pool.arenas, toks, pos, pt16, pt8, qmask)
        for i, (_, ls, ms) in enumerate(serial):
            assert int(np.argmax(ls)) == int(np.argmax(blogits[i]))
            np.testing.assert_allclose(np.asarray(ms, np.float32),
                                       np.asarray(bmass[i], np.float32),
                                       atol=2e-2)


# --------------------------------------------------------------------- #
# deterministic interleaving via pump() at decode_batch > 1
# --------------------------------------------------------------------- #
def test_pump_interleaves_batch_deterministically():
    """One pump = one K-step slice over the whole batch: every live
    stream gains exactly K tokens per pump, in admission order."""
    svc, cfg = make_svc(decode_batch=3)
    ps = prompts_for(cfg, 3, seed=11)
    with svc, ServiceRouter(svc, predict=False, slice_steps=2) as router:
        app = router.register_app("a", "fg")
        streams = [app.stream(app.new_ctx(), p, max_new_tokens=6)
                   for p in ps]
        for expect in (2, 4, 6):
            assert router.pump()
            assert [len(s.tokens) for s in streams] == [expect] * 3
        assert not router.pump()            # everything finished
        for s in streams:
            assert s.done and len(s.result()) == 6
        # between pumps the whole batch was parked: slots all idle
        assert len(svc.res.slots.held) == 0
        assert len(router.call_records) == 3


def test_partial_batch_refills_between_slices():
    """When a batch member finishes, a queued job takes its slot at the
    next slice boundary instead of waiting for the round to end."""
    svc, cfg = make_svc(decode_batch=2)
    ps = prompts_for(cfg, 3, seed=13)
    with svc, ServiceRouter(svc, predict=False, slice_steps=2) as router:
        app = router.register_app("a", "fg")
        s1 = app.stream(app.new_ctx(), ps[0], max_new_tokens=2)   # short
        s2 = app.stream(app.new_ctx(), ps[1], max_new_tokens=8)   # long
        s3 = app.stream(app.new_ctx(), ps[2], max_new_tokens=4)   # queued
        router.drain()
        for s, n in ((s1, 2), (s2, 8), (s3, 4)):
            assert len(s.result()) == n
        # s3 was admitted mid-round: its first token landed before the
        # long generation's last one
        assert s3.t_first_token < s2.token_times[-1]


# --------------------------------------------------------------------- #
# slot eviction under memory pressure
# --------------------------------------------------------------------- #
def test_slot_eviction_under_memory_pressure():
    """More contexts than slots under a tiny chunk budget: idle slots
    are reclaimed LRU-first, the reuse map never exceeds B entries, and
    the tokens still match an unconstrained serial run."""
    svc_ref, cfg = make_svc(decode_batch=1)
    svc, _ = make_svc(decode_batch=2, budget=40_000)
    ps = prompts_for(cfg, 4, seed=17)
    order = [0, 1, 2, 3, 0, 2, 1, 3]
    with svc_ref, svc:
        stubs_ref = [svc_ref.newLLMCtx() for _ in ps]
        ref = [svc_ref.callLLM(stubs_ref[i], ps[i], max_new_tokens=3)[1]
               for i in order]
        stubs = [svc.newLLMCtx() for _ in ps]
        out = []
        for i in order:
            out.append(svc.callLLM(stubs[i], ps[i], max_new_tokens=3)[1])
            assert len(svc._reuse) <= 2
            assert set(svc._reuse) == set(svc.res.slots.idle)
        assert out == ref
        # 4 contexts rotated through 2 slots: parked caches were evicted
        assert set(svc.res.slots.idle) < {s.ctx_id for s in stubs}
        assert svc.stats()["decode_slots"] == 2


def test_slot_allocator_refuses_oversubscription():
    """Holding more slots than exist is a scheduler bug and raises
    before any state is corrupted."""
    svc, cfg = make_svc(decode_batch=2)
    ps = prompts_for(cfg, 3, seed=19)
    with svc:
        sts = []
        for p in ps[:2]:
            stub = svc.newLLMCtx()
            sts.append(svc.begin_call(
                stub, GenerationRequest(prompt=p, max_new_tokens=4)))
        stub3 = svc.newLLMCtx()
        with pytest.raises(RuntimeError):
            svc.begin_call(stub3,
                           GenerationRequest(prompt=ps[2], max_new_tokens=4))
        # the refused call left nothing behind: finish the residents and
        # the third context still serves
        for st in sts:
            while svc.decode_step(st) is not None:
                pass
            svc.finish_call(st)
        assert len(svc.callLLM(stub3, ps[2], max_new_tokens=2)[1]) == 2


# --------------------------------------------------------------------- #
# preemption evicts ONE slot; the rest of the batch keeps decoding
# --------------------------------------------------------------------- #
def test_preemption_evicts_single_slot():
    svc, cfg = make_svc(decode_batch=2)
    ps = prompts_for(cfg, 3, seed=23)
    with svc, ServiceRouter(svc, predict=False, start=True,
                            slice_steps=2) as router:
        bg = router.register_app("agent", "background")
        fg = router.register_app("chat", "foreground")
        fg_stub = fg.new_ctx()              # before the bg batch holds
        bg_stubs = [bg.new_ctx(), bg.new_ctx()]     # the service lock
        bgs = [bg.stream(stub, p, max_new_tokens=48)
               for stub, p in zip(bg_stubs, ps[:2])]
        deadline = time.time() + 120
        while any(s.t_first_token is None for s in bgs):
            assert time.time() < deadline, "bg batch never started"
            time.sleep(0.001)
        fg_s = fg.stream(fg_stub, ps[2], max_new_tokens=4)
        fg_s.result(timeout=120)
        router.drain()
        assert router.preemptions >= 1
        # exactly one background slot was evicted for the foreground
        # request; its batch-mate kept its slot and kept decoding
        preempted = [s for s in bgs if s.n_preempts > 0]
        assert len(preempted) == 1
        survivor = next(s for s in bgs if s.n_preempts == 0)
        assert any(t > fg_s.t_first_token for t in survivor.token_times)
        for s in bgs:                       # preemption loses no tokens
            assert len(s.result(timeout=120)) == 48


def test_exclusive_head_drains_batch_without_thrash():
    """Regression: a queued exclusive request must not trigger repeated
    preemptions it can never profit from (it needs the WHOLE engine),
    and batch formation must not refill past it — the running batch
    drains, then the exclusive job runs alone, then the rest."""
    svc, cfg = make_svc(decode_batch=2)
    ps = prompts_for(cfg, 4, seed=37)
    with svc, ServiceRouter(svc, predict=False, slice_steps=2) as router:
        bg = router.register_app("agent", "background")
        fg = router.register_app("chat", "foreground")
        bgs = [bg.stream(bg.new_ctx(), p, max_new_tokens=6)
               for p in ps[:2]]
        router.pump()                       # bg batch underway, parked
        solo = fg.submit_request(
            fg.new_ctx(), GenerationRequest(prompt=ps[2], max_new_tokens=4,
                                            exclusive=True))
        late_bg = bg.stream(bg.new_ctx(), ps[3], max_new_tokens=2)
        router.drain()
        assert router.preemptions == 0      # no futile slot evictions
        assert len(solo.result()) == 4
        for s in bgs:
            assert len(s.result()) == 6
        assert len(late_bg.result()) == 2
        # nothing behind the exclusive head jumped the line: the late bg
        # job only decoded after the exclusive stream finished
        assert late_bg.t_first_token > solo.t_done


def test_running_exclusive_preempted_by_foreground():
    """Regression: a running exclusive generation blocks every slot, so
    it must count as a full engine for the preemption check — a
    foreground arrival evicts it instead of waiting out its whole
    generation."""
    svc, cfg = make_svc(decode_batch=2)
    ps = prompts_for(cfg, 2, seed=41)
    with svc, ServiceRouter(svc, predict=False, start=True,
                            slice_steps=2) as router:
        bg = router.register_app("agent", "background")
        fg = router.register_app("chat", "foreground")
        fg_stub, bg_stub = fg.new_ctx(), bg.new_ctx()
        solo = bg.submit_request(
            bg_stub, GenerationRequest(prompt=ps[0], max_new_tokens=48,
                                       exclusive=True))
        deadline = time.time() + 120
        while solo.t_first_token is None:
            assert time.time() < deadline, "exclusive stream never started"
            time.sleep(0.001)
        fg_s = fg.stream(fg_stub, ps[1], max_new_tokens=4)
        fg_s.result(timeout=120)
        router.drain()
        assert router.preemptions >= 1 and solo.n_preempts >= 1
        assert fg_s.t_done < solo.t_done    # fg did not wait out 48 tokens
        assert len(solo.result(timeout=120)) == 48


def test_exclusive_request_runs_alone():
    svc, cfg = make_svc(decode_batch=4)
    ps = prompts_for(cfg, 3, seed=29)
    with svc, ServiceRouter(svc, predict=False, slice_steps=2) as router:
        app = router.register_app("a", "fg")
        solo = app.submit_request(
            app.new_ctx(),
            GenerationRequest(prompt=ps[0], max_new_tokens=4,
                              exclusive=True))
        mates = [app.stream(app.new_ctx(), p, max_new_tokens=4)
                 for p in ps[1:]]
        assert router.pump()                # slice 1: the exclusive job only
        assert len(solo.tokens) == 2
        assert all(not s.tokens for s in mates)
        router.drain()
        assert len(solo.result()) == 4
        assert all(len(s.result()) == 4 for s in mates)


# --------------------------------------------------------------------- #
# router stop-check satellite: no dispatch after abort()
# --------------------------------------------------------------------- #
def test_pump_refuses_work_after_abort():
    """Regression: pump() used to pop and RUN a queued job after abort()
    had promised to cancel it."""
    svc, cfg = make_svc(decode_batch=1)
    with svc:
        router = ServiceRouter(svc, predict=False, slice_steps=2)
        app = router.register_app("a", "fg")
        stub = app.new_ctx()
        s = app.stream(stub, prompts_for(cfg, 1, seed=31)[0],
                       max_new_tokens=4)
        router.abort()
        assert s.cancelled
        assert not router.pump()            # refuses: router is stopped
        assert svc.contexts[stub.ctx_id].n_tokens == 0      # never ran
