"""Request/stream protocol unit tests (no model): SamplingParams
samplers and GenerationStream semantics."""
import threading

import numpy as np
import pytest

from repro.core.requests import (GenerationRequest, GenerationStream,
                                 SamplingParams)


# --------------------------------------------------------------------- #
# SamplingParams
# --------------------------------------------------------------------- #
def test_default_sampler_is_greedy_argmax():
    """temperature=0 (the default) must reproduce the old np.argmax
    behaviour exactly — the compat guarantee of the redesign."""
    sampler = SamplingParams().make_sampler()
    rng = np.random.RandomState(0)
    for _ in range(20):
        logits = rng.randn(64).astype(np.float32)
        assert sampler(logits) == int(np.argmax(logits))


def test_seeded_sampler_is_reproducible():
    logits = np.random.RandomState(1).randn(32)
    a = SamplingParams(temperature=0.7, seed=42).make_sampler()
    b = SamplingParams(temperature=0.7, seed=42).make_sampler()
    seq_a = [a(logits) for _ in range(16)]
    seq_b = [b(logits) for _ in range(16)]
    assert seq_a == seq_b
    assert all(0 <= t < 32 for t in seq_a)


def test_top_k_restricts_support():
    logits = np.array([0.0, 10.0, 9.0, -5.0, 1.0])
    sampler = SamplingParams(temperature=1.0, top_k=2, seed=0).make_sampler()
    draws = {sampler(logits) for _ in range(64)}
    assert draws <= {1, 2}          # only the top-2 ids are reachable


def test_top_k_one_equals_argmax():
    sampler = SamplingParams(temperature=5.0, top_k=1, seed=3).make_sampler()
    rng = np.random.RandomState(2)
    for _ in range(10):
        logits = rng.randn(16)
        assert sampler(logits) == int(np.argmax(logits))


# --------------------------------------------------------------------- #
# GenerationStream
# --------------------------------------------------------------------- #
def _stream(max_new=8):
    return GenerationStream(0, GenerationRequest(prompt=[1, 2],
                                                 max_new_tokens=max_new))


def test_stream_push_result_and_timestamps():
    s = _stream()
    for tok in (5, 6, 7):
        s.push(tok)
    s.finish()
    assert s.result() == [5, 6, 7]
    assert s.done and not s.cancelled and s.error is None
    assert s.ttft() is not None and s.ttft() >= 0
    assert len(s.tbt()) == 2
    assert all(dt >= 0 for dt in s.tbt())
    assert s.t_done >= s.t_first_token >= s.t_submit


def test_stream_iteration_across_threads():
    s = _stream()
    seen = []

    def consume():
        for tok in s:
            seen.append(tok)
    t = threading.Thread(target=consume)
    t.start()
    for tok in range(4):
        s.push(tok)
    s.finish()
    t.join(10.0)
    assert seen == [0, 1, 2, 3]


def test_stream_cancel_flags():
    s = _stream()
    assert s.cancel()               # not yet finished -> True
    assert s.cancel_requested and not s.done
    s.push(1)
    s.finish(cancelled=True)
    assert s.cancelled and s.result() == [1]
    assert not s.cancel()           # already finished -> False


def test_stream_error_raised_from_result_and_iter():
    s = _stream()
    s.push(9)
    s.finish(error=ValueError("boom"))
    with pytest.raises(ValueError):
        s.result()
    it = iter(s)
    assert next(it) == 9            # tokens before the error still yield
    with pytest.raises(ValueError):
        next(it)


def test_stream_result_timeout():
    s = _stream()
    with pytest.raises(TimeoutError):
        s.result(timeout=0.01)
