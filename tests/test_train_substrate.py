"""Optimizer, checkpointing (fault tolerance), data pipeline, traces."""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import SyntheticLM
from repro.trace.synth import PATTERNS, TABLE3, synthesize
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptConfig, apply_updates, init_state


def _quadratic_state(cfg, key=0):
    params = {"w": jax.random.normal(jax.random.PRNGKey(key), (8, 8))}
    return init_state(params, cfg)


@pytest.mark.parametrize("quantized", [False, True])
def test_adamw_minimizes_quadratic(quantized):
    cfg = OptConfig(lr=0.05, weight_decay=0.0, warmup_steps=1,
                    quantized=quantized)
    state = _quadratic_state(cfg)
    target = jnp.ones((8, 8))

    @jax.jit
    def step(state):
        loss, g = jax.value_and_grad(
            lambda p: jnp.sum((p["w"] - target) ** 2))(state["params"])
        new, _ = apply_updates(state, g, cfg)
        return new, loss

    losses = []
    for _ in range(120):
        state, loss = step(state)
        losses.append(float(loss))
    assert losses[-1] < 0.05 * losses[0]


def test_quantized_close_to_exact():
    exact = OptConfig(lr=0.02, weight_decay=0.0, quantized=False)
    quant = OptConfig(lr=0.02, weight_decay=0.0, quantized=True)
    se, sq = _quadratic_state(exact), _quadratic_state(quant)
    target = jnp.ones((8, 8))
    for _ in range(50):
        for s, c in ((se, exact), (sq, quant)):
            _, g = jax.value_and_grad(
                lambda p: jnp.sum((p["w"] - target) ** 2))(s["params"])
            new, _ = apply_updates(s, g, c)
            s.update(new)
    diff = float(jnp.max(jnp.abs(se["params"]["w"] - sq["params"]["w"])))
    assert diff < 0.15


def test_grad_clip_caps_update():
    cfg = OptConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0,
                    warmup_steps=1)
    state = _quadratic_state(cfg)
    g = {"w": jnp.full((8, 8), 1e6)}
    _, metrics = apply_updates(state, g, cfg)
    assert float(metrics["grad_norm"]) > 1e6


def test_checkpoint_roundtrip_and_gc():
    d = tempfile.mkdtemp()
    state = {"params": {"w": np.arange(12.0).reshape(3, 4)},
             "step": np.int32(7)}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(d, s, state, keep=2)
    assert ckpt.latest_step(d) == 5
    assert sorted(int(f.split("_")[1].split(".")[0])
                  for f in os.listdir(d)) == [4, 5]
    back = ckpt.restore(d)
    np.testing.assert_array_equal(back["params"]["w"],
                                  state["params"]["w"])


def test_checkpoint_async():
    d = tempfile.mkdtemp()
    ckpt.save_async(d, 1, {"x": np.ones(4)})
    ckpt.flush()
    assert ckpt.latest_step(d) == 1


def test_checkpoint_elastic_reshard():
    """Restore a checkpoint onto a DIFFERENT device mesh (subprocess with
    forced host devices) — the elastic-restart story."""
    d = tempfile.mkdtemp()
    ckpt.save(d, 1, {"params": {"w": np.arange(32.0).reshape(4, 8)}})
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, {repr(os.path.join(os.path.dirname(__file__), '..', 'src'))})
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint as ckpt
state = ckpt.restore({d!r}, 1)
mesh = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
w = jax.device_put(state["params"]["w"], NamedSharding(mesh, P("data", None)))
assert w.sharding.num_devices == 4
np.testing.assert_array_equal(np.asarray(w), np.arange(32.0).reshape(4, 8))
print("elastic-ok")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=120)
    assert "elastic-ok" in out.stdout, out.stderr[-2000:]


def test_data_pipeline_deterministic_and_sharded():
    a = SyntheticLM(vocab=100, seq=16, batch=4, n_shards=2, shard=0)
    b = SyntheticLM(vocab=100, seq=16, batch=4, n_shards=2, shard=0)
    c = SyntheticLM(vocab=100, seq=16, batch=4, n_shards=2, shard=1)
    np.testing.assert_array_equal(a.batch_for_step(3)["tokens"],
                                  b.batch_for_step(3)["tokens"])
    assert not np.array_equal(a.batch_for_step(3)["tokens"],
                              c.batch_for_step(3)["tokens"])


@pytest.mark.parametrize("pattern", PATTERNS)
def test_trace_synthesis(pattern):
    ev = synthesize(4, 30, vocab=1000, pattern=pattern, seed=1)
    assert len(ev) == 30
    times = [e.time for e in ev]
    assert times == sorted(times)
    for e in ev:
        lo, hi = TABLE3[e.dataset]
        n = len(e.prompt) + len(e.ground_truth)
        assert lo * 0.9 <= n <= hi * 1.1 + 2
    ev2 = synthesize(4, 30, vocab=1000, pattern=pattern, seed=1)
    assert all(a.ctx_id == b.ctx_id and np.array_equal(a.prompt, b.prompt)
               for a, b in zip(ev, ev2))
