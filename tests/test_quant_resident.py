"""Quant-resident decode (compressed chunks attended in place).

The contracts of DESIGN.md §2's third residency state:
  * token IDENTITY: decoding over int8 chunk segments through the fused
    dequant select emits exactly the tokens of the full-dequant bf16
    path at 8-bit (serial AND batched),
  * the byte budget charges quant-resident chunks at their compressed
    payload size (well under the raw bf16 footprint),
  * more contexts are decode-ready at a fixed budget than the slot
    count (the tier's whole point),
  * the decode-grid chunk-file round trip is byte-exact, so eviction
    and restore do not perturb generations.
"""
import tempfile

import numpy as np
import pytest

from conftest import tiny_model
from repro.core.chunks import QuantResidentChunk
from repro.core.restore import read_chunk_file, write_chunk_file
from repro.core.scheduler import ServiceRouter
from repro.core.service import LLMSConfig, LLMService


def make_svc(policy="vllm_sq", budget=10_000_000, max_ctx=128, cs=16,
             decode_batch=1, quant_resident=True):
    cfg, model, params = tiny_model("smollm-360m")
    sc = LLMSConfig(policy=policy, max_ctx_len=max_ctx, chunk_tokens=cs,
                    memory_budget=budget, decode_batch=decode_batch,
                    quant_resident=quant_resident,
                    swap_dir=tempfile.mkdtemp())
    return LLMService(model, params, sc), cfg


def prompts_for(cfg, n, length=12, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, cfg.vocab, length).tolist() for _ in range(n)]


def drive(svc, prompts, rounds=2, max_new=6):
    """Two calls per context: the second switches quant chunks back in."""
    stubs = [svc.newLLMCtx() for _ in prompts]
    outs = []
    for r in range(rounds):
        for stub, p in zip(stubs, prompts):
            outs.append(svc.callLLM(stub, p[r:] or p, max_new)[1])
    return stubs, outs


# --------------------------------------------------------------------- #
# token identity: fused in-place decode == full dequantization (8-bit)
# --------------------------------------------------------------------- #
def test_quant_decode_token_identical_to_full_dequant():
    """static8 makes every chunk an 8-bit decode-grid payload; the
    force_dequant control materializes the SAME payloads into bf16 at
    switch-in.  The fused select computes (code * scale) -> bf16 — the
    very value the control scatters — so tokens must match exactly."""
    svc_q, cfg = make_svc()
    svc_d, _ = make_svc()
    svc_d.res.force_dequant = True
    ps = prompts_for(cfg, 3, seed=5)
    with svc_q, svc_d:
        _, toks_q = drive(svc_q, ps)
        _, toks_d = drive(svc_d, ps)
        assert any(c.chunks for c in svc_q.contexts.values())
        assert all(m.quant for c in svc_q.contexts.values()
                   for m in c.chunks.values())
    assert toks_q == toks_d


def test_quant_decode_token_identical_batched():
    """Same identity through the batched [B, 1] decode entry
    (decode_batch >= 1 acceptance criterion): distinct contexts decode
    as one batch over their mixed slot caches."""
    svc_q, cfg = make_svc(decode_batch=2)
    svc_d, _ = make_svc(decode_batch=2)
    svc_d.res.force_dequant = True
    ps = prompts_for(cfg, 4, seed=11)

    def run(svc):
        with ServiceRouter(svc, predict=False, slice_steps=2) as router:
            app = router.register_app("a", "fg")
            stubs = [app.new_ctx() for _ in ps]
            for r in range(2):
                streams = [app.stream(st, p, max_new_tokens=5)
                           for st, p in zip(stubs, ps)]
                router.drain()
            return [list(s.tokens) for s in streams]

    with svc_q, svc_d:
        assert run(svc_q) == run(svc_d)


def test_quant_fidelity_under_eviction():
    """Eviction + restore of decode-grid chunks is byte-exact (the qc
    file round trip scatters the same codes), so a starved budget
    generates the same tokens as an ample one."""
    svc_big, cfg = make_svc(budget=10_000_000)
    ps = prompts_for(cfg, 3, seed=9)
    with svc_big:
        _, big = drive(svc_big, ps)
    svc_small, _ = make_svc(budget=12_000)
    with svc_small:
        _, small = drive(svc_small, ps)
        evicted = sum(1 for c in svc_small.contexts.values()
                      for m in c.chunks.values() if not m.in_memory)
    assert evicted > 0
    assert big == small


# --------------------------------------------------------------------- #
# accounting: compressed-size residency, decode-ready count
# --------------------------------------------------------------------- #
def test_budget_charges_quant_chunks_at_compressed_size():
    svc, cfg = make_svc()
    ps = prompts_for(cfg, 2)
    with svc:
        drive(svc, ps, rounds=1)
        raw = None
        for c in svc.contexts.values():
            for i, m in c.chunks.items():
                if not m.in_memory:
                    continue
                assert m.quant
                qc = c.payload[i]
                assert isinstance(qc, QuantResidentChunk)
                assert m.nbytes == qc.nbytes
                raw = svc.exe.codec.raw_chunk_bytes(
                    {k: v for k, v in qc.shapes.items()})
                # int8 codes + per-(token, kv-head) scales ~ 0.56x bf16
                assert qc.nbytes < 0.7 * raw
        assert raw is not None
        charged = sum(m.nbytes for c in svc.contexts.values()
                      for m in c.chunks.values() if m.in_memory)
        assert svc.mem.used == charged


def test_decode_ready_contexts_exceed_slots():
    """The headline: at one decode slot, the quant tier keeps MANY
    contexts decode-ready (switch-in is an int8 scatter), while the
    full-dequant baseline is warm only up to its parked slots."""
    svc_q, cfg = make_svc(decode_batch=1)
    svc_d, _ = make_svc(decode_batch=1)
    svc_d.res.force_dequant = True
    ps = prompts_for(cfg, 4, seed=2)
    with svc_q, svc_d:
        drive(svc_q, ps, rounds=1)
        drive(svc_d, ps, rounds=1)
        assert svc_q.decode_ready_contexts() == len(ps)
        assert svc_d.decode_ready_contexts() <= svc_d.decode_batch
        assert svc_q.stats()["quant_resident_chunks"] > 0


def test_quant_resident_requires_chunked_policy():
    with pytest.raises(ValueError):
        LLMSConfig(policy="swap", quant_resident=True)


def test_quant_resident_capability_gating():
    """quant_resident is an opt-in bit on the family's KVSpec: a
    servable family that does not declare it refuses at construction —
    not crash inside init_cache (rwkv6's constant state has no int8
    chunk segments) — while MLA's latent (ckv, kpe) chunks DO carry
    the opt-in, so the same config constructs cleanly there."""
    _, model, params = tiny_model("rwkv6-1.6b")
    sc = LLMSConfig(policy="llms", quant_resident=True, max_ctx_len=128,
                    swap_dir=tempfile.mkdtemp())
    assert not model.kv_spec().quant_resident
    with pytest.raises(ValueError, match="quant-resident"):
        LLMService(model, params, sc)

    _, model, params = tiny_model("deepseek-v2-lite-16b")
    assert model.kv_spec().quant_resident
    sc = LLMSConfig(policy="llms", quant_resident=True, max_ctx_len=128,
                    swap_dir=tempfile.mkdtemp())
    with LLMService(model, params, sc):
        pass


# --------------------------------------------------------------------- #
# decode-grid chunk files: byte-exact round trip
# --------------------------------------------------------------------- #
def test_token_head_chunk_file_roundtrip(tmp_path):
    rng = np.random.RandomState(3)
    L, KV, hd, T = 4, 2, 8, 16
    F, Fs = L * KV * hd, L * KV
    qc = QuantResidentChunk(
        n_tokens=T,
        data={"k": (rng.randint(-127, 128, (T, F)).astype(np.int8),
                    rng.rand(T, Fs).astype(np.float32)),
              "v": (rng.randint(-127, 128, (T, F)).astype(np.int8),
                    rng.rand(T, Fs).astype(np.float32))},
        shapes={"k": (T, F), "v": (T, F)})
    path = str(tmp_path / "qc.chunk")
    write_chunk_file(path, qc, n_layers=L)
    back = read_chunk_file(path)
    assert isinstance(back, QuantResidentChunk)
    assert back.n_tokens == T and back.bits == 8
    for leaf in ("k", "v"):
        np.testing.assert_array_equal(back.data[leaf][0], qc.data[leaf][0])
        np.testing.assert_array_equal(back.data[leaf][1], qc.data[leaf][1])


def test_extract_mixed_reads_through_quant_segments():
    """extract_mixed must report the fused-dequant values at masked
    positions (the bf16 array is stale there) — the re-encode source."""
    import jax.numpy as jnp
    svc, cfg = make_svc()
    with svc:
        codec = svc.exe.codec
        cache = svc.exe.fresh_cache(0)
        rng = np.random.RandomState(0)
        T = svc.exe.cs
        blocks = {n: jnp.asarray(rng.randn(
            T, int(np.prod([s for i, s in enumerate(cache[n].shape)
                            if i != 2]))).astype(np.float32))
            for n in codec.leaves}
        head_dims = {n: cache[n].shape[-1] for n in codec.leaves}
        qc = codec.quantize_resident_blocks(blocks, head_dims)
        cache = svc.exe.scatter_quant_fn(
            cache, jnp.arange(T),
            {n: jnp.asarray(qc.data[n][0]) for n in codec.leaves},
            {n: jnp.asarray(qc.data[n][1]) for n in codec.leaves})
        got = codec.extract_mixed(cache, 0, T)
        want = codec.dequantize_resident(qc)
        for n in codec.leaves:
            np.testing.assert_array_equal(
                np.asarray(got[n], np.float32),
                np.asarray(want[n], np.float32))
