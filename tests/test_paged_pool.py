"""Paged, unified KV pool: token identity with the slot-cache path
(serial and batch-4, quant-resident on and off), page reclamation under
pool pressure, continuous join/leave that must not perturb running
contexts, and the pool telemetry satellite."""
import tempfile

import numpy as np

from conftest import tiny_model
from repro.core.scheduler import ServiceRouter
from repro.core.service import LLMSConfig, LLMService


def make_svc(policy="llms", budget=10_000_000, max_ctx=128, cs=16,
             decode_batch=1, quant_resident=False, paged=True,
             pool_pages_16=0):
    cfg, model, params = tiny_model("smollm-360m")
    sc = LLMSConfig(policy=policy, max_ctx_len=max_ctx, chunk_tokens=cs,
                    memory_budget=budget, decode_batch=decode_batch,
                    quant_resident=quant_resident, paged_pool=paged,
                    pool_pages_16=pool_pages_16,
                    swap_dir=tempfile.mkdtemp())
    return LLMService(model, params, sc), cfg


def prompts_for(cfg, n, length=12, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(1, cfg.vocab, length).tolist() for _ in range(n)]


def drive(svc, prompts, rounds=2, max_new=6):
    """Interleaved calls per context: every later round switches each
    context back in (paged: a page-table read; slot: a scatter)."""
    stubs = [svc.newLLMCtx() for _ in prompts]
    outs = []
    for r in range(rounds):
        for stub, p in zip(stubs, prompts):
            outs.append(svc.callLLM(stub, p[r:] or p, max_new)[1])
    return stubs, outs


# --------------------------------------------------------------------- #
# token identity vs the slot-cache path
# --------------------------------------------------------------------- #
def test_paged_serial_tokens_match_slot_path():
    """Serial greedy decode over the paged pool emits exactly the
    slot-cache path's tokens, across interleaved multi-context calls
    (so round 2+ exercises switch-in via page table vs scatter)."""
    svc_p, cfg = make_svc(paged=True)
    svc_s, _ = make_svc(paged=False)
    ps = prompts_for(cfg, 3, seed=11)
    with svc_p, svc_s:
        assert svc_p.paged and not svc_s.paged
        _, out_p = drive(svc_p, ps)
        _, out_s = drive(svc_s, ps)
    assert out_p == out_s


def test_paged_quant_resident_tokens_match_slot_path():
    """With quant-resident chunks (static8: every full chunk is an
    8-bit decode-grid payload) the paged in-place attend over QUANT
    pages matches the slot path's scattered quant cache."""
    svc_p, cfg = make_svc(policy="vllm_sq", quant_resident=True)
    svc_s, _ = make_svc(policy="vllm_sq", quant_resident=True, paged=False)
    ps = prompts_for(cfg, 3, seed=5)
    with svc_p, svc_s:
        _, out_p = drive(svc_p, ps)
        _, out_s = drive(svc_s, ps)
        st = svc_p.stats()
    assert out_p == out_s
    assert st["pool_pages8_used"] > 0       # quant pages really in play


def _batch4_vs_serial(policy, quant):
    svc_ref, cfg = make_svc(policy=policy, quant_resident=quant,
                            paged=False)
    svc_b, _ = make_svc(policy=policy, quant_resident=quant,
                        decode_batch=4)
    ps = prompts_for(cfg, 4, seed=7)
    with svc_ref, svc_b:
        ref = [svc_ref.callLLM(svc_ref.newLLMCtx(), p, 6)[1] for p in ps]
        with ServiceRouter(svc_b, predict=False, slice_steps=2) as router:
            app = router.register_app("a", "fg")
            streams = [app.stream(app.new_ctx(), p, max_new_tokens=6)
                       for p in ps]
            router.drain()
            out = [s.result() for s in streams]
    assert out == ref
    assert router.stats()["tokens_per_round"] > 1.0


def test_paged_batch4_matches_slot_serial():
    """Four generations sharing paged decode rounds emit the same
    tokens as four independent slot-cache generations."""
    _batch4_vs_serial("llms", quant=False)


def test_paged_batch4_quant_matches_slot_serial():
    _batch4_vs_serial("vllm_sq", quant=True)


# --------------------------------------------------------------------- #
# page reclamation under pool pressure
# --------------------------------------------------------------------- #
def test_page_reclamation_under_pool_pressure():
    """A pool far smaller than the working set forces LRU whole-context
    reclaims; re-admission from payloads keeps tokens identical to the
    slot path."""
    svc_p, cfg = make_svc(pool_pages_16=17)     # ~2 contexts' worth
    svc_s, _ = make_svc(paged=False)
    ps = prompts_for(cfg, 6, seed=13)
    with svc_p, svc_s:
        _, out_p = drive(svc_p, ps, rounds=3)
        _, out_s = drive(svc_s, ps, rounds=3)
        st = svc_p.stats()
    assert out_p == out_s
    assert st["pool_reclaims"] > 0
    assert st["pool_pages16_used"] <= st["pool_pages16_total"]


def test_paged_identity_under_memory_budget_pressure():
    """Byte-budget evictions (chunks spilled to disk mid-sequence) free
    their pages; restores re-admit and tokens still match the slot
    path."""
    svc_p, cfg = make_svc(budget=60_000)
    svc_s, _ = make_svc(budget=60_000, paged=False)
    ps = prompts_for(cfg, 4, seed=17)
    with svc_p, svc_s:
        _, out_p = drive(svc_p, ps, rounds=3)
        _, out_s = drive(svc_s, ps, rounds=3)
        st = svc_p.stats()
    assert out_p == out_s
    assert st["pool_page_faults"] > 0


def test_paged_restore_ordered_after_inflight_aot_write(monkeypatch):
    """``flush_dirty`` marks a chunk ``on_disk`` when it SUBMITS the
    async write; a later restore must chain off that in-flight write
    rather than race its ``os.replace``.  Reproduces the failure shape
    seen under serve load: a chunk whose FIRST AoT write is still in
    flight is evicted (clean — nothing more to write) and immediately
    switched back in.  The unordered read raised FileNotFoundError
    here; the ordered read must wait and return the flushed payload."""
    import threading

    import repro.core.residency as res_mod
    orig = res_mod.write_chunk_file
    gate = threading.Event()

    def gated_write(path, cc, n_layers):
        gate.wait(5.0)
        return orig(path, cc, n_layers)

    svc, cfg = make_svc()
    svc_ref, _ = make_svc()
    p = prompts_for(cfg, 1, length=24, seed=31)[0]
    try:
        with svc, svc_ref:
            stub = svc.newLLMCtx()
            svc.callLLM(stub, p, 4)
            ctx = svc.contexts[stub.ctx_id]
            # rewind chunk 0 to "first write still in flight": no file
            # on disk, a gated async write pending, then evicted
            svc.res.store.delete((ctx.cid, 0))
            monkeypatch.setattr(res_mod, "write_chunk_file", gated_write)
            ctx.chunks[0].dirty = True
            assert svc.res.flush_dirty(ctx) == 1
            svc.res.evict((ctx.cid, 0))
            assert not ctx.chunks[0].in_memory
            threading.Timer(0.2, gate.set).start()
            out = svc.callLLM(stub, p[4:8], 4)[1]   # restores chunk 0

            stub_r = svc_ref.newLLMCtx()
            svc_ref.callLLM(stub_r, p, 4)
            ref = svc_ref.callLLM(stub_r, p[4:8], 4)[1]
    finally:
        gate.set()
    assert out == ref


# --------------------------------------------------------------------- #
# continuous batching: join/leave mid-round
# --------------------------------------------------------------------- #
def test_continuous_join_leaves_running_context_untouched():
    """Short generations leaving and queued ones joining mid-slice must
    not perturb a long-running member: its page-table row is the only
    thing the join touches, so its tokens equal a solo run's."""
    svc_solo, cfg = make_svc(paged=False)
    svc_b, _ = make_svc(decode_batch=2)
    rng = np.random.RandomState(21)
    long_p = rng.randint(1, cfg.vocab, 12).tolist()
    short_ps = [rng.randint(1, cfg.vocab, 8).tolist() for _ in range(3)]
    with svc_solo, svc_b:
        ref = svc_solo.callLLM(svc_solo.newLLMCtx(), long_p,
                               max_new_tokens=12)[1]
        with ServiceRouter(svc_b, predict=False, slice_steps=4) as router:
            app = router.register_app("a", "fg")
            s_long = app.stream(app.new_ctx(), long_p, max_new_tokens=12)
            shorts = [app.stream(app.new_ctx(), p, max_new_tokens=2)
                      for p in short_ps]
            router.drain()
            out_long = s_long.result()
            for s in shorts:
                assert len(s.result()) == 2
    assert out_long == ref
    assert router.joins_mid_slice > 0       # members really joined mid-slice


# --------------------------------------------------------------------- #
# telemetry
# --------------------------------------------------------------------- #
def test_pool_telemetry_in_stats():
    svc, cfg = make_svc(policy="vllm_sq", quant_resident=True)
    ps = prompts_for(cfg, 3, seed=2)
    with svc:
        drive(svc, ps, rounds=2)
        st = svc.stats()
    assert st["paged_pool"] is True
    for k in ("pool_pages16_total", "pool_pages16_used",
              "pool_pages8_total", "pool_pages8_used", "pool_page_faults",
              "pool_pt_switch_ins", "pool_admit_switch_ins",
              "pool_reclaims"):
        assert k in st, k
    assert st["pool_page_faults"] > 0
    # persist mode: round-2 switch-ins are pure page-table reads
    assert st["pool_pt_switch_ins"] > 0
    assert 0 < st["pool_pages16_used"] <= st["pool_pages16_total"]
