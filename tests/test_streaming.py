"""Streaming/preemptible generation path: stepwise begin/decode/finish
parity with callLLM, decode-slice preemption QoS, cancellation leaving
contexts consistent, and the lifecycle satellites (routed system
prompts, busy-delete guard, close idempotency, context managers)."""
import tempfile
import time

import numpy as np
import pytest

from conftest import tiny_model
from repro.core.requests import GenerationRequest, SamplingParams
from repro.core.scheduler import ServiceRouter
from repro.core.service import LLMSConfig, LLMService
from repro.trace.synth import synthesize


def make_svc(policy="llms", budget=10_000_000, max_ctx=128):
    cfg, model, params = tiny_model("smollm-360m")
    sc = LLMSConfig(policy=policy, max_ctx_len=max_ctx,
                    memory_budget=budget, swap_dir=tempfile.mkdtemp())
    return LLMService(model, params, sc), cfg


# --------------------------------------------------------------------- #
# stepwise decomposition ≡ the blocking Table-1 call
# --------------------------------------------------------------------- #
def test_begin_decode_finish_matches_callLLM():
    """Driving begin_call/decode_step/finish_call by hand produces the
    same tokens and context state as the compat shim (both greedy)."""
    svc_a, cfg = make_svc()
    svc_b, _ = make_svc()
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, cfg.vocab, 10).tolist() for _ in range(4)]
    with svc_a, svc_b:
        sa, sb = svc_a.newLLMCtx(), svc_b.newLLMCtx()
        for p in prompts:
            _, gen_a = svc_a.callLLM(sa, p, max_new_tokens=3)
            st = svc_b.begin_call(sb, GenerationRequest(prompt=p,
                                                        max_new_tokens=3))
            gen_b = []
            while True:
                tok = svc_b.decode_step(st)
                if tok is None:
                    break
                gen_b.append(tok)
            svc_b.finish_call(st)
            assert gen_a == gen_b == st.generated
        ctx_a = svc_a.contexts[sa.ctx_id]
        ctx_b = svc_b.contexts[sb.ctx_id]
        assert ctx_a.n_tokens == ctx_b.n_tokens
        np.testing.assert_array_equal(ctx_a.tokens[:ctx_a.n_tokens],
                                      ctx_b.tokens[:ctx_b.n_tokens])


def test_routed_stream_matches_direct_callLLM():
    """The router's sliced stream path (no preemption) is token-for-token
    the direct greedy path."""
    svc_a, cfg = make_svc()
    svc_b, _ = make_svc()
    events = synthesize(3, 8, cfg.vocab, pattern="markov", scale=0.03,
                        seed=5)
    with svc_a, svc_b:
        stubs_a = {}
        direct = []
        for ev in events:
            if ev.ctx_id not in stubs_a:
                stubs_a[ev.ctx_id] = svc_a.newLLMCtx()
            direct.append(svc_a.callLLM(stubs_a[ev.ctx_id],
                                        ev.prompt.tolist(),
                                        max_new_tokens=4)[1])
        with ServiceRouter(svc_b, predict=True, slice_steps=2) as router:
            app = router.register_app("a", "fg")
            stubs_b, streams = {}, []
            for ev in events:
                if ev.ctx_id not in stubs_b:
                    stubs_b[ev.ctx_id] = app.new_ctx()
                streams.append(app.stream(stubs_b[ev.ctx_id],
                                          ev.prompt.tolist(),
                                          max_new_tokens=4))
            router.drain()
            routed = [s.result() for s in streams]
    assert direct == routed


def test_sampled_generation_reproducible():
    """temperature>0 with a seed: same (service, request) -> same tokens;
    the RNG is per-request, not global."""
    sp = SamplingParams(temperature=0.8, top_k=8, seed=42)
    outs = []
    for _ in range(2):
        svc, cfg = make_svc()
        with svc:
            stub = svc.newLLMCtx()
            prompt = np.random.RandomState(3).randint(
                1, cfg.vocab, 10).tolist()
            outs.append(svc.callLLM(stub, prompt, max_new_tokens=6,
                                    sampling=sp)[1])
    assert outs[0] == outs[1]
    assert len(outs[0]) == 6


# --------------------------------------------------------------------- #
# decode-slice preemption (the Fig. 9-style QoS win)
# --------------------------------------------------------------------- #
def test_slice_preemption_interleaves_inline():
    """Deterministic slice protocol: a paused background stream resumes
    AFTER a later-admitted foreground request, and the foreground's
    first token lands before the background's tail tokens."""
    svc, cfg = make_svc()
    rng = np.random.RandomState(7)
    with svc, ServiceRouter(svc, predict=False, slice_steps=2) as router:
        fg = router.register_app("chat", "foreground")
        bg = router.register_app("agent", "background")
        bg_stub, fg_stub = bg.new_ctx(), fg.new_ctx()
        bg_s = bg.stream(bg_stub, rng.randint(1, cfg.vocab, 8).tolist(),
                         max_new_tokens=12)
        router.pump()                       # one slice: 2 tokens, suspended
        assert bg_s.tokens and len(bg_s.tokens) == 2 and not bg_s.done
        fg_s = fg.stream(fg_stub, rng.randint(1, cfg.vocab, 8).tolist(),
                         max_new_tokens=3)
        router.drain()                      # fg outranks the paused bg
        assert fg_s.result() and bg_s.result() is not None
        assert len(bg_s.tokens) == 12 and len(fg_s.tokens) == 3
        # fg finished while bg was suspended:
        assert fg_s.t_done < bg_s.token_times[2]
        # resume was a real, accounted context switch
        bg_rec = [r for r in svc.records if r["ctx"] == bg_stub.ctx_id][-1]
        assert bg_rec["n_preempts"] >= 1
        assert bg_rec["new_tokens"] == 8 + 12


def test_foreground_ttft_lower_under_slicing():
    """Acceptance: 1 fg + 1 bg app; fg TTFT under decode-slice preemption
    is strictly lower than under whole-generation dispatch (the fg call
    arrives while a long bg generation is in flight)."""
    def fg_ttft(slice_steps):
        svc, cfg = make_svc()
        rng = np.random.RandomState(11)
        with svc, ServiceRouter(svc, predict=False, start=True,
                                slice_steps=slice_steps) as router:
            fg = router.register_app("chat", "foreground")
            bg = router.register_app("agent", "background")
            fg_stub, bg_stub = fg.new_ctx(), bg.new_ctx()
            bg_s = bg.stream(bg_stub, rng.randint(1, cfg.vocab, 8).tolist(),
                             max_new_tokens=48)
            deadline = time.time() + 120
            while bg_s.t_first_token is None:     # bg decode underway
                assert time.time() < deadline, "bg stream never started"
                time.sleep(0.001)
            fg_s = fg.stream(fg_stub, rng.randint(1, cfg.vocab, 8).tolist(),
                             max_new_tokens=4)
            fg_s.result(timeout=120)
            bg_s.result(timeout=120)
            router.drain()
            return fg_s, bg_s, router.preemptions

    fg_whole, bg_whole, pre_whole = fg_ttft(0)
    fg_slice, bg_slice, pre_slice = fg_ttft(2)
    assert pre_whole == 0
    assert pre_slice >= 1 and bg_slice.n_preempts >= 1
    # sliced: fg finished while bg still decoding; whole: fg waited it out
    assert fg_slice.t_done < bg_slice.t_done
    assert fg_whole.t_first_token >= bg_whole.t_done
    assert fg_slice.ttft() < fg_whole.ttft()
    assert len(bg_slice.tokens) == 48       # preemption loses no tokens


# --------------------------------------------------------------------- #
# cancellation
# --------------------------------------------------------------------- #
def test_future_cancel_queued_inline():
    svc, cfg = make_svc()
    rng = np.random.RandomState(1)
    with svc, ServiceRouter(svc, predict=False) as router:
        app = router.register_app("a", "fg")
        s1, s2 = app.new_ctx(), app.new_ctx()
        f1 = app.submit(s1, rng.randint(1, cfg.vocab, 6).tolist(),
                        max_new_tokens=2)
        f2 = app.submit(s2, rng.randint(1, cfg.vocab, 6).tolist(),
                        max_new_tokens=2)
        assert f2.cancel()
        router.drain()
        assert len(f1.result()[1]) == 2
        assert f2.cancelled()
        assert svc.contexts[s2.ctx_id].n_tokens == 0   # never ran
        assert len(router.call_records) == 1


def test_future_cancel_queued_threaded():
    svc, cfg = make_svc()
    rng = np.random.RandomState(2)
    with svc, ServiceRouter(svc, predict=False, start=True) as router:
        app = router.register_app("a", "fg")
        s1, s2 = app.new_ctx(), app.new_ctx()
        f1 = app.submit(s1, rng.randint(1, cfg.vocab, 8).tolist(),
                        max_new_tokens=48)             # keeps dispatcher busy
        f2 = app.submit(s2, rng.randint(1, cfg.vocab, 6).tolist(),
                        max_new_tokens=2)
        won = f2.cancel()
        router.drain()
        assert len(f1.result(120)[1]) == 48
        if won:                     # cancel beat the dispatcher (typical)
            assert f2.cancelled()
            assert svc.contexts[s2.ctx_id].n_tokens == 0
        else:                       # raced: the job ran to completion
            assert len(f2.result(120)[1]) == 2


def test_stream_cancel_mid_generation_consistent():
    """GenerationStream.cancel() between slices: the tokens/chunks left
    in the context match exactly what was decoded, and the context keeps
    working afterwards."""
    svc, cfg = make_svc()
    rng = np.random.RandomState(3)
    with svc, ServiceRouter(svc, predict=False, slice_steps=2) as router:
        app = router.register_app("a", "fg")
        stub = app.new_ctx()
        prompt = rng.randint(1, cfg.vocab, 12).tolist()
        s = app.stream(stub, prompt, max_new_tokens=10)
        router.pump()                   # one slice: 2 tokens, suspended
        assert s.cancel()
        router.drain()
        assert s.done and s.cancelled
        toks = s.result()
        assert len(toks) == 2
        ctx = svc.contexts[stub.ctx_id]
        assert ctx.busy == 0
        assert ctx.n_tokens == len(prompt) + len(toks)
        np.testing.assert_array_equal(
            ctx.tokens[:ctx.n_tokens],
            np.asarray(prompt + toks, np.int32))
        # committed chunks cover exactly the decoded prefix
        assert sum(m.n_covered for m in ctx.chunks.values()) == ctx.n_tokens
        # the per-call record reflects the partial generation
        assert svc.records[-1]["new_tokens"] == len(prompt) + len(toks)
        # context still serves
        _, gen = app.call(stub, rng.randint(1, cfg.vocab, 6).tolist(),
                          max_new_tokens=2)
        assert len(gen) == 2
        app.del_ctx(stub)


def test_delete_busy_context_refused():
    svc, cfg = make_svc()
    rng = np.random.RandomState(4)
    with svc, ServiceRouter(svc, predict=False, slice_steps=2) as router:
        app = router.register_app("a", "fg")
        stub = app.new_ctx()
        s = app.stream(stub, rng.randint(1, cfg.vocab, 8).tolist(),
                       max_new_tokens=8)
        router.pump()                   # suspended mid-generation
        with pytest.raises(RuntimeError):
            app.del_ctx(stub)
        # a failed delete must have NO side effects: the exact-cache
        # resume path (the _active reuse tuple) survives
        assert svc._active is not None and svc._active[0] == stub.ctx_id
        s.cancel()
        router.drain()
        app.del_ctx(stub)               # after cancel: fine
        assert stub.ctx_id not in svc.contexts


def test_same_context_calls_serialize_across_preemption():
    """A request that would jump ahead of a suspended generation on the
    SAME context is held in the queue until that generation resumes and
    finishes (two generations may never overlap one context — the old
    behavior surfaced this as a begin_call RuntimeError under burst
    load); both streams then complete in admission order."""
    svc, cfg = make_svc()
    rng = np.random.RandomState(9)
    with svc, ServiceRouter(svc, predict=False, slice_steps=2) as router:
        bg = router.register_app("agent", "background")
        stub = bg.new_ctx()
        s1 = bg.stream(stub, rng.randint(1, cfg.vocab, 8).tolist(),
                       max_new_tokens=8)
        router.pump()                   # s1 suspended mid-generation
        s2 = bg.stream(stub, rng.randint(1, cfg.vocab, 8).tolist(),
                       max_new_tokens=2, priority="foreground")
        router.drain()
        assert s2.error is None
        assert len(s1.result()) == 8    # the suspended gen ran to term
        assert len(s2.result()) == 2    # then the newcomer got its turn
        ctx = svc.contexts[stub.ctx_id]
        assert ctx.busy == 0
        assert ctx.n_tokens == (8 + 8) + (8 + 2)   # both prompts + gens


def test_begin_call_refuses_overlap_at_service_layer():
    """The service-layer guard stays even though the router now
    serializes: overlapping a suspended generation directly raises."""
    svc, cfg = make_svc()
    rng = np.random.RandomState(11)
    with svc:
        stub = svc.newLLMCtx()
        st = svc.begin_call(stub, GenerationRequest(
            prompt=rng.randint(1, cfg.vocab, 6).tolist(),
            max_new_tokens=4))
        svc.decode_step_batch([st])
        svc.suspend_call(st)
        with pytest.raises(RuntimeError):
            svc.begin_call(stub, GenerationRequest(
                prompt=rng.randint(1, cfg.vocab, 4).tolist(),
                max_new_tokens=2))
        svc.resume_call(st)
        while not st.exhausted:
            svc.decode_step_batch([st])
        svc.finish_call(st)


def test_same_context_job_does_not_trigger_preemption():
    """The preemption predicate exempts a higher-priority job that
    targets the running job's own context (it could not legally overlap
    a suspended generation anyway)."""
    svc, cfg = make_svc()
    rng = np.random.RandomState(10)
    with svc, ServiceRouter(svc, predict=False, slice_steps=2) as router:
        fg = router.register_app("chat", "foreground")
        bg = router.register_app("agent", "background")
        stub, other = bg.new_ctx(), fg.new_ctx()
        fg.stream(stub, rng.randint(1, cfg.vocab, 6).tolist(),
                  max_new_tokens=2)     # fg job on the SAME ctx, queued
        assert not router._higher_priority_waiting(1, stub.ctx_id)
        assert router._higher_priority_waiting(1, other.ctx_id)
        router.drain()


def test_router_exit_aborts_on_exception():
    """An exception inside the with-body must NOT first drain (execute)
    the remaining queue; queued jobs are cancelled instead."""
    svc, cfg = make_svc()
    with svc:
        with pytest.raises(ValueError):
            with ServiceRouter(svc, predict=False) as router:
                app = router.register_app("a", "fg")
                stub = app.new_ctx()
                fut = app.submit(stub, [1, 2, 3], max_new_tokens=2)
                raise ValueError("boom")
        assert fut.cancelled()
        assert svc.contexts[stub.ctx_id].n_tokens == 0  # never ran


# --------------------------------------------------------------------- #
# streaming visibility
# --------------------------------------------------------------------- #
def test_stream_tokens_arrive_incrementally():
    svc, cfg = make_svc()
    rng = np.random.RandomState(5)
    with svc, ServiceRouter(svc, predict=False, start=True) as router:
        app = router.register_app("a", "fg")
        stub = app.new_ctx()
        s = app.stream(stub, rng.randint(1, cfg.vocab, 8).tolist(),
                       max_new_tokens=6)
        seen = list(s)                  # blocks per token as they decode
        assert seen == s.result() and len(seen) == 6
        assert s.ttft() is not None and s.ttft() >= 0
        assert len(s.tbt()) == 5
        st = router.stats()["foreground"]
        assert st["ttft_mean_s"] >= 0
        assert st["ttft_p99_s"] >= st["ttft_p50_s"] >= 0


# --------------------------------------------------------------------- #
# admission ordering extras: deadlines + per-request priority override
# --------------------------------------------------------------------- #
def test_deadline_orders_same_priority():
    svc, cfg = make_svc()
    rng = np.random.RandomState(6)
    with svc, ServiceRouter(svc, predict=False) as router:
        app = router.register_app("a", "fg")
        c1, c2 = app.new_ctx(), app.new_ctx()
        app.stream(c1, rng.randint(1, cfg.vocab, 6).tolist(),
                   max_new_tokens=2)                      # no deadline
        app.stream(c2, rng.randint(1, cfg.vocab, 6).tolist(),
                   max_new_tokens=2,
                   deadline=time.perf_counter() + 0.5)    # EDF: runs first
        router.drain()
        ran = [r["ctx"] for r in router.call_records]
        assert ran == [c2.ctx_id, c1.ctx_id]


def test_request_priority_overrides_session():
    svc, cfg = make_svc()
    rng = np.random.RandomState(7)
    with svc, ServiceRouter(svc, predict=False) as router:
        bg = router.register_app("agent", "background")
        c1, c2 = bg.new_ctx(), bg.new_ctx()
        bg.stream(c1, rng.randint(1, cfg.vocab, 6).tolist(),
                  max_new_tokens=2)
        bg.stream(c2, rng.randint(1, cfg.vocab, 6).tolist(),
                  max_new_tokens=2, priority="foreground")
        router.drain()
        ran = [r["ctx"] for r in router.call_records]
        assert ran == [c2.ctx_id, c1.ctx_id]
        assert router.call_records[0]["priority"] == 0


# --------------------------------------------------------------------- #
# lifecycle satellites
# --------------------------------------------------------------------- #
def test_del_ctx_clears_active_working_cache():
    """Regression: delLLMCtx used to leave the deleted context's bf16
    working cache pinned in the _active reuse tuple."""
    svc, cfg = make_svc()
    rng = np.random.RandomState(8)
    with svc:
        stub = svc.newLLMCtx()
        svc.callLLM(stub, rng.randint(1, cfg.vocab, 8).tolist(),
                    max_new_tokens=2)
        assert svc._active is not None and svc._active[0] == stub.ctx_id
        svc.delLLMCtx(stub)
        assert svc._active is None
        # deleting a NON-active context leaves the reuse tuple alone
        a, b = svc.newLLMCtx(), svc.newLLMCtx()
        svc.callLLM(a, rng.randint(1, cfg.vocab, 8).tolist(), 2)
        svc.callLLM(b, rng.randint(1, cfg.vocab, 8).tolist(), 2)
        svc.delLLMCtx(a)
        assert svc._active is not None and svc._active[0] == b.ctx_id


def test_system_prompt_routed_through_router():
    """newLLMCtx(system_prompt=...) encodes through the router's record
    and prediction path, not behind its back."""
    svc, cfg = make_svc()
    with svc, ServiceRouter(svc, predict=True) as router:
        app = router.register_app("a", "fg")
        stub = app.new_ctx(system_prompt=[1, 2, 3, 4])
        assert svc.contexts[stub.ctx_id].n_tokens == 4
        assert len(router.call_records) == 1
        assert router.call_records[0]["ctx"] == stub.ctx_id
        assert router.predictor.last == stub.ctx_id


def test_close_idempotent_and_context_managers():
    svc, cfg = make_svc()
    with svc:
        with ServiceRouter(svc, predict=False) as router:
            app = router.register_app("a", "fg")
            stub = app.new_ctx()
            app.call(stub, [1, 2, 3], max_new_tokens=2)
        with pytest.raises(RuntimeError):       # router is shut down
            app.submit(stub, [4], max_new_tokens=1)
    svc.close()
    svc.close()                                 # idempotent
