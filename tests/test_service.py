"""End-to-end LLMService behaviour: fidelity under memory pressure,
policy plumbing, AoT/lifecycle invariants, and the Table-1 API."""
import tempfile

import numpy as np
import pytest

from conftest import tiny_model
from repro.core.service import LLMSConfig, LLMService, POLICIES


def make_svc(policy="llms", budget=10_000_000, max_ctx=128, cs=16):
    cfg, model, params = tiny_model("smollm-360m")
    sc = LLMSConfig(policy=policy, max_ctx_len=max_ctx, chunk_tokens=cs,
                    memory_budget=budget, swap_dir=tempfile.mkdtemp())
    return LLMService(model, params, sc), cfg


def drive(svc, cfg, n_ctx=3, rounds=9, seed=7, max_new=4):
    rng = np.random.RandomState(seed)
    stubs = [svc.newLLMCtx() for _ in range(n_ctx)]
    outs = []
    for r in range(rounds):
        prompt = rng.randint(1, cfg.vocab, size=12).tolist()
        _, gen = svc.callLLM(stubs[r % n_ctx], prompt, max_new_tokens=max_new)
        outs.append(gen)
    return stubs, outs


def test_generation_fidelity_under_pressure():
    """The paper's central invariant: restore (I/O + pipelined recompute)
    must not change what the model generates."""
    svc_big, cfg = make_svc(budget=10_000_000)
    _, big = drive(svc_big, cfg)
    svc_big.close()
    svc_small, _ = make_svc(budget=12_000)   # forces chunk eviction
    _, small = drive(svc_small, cfg)
    evictions = sum(1 for c in svc_small.contexts.values()
                    for m in c.chunks.values() if not m.in_memory)
    svc_small.close()
    assert evictions > 0
    assert big == small
    assert svc_small is not None


@pytest.mark.parametrize("policy", POLICIES)
def test_policies_run_and_account(policy):
    svc, cfg = make_svc(policy=policy, budget=120_000)
    _, outs = drive(svc, cfg, rounds=6)
    st = svc.stats()
    assert st["calls"] == 6
    assert all(len(o) == 4 for o in outs)
    assert svc.mem.used <= svc.mem.budget or policy == "lmk"
    svc.close()


def test_aot_makes_chunks_clean():
    """§3.4: after callLLM returns, every chunk is already on disk
    (dirty == False) so Reclaim is free."""
    svc, cfg = make_svc(policy="llms")
    stubs, _ = drive(svc, cfg, n_ctx=1, rounds=2)
    svc.swapper.flush()
    ctx = svc.contexts[stubs[0].ctx_id]
    assert ctx.chunks, "context should have chunks"
    assert all(not m.dirty for m in ctx.chunks.values())
    assert all(svc.store.nbytes((ctx.cid, i)) for i in ctx.chunks)
    svc.close()


def test_compression_budget_respected():
    """Tolerance-aware plan meets the 50% global ratio vs 8-bit base."""
    svc, cfg = make_svc(policy="llms")
    stubs, _ = drive(svc, cfg, n_ctx=1, rounds=3)
    ctx = svc.contexts[stubs[0].ctx_id]
    bits = [m.bits for m in ctx.chunks.values()]
    ratio = {8: 1.0, 4: 0.5, 2: 0.25}
    avg = sum(ratio[b] for b in bits) / len(bits)
    assert avg <= 0.5 + 1e-9
    assert any(b == 8 for b in bits) or len(bits) < 3
    svc.close()


def test_del_ctx_releases_everything():
    svc, cfg = make_svc()
    stubs, _ = drive(svc, cfg, n_ctx=2, rounds=4)
    used_before = svc.mem.used
    svc.delLLMCtx(stubs[0])
    assert svc.mem.used < used_before
    assert stubs[0].ctx_id not in svc.contexts
    # double delete is a no-op
    svc.delLLMCtx(stubs[0])
    svc.close()


def test_condense_on_overflow():
    svc, cfg = make_svc(max_ctx=96)
    stub = svc.newLLMCtx()
    rng = np.random.RandomState(0)
    for _ in range(8):                      # 8 * (12 + 4) > 96: must condense
        svc.callLLM(stub, rng.randint(1, cfg.vocab, 12).tolist(),
                    max_new_tokens=4)
    ctx = svc.contexts[stub.ctx_id]
    assert ctx.n_tokens <= svc.n_slots
    svc.close()


def test_bind_and_stub_api():
    svc, cfg = make_svc()
    assert svc.bindLLMService("some-app") is svc
    stub = svc.newLLMCtx(system_prompt=[1, 2, 3, 4])
    assert svc.contexts[stub.ctx_id].n_tokens == 4
    svc.close()
