"""Hypothesis compatibility shim.

The property tests use a small subset of the hypothesis API.  When the
real package is installed (see requirements-dev.txt) we use it; when it
is absent the tests fall back to a deterministic, seeded example
generator so the tier-1 suite runs green without the dependency.

The fallback supports exactly what the suite needs:
  strategies: lists / floats / integers / booleans / tuples /
              sampled_from, plus .map()
  @given(*strategies)  — runs ``max_examples`` seeded examples
  @settings(max_examples=N, deadline=None) — example-count control

The first examples are boundary-biased (min sizes / interval endpoints)
so the cheap fallback still probes the edges hypothesis would shrink
toward; the rest are drawn from a RandomState seeded by the test name,
so failures reproduce run-to-run.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as np

    class _Strategy:
        """A generator of examples: edge(k) for the first few calls,
        then rng-driven random draws."""

        def __init__(self, draw, edges=()):
            self._draw = draw
            self._edges = list(edges)

        def example(self, rng, k: int):
            if k < len(self._edges):
                e = self._edges[k]
                return e(rng) if callable(e) else e
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(
                lambda rng: fn(self._draw(rng)),
                [lambda rng, e=e: fn(e(rng) if callable(e) else e)
                 for e in self._edges])

    class _Strategies:
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                [min_value, max_value])

        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(
                lambda rng: int(rng.randint(min_value, int(max_value) + 1)),
                [int(min_value), int(max_value)])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.randint(2)),
                             [False, True])

        @staticmethod
        def sampled_from(elements):
            xs = list(elements)
            return _Strategy(lambda rng: xs[rng.randint(len(xs))],
                             [xs[0], xs[-1]])

        @staticmethod
        def tuples(*ss):
            return _Strategy(
                lambda rng: tuple(s.example(rng, len(getattr(s, "_edges", [])))
                                  for s in ss))

        @staticmethod
        def lists(elem, min_size=0, max_size=10, **_):
            def draw(rng):
                n = int(rng.randint(min_size, max_size + 1))
                return [elem.example(rng, k + len(elem._edges))
                        for k in range(n)]
            edges = [lambda rng: [elem.example(rng, k)
                                  for k in range(max(min_size, 1))]]
            return _Strategy(draw, edges if min_size or max_size else [])

    st = _Strategies()

    def settings(max_examples: int = 20, **_kw):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            n = getattr(fn, "_compat_max_examples", 20)

            def wrapper():
                seed = zlib.crc32(fn.__name__.encode()) & 0x7FFFFFFF
                rng = np.random.RandomState(seed)
                for k in range(n):
                    fn(*(s.example(rng, k) for s in strategies))
            # NOT functools.wraps: pytest must see a zero-arg signature,
            # or it would treat the generated params as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
