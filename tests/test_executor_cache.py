"""Executor satellites: the process-wide _JIT_CACHE must key on a
stable model fingerprint (id() reuse after GC must never hand a new
model another model's jitted closures, and sweeps must not grow the
cache without bound), and the pipelined restore must never leave the
module-global _ACTIVE_FEED published."""
import gc
import tempfile

import jax
import numpy as np

from conftest import tiny_model
from repro.configs import get_config, reduced
from repro.core import executor as executor_mod
from repro.core.executor import (_JIT_CACHE, _JIT_CACHE_MAX, _jit_cache_put,
                                 ModelExecutor, model_fingerprint)
from repro.core.service import LLMSConfig, LLMService
from repro.models.registry import build_model


def _build(d_model=64, n_heads=4):
    cfg = reduced(get_config("smollm-360m")).with_overrides(
        name=f"fp-test-{d_model}-{n_heads}", d_model=d_model,
        n_heads=n_heads, head_dim=d_model // n_heads)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_no_cross_model_cache_hit_after_gc():
    """Build two differently-configured models back-to-back (the first
    garbage-collected, so the second may reuse its id()): the second
    must compile its own callables, never inherit the first's."""
    sc = LLMSConfig(policy="llms", max_ctx_len=64)
    model_a, params_a = _build(d_model=64)
    exe_a = ModelExecutor(model_a, params_a, sc)
    fp_a, decode_a = exe_a._fp, exe_a.decode_fn
    keys_a = {k for k in _JIT_CACHE if k[0] == fp_a}
    assert keys_a
    del model_a, params_a, exe_a
    gc.collect()

    model_b, params_b = _build(d_model=32)
    exe_b = ModelExecutor(model_b, params_b, sc)
    assert exe_b._fp != fp_a
    assert exe_b.decode_fn is not decode_a
    assert not keys_a & {k for k in _JIT_CACHE if k[0] == exe_b._fp}


def test_same_config_models_share_compilations():
    """The point of the process-wide cache: two models lowering to the
    same computation (same config + param tree) HIT, so policy/budget
    sweeps never recompile."""
    sc = LLMSConfig(policy="llms", max_ctx_len=64)
    model_a, params_a = _build(d_model=64)
    model_b, params_b = _build(d_model=64)
    assert model_fingerprint(model_a, params_a) == \
        model_fingerprint(model_b, params_b)
    exe_a = ModelExecutor(model_a, params_a, sc)
    exe_b = ModelExecutor(model_b, params_b, sc)
    assert exe_b.decode_fn is exe_a.decode_fn


def test_jit_cache_is_bounded():
    before = dict(_JIT_CACHE)
    try:
        for i in range(2 * _JIT_CACHE_MAX):
            _jit_cache_put(("bound-test", i), object())
        assert len(_JIT_CACHE) <= _JIT_CACHE_MAX
        # LRU: the most recent synthetic keys survived
        assert ("bound-test", 2 * _JIT_CACHE_MAX - 1) in _JIT_CACHE
        assert ("bound-test", 0) not in _JIT_CACHE
    finally:
        for k in [k for k in _JIT_CACHE if k[0] == "bound-test"]:
            del _JIT_CACHE[k]
        for k, v in before.items():     # restore anything LRU-evicted
            _JIT_CACHE.setdefault(k, v)


def test_active_feed_cleared_after_pipelined_restore():
    """Regression: run_pipelined used to leave the last restore's
    LayerFeed published forever (pinning its chunk buffers and exposing
    a stale feed to later retraces)."""
    cfg, model, params = tiny_model("smollm-360m")
    # paged_pool=False: the pipelined recompute restore is a slot-path
    # mechanism — paged switch-ins admit from payload/disk instead.
    sc = LLMSConfig(policy="llms", max_ctx_len=128, memory_budget=15_000,
                    swap_dir=tempfile.mkdtemp(), paged_pool=False)
    rng = np.random.RandomState(0)
    pipelined = {"n": 0}
    with LLMService(model, params, sc) as svc:
        orig = svc.exe.run_pipelined

        def spy(*a, **kw):
            assert executor_mod._ACTIVE_FEED is None    # unset on entry
            out = orig(*a, **kw)
            pipelined["n"] += 1
            return out
        svc.exe.run_pipelined = spy
        stubs = [svc.newLLMCtx() for _ in range(3)]
        for _ in range(3):      # tiny budget: every switch-in restores
            for stub in stubs:
                svc.callLLM(stub, rng.randint(1, cfg.vocab, 24).tolist(),
                            max_new_tokens=2)
        assert executor_mod._ACTIVE_FEED is None
    assert pipelined["n"] > 0, "trace never exercised the pipelined path"
