import os
import sys

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS before any jax import; never set it globally here)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import pytest

from repro.configs import get_config, reduced
from repro.models.registry import build_model

_PARAMS_CACHE = {}


def tiny_model(name: str):
    """(cfg, model, params) for the reduced config of an arch, cached."""
    if name not in _PARAMS_CACHE:
        cfg = reduced(get_config(name))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _PARAMS_CACHE[name] = (cfg, model, params)
    return _PARAMS_CACHE[name]


def make_batch(cfg, B=2, S=24, seed=1):
    import jax.numpy as jnp
    key = jax.random.PRNGKey(seed)
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tok, "targets": tok}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (B, cfg.vision.n_image_tokens, cfg.vision.d_vision),
            jnp.float32)
    return batch


@pytest.fixture(scope="session")
def bench_service_model():
    from benchmarks.common import bench_model
    return bench_model()
