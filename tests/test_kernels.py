"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import chunk_quant, decode_qattn as kdq, ref
from repro.kernels import attn_density as kad


@pytest.mark.parametrize("bits", [8, 4, 2])
@pytest.mark.parametrize("shape", [(16, 128), (16, 384), (32, 100),
                                   (8, 512), (4, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunk_quant_matches_ref(bits, shape, dtype):
    T, F = shape
    x = (jax.random.normal(jax.random.PRNGKey(T * F + bits), shape,
                           jnp.float32) * 3).astype(dtype)
    p_ref, s_ref = ref.quantize_ref(x, bits)
    p_k, s_k = chunk_quant.quantize(x, bits, interpret=True)
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_k), rtol=1e-6)
    if dtype == jnp.float32:
        # bit-exact in fp32; bf16 inputs can differ by 1 code at rounding
        # boundaries (1-ulp reduction-order differences in interpret mode)
        np.testing.assert_array_equal(np.asarray(p_ref), np.asarray(p_k))
    d_ref = ref.dequantize_ref(p_ref, s_ref, bits, T, jnp.float32)
    d_k = chunk_quant.dequantize(p_k, s_k, bits, T, jnp.float32,
                                 interpret=True)
    tol = float(np.max(np.asarray(s_k))) * (0.0 if dtype == jnp.float32
                                            else 1.01)
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_k),
                               rtol=1e-6, atol=tol + 1e-7)


@pytest.mark.parametrize("case", [
    dict(B=2, Sq=64, Sk=64, H=4, KV=2, hd=32, window=0, n_sinks=0),
    dict(B=1, Sq=100, Sk=100, H=8, KV=8, hd=64, window=0, n_sinks=0),
    dict(B=1, Sq=128, Sk=128, H=4, KV=1, hd=16, window=48, n_sinks=8),
    dict(B=2, Sq=48, Sk=48, H=6, KV=3, hd=8, window=0, n_sinks=0),
])
def test_attn_density_matches_ref(case):
    c = case
    ks = jax.random.split(jax.random.PRNGKey(sum(c.values())), 3)
    q = jax.random.normal(ks[0], (c["B"], c["Sq"], c["H"], c["hd"]),
                          jnp.float32)
    k = jax.random.normal(ks[1], (c["B"], c["Sk"], c["KV"], c["hd"]),
                          jnp.float32)
    v = jax.random.normal(ks[2], (c["B"], c["Sk"], c["KV"], c["hd"]),
                          jnp.float32)
    o_ref, d_ref = ref.attn_density_ref(q, k, v, c["window"], c["n_sinks"])
    o_k, d_k = kad.attn_density(q, k, v, c["window"], c["n_sinks"],
                                interpret=True, bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_ref),
                               rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("case", [
    dict(B=2, S=96, H=8, KV=2, hd=32, nv=50, window=0, n_sinks=0),
    dict(B=1, S=200, H=4, KV=4, hd=64, nv=200, window=0, n_sinks=0),
    dict(B=3, S=128, H=8, KV=1, hd=16, nv=100, window=40, n_sinks=4),
])
def test_decode_qattn_matches_ref(case):
    c = case
    ks = jax.random.split(jax.random.PRNGKey(c["S"] + c["H"]), 5)
    q = jax.random.normal(ks[0], (c["B"], c["H"], c["hd"]), jnp.float32)
    kq = jax.random.randint(ks[1], (c["B"], c["S"], c["KV"], c["hd"]),
                            -127, 128, jnp.int32).astype(jnp.int8)
    vq = jax.random.randint(ks[2], (c["B"], c["S"], c["KV"], c["hd"]),
                            -127, 128, jnp.int32).astype(jnp.int8)
    kscale = jax.random.uniform(ks[3], (c["B"], c["S"], c["KV"]),
                                jnp.float32, 0.001, 0.02)
    vscale = jax.random.uniform(ks[4], (c["B"], c["S"], c["KV"]),
                                jnp.float32, 0.001, 0.02)
    o_ref = ref.decode_qattn_ref(q, kq, vq, kscale, vscale, c["nv"],
                                 c["window"], c["n_sinks"])
    o_k = kdq.decode_qattn(q, kq, vq, kscale, vscale, c["nv"], c["window"],
                           c["n_sinks"], interpret=True, bs=32)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-5)


def _mixed_case(c, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed + c["S"]), 8)
    q = jax.random.normal(ks[0], (c["B"], c["H"], c["hd"]), jnp.float32)
    k = jax.random.normal(ks[1], (c["B"], c["S"], c["KV"], c["hd"]),
                          jnp.bfloat16)
    v = jax.random.normal(ks[2], (c["B"], c["S"], c["KV"], c["hd"]),
                          jnp.bfloat16)
    kq = jax.random.randint(ks[3], (c["B"], c["S"], c["KV"], c["hd"]),
                            -127, 128, jnp.int32).astype(jnp.int8)
    vq = jax.random.randint(ks[4], (c["B"], c["S"], c["KV"], c["hd"]),
                            -127, 128, jnp.int32).astype(jnp.int8)
    kscale = jax.random.uniform(ks[5], (c["B"], c["S"], c["KV"]),
                                jnp.float32, 0.001, 0.02)
    vscale = jax.random.uniform(ks[6], (c["B"], c["S"], c["KV"]),
                                jnp.float32, 0.001, 0.02)
    qm = jax.random.bernoulli(ks[7], 0.5, (c["B"], c["S"]))
    return q, k, v, kq, vq, kscale, vscale, qm


@pytest.mark.parametrize("case", [
    dict(B=2, S=96, H=8, KV=2, hd=32, nv=50, window=0, n_sinks=0),
    dict(B=1, S=200, H=4, KV=4, hd=64, nv=200, window=0, n_sinks=0),
    dict(B=3, S=128, H=8, KV=1, hd=16, nv=100, window=40, n_sinks=4),
])
def test_decode_mqattn_matches_ref(case):
    """Pallas mixed kernel (interpret) vs oracle over half-quant caches."""
    c = case
    q, k, v, kq, vq, ks_, vs_, qm = _mixed_case(c)
    o_ref = ref.decode_mqattn_ref(q, k, v, kq, vq, ks_, vs_, qm, c["nv"],
                                  c["window"], c["n_sinks"])
    o_k = kdq.decode_mqattn(q, k, v, kq, vq, ks_, vs_, qm, c["nv"],
                            c["window"], c["n_sinks"], interpret=True, bs=32)
    np.testing.assert_allclose(np.asarray(o_k, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("case", [
    dict(B=2, S=96, H=8, KV=2, hd=32, nv=50, window=0, n_sinks=0),
    dict(B=3, S=128, H=8, KV=1, hd=16, nv=100, window=40, n_sinks=4),
])
@pytest.mark.parametrize("block", [32, 64])
def test_mixed_blocked_jnp_matches_ref(case, block):
    """The blocked-jnp fused-dequant CPU path (online softmax over key
    blocks) vs the oracle, with and without the density statistic."""
    from repro.models import common as C
    c = case
    q, k, v, kq, vq, ks_, vs_, qm = _mixed_case(c, seed=7)
    o_ref = ref.decode_mqattn_ref(q, k, v, kq, vq, ks_, vs_, qm, c["nv"],
                                  c["window"], c["n_sinks"])
    qb = q[:, None].astype(jnp.bfloat16)
    o_b = C.mixed_decode_attention_blocked(
        qb, k, v, kq, vq, ks_, vs_, qm, jnp.int32(c["nv"]),
        c["window"], c["n_sinks"], block=block)
    np.testing.assert_allclose(np.asarray(o_b[:, 0], np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=3e-2, atol=3e-2)
    o_b2, mass = C.mixed_decode_attention_blocked(
        qb, k, v, kq, vq, ks_, vs_, qm, jnp.int32(c["nv"]),
        c["window"], c["n_sinks"], want_density=True, block=block)
    np.testing.assert_array_equal(np.asarray(o_b2), np.asarray(o_b))
    mass = np.asarray(mass)
    assert mass.shape == (c["B"], c["S"])
    # each row's mass over visible keys sums to ~1 (normalized softmax)
    np.testing.assert_allclose(mass.sum(axis=1), 1.0, rtol=1e-3)


def test_mixed_select_identical_to_full_dequant_attention():
    """The select path must be BITWISE identical to materializing the
    dequantized values into the bf16 cache and running the plain decode
    attention — the token-identity contract of the quant tier."""
    from repro.models import common as C
    c = dict(B=2, S=64, H=4, KV=2, hd=16, nv=40, window=0, n_sinks=0)
    q, k, v, kq, vq, ks_, vs_, qm = _mixed_case(c, seed=3)
    qb = q[:, None].astype(jnp.bfloat16)
    mixed = C.mixed_decode_attention(qb, k, v, kq, vq, ks_, vs_, qm,
                                     jnp.int32(c["nv"]))
    k_mat = C.dequant_select(k, kq, ks_, qm)
    v_mat = C.dequant_select(v, vq, vs_, qm)
    full = C.decode_attention(qb, k_mat, v_mat, jnp.int32(c["nv"]))
    np.testing.assert_array_equal(np.asarray(mixed, np.float32),
                                  np.asarray(full, np.float32))


def test_ops_dispatch_ref_on_cpu():
    from repro.kernels import ops
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
    p, s = ops.chunk_quantize(x, bits=4)
    y = ops.chunk_dequantize(p, s, bits=4, n_tokens=16)
    assert y.shape == x.shape
    c = dict(B=1, S=64, H=4, KV=2, hd=16, nv=30, window=0, n_sinks=0)
    q, k, v, kq, vq, ks_, vs_, qm = _mixed_case(c)
    o = ops.decode_mqattn(q, k, v, kq, vq, ks_, vs_, qm, c["nv"])
    assert o.shape == q.shape
