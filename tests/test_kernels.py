"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import chunk_quant, decode_qattn as kdq, ref
from repro.kernels import attn_density as kad


@pytest.mark.parametrize("bits", [8, 4, 2])
@pytest.mark.parametrize("shape", [(16, 128), (16, 384), (32, 100),
                                   (8, 512), (4, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunk_quant_matches_ref(bits, shape, dtype):
    T, F = shape
    x = (jax.random.normal(jax.random.PRNGKey(T * F + bits), shape,
                           jnp.float32) * 3).astype(dtype)
    p_ref, s_ref = ref.quantize_ref(x, bits)
    p_k, s_k = chunk_quant.quantize(x, bits, interpret=True)
    np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_k), rtol=1e-6)
    if dtype == jnp.float32:
        # bit-exact in fp32; bf16 inputs can differ by 1 code at rounding
        # boundaries (1-ulp reduction-order differences in interpret mode)
        np.testing.assert_array_equal(np.asarray(p_ref), np.asarray(p_k))
    d_ref = ref.dequantize_ref(p_ref, s_ref, bits, T, jnp.float32)
    d_k = chunk_quant.dequantize(p_k, s_k, bits, T, jnp.float32,
                                 interpret=True)
    tol = float(np.max(np.asarray(s_k))) * (0.0 if dtype == jnp.float32
                                            else 1.01)
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_k),
                               rtol=1e-6, atol=tol + 1e-7)


@pytest.mark.parametrize("case", [
    dict(B=2, Sq=64, Sk=64, H=4, KV=2, hd=32, window=0, n_sinks=0),
    dict(B=1, Sq=100, Sk=100, H=8, KV=8, hd=64, window=0, n_sinks=0),
    dict(B=1, Sq=128, Sk=128, H=4, KV=1, hd=16, window=48, n_sinks=8),
    dict(B=2, Sq=48, Sk=48, H=6, KV=3, hd=8, window=0, n_sinks=0),
])
def test_attn_density_matches_ref(case):
    c = case
    ks = jax.random.split(jax.random.PRNGKey(sum(c.values())), 3)
    q = jax.random.normal(ks[0], (c["B"], c["Sq"], c["H"], c["hd"]),
                          jnp.float32)
    k = jax.random.normal(ks[1], (c["B"], c["Sk"], c["KV"], c["hd"]),
                          jnp.float32)
    v = jax.random.normal(ks[2], (c["B"], c["Sk"], c["KV"], c["hd"]),
                          jnp.float32)
    o_ref, d_ref = ref.attn_density_ref(q, k, v, c["window"], c["n_sinks"])
    o_k, d_k = kad.attn_density(q, k, v, c["window"], c["n_sinks"],
                                interpret=True, bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_ref),
                               rtol=2e-3, atol=2e-4)


@pytest.mark.parametrize("case", [
    dict(B=2, S=96, H=8, KV=2, hd=32, nv=50, window=0, n_sinks=0),
    dict(B=1, S=200, H=4, KV=4, hd=64, nv=200, window=0, n_sinks=0),
    dict(B=3, S=128, H=8, KV=1, hd=16, nv=100, window=40, n_sinks=4),
])
def test_decode_qattn_matches_ref(case):
    c = case
    ks = jax.random.split(jax.random.PRNGKey(c["S"] + c["H"]), 5)
    q = jax.random.normal(ks[0], (c["B"], c["H"], c["hd"]), jnp.float32)
    kq = jax.random.randint(ks[1], (c["B"], c["S"], c["KV"], c["hd"]),
                            -127, 128, jnp.int32).astype(jnp.int8)
    vq = jax.random.randint(ks[2], (c["B"], c["S"], c["KV"], c["hd"]),
                            -127, 128, jnp.int32).astype(jnp.int8)
    kscale = jax.random.uniform(ks[3], (c["B"], c["S"], c["KV"]),
                                jnp.float32, 0.001, 0.02)
    vscale = jax.random.uniform(ks[4], (c["B"], c["S"], c["KV"]),
                                jnp.float32, 0.001, 0.02)
    o_ref = ref.decode_qattn_ref(q, kq, vq, kscale, vscale, c["nv"],
                                 c["window"], c["n_sinks"])
    o_k = kdq.decode_qattn(q, kq, vq, kscale, vscale, c["nv"], c["window"],
                           c["n_sinks"], interpret=True, bs=32)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-5)


def test_ops_dispatch_ref_on_cpu():
    from repro.kernels import ops
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64))
    p, s = ops.chunk_quantize(x, bits=4)
    y = ops.chunk_dequantize(p, s, bits=4, n_tokens=16)
    assert y.shape == x.shape
