"""Per-architecture smoke tests (reduced configs, CPU) + decode/prefill
and recompute parity for the cache-bearing families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, tiny_model
from repro.configs import REGISTRY

ALL_ARCHS = sorted(REGISTRY)


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_train_step(name):
    cfg, model, params = tiny_model(name)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), name
    assert 0 < float(loss) < 20


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_prefill_decode_parity(name):
    cfg, model, params = tiny_model(name)
    B, S = 2, 24
    batch = make_batch(cfg, B, S)
    pb = dict(batch)
    pb.pop("targets")
    want_density = cfg.family != "rwkv6"
    pf = jax.jit(lambda p, b: model.prefill(p, b, want_density=want_density)
                 )(params, pb)
    assert pf.logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(pf.logits)).all()
    if want_density:
        assert pf.density.shape == (B, S)
        assert np.isfinite(np.asarray(pf.density)).all()

    cache = model.init_cache(B, S)
    if cfg.family in ("encdec", "vlm"):
        cache["xk"], cache["xv"] = pf.cache["xk"], pf.cache["xv"]
    dec = jax.jit(model.decode_step)
    for i in range(S):
        out = dec(params, batch["tokens"][:, i:i + 1], cache)
        cache = out.cache
    np.testing.assert_allclose(np.asarray(out.logits), np.asarray(pf.logits),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("name", ["smollm-360m", "llama2-7b",
                                  "deepseek-v2-lite-16b"])
def test_recompute_exact(name):
    """Paper Fig. 7: interleaved-chunk recompute restores KV exactly."""
    cfg, model, params = tiny_model(name)
    B, S = 1, 32
    batch = make_batch(cfg, B, S)
    pf = jax.jit(lambda p, b: model.prefill(p, b))(params,
                                                   {"tokens": batch["tokens"]})
    leaves = ("ckv", "kpe") if cfg.family == "mla_moe" else ("k", "v")
    miss = jnp.array([3, 4, 10, 11, 20, 21])
    holey = dict(pf.cache)
    for lf in leaves:
        holey[lf] = holey[lf].at[:, :, miss].set(0)
    cache2, hidden, dens = jax.jit(
        lambda p, t, q, c: model.recompute(p, t, q, c, S, want_density=True)
    )(params, batch["tokens"][:, miss], miss, holey)
    for lf in leaves:
        np.testing.assert_allclose(np.asarray(cache2[lf]),
                                   np.asarray(pf.cache[lf]),
                                   rtol=2e-2, atol=2e-2)
    assert hidden.shape[1] == len(miss)
    assert np.isfinite(np.asarray(dens)).all()


def test_extend_is_prefill_append():
    """recompute with a contiguous suffix == prefill of the whole seq."""
    cfg, model, params = tiny_model("smollm-360m")
    B, S0, T = 1, 16, 8
    batch = make_batch(cfg, B, S0 + T)
    toks = batch["tokens"]
    pf_full = jax.jit(lambda p, b: model.prefill(p, b))(
        params, {"tokens": toks})
    pf_half = jax.jit(lambda p, b: model.prefill(p, b))(
        params, {"tokens": toks[:, :S0]})
    cache = {k: (jnp.pad(v, ((0, 0), (0, 0), (0, T), (0, 0), (0, 0)))
                 if k != "pos" else v)
             for k, v in pf_half.cache.items()}
    pos = jnp.arange(S0, S0 + T, dtype=jnp.int32)
    cache2, hidden, _ = jax.jit(
        lambda p, t, q, c: model.recompute(p, t, q, c, S0 + T)
    )(params, toks[:, S0:], pos, cache)
    logits = np.asarray(hidden[:, -1] @ model.head_weight(params))
    np.testing.assert_allclose(logits, np.asarray(pf_full.logits),
                               rtol=3e-2, atol=3e-2)


def test_flash_attention_matches_blocked():
    from repro.models import common as C
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    B, S, H, KV, hd = 2, 160, 6, 2, 16
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    ref = C.blocked_causal_attention(q, k, v, block=64).out
    out = C.flash_attention(q, k, v, 0, 64, 0, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
    # gradients flow and are finite
    g = jax.grad(lambda q: jnp.sum(C.flash_attention(q, k, v, 0, 64, 0, 0)
                                   .astype(jnp.float32)))(q)
    assert np.isfinite(np.asarray(g)).all()


def test_flash_attention_grad_matches_reference():
    from repro.models import common as C
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    B, S, H, KV, hd = 1, 96, 4, 2, 8
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)

    def ref_loss(q, k, v):
        pos = jnp.arange(S)
        mask = C.causal_window_mask(pos, pos)
        return jnp.sum(C.gqa_attention(q, k, v, mask).out
                       .astype(jnp.float32) ** 2)

    def flash_loss(q, k, v):
        return jnp.sum(C.flash_attention(q, k, v, 0, 32, 0, 0)
                       .astype(jnp.float32) ** 2)

    g_ref = jax.grad(ref_loss, argnums=(0, 1, 2))(q, k, v)
    g_fl = jax.grad(flash_loss, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_fl):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-3, atol=2e-3)


def test_rwkv_chunked_matches_sequential():
    """Chunked-parallel wkv == step-by-step recurrence."""
    cfg, model, params = tiny_model("rwkv6-1.6b")
    B, S = 2, 21
    batch = make_batch(cfg, B, S)
    pf = jax.jit(lambda p, b: model.prefill(p, b))(params,
                                                   {"tokens": batch["tokens"]})
    cache = model.init_cache(B, S)
    dec = jax.jit(model.decode_step)
    for i in range(S):
        out = dec(params, batch["tokens"][:, i:i + 1], cache)
        cache = out.cache
    np.testing.assert_allclose(np.asarray(cache["wkv"]),
                               np.asarray(pf.cache["wkv"]),
                               rtol=2e-2, atol=2e-2)
