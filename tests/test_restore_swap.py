"""Segmented chunk-file format, LayerFeed ordering, swap tier."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.chunks import CompressedChunk
from repro.core.restore import (LayerFeed, np_dequantize, read_chunk_file,
                                read_chunk_layer, write_chunk_file,
                                _read_header)
from repro.core.swap import AsyncSwapper, DiskStore
from repro.kernels import ref


def _mk_chunk(bits, T=16, L=4, Fl=32, seed=0):
    F = L * Fl
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (T, F)),
                   np.float32)
    if bits == 16:
        data = {"k": (x.astype(np.float16), np.zeros(0, np.float32)),
                "v": (x.astype(np.float16) * 2, np.zeros(0, np.float32))}
    else:
        pk, sk = ref.quantize_ref(jnp.asarray(x), bits)
        pv, sv = ref.quantize_ref(jnp.asarray(x * 2), bits)
        data = {"k": (np.asarray(pk), np.asarray(sk)),
                "v": (np.asarray(pv), np.asarray(sv))}
    shapes = {"k": (T, F), "v": (T, F)}
    return CompressedChunk(bits=bits, n_tokens=T, data=data, shapes=shapes), x


@pytest.mark.parametrize("bits", [16, 8, 4, 2])
def test_chunk_file_roundtrip(bits):
    cc, x = _mk_chunk(bits)
    path = os.path.join(tempfile.mkdtemp(), "c.bin")
    write_chunk_file(path, cc, n_layers=4)
    back = read_chunk_file(path)
    assert back.bits == bits and back.n_tokens == cc.n_tokens
    for name in cc.data:
        np.testing.assert_array_equal(back.data[name][0], cc.data[name][0])
        np.testing.assert_allclose(back.data[name][1], cc.data[name][1])


@pytest.mark.parametrize("bits", [16, 8, 4, 2])
def test_per_layer_read_matches_whole(bits):
    cc, x = _mk_chunk(bits, L=4, Fl=32)
    path = os.path.join(tempfile.mkdtemp(), "c.bin")
    write_chunk_file(path, cc, n_layers=4)
    whole = read_chunk_file(path)
    w_deq = {n: np_dequantize(*whole.data[n], bits, 16) for n in cc.data}
    with open(path, "rb") as f:
        header, base = _read_header(f)
        for l in range(4):
            seg = read_chunk_layer(f, header, base, l)
            for n in cc.data:
                np.testing.assert_allclose(
                    seg[n], w_deq[n][:, l * 32:(l + 1) * 32],
                    rtol=1e-6, atol=1e-7)


def test_layerfeed_streams_in_order():
    tmp = tempfile.mkdtemp()
    paths = []
    for c in range(3):
        cc, _ = _mk_chunk(8, T=16, L=4, Fl=32, seed=c)
        p = os.path.join(tmp, f"c{c}.bin")
        write_chunk_file(p, cc, n_layers=4)
        paths.append(p)
    feed = LayerFeed(paths, ["k", "v"], n_layers=4, chunk_tokens=16,
                     leaf_dims={"k": (4, 8), "v": (4, 8)}, pad_chunks=1)
    for l in range(4):
        got = feed.fetch(l)
        assert got["k"].shape == (4 * 16, 4, 8)     # 3 chunks + 1 pad
        assert np.all(got["k"][48:] == 0)           # padded chunk zeroed
    feed.close()


def test_diskstore_async_swapper():
    store = DiskStore(tempfile.mkdtemp())
    sw = AsyncSwapper(store)
    fut = sw.write_async((1, "state"), {"a": np.arange(10)})
    back = sw.read((1, "state"))                    # waits for the write
    np.testing.assert_array_equal(back["a"], np.arange(10))
    fut.result()
    assert store.nbytes((1, "state")) > 0
    store.delete((1, "state"))
    assert store.nbytes((1, "state")) is None
    sw.shutdown()


@given(st.sampled_from([8, 4, 2]), st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_np_dequantize_matches_jnp_ref(bits, seed):
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (16, 64)),
                   np.float32)
    p, s = ref.quantize_ref(jnp.asarray(x), bits)
    a = np_dequantize(np.asarray(p), np.asarray(s), bits, 16)
    b = np.asarray(ref.dequantize_ref(p, s, bits, 16, jnp.float32))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)
