"""AsyncSwapper regression tests: same-key writes must chain on the
pool, never block the submitting thread (AoT swap-out is advertised as
asynchronous — paper §3.4, DESIGN.md §3)."""
import tempfile
import threading
import time

import pytest

from repro.core.swap import AsyncSwapper, DiskStore


def make_swapper(workers=2):
    store = DiskStore(tempfile.mkdtemp(prefix="swap_async_"))
    return store, AsyncSwapper(store, workers=workers)


def test_same_key_double_write_does_not_block():
    """A second write to an in-flight key returns immediately instead of
    waiting on prev.result()."""
    store, sw = make_swapper()
    gate = threading.Event()
    started = threading.Event()

    def slow_write():
        started.set()
        assert gate.wait(10.0), "gate never released"
        return store.write((0, 0), {"v": 1})

    f1 = sw.submit((0, 0), slow_write)
    assert started.wait(5.0)
    t0 = time.perf_counter()
    f2 = sw.write_async((0, 0), {"v": 2})
    submit_elapsed = time.perf_counter() - t0
    assert submit_elapsed < 0.5, \
        f"submit blocked {submit_elapsed:.3f}s on in-flight same-key write"
    assert not f2.done(), "chained write ran before its predecessor"
    gate.set()
    f1.result(10.0)
    f2.result(10.0)
    sw.flush()
    assert store.read((0, 0)) == {"v": 2}   # later write wins
    sw.shutdown()


def test_same_key_writes_serialize_in_order():
    """Chained writes apply in submission order even under a burst."""
    store, sw = make_swapper(workers=2)
    for v in range(8):
        sw.write_async((1, 3), {"v": v})
    sw.flush()
    assert store.read((1, 3)) == {"v": 7}
    sw.shutdown()


def test_read_waits_for_inflight_write():
    store, sw = make_swapper()
    gate = threading.Event()

    def slow_write():
        assert gate.wait(10.0)
        return store.write((2, 0), {"v": "late"})

    sw.submit((2, 0), slow_write)
    got = {}

    def reader():
        got["v"] = sw.read((2, 0))

    t = threading.Thread(target=reader)
    t.start()
    time.sleep(0.05)
    assert "v" not in got                   # read is waiting on the write
    gate.set()
    t.join(10.0)
    assert got["v"] == {"v": "late"}
    sw.shutdown()


def test_submit_failure_propagates_and_unblocks_chain():
    store, sw = make_swapper()

    def boom():
        raise RuntimeError("disk on fire")

    f1 = sw.submit((3, 0), boom)
    f2 = sw.write_async((3, 0), {"v": "after"})   # chains after the failure
    with pytest.raises(RuntimeError):
        f1.result(10.0)
    f2.result(10.0)                                # still runs
    assert store.read((3, 0)) == {"v": "after"}
    sw.shutdown()
