"""AsyncSwapper regression tests: same-key writes must chain on the
pool, never block the submitting thread (AoT swap-out is advertised as
asynchronous — paper §3.4, DESIGN.md §3)."""
import tempfile
import threading
import time

import pytest

from repro.core.swap import AsyncSwapper, DiskStore


def make_swapper(workers=2):
    store = DiskStore(tempfile.mkdtemp(prefix="swap_async_"))
    return store, AsyncSwapper(store, workers=workers)


def test_same_key_double_write_does_not_block():
    """A second write to an in-flight key returns immediately instead of
    waiting on prev.result()."""
    store, sw = make_swapper()
    gate = threading.Event()
    started = threading.Event()

    def slow_write():
        started.set()
        assert gate.wait(10.0), "gate never released"
        return store.write((0, 0), {"v": 1})

    f1 = sw.submit((0, 0), slow_write)
    assert started.wait(5.0)
    t0 = time.perf_counter()
    f2 = sw.write_async((0, 0), {"v": 2})
    submit_elapsed = time.perf_counter() - t0
    assert submit_elapsed < 0.5, \
        f"submit blocked {submit_elapsed:.3f}s on in-flight same-key write"
    assert not f2.done(), "chained write ran before its predecessor"
    gate.set()
    f1.result(10.0)
    f2.result(10.0)
    sw.flush()
    assert store.read((0, 0)) == {"v": 2}   # later write wins
    sw.shutdown()


def test_same_key_writes_serialize_in_order():
    """Chained writes apply in submission order even under a burst."""
    store, sw = make_swapper(workers=2)
    for v in range(8):
        sw.write_async((1, 3), {"v": v})
    sw.flush()
    assert store.read((1, 3)) == {"v": 7}
    sw.shutdown()


def test_read_waits_for_inflight_write():
    store, sw = make_swapper()
    gate = threading.Event()

    def slow_write():
        assert gate.wait(10.0)
        return store.write((2, 0), {"v": "late"})

    sw.submit((2, 0), slow_write)
    got = {}

    def reader():
        got["v"] = sw.read((2, 0))

    t = threading.Thread(target=reader)
    t.start()
    time.sleep(0.05)
    assert "v" not in got                   # read is waiting on the write
    gate.set()
    t.join(10.0)
    assert got["v"] == {"v": "late"}
    sw.shutdown()


def test_read_async_does_not_deadlock_single_worker():
    """Regression: read_async used to submit a BLOCKING read to the same
    pool that executes writes — with every worker parked in a read
    waiting on a pending same-key write, the chained write could never
    get a worker and the pool self-deadlocked.  With workers=1 the old
    code hangs here; chained reads complete."""
    store, sw = make_swapper(workers=1)
    gate = threading.Event()

    f1 = sw.submit((4, 0), gate.wait, 10.0)       # occupies the only worker
    f2 = sw.write_async((4, 0), {"v": "written"})  # chained behind f1
    r = sw.read_async((4, 0))                      # must chain off f2,
    assert not r.done()                            # not steal the worker
    gate.set()
    assert r.result(10.0) == {"v": "written"}
    f1.result(10.0)
    f2.result(10.0)
    sw.shutdown()


def test_read_async_propagates_failed_write():
    """Parity with the blocking read (which raises via fut.result()): a
    chained read must surface the failed same-key write, not silently
    return stale pre-write bytes."""
    store, sw = make_swapper(workers=1)
    store.write((6, 0), {"v": "stale"})
    gate = threading.Event()

    def boom():
        raise RuntimeError("disk on fire")

    sw.submit((6, 0), gate.wait, 10.0)  # keeps the key in flight
    sw.submit((6, 0), boom)             # the write that will fail
    r = sw.read_async((6, 0))           # chained behind it
    gate.set()
    with pytest.raises(RuntimeError, match="disk on fire"):
        r.result(10.0)
    sw.shutdown()


def test_read_async_without_pending_write_is_direct():
    store, sw = make_swapper(workers=1)
    store.write((5, 0), {"v": 1})
    assert sw.read_async((5, 0)).result(10.0) == {"v": 1}
    sw.shutdown()


def test_total_bytes_safe_under_concurrent_writes():
    """DiskStore.total_bytes snapshots under the store lock; hammering
    writes from threads while summing must never raise or tear."""
    store, sw = make_swapper(workers=2)
    stop = threading.Event()
    errors = []

    def writer(tid):
        for i in range(200):
            store.write((tid, i), {"v": i})

    def reader():
        while not stop.is_set():
            try:
                assert store.total_bytes >= 0
            except Exception as e:          # pragma: no cover - the bug
                errors.append(e)
                return

    rt = threading.Thread(target=reader)
    rt.start()
    ws = [threading.Thread(target=writer, args=(t,)) for t in range(4)]
    for w in ws:
        w.start()
    for w in ws:
        w.join(30.0)
    stop.set()
    rt.join(10.0)
    assert not errors
    assert store.total_bytes == sum(store._bytes.values())
    sw.shutdown()


def test_submit_failure_propagates_and_unblocks_chain():
    store, sw = make_swapper()

    def boom():
        raise RuntimeError("disk on fire")

    f1 = sw.submit((3, 0), boom)
    f2 = sw.write_async((3, 0), {"v": "after"})   # chains after the failure
    with pytest.raises(RuntimeError):
        f1.result(10.0)
    f2.result(10.0)                                # still runs
    assert store.read((3, 0)) == {"v": "after"}
    sw.shutdown()
