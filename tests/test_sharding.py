"""Structural sharding-rule checks: every sharded dim of every arch's
params/caches must divide the production axis sizes.  Pure pytree math —
catches rule regressions without 512 forced devices."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, REGISTRY, SHAPES, get_config
from repro.models.registry import build_model
from repro.sharding.rules import (_axsize, cache_pspecs, param_pspecs,
                                  state_pspecs)
from repro.train.optimizer import OptConfig, init_state

ARCHS = sorted(REGISTRY)


def _check_tree(tree, specs, where):
    leaves, _ = jax.tree_util.tree_flatten(tree)
    slv, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(slv), where
    for leaf, spec in zip(leaves, slv):
        entries = tuple(spec)
        assert len(entries) <= len(leaf.shape), (where, leaf.shape, spec)
        for dim, e in zip(leaf.shape, entries):
            assert dim % _axsize(e) == 0, (where, leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_divisible(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    _check_tree(params, param_pspecs(cfg, params), arch)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_state_specs_divisible(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    import functools
    state = jax.eval_shape(functools.partial(
        init_state, cfg=OptConfig(quantized=True)), params)
    _check_tree(state, state_pspecs(cfg, state), arch)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_specs_divisible(arch, shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    from repro.configs import shape_applicability
    ok, _ = shape_applicability(cfg, shape)
    if not ok:
        pytest.skip("shape inapplicable")
    model = build_model(cfg)
    cache = model.cache_specs(shape)
    specs = cache_pspecs(cfg, cache, shape, ("data",))
    _check_tree(cache, specs, f"{arch}/{shape_name}")


def test_sharded_params_have_major_coverage():
    """The big 2D-shardable weights must actually be sharded (not silently
    replicated) — guards against rules regressing to P()."""
    cfg = get_config("qwen3-32b")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = param_pspecs(cfg, params)
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    by_name = {jax.tree_util.keystr(k): v for k, v in flat}
    assert any("wq" in k and tuple(v) != () for k, v in by_name.items())
    leaves, _ = jax.tree_util.tree_flatten(params)
    slv, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    sharded_bytes = sum(
        int(jnp.prod(jnp.array(l.shape))) for l, s in zip(leaves, slv)
        if tuple(s))
    total = sum(int(jnp.prod(jnp.array(l.shape))) for l in leaves)
    assert sharded_bytes / total > 0.95
