"""Per-family conformance suite for the KVSpec cache-adapter protocol
(DESIGN.md §2): every family in the registry must honor the contract
its spec declares — layout validation, chunk/whole-state round-trips,
the executor servable gate, batched-decode token identity — and the
one-release deprecation shims must warn.  Plus a ZooService routing
unit test (the heterogeneous zoo behind one budget, DESIGN.md §4)."""
import tempfile
import warnings

import jax
import numpy as np
import pytest

from conftest import make_batch, tiny_model
from repro.configs import REGISTRY, get_config, reduced
from repro.core.chunks import ChunkCodec, WholeStateCodec
from repro.core.service import LLMSConfig
from repro.models.kvspec import LAYOUT_MIXED, LAYOUT_WINDOW
from repro.models.registry import FAMILIES, family_spec

# one representative arch per family; zoo families pinned to the
# benchmark's members, the rest take the first registry entry
FAMILY_ARCH = {"dense": "smollm-360m",
               "mla_moe": "deepseek-v2-lite-16b",
               "rwkv6": "rwkv6-1.6b"}
for _name in sorted(REGISTRY):
    FAMILY_ARCH.setdefault(REGISTRY[_name].family, _name)

ALL_FAMILIES = sorted(FAMILY_ARCH)


def spec_only(family):
    """(cfg, spec) without touching params — the registry query path."""
    cfg = reduced(get_config(FAMILY_ARCH[family]))
    return cfg, family_spec(cfg)


def test_registry_covers_every_family():
    assert set(FAMILY_ARCH) == set(FAMILIES)


@pytest.mark.parametrize("family", ALL_FAMILIES)
def test_spec_declares_a_coherent_cache(family):
    cfg, spec = spec_only(family)
    assert spec.family == cfg.family == family
    # KVSpec.__post_init__ enforces the cross-field invariants; assert
    # the repo-level expectations on top
    assert spec.seq_leaves or spec.state_leaves
    assert spec.tolerance_class in ("kv", "latent", "image", "state")
    assert spec.min_bits in (2, 4, 8, 16)
    assert LAYOUT_WINDOW in spec.layouts
    if spec.quant_resident:
        assert LAYOUT_MIXED in spec.layouts
    if spec.state_leaves:
        # recurrent state is never chunk-quantized below 16 bits and
        # never pad-extended
        if not spec.seq_leaves:
            assert spec.min_bits == 16 and not spec.pad_safe


@pytest.mark.parametrize("family", ALL_FAMILIES)
def test_undeclared_layout_is_a_clean_error(family):
    _, model, _ = tiny_model(FAMILY_ARCH[family])
    spec = model.kv_spec()
    with pytest.raises(ValueError, match="does not support cache layout"):
        model.init_cache(1, 32, layout="bogus")
    if LAYOUT_MIXED not in spec.layouts:
        with pytest.raises(ValueError,
                           match="does not support cache layout"):
            model.init_cache(1, 32, layout=LAYOUT_MIXED)
    else:
        model.init_cache(1, 32, layout=LAYOUT_MIXED)


@pytest.mark.parametrize("family", ALL_FAMILIES)
def test_supports_flags_warn_and_answer_from_spec(family):
    _, model, _ = tiny_model(FAMILY_ARCH[family])
    spec = model.kv_spec()
    for attr, field in (("supports_batched_decode", "batched_decode"),
                        ("supports_quant_resident", "quant_resident"),
                        ("supports_paged_pool", "paged")):
        with pytest.warns(DeprecationWarning, match="deprecated"):
            assert getattr(model, attr) == getattr(spec, field)


@pytest.mark.parametrize("family", ALL_FAMILIES)
def test_mixed_quant_kwarg_warns_and_maps_to_layout(family):
    _, model, _ = tiny_model(FAMILY_ARCH[family])
    spec = model.kv_spec()
    want_mixed = spec.quant_resident
    with pytest.warns(DeprecationWarning, match="mixed_quant"):
        legacy = model.init_cache(2, 32, mixed_quant=want_mixed)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        new = model.init_cache(
            2, 32, layout=LAYOUT_MIXED if want_mixed else LAYOUT_WINDOW)
    assert jax.tree.structure(legacy) == jax.tree.structure(new)


@pytest.mark.parametrize("family", ALL_FAMILIES)
def test_declared_leaves_round_trip(family):
    """The codec contract: every declared leaf extracts to a canonical
    block and inserts back bit-exactly (chunk codec over seq_leaves,
    whole-state codec over state_leaves)."""
    _, model, _ = tiny_model(FAMILY_ARCH[family])
    spec = model.kv_spec()
    cache = model.init_cache(2, 32)
    key = jax.random.PRNGKey(3)
    filled = dict(cache)
    for name in spec.seq_leaves + spec.state_leaves:
        a = cache[name]
        key, sub = jax.random.split(key)
        filled[name] = jax.random.normal(sub, a.shape).astype(a.dtype)
    if spec.seq_leaves:
        codec = ChunkCodec(spec.seq_leaves, 16)
        blocks = codec.extract(filled, 0, 16)
        assert set(blocks) == set(spec.seq_leaves)
        back = codec.extract(codec.insert(cache, 0, blocks), 0, 16)
        for name in blocks:
            np.testing.assert_array_equal(np.asarray(blocks[name]),
                                          np.asarray(back[name]))
    if spec.state_leaves:
        codec = WholeStateCodec(spec.state_leaves, 16)
        blocks = codec.extract(filled)
        assert set(blocks) == set(spec.state_leaves)
        back = codec.extract(codec.insert(cache, 0, blocks))
        for name in blocks:
            np.testing.assert_array_equal(np.asarray(blocks[name]),
                                          np.asarray(back[name]))


@pytest.mark.parametrize("family", ALL_FAMILIES)
def test_executor_honors_servable_gate(family):
    from repro.core.executor import ModelExecutor
    _, model, params = tiny_model(FAMILY_ARCH[family])
    sc = LLMSConfig(policy="llms", max_ctx_len=64, chunk_tokens=16)
    if model.kv_spec().servable:
        exe = ModelExecutor(model, params, sc)
        assert exe.spec is not None
        assert exe.chunked_cache == model.kv_spec().chunkable
    else:
        with pytest.raises(ValueError, match="not servable"):
            ModelExecutor(model, params, sc)


@pytest.mark.parametrize(
    "family", [f for f in ALL_FAMILIES
               if family_spec(reduced(get_config(FAMILY_ARCH[f])))
               .batched_decode])
def test_batched_decode_is_token_identical_to_serial(family):
    """The spec bit is a PROMISE: [B, 1] batched decode must pick the
    same tokens as B serial batch-1 decodes."""
    cfg, model, params = tiny_model(FAMILY_ARCH[family])
    B, S = 2, 8
    batch = make_batch(cfg, B, S, seed=11)
    dec = jax.jit(model.decode_step)
    cb = model.init_cache(B, 16)
    for i in range(S):
        out = dec(params, batch["tokens"][:, i:i + 1], cb)
        cb = out.cache
    serial = []
    for b in range(B):
        c1 = model.init_cache(1, 16)
        for i in range(S):
            o = dec(params, batch["tokens"][b:b + 1, i:i + 1], c1)
            c1 = o.cache
        serial.append(np.asarray(o.logits))
    batched = np.asarray(out.logits)
    serial = np.concatenate(serial, axis=0)
    np.testing.assert_array_equal(batched.argmax(-1), serial.argmax(-1))
    np.testing.assert_allclose(batched, serial, rtol=2e-2, atol=2e-2)


# --------------------------------------------------------------------- #
# ZooService: many families, one budget
# --------------------------------------------------------------------- #

def _zoo(fams=("dense", "rwkv6"), budget=500_000):
    members = {}
    for fam in fams:
        _, model, params = tiny_model(FAMILY_ARCH[fam])
        members[fam] = (model, params,
                        LLMSConfig(policy="llms", max_ctx_len=64,
                                   chunk_tokens=16, memory_budget=budget))
    from repro.core.zoo import ZooService
    return ZooService(members, memory_budget=budget,
                      swap_dir=tempfile.mkdtemp(prefix="zoo_test_"))


def test_zoo_routes_by_context_ownership():
    with _zoo() as zoo:
        with pytest.raises(ValueError, match="unknown family"):
            zoo.newLLMCtx(family="nope")
        s_d = zoo.newLLMCtx(family="dense")
        s_r = zoo.newLLMCtx(family="rwkv6")
        # one cid space across members
        assert s_d.ctx_id != s_r.ctx_id
        assert zoo.family_of(s_d.ctx_id) == "dense"
        assert zoo.family_of(s_r.ctx_id) == "rwkv6"
        _, toks_d = zoo.callLLM(s_d, [1, 2, 3, 4], max_new_tokens=3)
        _, toks_r = zoo.callLLM(s_r, [5, 6, 7, 8], max_new_tokens=3)
        assert len(toks_d) == 3 and len(toks_r) == 3
        st = zoo.stats()
        assert set(st["families"]) == {"dense", "rwkv6"}
        assert st["families"]["dense"]["total_calls"] == 1
        assert st["families"]["rwkv6"]["total_calls"] == 1
        assert st["total_calls"] == 2
        # both families' bytes are charged to the ONE budget
        assert st["families"]["dense"]["resident_bytes"] > 0
        assert st["families"]["rwkv6"]["resident_bytes"] > 0
        assert st["mem_used"] <= 500_000
        zoo.delLLMCtx(s_d)
        assert s_d.ctx_id not in zoo._owner


def test_zoo_default_family_is_first_member():
    with _zoo() as zoo:
        stub = zoo.newLLMCtx()
        assert zoo.family_of(stub.ctx_id) == "dense"


def test_zoo_tokens_match_solo_service():
    """The shared substrate must not change what a member generates:
    the same prompt to the same family, solo vs zoo, same tokens."""
    from repro.core.service import LLMService
    prompt = [9, 10, 11, 12]
    with _zoo() as zoo:
        stub = zoo.newLLMCtx(family="dense")
        _, zoo_toks = zoo.callLLM(stub, prompt, max_new_tokens=4)
    _, model, params = tiny_model(FAMILY_ARCH["dense"])
    sc = LLMSConfig(policy="llms", max_ctx_len=64, chunk_tokens=16,
                    memory_budget=500_000,
                    swap_dir=tempfile.mkdtemp(prefix="solo_test_"))
    svc = LLMService(model, params, sc)
    try:
        stub = svc.newLLMCtx()
        _, solo_toks = svc.callLLM(stub, prompt, max_new_tokens=4)
    finally:
        svc.close()
    assert zoo_toks == solo_toks
