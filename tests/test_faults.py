"""Fault injection + recovery (DESIGN.md §6): seeded failpoint
registry, checksummed chunk/blob envelopes, retry classification,
recompute recovery token-identity, ENOSPC degraded mode, watchdog
preemption, and degraded background shedding."""
import os
import tempfile
import threading

import numpy as np
import pytest

from conftest import tiny_model
from repro.core.faults import (FAULTS, ChunkCorruptError, DiskFullError,
                               FaultRegistry, FaultSpec,
                               PersistentIOError, SwapTimeoutError,
                               TransientIOError, canon_key, clear_faults,
                               corrupt_file, install_faults,
                               plan_from_config, retryable, set_disk_full,
                               with_retries)
from repro.core.pagepool import PagePool
from repro.core.requests import BACKGROUND, FOREGROUND
from repro.core.restore import (read_chunk_file, verify_chunk_file,
                                write_chunk_file)
from repro.core.scheduler import ServiceRouter
from repro.core.service import LLMSConfig, LLMService
from repro.core.swap import AsyncSwapper, DiskStore, open_blob, seal_blob


@pytest.fixture(autouse=True)
def _clean_faults():
    clear_faults()
    yield
    clear_faults()


def _check_outcomes(reg, site, key, n, exc):
    """Outcome vector of ``n`` consecutive checks (True = raised)."""
    out = []
    for _ in range(n):
        try:
            reg.check(site, key)
            out.append(False)
        except exc:
            out.append(True)
    return out


def _find_seed(spec, site, key, want, exc, limit=5000):
    """A seed whose first len(want) draws produce exactly ``want``."""
    reg = FaultRegistry()
    for seed in range(limit):
        reg.install([spec], seed)
        if _check_outcomes(reg, site, key, len(want), exc) == want:
            return seed
    raise AssertionError("no seed found for wanted outcome pattern")


# --------------------------------------------------------------------- #
# registry units
# --------------------------------------------------------------------- #
def test_canon_key():
    assert canon_key((3, 7)) == "3:7"
    assert canon_key("/tmp/x/ctx3_chunk7.pkl") == "ctx3_chunk7.pkl"
    assert canon_key("/tmp/x/ctx3_chunk7.pkl.tmp") == "ctx3_chunk7.pkl"


def test_transient_fires_consecutively_then_heals():
    spec = FaultSpec(kind="transient_eio", sites=("disk.read",),
                     rate=0.3, fail_n=2)
    want = [True, True, False, False, False, False]
    seed = _find_seed(spec, "disk.read", (1, 0), want, TransientIOError)
    reg = FaultRegistry()
    reg.install([spec], seed)
    assert _check_outcomes(reg, "disk.read", (1, 0), 6,
                           TransientIOError) == want
    assert reg.counters()["injected"]["transient_eio"] == 2


def test_same_seed_replays_identically():
    spec = FaultSpec(kind="transient_eio", sites=("disk.read",
                                                  "disk.write"), rate=0.4)
    reg = FaultRegistry()
    runs = []
    for _ in range(2):
        reg.install([spec], 99)
        out = []
        for key in [(0, 0), (0, 1), (1, 0)] * 4:
            out += _check_outcomes(reg, "disk.read", key, 2,
                                   TransientIOError)
            out += _check_outcomes(reg, "disk.write", key, 2,
                                   TransientIOError)
        runs.append(out)
    assert runs[0] == runs[1]
    assert any(runs[0])          # rate 0.4 over 48 draws: some fire
    reg.install([spec], 100)     # different seed -> different draws
    out2 = []
    for key in [(0, 0), (0, 1), (1, 0)] * 4:
        out2 += _check_outcomes(reg, "disk.read", key, 2,
                                TransientIOError)
        out2 += _check_outcomes(reg, "disk.write", key, 2,
                                TransientIOError)
    assert out2 != runs[0]


def test_persistent_marks_key_until_rewrite():
    spec = FaultSpec(kind="persistent_eio", sites=("disk.write",),
                     rate=0.3)
    # first draw fires; the mark (not fresh draws) keeps it failing
    want = [True, True, True, True]
    seed = _find_seed(spec, "disk.write", (2, 0), want, PersistentIOError)
    reg = FaultRegistry()
    reg.install([spec], seed)
    assert _check_outcomes(reg, "disk.write", (2, 0), 4,
                           PersistentIOError) == want
    reg.note_write_ok((2, 0))
    # mark cleared; the seed search guaranteed ops 1..3 drew clean, but
    # op 4+ is a fresh draw — just assert the mark itself is gone
    assert canon_key((2, 0)) not in reg._persistent


def test_enospc_and_disk_full_window():
    reg = FaultRegistry()
    reg.install([FaultSpec(kind="enospc", sites=("disk.write",),
                           rate=1.0)], 0)
    with pytest.raises(DiskFullError):
        reg.check("disk.write", (0, 0))
    reg.check("disk.read", (0, 0))       # read sites unaffected
    reg.clear()
    assert not reg.active
    reg.set_disk_full(True)
    assert reg.active and reg.disk_full
    with pytest.raises(DiskFullError):
        reg.check("disk.write", (0, 0))
    reg.check("disk.read", (0, 0))
    reg.set_disk_full(False)
    reg.check("disk.write", (0, 0))


def test_corrupt_action_and_corrupt_file():
    reg = FaultRegistry()
    reg.install([FaultSpec(kind="torn_write", sites=("disk.write",),
                           rate=1.0)], 0)
    assert reg.corrupt_action((0, 0)) == "torn"
    reg.install([FaultSpec(kind="bit_flip", sites=("disk.write",),
                           rate=1.0)], 0)
    assert reg.corrupt_action((0, 0)) == "bit_flip"
    reg.clear()
    assert reg.corrupt_action((0, 0)) is None

    tmp = tempfile.mkdtemp()
    p = os.path.join(tmp, "f.bin")
    raw = bytes(range(256)) * 4
    with open(p, "wb") as f:
        f.write(raw)
    corrupt_file(p, "torn")
    assert os.path.getsize(p) == len(raw) // 2
    with open(p, "wb") as f:
        f.write(raw)
    corrupt_file(p, "bit_flip")
    with open(p, "rb") as f:
        got = f.read()
    assert len(got) == len(raw) and got != raw
    assert sum(a != b for a, b in zip(got, raw)) == 1


def test_plan_from_config_validation():
    specs, seed = plan_from_config(
        {"transient_eio": 0.1, "bit_flip": 0.02, "seed": 42}, 7)
    assert seed == 42
    assert {s.kind for s in specs} == {"transient_eio", "bit_flip"}
    specs, seed = plan_from_config({"enospc": 0.5}, 7)
    assert seed == 7 and specs[0].sites == ("disk.write",)
    with pytest.raises(ValueError):
        plan_from_config({"nope": 1.0}, 0)


# --------------------------------------------------------------------- #
# checksummed envelopes
# --------------------------------------------------------------------- #
def test_blob_envelope_detects_tampering():
    blob = b"payload bytes" * 20
    raw = seal_blob(blob)
    assert open_blob(raw, "t") == blob
    flipped = bytearray(raw)
    flipped[len(raw) // 2] ^= 0x10
    with pytest.raises(ChunkCorruptError):
        open_blob(bytes(flipped), "t")
    with pytest.raises(ChunkCorruptError):
        open_blob(raw[:len(raw) // 2], "t")
    with pytest.raises(ChunkCorruptError):
        open_blob(b"XXXX" + raw[4:], "t")


def _mk_chunk_file(path):
    from repro.core.chunks import CompressedChunk
    x = np.random.RandomState(0).randn(16, 128).astype(np.float16)
    cc = CompressedChunk(
        bits=16, n_tokens=16,
        data={"k": (x, np.zeros(0, np.float32)),
              "v": (x * 2, np.zeros(0, np.float32))},
        shapes={"k": (16, 128), "v": (16, 128)})
    write_chunk_file(path, cc, n_layers=4)
    return cc


@pytest.mark.parametrize("action", ["torn", "bit_flip"])
def test_chunk_file_detects_corruption(action):
    tmp = tempfile.mkdtemp()
    p = os.path.join(tmp, "c.bin")
    _mk_chunk_file(p)
    verify_chunk_file(p)                 # intact: no raise
    corrupt_file(p, action)
    with pytest.raises(ChunkCorruptError):
        read_chunk_file(p)
    if action == "torn":                 # structural pre-validation
        with pytest.raises(ChunkCorruptError):
            verify_chunk_file(p)


def test_tmp_sweep_regression():
    """A crash between temp-write and os.replace leaves an orphan
    ``*.tmp``; startup must sweep it and never serve its bytes."""
    root = tempfile.mkdtemp()
    store = DiskStore(root)
    store.write((0, 0), {"x": 1})
    orphan = store._path((0, 1)) + ".tmp"
    with open(orphan, "wb") as f:
        f.write(b"garbage from a torn writer")
    store2 = DiskStore(root)             # restart
    assert store2.tmp_swept == 1
    assert not os.path.exists(orphan)
    assert store2.read((0, 0)) == {"x": 1}


# --------------------------------------------------------------------- #
# retry classification + swapper behaviour
# --------------------------------------------------------------------- #
def test_retryable_classification():
    assert retryable(TransientIOError("x"))
    assert retryable(PersistentIOError("x"))     # exhausts the budget
    assert not retryable(DiskFullError("x"))     # retry can't free space
    assert not retryable(ChunkCorruptError("x"))
    assert not retryable(FileNotFoundError("x"))
    assert not retryable(ValueError("x"))


def test_with_retries_bounded_backoff():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientIOError("x")
        return "ok"
    assert with_retries(flaky, attempts=3, base_s=0.0) == "ok"
    assert calls["n"] == 3

    calls["n"] = 0

    def hard():
        calls["n"] += 1
        raise ChunkCorruptError("x")
    with pytest.raises(ChunkCorruptError):
        with_retries(hard, attempts=3, base_s=0.0)
    assert calls["n"] == 1               # non-retryable: no second try


def test_swapper_retries_transient_read():
    spec = FaultSpec(kind="transient_eio", sites=("disk.read",),
                     rate=0.3, fail_n=1)
    want = [True, False, False, False]
    seed = _find_seed(spec, "disk.read", (0, 0), want, TransientIOError)
    store = DiskStore(tempfile.mkdtemp())
    store.write((0, 0), {"x": 5})
    install_faults([spec], seed)
    sw = AsyncSwapper(store, retries=3, retry_base_s=0.0)
    try:
        assert sw.read((0, 0)) == {"x": 5}
        assert sw.io_retries == 1 and sw.io_recovered == 1
    finally:
        clear_faults()
        sw.shutdown()


def test_wait_flush_timeout_and_shutdown_cancels_chained():
    store = DiskStore(tempfile.mkdtemp())
    sw = AsyncSwapper(store, workers=1)
    gate = threading.Event()
    f1 = sw.submit((0, 0), lambda: gate.wait(10))
    f2 = sw.submit((0, 0), lambda: 2)    # chained behind the wedged f1
    try:
        with pytest.raises(SwapTimeoutError):
            sw.wait((0, 0), timeout=0.05)
        with pytest.raises(SwapTimeoutError):
            sw.flush(timeout=0.05)
        sw.shutdown(timeout=0.1)         # must not hang on the wedge
        assert f2.cancelled()            # never started -> cancelled
        assert not f1.cancelled()        # in flight: left to finish
    finally:
        gate.set()


def test_pool_admit_failpoint_retries_in_place():
    spec = FaultSpec(kind="transient_eio", sites=("pool.admit",),
                     rate=0.3, fail_n=1)
    want = [True, False, False]
    seed = _find_seed(spec, "pool.admit", (5, 0), want, TransientIOError)
    install_faults([spec], seed)
    pp = object.__new__(PagePool)
    pp.admit_fault_retries = 0
    pp._admit_check(5, 0)                # transient: retried on the spot
    assert pp.admit_fault_retries == 1


# --------------------------------------------------------------------- #
# spec plumbing
# --------------------------------------------------------------------- #
def test_scenario_spec_fault_validation():
    from repro.loadgen.spec import ScenarioSpec, validate_spec
    ok = ScenarioSpec(name="t", n_contexts=1, n_calls=1,
                      faults={"transient_eio": 0.1,
                              "disk_full_windows": [[1.0, 2.0]],
                              "swap_deadline_s": 5.0})
    validate_spec(ok)
    with pytest.raises(ValueError):
        validate_spec(ok.override(faults={"bogus_knob": 1.0}))
    with pytest.raises(ValueError):
        validate_spec(ok.override(faults={"disk_full_windows": [[5, 2]]}))
    with pytest.raises(ValueError):
        validate_spec(ok.override(faults={"swap_deadline_s": 0}))


def test_config_plumbs_watchdog_and_retries():
    cfg, model, params = tiny_model("smollm-360m")
    sc = LLMSConfig(policy="llms_nocomp", max_ctx_len=64, chunk_tokens=16,
                    memory_budget=100_000, io_retries=5,
                    io_retry_base_s=0.001, swap_deadline_s=7.5,
                    swap_dir=tempfile.mkdtemp())
    svc = LLMService(model, params, sc)
    try:
        assert svc.swapper.retries == 5
        assert svc.res._deadline == 7.5
        assert "degraded_mode" in svc.stats()
        assert "chunks_recovered_recompute" in svc.stats()
    finally:
        svc.close()


# --------------------------------------------------------------------- #
# end-to-end recovery
# --------------------------------------------------------------------- #
def _svc(policy="llms_nocomp", budget=12_000, paged=False, **kw):
    cfg, model, params = tiny_model("smollm-360m")
    sc = LLMSConfig(policy=policy, max_ctx_len=128, chunk_tokens=16,
                    memory_budget=budget, paged_pool=paged,
                    swap_dir=tempfile.mkdtemp(), **kw)
    return LLMService(model, params, sc), cfg


def _drive(svc, cfg, n_ctx=3, rounds=9, seed=7, max_new=4):
    rng = np.random.RandomState(seed)
    stubs = [svc.newLLMCtx() for _ in range(n_ctx)]
    outs = []
    for r in range(rounds):
        prompt = rng.randint(1, cfg.vocab, size=12).tolist()
        _, gen = svc.callLLM(stubs[r % n_ctx], prompt,
                             max_new_tokens=max_new)
        outs.append(gen)
    return outs


@pytest.mark.parametrize("paged", [False, True])
def test_corrupt_chunk_recovery_token_identity(paged):
    """Bit-flipped chunk files are detected by CRC and recovered by
    recompute from tokens; under the 16-bit policy the recovered run's
    tokens are IDENTICAL to the fault-free run's (DESIGN.md §6)."""
    svc, cfg = _svc(paged=paged)
    clean = _drive(svc, cfg)
    svc.close()

    install_faults(
        [FaultSpec(kind="bit_flip", sites=("disk.write",), rate=0.25)],
        seed=2024)
    svc2, _ = _svc(paged=paged)
    try:
        faulty = _drive(svc2, cfg)
        st = svc2.stats()
    finally:
        clear_faults()
        svc2.close()
    assert st["faults_injected_total"] > 0, "no faults drawn: dead test"
    assert st["chunks_corrupt_detected"] > 0
    assert st["chunks_recovered_recompute"] > 0
    assert st["recover_failed"] == 0
    assert faulty == clean


def test_transient_eio_recovered_by_retries():
    install_faults(
        [FaultSpec(kind="transient_eio",
                   sites=("disk.read", "disk.write", "swap.worker"),
                   rate=0.10, fail_n=1)], seed=77)
    svc, cfg = _svc()
    try:
        _drive(svc, cfg)
        st = svc.stats()
    finally:
        clear_faults()
        svc.close()
    assert st["faults_injected_total"] > 0
    assert st["io_retries"] > 0
    assert st["io_failed_jobs"] == 0     # fail_n=1 always heals in-budget
    assert st["recover_failed"] == 0


def test_enospc_degraded_cycle_token_identity():
    """Disk-full window: degraded mode is entered (AoT off, evictions
    drop dirty payloads), foreground calls keep completing via
    recompute, and the probe write exits the mode once space returns."""
    svc, cfg = _svc()
    clean = _drive(svc, cfg, rounds=12)
    svc.close()

    svc3, _ = _svc()
    try:
        rng = np.random.RandomState(7)
        stubs = [svc3.newLLMCtx() for _ in range(3)]
        outs = []
        for r in range(12):
            if r == 4:
                set_disk_full(True)
            if r == 8:
                set_disk_full(False)
            prompt = rng.randint(1, cfg.vocab, size=12).tolist()
            _, gen = svc3.callLLM(stubs[r % 3], prompt, max_new_tokens=4)
            outs.append(gen)
            if r == 6:
                assert svc3.res.degraded, \
                    "writes failing but degraded mode never entered"
        st = svc3.stats()
    finally:
        clear_faults()
        svc3.close()
    assert st["degraded_entries"] >= 1
    assert st["degraded_exits"] >= 1
    assert not st["degraded_mode"], "probe never exited degraded mode"
    assert outs == clean
    # post-exit flush: nothing left permanently dirty
    assert st["recover_failed"] == 0


# --------------------------------------------------------------------- #
# router: degraded shedding + watchdog preemption
# --------------------------------------------------------------------- #
def test_degraded_sheds_background_until_fg_served():
    svc, cfg = _svc(budget=200_000)
    router = ServiceRouter(svc, predict=False, start=False, slice_steps=2)
    try:
        fg = router.register_app("fg", "foreground")
        bg = router.register_app("bg", "background")
        sf, sb = fg.new_ctx(), bg.new_ctx()
        st_bg = bg.stream(sb, [1, 2, 3], max_new_tokens=2)
        st_fg = fg.stream(sf, [4, 5, 6], max_new_tokens=2)
        svc.res._enter_degraded()
        jobs = router._pop_batch(4, set())
        assert [j["prio"] for j in jobs] == [FOREGROUND]
        assert router.bg_shed == 1
        router._run_batch(jobs, refill=False)
        # only background remains: it must NOT be shed (livelock guard)
        jobs2 = router._pop_batch(4, set())
        assert [j["prio"] for j in jobs2] == [BACKGROUND]
        router._run_batch(jobs2, refill=False)
        assert st_fg.done and st_bg.done
        assert st_fg.error is None and st_bg.error is None
        assert router.stats()["bg_shed"] == 1
    finally:
        router.shutdown()
        clear_faults()
        svc.close()


def test_watchdog_timeout_requeues_then_fails():
    svc, cfg = _svc(budget=200_000)
    router = ServiceRouter(svc, predict=False, start=False)
    try:
        app = router.register_app("a", "foreground")
        stub = app.new_ctx()
        real = svc.begin_call
        calls = {"n": 0}

        def wedged_twice(stub_, req):
            calls["n"] += 1
            if calls["n"] <= 2:
                raise SwapTimeoutError("swap wedged")
            return real(stub_, req)

        svc.begin_call = wedged_twice
        s1 = app.stream(stub, [1, 2, 3], max_new_tokens=2)
        router.drain()
        assert s1.done and s1.error is None     # requeued, then served
        assert router.watchdog_preempts == 2

        svc.begin_call = lambda *_: (_ for _ in ()).throw(
            SwapTimeoutError("permanently wedged"))
        s2 = app.stream(stub, [1, 2, 3], max_new_tokens=2)
        router.drain()
        assert isinstance(s2.error, SwapTimeoutError)   # bounded: fails
        assert router.watchdog_preempts == 5            # 2 + 3 more
        svc.begin_call = real
    finally:
        router.shutdown()
        svc.close()
