"""Property tests for the tolerance-aware compression planner (Eq. 1-3)
and the chunk codec."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import compression as comp
from repro.kernels import ref

RATIO = {8: 1.0, 4: 0.5, 2: 0.25}


@given(st.lists(st.floats(0.0, 10.0), min_size=1, max_size=40),
       st.sampled_from([0.3, 0.5, 0.75]))
@settings(max_examples=60, deadline=None)
def test_plan_buckets_constraint(ds, ratio_global):
    D = np.asarray(ds)
    bits = comp.plan_buckets(D, ratio_global)
    assert len(bits) == len(D)
    avg = sum(RATIO[int(b)] for b in bits) / len(bits)
    assert avg <= ratio_global + 1e-9


@given(st.lists(st.floats(0.0, 10.0), min_size=1, max_size=7))
@settings(max_examples=40, deadline=None)
def test_plan_buckets_optimal_vs_bruteforce(ds):
    D = np.asarray(ds)
    bits = comp.plan_buckets(D, 0.5)
    _, best_info = comp.plan_buckets_brute(D, 0.5)
    info = sum(RATIO[int(b)] * d for b, d in zip(bits, D))
    assert info >= best_info - 1e-9


def test_plan_buckets_density_monotone():
    """Denser chunks never get FEWER bits (the paper's intent)."""
    D = np.asarray([9.0, 5.0, 4.0, 1.0, 0.5, 0.1])
    bits = comp.plan_buckets(D, 0.5)
    order = np.argsort(-D)
    b_sorted = bits[order]
    assert all(b_sorted[i] >= b_sorted[i + 1]
               for i in range(len(b_sorted) - 1))


def test_unmeasured_chunks_treated_densest():
    dens = np.zeros(128)
    cnt = np.zeros(128)
    cnt[:96] = 1                               # chunks 6,7 unmeasured
    D = comp.chunk_density(dens, cnt, 128, 16)
    assert np.isinf(D[6]) and np.isinf(D[7])
    bits = comp.plan_buckets(D, 0.5)           # n=8: two 8-bit slots fit
    assert bits[6] == 8 and bits[7] == 8       # unmeasured stay precise


@given(st.integers(2, 5).map(lambda k: 2 ** k),      # T in {4..32}
       st.integers(1, 20).map(lambda k: k * 8),      # F multiple of 8
       st.sampled_from([8, 4, 2]),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_quant_roundtrip_error_bound(T, F, bits, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (T, F),
                          jnp.float32) * 2.0
    packed, scale = ref.quantize_ref(x, bits)
    out = ref.dequantize_ref(packed, scale, bits, T, jnp.float32)
    err = np.abs(np.asarray(out) - np.asarray(x))
    bound = np.asarray(scale)[None, :] * 0.51 + 1e-6
    assert (err <= bound).all()


@given(st.sampled_from([8, 4, 2]), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_quant_idempotent(bits, seed):
    """quant(dequant(quant(x))) == quant(x): re-encoding at the same
    level is lossless (matters when the service re-plans levels)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (16, 64), jnp.float32)
    p1, s1 = ref.quantize_ref(x, bits)
    y = ref.dequantize_ref(p1, s1, bits, 16, jnp.float32)
    p2, s2 = ref.quantize_ref(y, bits)
    y2 = ref.dequantize_ref(p2, s2, bits, 16, jnp.float32)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y),
                               rtol=1e-5, atol=1e-6)
