"""Tier-1 tests for the concurrency/JIT discipline analyzer
(``repro.analysis``): fixture corpus through the static checkers,
baseline round-trip, the runtime lock-order witness, and the
repo-clean gate the CI analysis job enforces.
"""
import textwrap
import threading

import pytest

from repro.analysis import baseline
from repro.analysis.findings import Finding
from repro.analysis.runner import (
    REPO_ROOT, analyze_source, run_default)
from repro.analysis.runtime import (
    LockOrderError, OrderedLock, order_graph, reset_witness,
    witness_condition, witness_lock, witness_rlock)

FIXDIR = REPO_ROOT / "src" / "repro" / "analysis" / "fixtures"


def rules(findings):
    return sorted(f"{f.checker}/{f.rule}" for f in findings)


def analyze(src):
    return analyze_source(textwrap.dedent(src))


# --------------------------------------------------------------------- #
# static checkers: inline fixture corpus
# --------------------------------------------------------------------- #

def test_locked_call_without_lock_flagged():
    fs = analyze("""
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.n = 0

            def _bump_locked(self):
                self.n += 1

            def good(self):
                with self._lock:
                    self._bump_locked()

            def bad(self):
                self._bump_locked()
    """)
    assert rules(fs) == ["lock/locked-call"]
    (f,) = fs
    assert f.scope == "Counter.bad"


def test_blocking_under_lock_flagged():
    fs = analyze("""
        import threading
        import time

        class Poller:
            def __init__(self):
                self._lock = threading.Lock()

            def bad(self):
                with self._lock:
                    time.sleep(0.01)

            def good(self):
                time.sleep(0.01)
    """)
    assert rules(fs) == ["lock/blocking-under-lock"]
    assert fs[0].scope == "Poller.bad"


def test_condition_wait_under_own_lock_allowed():
    # Condition.wait releases the lock while blocked (allow_held)
    fs = analyze("""
        import threading

        class Q:
            def __init__(self):
                self._cv = threading.Condition()
                self.items = []

            def pop(self):
                with self._cv:
                    while not self.items:
                        self._cv.wait()
                    return self.items.pop()
    """)
    assert "lock/blocking-under-lock" not in rules(fs)


def test_jit_self_closure_flagged():
    fs = analyze("""
        import jax

        class Model:
            def __init__(self):
                self.scale = 2.0
                self.fn = jax.jit(lambda x: x * self.scale)
    """)
    assert "jit/self-in-traced-fn" in rules(fs)


def test_jit_host_call_flagged():
    fs = analyze("""
        import jax

        def make():
            def step(x):
                print(x)
                return x + 1
            return jax.jit(step)
    """)
    assert "jit/host-call-in-jit" in rules(fs)


def test_unhashable_jit_key_flagged():
    # the PR 3 `id(model)` cache-key bug class
    fs = analyze("""
        def lookup(cache, model, shape):
            key = [id(model), shape]
            return cache[key]
    """)
    assert "jit/unhashable-jit-key" in rules(fs)


# --------------------------------------------------------------------- #
# committed regression fixtures (also exercised by --selftest)
# --------------------------------------------------------------------- #

def test_pr3_deadlock_fixture_flagged():
    src = (FIXDIR / "pr3_deadlock.py").read_text()
    assert "lock/blocking-in-worker" in rules(analyze_source(src))


def test_family_dispatch_fixture_flagged():
    src = (FIXDIR / "family_dispatch.py").read_text()
    flagged = [f for f in analyze_source(src)
               if f.checker == "family" and f.rule == "string-dispatch"]
    # the two old executor gates + the != fork; the `fam not in` local
    # alias is deliberately out of reach (name-based, no dataflow)
    assert len(flagged) >= 3
    assert {f.scope for f in flagged} == {"OldExecutor.init_cache"}


def test_family_dispatch_registry_allowlisted():
    # the registry IS the dispatch point: scanning the real tree must
    # not flag it (covered by the repo-clean gate below, but assert the
    # allowlist explicitly so a rename breaks loudly)
    from repro.analysis import config as acfg
    assert "src/repro/models/registry.py" in \
        acfg.FAMILY_DISPATCH_ALLOWED_FILES


def test_pr6_restore_race_fixture_flagged():
    src = (FIXDIR / "pr6_restore_race.py").read_text()
    fs = analyze_source(src)
    flagged = [f for f in fs if f.rule == "unordered-store-read"]
    assert len(flagged) == 1
    # only the unordered variant — restore_chunk_fixed waits first
    assert flagged[0].scope == "BadRestore.restore_chunk"


# --------------------------------------------------------------------- #
# baseline round-trip
# --------------------------------------------------------------------- #

def _finding(msg="blocking call under lock", line=10):
    return Finding(checker="lock", rule="blocking-under-lock",
                   file="src/x.py", line=line, scope="C.f", message=msg)


def test_baseline_round_trip(tmp_path):
    path = tmp_path / "base.json"
    old = _finding()
    baseline.write(path, [old])
    known = baseline.load(path)
    assert old.fingerprint in known

    moved = _finding(line=99)          # pure code motion: same identity
    fresh = _finding(msg="a brand-new finding")
    new, grandfathered = baseline.diff([moved, fresh], known)
    assert [f.message for f in grandfathered] == [moved.message]
    assert [f.message for f in new] == [fresh.message]


def test_baseline_missing_file_is_empty(tmp_path):
    assert baseline.load(tmp_path / "nope.json") == set()


# --------------------------------------------------------------------- #
# runtime lock-order witness
# --------------------------------------------------------------------- #

@pytest.fixture(autouse=True)
def _clean_graph():
    reset_witness()
    yield
    reset_witness()


def test_ordered_lock_records_edges():
    a, b = OrderedLock("A"), OrderedLock("B")
    with a:
        with b:
            pass
    assert "B" in order_graph().get("A", set())


def test_ordered_lock_cycle_raises():
    a, b = OrderedLock("A"), OrderedLock("B")
    with a:
        with b:
            pass
    with b:
        with pytest.raises(LockOrderError):
            a.acquire()
        # the refused acquire must not leave state behind
    assert "A" not in order_graph().get("B", set())


def test_ordered_lock_reentry_no_self_edge():
    r = OrderedLock("R", threading.RLock())
    with r:
        with r:
            pass
    assert "R" not in order_graph().get("R", set())


def test_two_thread_inversion_detected():
    """End-to-end: opposite-order acquisition across two threads raises
    instead of deadlocking."""
    a, b = OrderedLock("A"), OrderedLock("B")
    ready = threading.Event()
    errors = []

    def t1():
        with a:
            with b:
                ready.set()

    def t2():
        ready.wait(5)
        try:
            with b:
                with a:
                    pass
        except LockOrderError as e:
            errors.append(e)

    th1, th2 = threading.Thread(target=t1), threading.Thread(target=t2)
    th1.start()
    th1.join(5)
    th2.start()
    th2.join(5)
    assert len(errors) == 1
    assert "inversion" in str(errors[0])


def test_witness_env_gating(monkeypatch):
    monkeypatch.delenv("LLMS_LOCK_WITNESS", raising=False)
    assert not isinstance(witness_lock("x"), OrderedLock)
    monkeypatch.setenv("LLMS_LOCK_WITNESS", "1")
    assert isinstance(witness_lock("x"), OrderedLock)
    assert isinstance(witness_rlock("x"), OrderedLock)
    cv = witness_condition("x")
    assert isinstance(cv, threading.Condition)
    with cv:
        cv.notify_all()


# --------------------------------------------------------------------- #
# the CI gate itself
# --------------------------------------------------------------------- #

def test_repo_is_clean_against_baseline():
    new, _ = run_default()
    assert new == [], "\n".join(f.render() for f in new)
