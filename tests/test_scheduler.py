"""ServiceRouter layer: admission/priority ordering, next-context
prediction driving §3.4 AoT swap-out, and trace determinism."""
import tempfile

import numpy as np
import pytest

from conftest import tiny_model
from repro.core.scheduler import (NextContextPredictor, ServiceRouter,
                                  parse_priority)
from repro.core.service import LLMSConfig, LLMService
from repro.trace.synth import PATTERNS, synthesize


def make_svc(policy="llms", budget=10_000_000, max_ctx=128):
    cfg, model, params = tiny_model("smollm-360m")
    sc = LLMSConfig(policy=policy, max_ctx_len=max_ctx,
                    memory_budget=budget, swap_dir=tempfile.mkdtemp())
    return LLMService(model, params, sc), cfg


# --------------------------------------------------------------------- #
# admission / priority ordering
# --------------------------------------------------------------------- #
def test_foreground_admitted_before_queued_background():
    """With jobs queued, drain must run all foreground calls before any
    background call, FIFO within each priority."""
    svc, cfg = make_svc()
    router = ServiceRouter(svc, predict=False, start=False)
    fg = router.register_app("chat", "foreground")
    bg = router.register_app("indexer", "background")
    rng = np.random.RandomState(0)
    stubs = {s: sess.new_ctx() for s, sess in
             [("b0", bg), ("b1", bg), ("f0", fg), ("f1", fg)]}
    order = [("b0", bg), ("f0", fg), ("b1", bg), ("f1", fg)]
    for name, sess in order:                       # bg submitted FIRST
        sess.submit(stubs[name], rng.randint(1, cfg.vocab, 8).tolist(),
                    max_new_tokens=2)
    router.drain()
    ran = [r["app"] for r in router.call_records]
    assert ran == ["chat", "chat", "indexer", "indexer"]
    fg_ctxs = [r["ctx"] for r in router.call_records[:2]]
    assert fg_ctxs == [stubs["f0"].ctx_id, stubs["f1"].ctx_id]  # FIFO in prio
    router.shutdown()
    svc.close()


def test_per_priority_latency_stats():
    svc, cfg = make_svc()
    router = ServiceRouter(svc, predict=False, start=False)
    fg = router.register_app("a", "fg")
    bg = router.register_app("b", "bg")
    rng = np.random.RandomState(1)
    for sess in (fg, bg):
        stub = sess.new_ctx()
        for _ in range(2):
            sess.call(stub, rng.randint(1, cfg.vocab, 8).tolist(),
                      max_new_tokens=2)
    st = router.stats()
    for prio in ("foreground", "background"):
        assert st[prio]["calls"] == 2
        assert st[prio]["latency_mean_s"] >= st[prio]["service_mean_s"] >= 0
        assert st[prio]["wait_mean_s"] >= 0
    router.shutdown()
    svc.close()


def test_threaded_router_serializes_service_access():
    """start=True: a dispatcher thread drains; results match submissions."""
    svc, cfg = make_svc()
    router = ServiceRouter(svc, predict=True, start=True)
    fg = router.register_app("app", "foreground")
    rng = np.random.RandomState(2)
    stubs = [fg.new_ctx() for _ in range(3)]
    futs = [fg.submit(stubs[i % 3], rng.randint(1, cfg.vocab, 8).tolist(),
                      max_new_tokens=2) for i in range(9)]
    router.drain()
    outs = [f.result(30.0) for f in futs]
    assert all(len(gen) == 2 for _, gen in outs)
    assert len(router.call_records) == 9
    assert svc.stats()["calls"] == 9
    router.shutdown()
    svc.close()


def test_exception_reported_to_submitter():
    svc, cfg = make_svc()
    router = ServiceRouter(svc, predict=False, start=False)
    fg = router.register_app("a", "fg")
    stub = fg.new_ctx()
    huge = [1] * (svc.n_slots * 2)                 # violates half-window
    fut = fg.submit(stub, huge, max_new_tokens=0)
    router.drain()
    with pytest.raises(AssertionError):
        fut.result(10.0)
    router.shutdown()
    svc.close()


def test_parse_priority():
    assert parse_priority("fg") == parse_priority("foreground") == 0
    assert parse_priority("bg") == parse_priority("background") == 1
    assert parse_priority(1) == 1


# --------------------------------------------------------------------- #
# next-context prediction -> AoT swap-out (§3.4)
# --------------------------------------------------------------------- #
def test_predictor_learns_first_order_pattern():
    p = NextContextPredictor()
    for cid in [0, 1, 0, 1, 0, 1, 0]:
        p.observe(cid)
    assert p.predict(0) == 1
    assert p.predict(1) == 0
    assert p.predict() == 1                 # latest ctx is 0
    assert p.predict(99) is None            # never seen


def test_prediction_drives_aot_swap_out():
    """llms_nolife disables the service's own AoT swap-out, so chunks stay
    dirty after a call; the router's prediction hook must still flush the
    outgoing context's chunks to disk ahead of eviction."""
    svc, cfg = make_svc(policy="llms_nolife")
    assert not svc.cfg.use_aot
    router = ServiceRouter(svc, predict=True, start=False)
    app = router.register_app("a", "fg")
    rng = np.random.RandomState(3)
    sa, sb = app.new_ctx(), app.new_ctx()
    for stub in (sa, sb, sa, sb, sa):              # alternating trace
        app.call(stub, rng.randint(1, cfg.vocab, 12).tolist(),
                 max_new_tokens=2)
    assert router.prefetch_hints > 0
    assert router.aot_flushes > 0
    svc.swapper.flush()
    # the non-active context's chunks were flushed by the hint, with no
    # eviction pressure (big budget) to force a sync write
    ctx_a = svc.contexts[sa.ctx_id]
    assert ctx_a.chunks
    assert all(not m.dirty and m.on_disk for m in ctx_a.chunks.values())
    assert all(svc.store.nbytes((ctx_a.cid, i)) for i in ctx_a.chunks)
    router.shutdown()
    svc.close()


def test_prediction_flush_keeps_grown_chunks_fresh():
    """Regression: the prediction-driven flush clears dirty flags; a
    partial chunk that then GROWS must still be re-encoded (payloads are
    append-only snapshots).  Payloads must match a prediction-off run
    byte-for-byte."""
    def payloads(policy, predict, rng_seed=5):
        svc, cfg = make_svc(policy=policy)
        router = ServiceRouter(svc, predict=predict, start=False)
        app = router.register_app("a", "fg")
        rng = np.random.RandomState(rng_seed)
        sa, sb = app.new_ctx(), app.new_ctx()
        prompts = [rng.randint(1, cfg.vocab, 11).tolist() for _ in range(8)]
        for i, stub in enumerate([sa, sb] * 4):    # non-chunk-aligned calls
            app.call(stub, prompts[i], max_new_tokens=3)
        out = {(c.cid, i): cc for c in svc.contexts.values()
               for i, cc in c.payload.items()}
        snap = {k: {n: (np.asarray(p).copy(), np.asarray(s).copy())
                    for n, (p, s) in cc.data.items()}
                for k, cc in out.items()}
        router.shutdown()
        svc.close()
        return snap

    for policy in ("vllm_sq", "llms_nolife"):
        with_pred = payloads(policy, True)
        no_pred = payloads(policy, False)
        assert set(with_pred) == set(no_pred)
        for k in with_pred:
            for n in with_pred[k]:
                np.testing.assert_array_equal(with_pred[k][n][0],
                                              no_pred[k][n][0])


def test_prediction_accuracy_tracked():
    svc, cfg = make_svc()
    router = ServiceRouter(svc, predict=True, start=False)
    app = router.register_app("a", "fg")
    rng = np.random.RandomState(4)
    sa, sb = app.new_ctx(), app.new_ctx()
    for stub in (sa, sb, sa, sb, sa, sb, sa, sb):
        app.call(stub, rng.randint(1, cfg.vocab, 8).tolist(),
                 max_new_tokens=2)
    st = router.stats()
    assert st["pred_total"] > 0
    # strict alternation: the first-order predictor converges on it
    assert st["pred_hits"] >= st["pred_total"] // 2
    router.shutdown()
    svc.close()


# --------------------------------------------------------------------- #
# trace determinism (same seed => identical events)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("pattern", PATTERNS)
def test_synthesize_deterministic(pattern):
    a = synthesize(4, 20, 512, pattern=pattern, scale=0.1, seed=9)
    b = synthesize(4, 20, 512, pattern=pattern, scale=0.1, seed=9)
    assert len(a) == len(b) == 20
    for ea, eb in zip(a, b):
        assert ea.time == eb.time
        assert ea.ctx_id == eb.ctx_id
        assert ea.dataset == eb.dataset
        np.testing.assert_array_equal(ea.prompt, eb.prompt)
        np.testing.assert_array_equal(ea.ground_truth, eb.ground_truth)


def test_synthesize_seed_sensitivity():
    a = synthesize(4, 20, 512, pattern="markov", scale=0.1, seed=0)
    b = synthesize(4, 20, 512, pattern="markov", scale=0.1, seed=1)
    assert any(ea.ctx_id != eb.ctx_id or len(ea.prompt) != len(eb.prompt)
               for ea, eb in zip(a, b))
