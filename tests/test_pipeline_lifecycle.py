"""Properties of the Eq.-4 pipeline planner and the LCTRU lifecycle."""
import itertools

from _hypothesis_compat import given, settings, st

from repro.core.lifecycle import LCTRUQueue, MemoryManager
from repro.core.pipeline import PipelineProfile, fit_linear, plan_split


@given(st.lists(st.tuples(st.integers(1_000, 200_000), st.booleans()),
                min_size=0, max_size=10),
       st.floats(1e-4, 1e-2), st.floats(1e-9, 1e-6))
@settings(max_examples=60, deadline=None)
def test_plan_split_beats_or_matches_bruteforce(chunks, re_per, io_per):
    prof = PipelineProfile(re_base=1e-3, re_per_chunk=re_per,
                           io_base=1e-4, io_per_byte=io_per)
    miss = [(i, b, r) for i, (b, r) in enumerate(chunks)]
    re_idx, io_idx, pred = plan_split(miss, prof)
    assert sorted(re_idx + io_idx) == sorted(m[0] for m in miss)
    # brute force over all recompute subsets (recomputable only)
    rec = [m for m in miss if m[2]]
    best = float("inf")
    for k in range(len(rec) + 1):
        for sub in itertools.combinations(rec, k):
            sub_ids = {s[0] for s in sub}
            io_b = sum(b for i, b, _ in miss if i not in sub_ids)
            best = min(best, max(prof.t_re(len(sub)), prof.t_io(io_b)))
    assert pred <= best + 1e-9 or abs(pred - best) < 1e-9


def test_plan_split_prefers_heavy_chunks():
    prof = PipelineProfile(re_base=0, re_per_chunk=1e-3, io_base=0,
                           io_per_byte=1e-6)
    miss = [(0, 100_000, True), (1, 1_000, True), (2, 50_000, True)]
    re_idx, io_idx, _ = plan_split(miss, prof)
    if re_idx:
        # heaviest chunk moves to recompute first (paper principle ii)
        assert 0 in re_idx


def test_fit_linear():
    base, slope = fit_linear([1, 2, 4], [1.1, 2.1, 4.1])
    assert abs(base - 0.1) < 1e-6 and abs(slope - 1.0) < 1e-6


def test_lctru_heavy_first_lru_within():
    q = LCTRUQueue()
    q.touch(("a", 0), 2)
    q.touch(("b", 0), 8)     # heavy, oldest among 8-bit
    q.touch(("b", 1), 8)
    q.touch(("c", 0), 16)    # heaviest level
    assert q.pop() == ("c", 0)
    assert q.pop() == ("b", 0)       # LRU within the 8-bit sub-queue
    q.touch(("a", 1), 4)
    assert q.pop() == ("b", 1)
    assert q.pop() == ("a", 1)
    assert q.pop() == ("a", 0)
    assert q.pop() is None


def test_lctru_touch_moves_to_mru():
    q = LCTRUQueue()
    q.touch((1, 0), 8)
    q.touch((1, 1), 8)
    q.touch((1, 0), 8)               # re-access
    assert q.pop() == (1, 1)


def test_lru_only_mode_ignores_levels():
    q = LCTRUQueue(lru_only=True)
    q.touch((1, 0), 2)
    q.touch((1, 1), 16)
    assert q.pop() == (1, 0)         # pure recency


def test_memory_manager_respects_lock():
    q = LCTRUQueue()
    mm = MemoryManager(budget=100, queue=q)
    mm.register((1, 0), 60, 8)
    mm.register((2, 0), 60, 8)
    evicted = []
    mm.reclaim(40, evicted.append, locked={1})
    assert evicted == [(2, 0)]
    assert mm.used == 60


def test_memory_manager_accounting():
    q = LCTRUQueue()
    mm = MemoryManager(budget=1000, queue=q)
    mm.register((1, 0), 100, 8)
    mm.register((1, 0), 150, 4)      # resize in place
    assert mm.used == 150
    mm.unregister((1, 0))
    assert mm.used == 0
