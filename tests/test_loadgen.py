"""Scale-harness tests: scenario specs, the virtual-clock driver's
determinism contract, a mid-size end-to-end soak, and the replay shim.

The determinism test is the load-bearing one: two same-seed runs of a
mixed burst scenario must produce byte-identical event logs (sha256 over
every emitted line) AND identical aggregate metrics — everything in
``deterministic_view`` is a pure function of the scenario seed.
"""
import pytest
from conftest import tiny_model

from repro.loadgen import (SCENARIOS, ScenarioSpec, build_service,
                           get_scenario, load_scenario, make_events,
                           replay_trace, run_scenario, scenario_from_dict,
                           validate_spec)
from repro.loadgen.metrics import EventLog, deterministic_view, gate_metrics
from repro.trace.synth import synthesize_mixed


# ------------------------------------------------------------------ #
# spec / scenario library
# ------------------------------------------------------------------ #
def test_scenario_library_complete_and_valid():
    # >= 6 named scenarios, all validated at import; the scale soak
    # really is 10^4 contexts
    assert len(SCENARIOS) >= 6
    for spec in SCENARIOS.values():
        validate_spec(spec)
    assert SCENARIOS["scale_10k"].n_contexts >= 10_000


def test_get_scenario_override_and_unknown():
    s = get_scenario("smoke_ci", n_calls=8, seed=99)
    assert (s.n_calls, s.seed) == (8, 99)
    assert SCENARIOS["smoke_ci"].n_calls != 8      # library untouched
    with pytest.raises(KeyError):
        get_scenario("nope")


def test_load_scenario_rejects_unknown_keys():
    with pytest.raises((KeyError, ValueError, TypeError)):
        load_scenario({"name": "x", "n_contexts": 4, "n_calls": 8,
                       "no_such_field": 1})


def test_scenario_from_dict_base_overlay():
    s = scenario_from_dict({"base": "smoke_ci", "name": "variant",
                            "n_calls": 12})
    assert s.name == "variant" and s.n_calls == 12
    assert s.arrival == SCENARIOS["smoke_ci"].arrival   # inherited


def test_validate_spec_rejects_bad_fields():
    base = SCENARIOS["smoke_ci"]
    with pytest.raises(ValueError):
        validate_spec(base.override(arrival={"kind": "martian"}))
    with pytest.raises(ValueError):
        validate_spec(base.override(ctx_pattern="zigzag"))
    with pytest.raises(ValueError):
        validate_spec(base.override(round_s=-1.0))


def test_synthesize_mixed_deterministic():
    kw = dict(arrival={"kind": "bursty", "rate_per_s": 2.0,
                       "burst_every_s": 10.0, "burst_size": 6,
                       "burst_rate_per_s": 30.0, "burst_frac": 0.3},
              ctx_pattern="random",
              prompt_len={"dist": "uniform", "lo": 3, "hi": 8},
              output_len={"dist": "fixed", "n": 3},
              apps=[{"name": "chat", "priority": "foreground"},
                    {"name": "agent", "priority": "background"}],
              seed=5)
    a = synthesize_mixed(8, 40, 512, **kw)
    b = synthesize_mixed(8, 40, 512, **kw)
    assert len(a) == 40
    assert [e.time for e in a] == [e.time for e in b]
    assert [e.ctx_id for e in a] == [e.ctx_id for e in b]
    assert all((x.prompt == y.prompt).all() for x, y in zip(a, b))
    assert {e.app for e in a} == {"chat", "agent"}


# ------------------------------------------------------------------ #
# virtual-clock driver: determinism + e2e invariants
# ------------------------------------------------------------------ #
def _run(spec, events=None, log_keep=None):
    cfg, model, params = tiny_model("llama2-7b")
    svc = build_service(spec, model, params)
    with svc:
        return run_scenario(spec, svc, cfg.vocab, events=events,
                            log_keep=log_keep)


def test_same_seed_runs_identical():
    spec = get_scenario("smoke_ci", n_calls=48)
    events = make_events(spec, tiny_model("llama2-7b")[0].vocab)
    a = _run(spec, events=events)
    b = _run(spec, events=events)
    # byte-identical event log...
    assert a["event_log_sha256"] == b["event_log_sha256"]
    assert a["events_logged"] == b["events_logged"]
    # ...and identical aggregate metrics (everything but wall time and
    # the wall-clock service section)
    assert deterministic_view(a) == deterministic_view(b)


def test_event_log_retention_bounded():
    log = EventLog(keep=4)
    for i in range(10):
        log.emit("round", float(i), i)
    assert len(log.lines) == 4
    assert log.n == 10


def test_e2e_mixed_scenario_invariants():
    # ~64 contexts of mixed fg/bg burst load end-to-end: every stream
    # finishes, the budget and pool invariants hold, both priority
    # sections are populated
    spec = get_scenario("smoke_ci", n_contexts=64, n_calls=128,
                        memory_budget=28_000)
    rep = _run(spec)
    assert rep["streams"]["total"] == 128
    assert rep["streams"]["stuck"] == 0
    assert rep["streams"]["errors"] == 0
    assert rep["budget"]["ok"]
    pool = rep["pool"]
    assert pool["pool_pages16_used"] <= pool["pool_pages16_total"]
    r = rep["router"]
    assert r["decoded_tokens"] > 0
    for prio in ("foreground", "background"):
        assert r[prio]["calls"] > 0
        assert r[prio]["wait_p95_s"] >= r[prio]["wait_p50_s"] >= 0.0
    assert "queue_depth" in r and r["queue_depth"]["samples"] > 0
    # virtual time moved, and gate metrics extract cleanly
    assert rep["virtual_duration_s"] > 0
    gm = gate_metrics(rep)
    assert gm["budget_ok"] and gm["stuck_streams"] == 0


def test_preemption_fires_in_burst_scenario():
    rep = _run(get_scenario("smoke_ci"))
    r = rep["router"]
    assert r["preemptions"] > 0
    assert r["preemptions_by_priority"]["background"] > 0
    assert r["preemptions_by_priority"]["foreground"] == 0


# ------------------------------------------------------------------ #
# replay shim (the single wall-clock replay implementation)
# ------------------------------------------------------------------ #
def test_replay_trace_serial_matches_contract():
    from benchmarks.common import bench_events, make_service
    events = bench_events(4, 12, seed=2)
    svc = make_service("llms", 30_000)
    with svc:
        st = replay_trace(svc, events, mode="serial", max_new=2,
                          warm=False, measured_throttle=None)
    # measured stats only (no warm pass here), router section attached
    assert st["router"]["foreground"]["calls"] == 12
    assert st["switch_mean_s"] >= 0.0


def test_replay_trace_flood_routes_and_drains():
    from benchmarks.common import bench_events, make_service
    events = bench_events(4, 12, seed=2)
    svc = make_service("llms", 30_000, decode_batch=2)
    with svc:
        st = replay_trace(
            svc, events, mode="flood", max_new=2, warm=False,
            slice_steps=2, measured_throttle=None,
            apps=(("chat", "foreground"), ("agent", "background")),
            route=lambda ev: "chat" if ev.ctx_id % 2 == 0 else "agent")
    r = st["router"]
    assert r["foreground"]["calls"] + r["background"]["calls"] == 12
    assert r["background"]["calls"] > 0
