"""Public kernel entry points.

Each op dispatches to the Pallas TPU kernel on TPU backends and to the
pure-jnp oracle (ref.py) elsewhere.  ``force`` overrides for testing:
  "pallas"     - pallas_call compiled for the current backend
  "interpret"  - pallas_call in interpret mode (runs anywhere; used by
                 the kernel-vs-oracle test sweeps)
  "ref"        - the jnp oracle
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(force: Optional[str]) -> str:
    if force is not None:
        return force
    return "pallas" if _on_tpu() else "ref"


# --------------------------------------------------------------------- #
# chunk quantization codec
# --------------------------------------------------------------------- #
def chunk_quantize(x: Array, bits: int, force: Optional[str] = None
                   ) -> Tuple[Array, Array]:
    """(T, F) float -> (packed int8 (T*bits//8, F), scales fp32 (F,))."""
    mode = _mode(force)
    if mode == "ref":
        return ref.quantize_ref(x, bits)
    from repro.kernels import chunk_quant
    return chunk_quant.quantize(x, bits, interpret=(mode == "interpret"))


def chunk_dequantize(packed: Array, scale: Array, bits: int, n_tokens: int,
                     dtype=jnp.bfloat16, force: Optional[str] = None) -> Array:
    mode = _mode(force)
    if mode == "ref":
        return ref.dequantize_ref(packed, scale, bits, n_tokens, dtype)
    from repro.kernels import chunk_quant
    return chunk_quant.dequantize(packed, scale, bits, n_tokens, dtype,
                                  interpret=(mode == "interpret"))


# --------------------------------------------------------------------- #
# flash attention with fused Eq.-1 density statistic
# --------------------------------------------------------------------- #
def attn_density(q: Array, k: Array, v: Array, window: int = 0,
                 n_sinks: int = 0, force: Optional[str] = None
                 ) -> Tuple[Array, Array]:
    mode = _mode(force)
    if mode == "ref":
        return ref.attn_density_ref(q, k, v, window, n_sinks)
    from repro.kernels import attn_density as kad
    return kad.attn_density(q, k, v, window, n_sinks,
                            interpret=(mode == "interpret"))


# --------------------------------------------------------------------- #
# decode attention over an int8-quantized KV cache (fused dequant)
# --------------------------------------------------------------------- #
def decode_qattn(q: Array, k_q: Array, v_q: Array, k_scale: Array,
                 v_scale: Array, n_valid, window: int = 0, n_sinks: int = 0,
                 force: Optional[str] = None) -> Array:
    mode = _mode(force)
    if mode == "ref":
        return ref.decode_qattn_ref(q, k_q, v_q, k_scale, v_scale, n_valid,
                                    window, n_sinks)
    from repro.kernels import decode_qattn as kdq
    return kdq.decode_qattn(q, k_q, v_q, k_scale, v_scale, n_valid, window,
                            n_sinks, interpret=(mode == "interpret"))


# --------------------------------------------------------------------- #
# mixed-precision decode attention (bf16 window + int8 quant-resident
# segments, fused dequant behind a per-position select)
# --------------------------------------------------------------------- #
def decode_mqattn(q: Array, k: Array, v: Array, k_q: Array, v_q: Array,
                  k_scale: Array, v_scale: Array, quant_mask: Array,
                  n_valid, window: int = 0, n_sinks: int = 0,
                  force: Optional[str] = None) -> Array:
    mode = _mode(force)
    if mode == "ref":
        return ref.decode_mqattn_ref(q, k, v, k_q, v_q, k_scale, v_scale,
                                     quant_mask, n_valid, window, n_sinks)
    from repro.kernels import decode_qattn as kdq
    return kdq.decode_mqattn(q, k, v, k_q, v_q, k_scale, v_scale,
                             quant_mask, n_valid, window, n_sinks,
                             interpret=(mode == "interpret"))
