"""Pallas TPU kernel: flash-attention forward fused with the paper's
Eq.-1 information-density statistic.

The paper's prototype reads attention matrices off the accelerator to
estimate per-token density — impossible at 32k context on TPU (the
(B,H,Sq,Sk) matrix would be terabytes).  Here the density (per-key
attention mass) is accumulated inside the online-softmax loop:

  pass 1 (kernel `_fwd`):  classic flash forward; emits out, row max m,
          row sum l (grid: B x H x nQ x nK, k innermost, VMEM scratch).
  pass 2 (kernel `_mass`): re-walks the score blocks with the final
          (m, l) and accumulates sum_q p[q,k] per key block
          (grid: B x H x nK x nQ, q innermost).

Both passes stream K/V through VMEM tiles; nothing (B,H,Sq,Sk)-sized is
ever materialized.  The wrapper normalizes by per-key visible-query
counts and head count (Eq. 1).  Oracle: kernels/ref.py::attn_density_ref.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def _mask(iq, ik, bq, bk, sq_valid, sk_valid, window, n_sinks):
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = (k_pos <= q_pos) & (k_pos < sk_valid) & (q_pos < sq_valid)
    if window > 0:
        m = m & ((k_pos > q_pos - window) | (k_pos < n_sinks))
    return m


def _fwd(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
         acc, mx, lx, *, bq, bk, nk, scale, sq, sk, window, n_sinks):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        mx[...] = jnp.full_like(mx, NEG_INF)
        lx[...] = jnp.zeros_like(lx)

    q = q_ref[0, 0].astype(jnp.float32)                 # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    s = (q @ k.T) * scale                               # (bq, bk)
    s = jnp.where(_mask(iq, ik, bq, bk, sq, sk, window, n_sinks), s,
                  NEG_INF)
    m_prev = mx[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    lx[...] = lx[...] * alpha + jnp.sum(p, axis=1)
    acc[...] = acc[...] * alpha[:, None] + p @ v
    mx[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = lx[...]
        o_ref[0, 0] = (acc[...] / jnp.maximum(l, 1e-30)[:, None]
                       ).astype(o_ref.dtype)
        m_ref[0, 0] = mx[...]
        l_ref[0, 0] = l


def _mass(q_ref, k_ref, m_ref, l_ref, mass_ref, macc,
          *, bq, bk, nq, scale, sq, sk, window, n_sinks):
    iq = pl.program_id(3)
    ik = pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        macc[...] = jnp.zeros_like(macc)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    s = (q @ k.T) * scale
    valid = _mask(iq, ik, bq, bk, sq, sk, window, n_sinks)
    s = jnp.where(valid, s, NEG_INF)
    m = m_ref[0, 0]
    l = jnp.maximum(l_ref[0, 0], 1e-30)
    p = jnp.exp(s - m[:, None]) / l[:, None]
    p = jnp.where(valid, p, 0.0)
    macc[...] = macc[...] + jnp.sum(p, axis=0)          # (bk,)

    @pl.when(iq == nq - 1)
    def _done():
        mass_ref[0, 0] = macc[...]


def attn_density(q: Array, k: Array, v: Array, window: int = 0,
                 n_sinks: int = 0, interpret: bool = False,
                 bq: int = 128, bk: int = 128) -> Tuple[Array, Array]:
    """q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd) -> (out (B,Sq,H,hd),
    density (B,Sk))."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = 1.0 / float(np.sqrt(hd))
    bq = min(bq, max(Sq, 8))
    bk = min(bk, max(Sk, 8))
    nq = (Sq + bq - 1) // bq
    nk = (Sk + bk - 1) // bk
    Sqp, Skp = nq * bq, nk * bk

    qt = jnp.moveaxis(q, 2, 1)                           # (B,H,Sq,hd)
    kt = jnp.moveaxis(k, 2, 1)                           # (B,KV,Sk,hd)
    vt = jnp.moveaxis(v, 2, 1)
    if Sqp != Sq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    if Skp != Sk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))

    kw = dict(bq=bq, bk=bk, scale=scale, sq=Sq, sk=Sk, window=window,
              n_sinks=n_sinks)
    out, m, l = pl.pallas_call(
        functools.partial(_fwd, nk=nk, **kw),
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sqp, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sqp), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Sqp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)

    mass = pl.pallas_call(
        functools.partial(_mass, nq=nq, **kw),
        grid=(B, H, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, j, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, j, i, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, j, i: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, j, i: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, bk), lambda b, h, j, i: (b, h, j)),
        out_shape=jax.ShapeDtypeStruct((B, H, Skp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bk,), jnp.float32)],
        interpret=interpret,
    )(qt, kt, m, l)

    out = jnp.moveaxis(out[:, :, :Sq], 1, 2)             # (B,Sq,H,hd)
    # Eq.-1 normalization: per key, divide by (H * visible query count)
    k_pos = jnp.arange(Sk)
    nvalid = jnp.asarray(Sq - k_pos if window <= 0 else None) \
        if window <= 0 else None
    if window > 0:
        q_pos = jnp.arange(Sq)
        vis = (k_pos[None, :] <= q_pos[:, None]) & \
              ((k_pos[None, :] > q_pos[:, None] - window)
               | (k_pos[None, :] < n_sinks))
        nvalid = jnp.sum(vis, axis=0)
    else:
        nvalid = jnp.maximum(Sq - k_pos, 0)
    nvalid = jnp.maximum(nvalid, 1)
    density = jnp.sum(mass[:, :, :Sk], axis=1) / (H * nvalid[None, :])
    return out, density.astype(jnp.float32)
