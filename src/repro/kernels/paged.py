"""Paged KV-pool gather/scatter primitives.

The unified KV pool (core/pagepool.py) stores chunk-granular pages in
fixed arenas shaped ``(L, P, cs, ...)`` — one page = one chunk's worth
of a cache leaf across all layers.  Decode/prefill entries consume the
pool through ``gather_pages``: a per-slot page-index row materializes
the SAME dense ``(L, B, S, ...)`` layout the slot-cache entry points
were built on, so the paged path is bit-identical to the slot path by
construction (identical values at every valid position; invalid
positions are masked to exactly zero weight by the attention mask
before they can contribute).

This is the blocked-jnp CPU mirror: XLA lowers the advanced-indexing
gather to a block copy per (layer, page) that fuses with the
downstream attention read.  On TPU the natural implementation is a
Pallas kernel that keeps the arena in HBM and DMA-gathers the page
list into VMEM tiles ahead of the attention loop (the MNN-LLM
layout); the downstream mixed-decode attention already dispatches to
``kernels.ops.decode_mqattn`` there, so only the gather itself would
move into Pallas.
"""
from __future__ import annotations

import jax


def gather_pages(arena: jax.Array, tables: jax.Array) -> jax.Array:
    """Materialize per-slot views of the pool.

    arena: (L, P, cs, ...) page arena; tables: (B, C) int32 page
    indices (page 0 is the scratch/zero page — rows point chunks they
    don't own at it).  -> (L, B, C*cs, ...) dense cache leaf.
    """
    g = arena[:, tables]                       # (L, B, C, cs, ...)
    L, B, C, cs = g.shape[:4]
    return g.reshape(L, B, C * cs, *g.shape[4:])


def scatter_token(arena: jax.Array, pages: jax.Array, offs: jax.Array,
                  val: jax.Array) -> jax.Array:
    """Write one new token per slot back into its tail page.

    arena: (L, P, cs, ...); pages/offs: (B,) int32 (page index and
    in-page offset per slot); val: (L, B, ...).  Distinct slots own
    distinct pages so the scatter indices never collide, except on the
    scratch page 0 where padded rows land (their values are never
    attended, so the write order is irrelevant).
    """
    return arena.at[:, pages, offs].set(val)


def scatter_chunk(arena: jax.Array, page, blk: jax.Array) -> jax.Array:
    """Admit one chunk: blk (L, cs, ...) -> arena[:, page]."""
    return arena.at[:, page].set(blk)


def gather_chunk(arena: jax.Array, page) -> jax.Array:
    """Read one chunk's page back out: -> (L, cs, ...)."""
    return arena[:, page]
