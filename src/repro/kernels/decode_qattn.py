"""Pallas TPU kernel: single-token decode attention over an INT8 KV
cache with FUSED dequantization.

The serving hot path for LLMS: resident chunks live compressed (int8 +
per-(token, kv-head) scales); attention dequantizes inside VMEM instead
of materializing a bf16 cache in HBM.  This halves the decode roofline's
HBM term — the dominant term for every decode_* dry-run cell
(EXPERIMENTS.md §Roofline).

Layout: q (B,H,hd); caches (B,S,KV,hd) int8; scales (B,S,KV) fp32.
Grid (B, KV, nS) — S innermost, online softmax in VMEM scratch, G=H/KV
query heads processed together as the matmul M dimension.

Oracle: kernels/ref.py::decode_qattn_ref.
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array
NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def _kernel(q_ref, kq_ref, vq_ref, ks_ref, vs_ref, nv_ref, o_ref,
            acc, mx, lx, *, bs, ns, scale, S, window, n_sinks):
    js = pl.program_id(2)

    @pl.when(js == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        mx[...] = jnp.full_like(mx, NEG_INF)
        lx[...] = jnp.zeros_like(lx)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, hd)
    k = kq_ref[0, :, 0].astype(jnp.float32) * ks_ref[0, :, 0][:, None]
    v = vq_ref[0, :, 0].astype(jnp.float32) * vs_ref[0, :, 0][:, None]
    s = (q @ k.T) * scale                               # (G, bs)
    k_pos = js * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    nv = nv_ref[0, 0]
    valid = (k_pos < nv) & (k_pos < S)
    if window > 0:
        valid = valid & ((k_pos >= nv - window) | (k_pos < n_sinks))
    s = jnp.where(valid, s, NEG_INF)
    m_prev = mx[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    lx[...] = lx[...] * alpha + jnp.sum(p, axis=1)
    acc[...] = acc[...] * alpha[:, None] + p @ v
    mx[...] = m_new

    @pl.when(js == ns - 1)
    def _done():
        o_ref[0, 0] = (acc[...] / jnp.maximum(lx[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_qattn(q: Array, k_q: Array, v_q: Array, k_scale: Array,
                 v_scale: Array, n_valid, window: int = 0, n_sinks: int = 0,
                 interpret: bool = False, bs: int = 256) -> Array:
    """q (B,H,hd); k_q/v_q (B,S,KV,hd) int8; scales (B,S,KV) fp32;
    n_valid () or (B,).  Returns (B,H,hd) in q.dtype."""
    B, H, hd = q.shape
    S, KV = k_q.shape[1], k_q.shape[2]
    G = H // KV
    bs = min(bs, max(S, 8))
    ns = (S + bs - 1) // bs
    Sp = ns * bs
    if Sp != S:
        padw = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        k_q = jnp.pad(k_q, padw)
        v_q = jnp.pad(v_q, padw)
        k_scale = jnp.pad(k_scale, ((0, 0), (0, Sp - S), (0, 0)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, Sp - S), (0, 0)))
    qg = q.reshape(B, KV, G, hd)
    nv = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32).reshape(-1),
                          (B,)).reshape(B, 1)

    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs, ns=ns,
                          scale=1.0 / float(np.sqrt(hd)), S=S,
                          window=window, n_sinks=n_sinks),
        grid=(B, KV, ns),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, n, j: (b, n, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, n, j: (b, j, n, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, n, j: (b, j, n, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, n, j: (b, j, n)),
            pl.BlockSpec((1, bs, 1), lambda b, n, j: (b, j, n)),
            pl.BlockSpec((1, 1), lambda b, n, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, n, j: (b, n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k_q, v_q, k_scale, v_scale, nv)
    return out.reshape(B, H, hd)


# --------------------------------------------------------------------- #
# Mixed-precision decode attention: bf16 recent window + int8
# quant-resident chunk segments, selected per position by quant_mask and
# dequantized in VMEM (the quant-resident residency tier's hot path).
# --------------------------------------------------------------------- #
def _mixed_kernel(q_ref, k_ref, v_ref, kq_ref, vq_ref, ks_ref, vs_ref,
                  qm_ref, nv_ref, o_ref, acc, mx, lx, *, bs, ns, scale, S,
                  window, n_sinks):
    js = pl.program_id(2)

    @pl.when(js == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        mx[...] = jnp.full_like(mx, NEG_INF)
        lx[...] = jnp.zeros_like(lx)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, hd)
    m = qm_ref[0, :][:, None]                           # (bs, 1) bool
    # fused dequant THROUGH the storage dtype: a quant position must
    # contribute exactly the value a full dequantization would have
    # materialized into the bf16 cache (token-identity contract)
    kd = (kq_ref[0, :, 0].astype(jnp.float32)
          * ks_ref[0, :, 0][:, None]).astype(k_ref.dtype)
    vd = (vq_ref[0, :, 0].astype(jnp.float32)
          * vs_ref[0, :, 0][:, None]).astype(v_ref.dtype)
    k = jnp.where(m, kd, k_ref[0, :, 0]).astype(jnp.float32)
    v = jnp.where(m, vd, v_ref[0, :, 0]).astype(jnp.float32)
    s = (q @ k.T) * scale                               # (G, bs)
    k_pos = js * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    nv = nv_ref[0, 0]
    valid = (k_pos < nv) & (k_pos < S)
    if window > 0:
        valid = valid & ((k_pos >= nv - window) | (k_pos < n_sinks))
    s = jnp.where(valid, s, NEG_INF)
    m_prev = mx[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    lx[...] = lx[...] * alpha + jnp.sum(p, axis=1)
    acc[...] = acc[...] * alpha[:, None] + p @ v
    mx[...] = m_new

    @pl.when(js == ns - 1)
    def _done():
        o_ref[0, 0] = (acc[...] / jnp.maximum(lx[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_mqattn(q: Array, k: Array, v: Array, k_q: Array, v_q: Array,
                  k_scale: Array, v_scale: Array, quant_mask: Array,
                  n_valid, window: int = 0, n_sinks: int = 0,
                  interpret: bool = False, bs: int = 256) -> Array:
    """q (B,H,hd); k/v (B,S,KV,hd) bf16; k_q/v_q (B,S,KV,hd) int8;
    scales (B,S,KV) fp32; quant_mask (B,S) bool; n_valid () or (B,).
    Returns (B,H,hd) in q.dtype.  Oracle: ref.py::decode_mqattn_ref."""
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    bs = min(bs, max(S, 8))
    ns = (S + bs - 1) // bs
    Sp = ns * bs
    if Sp != S:
        padw = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
        k_q = jnp.pad(k_q, padw)
        v_q = jnp.pad(v_q, padw)
        k_scale = jnp.pad(k_scale, ((0, 0), (0, Sp - S), (0, 0)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, Sp - S), (0, 0)))
        quant_mask = jnp.pad(quant_mask, ((0, 0), (0, Sp - S)))
    qg = q.reshape(B, KV, G, hd)
    nv = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32).reshape(-1),
                          (B,)).reshape(B, 1)

    out = pl.pallas_call(
        functools.partial(_mixed_kernel, bs=bs, ns=ns,
                          scale=1.0 / float(np.sqrt(hd)), S=S,
                          window=window, n_sinks=n_sinks),
        grid=(B, KV, ns),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, n, j: (b, n, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, n, j: (b, j, n, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, n, j: (b, j, n, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, n, j: (b, j, n, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, n, j: (b, j, n, 0)),
            pl.BlockSpec((1, bs, 1), lambda b, n, j: (b, j, n)),
            pl.BlockSpec((1, bs, 1), lambda b, n, j: (b, j, n)),
            pl.BlockSpec((1, bs), lambda b, n, j: (b, j)),
            pl.BlockSpec((1, 1), lambda b, n, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, n, j: (b, n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, k_q, v_q, k_scale, v_scale, quant_mask, nv)
    return out.reshape(B, H, hd)
