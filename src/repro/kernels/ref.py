"""Pure-jnp oracles for every Pallas kernel.

These are the semantics of record: the Pallas kernels in this package
must match them (tests sweep shapes/dtypes and assert_allclose), and the
LLMS core uses them directly on CPU where interpret-mode Pallas would be
needlessly slow.

Quantization codec (paper §3.2, "channel-wise linear quantization"):
  * canonical layout (T, F): T tokens (the chunk axis), F flattened
    channels (layers x kv-heads x head-dim),
  * symmetric per-channel scales over the token axis: s_f = max_t|x| / qmax,
  * codes clipped to [-qmax, qmax] with qmax = 2^(bits-1) - 1,
  * sub-byte codes are PACKED along the token axis into int8 lanes
    (4-bit: 2 codes/byte, 2-bit: 4 codes/byte) -- the TPU-friendly
    version of the paper's "parallel bit-shift" packing.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def qmax_for(bits: int) -> int:
    return (1 << (bits - 1)) - 1


# --------------------------------------------------------------------- #
# chunk_quant oracle
# --------------------------------------------------------------------- #
def quantize_ref(x: Array, bits: int) -> Tuple[Array, Array]:
    """x: (T, F) float -> (packed int8 (T*bits//8, F), scales fp32 (F,))."""
    assert bits in (8, 4, 2), bits
    T, F = x.shape
    qm = qmax_for(bits)
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=0) / qm                 # (F,)
    scale = jnp.maximum(scale, 1e-8)
    codes = jnp.clip(jnp.round(xf / scale), -qm, qm).astype(jnp.int32)
    if bits == 8:
        return codes.astype(jnp.int8), scale
    per = 8 // bits                                           # codes per byte
    assert T % per == 0, (T, bits)
    u = (codes & ((1 << bits) - 1)).astype(jnp.uint8)         # two's complement
    u = u.reshape(T // per, per, F)
    packed = jnp.zeros((T // per, F), jnp.uint8)
    for j in range(per):
        packed = packed | (u[:, j] << (bits * j)).astype(jnp.uint8)
    return packed.astype(jnp.int8), scale


def dequantize_ref(packed: Array, scale: Array, bits: int, T: int,
                   dtype=jnp.bfloat16) -> Array:
    """Inverse of quantize_ref -> (T, F)."""
    assert bits in (8, 4, 2), bits
    if bits == 8:
        return (packed.astype(jnp.float32) * scale).astype(dtype)
    per = 8 // bits
    rows, F = packed.shape
    assert rows * per == T
    u = packed.astype(jnp.uint8)
    outs = []
    mask = (1 << bits) - 1
    half = 1 << (bits - 1)
    for j in range(per):
        c = ((u >> (bits * j)) & mask).astype(jnp.int32)
        c = jnp.where(c >= half, c - (1 << bits), c)          # sign-extend
        outs.append(c)
    codes = jnp.stack(outs, axis=1).reshape(T, F)
    return (codes.astype(jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------- #
# attn_density oracle: flash attention fwd + Eq.-1 per-key mass
# --------------------------------------------------------------------- #
def attn_density_ref(q: Array, k: Array, v: Array,
                     window: int = 0, n_sinks: int = 0
                     ) -> Tuple[Array, Array]:
    """q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd); causal (q_i sees k_j, j<=i,
    with optional sliding window + sinks).  Returns (out (B,Sq,H,hd),
    density (B,Sk)) where density is Eq. (1): per key, mean normalized
    attention mass over the queries that can see it, averaged over heads.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqngd,bknd->bngqk", qg, kf) / np.sqrt(hd)
    q_pos = jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    m = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m = m & ((k_pos[None, :] > q_pos[:, None] - window)
                 | (k_pos[None, :] < n_sinks))
    s = jnp.where(m[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngqk,bknd->bqngd", p, v.astype(jnp.float32))
    out = out.reshape(B, Sq, H, hd).astype(q.dtype)
    mass = jnp.sum(p, axis=(1, 2, 3))                          # (B, Sk)
    nvalid = jnp.maximum(jnp.sum(m, axis=0), 1)                # (Sk,)
    density = (mass / (H * nvalid[None, :])).astype(jnp.float32)
    return out, density


# --------------------------------------------------------------------- #
# decode-grid quantization (per-(token, kv-head) symmetric scales)
#
# The chunk codec above is the STORAGE grid (per-channel scales over the
# token axis).  The decode-attention kernels consume the DECODE grid:
# one scale per (token, kv-head), shared across head_dim — the same grid
# ``models/dense.decode_step`` uses for newly decoded tokens, so a
# quant-resident chunk and a freshly quantized token dequantize through
# one code path.
# --------------------------------------------------------------------- #
def quantize_token_head_ref(x: Array) -> Tuple[Array, Array]:
    """x: (..., hd) float -> (codes int8 (..., hd), scales fp32 (...,)).
    Symmetric max-abs over the trailing head_dim axis, qmax 127."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1) / 127.0, 1e-8)
    codes = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127
                     ).astype(jnp.int8)
    return codes, scale


def dequantize_token_head_ref(codes: Array, scale: Array,
                              dtype=jnp.bfloat16) -> Array:
    """Inverse of quantize_token_head_ref -> (..., hd) in ``dtype``."""
    return (codes.astype(jnp.float32) * scale[..., None]).astype(dtype)


# --------------------------------------------------------------------- #
# decode_qattn oracle: one-step attention over an int8 KV cache
# --------------------------------------------------------------------- #
def decode_qattn_ref(q: Array, k_q: Array, v_q: Array,
                     k_scale: Array, v_scale: Array,
                     n_valid, window: int = 0, n_sinks: int = 0) -> Array:
    """q: (B,H,hd); k_q/v_q: (B,S,KV,hd) int8; scales: (B,S,KV) fp32.
    n_valid: () or (B,) number of valid cache entries.  Fused dequant +
    online-softmax attention.  Returns (B,H,hd) in q.dtype."""
    B, H, hd = q.shape
    S, KV = k_q.shape[1], k_q.shape[2]
    G = H // KV
    k = k_q.astype(jnp.float32) * k_scale[..., None]
    v = v_q.astype(jnp.float32) * v_scale[..., None]
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bngd,bknd->bngk", qg, k) / np.sqrt(hd)
    k_pos = jnp.arange(S)
    nv = jnp.asarray(n_valid)
    nv = nv[None].repeat(B, 0) if nv.ndim == 0 else nv
    valid = k_pos[None, :] < nv[:, None]
    if window > 0:
        valid = valid & ((k_pos[None, :] >= nv[:, None] - window)
                         | (k_pos[None, :] < n_sinks))
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngk,bknd->bngd", p, v)
    return out.reshape(B, H, hd).astype(q.dtype)


# --------------------------------------------------------------------- #
# decode_mqattn oracle: one-step attention over a MIXED cache
# (bf16 recent window + int8 quant-resident segments, fused dequant)
# --------------------------------------------------------------------- #
def decode_mqattn_ref(q: Array, k: Array, v: Array, k_q: Array, v_q: Array,
                      k_scale: Array, v_scale: Array, quant_mask: Array,
                      n_valid, window: int = 0, n_sinks: int = 0) -> Array:
    """q: (B,H,hd); k/v: (B,S,KV,hd) bf16; k_q/v_q: (B,S,KV,hd) int8;
    scales: (B,S,KV) fp32; quant_mask: (B,S) bool — True where the cache
    entry lives in the quantized segments.  Dequantization is fused: a
    quant position contributes ``(code * scale) -> cache dtype`` exactly
    as if it had been materialized into the bf16 cache, so the mixed
    path is equivalent to full dequantization.  Returns (B,H,hd)."""
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    m = quant_mask[:, :, None, None]
    kf = jnp.where(m, (k_q.astype(jnp.float32) * k_scale[..., None]
                       ).astype(k.dtype), k).astype(jnp.float32)
    vf = jnp.where(m, (v_q.astype(jnp.float32) * v_scale[..., None]
                       ).astype(v.dtype), v).astype(jnp.float32)
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bngd,bknd->bngk", qg, kf) / np.sqrt(hd)
    k_pos = jnp.arange(S)
    nv = jnp.asarray(n_valid)
    nv = nv[None].repeat(B, 0) if nv.ndim == 0 else nv
    valid = k_pos[None, :] < nv[:, None]
    if window > 0:
        valid = valid & ((k_pos[None, :] >= nv[:, None] - window)
                         | (k_pos[None, :] < n_sinks))
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngk,bknd->bngd", p, vf)
    return out.reshape(B, H, hd).astype(q.dtype)
