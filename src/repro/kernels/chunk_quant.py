"""Pallas TPU kernel: chunk-wise KV quantization codec (paper §3.2/§4).

The paper packs sub-byte codes "with parallel bit-shift operations" on a
phone CPU; the TPU-native version tiles (chunk-tokens x channels) blocks
into VMEM, computes per-channel symmetric scales on the VPU, and packs
2/4-bit codes into int8 lanes with shifts.  Channel tiles are 128-lane
aligned; the token axis (16 by default) sits on sublanes.

Matches kernels/ref.py bit-exactly (tests sweep shapes/dtypes).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import qmax_for

Array = jax.Array
LANES = 128


def _quant_kernel(x_ref, packed_ref, scale_ref, *, bits: int, T: int):
    x = x_ref[...].astype(jnp.float32)                 # (T, BF)
    qm = qmax_for(bits)
    s = jnp.max(jnp.abs(x), axis=0) / qm               # (BF,)
    s = jnp.maximum(s, 1e-8)
    scale_ref[...] = s
    codes = jnp.clip(jnp.round(x / s[None, :]), -qm, qm).astype(jnp.int32)
    if bits == 8:
        packed_ref[...] = codes.astype(jnp.int8)
        return
    per = 8 // bits
    mask = (1 << bits) - 1
    u = (codes & mask).astype(jnp.int32)               # two's complement
    acc = u[0::per]
    for j in range(1, per):
        acc = acc | (u[j::per] << (bits * j))
    packed_ref[...] = acc.astype(jnp.int8)


def _dequant_kernel(packed_ref, scale_ref, o_ref, *, bits: int, T: int,
                    dtype):
    p = packed_ref[...]
    s = scale_ref[...]
    if bits == 8:
        o_ref[...] = (p.astype(jnp.float32) * s[None, :]).astype(dtype)
        return
    per = 8 // bits
    mask = (1 << bits) - 1
    half = 1 << (bits - 1)
    u = p.astype(jnp.int32) & 0xFF                     # as unsigned byte
    rows = []
    for j in range(per):
        c = (u >> (bits * j)) & mask
        c = jnp.where(c >= half, c - (1 << bits), c)
        rows.append(c)
    # interleave back to (T, BF): token t = rows[t % per][t // per]
    cat = jnp.stack(rows, axis=1).reshape(T, p.shape[1])
    o_ref[...] = (cat.astype(jnp.float32) * s[None, :]).astype(dtype)


def _pad_to(x: Array, mult: int, axis: int) -> Tuple[Array, int]:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def quantize(x: Array, bits: int, interpret: bool = False
             ) -> Tuple[Array, Array]:
    """x: (T, F) -> (packed (T*bits//8, F) int8, scales (F,) fp32)."""
    assert bits in (8, 4, 2)
    T, F = x.shape
    assert T % (8 // bits) == 0, (T, bits)
    xp, pad = _pad_to(x, LANES, 1)
    Fp = xp.shape[1]
    bf = min(Fp, 512)
    while Fp % bf:
        bf //= 2
    Tp = T * bits // 8
    packed, scale = pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits, T=T),
        grid=(Fp // bf,),
        in_specs=[pl.BlockSpec((T, bf), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((Tp, bf), lambda i: (0, i)),
                   pl.BlockSpec((bf,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((Tp, Fp), jnp.int8),
                   jax.ShapeDtypeStruct((Fp,), jnp.float32)],
        interpret=interpret,
    )(xp)
    if pad:
        packed, scale = packed[:, :F], scale[:F]
    return packed, scale


def dequantize(packed: Array, scale: Array, bits: int, n_tokens: int,
               dtype=jnp.bfloat16, interpret: bool = False) -> Array:
    assert bits in (8, 4, 2)
    Tp, F = packed.shape
    pp, pad = _pad_to(packed, LANES, 1)
    sp, _ = _pad_to(scale, LANES, 0)
    Fp = pp.shape[1]
    bf = min(Fp, 512)
    while Fp % bf:
        bf //= 2
    out = pl.pallas_call(
        functools.partial(_dequant_kernel, bits=bits, T=n_tokens,
                          dtype=dtype),
        grid=(Fp // bf,),
        in_specs=[pl.BlockSpec((Tp, bf), lambda i: (0, i)),
                  pl.BlockSpec((bf,), lambda i: (i,))],
        out_specs=pl.BlockSpec((n_tokens, bf), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n_tokens, Fp), dtype),
        interpret=interpret,
    )(pp, sp)
    return out[:, :F] if pad else out
