"""Scale harness: declarative load scenarios + virtual-clock driver +
metrics/report layer (DESIGN.md "Scale harness").

  spec       ScenarioSpec dataclass + YAML-ish dict loader
  scenarios  named scenario library (steady_poisson ... scale_10k)
  driver     VirtualClock, run_scenario, and the repo's single
             wall-clock trace replay (replay_trace)
  metrics    deterministic EventLog (sha256 probe) + report/gate JSON
"""
from repro.loadgen.driver import (VirtualClock, bind_apps_by_ctx,  # noqa: F401
                                  build_service, build_zoo_service,
                                  make_events, replay_trace, run_scenario)
from repro.loadgen.metrics import (EventLog, build_report,  # noqa: F401
                                   gate_metrics, write_bench)
from repro.loadgen.scenarios import (SCENARIOS, get_scenario,  # noqa: F401
                                     scenario_from_dict)
from repro.loadgen.spec import (ScenarioSpec, load_scenario,  # noqa: F401
                                validate_spec)
