"""Event log + metrics/report layer for the scale harness.

``EventLog`` is the determinism probe: every scheduling event the
driver observes (arrive/begin/round/preempt/done/flush) is rendered to
one canonical text line and folded into a running sha256.  Two runs of
the same scenario seed must produce byte-identical logs — the hash
makes that checkable at 10^5-event scale without retaining the lines
(only the first ``keep`` are kept for inspection; the hash covers all).

``build_report`` aggregates one scenario run into the JSON shape the
regression gate consumes: per-priority p50/p95/p99 TTFT and TBT in
VIRTUAL seconds (machine-portable — the simulation clock advances by
the spec's cost model, never by wall time), admission waits, queue
depth, preemption counts, pool fault/reclaim counters, switch-in
totals, and bytes-moved-per-token from the swap tier's byte counters.
"""
from __future__ import annotations

import hashlib
import json
from typing import Any, Dict, List, Optional


class EventLog:
    """Append-only scheduling-event log with an incremental sha256.

    Lines are ``kind t field0 field1 ...`` with times rendered via
    ``repr`` (exact — two equal floats always render identically).
    """

    def __init__(self, keep: Optional[int] = 4096):
        self._sha = hashlib.sha256()
        self._keep = keep
        self.lines: List[str] = []
        self.n = 0

    def emit(self, kind: str, t: float, *fields: Any):
        line = " ".join([kind, repr(float(t))] + [str(f) for f in fields])
        self._sha.update(line.encode())
        self._sha.update(b"\n")
        if self._keep is None or self.n < self._keep:
            self.lines.append(line)
        self.n += 1

    def sha256(self) -> str:
        return self._sha.hexdigest()


def _round_floats(obj: Any, ndigits: int = 9) -> Any:
    """Stabilize a report for JSON diffing: cut float noise far below
    metric significance (virtual times are exact; wall times are not)."""
    if isinstance(obj, float):
        return round(obj, ndigits)
    if isinstance(obj, dict):
        return {k: _round_floats(v, ndigits) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_round_floats(v, ndigits) for v in obj]
    return obj


_FAULT_KEYS = (
    "degraded_mode", "degraded_entries", "degraded_exits",
    "chunks_recovered_recompute", "chunks_corrupt_detected",
    "io_errors_detected", "evict_dropped", "recover_failed",
    "io_retries", "io_recovered", "io_failed_jobs",
    "tmp_files_swept", "delete_errors",
    "faults_injected_total", "faults_injected")


def build_report(spec, *, router_stats: Dict[str, Any],
                 svc_stats: Dict[str, Any], log: EventLog,
                 virtual_s: float, wall_s: float,
                 io_read: int, io_written: int,
                 n_streams: int, n_stuck: int, n_errors: int,
                 mem_used: int, n_errors_fg: int = 0,
                 tokens_sha256: Optional[str] = None,
                 tokens_sha_by_app: Optional[Dict[str, str]] = None
                 ) -> Dict[str, Any]:
    """One scenario run -> the report dict written to
    BENCH_scenarios.json.  Everything except ``wall_s`` is
    deterministic in (scenario, seed) and portable across machines."""
    toks = int(router_stats.get("decoded_tokens", 0))
    moved = int(io_read) + int(io_written)
    report: Dict[str, Any] = {
        "scenario": spec.name,
        "seed": spec.seed,
        "spec": spec.to_dict(),
        "n_contexts": spec.n_contexts,
        "n_calls": spec.n_calls,
        "virtual_duration_s": virtual_s,
        "wall_s": wall_s,                      # NOT gated: machine-local
        "event_log_sha256": log.sha256(),
        "events_logged": log.n,
        "streams": {"total": n_streams, "stuck": n_stuck,
                    "errors": n_errors, "errors_fg": n_errors_fg},
        "budget": {"memory_budget": spec.memory_budget,
                   "mem_used": mem_used,
                   "ok": mem_used <= spec.memory_budget},
        "io": {"disk_bytes_read": int(io_read),
               "disk_bytes_written": int(io_written),
               "bytes_moved_per_token": moved / max(1, toks)},
        "router": router_stats,
        "service": {k: svc_stats.get(k) for k in (
            "total_calls", "switch_mean_s", "switch_p99_s",
            "switch_total_s", "mem_used", "disk_bytes",
            "decode_ready_contexts", "quant_resident_chunks",
            "paged_pool") if k in svc_stats},
        "pool": {k: svc_stats[k] for k in (
            "pool_pages16_total", "pool_pages16_used",
            "pool_pages8_total", "pool_pages8_used",
            "pool_page_faults", "pool_pt_switch_ins",
            "pool_admit_switch_ins", "pool_reclaims",
            "pool_admit_fault_retries")
            if k in svc_stats},
        "faults": {k: svc_stats[k] for k in _FAULT_KEYS
                   if k in svc_stats},
    }
    report["faults"]["watchdog_preempts"] = int(
        router_stats.get("watchdog_preempts", 0))
    report["faults"]["bg_shed"] = int(router_stats.get("bg_shed", 0))
    if tokens_sha256 is not None:
        # every decoded token, streams in admission order: the recovery
        # token-identity probe (DESIGN.md §6) — identical across
        # same-seed runs, and for 16-bit policies identical to the
        # fault-free run of the same workload
        report["tokens_sha256"] = tokens_sha256
    if tokens_sha_by_app is not None:
        # per-app split of the probe: with contexts bound to apps (zoo
        # scenarios) each app's hash must match its family served solo
        report["tokens_sha_by_app"] = dict(tokens_sha_by_app)
    return _round_floats(report)


def gate_metrics(report: Dict[str, Any]) -> Dict[str, Any]:
    """The machine-portable subset ``check_regression --kind scenario``
    compares (virtual-time QoS + throughput shape + movement cost)."""
    r = report["router"]
    out: Dict[str, Any] = {
        "scenario": report["scenario"],
        "seed": report["seed"],
        "event_log_sha256": report["event_log_sha256"],
        "virtual_duration_s": report["virtual_duration_s"],
        "tokens_per_round": r.get("tokens_per_round", 0.0),
        "preemptions": r.get("preemptions", 0),
        "bytes_moved_per_token": report["io"]["bytes_moved_per_token"],
        "stuck_streams": report["streams"]["stuck"],
        "errors": report["streams"].get("errors", 0),
        "errors_fg": report["streams"].get("errors_fg", 0),
        "budget_ok": report["budget"]["ok"],
    }
    if "tokens_sha256" in report:
        out["tokens_sha256"] = report["tokens_sha256"]
    if "tokens_sha_by_app" in report:
        out["tokens_sha_by_app"] = report["tokens_sha_by_app"]
    fl = report.get("faults") or {}
    if fl.get("faults_injected_total") or fl.get("degraded_entries"):
        for k in ("faults_injected_total", "chunks_recovered_recompute",
                  "chunks_corrupt_detected", "recover_failed",
                  "degraded_entries", "degraded_exits", "degraded_mode",
                  "io_failed_jobs", "evict_dropped", "watchdog_preempts",
                  "bg_shed"):
            out[k] = fl.get(k, 0)
    fg = r.get("foreground")
    if fg:
        for k in ("ttft_p50_s", "ttft_p95_s", "ttft_p99_s",
                  "tbt_p50_s", "tbt_p99_s", "wait_p95_s"):
            if k in fg:
                out[f"fg_{k}"] = fg[k]
    bg = r.get("background")
    if bg:
        for k in ("wait_p50_s", "wait_p95_s", "wait_p99_s"):
            if k in bg:
                out[f"bg_{k}"] = bg[k]
    return out


def deterministic_view(report: Dict[str, Any]) -> Dict[str, Any]:
    """The subset of a report that must be IDENTICAL across same-seed
    runs.  ``wall_s`` is machine time; the ``service`` section carries
    wall-clock switch timings and the disk store's residual byte count
    (async swap-out completion order is thread-scheduling dependent).
    Everything else — event log hash, virtual-time QoS, queue depth,
    pool counters, io deltas — is a pure function of the seed."""
    return {k: v for k, v in report.items()
            if k not in ("wall_s", "service")}


def write_bench(path: str, doc: Dict[str, Any]):
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
