"""Declarative scenario specs for the scale harness (DESIGN.md "Scale
harness").

A ``ScenarioSpec`` is the full description of one load experiment:
the synthetic workload (arrival process x context-selection pattern x
length distributions x per-app priority mix, all derived from ONE
seed), the service configuration it runs against, and the virtual-time
cost model the driver uses to advance the simulation clock.  Specs are
frozen dataclasses so a named scenario can never be mutated in place —
derive variants with ``override()``.

``load_scenario`` is the YAML-ish loader: it accepts the plain-dict
form (what ``yaml.safe_load`` of a scenario file would produce) and
validates every field against the spec schema, so a typo'd key or an
unknown arrival kind fails loudly at load time instead of silently
running the default workload.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Tuple

from repro.core.faults import plan_from_config
from repro.trace.synth import ARRIVALS, CTX_PATTERNS

_PRIORITIES = ("foreground", "fg", "background", "bg")
_LEN_DISTS = ("fixed", "uniform", "lognormal", "bimodal")


def _default_arrival() -> Dict[str, Any]:
    return {"kind": "poisson", "rate_per_s": 0.5}


def _default_prompt_len() -> Dict[str, Any]:
    return {"dist": "uniform", "lo": 4, "hi": 12}


def _default_output_len() -> Dict[str, Any]:
    return {"dist": "fixed", "n": 4}


def _default_apps() -> Tuple[Dict[str, Any], ...]:
    return ({"name": "app0", "priority": "foreground", "weight": 1.0},)


@dataclass(frozen=True)
class ScenarioSpec:
    """One named, seeded load scenario (workload + service + cost model).

    Workload fields feed ``trace.synth.synthesize_mixed``; service
    fields feed ``driver.build_service``; the cost-model fields are the
    virtual seconds the driver charges per scheduling event so QoS
    metrics are deterministic in the seed (DESIGN.md "Scale harness").
    """
    name: str
    n_contexts: int
    n_calls: int
    seed: int = 0
    # -- workload ------------------------------------------------------ #
    arrival: Mapping[str, Any] = field(default_factory=_default_arrival)
    ctx_pattern: str = "markov"
    prompt_len: Mapping[str, Any] = field(default_factory=_default_prompt_len)
    output_len: Mapping[str, Any] = field(default_factory=_default_output_len)
    apps: Tuple[Mapping[str, Any], ...] = field(default_factory=_default_apps)
    prompt_source: str = "markov"        # "uniform" skips the markov walk
    # -- service under test -------------------------------------------- #
    model_profile: str = "bench"         # "bench" (~8M) | "reduced" (tiny)
    policy: str = "llms"
    memory_budget: int = 30_000
    max_ctx_len: int = 96
    chunk_tokens: int = 16
    decode_batch: int = 4
    slice_steps: int = 2
    paged_pool: bool = True
    quant_resident: bool = False
    record_limit: Optional[int] = 4096   # bound per-call dict retention
    predict: bool = True                 # §3.4 next-context hints
    profile: bool = True                 # profile_pipeline for llms policy
    disk_bw: Optional[float] = 25e6      # None = unthrottled swap tier
    disk_lat: float = 2e-4
    # -- virtual-time cost model (simulated seconds) -------------------- #
    round_s: float = 0.05                # one batched decode round
    prefill_per_token_s: float = 0.01    # charged at begin (not resume)
    switch_base_s: float = 0.2           # begin/resume fixed cost
    idle_flush_s: Optional[float] = 60.0  # virtual idle gap -> AoT flush
    # -- fault injection (DESIGN.md §6) --------------------------------- #
    # per-kind rates + meta knobs, validated by faults.plan_from_config:
    #   transient_eio/persistent_eio/enospc/torn_write/bit_flip/slow_io/
    #   pool_admit (rates), fail_n, slow_io_s, seed (defaults spec.seed),
    #   disk_full_windows ([[t_on, t_off], ...] in VIRTUAL seconds),
    #   swap_deadline_s (per-slice switch-in watchdog).
    faults: Mapping[str, Any] = field(default_factory=dict)
    notes: str = ""

    def override(self, **kw) -> "ScenarioSpec":
        """A variant spec with the given fields replaced (reduced CI
        sizes, sweep points, ...)."""
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["apps"] = [dict(a) for a in self.apps]
        d["arrival"] = dict(self.arrival)
        d["prompt_len"] = dict(self.prompt_len)
        d["output_len"] = dict(self.output_len)
        d["faults"] = {k: (list(list(w) for w in v)
                           if k == "disk_full_windows" else v)
                       for k, v in self.faults.items()}
        return d


_FIELDS = {f.name for f in dataclasses.fields(ScenarioSpec)}
_REQUIRED = ("name", "n_contexts", "n_calls")


def validate_spec(spec: ScenarioSpec) -> ScenarioSpec:
    """Schema checks beyond dataclass typing; returns the spec."""
    if spec.n_contexts <= 0 or spec.n_calls <= 0:
        raise ValueError(f"{spec.name}: n_contexts/n_calls must be > 0")
    kind = spec.arrival.get("kind", "poisson")
    if kind not in ARRIVALS:
        raise ValueError(f"{spec.name}: unknown arrival kind {kind!r} "
                         f"(one of {ARRIVALS})")
    if float(spec.arrival.get("rate_per_s", 0.5)) <= 0:
        raise ValueError(f"{spec.name}: arrival rate_per_s must be > 0")
    if spec.ctx_pattern not in CTX_PATTERNS:
        raise ValueError(f"{spec.name}: unknown ctx_pattern "
                         f"{spec.ctx_pattern!r} (one of {CTX_PATTERNS})")
    for ln, which in ((spec.prompt_len, "prompt_len"),
                      (spec.output_len, "output_len")):
        if ln.get("dist", "fixed") not in _LEN_DISTS:
            raise ValueError(f"{spec.name}: {which} dist "
                             f"{ln.get('dist')!r} (one of {_LEN_DISTS})")
    if not spec.apps:
        raise ValueError(f"{spec.name}: at least one app required")
    names = set()
    for a in spec.apps:
        nm = a.get("name")
        if not nm or nm in names:
            raise ValueError(f"{spec.name}: apps need unique names")
        names.add(nm)
        if str(a.get("priority", "foreground")).lower() not in _PRIORITIES:
            raise ValueError(f"{spec.name}: app {nm!r} priority "
                             f"{a.get('priority')!r}")
        for which in ("prompt_len", "output_len"):   # per-app overrides
            if which in a and a[which].get("dist",
                                           "fixed") not in _LEN_DISTS:
                raise ValueError(f"{spec.name}: app {nm!r} {which} dist "
                                 f"{a[which].get('dist')!r}")
    if spec.prompt_source not in ("markov", "uniform"):
        raise ValueError(f"{spec.name}: prompt_source "
                         f"{spec.prompt_source!r}")
    if spec.model_profile not in ("bench", "reduced"):
        raise ValueError(f"{spec.name}: model_profile "
                         f"{spec.model_profile!r} (bench | reduced)")
    if spec.slice_steps < 0 or spec.decode_batch < 1:
        raise ValueError(f"{spec.name}: bad slice_steps/decode_batch")
    if min(spec.round_s, spec.prefill_per_token_s, spec.switch_base_s) < 0:
        raise ValueError(f"{spec.name}: cost model must be >= 0")
    if spec.faults:
        try:
            plan_from_config(dict(spec.faults), spec.seed)
        except (ValueError, TypeError) as e:
            raise ValueError(f"{spec.name}: bad faults config: {e}") from e
        for w in spec.faults.get("disk_full_windows", ()):
            a, b = float(w[0]), float(w[1])
            if not 0 <= a < b:
                raise ValueError(f"{spec.name}: disk_full_window {w} "
                                 "needs 0 <= t_on < t_off")
        dl = spec.faults.get("swap_deadline_s")
        if dl is not None and float(dl) <= 0:
            raise ValueError(f"{spec.name}: swap_deadline_s must be > 0")
    return spec


def load_scenario(doc: Mapping[str, Any],
                  base: Optional[ScenarioSpec] = None) -> ScenarioSpec:
    """Build a validated spec from a plain dict (e.g. parsed YAML).

    Unknown keys are an error, not a warning: a scenario file that
    misspells ``slice_steps`` must not silently run the default.  With
    ``base``, the dict is an OVERLAY — only the given fields replace
    the base spec's (used for reduced CI variants of named scenarios).
    """
    unknown = set(doc) - _FIELDS
    if unknown:
        raise ValueError(f"unknown scenario fields: {sorted(unknown)}")
    if base is None:
        missing = [k for k in _REQUIRED if k not in doc]
        if missing:
            raise ValueError(f"scenario missing required fields: {missing}")
        spec = ScenarioSpec(**{k: _coerce(k, v) for k, v in doc.items()})
    else:
        spec = base.override(**{k: _coerce(k, v) for k, v in doc.items()})
    return validate_spec(spec)


def _coerce(key: str, val: Any) -> Any:
    """Normalize loader-friendly forms (lists -> tuples for apps)."""
    if key == "apps":
        return tuple(dict(a) for a in val)
    return val
