"""Virtual-clock scenario driver + the repo's single trace-replay
implementation (DESIGN.md "Scale harness").

**Virtual clock.**  ``run_scenario`` replaces wall time with an
event-heap simulation clock injected into ``ServiceRouter`` and every
``GenerationStream``: the clock advances only on deterministic
scheduling events (a batched decode round costs ``spec.round_s``
virtual seconds, a begin costs ``switch_base_s`` plus prefill, an idle
engine jumps straight to the next arrival).  Model execution still
runs for real — tokens are genuinely decoded — but no code path ever
sleeps, so 10^4-10^5 synthetic contexts drive through the router on
CPU in bounded wall time while every QoS metric (TTFT, TBT, admission
wait, queue depth) is an exact, machine-portable function of the
scenario seed.  Arrivals are injected from the router's ``on_round``
hook at their exact virtual timestamps, so a burst that lands
mid-slice exercises preemption the same way a wall-clock run would.

**Replay.**  ``replay_trace`` is the ONE replay loop in the repo:
``benchmarks/common.py:replay`` (serial, strict trace order) and
``examples/serve_trace.py`` (flood + drain, fg/bg split) are both
expressed on it.  Wall-clock mode; warm pass first so jit compilation
never lands in the measured pass.
"""
from __future__ import annotations

import hashlib
import tempfile
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.faults import (clear_faults, install_faults,
                               plan_from_config, set_disk_full)
from repro.core.restore import io_counters, set_disk_throttle
from repro.core.requests import FOREGROUND
from repro.core.scheduler import ServiceRouter, parse_priority
from repro.core.service import LLMSConfig, LLMService
from repro.core.zoo import ZooService
from repro.loadgen.metrics import EventLog, build_report
from repro.loadgen.spec import ScenarioSpec
from repro.trace.synth import TraceEvent, synthesize_mixed


class VirtualClock:
    """Injectable simulation clock (callable -> current virtual time).

    ``advance`` charges a cost, ``advance_to`` jumps forward (never
    backward), and ``at`` temporarily rewinds to stamp an admission at
    its exact arrival instant while a later virtual time is current.
    """

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float):
        self.t += dt

    def advance_to(self, t: float):
        if t > self.t:
            self.t = t

    @contextmanager
    def at(self, t: float):
        saved = self.t
        self.t = float(t)
        try:
            yield
        finally:
            self.t = max(saved, self.t)


def make_events(spec: ScenarioSpec, vocab: int) -> List[TraceEvent]:
    """The scenario's synthetic workload (deterministic in spec.seed)."""
    return synthesize_mixed(
        spec.n_contexts, spec.n_calls, vocab,
        arrival=dict(spec.arrival), ctx_pattern=spec.ctx_pattern,
        prompt_len=dict(spec.prompt_len), output_len=dict(spec.output_len),
        apps=[dict(a) for a in spec.apps],
        prompt_source=spec.prompt_source, seed=spec.seed)


def build_service(spec: ScenarioSpec, model, params) -> LLMService:
    """The service under test, configured per the spec (the model is
    supplied by the caller — src/repro/loadgen stays model-agnostic)."""
    if spec.disk_bw is None:
        set_disk_throttle(None)
    else:
        set_disk_throttle(spec.disk_bw, spec.disk_lat)
    dl = spec.faults.get("swap_deadline_s") if spec.faults else None
    sc = LLMSConfig(policy=spec.policy, max_ctx_len=spec.max_ctx_len,
                    chunk_tokens=spec.chunk_tokens,
                    memory_budget=spec.memory_budget,
                    decode_batch=spec.decode_batch,
                    quant_resident=spec.quant_resident,
                    paged_pool=spec.paged_pool,
                    record_limit=spec.record_limit,
                    swap_deadline_s=None if dl is None else float(dl),
                    swap_dir=tempfile.mkdtemp(
                        prefix=f"loadgen_{spec.name}_"))
    svc = LLMService(model, params, sc)
    if spec.profile and sc.use_pipeline:
        svc.profile_pipeline()
    return svc


def build_zoo_service(spec: ScenarioSpec,
                      models: Dict[str, Tuple[Any, Any]]) -> ZooService:
    """A multi-family ``ZooService`` under test: one member per entry of
    ``models`` (family -> (model, params)), every member configured from
    the spec but sharing ONE byte budget / swap tier / eviction order.
    Per-member capability knobs derive from each family's KVSpec —
    ``quant_resident`` only lands on families that declare it."""
    if spec.disk_bw is None:
        set_disk_throttle(None)
    else:
        set_disk_throttle(spec.disk_bw, spec.disk_lat)
    dl = spec.faults.get("swap_deadline_s") if spec.faults else None
    members: Dict[str, Tuple[Any, Any, LLMSConfig]] = {}
    for fam, (model, params) in models.items():
        sc = LLMSConfig(
            policy=spec.policy, max_ctx_len=spec.max_ctx_len,
            chunk_tokens=spec.chunk_tokens,
            memory_budget=spec.memory_budget,
            decode_batch=spec.decode_batch,
            quant_resident=(spec.quant_resident
                            and model.kv_spec().quant_resident),
            paged_pool=spec.paged_pool,
            swap_deadline_s=None if dl is None else float(dl))
        members[fam] = (model, params, sc)
    return ZooService(members, memory_budget=spec.memory_budget,
                      swap_dir=tempfile.mkdtemp(
                          prefix=f"loadgen_{spec.name}_"))


def bind_apps_by_ctx(events: List[TraceEvent],
                     spec: ScenarioSpec) -> List[TraceEvent]:
    """Deterministically rebind every event to the app that owns its
    context (ctx_id modulo the app list), so each context's calls all
    belong to ONE app — the precondition for the per-family
    solo-vs-mixed token-identity probe (mixed_zoo gate): filtering the
    bound events by app yields exactly that family's workload."""
    apps = [dict(a) for a in spec.apps]
    for ev in events:
        a = apps[ev.ctx_id % len(apps)]
        ev.app = str(a["name"])
        ev.priority = str(a.get("priority", "foreground"))
    return events


def run_scenario(spec: ScenarioSpec, svc: Any, vocab: int, *,
                 log_keep: Optional[int] = 4096,
                 events: Optional[List[TraceEvent]] = None
                 ) -> Dict[str, Any]:
    """Drive one scenario through a ``ServiceRouter`` under the virtual
    clock; -> the report dict (see ``metrics.build_report``).

    The caller owns ``svc`` (build one with ``build_service``); the
    router is created here so the clock wires into every stream."""
    assert spec.slice_steps >= 1, \
        "scenario driver needs slice_steps >= 1 (refill/preempt between " \
        "slices); use replay_trace for whole-generation dispatch"
    if events is None:
        events = make_events(spec, vocab)
    clock = VirtualClock()
    log = EventLog(keep=log_keep)
    io0 = io_counters()
    wall0 = time.perf_counter()

    # fault plan (DESIGN.md §6): installed for the whole run, cleared on
    # exit; disk-full windows toggle on VIRTUAL time so every injected
    # failure — and the degraded-mode transitions it causes — lands at a
    # seed-deterministic instant
    fault_cfg = dict(spec.faults) if spec.faults else {}
    windows = [(float(a), float(b))
               for a, b in fault_cfg.get("disk_full_windows", ())]
    if fault_cfg:
        fspecs, fseed = plan_from_config(fault_cfg, spec.seed)
        install_faults(fspecs, fseed)
    else:
        clear_faults()
    df_on = False

    def update_disk_full():
        nonlocal df_on
        if not windows:
            return
        on = any(a <= clock.t < b for a, b in windows)
        if on != df_on:
            df_on = on
            set_disk_full(on)
            log.emit("disk_full", clock.t, int(on))

    router = ServiceRouter(svc, predict=spec.predict, start=False,
                           slice_steps=spec.slice_steps, clock=clock,
                           record_limit=spec.record_limit)
    sessions = {a["name"]: router.register_app(
        a["name"], a.get("priority", "foreground"),
        family=a.get("family")) for a in spec.apps}
    stubs: Dict[int, Any] = {}
    streams: List[Any] = []
    stream_apps: List[str] = []
    next_ev = 0

    def inject_due():
        """Admit every arrival whose virtual time has passed, stamped
        at its exact arrival instant."""
        nonlocal next_ev
        while next_ev < len(events) and events[next_ev].time <= clock.t:
            ev = events[next_ev]
            next_ev += 1
            sess = sessions[ev.app]
            if ev.ctx_id not in stubs:
                stubs[ev.ctx_id] = sess.new_ctx()
            with clock.at(ev.time):
                streams.append(sess.stream(
                    stubs[ev.ctx_id], ev.prompt.tolist(),
                    max_new_tokens=ev.max_new, priority=ev.priority))
            stream_apps.append(ev.app)
            log.emit("arrive", ev.time, ev.ctx_id, ev.priority, ev.app)

    def on_begin(job, resumed):
        dt = spec.switch_base_s
        if not resumed:
            dt += spec.prefill_per_token_s * len(job["request"].prompt)
        clock.advance(dt)
        log.emit("begin", clock.t, job["stub"].ctx_id, int(resumed),
                 job["prio"])

    def on_round(live):
        clock.advance(spec.round_s)
        update_disk_full()
        log.emit("round", clock.t, len(live))
        inject_due()

    def on_preempt(job):
        log.emit("preempt", clock.t, job["stub"].ctx_id, job["prio"])

    def on_complete(job, cancelled):
        log.emit("done", clock.t, job["stub"].ctx_id, job["prio"],
                 len(job["stream"].tokens), int(cancelled))

    router.on_begin = on_begin
    router.on_round = on_round
    router.on_preempt = on_preempt
    router.on_complete = on_complete

    try:
        with router:
            update_disk_full()
            while True:
                inject_due()
                if router.pump(max_slices=None):
                    continue
                if next_ev >= len(events):
                    break
                # engine idle, nothing queued: jump to the next arrival;
                # a long enough virtual gap lets the AoT writes complete
                # (device-idle I/O, benchmarks/common.py regime note)
                gap = events[next_ev].time - clock.t
                if spec.idle_flush_s is not None and gap > spec.idle_flush_s:
                    svc.swapper.flush(raise_errors=False)
                    log.emit("flush", clock.t, gap)
                clock.advance_to(events[next_ev].time)
                update_disk_full()

        # settle in-flight AoT writes BEFORE the final byte snapshot: the
        # last swap-outs are still on the swapper threads, and counting a
        # write depends on whether it executed yet — the one wall-clock
        # race that would leak into an otherwise deterministic report.
        # Errors never raise here: failed jobs were already classified
        # and counted on the workers (fault scenarios).
        svc.swapper.flush(raise_errors=False)
        wall_s = time.perf_counter() - wall0
        io1 = io_counters()
        n_stuck = sum(not s.done for s in streams)
        n_errors = sum(s.error is not None for s in streams)
        n_errors_fg = sum(
            s.error is not None
            and parse_priority(s.request.priority) == FOREGROUND
            for s in streams)
        # recovery-identity probe: every decoded token, streams in
        # admission order — two runs that recover differently (or a
        # fault run that diverges from the fault-free run) hash apart
        sha = hashlib.sha256()
        for s in streams:
            sha.update((",".join(map(str, s.tokens)) + ";").encode())
        # per-app split of the same probe: the mixed_zoo gate compares
        # each app's hash against the family served SOLO at the same seed
        by_app = {a["name"]: hashlib.sha256() for a in spec.apps}
        for s, app in zip(streams, stream_apps):
            by_app[app].update((",".join(map(str, s.tokens)) + ";").encode())
        return build_report(
            spec, router_stats=router.stats(), svc_stats=svc.stats(),
            log=log, virtual_s=clock.t, wall_s=wall_s,
            io_read=io1["read"] - io0["read"],
            io_written=io1["write"] - io0["write"],
            n_streams=len(streams), n_stuck=n_stuck, n_errors=n_errors,
            n_errors_fg=n_errors_fg, tokens_sha256=sha.hexdigest(),
            tokens_sha_by_app={k: v.hexdigest()
                               for k, v in by_app.items()},
            mem_used=svc.mem.used)
    finally:
        set_disk_full(False)
        clear_faults()


# --------------------------------------------------------------------- #
# wall-clock trace replay (the single implementation)
# --------------------------------------------------------------------- #
def replay_trace(svc: LLMService, events, *, mode: str = "serial",
                 max_new: int = 4, idle_flush_s: Optional[float] = 60.0,
                 warm: bool = True, predict: bool = False,
                 slice_steps: int = 0,
                 apps: Tuple[Tuple[str, str], ...] = (
                     ("bench", "foreground"),),
                 route: Optional[Callable[[Any], str]] = None,
                 measured_throttle: Optional[Tuple[float, float]] = (
                     25e6, 2e-4)) -> Dict[str, Any]:
    """Replay a trace through a ``ServiceRouter`` (inline dispatch).

      mode="serial"  one call at a time in strict trace order, arrival
                     gaps bookkept not slept (gaps > ``idle_flush_s``
                     flush the AoT writes) — benchmarks/common.replay.
      mode="flood"   admit every event up front, then drain: exercises
                     queueing/preemption — examples/serve_trace.py.

    ``route(ev) -> app name`` picks the submitting session (default:
    the first app).  With ``warm`` a full pass runs first (throttle
    off) so jit compilation never lands in the measured pass; stats are
    reset in between (``router.reset_stats`` — accumulators too, not
    just the record lists).  -> ``svc.stats()`` + ``"router"`` section.
    """
    assert mode in ("serial", "flood"), mode
    with ServiceRouter(svc, predict=predict, start=False,
                       slice_steps=slice_steps) as router:
        sessions = {name: router.register_app(name, prio)
                    for name, prio in apps}
        first = apps[0][0]
        pick = route or (lambda ev: first)

        def one_pass():
            stubs: Dict[int, Any] = {}
            if mode == "serial":
                prev_t = None
                for ev in events:
                    sess = sessions[pick(ev)]
                    if ev.ctx_id not in stubs:
                        stubs[ev.ctx_id] = sess.new_ctx()
                    if idle_flush_s is not None and prev_t is not None \
                            and ev.time - prev_t > idle_flush_s:
                        svc.swapper.flush()   # device idle: I/O completed
                    sess.call(stubs[ev.ctx_id], ev.prompt.tolist(),
                              max_new_tokens=max_new)
                    prev_t = ev.time
            else:
                streams = []
                for ev in events:
                    sess = sessions[pick(ev)]
                    if ev.ctx_id not in stubs:
                        stubs[ev.ctx_id] = sess.new_ctx()
                    streams.append(sess.stream(stubs[ev.ctx_id],
                                               ev.prompt.tolist(),
                                               max_new_tokens=max_new))
                router.drain()
                for s in streams:
                    s.result()    # surface call failures, like serial
            return stubs

        if warm:
            set_disk_throttle(None)       # warm pass: compile everything
            sess0 = sessions[first]
            for stub in one_pass().values():
                sess0.del_ctx(stub)
            svc.records.clear()
            router.reset_stats()
            if measured_throttle is not None:
                set_disk_throttle(*measured_throttle)
        one_pass()
        st = svc.stats()
        st["router"] = router.stats()
    return st
