"""Named scenario library for the scale harness.

Each entry is a frozen, seeded ``ScenarioSpec`` — same name + same seed
always synthesizes the same workload and (under the virtual-clock
driver) the same event log.  The library covers the load shapes the
LLMaaS stack is built for (paper §2: one shared model, many apps):

  steady_poisson    open-loop Poisson arrivals over a markov context
                    pattern — the calibration baseline.
  fg_burst_over_bg  bursty foreground interactions arriving over a
                    steady background-agent load: the preemption /
                    decode-slice story (paper §2.2, DESIGN.md §4).
  diurnal_ramp      sinusoidal arrival rate (a day compressed into the
                    trace): queue depth breathes, AoT flushes happen in
                    the troughs.
  herd_restore      thundering-herd: batches of simultaneous arrivals
                    on cold contexts, hammering the restore/switch-in
                    path all at once.
  eviction_churn    adversarial ``sweep`` context pattern over far more
                    contexts than the budget holds — every touch is the
                    coldest context, defeating LRU/LCTRU, maximizing
                    pool reclaims and page faults.
  scale_10k         10^4 contexts / 10^4 calls through the router on
                    CPU in bounded wall time (uniform token source, no
                    disk throttle): the scale soak that surfaces O(n)
                    scans and unbounded retention.
  flaky_disk        transient EIO + bit-flips + torn writes + slow IO
                    injected into the swap tier under eviction pressure
                    (DESIGN.md §6): every fault must be retried or
                    recovered by recompute — zero failed foreground
                    calls, tokens identical to the fault-free run.
  disk_full_churn   ENOSPC windows over the churn workload: the service
                    enters degraded mode (AoT off, background shed),
                    keeps serving foreground via evict+recompute, and
                    exits when the probe write succeeds.
  mixed_zoo         three model families (dense + MLA latent + RWKV6
                    constant state) behind ONE ServiceRouter sharing a
                    single byte budget and swap tier (ZooService);
                    per-family tokens must match each family solo.
  smoke_ci          reduced mixed scenario for the CI gate (seconds).

``get_scenario(name, **overrides)`` returns a (variant of a) library
spec; ``scenario_from_dict`` loads the YAML-ish form, with ``base:``
naming a library entry to overlay.
"""
from __future__ import annotations

from typing import Any, Dict, Mapping

from repro.loadgen.spec import ScenarioSpec, load_scenario, validate_spec

_FG_BG = (
    {"name": "chat", "priority": "foreground", "weight": 1.0},
    {"name": "agent", "priority": "background", "weight": 2.0},
)

_SPECS = (
    ScenarioSpec(
        name="steady_poisson", seed=11,
        n_contexts=64, n_calls=512,
        arrival={"kind": "poisson", "rate_per_s": 2.0},
        ctx_pattern="markov",
        prompt_len={"dist": "bimodal", "short": (4, 8), "long": (24, 48),
                    "p_long": 0.15},
        output_len={"dist": "uniform", "lo": 2, "hi": 6},
        apps=_FG_BG,
        notes="open-loop baseline: steady mixed load, moderate reuse"),
    ScenarioSpec(
        name="fg_burst_over_bg", seed=23,
        n_contexts=48, n_calls=640,
        arrival={"kind": "bursty", "rate_per_s": 1.0,
                 "burst_every_s": 40.0, "burst_size": 24,
                 "burst_rate_per_s": 40.0, "burst_frac": 0.4},
        ctx_pattern="markov",
        prompt_len={"dist": "uniform", "lo": 4, "hi": 12},
        output_len={"dist": "uniform", "lo": 2, "hi": 8},
        apps=(
            {"name": "chat", "priority": "foreground", "weight": 1.0,
             "output_len": {"dist": "uniform", "lo": 2, "hi": 4}},
            {"name": "agent", "priority": "background", "weight": 2.0,
             "output_len": {"dist": "uniform", "lo": 10, "hi": 18}},
            {"name": "indexer", "priority": "background", "weight": 1.0,
             "output_len": {"dist": "uniform", "lo": 10, "hi": 18}},
        ),
        slice_steps=2, decode_batch=4,
        notes="burst arrivals route to foreground apps -> preemptions"),
    ScenarioSpec(
        name="diurnal_ramp", seed=37,
        n_contexts=64, n_calls=512,
        arrival={"kind": "diurnal", "rate_per_s": 1.0,
                 "period_s": 600.0, "amplitude": 0.9},
        ctx_pattern="gaussian",
        prompt_len={"dist": "lognormal", "median": 8, "sigma": 0.5,
                    "lo": 2, "hi": 48},
        output_len={"dist": "fixed", "n": 4},
        apps=_FG_BG,
        idle_flush_s=20.0,
        notes="rate breathes over a compressed day; troughs AoT-flush"),
    ScenarioSpec(
        name="herd_restore", seed=41,
        n_contexts=96, n_calls=384,
        arrival={"kind": "herd", "herd_every_s": 30.0, "herd_size": 16,
                 "rate_per_s": 1 / 30.0},
        ctx_pattern="random",
        prompt_len={"dist": "uniform", "lo": 4, "hi": 10},
        output_len={"dist": "fixed", "n": 3},
        apps=_FG_BG,
        memory_budget=24_000,
        notes="simultaneous cold arrivals hammer restore/switch-in"),
    ScenarioSpec(
        name="eviction_churn", seed=53,
        n_contexts=160, n_calls=480,
        arrival={"kind": "uniform", "rate_per_s": 4.0},
        ctx_pattern="sweep",
        prompt_len={"dist": "fixed", "n": 6},
        output_len={"dist": "fixed", "n": 3},
        apps=_FG_BG,
        memory_budget=20_000,
        notes="round-robin over >> budget contexts: every switch-in "
              "misses, reclaim path saturates"),
    ScenarioSpec(
        name="scale_10k", seed=67,
        n_contexts=10_000, n_calls=10_000,
        arrival={"kind": "poisson", "rate_per_s": 50.0},
        ctx_pattern="sweep",
        prompt_len={"dist": "fixed", "n": 4},
        output_len={"dist": "fixed", "n": 2},
        apps=_FG_BG,
        prompt_source="uniform",
        memory_budget=120_000, max_ctx_len=32,
        decode_batch=8, slice_steps=4,
        record_limit=2048, predict=False, profile=False,
        disk_bw=None, model_profile="reduced",
        notes="10^4 contexts through the router on CPU under the "
              "virtual clock in ~1 min; unthrottled swap tier, uniform "
              "tokens, tiny model (the harness is the thing under test)"),
    ScenarioSpec(
        name="flaky_disk", seed=71,
        n_contexts=24, n_calls=160,
        arrival={"kind": "poisson", "rate_per_s": 2.0},
        ctx_pattern="sweep",
        prompt_len={"dist": "uniform", "lo": 4, "hi": 10},
        output_len={"dist": "fixed", "n": 3},
        apps=_FG_BG,
        # 16-bit chunk storage: the bf16->fp16->bf16 payload roundtrip
        # is lossless, so recompute-based recovery is BIT-EXACT and the
        # tokens_sha256 probe must match the fault-free run (quantized
        # tiers recover approximately — deterministic, but not
        # token-identical; DESIGN.md §6)
        policy="llms_nocomp",
        memory_budget=20_000, decode_batch=2,
        faults={"transient_eio": 0.03, "bit_flip": 0.01,
                "torn_write": 0.01, "slow_io": 0.02, "slow_io_s": 0.002,
                "fail_n": 1, "seed": 1234},
        notes="seeded storage faults under eviction churn: every "
              "injected failure is retried or recovered by recompute; "
              "zero failed foreground calls, tokens identical to the "
              "fault-free run"),
    ScenarioSpec(
        name="disk_full_churn", seed=83,
        n_contexts=32, n_calls=192,
        arrival={"kind": "uniform", "rate_per_s": 4.0},
        ctx_pattern="sweep",
        prompt_len={"dist": "fixed", "n": 6},
        output_len={"dist": "fixed", "n": 3},
        apps=_FG_BG,
        policy="llms_nocomp",
        memory_budget=20_000, decode_batch=2,
        # the window closes well before the trace ends so the probe
        # write succeeds and the run finishes OUT of degraded mode
        faults={"disk_full_windows": [[10.0, 25.0]], "seed": 4321},
        notes="ENOSPC window mid-run: enter degraded mode (AoT off, "
              "background shed, evictions drop dirty payloads), keep "
              "serving foreground via recompute, exit via the probe"),
    ScenarioSpec(
        name="mixed_zoo", seed=91,
        n_contexts=9, n_calls=18,
        arrival={"kind": "uniform", "rate_per_s": 2.0},
        # sweep + n_calls = 2*n_contexts: every context is touched
        # exactly twice, so the second call restores the first call's
        # compressed state — the MLA member's quant-resident latent
        # chunks are actually exercised, not just created.  Contexts
        # are bound to apps by driver.bind_apps_by_ctx (ctx_id mod 3),
        # so each app's token hash is comparable against its family
        # served SOLO at the same seed (tokens_sha_by_app).
        ctx_pattern="sweep",
        prompt_len={"dist": "uniform", "lo": 4, "hi": 8},
        output_len={"dist": "fixed", "n": 3},
        # all-foreground: equal priority means no preemption, so every
        # generation runs begin -> decode -> finish uninterrupted and
        # the solo-vs-mixed identity is a statement about the zoo's
        # shared-substrate routing, not about preemption timing
        apps=(
            {"name": "chat", "priority": "foreground", "weight": 1.0,
             "family": "dense"},
            {"name": "scholar", "priority": "foreground", "weight": 1.0,
             "family": "mla_moe"},
            {"name": "agent", "priority": "foreground", "weight": 1.0,
             "family": "rwkv6"},
        ),
        decode_batch=2, slice_steps=8,
        memory_budget=60_000, max_ctx_len=64,
        quant_resident=True, paged_pool=False,
        model_profile="reduced", profile=False,
        notes="three families (dense + MLA latent + RWKV6 constant "
              "state) behind ONE router against one byte budget and "
              "one swap tier; per-family tokens must equal each family "
              "served solo at the same seed"),
    ScenarioSpec(
        name="smoke_ci", seed=7,
        n_contexts=16, n_calls=96,
        arrival={"kind": "bursty", "rate_per_s": 2.0,
                 "burst_every_s": 15.0, "burst_size": 10,
                 "burst_rate_per_s": 20.0, "burst_frac": 0.3},
        ctx_pattern="random",
        prompt_len={"dist": "uniform", "lo": 3, "hi": 8},
        output_len={"dist": "fixed", "n": 3},
        # background outputs run long so slots are occupied when the
        # foreground burst lands — the burst must PREEMPT (uniformly
        # short outputs free slots so fast that continuous refill
        # always seats the burst without evicting anyone)
        apps=(
            {"name": "chat", "priority": "foreground", "weight": 1.0,
             "output_len": {"dist": "uniform", "lo": 2, "hi": 4}},
            {"name": "agent", "priority": "background", "weight": 2.0,
             "output_len": {"dist": "uniform", "lo": 12, "hi": 20}},
        ),
        decode_batch=2,
        memory_budget=24_000, max_ctx_len=64,
        notes="reduced mixed scenario for the CI regression gate"),
)

SCENARIOS: Dict[str, ScenarioSpec] = {s.name: validate_spec(s)
                                      for s in _SPECS}


def get_scenario(name: str, **overrides: Any) -> ScenarioSpec:
    """A library scenario, optionally with fields overridden (the
    variant keeps the base seed unless ``seed=`` is overridden)."""
    try:
        spec = SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r} "
                       f"(have: {sorted(SCENARIOS)})") from None
    return validate_spec(spec.override(**overrides)) if overrides else spec


def scenario_from_dict(doc: Mapping[str, Any]) -> ScenarioSpec:
    """YAML-ish loader entry point: ``base:`` overlays a library spec."""
    doc = dict(doc)
    base = doc.pop("base", None)
    return load_scenario(doc, base=SCENARIOS[base] if base else None)
