"""Deterministic, seeded failpoint registry for the storage tier.

LLMS is a *system service*: the flash path the paper swaps KV chunks
through is slow, contended and occasionally fails (full disk, torn
writes under power events, transient EIO).  This module lets the test
suite and the loadgen scenarios inject those faults at the real call
sites (``DiskStore.read/write/delete``, ``AsyncSwapper`` worker bodies,
``PagePool`` admission) with REPLAYABLE draws, so fault runs stay under
the harness determinism contract (DESIGN.md §5).

Determinism: a fault decision is a pure hash of
``(seed, kind, site, key, op#)`` where ``op#`` is a per-(site, key)
operation counter.  Same-key storage ops are serialized by
``AsyncSwapper`` and issued from the single dispatcher thread, so the
per-key op sequence — and therefore every draw — is identical across
same-seed runs regardless of IO-thread interleaving.  (A shared RNG
stream would NOT survive thread scheduling.)

Fault kinds
    transient_eio    op fails ``fail_n`` consecutive attempts, then heals
                     (bounded retry always succeeds)
    persistent_eio   key fails until a successful rewrite replaces it
    enospc           writes fail with ENOSPC (also forced globally via
                     ``set_disk_full`` for scenario windows)
    torn_write       file is truncated after the temp write (detected by
                     the checksum preamble on read)
    bit_flip         one payload byte is flipped (detected by CRC32)
    slow_io          the op sleeps ``lat_s`` before proceeding

Sites: ``disk.read``, ``disk.write``, ``disk.delete``, ``swap.worker``,
``pool.admit``.
"""
from __future__ import annotations

import errno
import hashlib
import os
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional, Set, Tuple

from repro.analysis.markers import requires_lock
from repro.analysis.runtime import witness_lock

KINDS = ("transient_eio", "persistent_eio", "enospc", "torn_write",
         "bit_flip", "slow_io")
SITES = ("disk.read", "disk.write", "disk.delete", "swap.worker",
         "pool.admit")
_WRITE_SITES = ("disk.write",)
_IO_SITES = ("disk.read", "disk.write", "swap.worker")


# --------------------------------------------------------------------- #
# failure taxonomy (DESIGN.md §6): detection exceptions
# --------------------------------------------------------------------- #
class TransientIOError(OSError):
    """Injected EIO that heals after ``fail_n`` attempts (retryable)."""

    def __init__(self, msg: str):
        super().__init__(errno.EIO, msg)


class PersistentIOError(OSError):
    """Injected EIO that persists until the key is rewritten.  A caller
    cannot distinguish it from a transient one — the bounded retry
    budget does (it exhausts, and recovery falls back to recompute)."""

    def __init__(self, msg: str):
        super().__init__(errno.EIO, msg)


class DiskFullError(OSError):
    """Injected ENOSPC on the write path (degraded-mode trigger)."""

    def __init__(self, msg: str):
        super().__init__(errno.ENOSPC, msg)


class ChunkCorruptError(RuntimeError):
    """A chunk/state file failed checksum/structure verification.  NOT
    retryable (re-reading returns the same bytes) — recovery must
    recompute from tokens (paper §3.3's IO-Recompute lever)."""


class SwapTimeoutError(TimeoutError):
    """A swap wait exceeded the watchdog deadline; the router converts
    it into a preemption instead of a wedged engine."""


@dataclass(frozen=True)
class FaultSpec:
    """One fault stream: ``kind`` drawn at ``rate`` on ``sites``."""
    kind: str
    sites: Tuple[str, ...]
    rate: float
    fail_n: int = 1          # transient_eio: consecutive failing attempts
    lat_s: float = 0.0       # slow_io: injected latency


def canon_key(key: Any) -> str:
    """Canonical per-key identity for draw counters: tuple store keys
    map to ``ctx:idx``; path-level ops use the file's basename (stable
    across the temp dir) minus any ``.tmp`` suffix."""
    if isinstance(key, tuple):
        return ":".join(str(k) for k in key)
    s = os.path.basename(str(key))
    return s[:-4] if s.endswith(".tmp") else s


class FaultRegistry:
    """Process-global injection state.  Inactive (no plan installed and
    disk not forced full) ⇒ every hook is a cheap no-op."""

    def __init__(self):
        self._lock = witness_lock("faults.registry")
        self._specs: Tuple[FaultSpec, ...] = ()
        self._seed = 0
        self._ops: Dict[Tuple[str, str], int] = {}
        self._transient: Dict[Tuple[str, str], int] = {}  # remaining fails
        self._persistent: Set[str] = set()                # keys gone bad
        self._disk_full = False
        self.injected: Dict[str, int] = {}

    # -- lifecycle ----------------------------------------------------- #
    def install(self, specs, seed: int):
        """Install a plan, resetting ALL draw state so same-seed runs
        replay identically."""
        for s in specs:
            if s.kind not in KINDS:
                raise ValueError(f"unknown fault kind {s.kind!r}")
            for site in s.sites:
                if site not in SITES:
                    raise ValueError(f"unknown fault site {site!r}")
        with self._lock:
            self._specs = tuple(specs)
            self._seed = int(seed)
            self._ops.clear()
            self._transient.clear()
            self._persistent.clear()
            self._disk_full = False
            self.injected = {}

    def clear(self):
        self.install((), 0)

    @property
    def active(self) -> bool:
        return bool(self._specs) or self._disk_full

    def set_disk_full(self, on: bool):
        """Force ENOSPC on every write (scenario disk-full windows)."""
        with self._lock:
            self._disk_full = bool(on)

    @property
    def disk_full(self) -> bool:
        return self._disk_full

    def counters(self) -> Dict[str, Any]:
        with self._lock:
            inj = dict(self.injected)
        return {"injected": inj, "injected_total": sum(inj.values())}

    # -- draws ---------------------------------------------------------- #
    def _u(self, kind: str, site: str, keystr: str, n: int) -> float:
        h = hashlib.blake2b(
            f"{self._seed}|{kind}|{site}|{keystr}|{n}".encode(),
            digest_size=8).digest()
        return int.from_bytes(h, "big") / float(1 << 64)

    @requires_lock("_lock")
    def _count(self, kind: str):
        self.injected[kind] = self.injected.get(kind, 0) + 1

    def check(self, site: str, key: Any):
        """Failpoint: called at the top of one storage/pool operation.
        Raises the drawn fault (after any slow-IO sleep) or returns."""
        if not self.active:
            return
        keystr = canon_key(key)
        sleep_s = 0.0
        err: Optional[Exception] = None
        with self._lock:
            n = self._ops.get((site, keystr), 0)
            self._ops[(site, keystr)] = n + 1
            if self._disk_full and site in _WRITE_SITES:
                self._count("enospc")
                err = DiskFullError(f"disk full: {site} {keystr}")
            for spec in self._specs:
                if err is not None:
                    break
                if site not in spec.sites:
                    continue
                if spec.kind == "slow_io":
                    if self._u("slow_io", site, keystr, n) < spec.rate:
                        self._count("slow_io")
                        sleep_s += spec.lat_s
                elif spec.kind == "transient_eio":
                    left = self._transient.get((site, keystr), 0)
                    if left > 0:
                        self._transient[(site, keystr)] = left - 1
                        self._count("transient_eio")
                        err = TransientIOError(
                            f"transient EIO: {site} {keystr}")
                    elif self._u("transient_eio", site, keystr,
                                 n) < spec.rate:
                        self._transient[(site, keystr)] = spec.fail_n - 1
                        self._count("transient_eio")
                        err = TransientIOError(
                            f"transient EIO: {site} {keystr}")
                elif spec.kind == "persistent_eio":
                    if keystr in self._persistent:
                        self._count("persistent_eio")
                        err = PersistentIOError(
                            f"persistent EIO: {site} {keystr}")
                    elif self._u("persistent_eio", site, keystr,
                                 n) < spec.rate:
                        self._persistent.add(keystr)
                        self._count("persistent_eio")
                        err = PersistentIOError(
                            f"persistent EIO: {site} {keystr}")
                elif spec.kind == "enospc" and site in _WRITE_SITES:
                    if self._u("enospc", site, keystr, n) < spec.rate:
                        self._count("enospc")
                        err = DiskFullError(f"ENOSPC: {site} {keystr}")
        if sleep_s:
            time.sleep(sleep_s)
        if err is not None:
            raise err

    def corrupt_action(self, key: Any) -> Optional[str]:
        """Post-write corruption draw: ``"torn"`` | ``"bit_flip"`` |
        None.  Separate counter stream from ``check`` so adding
        corruption faults never perturbs error draws."""
        if not self._specs:
            return None
        keystr = canon_key(key)
        with self._lock:
            n = self._ops.get(("corrupt", keystr), 0)
            self._ops[("corrupt", keystr)] = n + 1
            for spec in self._specs:
                if spec.kind == "torn_write" and \
                        self._u("torn_write", "corrupt", keystr,
                                n) < spec.rate:
                    self._count("torn_write")
                    return "torn"
                if spec.kind == "bit_flip" and \
                        self._u("bit_flip", "corrupt", keystr,
                                n) < spec.rate:
                    self._count("bit_flip")
                    return "bit_flip"
        return None

    def note_write_ok(self, key: Any):
        """A successful rewrite replaces the bad disk copy: clear any
        persistent mark so the new file is readable."""
        if not self._specs:
            return
        with self._lock:
            self._persistent.discard(canon_key(key))


FAULTS = FaultRegistry()


def install_faults(specs, seed: int):
    FAULTS.install(specs, seed)


def clear_faults():
    FAULTS.clear()


def set_disk_full(on: bool):
    FAULTS.set_disk_full(on)


def fault_counters() -> Dict[str, Any]:
    return FAULTS.counters()


def corrupt_file(path: str, action: str):
    """Apply a drawn corruption to a file on disk (used on the temp
    file just before the atomic replace, and by tests directly)."""
    size = os.path.getsize(path)
    if action == "torn":
        with open(path, "r+b") as f:
            f.truncate(max(1, size // 2))
    elif action == "bit_flip":
        # flip a byte in the back half: past the preamble, inside the
        # checksummed region, position derived from the size so it is
        # deterministic
        pos = size // 2 + size % 7
        with open(path, "r+b") as f:
            f.seek(min(pos, size - 1))
            b = f.read(1)
            f.seek(min(pos, size - 1))
            f.write(bytes([b[0] ^ 0x40]))
    else:
        raise ValueError(f"unknown corruption {action!r}")


# --------------------------------------------------------------------- #
# retry/backoff classification (recovery ladder step 1, DESIGN.md §6)
# --------------------------------------------------------------------- #
def retryable(err: BaseException) -> bool:
    """Transient-vs-terminal classification for the retry loop.

    Corrupt bytes re-read identically ⇒ not retryable; ENOSPC retries
    cannot free space ⇒ not retryable (degrade instead); a missing file
    stays missing ⇒ not retryable.  Everything else OSError (EIO et
    al.) is worth the bounded budget — persistent EIO simply exhausts
    it and falls through to recompute."""
    if isinstance(err, (ChunkCorruptError, FileNotFoundError)):
        return False
    if isinstance(err, OSError):
        return err.errno != errno.ENOSPC
    return False


def with_retries(fn: Callable[[], Any], attempts: int = 3,
                 base_s: float = 0.002,
                 on_retry: Optional[Callable[[int, BaseException],
                                             None]] = None) -> Any:
    """Run ``fn`` with bounded exponential backoff on retryable errors."""
    k = 0
    while True:
        try:
            return fn()
        except Exception as e:
            if k + 1 >= attempts or not retryable(e):
                raise
            if on_retry is not None:
                on_retry(k, e)
            time.sleep(base_s * (2 ** k))
            k += 1


# --------------------------------------------------------------------- #
# scenario-config -> plan
# --------------------------------------------------------------------- #
_RATE_KEYS = ("transient_eio", "persistent_eio", "enospc", "torn_write",
              "bit_flip", "slow_io", "pool_admit")
_META_KEYS = ("seed", "fail_n", "slow_io_s", "disk_full_windows",
              "swap_deadline_s")


def plan_from_config(cfg: Mapping[str, Any],
                     default_seed: int) -> Tuple[List[FaultSpec], int]:
    """Build (specs, seed) from a scenario ``faults`` mapping.  Keys are
    per-kind rates plus ``fail_n``/``slow_io_s``/``seed``; unknown keys
    fail loudly (same contract as the spec loader)."""
    unknown = set(cfg) - set(_RATE_KEYS) - set(_META_KEYS)
    if unknown:
        raise ValueError(f"unknown fault config keys: {sorted(unknown)}")
    fail_n = int(cfg.get("fail_n", 1))
    lat = float(cfg.get("slow_io_s", 0.001))
    specs: List[FaultSpec] = []
    for kind in ("transient_eio", "persistent_eio", "slow_io"):
        rate = float(cfg.get(kind, 0.0))
        if rate > 0:
            specs.append(FaultSpec(kind=kind, sites=_IO_SITES, rate=rate,
                                   fail_n=fail_n, lat_s=lat))
    if float(cfg.get("enospc", 0.0)) > 0:
        specs.append(FaultSpec(kind="enospc", sites=_WRITE_SITES,
                               rate=float(cfg["enospc"])))
    for kind in ("torn_write", "bit_flip"):
        rate = float(cfg.get(kind, 0.0))
        if rate > 0:
            specs.append(FaultSpec(kind=kind, sites=_WRITE_SITES,
                                   rate=rate))
    if float(cfg.get("pool_admit", 0.0)) > 0:
        specs.append(FaultSpec(kind="transient_eio", sites=("pool.admit",),
                               rate=float(cfg["pool_admit"]),
                               fail_n=fail_n))
    seed = cfg.get("seed")
    return specs, int(default_seed if seed is None else seed)
