"""Context store — per-app persistent context state (paper Fig. 4).

Layer 2 of the four-layer design (DESIGN.md §1): owns the ``Context``
records (resident text, chunk metadata, compressed payloads, attention
density accounting) and their lifecycle bookkeeping against the memory
manager and the disk store.  It never runs the model; condense hands
the surviving token tail back to the caller for re-encoding.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import numpy as np

from repro.core.chunks import ChunkMeta, CompressedChunk, QuantResidentChunk
from repro.core.lifecycle import MemoryManager
from repro.core.swap import DiskStore
from repro.analysis.markers import requires_serialized


@dataclass
class LLMCtxStub:
    """Table 1: the opaque handle apps hold."""
    ctx_id: int


@dataclass
class Context:
    cid: int
    tokens: np.ndarray                      # resident text (paper Fig. 4)
    n_tokens: int = 0
    chunks: Dict[int, ChunkMeta] = field(default_factory=dict)
    payload: Dict[int, CompressedChunk] = field(default_factory=dict)
    # decode-grid memo of packed 4/2-bit payloads (quant-resident tier):
    # the unpack+re-grid to int8 runs once per re-encode, not per
    # switch-in.  Charged at the PACKED payload size — the decodable
    # int8 form is bookkept as if unpacked on the fly (DESIGN.md §2) —
    # and dropped with the payload on evict/condense.
    qmemo: Dict[int, "QuantResidentChunk"] = field(default_factory=dict)
    whole: Optional[Dict[str, np.ndarray]] = None   # non-chunked policies
    whole_tokens: int = 0
    # positions whose KV was never computed: each call's final emitted
    # token is appended to the text but the decode budget ends before
    # its KV round, so the canonical payload stores ZERO rows there.
    # Recompute-based fault recovery (DESIGN.md §6) must zero these
    # rows to reproduce the payload bytes exactly.
    kv_holes: set = field(default_factory=set)
    alive: bool = True                      # lmk: killed => False
    density_sum: Optional[np.ndarray] = None
    density_cnt: Optional[np.ndarray] = None
    # resume bookkeeping: count of in-flight GenerationStates (begun but
    # not finished — includes slice-preempted, swapped-out generations).
    # A busy context cannot be deleted: a suspended stream will switch
    # its state back in to keep decoding.
    busy: int = 0


class ContextStore:
    """Registry of contexts + chunk/payload/density bookkeeping."""

    def __init__(self, mem: MemoryManager, store: DiskStore, s_work: int,
                 cid_alloc: Optional[Callable[[], int]] = None):
        self.mem = mem
        self.store = store
        self.s_work = s_work
        self.contexts: Dict[int, Context] = {}
        self._next_cid = 0
        # multi-executor zoo (DESIGN.md §4): stores sharing one DiskStore
        # must not collide on cid, so the ZooService injects one shared
        # allocator; standalone stores keep the private counter.
        self._cid_alloc = cid_alloc

    @requires_serialized
    def create(self) -> Context:
        if self._cid_alloc is not None:
            cid = self._cid_alloc()
        else:
            cid = self._next_cid
            self._next_cid += 1
        ctx = Context(
            cid=cid, tokens=np.zeros(self.s_work, np.int32),
            density_sum=np.zeros(self.s_work, np.float64),
            density_cnt=np.zeros(self.s_work, np.float64))
        self.contexts[cid] = ctx
        return ctx

    def get(self, cid: int) -> Context:
        return self.contexts[cid]

    @requires_serialized
    def delete(self, cid: int) -> Optional[Context]:
        """Drop a context and release every byte it holds (mem + disk).
        Refuses while a generation is in flight (possibly suspended) on
        it — resume would otherwise decode into freed state."""
        ctx = self.contexts.get(cid)
        if ctx is None:
            return None
        if ctx.busy:
            raise RuntimeError(
                f"ctx {cid} has {ctx.busy} in-flight generation(s); "
                "cancel the stream(s) before delLLMCtx")
        self.contexts.pop(cid)
        for idx in list(ctx.chunks):
            self.mem.unregister((ctx.cid, idx))
            self.store.delete((ctx.cid, idx))
        self.mem.unregister((ctx.cid, -1))
        self.store.delete((ctx.cid, -1))
        return ctx

    def acc_density(self, ctx: Context, mass: np.ndarray, n_visible: int):
        """Eq. 1 accumulation: attention mass per position + visit counts."""
        ctx.density_sum[:len(mass)] += mass
        ctx.density_cnt[:n_visible] += 1

    @requires_serialized
    def reset_for_condense(self, ctx: Context, keep: int, cs: int
                           ) -> np.ndarray:
        """Context overflow (paper §4 streaming): release all chunk state
        and return the most recent ``keep`` tokens (chunk-aligned) for the
        caller to re-encode at positions [0, keep)."""
        keep = max(cs, min((keep // cs) * cs, (ctx.n_tokens // cs) * cs))
        tail = ctx.tokens[ctx.n_tokens - keep:ctx.n_tokens].copy()
        for idx in list(ctx.chunks):
            self.mem.unregister((ctx.cid, idx))
            self.store.delete((ctx.cid, idx))
        self.mem.unregister((ctx.cid, -1))
        ctx.chunks.clear()
        ctx.payload.clear()
        ctx.qmemo.clear()
        ctx.kv_holes.clear()
        ctx.whole = None
        ctx.tokens[:] = 0
        ctx.n_tokens = 0
        ctx.density_sum[:] = 0
        ctx.density_cnt[:] = 0
        return tail
