"""Residency engine — switch-in/switch-out of context state (paper §3).

Layer 3 of the four-layer design (DESIGN.md §1): decides where every
chunk lives (bf16 working cache / compressed DRAM / disk) and moves it.
Switch-in plans the I/O-vs-recompute split (Eq. 4), dispatches the
layer-pipelined restore (Fig. 8), and assembles resident chunks into
one working-cache SLOT.  Switch-out runs tolerance-aware compression
(Eq. 1-3) and ahead-of-time swap-out (§3.4).  Eviction implements the
Reclaim primitive over the LCTRU order.

The paper prototype's working-set lock (one resident context) is
generalized to a ``SlotAllocator`` over ``decode_batch`` slots: up to B
contexts hold bf16 slot caches simultaneously and decode as one batch,
while the LCTRU queue and the compressed-chunk byte budget stay GLOBAL
across slots — eviction pressure from one slot's restore can reclaim
any context's chunks.  Preempting a generation evicts ONE slot (its
context switches out through the same compress/AoT path), not the
whole engine.

Built on ``lifecycle`` (eviction order + budget), ``swap`` (async disk
tier), and ``restore`` (segmented chunk files + LayerFeed); runs the
model only through the ``ModelExecutor``.
"""
from __future__ import annotations

import errno
import math
import os
import time
from collections import OrderedDict
from concurrent.futures import Future
from concurrent.futures import TimeoutError as _FutTimeout
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.markers import requires_serialized
from repro.analysis.runtime import witness_lock
from repro.core import compression as comp
from repro.core.chunks import ChunkMeta, CompressedChunk, QuantResidentChunk
from repro.core.context_store import Context, ContextStore
from repro.core.executor import ModelExecutor
from repro.core.faults import (FAULTS, ChunkCorruptError, DiskFullError,
                               SwapTimeoutError, with_retries)
from repro.core.lifecycle import LCTRUQueue, MemoryManager
from repro.core.pagepool import BF16, QUANT, PagePool
from repro.core.pipeline import PipelineProfile, fit_linear, plan_split
from repro.core.restore import (LayerFeed, read_chunk_file,
                                verify_chunk_file, write_chunk_file)
from repro.core.swap import AsyncSwapper, DiskStore


class SlotAllocator:
    """The working-set "lock" generalized to B decode slots.

    Each slot holds one context's bf16 working cache.  A slot is HELD
    while a generation is resident on it (between switch-in and
    switch-out/suspend); switching out PARKS the slot — the cache stays
    resident, keyed by context id, so an immediate resume or follow-up
    call on the same context reuses it with zero restore (the old
    single-entry ``_active`` fast path, now one per slot).  Acquiring a
    slot when none is free reclaims the least-recently-parked idle slot
    (its cached state is dropped — the context's chunks are already
    committed, so nothing is lost).  Holding more than B slots is a
    scheduler bug and raises."""

    def __init__(self, n_slots: int):
        self.n_slots = max(1, int(n_slots))
        self._free = list(range(self.n_slots - 1, -1, -1))
        self.held: Dict[int, int] = {}                   # cid -> slot
        self.idle: "OrderedDict[int, int]" = OrderedDict()  # cid -> slot, LRU

    def acquire(self, cid: int,
                on_evict: Optional[Callable[[int], None]] = None) -> int:
        """Claim a slot for ``cid``: its own parked slot if one exists,
        else a free slot, else the LRU parked slot (``on_evict`` is told
        which context lost its cached state)."""
        assert cid not in self.held, f"ctx {cid} already holds a slot"
        if cid in self.idle:
            slot = self.idle.pop(cid)
        elif self._free:
            slot = self._free.pop()
        elif self.idle:
            victim, slot = self.idle.popitem(last=False)
            if on_evict is not None:
                on_evict(victim)
        else:
            raise RuntimeError(
                f"all {self.n_slots} decode slots are held by in-flight "
                "generations; suspend one before switching another in")
        self.held[cid] = slot
        return slot

    def park(self, cid: int):
        """held -> idle: the generation switched out but its slot cache
        stays resident for exact reuse (MRU end of the idle order)."""
        self.idle[cid] = self.held.pop(cid)

    def release(self, cid: int):
        """Give the slot back entirely (context deleted / state reset)."""
        slot = self.held.pop(cid, None)
        if slot is None:
            slot = self.idle.pop(cid, None)
        if slot is not None:
            self._free.append(slot)


class ResidencyEngine:
    """Restore planning + chunk assembly + compress/AoT swap-out."""

    def __init__(self, exe: ModelExecutor, ctxs: ContextStore,
                 store: DiskStore, swapper: AsyncSwapper,
                 queue: LCTRUQueue, mem: MemoryManager, cfg):
        self.exe = exe
        self.ctxs = ctxs
        self.store = store
        self.swapper = swapper
        self.queue = queue
        self.mem = mem
        self.cfg = cfg
        self.slots = SlotAllocator(exe.decode_slots)
        # paged KV pool: per-context page tables replace slot-cache
        # ownership for dense families (see core/pagepool.py).  With
        # pool_persist (default) a context's pages SURVIVE switch-out —
        # the next switch-in is a page-table read; pool_persist=False is
        # the slot-like A/B baseline (pages freed at swap-out, every
        # switch-in re-admits).
        self.pool: Optional[PagePool] = (
            PagePool(exe, ctxs) if exe.paged else None)
        self.pool_persist = True
        self.profile = PipelineProfile()
        self.profiled = False
        self.epoch = 0                      # bumped on any eviction
        # multi-family routing hook (core/zoo.py): when several engines
        # share one MemoryManager/LCTRUQueue, a reclaim started by one
        # member may pick a victim chunk owned by another.  Keys whose
        # context is unknown HERE are forwarded to the owner through
        # this callable instead of being silently dropped.
        self.route_evict: Optional[Callable[[Tuple[int, int]], None]] = None
        # contexts that may hold dirty (unflushed) chunks: the §3.4
        # prediction hook flushes ONLY these instead of scanning every
        # context (the scan was O(total contexts) per completed call —
        # quadratic over a trace, and the top profile line at the scale
        # harness's 10^4 contexts).  Maintained at the single site that
        # marks chunks dirty; stale entries are dropped lazily.
        self._dirty_cids: set = set()
        # A/B control for the quant-resident tier: with the flag set,
        # switch-in MATERIALIZES every quant payload into the bf16 slot
        # (full-dequant baseline) instead of scattering codes behind the
        # fused kernel.  Payload creation is unaffected, so the two legs
        # decode from identical quantized representations — the
        # token-identity contract benchmarks/tests rely on.
        self.force_dequant = False
        # -- fault tolerance (DESIGN.md §6) ---------------------------- #
        # recovery ladder: retry (AsyncSwapper) -> recompute (here) ->
        # degrade (ENOSPC) -> fail.  While degraded, AoT swap-out is off
        # and eviction DROPS dirty payloads instead of persisting them;
        # a periodic probe write exits the mode once space returns.
        # degraded-mode flags and recovery counters are written from
        # BOTH the dispatcher and the swapper's IO threads (terminal
        # job failures land via on_job_error): every write goes through
        # _flags_lock.  Reads of the two mode FLAGS stay lock-free by
        # design (monotonic-latch pattern — see the shared-state
        # allowlist in repro/analysis/config.py).
        self._flags_lock = witness_lock("residency.flags")
        self.aot_enabled = True
        self.degraded = False
        self.degraded_entries = 0
        self.degraded_exits = 0
        self._degrade_ticks = 0
        self.chunks_recovered_recompute = 0
        self.chunks_corrupt_detected = 0
        self.io_errors_detected = 0
        self.evict_dropped = 0
        self.recover_failed = 0
        swapper.on_job_error = self._on_io_error

    # ------------------------------------------------------------------ #
    # failure detection + degraded mode (DESIGN.md §6)
    # ------------------------------------------------------------------ #
    @property
    def _deadline(self) -> Optional[float]:
        """Per-swap watchdog deadline (None = wait forever)."""
        return getattr(self.cfg, "swap_deadline_s", None)

    def _fut_result(self, fut: Future):
        """Future wait under the watchdog: a wedged swap surfaces as
        SwapTimeoutError (which the router turns into a preemption)
        instead of blocking the engine forever."""
        try:
            return fut.result(self._deadline)
        except _FutTimeout:
            raise SwapTimeoutError(
                f"swap read exceeded {self._deadline}s") from None

    def _note_read_failure(self, err: BaseException):
        with self._flags_lock:
            if isinstance(err, ChunkCorruptError):
                self.chunks_corrupt_detected += 1
            else:
                self.io_errors_detected += 1

    def _on_io_error(self, key, err: BaseException):
        """AsyncSwapper terminal-failure callback (runs on an I/O
        thread).  ENOSPC flips degraded mode immediately; every other
        failed job is recovered lazily — the next read of the key
        retries and then recomputes."""
        if isinstance(err, OSError) and err.errno == errno.ENOSPC:
            self._enter_degraded()

    def _enter_degraded(self):
        with self._flags_lock:
            if not self.degraded:
                self.degraded = True
                self.aot_enabled = False
                self.degraded_entries += 1
                self._degrade_ticks = 0

    @requires_serialized
    def degraded_tick(self):
        """Deterministic disk-space probe: every 4th switch-out while
        degraded, attempt a tiny write.  Success means space returned —
        re-enable AoT and flush what accumulated dirty in the interim.
        Tick-count based (not wall clock) so virtual-clock scenario runs
        replay identically."""
        with self._flags_lock:
            if not self.degraded:
                return
            self._degrade_ticks += 1
            if self._degrade_ticks % 4:
                return
        # probe OUTSIDE _flags_lock: the write is real (blocking) disk
        # IO and must not stall an IO thread reporting a failure
        probe = (-3, "probe")
        try:
            self.store.write(probe, b"ok")
            self.store.delete(probe)
        except OSError:
            return
        with self._flags_lock:
            self.degraded = False
            self.aot_enabled = True
            self.degraded_exits += 1
        if self.cfg.use_disk and self.cfg.chunked:
            for cid in sorted(self._dirty_cids):
                ctx = self.ctxs.contexts.get(cid)
                if ctx is not None:
                    self.flush_dirty(ctx)

    def fault_stats(self) -> Dict[str, Any]:
        c = FAULTS.counters()
        return {
            "degraded_mode": int(self.degraded),
            "degraded_entries": self.degraded_entries,
            "degraded_exits": self.degraded_exits,
            "chunks_recovered_recompute": self.chunks_recovered_recompute,
            "chunks_corrupt_detected": self.chunks_corrupt_detected,
            "io_errors_detected": self.io_errors_detected,
            "evict_dropped": self.evict_dropped,
            "recover_failed": self.recover_failed,
            "io_retries": self.swapper.io_retries,
            "io_recovered": self.swapper.io_recovered,
            "io_failed_jobs": self.swapper.io_failed,
            "tmp_files_swept": self.store.tmp_swept,
            "delete_errors": self.store.delete_errors,
            "faults_injected_total": c["injected_total"],
            "faults_injected": c["injected"],
        }

    # ------------------------------------------------------------------ #
    # switch-in: restore every chunk to memory (Load primitive)
    # ------------------------------------------------------------------ #
    @requires_serialized
    def switch_in(self, ctx: Context):
        """-> (cache, switch_seconds).  Missing-chunk restore (reclaim +
        I/O + recompute) is the timed QoS path; resident-chunk assembly
        into the bf16 working cache is not (see LLMService.callLLM)."""
        exe = self.exe
        if self.pool is not None:
            return self._switch_in_paged(ctx)
        cache = exe.fresh_cache(ctx.n_tokens)
        if ctx.n_tokens == 0:
            return cache, 0.0
        if not self.cfg.chunked or not exe.chunked_cache:
            # whole-state families (constant-size recurrent caches)
            # degenerate to snapshot/restore regardless of policy
            return self._restore_whole_timed(ctx, cache)

        # ---- assembly of resident chunks (inference-side cost) -------- #
        # quant mode: compressed chunks go BEHIND the fused kernel —
        # decode-grid payloads scatter their codes verbatim (a pure
        # memcpy, the QUANT_RESIDENT no-op switch-in), packed 4/2-bit
        # payloads unpack + re-grid to int8; only bf16-raw (16-bit)
        # chunks still materialize in the bf16 window
        quant_mode = self.exe.quant_resident and not self.force_dequant
        by_bits: Dict[int, List[int]] = {}
        q_idxs: List[int] = []
        for i, m in sorted(ctx.chunks.items()):
            if m.in_memory:
                if quant_mode and m.bits != 16:
                    q_idxs.append(i)
                else:
                    by_bits.setdefault(m.bits, []).append(i)
                self.queue.touch((ctx.cid, i), m.bits)
                m.last_access = time.time()
        # slot-path quant assembly (paged_pool=False only; the pool
        # admits quant pages once instead): scatter each decode-grid
        # payload's codes + scales behind the fused kernel, re-gridding
        # packed 4/2-bit payloads to int8 via the qmemo
        if q_idxs:
            codec = exe.codec
            head_dims = {n: exe.work_cache[n].shape[-1]
                         for n in codec.leaves}
            codes = {n: [] for n in codec.leaves}
            scales = {n: [] for n in codec.leaves}
            for i in q_idxs:
                cc = ctx.payload[i]
                if not isinstance(cc, QuantResidentChunk):
                    cc = ctx.qmemo.get(i)
                    if cc is None:      # re-grid once per (re-)encode
                        cc = codec.quantize_resident_blocks(
                            self._payload_blocks(ctx.payload[i]), head_dims)
                        ctx.qmemo[i] = cc
                for n in codec.leaves:
                    codes[n].append(cc.data[n][0])
                    scales[n].append(cc.data[n][1])
            pos = exe.chunk_positions(q_idxs)
            pos_b = exe.bucket_pad(pos, exe.pad_slot)
            pad = len(pos_b) - len(pos)

            def assemble(parts):
                # payloads are host numpy: concatenate + pad on the host
                # and ship ONE array per leaf, ONE scatter for the whole
                # quant tier (per-chunk dispatches would dominate the
                # QoS path, and jnp.concatenate would compile a kernel
                # per (chunk-count, pad) combination)
                out = np.concatenate([np.asarray(p) for p in parts])
                if pad:
                    out = np.concatenate(
                        [out, np.zeros((pad,) + out.shape[1:], out.dtype)])
                return jnp.asarray(out)

            cache = exe.scatter_quant_fn(
                cache, jnp.asarray(pos_b),
                {n: assemble(codes[n]) for n in codec.leaves},
                {n: assemble(scales[n]) for n in codec.leaves})
        for bits, idxs in by_bits.items():
            # decode each payload once, not once per leaf
            chunk_blocks = [self._payload_blocks(ctx.payload[i])
                            for i in idxs]
            blocks = {name: jnp.concatenate(
                [cb[name] for cb in chunk_blocks])
                for name in exe.codec.leaves}
            pos = exe.chunk_positions(idxs)
            pos_b = exe.bucket_pad(pos, exe.pad_slot)
            if len(pos_b) != len(pos):
                pad = len(pos_b) - len(pos)
                blocks = {k: jnp.concatenate(
                    [v, jnp.zeros((pad,) + v.shape[1:], v.dtype)])
                    for k, v in blocks.items()}
            cache = exe.scatter_fn(cache, jnp.asarray(pos_b), blocks)
        jax.block_until_ready(cache[exe.codec.leaves[0]])

        # ---- timed: reclaim + restore of missing chunks ---------------- #
        t0 = time.perf_counter()
        missing = sorted(i for i, m in ctx.chunks.items() if not m.in_memory)
        need = sum(ctx.chunks[i].nbytes for i in missing)
        self.mem.reclaim(need, self.evict, locked={ctx.cid})
        if missing:
            re_idx, io_idx = self._plan_restore(ctx, missing)
            cache = self._restore_chunks(ctx, cache, re_idx, io_idx)
            jax.block_until_ready(cache[exe.codec.leaves[0]])
        return cache, time.perf_counter() - t0

    # ------------------------------------------------------------------ #
    # paged switch-in: a page-table read plus first-admission faults
    # ------------------------------------------------------------------ #
    @requires_serialized
    def _switch_in_paged(self, ctx: Context) -> Tuple[None, float]:
        """Pool-mode switch-in.  Chunks whose pages survive from a
        previous residency cost NOTHING (their table entries are read at
        decode time); in-memory chunks without pages are admitted once
        (the page fault — ``codec``-layout payload -> page arena);
        missing chunks are restored from disk first (the timed QoS
        path).  Returns (None, t): there is no per-slot cache — the
        decode entry gathers straight from the pool."""
        exe, pool = self.exe, self.pool
        pool.table(ctx.cid)
        pool.touch(ctx.cid)
        if ctx.n_tokens == 0:
            return None, 0.0
        quant_mode = exe.quant_resident and not self.force_dequant

        # ---- untimed: resident chunks (table read / first admission) -- #
        admitted = 0
        for i, m in sorted(ctx.chunks.items()):
            if m.in_memory:
                if pool.kind(ctx.cid, i) == 0:
                    self._admit_chunk(ctx, i, quant_mode)
                    admitted += 1
                else:
                    pool.pt_switch_ins += 1
                self.queue.touch((ctx.cid, i), m.bits)
                m.last_access = time.time()
        pool.admit_switch_ins += admitted

        # ---- timed: reclaim + disk restore of missing chunks ---------- #
        t0 = time.perf_counter()
        missing = sorted(i for i, m in ctx.chunks.items() if not m.in_memory)
        if missing:
            need = sum(ctx.chunks[i].nbytes for i in missing)
            self.mem.reclaim(need, self.evict, locked={ctx.cid})
            # I/O-first restore: eviction normally persists a chunk
            # before it leaves memory, so the payload bytes exist on
            # disk — except after a storage fault (failed write, corrupt
            # file, degraded-mode drop), where the recovery ladder
            # recomputes the chunk from its tokens in ascending order
            # (each recompute attends the already-restored prefix).
            # The layer-pipelined recompute stays a slot-mode feature.
            futs = {i: self._read_chunk_async((ctx.cid, i))
                    for i in missing if ctx.chunks[i].on_disk}
            for i in missing:
                cc = None
                if i in futs:
                    try:
                        cc = self._fut_result(futs[i])
                    except SwapTimeoutError:
                        raise
                    except (ChunkCorruptError, OSError) as err:
                        self._note_read_failure(err)
                if cc is not None:
                    self._mark_loaded(ctx, i, payload=cc)
                    # a surviving page (evicted-while-busy chunk) already
                    # holds exactly this payload's values — skip the admit
                    if pool.kind(ctx.cid, i) == 0:
                        self._admit_chunk(ctx, i, quant_mode)
                else:
                    self._recover_chunk_paged(ctx, i, quant_mode)
        if admitted or missing:
            jax.block_until_ready(
                pool.arenas[exe.codec.leaves[0] + "16"])
        return None, time.perf_counter() - t0

    def _admit_chunk(self, ctx: Context, i: int, quant_mode: bool):
        """Page-fault one in-memory chunk into the pool.  Full
        decode-grid chunks take a QUANT page (codes + scales attended in
        place); everything else — bf16-raw, packed 4/2-bit, and partial
        tail chunks — dequantizes into a BF16 page.  The dequant math is
        the same fused-select arithmetic, so both kinds yield the exact
        values the slot path would attend."""
        exe, pool, codec = self.exe, self.pool, self.exe.codec
        m = ctx.chunks[i]
        cc = ctx.payload[i]
        if quant_mode and m.bits != 16 and m.n_covered == exe.cs:
            qc = cc
            if not isinstance(qc, QuantResidentChunk):
                qc = ctx.qmemo.get(i)
                if qc is None:
                    head_dims = {n: exe.work_cache[n].shape[-1]
                                 for n in codec.leaves}
                    qc = codec.quantize_resident_blocks(
                        self._payload_blocks(cc), head_dims)
                    ctx.qmemo[i] = qc
            page = pool.alloc8(ctx.cid, i)
            pool.arenas = exe.admit8_fn(
                pool.arenas, page,
                {n: jnp.asarray(qc.data[n][0]) for n in codec.leaves},
                {n: jnp.asarray(qc.data[n][1]) for n in codec.leaves})
        else:
            blocks = self._payload_blocks(cc)
            page = pool.alloc16(ctx.cid, i)
            pool.arenas = exe.admit16_fn(pool.arenas, page, blocks)
        pool.page_faults += 1

    def ensure_extend_range(self, ctx: Context, c_lo: int, c_hi: int):
        """Give chunks [c_lo, c_hi] writable bf16 pages ahead of a paged
        prefill-append.  Fresh tail chunks get pages straight off the
        free list (their garbage is never attended until written);
        anything already admitted as a quant page is converted back to
        bf16 — append must be able to write into the chunk."""
        pool = self.pool
        for ci in range(c_lo, c_hi + 1):
            k = pool.kind(ctx.cid, ci)
            if k == BF16:
                continue
            if k == QUANT or ci in ctx.payload:
                blocks = self._payload_blocks(ctx.payload[ci])
                pool.free_chunk(ctx.cid, ci)
                page = pool.alloc16(ctx.cid, ci)
                pool.arenas = self.exe.admit16_fn(pool.arenas, page, blocks)
                pool.page_faults += 1
            else:
                self._alloc_fresh16(ctx.cid, ci)

    def ensure_tail(self, ctx: Context, ci: int):
        """Give the decode tail chunk a writable bf16 page."""
        if self.pool.kind(ctx.cid, ci) == 0:
            self._alloc_fresh16(ctx.cid, ci)

    def _alloc_fresh16(self, cid: int, ci: int):
        """Allocate AND zero a fresh bf16 page: recycled pages hold
        their previous owner's data, but the slot path's never-written
        positions are exactly zero — and some of them are attended (and
        encoded at swap-out), so both paths must agree there."""
        page = self.pool.alloc16(cid, ci)
        self.pool.arenas = self.exe.zero16_fn(self.pool.arenas, page)

    # -- recompute-based recovery (ladder step 2, DESIGN.md §6) -------- #
    @staticmethod
    def _hole_segments(ctx: Context, lo: int, hi: int
                       ) -> List[Tuple[int, int]]:
        """Token ranges of [lo, hi) between KV holes.  Hole positions
        (each call's final emitted token) were never fed through the
        model, so recompute must skip them — their KV rows stay zero,
        exactly what the canonical payload stores."""
        segs, a = [], lo
        for h in sorted(x for x in ctx.kv_holes if lo <= x < hi):
            if h > a:
                segs.append((a, h))
            a = h + 1
        if hi > a:
            segs.append((a, hi))
        return segs

    def _recompute_blocks_paged(self, ctx: Context, i: int):
        """Recompute chunk ``i``'s KV into a fresh zeroed bf16 page from
        the context's resident tokens (paper §3.3: a KV chunk is always
        recomputable) and read it back as (cs, F) blocks.  Requires
        every earlier chunk's page to be resident — callers restore in
        ascending chunk order, so the prefix is always attended."""
        exe, pool = self.exe, self.pool
        m = ctx.chunks[i]
        cs = exe.cs
        lo = i * cs
        covered = m.n_covered or min(ctx.n_tokens - lo, cs)
        if pool.kind(ctx.cid, i) != 0:
            pool.free_chunk(ctx.cid, i)
        self._alloc_fresh16(ctx.cid, i)
        pt16, pt8, qmask = pool.rows([ctx.cid])
        for a, b in self._hole_segments(ctx, lo, lo + covered):
            toks = np.asarray(ctx.tokens[a:b], np.int32)
            pool.arenas, _, _ = exe.paged_extend(pool.arenas, toks, a,
                                                 pt16, pt8, qmask)
        page = int(pool._tables[ctx.cid]["p16"][i])
        return exe.read16_fn(pool.arenas, page)

    @requires_serialized
    def _recover_chunk_paged(self, ctx: Context, i: int, quant_mode: bool):
        """The disk copy is missing/corrupt/unreadable after retries:
        recompute the chunk from tokens, re-encode it at its assigned
        level, re-admit FROM THE PAYLOAD (so decode attends exactly the
        payload-roundtrip values a disk restore would have given), and
        rewrite the repaired payload to disk unless degraded."""
        if not self.exe.recomputable:
            with self._flags_lock:
                self.recover_failed += 1
            raise ChunkCorruptError(
                f"ctx {ctx.cid} chunk {i}: disk copy unreadable and "
                f"family {self.exe.model.cfg.family!r} cannot recompute")
        m = ctx.chunks[i]
        if self.pool.kind(ctx.cid, i) == BF16:
            # the page survived the eviction (busy context): it holds
            # the authoritative values — rebuild the payload from it
            # instead of recomputing
            page = int(self.pool._tables[ctx.cid]["p16"][i])
            blocks = self.exe.read16_fn(self.pool.arenas, page)
        else:
            blocks = self._recompute_blocks_paged(ctx, i)
        want_quant = self.exe.quant_resident and m.bits == 8
        cc = self._encode_blocks(blocks, m.bits, quant=want_quant)
        ctx.payload[i] = cc
        ctx.qmemo.pop(i, None)
        m.quant = want_quant
        m.nbytes = cc.nbytes
        m.in_memory = True
        # drop the raw recompute page and re-admit from the payload —
        # same drop-on-encode rule as swap-out (re-encoding is lossy for
        # quantized tiers; for 16-bit storage the roundtrip is exact)
        self.pool.free_chunk(ctx.cid, i)
        if (self.cfg.use_disk and self.aot_enabled
                and self._write_chunk_async(ctx.cid, i, cc)):
            m.dirty, m.on_disk = False, True
        else:
            m.dirty, m.on_disk = True, False
            self._dirty_cids.add(ctx.cid)
        self.mem.register((ctx.cid, i), m.nbytes, m.bits)
        self._admit_chunk(ctx, i, quant_mode)
        with self._flags_lock:
            self.chunks_recovered_recompute += 1

    def _plan_restore(self, ctx, missing: List[int]
                      ) -> Tuple[List[int], List[int]]:
        if not (self.cfg.use_pipeline and self.exe.recomputable):
            return [], missing
        plan_in = [(i, ctx.chunks[i].nbytes, True) for i in missing]
        if self.profiled:
            re_idx, io_idx, _ = plan_split(plan_in, self.profile, True)
        else:   # unprofiled fallback: split heaviest half to recompute
            order = sorted(missing, key=lambda i: -ctx.chunks[i].nbytes)
            re_idx = order[:len(order) // 2]
            io_idx = [i for i in missing if i not in set(re_idx)]
        return sorted(re_idx), sorted(io_idx)

    @requires_serialized
    def _restore_chunks(self, ctx: Context, cache, re_idx: List[int],
                        io_idx: List[int]):
        """Fig. 8 restore.  dense + recompute-set: per-layer pipelined scan;
        otherwise: async whole-chunk reads (+ recompute second phase).

        Fault recovery (DESIGN.md §6): chunks whose disk copy is
        missing/corrupt/unreadable after retries are DEMOTED to the
        recompute set instead of failing the call — a chunk is always
        recomputable from the context's tokens (paper §3.3)."""
        exe = self.exe
        quant_mode = exe.quant_resident and not self.force_dequant
        recovered: List[int] = []            # unreadable -> recomputed
        pending_io = list(io_idx)
        did_recompute = False
        use_pipe = (bool(re_idx) and exe.spec.pipelined_restore)
        if use_pipe:
            # pre-validate the feed's files: the scan reads them deep
            # inside jax io_callbacks where a corrupt file aborts the
            # whole restore — route guaranteed-bad chunks to recompute
            ok_io: List[int] = []
            for i in pending_io:
                if not ctx.chunks[i].on_disk:    # degraded-mode drop
                    recovered.append(i)
                    continue
                try:
                    self.swapper.wait((ctx.cid, i), timeout=self._deadline)
                    verify_chunk_file(self.store._path((ctx.cid, i)))
                    ok_io.append(i)
                except SwapTimeoutError:
                    raise
                except (ChunkCorruptError, OSError) as err:
                    self._note_read_failure(err)
                    recovered.append(i)
            re_all = sorted(set(re_idx) | set(recovered))
            try:
                cache = self._restore_pipelined(ctx, cache, re_all, ok_io)
                for i in ok_io:
                    self._mark_loaded(ctx, i, payload=None)
                pending_io = []
                did_recompute = True
            except SwapTimeoutError:
                raise
            except Exception as err:
                # passed header validation but failed mid-feed (e.g. a
                # flipped byte inside a layer segment): fall back to
                # whole-file reads, which verify per-layer CRCs up front
                self._note_read_failure(err)
                pending_io = ok_io
        if pending_io:
            # async whole-chunk reads, insert as they land
            futs = {i: self._read_chunk_async((ctx.cid, i))
                    for i in pending_io if ctx.chunks[i].on_disk}
            for i in pending_io:
                cc = None
                if i in futs:
                    try:
                        cc = self._fut_result(futs[i])
                    except SwapTimeoutError:
                        raise
                    except (ChunkCorruptError, OSError) as err:
                        self._note_read_failure(err)
                if cc is None:
                    if i not in recovered:
                        recovered.append(i)
                    continue
                if quant_mode and isinstance(cc, QuantResidentChunk):
                    # decode-grid bytes go straight back behind the
                    # fused kernel — the read IS the restore
                    pos = jnp.asarray(exe.chunk_positions([i]))
                    cache = exe.scatter_quant_fn(
                        cache, pos,
                        {n: jnp.asarray(cc.data[n][0])
                         for n in exe.codec.leaves},
                        {n: jnp.asarray(cc.data[n][1])
                         for n in exe.codec.leaves})
                else:
                    cache = exe.insert_fn(cache, jnp.int32(i * exe.cs),
                                          self._payload_blocks(cc))
                self._mark_loaded(ctx, i, payload=cc)

        re_all = sorted(set(re_idx) | set(recovered))
        if re_all and not did_recompute:
            if recovered and not exe.recomputable:
                with self._flags_lock:
                    self.recover_failed += 1
                raise ChunkCorruptError(
                    f"ctx {ctx.cid} chunks {recovered}: disk copies "
                    f"unreadable and family "
                    f"{exe.model.cfg.family!r} cannot recompute")
            # second phase (exact: I/O chunks now resident)
            miss_pos = self._feed_positions(ctx, re_all)
            miss_b = exe.bucket_pad(miss_pos, exe.pad_slot)
            toks_b = exe.bucket_pad(ctx.tokens[miss_pos], 0)
            cache, _, _ = exe.extend_nod_fn(
                exe.params, jnp.asarray(toks_b)[None],
                jnp.asarray(miss_b), cache, jnp.int32(ctx.n_tokens))

        # recomputed chunks: re-encode each payload at its assigned level
        rec = set(recovered)
        for i in re_all:
            m = ctx.chunks[i]
            want_quant = self.exe.quant_resident and m.bits == 8
            ctx.payload[i] = self._make_payload(cache, i, m.bits,
                                                quant=want_quant)
            ctx.qmemo.pop(i, None)
            m.quant = want_quant
            m.in_memory = True
            if i in rec:
                m.nbytes = ctx.payload[i].nbytes
                # rewrite the repaired chunk so the next restore is a
                # plain read again (unless writes are failing: leave it
                # dirty for the post-degraded flush)
                if (self.cfg.use_disk and self.aot_enabled
                        and self._write_chunk_async(ctx.cid, i,
                                                    ctx.payload[i])):
                    m.dirty, m.on_disk = False, True
                else:
                    m.dirty, m.on_disk = True, False
                    self._dirty_cids.add(ctx.cid)
                with self._flags_lock:
                    self.chunks_recovered_recompute += 1
            else:
                m.dirty = False               # already on disk
            self.mem.register((ctx.cid, i), m.nbytes, m.bits)
        return cache

    def _restore_pipelined(self, ctx: Context, cache, re_idx: List[int],
                           io_idx: List[int]):
        """The Fig. 8 layer-pipelined scan over a validated I/O set."""
        exe = self.exe
        nio_b = next(x for x in exe.io_buckets
                     if x >= max(len(io_idx), 1))
        pad_chunks = nio_b - len(io_idx)
        io_pos_b = np.concatenate(
            [exe.chunk_positions(io_idx),
             np.full(pad_chunks * exe.cs, exe.pad_slot, np.int32)])
        paths = [self.store._path((ctx.cid, i)) for i in io_idx]
        feed = LayerFeed(paths, exe.codec.leaves, exe.n_layers,
                         exe.cs, exe.leaf_dims, pad_chunks=pad_chunks,
                         pool=self.swapper.pool)
        miss_pos = self._feed_positions(ctx, re_idx)
        miss_b = exe.bucket_pad(miss_pos, exe.pad_slot)
        toks_b = exe.bucket_pad(ctx.tokens[miss_pos], 0)
        try:
            out = exe.run_pipelined(feed, toks_b, miss_b, io_pos_b,
                                    cache, ctx.n_tokens)
            jax.block_until_ready(out[exe.codec.leaves[0]])
        except BaseException:
            feed.close(raise_errors=False)
            raise
        feed.close()
        return out

    def _feed_positions(self, ctx: Context, idxs: List[int]) -> np.ndarray:
        """Chunk positions to FEED through recompute: every position of
        the given chunks except KV holes (each call's final emitted
        token) — the original timeline never ran those through the
        model, so their cache rows stay zero, exactly what the canonical
        payload stores (see ``_make_payload_paged``)."""
        pos = self.exe.chunk_positions(idxs)
        if not ctx.kv_holes:
            return pos
        keep = np.asarray([p for p in pos if int(p) not in ctx.kv_holes],
                          np.int32)
        return keep if len(keep) else pos[:0]

    def _read_chunk_async(self, key):
        """Read a chunk file on the I/O pool, ORDERED AFTER any
        in-flight same-key AoT write: ``flush_dirty`` marks ``on_disk``
        when it SUBMITS the write, so reading the path directly races
        the writer's ``os.replace`` (FileNotFoundError under load)."""
        return self.swapper.submit(key, read_chunk_file,
                                   self.store._path(key))

    def _read_chunk(self, key):
        """Synchronous chunk-file read; blocks the caller on any
        in-flight same-key write first (see ``_read_chunk_async``),
        bounded by the watchdog deadline, with the worker retry budget
        for transient IO errors."""
        self.swapper.wait(key, timeout=self._deadline)

        def _on_retry(_k, _e):
            self.swapper.note_retry()

        return with_retries(lambda: read_chunk_file(self.store._path(key)),
                            attempts=self.swapper.retries,
                            base_s=self.swapper.retry_base_s,
                            on_retry=_on_retry)

    @requires_serialized
    def _mark_loaded(self, ctx, i: int, payload):
        if payload is None:
            payload = self._read_chunk((ctx.cid, i))
        ctx.payload[i] = payload
        ctx.qmemo.pop(i, None)
        m = ctx.chunks[i]
        m.in_memory, m.dirty = True, False
        m.quant = isinstance(payload, QuantResidentChunk)
        self.mem.register((ctx.cid, i), m.nbytes, m.bits)

    # -- whole-context policies (swap / lmk) ----------------------------- #
    @requires_serialized
    def _restore_whole_timed(self, ctx: Context, cache):
        exe = self.exe
        t_switch = 0.0
        if ctx.whole is None and self.cfg.use_disk and \
                self.store.nbytes((ctx.cid, -1)):
            t0 = time.perf_counter()
            self.mem.reclaim(self.store.nbytes((ctx.cid, -1)) or 0,
                             self.evict, locked={ctx.cid})
            try:
                ctx.whole = self.swapper.read((ctx.cid, -1),
                                              timeout=self._deadline)
                t_switch = time.perf_counter() - t0
                ctx.whole_tokens = ctx.n_tokens
                self.mem.register((ctx.cid, -1),
                                  self._whole_bytes(ctx), 16)
                self.queue.touch((ctx.cid, -1), 16)
            except SwapTimeoutError:
                raise
            except (ChunkCorruptError, OSError) as err:
                # unreadable whole-state file: drop the stale accounting
                # entry and fall through to the LMK recompute branch —
                # the whole context rebuilds from its resident text
                self._note_read_failure(err)
                self.store.drop_bytes((ctx.cid, -1))
                with self._flags_lock:
                    self.chunks_recovered_recompute += 1
        if ctx.whole is not None:
            pass                                       # resident
        else:
            # LMK: killed — recompute the whole context from its text
            t0 = time.perf_counter()
            self.mem.reclaim(0, self.evict, locked={ctx.cid})
            pos = np.arange(ctx.n_tokens, dtype=np.int32)
            if exe.pad_safe:
                pos_b = exe.bucket_pad(pos, exe.pad_slot)
                toks_b = exe.bucket_pad(ctx.tokens[:ctx.n_tokens], 0)
            else:
                # recurrent carry: pads would fold into the state
                pos_b, toks_b = pos, ctx.tokens[:ctx.n_tokens]
            cache, _, dens = exe.extend_fn(
                exe.params, jnp.asarray(toks_b)[None], jnp.asarray(pos_b),
                exe.setpos_fn(cache, jnp.int32(0)), jnp.int32(ctx.n_tokens))
            jax.block_until_ready(cache[exe.codec.leaves[0]])
            t_switch = time.perf_counter() - t0
            self.ctxs.acc_density(ctx, np.asarray(dens[0], np.float64),
                                  ctx.n_tokens)
            ctx.whole = self._extract_whole(cache, ctx.n_tokens)
            ctx.whole_tokens = ctx.n_tokens
            ctx.alive = True
            self.mem.register((ctx.cid, -1), self._whole_bytes(ctx), 16)
            return (exe.setpos_fn(cache, jnp.int32(ctx.n_tokens)), t_switch)
        blocks = {k: jnp.asarray(v) for k, v in ctx.whole.items()}
        cache = exe.insert_fn(cache, jnp.int32(0), blocks)
        self.queue.touch((ctx.cid, -1), 16)
        return exe.setpos_fn(cache, jnp.int32(ctx.n_tokens)), t_switch

    def _extract_whole(self, cache, n_tokens: int) -> Dict[str, np.ndarray]:
        hi = self.exe.bucket_len(max(n_tokens, 1))
        out = {}
        for k, v in self.exe.codec.extract(cache, 0, hi).items():
            # 16-bit floats snapshot as fp16; fp32 state stays exact —
            # rwkv6's wkv recurrence is fp32 by design, and halving it
            # would perturb every continued decode
            dt = np.float32 if v.dtype == jnp.float32 else np.float16
            out[k] = np.asarray(v, dt)
        return out

    def _whole_bytes(self, ctx) -> int:
        return sum(v.nbytes for v in (ctx.whole or {}).values())

    # -- payload codecs ------------------------------------------------- #
    def _payload_blocks(self, cc) -> Dict[str, jax.Array]:
        if isinstance(cc, QuantResidentChunk):
            return self.exe.codec.dequantize_resident(cc)
        if cc.bits == 16:
            return {k: jnp.asarray(p).astype(jnp.bfloat16)
                    for k, (p, _) in cc.data.items()}
        return self.exe.codec.decompress(cc)

    def _encode_blocks(self, blocks, bits: int, quant: bool):
        """(T, F) blocks -> payload: decode-grid QuantResidentChunk when
        ``quant``, else the storage codec at ``bits``."""
        codec = self.exe.codec
        if quant:
            head_dims = {n: self.exe.work_cache[n].shape[-1]
                         for n in codec.leaves}
            return codec.quantize_resident_blocks(blocks, head_dims)
        if bits == 16:
            return CompressedChunk(
                bits=16, n_tokens=next(iter(blocks.values())).shape[0],
                data={k: (np.asarray(v, np.float16), np.zeros(0, np.float32))
                      for k, v in blocks.items()},
                shapes={k: tuple(v.shape) for k, v in blocks.items()})
        return codec.compress_blocks(blocks, bits)

    def _make_payload(self, cache, i: int, bits: int, quant: bool = False):
        """Encode chunk i from the slot cache.  A mixed cache is read
        through ``extract_mixed`` — its bf16 array is stale at
        quant-resident positions."""
        cs = self.exe.cs
        lo, hi = i * cs, (i + 1) * cs
        codec = self.exe.codec
        blocks = (codec.extract_mixed(cache, lo, hi)
                  if self.exe.quant_resident
                  else codec.extract(cache, lo, hi))
        return self._encode_blocks(blocks, bits, quant)

    def _make_payload_paged(self, ctx: Context, i: int, bits: int,
                            quant: bool = False):
        """Encode chunk i from the pool.  A bf16 page is read back
        through the jitted page reader; a quant page (or an unadmitted
        chunk) re-encodes from its existing payload — the page holds
        exactly the payload's codes, so nothing is lost."""
        exe, pool = self.exe, self.pool
        if pool.kind(ctx.cid, i) == BF16:
            page = int(pool._tables[ctx.cid]["p16"][i])
            blocks = exe.read16_fn(pool.arenas, page)
        else:
            cc = ctx.payload.get(i)
            if cc is not None:
                blocks = self._payload_blocks(cc)
            elif ctx.chunks[i].on_disk:
                # evicted out from under a busy context by another
                # context's reclaim — eviction wrote it to disk first
                # (possibly asynchronously, via an earlier AoT flush)
                blocks = self._payload_blocks(
                    self._read_chunk((ctx.cid, i)))
            else:
                # the chunk was never written at all: its only tokens
                # are emitted-but-never-decoded (the call's final token
                # has no decode round).  The slot path encodes the zero
                # cache here — match it exactly.
                blocks = {n: jnp.zeros(
                    (exe.cs, int(np.prod(
                        [s for a, s in enumerate(exe.leaf_shapes[n])
                         if a != 2]))), jnp.bfloat16)
                    for n in exe.codec.leaves}
        return self._encode_blocks(blocks, bits, quant)

    # ------------------------------------------------------------------ #
    # compress + AoT swap-out (Reclaim is then free)
    # ------------------------------------------------------------------ #
    @requires_serialized
    def compress_and_swap_out(self, ctx: Context, cache):
        cfg = self.cfg
        if not cfg.chunked or not self.exe.chunked_cache:
            ctx.whole = self._extract_whole(cache, ctx.n_tokens)
            ctx.whole_tokens = ctx.n_tokens
            self.mem.register((ctx.cid, -1), self._whole_bytes(ctx), 16)
            return

        cs = self.exe.cs
        n_chunks = math.ceil(ctx.n_tokens / cs)
        if cfg.compression == "tolerance":
            D = comp.chunk_density(ctx.density_sum, ctx.density_cnt,
                                   ctx.n_tokens, cs)
            bits = comp.plan_buckets(D, cfg.ratio_global, cfg.levels)
        elif cfg.compression == "static8":
            D = np.zeros(n_chunks)
            bits = np.full(n_chunks, 8, np.int64)
        else:
            D = np.zeros(n_chunks)
            bits = np.full(n_chunks, 16, np.int64)
        # the family's Eq.-3 floor: MLA latents / VLM image chunks carry
        # no cross-head redundancy, so the planner never drops them
        # below KVSpec.min_bits however low their measured density
        bits = np.maximum(bits, self.exe.spec.min_bits)

        for i in range(n_chunks):
            m = ctx.chunks.get(i)
            if m is None:
                m = ChunkMeta(idx=i)
                ctx.chunks[i] = m
            want = int(bits[i])
            # §3.2 Eq. 3 bucket -> residency representation: in quant
            # mode an 8-bit chunk is PROMOTED to the decode grid (its
            # payload becomes directly decodable; switch-in degenerates
            # to a memcpy); 4/2-bit chunks keep the packed storage
            # codec — still charged at packed size — and are re-gridded
            # behind the fused kernel at assembly time
            want_quant = self.exe.quant_resident and want == 8
            m.density = float(D[i])
            covered = min(ctx.n_tokens - i * cs, cs)
            if (m.dirty or want != m.bits or i not in ctx.payload
                    or covered != m.n_covered or m.quant != want_quant):
                if self.pool is not None:
                    try:
                        cc = self._make_payload_paged(ctx, i, want,
                                                      quant=want_quant)
                    except (ChunkCorruptError, OSError) as err:
                        # the encode needed the chunk's disk copy (busy-
                        # evicted, no page) and it is unreadable.  The
                        # prefix may be paged out here, so recompute is
                        # not safe mid-swap-out — leave the chunk
                        # MISSING; the next switch-in recovers it with
                        # the prefix resident (recovery ladder §6)
                        self._note_read_failure(err)
                        m.bits, m.n_covered = want, covered
                        m.density = float(D[i])
                        m.quant = want_quant
                        m.dirty, m.in_memory, m.on_disk = \
                            False, False, False
                        ctx.payload.pop(i, None)
                        ctx.qmemo.pop(i, None)
                        self.pool.free_chunk(ctx.cid, i)
                        self.mem.unregister((ctx.cid, i))
                        continue
                    # drop-on-encode: the page now disagrees with the
                    # canonical payload (re-encoding is lossy), so free
                    # it — the next switch-in re-admits from the payload
                    # and attends exactly what the slot path would
                    self.pool.free_chunk(ctx.cid, i)
                else:
                    cc = self._make_payload(cache, i, want,
                                            quant=want_quant)
                ctx.payload[i] = cc
                ctx.qmemo.pop(i, None)
                m.bits, m.nbytes, m.n_covered = want, cc.nbytes, covered
                m.quant = want_quant
                m.dirty, m.in_memory, m.on_disk = True, True, False
                self._dirty_cids.add(ctx.cid)
                # AoT re-admit (§3.4 spirit, like the qmemo re-grid
                # below): pay the page write NOW, at switch-out, so the
                # next switch-in is a pure page-table read — of exactly
                # the payload-roundtrip values the slot path would
                # scatter.  Best-effort: an exhausted pool just leaves
                # the chunk paged-out for a later switch-in fault.
                if (self.pool is not None and self.pool_persist
                        and not self.force_dequant):
                    try:
                        self._admit_chunk(ctx, i, self.exe.quant_resident)
                    except RuntimeError:
                        pass
            # AoT re-grid (§3.4 spirit): a packed 4/2-bit chunk whose
            # payload was just (re-)encoded gets its decode-grid memo
            # built NOW, at switch-out, so the next switch-in stays a
            # pure scatter.  Built from the packed payload (not the raw
            # cache) so assembly sees identical codes before and after
            # an eviction/restore round trip.
            if (self.exe.quant_resident and not m.quant and m.bits != 16
                    and i not in ctx.qmemo and i in ctx.payload):
                ctx.qmemo[i] = self.exe.codec.quantize_resident_blocks(
                    self._payload_blocks(ctx.payload[i]),
                    {n: self.exe.work_cache[n].shape[-1]
                     for n in self.exe.codec.leaves})
            self.mem.register((ctx.cid, i), m.nbytes, m.bits)
            m.last_access = time.time()

        # pool_persist=False (and the force_dequant control): behave
        # like the slot path — pages die with the residency, so every
        # switch-in pays the full re-admission
        if self.pool is not None and not (self.pool_persist
                                          and not self.force_dequant):
            self.pool.free_ctx(ctx.cid)

        if cfg.use_aot and cfg.use_disk:
            self.flush_dirty(ctx)
        self.degraded_tick()

    @requires_serialized
    def flush_dirty(self, ctx: Context) -> int:
        """AoT swap-out (§3.4): asynchronously write every dirty chunk so a
        later Reclaim is free.  Also the scheduler's prediction hook: when
        the router predicts a context switch, the outgoing contexts get
        flushed ahead of the memory pressure.  Returns chunks submitted.
        Disabled while degraded — writes are failing; chunks stay dirty
        and the post-degraded flush catches them up."""
        if not self.aot_enabled:
            return 0
        n = 0
        for i, m in ctx.chunks.items():
            if m.dirty and i in ctx.payload:
                if not self._write_chunk_async(ctx.cid, i, ctx.payload[i]):
                    break               # disk full: stop, chunks stay dirty
                m.dirty, m.on_disk = False, True
                n += 1
        if not any(m.dirty for m in ctx.chunks.values()):
            self._dirty_cids.discard(ctx.cid)
        return n

    @requires_serialized
    def prepare_switch(self, predicted_cid: int) -> int:
        """Next-context prediction hint (scheduler -> §3.4 AoT swap-out):
        protect the predicted context's resident chunks in the LCTRU order
        and flush dirty chunks of every OTHER context ahead of time.
        Returns the number of chunks flushed."""
        pred = self.ctxs.contexts.get(predicted_cid)
        if pred is not None:
            for i, m in pred.chunks.items():
                if m.in_memory:
                    self.queue.touch((pred.cid, i), m.bits)
            if pred.whole is not None:
                self.queue.touch((pred.cid, -1), 16)
        if not (self.cfg.use_disk and self.cfg.chunked):
            return 0
        flushed = 0
        # only contexts that can actually hold dirty chunks — NOT a scan
        # over every context (that was quadratic over a long trace)
        for cid in sorted(self._dirty_cids):
            if cid == predicted_cid:
                continue
            ctx = self.ctxs.contexts.get(cid)
            if ctx is None:                     # deleted since marked
                self._dirty_cids.discard(cid)
                continue
            flushed += self.flush_dirty(ctx)
        return flushed

    def _write_chunk_async(self, cid: int, idx: int,
                           cc: CompressedChunk) -> bool:
        """Submit an AoT chunk write; False when the disk is full (the
        chunk must stay dirty).  A full filesystem fails ``write()``
        immediately, so ENOSPC surfaces HERE on the submitting
        (dispatcher) thread — degraded-mode entry is then deterministic
        under the loadgen virtual clock instead of landing at whatever
        wall instant an IO worker would report it."""
        key = (cid, idx)
        if FAULTS.disk_full:
            self.swapper.note_io_failure()
            self._on_io_error(key, DiskFullError(
                f"disk full (write {key})"))
            return False
        path = self.store._path(key)

        def work():
            n = write_chunk_file(path, cc, self.exe.n_layers)
            self.store.set_bytes(key, n)
        self.swapper.submit(key, work)
        return True

    # ------------------------------------------------------------------ #
    # eviction (Reclaim primitive)
    # ------------------------------------------------------------------ #
    @requires_serialized
    def evict(self, key):
        cid, idx = key
        if self.route_evict is not None and cid not in self.ctxs.contexts:
            # shared-budget reclaim picked another family's chunk: hand
            # the key to its owning engine (which bumps ITS epoch)
            self.route_evict(key)
            return
        self.epoch += 1
        ctx = self.ctxs.contexts.get(cid)
        if ctx is None:
            return
        if idx == -1:
            if self.cfg.use_disk and ctx.whole is not None:
                try:                                     # sync: paper's
                    self.store.write((cid, -1), ctx.whole)  # reclaim-
                except OSError as err:                   # time cost
                    # can't persist: degrade on ENOSPC and drop — an
                    # older on-disk copy covers fewer tokens, so the
                    # accounting entry must go too (the next restore
                    # then recomputes from text, LMK-style)
                    if getattr(err, "errno", None) == errno.ENOSPC:
                        self._enter_degraded()
                    self.evict_dropped += 1
                    self.store.drop_bytes((cid, -1))
            ctx.whole = None
            ctx.alive = False
            return
        m = ctx.chunks.get(idx)
        if m is None:
            return
        if m.dirty:                         # no-AoT policies pay here (sync)
            ok = False
            if not self.degraded:           # degraded: every write fails
                try:
                    n = with_retries(
                        lambda: write_chunk_file(self.store._path(key),
                                                 ctx.payload[idx],
                                                 self.exe.n_layers),
                        attempts=self.swapper.retries,
                        base_s=self.swapper.retry_base_s)
                    self.store.set_bytes(key, n)
                    ok = True
                except OSError as err:
                    if getattr(err, "errno", None) == errno.ENOSPC:
                        self._enter_degraded()
            if not ok:
                # recovery ladder: the chunk stays recomputable from
                # tokens, so eviction must not wedge the reclaim path —
                # drop the payload and let the next switch-in recompute
                self.evict_dropped += 1
                m.dirty, m.on_disk, m.in_memory = False, False, False
                ctx.payload.pop(idx, None)
                ctx.qmemo.pop(idx, None)
                if self.pool is not None and not ctx.busy:
                    self.pool.free_chunk(cid, idx)
                return
            m.dirty = False
        m.on_disk, m.in_memory = True, False
        ctx.payload.pop(idx, None)
        ctx.qmemo.pop(idx, None)
        # free the chunk's pool pages too — unless the context is mid-
        # generation: a busy context's pages are its authoritative state
        # (the payload just written covers only the last swap-out), and
        # its own swap-out will re-encode + drop them
        if self.pool is not None and not ctx.busy:
            self.pool.free_chunk(cid, idx)

    # ------------------------------------------------------------------ #
    @requires_serialized
    def profile_pipeline(self, n_points: Tuple[int, ...] = (1, 2, 4)):
        """Paper §3.3.i: one-shot installation-time profiling of T_re/T_IO."""
        exe = self.exe
        if not (exe.recomputable and exe.chunked_cache):
            return          # pipeline planning is a chunk-restore notion
        toks = np.ones(exe.n_slots, np.int32)
        cache = exe.fresh_cache(0)
        xs, ts = [], []
        for x in n_points:
            M = x * exe.cs
            pos_b = exe.bucket_pad(np.arange(M, dtype=np.int32),
                                   exe.pad_slot)
            toks_b = exe.bucket_pad(toks[:M], 0)
            args = (exe.params, jnp.asarray(toks_b)[None],
                    jnp.asarray(pos_b), cache, jnp.int32(M))
            out = exe.extend_nod_fn(*args)               # compile
            jax.block_until_ready(out[0][exe.codec.leaves[0]])
            t0 = time.perf_counter()
            out = exe.extend_nod_fn(*args)
            jax.block_until_ready(out[0][exe.codec.leaves[0]])
            ts.append(time.perf_counter() - t0)
            xs.append(x)
        self.profile.re_base, self.profile.re_per_chunk = fit_linear(xs, ts)

        cc = self._make_payload(exe.work_cache, 0, 8)
        ios_x, ios_t = [], []
        for n in (1, 2, 4):
            paths = [self.store._path((-2, f"probe{j}")) for j in range(n)]
            for p in paths:
                write_chunk_file(p, cc, exe.n_layers)
            t0 = time.perf_counter()
            for p in paths:
                read_chunk_file(p)
            ios_t.append(time.perf_counter() - t0)
            ios_x.append(n * cc.nbytes)
            for p in paths:
                os.remove(p)
        self.profile.io_base, self.profile.io_per_byte = \
            fit_linear(ios_x, ios_t)
        self.profiled = True
