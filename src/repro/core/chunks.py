"""Chunked view of a context's KV cache (paper §3.1, Fig. 4).

A chunk covers ``chunk_tokens`` consecutive tokens ACROSS ALL LAYERS
(the paper's layout).  The codec canonicalizes each family's
sequence-indexed cache leaves into (T, F) blocks — T chunk tokens,
F = flattened (layers x heads x channels) — which is the layout the
quantizer (kernels/ref.py, kernels/chunk_quant.py) operates on.

Family applicability is data-driven: the codec is built from the
family's ``KVSpec.seq_leaves`` — the cache leaves that grow with the
token axis.  rwkv6 has none (constant-size state): its context
degenerates to a single state blob handled by :class:`WholeStateCodec`
(DESIGN.md §Arch-applicability).  ``SEQ_LEAVES`` remains as the legacy
family->leaves table for pre-KVSpec callers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

Array = jax.Array

# cache leaves that carry a token axis (axis AFTER the (layer, batch) dims)
SEQ_LEAVES = {
    "dense": ("k", "v"),
    "moe": ("k", "v"),
    "mla_moe": ("ckv", "kpe"),
    "vlm": ("k", "v"),            # xk/xv are image-resident (swap-only blob)
    "rglru_hybrid": ("k", "v"),   # conv/lru are snapshot state blobs
    "encdec": ("k", "v"),         # xk/xv resident
    "rwkv6": (),                  # constant-size state: no sequence leaves
}
TOKEN_AXIS = 2                     # (L, B, S, ...) for every seq leaf


@dataclass
class CompressedChunk:
    """One chunk's compressed payload: leaf -> (packed int8, scales)."""
    bits: int
    n_tokens: int
    data: Dict[str, Tuple[np.ndarray, np.ndarray]]
    shapes: Dict[str, Tuple[int, ...]]          # original leaf slice shapes

    @property
    def nbytes(self) -> int:
        return sum(p.nbytes + s.nbytes for p, s in self.data.values())


@dataclass
class QuantResidentChunk:
    """One chunk's DECODE-GRID payload: leaf -> (codes (T, F) int8,
    scales (T, F//hd) fp32), quantized per (token, kv-head) over the
    trailing head_dim — the grid the fused decode-attention kernels
    consume (kernels/decode_qattn.py), so switch-in is a pure scatter
    of these bytes into the slot's int8 segments: no dequantization.
    The per-leaf head_dim is recoverable as codes.F // scales.Fs."""
    n_tokens: int
    data: Dict[str, Tuple[np.ndarray, np.ndarray]]
    shapes: Dict[str, Tuple[int, ...]]          # (T, F) block shapes
    bits: int = 8                               # decode grid is int8

    @property
    def nbytes(self) -> int:
        return sum(p.nbytes + s.nbytes for p, s in self.data.values())


class ChunkCodec:
    """Extract / insert / (de)quantize chunks of a cache pytree."""

    def __init__(self, leaves, chunk_tokens: int = 16):
        if isinstance(leaves, str):     # legacy: family name
            leaves = SEQ_LEAVES[leaves]
        self.leaves = tuple(leaves)
        self.cs = chunk_tokens
        if not self.leaves:
            raise ValueError("cache has no sequence leaves; "
                             "use WholeStateCodec")
        # jitted per-(bits, shape) quant/dequant
        self._q = jax.jit(kops.chunk_quantize, static_argnames=("bits",))
        self._dq = jax.jit(kops.chunk_dequantize,
                           static_argnames=("bits", "n_tokens"))

        def _qth(blk, hd):
            """(T, F) block -> decode-grid (codes (T, F) int8,
            scales (T, F//hd) fp32): symmetric max-abs per (token,
            flattened (layer, kv-head)) group over head_dim."""
            from repro.kernels import ref as kref
            T, F = blk.shape
            codes, scale = kref.quantize_token_head_ref(
                blk.reshape(T, F // hd, hd))
            return codes.reshape(T, F), scale

        def _dqth(codes, scale, hd, dtype):
            from repro.kernels import ref as kref
            T, F = codes.shape
            out = kref.dequantize_token_head_ref(
                codes.reshape(T, F // hd, hd), scale, dtype)
            return out.reshape(T, F)

        self._qth = jax.jit(_qth, static_argnames=("hd",))
        self._dqth = jax.jit(_dqth, static_argnames=("hd", "dtype"))

    # -- canonical (T, F) view ------------------------------------------ #
    def extract(self, cache, lo: int, hi: int) -> Dict[str, Array]:
        """Slice tokens [lo, hi) of each seq leaf -> (T, F) arrays."""
        out = {}
        for name in self.leaves:
            a = cache[name]                        # (L, B, S, ...)
            sl = jax.lax.slice_in_dim(a, lo, hi, axis=TOKEN_AXIS)
            t = jnp.moveaxis(sl, TOKEN_AXIS, 0)    # (T, L, B, ...)
            out[name] = t.reshape(t.shape[0], -1)
        return out

    def insert(self, cache, lo: int, blocks: Dict[str, Array]):
        """Write (T, F) blocks back at token offset lo."""
        new = dict(cache)
        for name, blk in blocks.items():
            a = cache[name]
            T = blk.shape[0]
            shp = list(a.shape)
            shp[TOKEN_AXIS] = T
            t = blk.reshape([T] + [s for i, s in enumerate(shp)
                                   if i != TOKEN_AXIS])
            t = jnp.moveaxis(t, 0, TOKEN_AXIS).astype(a.dtype)
            idx = [0] * a.ndim
            idx[TOKEN_AXIS] = lo
            new[name] = jax.lax.dynamic_update_slice(a, t, tuple(idx))
        return new

    def scatter(self, cache, positions: Array, blocks: Dict[str, Array]):
        """Write (T, F) blocks at arbitrary token ``positions`` (T,)."""
        new = dict(cache)
        for name, blk in blocks.items():
            a = cache[name]
            T = blk.shape[0]
            shp = list(a.shape)
            shp[TOKEN_AXIS] = T
            t = blk.reshape([T] + [s for i, s in enumerate(shp)
                                   if i != TOKEN_AXIS])
            t = jnp.moveaxis(t, 0, TOKEN_AXIS).astype(a.dtype)
            new[name] = a.at[:, :, positions].set(t)
        return new

    # -- decode-grid (quant-resident) payloads -------------------------- #
    def quantize_resident_blocks(self, blocks: Dict[str, Array],
                                 head_dims: Dict[str, int]
                                 ) -> QuantResidentChunk:
        """(T, F) float blocks -> decode-grid payload (e.g. re-gridding a
        dequantized 4/2-bit storage chunk behind the fused kernel)."""
        data, shapes = {}, {}
        for name, blk in blocks.items():
            codes, scale = self._qth(blk, hd=head_dims[name])
            data[name] = (np.asarray(codes), np.asarray(scale))
            shapes[name] = tuple(blk.shape)
        return QuantResidentChunk(n_tokens=next(
            iter(blocks.values())).shape[0], data=data, shapes=shapes)

    def dequantize_resident(self, qc: QuantResidentChunk,
                            dtype=jnp.bfloat16) -> Dict[str, Array]:
        """Materialize a decode-grid payload as (T, F) bf16 blocks (the
        full-dequant control path; the fused kernels compute exactly
        these values inline)."""
        out = {}
        for name, (codes, scale) in qc.data.items():
            hd = codes.shape[1] // scale.shape[1]
            out[name] = self._dqth(jnp.asarray(codes), jnp.asarray(scale),
                                   hd=hd, dtype=dtype)
        return out

    def scatter_quant(self, cache, positions: Array,
                      codes: Dict[str, Array], scales: Dict[str, Array]):
        """Write decode-grid (T, F) code blocks / (T, Fs) scale blocks
        into the ``<leaf>_q`` / ``<leaf>_scale`` segments at token
        ``positions`` (T,) and raise quant_mask there.  The pure-memcpy
        switch-in of the QUANT_RESIDENT tier."""
        new = dict(cache)
        for name in codes:
            for leaf, blk in ((f"{name}_q", codes[name]),
                              (f"{name}_scale", scales[name])):
                a = cache[leaf]
                T = blk.shape[0]
                shp = list(a.shape)
                shp[TOKEN_AXIS] = T
                t = blk.reshape([T] + [s for i, s in enumerate(shp)
                                       if i != TOKEN_AXIS])
                t = jnp.moveaxis(t, 0, TOKEN_AXIS).astype(a.dtype)
                new[leaf] = a.at[:, :, positions].set(t)
        new["quant_mask"] = cache["quant_mask"].at[:, :, positions].set(True)
        return new

    def leaf_slice_shape(self, cache_shapes: Dict[str, Tuple[int, ...]],
                         name: str, T: int) -> Tuple[int, ...]:
        shp = list(cache_shapes[name])
        shp[TOKEN_AXIS] = T
        return tuple(shp)

    def extract_mixed(self, cache, lo: int, hi: int) -> Dict[str, Array]:
        """(T, F) blocks of the TRUE cache values of tokens [lo, hi):
        the bf16 window where quant_mask is clear, the fused dequant of
        the int8 segments where it is set.  The only valid re-encode
        source for a mixed cache — the bf16 array is stale at
        quant-resident positions."""
        out = self.extract(cache, lo, hi)
        if "quant_mask" not in cache:
            return out
        qm = cache["quant_mask"]                    # (1, B, S)
        assert qm.shape[1] == 1, "mixed extract expects a batch-1 slot"
        m = jax.lax.slice_in_dim(qm, lo, hi, axis=TOKEN_AXIS)
        m = m.reshape(-1)[:, None]                  # (T, 1)
        for name in self.leaves:
            hd = cache[name].shape[-1]
            cq = jnp.moveaxis(jax.lax.slice_in_dim(
                cache[f"{name}_q"], lo, hi, axis=TOKEN_AXIS), TOKEN_AXIS, 0)
            sc = jnp.moveaxis(jax.lax.slice_in_dim(
                cache[f"{name}_scale"], lo, hi, axis=TOKEN_AXIS),
                TOKEN_AXIS, 0)
            T = cq.shape[0]
            dq = (cq.reshape(T, -1, hd).astype(jnp.float32)
                  * sc.reshape(T, -1)[..., None]).astype(out[name].dtype)
            out[name] = jnp.where(m, dq.reshape(T, -1), out[name])
        return out

    # -- compression ------------------------------------------------------ #
    def compress(self, cache, lo: int, hi: int, bits: int) -> CompressedChunk:
        return self.compress_blocks(self.extract(cache, lo, hi), bits)

    def compress_blocks(self, blocks: Dict[str, Array],
                        bits: int) -> CompressedChunk:
        data, shapes = {}, {}
        for name, blk in blocks.items():
            packed, scale = self._q(blk, bits=bits)
            data[name] = (np.asarray(packed), np.asarray(scale))
            shapes[name] = blk.shape
        return CompressedChunk(bits=bits,
                               n_tokens=next(iter(blocks.values())).shape[0],
                               data=data, shapes=shapes)

    def decompress(self, cc: CompressedChunk) -> Dict[str, Array]:
        out = {}
        for name, (packed, scale) in cc.data.items():
            out[name] = self._dq(jnp.asarray(packed), jnp.asarray(scale),
                                 bits=cc.bits, n_tokens=cc.n_tokens)
        return out

    def raw_chunk_bytes(self, cc_or_shapes, bytes_per_elem: int = 2) -> int:
        """Uncompressed (bf16) footprint of a chunk with these shapes."""
        shapes = cc_or_shapes.shapes if isinstance(cc_or_shapes,
                                                   CompressedChunk) \
            else cc_or_shapes
        return sum(int(np.prod(s)) * bytes_per_elem for s in shapes.values())


class WholeStateCodec:
    """Whole-state 'chunk' codec for constant-size recurrent caches
    (``KVSpec.state_leaves`` with no ``seq_leaves``).  The context
    degenerates to a single blob: extract/insert move the full state
    regardless of the requested token range, so the layers above can
    treat the blob as one chunk covering every token.  No token
    scatter, no quant segments — those are sequence-cache notions."""

    def __init__(self, leaves, chunk_tokens: int = 16):
        self.leaves = tuple(leaves)
        self.cs = chunk_tokens
        if not self.leaves:
            raise ValueError("whole-state codec needs state leaves")

    def extract(self, cache, lo: int = 0, hi: int = 0) -> Dict[str, Array]:
        return {name: cache[name] for name in self.leaves}

    def insert(self, cache, lo, blocks: Dict[str, Array]):
        new = dict(cache)
        for name, blk in blocks.items():
            a = cache[name]
            new[name] = jnp.asarray(blk).reshape(a.shape).astype(a.dtype)
        return new

    def scatter(self, cache, positions, blocks):
        raise NotImplementedError("whole-state cache has no token scatter")

    def scatter_quant(self, cache, positions, codes, scales):
        raise NotImplementedError("whole-state cache has no quant segments")


@dataclass
class ChunkMeta:
    """Lifecycle record for one chunk (paper §3.4)."""
    idx: int
    bits: int = 16                 # 16 = uncompressed (raw bf16)
    density: float = float("inf")  # unmeasured => treated as most dense
    last_access: float = 0.0
    in_memory: bool = True
    on_disk: bool = False
    dirty: bool = True             # differs from the on-disk copy
    nbytes: int = 0
    n_covered: int = 0             # context tokens the payload encodes: a
                                   # partial chunk that grew must re-encode
                                   # even if clean (KV is append-only)
    quant: bool = False            # payload is a decode-grid
                                   # QuantResidentChunk (QUANT_RESIDENT
                                   # when in_memory: switch-in is a pure
                                   # scatter behind the fused kernel)


def chunk_ranges(n_tokens: int, cs: int) -> List[Tuple[int, int]]:
    return [(i, min(i + cs, n_tokens)) for i in range(0, n_tokens, cs)]
