"""Request/stream protocol — the service API every layer speaks.

The paper's LLMaaS premise (§2) is that foreground interactions must
not wait behind background agents, so the request path is built around
STREAMS, not return values:

  ``GenerationRequest``  what an app asks for: prompt, budget, sampling
                         (seeded, temperature/top-k — defaults reproduce
                         the old greedy ``np.argmax`` path exactly),
                         optional priority override and deadline.
  ``GenerationStream``   the handle the app holds while the service
                         decodes: iterate tokens as they land, cancel
                         mid-generation, or block on ``result()``.
                         Records TTFT / per-token timestamps — the
                         QoS numbers decode-slice scheduling improves.

``LLMService.begin_call / decode_step / finish_call`` consume a
``GenerationRequest``; ``ServiceRouter`` produces ``GenerationStream``s
and runs generations in bounded decode slices so a newly arrived
foreground request preempts an in-flight background stream
(DESIGN.md §2).  This module is dependency-free bookkeeping: no jax,
no model, importable from any layer.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Iterator, List, Optional, Sequence, Union

import numpy as np

from repro.analysis.markers import requires_lock
from repro.analysis.runtime import witness_condition

# Priorities live here (not scheduler.py) so requests can name them
# without importing the router; scheduler re-exports for compat.
FOREGROUND = 0
BACKGROUND = 1


@dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding parameters.

    Defaults (``temperature=0``) reproduce the pre-stream greedy path
    token-for-token: plain ``np.argmax`` over the logits.  With
    ``temperature > 0`` the sampler draws from the (optionally top-k
    truncated) softmax using a per-request ``np.random.default_rng(seed)``
    so a (request, seed) pair is reproducible across runs.
    """
    temperature: float = 0.0
    top_k: int = 0                       # 0 = no truncation
    seed: Optional[int] = None

    def make_sampler(self) -> Callable[[np.ndarray], int]:
        """-> callable(logits) -> token id.  Stateful iff temperature>0
        (owns the request's RNG), so build one per generation."""
        if self.temperature <= 0.0:
            return lambda logits: int(np.argmax(logits))
        rng = np.random.default_rng(self.seed)
        temp, top_k = float(self.temperature), int(self.top_k)

        def sample(logits: np.ndarray) -> int:
            x = np.asarray(logits, np.float64) / temp
            if 0 < top_k < x.size:
                kth = np.partition(x, -top_k)[-top_k]
                x = np.where(x < kth, -np.inf, x)
            x -= x.max()
            p = np.exp(x)
            p /= p.sum()
            return int(rng.choice(x.size, p=p))
        return sample


@dataclass
class GenerationRequest:
    """One generation ask.  ``priority=None`` inherits the submitting
    session's priority; ``deadline`` is an absolute ``time.perf_counter``
    instant used to order same-priority admissions (EDF, then FIFO).

    ``exclusive=True`` asks the router never to share a decode batch:
    the request runs with the engine to itself (it may wait for the
    current batch to drain first).  For latency-critical calls that
    must not see batch-mates' per-step cost."""
    prompt: Sequence[int]
    max_new_tokens: int = 16
    sampling: SamplingParams = field(default_factory=SamplingParams)
    priority: Optional[Union[int, str]] = None
    deadline: Optional[float] = None
    exclusive: bool = False


class GenerationStream:
    """Handle for one in-flight generation (producer: the router's
    dispatch; consumer: the app).  Thread-safe; tokens are observable
    as they land, so with a threaded router apps genuinely stream."""

    def __init__(self, ctx_id: int, request: GenerationRequest,
                 clock: Optional[Callable[[], float]] = None):
        # ``clock`` replaces wall time for every QoS timestamp (t_submit,
        # token times, t_done).  The loadgen virtual-clock driver injects
        # a simulation clock here so TTFT/TBT are deterministic in the
        # scenario seed; None keeps wall-clock behavior.
        self._now = clock or time.perf_counter
        self.ctx_id = ctx_id
        self.request = request
        self.t_submit = self._now()
        self.t_first_token: Optional[float] = None
        self.t_done: Optional[float] = None
        self.token_times: List[float] = []
        self.n_preempts = 0                 # times switched out mid-gen
        self._tokens: List[int] = []
        self._cv = witness_condition("requests.stream")
        self._done = False
        self._cancelled = False
        self._cancel_requested = False
        self._error: Optional[BaseException] = None

    # -- producer side (router dispatch) ------------------------------- #
    def push(self, tok: int):
        now = self._now()
        with self._cv:
            self._tokens.append(int(tok))
            self.token_times.append(now)
            if self.t_first_token is None:
                self.t_first_token = now
            self._cv.notify_all()

    @requires_lock("_cv")
    def _finish_locked(self, error: Optional[BaseException],
                       cancelled: bool):
        if self._done:
            return
        self._done = True
        self._cancelled = cancelled
        self._error = error
        self.t_done = self._now()
        self._cv.notify_all()

    def finish(self, error: Optional[BaseException] = None,
               cancelled: bool = False):
        with self._cv:
            self._finish_locked(error, cancelled)

    # -- consumer side -------------------------------------------------- #
    def cancel(self) -> bool:
        """Request cancellation.  Queued: the job never starts; running:
        decoding stops at the next slice boundary and the tokens decoded
        so far stay committed to the context.  Returns False if the
        stream had already finished."""
        with self._cv:
            self._cancel_requested = True
            return not self._done

    @property
    def cancel_requested(self) -> bool:
        with self._cv:
            return self._cancel_requested

    @property
    def done(self) -> bool:
        with self._cv:
            return self._done

    @property
    def cancelled(self) -> bool:
        with self._cv:
            return self._cancelled

    @property
    def error(self) -> Optional[BaseException]:
        with self._cv:
            return self._error

    @property
    def tokens(self) -> List[int]:
        with self._cv:
            return list(self._tokens)

    def __iter__(self) -> Iterator[int]:
        """Yield tokens in decode order, blocking until each lands;
        raises the job's error (if any) after the last token."""
        i = 0
        while True:
            with self._cv:
                while i >= len(self._tokens) and not self._done:
                    self._cv.wait()
                if i < len(self._tokens):
                    tok = self._tokens[i]
                    i += 1
                else:
                    if self._error is not None:
                        raise self._error
                    return
            yield tok

    def result(self, timeout: Optional[float] = None) -> List[int]:
        """Block until the generation finishes; -> all decoded tokens
        (a cancelled stream returns the tokens decoded before cancel)."""
        with self._cv:
            if not self._cv.wait_for(lambda: self._done, timeout):
                raise TimeoutError("generation still running")
            if self._error is not None:
                raise self._error
            return list(self._tokens)

    # -- QoS timestamps -------------------------------------------------- #
    def ttft(self) -> Optional[float]:
        """Time-to-first-token from submission (None until it lands)."""
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.t_submit

    def tbt(self) -> List[float]:
        """Inter-token gaps (time-between-tokens), len = n_tokens - 1."""
        ts = self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]
