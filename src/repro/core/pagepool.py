"""Unified paged KV pool — page tables and free lists over the arenas.

The executor owns the device arenas (one fixed ``(L, P, cs, ...)``
buffer per cache leaf and kind); this module owns everything about
WHICH page holds WHAT: per-context page tables at chunk granularity,
the free lists, the LRU reclaim order, and the occupancy/fault
counters ``LLMService.stats`` surfaces.

Two page kinds mirror the PR-4 mixed cache leaves:

  * ``BF16``  — full-precision pages (``<leaf>16`` arenas).  Working
    tails, freshly prefetched chunks, and dequantized admissions live
    here; decode writes new tokens into the context's bf16 tail page.
  * ``QUANT`` — int8 codes + per-(token, kv-head) scales
    (``<leaf>8``/``<leaf>8s`` arenas).  Full decode-grid chunks admit
    here once and are attended in place through the fused dequant
    select — switch-in never rescatters them.

Page 0 of every arena is the reserved scratch page: page-table entries
for chunks a context does not own point there, padded batch rows use
the all-zero table row, and decode's tail scatter for padded rows
lands there.  Its contents are garbage by design; the attention masks
(``k_pos < seq_len`` and the causal window) give those positions
exactly zero weight, so the garbage is unobservable.

Residency state becomes a page-table property: a chunk is
pool-resident iff its table entry is non-zero, and switching a context
in is a table read (plus first-admission faults), not a scatter.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.faults import FAULTS, with_retries

BF16 = 1
QUANT = 2


class PagePool:
    """Page tables + free lists over the executor's page arenas."""

    def __init__(self, exe, ctxs):
        self.exe = exe
        self.ctxs = ctxs
        self.cs = exe.cs
        self.pages_per_ctx = exe.pages_per_ctx
        self.arenas = exe.init_arenas()
        # page 0 reserved as scratch in both kinds; hand out low pages
        # first so tiny workloads stay in a compact prefix of the arena
        self._free16: List[int] = list(range(exe.pool_pages16 - 1, 0, -1))
        self._free8: List[int] = list(range(exe.pool_pages8 - 1, 0, -1))
        # cid -> {"p16": (C,) int32, "p8": (C,) int32, "kind": (C,) u8}
        self._tables: Dict[int, Dict[str, np.ndarray]] = {}
        # (kind, page) -> (cid, chunk-index), for debugging/invariants
        self._owner: Dict[Tuple[int, int], Tuple[int, int]] = {}
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self.page_faults = 0        # admissions (DRAM/disk -> pool pages)
        self.pt_switch_ins = 0      # chunk switch-ins = pure table reads
        self.admit_switch_ins = 0   # chunk switch-ins that paid an admit
        self.reclaims = 0           # whole-context reclaim evictions
        self.admit_fault_retries = 0   # injected pool.admit faults retried

    # -- tables -------------------------------------------------------- #
    def table(self, cid: int) -> Dict[str, np.ndarray]:
        t = self._tables.get(cid)
        if t is None:
            C = self.pages_per_ctx
            t = {"p16": np.zeros(C, np.int32),
                 "p8": np.zeros(C, np.int32),
                 "kind": np.zeros(C, np.uint8)}
            self._tables[cid] = t
        self._lru.setdefault(cid, None)
        return t

    def touch(self, cid: int) -> None:
        if cid in self._lru:
            self._lru.move_to_end(cid)

    def kind(self, cid: int, ci: int) -> int:
        t = self._tables.get(cid)
        return int(t["kind"][ci]) if t is not None else 0

    def rows(self, cids: Sequence[int]):
        """Stacked page-table rows for a decode/prefill batch:
        -> (pt16 (B, C) i32, pt8 (B, C) i32 | None, qmask (B, C) bool
        | None).  The quant row/mask are None outside quant-resident
        mode (the jitted entries specialize on their absence)."""
        ts = [self.table(c) for c in cids]
        pt16 = np.stack([t["p16"] for t in ts])
        if not self.exe.quant_resident:
            return pt16, None, None
        pt8 = np.stack([t["p8"] for t in ts])
        qmask = np.stack([t["kind"] == QUANT for t in ts])
        return pt16, pt8, qmask

    # -- allocation ---------------------------------------------------- #
    def _admit_check(self, cid: int, ci: int) -> None:
        """``pool.admit`` failpoint: admission is the pool's only
        externally-driven mutation, so transient faults injected here
        cover the whole alloc path.  Retried on the spot — the check
        runs before any table/free-list mutation, so a retry is safe —
        and only transient kinds are planned for this site, so a
        persistent draw (tests only) still propagates."""
        if not FAULTS.active:
            return
        tries = 0

        def _on_retry(_key, _err):
            nonlocal tries
            tries += 1

        with_retries(lambda: FAULTS.check("pool.admit", (cid, ci)),
                     attempts=3, base_s=0.0, on_retry=_on_retry)
        self.admit_fault_retries += tries

    def _pop(self, free: List[int], kind_name: str, for_cid: int) -> int:
        if not free:
            self._reclaim(for_cid)
        if not free:
            raise RuntimeError(
                f"paged KV pool exhausted ({kind_name}): every page is "
                "held by a busy context — raise pool_pages_16/"
                "pool_pages_8 or lower decode_batch")
        return free.pop()

    def _reclaim(self, for_cid: int) -> None:
        """Free the least-recently-used non-busy context's pages.  Busy
        contexts' pages are authoritative state (their latest tokens may
        exist nowhere else); non-busy contexts always have payloads or
        disk copies, so dropping their pages only costs re-admission.
        ``for_cid`` (the allocating context) is never a victim: during
        its own switch-in/prefill it is not yet marked busy."""
        for cid in list(self._lru):
            if cid == for_cid:
                continue
            ctx = self.ctxs.contexts.get(cid)
            if ctx is not None and ctx.busy:
                continue
            if self._table_empty(cid):
                self._lru.pop(cid, None)
                continue
            self.free_ctx(cid)
            self._lru.pop(cid, None)
            self.reclaims += 1
            return

    def _table_empty(self, cid: int) -> bool:
        t = self._tables.get(cid)
        return t is None or (not t["p16"].any() and not t["p8"].any())

    def alloc16(self, cid: int, ci: int) -> int:
        self._admit_check(cid, ci)
        t = self.table(cid)
        assert t["kind"][ci] == 0, (cid, ci, t["kind"][ci])
        page = self._pop(self._free16, "bf16", cid)
        t["p16"][ci] = page
        t["kind"][ci] = BF16
        self._owner[(BF16, page)] = (cid, ci)
        return page

    def alloc8(self, cid: int, ci: int) -> int:
        self._admit_check(cid, ci)
        t = self.table(cid)
        assert t["kind"][ci] == 0, (cid, ci, t["kind"][ci])
        page = self._pop(self._free8, "quant", cid)
        t["p8"][ci] = page
        t["kind"][ci] = QUANT
        self._owner[(QUANT, page)] = (cid, ci)
        return page

    # -- freeing ------------------------------------------------------- #
    def free_chunk(self, cid: int, ci: int) -> None:
        t = self._tables.get(cid)
        if t is None or t["kind"][ci] == 0:
            return
        if t["p16"][ci]:
            self._free16.append(int(t["p16"][ci]))
            self._owner.pop((BF16, int(t["p16"][ci])), None)
        if t["p8"][ci]:
            self._free8.append(int(t["p8"][ci]))
            self._owner.pop((QUANT, int(t["p8"][ci])), None)
        t["p16"][ci] = 0
        t["p8"][ci] = 0
        t["kind"][ci] = 0

    def free_ctx(self, cid: int) -> None:
        t = self._tables.get(cid)
        if t is None:
            return
        for ci in np.nonzero(t["kind"])[0]:
            self.free_chunk(cid, int(ci))

    def drop(self, cid: int) -> None:
        self.free_ctx(cid)
        self._tables.pop(cid, None)
        self._lru.pop(cid, None)

    # -- telemetry ----------------------------------------------------- #
    def stats(self) -> Dict[str, int]:
        return {
            "pool_pages16_total": self.exe.pool_pages16 - 1,
            "pool_pages16_used": (self.exe.pool_pages16 - 1
                                  - len(self._free16)),
            "pool_pages8_total": self.exe.pool_pages8 - 1,
            "pool_pages8_used": (self.exe.pool_pages8 - 1
                                 - len(self._free8)),
            "pool_page_faults": self.page_faults,
            "pool_pt_switch_ins": self.pt_switch_ins,
            "pool_admit_switch_ins": self.admit_switch_ins,
            "pool_reclaims": self.reclaims,
            "pool_admit_fault_retries": self.admit_fault_retries,
        }
