"""LLMS core: the paper's contribution (chunked KV compression/swapping).

Public surface (DESIGN.md §1):
  LLMService / LLMSConfig / LLMCtxStub  (paper Table 1 API, facade)
  requests.GenerationRequest / SamplingParams / GenerationStream
                                        (request/stream protocol)
  scheduler.ServiceRouter / AppSession  (decode-slice admission front-end)
  executor.ModelExecutor                (jitted entry points, layer 1)
  context_store.ContextStore            (persistent contexts, layer 2)
  residency.ResidencyEngine             (switch-in/out engine, layer 3)
  ChunkCodec / CompressedChunk          (chunk memory model, Fig. 4)
  compression.plan_buckets              (tolerance-aware planner, Eq. 3)
  pipeline.plan_split                   (swapping-recompute planner, Eq. 4)
  lifecycle.LCTRUQueue                  (eviction order, §3.4)
"""
from repro.core.requests import (  # noqa
    BACKGROUND, FOREGROUND, GenerationRequest, GenerationStream,
    SamplingParams)
from repro.core.service import LLMService, LLMSConfig, LLMCtxStub  # noqa
from repro.core.scheduler import (  # noqa
    AppSession, NextContextPredictor, ServiceRouter)
