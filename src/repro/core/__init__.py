"""LLMS core: the paper's contribution (chunked KV compression/swapping).

Public surface:
  LLMService / LLMSConfig / LLMCtxStub  (paper Table 1 API)
  ChunkCodec / CompressedChunk          (chunk memory model, Fig. 4)
  compression.plan_buckets              (tolerance-aware planner, Eq. 3)
  pipeline.plan_split                   (swapping-recompute planner, Eq. 4)
  lifecycle.LCTRUQueue                  (eviction order, §3.4)
"""
from repro.core.service import LLMService, LLMSConfig, LLMCtxStub  # noqa
