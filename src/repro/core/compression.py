"""Tolerance-aware compression (paper §3.2, Eqs. 1–3).

``chunk_density`` reduces the per-token Eq.-1 statistic (computed inside
attention — kernels/attn_density.py on TPU, the blocked-jnp path on CPU)
to per-chunk information densities.

``plan_buckets`` solves Eq. (3): assign each chunk a compression level
from ``levels`` so that the *retained* context information
``sum_w ratio_w * sum_{bucket w} D_i`` is maximized subject to the
OS-configured global average ratio ``sum_w ratio_w * |bucket w| =
ratio_global * n``.  (DESIGN.md §2 records why we maximize retained —
not 1/ratio-weighted — information: the printed Eq. 3 weight is inverted
relative to the paper's own prose.)

With the paper's default three levels {8/8, 4/8, 2/8} and ratio 1/2 the
constraint reduces to ``2*k1 + k2 = n`` over prefix counts of the
density-sorted chunks, solved exactly by an O(n) prefix-sum scan.  A
brute-force reference (`plan_buckets_brute`) exists for the property
tests.
"""
from __future__ import annotations

import itertools
from typing import Sequence, Tuple

import numpy as np

# (bits, ratio-of-baseline)
DEFAULT_LEVELS: Tuple[Tuple[int, float], ...] = ((8, 1.0), (4, 0.5), (2, 0.25))


def chunk_density(token_density: np.ndarray, token_count: np.ndarray,
                  n_tokens: int, cs: int) -> np.ndarray:
    """Per-chunk D_i from accumulated per-token (mass_sum, n_queries).

    token_density: (S,) accumulated Eq.-1 mass sums; token_count: (S,)
    number of measurement passes per token.  Unmeasured tokens get +inf
    (treated as maximally dense until measured)."""
    n_chunks = (n_tokens + cs - 1) // cs
    out = np.empty(n_chunks, np.float64)
    for i in range(n_chunks):
        lo, hi = i * cs, min((i + 1) * cs, n_tokens)
        cnt = token_count[lo:hi]
        if np.all(cnt > 0):
            out[i] = float(np.mean(token_density[lo:hi] / cnt))
        else:
            out[i] = float("inf")
    return out


def retained_info(density: np.ndarray, bits: np.ndarray,
                  levels: Sequence[Tuple[int, float]] = DEFAULT_LEVELS
                  ) -> float:
    ratio = {b: r for b, r in levels}
    fin = density[np.isfinite(density)]
    sub = (float(np.max(fin)) if fin.size else 0.0) + 1.0
    d = np.where(np.isinf(density), sub, density)
    return float(sum(ratio[int(b)] * di for b, di in zip(bits, d)))


def plan_buckets(density: np.ndarray,
                 ratio_global: float = 0.5,
                 levels: Sequence[Tuple[int, float]] = DEFAULT_LEVELS
                 ) -> np.ndarray:
    """-> per-chunk bit assignment (n,) int.  Exact for 3 levels."""
    n = len(density)
    if n == 0:
        return np.zeros(0, np.int64)
    assert len(levels) == 3, "planner expects 3 compression levels"
    (b1, r1), (b2, r2), (b3, r3) = levels
    assert r1 > r2 > r3
    # rank: densest first (inf = unmeasured counts as densest)
    order = np.argsort(-np.nan_to_num(density, posinf=np.inf))
    d_sorted = density[order]
    # unmeasured (inf) chunks substitute STRICTLY above the measured max so
    # they win high-precision slots even when measured densities tie at 0
    fin = d_sorted[np.isfinite(d_sorted)]
    sub = (float(np.max(fin)) if fin.size else 0.0) + 1.0
    d_finite = np.nan_to_num(d_sorted, posinf=sub)
    prefix = np.concatenate([[0.0], np.cumsum(d_finite)])

    best_info, best = -np.inf, None
    target = ratio_global * n
    for k1 in range(n + 1):
        # solve k2 from the ratio constraint
        denom = r2 - r3
        k2f = (target - k1 * r1 + k1 * r2 - n * r3) / denom
        for k2 in {int(np.floor(k2f)), int(np.ceil(k2f))}:
            k2 = min(max(k2, k1), n)
            ratio = (k1 * r1 + (k2 - k1) * r2 + (n - k2) * r3) / n
            if ratio > ratio_global + 1e-9:
                continue
            info = (r1 * prefix[k1] + r2 * (prefix[k2] - prefix[k1])
                    + r3 * (prefix[n] - prefix[k2]))
            if info > best_info + 1e-12:
                best_info, best = info, (k1, k2)
    assert best is not None
    k1, k2 = best
    bits_sorted = np.full(n, b3, np.int64)
    bits_sorted[:k2] = b2
    bits_sorted[:k1] = b1
    bits = np.empty(n, np.int64)
    bits[order] = bits_sorted
    return bits


def plan_buckets_brute(density: np.ndarray, ratio_global: float = 0.5,
                       levels: Sequence[Tuple[int, float]] = DEFAULT_LEVELS
                       ) -> Tuple[np.ndarray, float]:
    """Exhaustive reference for tests (n <= ~8)."""
    n = len(density)
    d = np.nan_to_num(density, posinf=(np.max(
        density[np.isfinite(density)]) if np.any(np.isfinite(density))
        else 1.0))
    best_info, best = -np.inf, None
    for combo in itertools.product(range(len(levels)), repeat=n):
        ratio = sum(levels[c][1] for c in combo) / n
        if ratio > ratio_global + 1e-9:
            continue
        info = sum(levels[c][1] * d[i] for i, c in enumerate(combo))
        if info > best_info + 1e-12:
            best_info = info
            best = np.array([levels[c][0] for c in combo], np.int64)
    return best, best_info
