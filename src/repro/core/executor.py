"""Model executor — the jitted entry points of the serving stack.

Layer 1 of the four-layer design (DESIGN.md §1): owns the bf16 working
cache — ``decode_batch`` independent slot caches, so up to B contexts
are simultaneously hot — the power-of-two bucket/padding logic that
keeps jit compilation counts bounded (token buckets for prefill, batch
buckets for the batched decode entry), and the process-wide
``_JIT_CACHE`` shared across service instances of the same
(model-fingerprint, window) so benchmark sweeps don't recompile.
Everything above (residency, scheduler) treats this layer as "run the
model on these tokens/positions"; nothing here knows about
chunks-on-disk, budgets, or apps.

Every capability decision here is driven by the family's declarative
``KVSpec`` (``model.kv_spec()``): which codec slices the cache
(``ChunkCodec`` over ``spec.seq_leaves`` vs. ``WholeStateCodec`` over
``spec.state_leaves``), whether prompts may be bucket-padded
(``spec.pad_safe`` — recurrent state folds pad tokens into the carry,
so those families extend at exact length), and the
batched/quant/paged/recompute gates.  No family string dispatch.

``extend`` (prefill) and ``decode`` (one token, one slot) are the
stepwise slot-cache entry points; when the paged KV pool is enabled
(``cfg.paged_pool`` + ``spec.paged``) the ``paged_extend``/``paged_decode``
entries run the same computations directly over the global page arenas
— per-slot page-table rows gather each context's chunks into the dense
layout inside the jitted step, so batch membership changes cost a
page-table row swap instead of the merge/split copies the old BatchRun
path paid.  ``LLMService`` drives one decode round per
``decode_step``/``decode_step_batch`` so the router can slice
generations, batch compatible contexts, and preempt between slices
(DESIGN.md §2).
"""
from __future__ import annotations

import functools
import hashlib
import math
import weakref
from collections import OrderedDict
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunks import ChunkCodec, WholeStateCodec
from repro.models.kvspec import LAYOUT_MIXED, LAYOUT_WINDOW

Array = jax.Array

# (model-fingerprint, window, n_sinks, family, chunk_tokens[, entry])
# -> jitted callables.  Shared process-wide so sweeps over policies /
# budgets reuse compilations.  Keys use a STABLE content fingerprint of
# (config, param treedef/shapes/dtypes) — never ``id(model)``: a dead
# model's id can be reused by a new object, which would silently hand it
# callables closing over the old model — and the cache is LRU-bounded so
# long sweeps over many distinct models can't grow it without bound.
_JIT_CACHE: "OrderedDict[Tuple, Any]" = OrderedDict()
_JIT_CACHE_MAX = 64

# model object -> fingerprint memo.  Weak keys: memoizing must not keep
# retired models (and the params they map to) alive.
_FPRINT_MEMO: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _jit_cache_get(key):
    val = _JIT_CACHE.get(key)
    if val is not None:
        _JIT_CACHE.move_to_end(key)
    return val


def _jit_cache_put(key, val):
    _JIT_CACHE[key] = val
    _JIT_CACHE.move_to_end(key)
    while len(_JIT_CACHE) > _JIT_CACHE_MAX:
        _JIT_CACHE.popitem(last=False)


def model_fingerprint(model, params) -> str:
    """Stable identity of the jitted computation: model class + full
    config + parameter tree structure/shapes/dtypes.  Two models with
    the same fingerprint lower to identical HLO, so sharing their cache
    entries is sound; two models that differ in any of these never
    collide (even if ``id()`` is reused after a GC)."""
    fp = _FPRINT_MEMO.get(model)
    if fp is None:
        leaves, treedef = jax.tree_util.tree_flatten(params)
        sig = (type(model).__name__, repr(model.cfg), str(treedef),
               tuple((tuple(l.shape), str(l.dtype)) for l in leaves))
        fp = hashlib.sha1(repr(sig).encode()).hexdigest()
        _FPRINT_MEMO[model] = fp
    return fp


# The pipelined recompute scan pulls per-layer I/O data through an
# ordered io_callback; the active LayerFeed is published here by the
# residency engine just before dispatch (single-threaded by design —
# the scheduler serializes all model execution) and cleared when the
# dispatch completes, so no stale feed (or the chunk buffers it holds)
# outlives its restore.
_ACTIVE_FEED = None


def _feed_fetch(layer):
    return _ACTIVE_FEED.fetch(layer)


def _pow2_buckets(lo: int, hi: int) -> List[int]:
    out, b = [], lo
    while b < hi:
        out.append(b)
        b *= 2
    out.append(hi)
    return out


class ModelExecutor:
    """Jitted model entry points + bucket/padding helpers (one model)."""

    def __init__(self, model, params, cfg):
        self.model = model
        self.params = params
        self.cfg = cfg
        mc = model.cfg
        spec = model.kv_spec()
        self.spec = spec
        if not spec.servable:
            raise ValueError(
                f"family {spec.family!r} is not servable: its KVSpec "
                "declares no text-only prefill/extend entry")
        self.cs = cfg.chunk_tokens
        self.n_slots = math.ceil(cfg.max_ctx_len / self.cs) * self.cs
        self.chunked_cache = spec.chunkable
        if spec.chunkable:
            self.codec = ChunkCodec(spec.seq_leaves, self.cs)
        else:
            self.codec = WholeStateCodec(spec.state_leaves, self.cs)
        self.recomputable = spec.recomputable
        self.pad_safe = spec.pad_safe

        # quant-resident working cache: bf16 recent window + int8 chunk
        # segments the fused decode-attention kernels read in place
        self.quant_resident = bool(getattr(cfg, "quant_resident", False))
        if self.quant_resident and not spec.quant_resident:
            raise ValueError(
                f"family {spec.family!r} does not support the quant-resident "
                "working cache (families opt in via KVSpec.quant_resident)")

        # working cache: decode_batch independent slot caches (the
        # paper's working-set lock generalized to a slot table); each
        # slot is a batch-1 cache restored/switched independently.  In
        # paged mode the slots are page-table views into the pool and
        # decode runs one [B, 1] jitted step over gathered page rows.
        self.decode_slots = max(1, int(getattr(cfg, "decode_batch", 1) or 1))
        self.can_batch_decode = spec.batched_decode
        self.tok_buckets = _pow2_buckets(self.cs, self.n_slots)
        self.io_buckets = _pow2_buckets(1, max(self.n_slots // self.cs, 1))
        self.batch_buckets = _pow2_buckets(1, self.decode_slots)
        self.s_work = self.n_slots + self.tok_buckets[-1]
        self.pad_slot = self.s_work - 1
        self.work_cache = model.init_cache(
            1, self.s_work,
            layout=LAYOUT_MIXED if self.quant_resident else LAYOUT_WINDOW)
        self._zero_cache = self.work_cache

        self._fp = model_fingerprint(model, params)
        ck = (self._fp, cfg.window, cfg.n_sinks, mc.family, self.cs)
        cached = _jit_cache_get(ck)
        if cached is None:
            cw = dict(window=cfg.window, n_sinks=cfg.n_sinks)
            cached = {
                "extend": jax.jit(functools.partial(
                    model.recompute, want_density=True, **cw)),
                "extend_nod": jax.jit(functools.partial(
                    model.recompute, want_density=False, **cw)),
                "decode": jax.jit(functools.partial(
                    model.decode_step, want_density=True, **cw)),
                "logits": jax.jit(
                    lambda p, h: (h @ model.head_weight(p)
                                  ).astype(jnp.float32)),
                "insert": jax.jit(self.codec.insert),
                "scatter": jax.jit(self.codec.scatter),
                "scatter_quant": jax.jit(self.codec.scatter_quant),
                "setpos": jax.jit(lambda c, p: {**c, "pos": p}),
            }
            _jit_cache_put(ck, cached)
        self.extend_fn = cached["extend"]
        self.extend_nod_fn = cached["extend_nod"]
        self.decode_fn = cached["decode"]
        self.logits_fn = cached["logits"]
        self.insert_fn = cached["insert"]
        self.scatter_fn = cached["scatter"]
        self.scatter_quant_fn = cached["scatter_quant"]
        self.setpos_fn = cached["setpos"]

        shapes = {k: v.shape for k, v in self.work_cache.items()
                  if k in self.codec.leaves}
        self.leaf_shapes = shapes
        self.n_layers = next(iter(shapes.values()))[0]
        self.leaf_dims = dict(spec.leaf_dims)

        # paged KV pool: contexts whose spec declares ``paged`` decode
        # as views into one global page arena instead of owning slot
        # caches.  Families without the capability (recurrent state,
        # overridden decode entries) keep the slot path.
        self.paged = (
            bool(getattr(cfg, "paged_pool", False))
            and bool(getattr(cfg, "chunked", False))
            and spec.paged
            and self.can_batch_decode
            and self.s_work % self.cs == 0)
        self.pages_per_ctx = self.s_work // self.cs
        if self.paged:
            C = self.pages_per_ctx
            # +1 everywhere: page 0 is the reserved scratch page.  The
            # bf16 arena must at least fit every decode slot's full page
            # row or a single round could not be satisfied.
            self.pool_pages16 = max(
                int(getattr(cfg, "pool_pages_16", 0) or 16 * C + 1),
                self.decode_slots * C + 1)
            self.pool_pages8 = (
                int(getattr(cfg, "pool_pages_8", 0) or 16 * C + 1)
                if self.quant_resident else 1)
            pk = (self._fp, cfg.window, cfg.n_sinks, mc.family, self.cs,
                  self.quant_resident, "paged")
            pcached = _jit_cache_get(pk)
            if pcached is None:
                cw = dict(window=cfg.window, n_sinks=cfg.n_sinks)
                L = mc.n_layers
                leaves = tuple(self.codec.leaves)
                dims = dict(self.leaf_dims)
                cs, nl = self.cs, self.n_layers

                # admission converts the chunk-file block layout
                # (cs, L*prod(dims)) used by the codec/payload paths
                # into the page layout (L, cs, *dims) inside the jit, so
                # host code hands over exactly the payload blocks.
                def admit16(arenas, page, blocks):
                    out = dict(arenas)
                    for n in leaves:
                        t = blocks[n].reshape(cs, nl, 1, *dims[n])[:, :, 0]
                        t = jnp.moveaxis(t, 0, 1)
                        out[n + "16"] = arenas[n + "16"].at[:, page].set(
                            t.astype(arenas[n + "16"].dtype))
                    return out

                def admit8(arenas, page, codes, scales):
                    out = dict(arenas)
                    for n in leaves:
                        t = codes[n].reshape(cs, nl, 1, *dims[n])[:, :, 0]
                        out[n + "8"] = arenas[n + "8"].at[:, page].set(
                            jnp.moveaxis(t, 0, 1))
                        s = scales[n].reshape(cs, nl, *dims[n][:-1])
                        out[n + "8s"] = arenas[n + "8s"].at[:, page].set(
                            jnp.moveaxis(s, 0, 1))
                    return out

                def read16(arenas, page):
                    return {n: jnp.moveaxis(
                        arenas[n + "16"][:, page], 0, 1).reshape(cs, -1)
                        for n in leaves}

                # fresh tail pages must start as zeros: the slot path's
                # never-written positions are exactly zero (fresh_cache
                # is the shared zero cache), and unwritten-but-attended
                # positions (e.g. a call's final emitted token) must
                # encode identically on both paths
                def zero16(arenas, page):
                    out = dict(arenas)
                    for n in leaves:
                        a = arenas[n + "16"]
                        out[n + "16"] = a.at[:, page].set(
                            jnp.zeros(a.shape[:1] + a.shape[2:], a.dtype))
                    return out

                pcached = {
                    # unroll mirrors the old batched-decode entry: XLA
                    # CPU's rolled scan shuffles the gathered multi-row
                    # cache every layer and dominates the step
                    "decode": jax.jit(functools.partial(
                        model.decode_paged, want_density=True,
                        unroll=L if L <= 48 else 1, **cw)),
                    "extend": jax.jit(functools.partial(
                        model.extend_paged, want_density=True, **cw)),
                    "admit16": jax.jit(admit16),
                    "admit8": jax.jit(admit8),
                    "read16": jax.jit(read16),
                    "zero16": jax.jit(zero16),
                }
                _jit_cache_put(pk, pcached)
            self.paged_decode_fn = pcached["decode"]
            self.paged_extend_fn = pcached["extend"]
            self.admit16_fn = pcached["admit16"]
            self.admit8_fn = pcached["admit8"]
            self.read16_fn = pcached["read16"]
            self.zero16_fn = pcached["zero16"]

    @property
    def max_request_tokens(self) -> int:
        """Largest prompt+generation a single request may add: half the
        token window, so one call can never condense its own output."""
        return self.n_slots // 2

    # -- bucket / padding helpers ------------------------------------- #
    def bucket_len(self, n: int) -> int:
        return next(x for x in self.tok_buckets if x >= n)

    def bucket_pad(self, arr: np.ndarray, fill) -> np.ndarray:
        b = self.bucket_len(len(arr))
        if b == len(arr):
            return arr
        return np.concatenate([arr, np.full(b - len(arr), fill, arr.dtype)])

    def chunk_positions(self, idxs: Sequence[int]) -> np.ndarray:
        pos = []
        for i in idxs:
            pos.extend(range(i * self.cs, (i + 1) * self.cs))
        return np.asarray(pos, np.int32)

    # -- model entry points ------------------------------------------- #
    def fresh_cache(self, n_tokens: int):
        return self.setpos_fn(self._zero_cache, jnp.int32(n_tokens))

    def extend(self, cache, prompt: np.ndarray, n0: int):
        """Append ``prompt`` at positions [n0, n0+M) -> (cache, last-token
        logits, per-position density mass)."""
        M = len(prompt)
        pos = np.arange(n0, n0 + M, dtype=np.int32)
        if self.pad_safe:
            pos_b = self.bucket_pad(pos, self.pad_slot)
            toks_b = self.bucket_pad(np.asarray(prompt, np.int32), 0)
        else:
            # recurrent carry: a pad token would fold into the state —
            # run at exact length (one retrace per distinct length)
            pos_b, toks_b = pos, np.asarray(prompt, np.int32)
        cache, hidden, dens = self.extend_fn(
            self.params, jnp.asarray(toks_b)[None], jnp.asarray(pos_b),
            cache, jnp.int32(n0 + M))
        logits = np.asarray(self.logits_fn(self.params, hidden[:, M - 1]))[0]
        cache = self.setpos_fn(cache, jnp.int32(n0 + M))
        return cache, logits, np.asarray(dens[0], np.float64)

    def decode(self, cache, tok: int):
        out, mass = self.decode_fn(
            self.params, jnp.asarray([[tok]], jnp.int32), cache)
        return (out.cache, np.asarray(out.logits[0]),
                np.asarray(mass[0], np.float64))

    # -- paged KV pool entry points ----------------------------------- #
    def init_arenas(self):
        """Fresh page arenas — one fixed buffer per (leaf, kind).  Page 0
        is the reserved scratch/zero page every unowned page-table entry
        points at; its contents are garbage after the first write and
        never attended (the causal/seq-len masks zero those positions)."""
        assert self.paged
        arenas = {}
        for n in self.codec.leaves:
            dims = self.leaf_dims[n]
            arenas[n + "16"] = jnp.zeros(
                (self.n_layers, self.pool_pages16, self.cs, *dims),
                self.work_cache[n].dtype)
            if self.quant_resident:
                arenas[n + "8"] = jnp.zeros(
                    (self.n_layers, self.pool_pages8, self.cs, *dims),
                    jnp.int8)
                arenas[n + "8s"] = jnp.zeros(
                    (self.n_layers, self.pool_pages8, self.cs, *dims[:-1]),
                    jnp.float32)
        return arenas

    def paged_extend(self, arenas, prompt: np.ndarray, n0: int,
                     pt16, pt8, qmask):
        """Paged form of ``extend``: append ``prompt`` at [n0, n0+M) for
        the single context whose page-table row is ``pt16[0]`` (and
        ``pt8[0]``/``qmask[0]`` under quant_resident, else None).
        Padded positions land on the scratch page 0.
        -> (arenas', last-token logits, per-position density mass)."""
        M = len(prompt)
        pos = np.arange(n0, n0 + M, dtype=np.int32)
        pos_b = self.bucket_pad(pos, self.pad_slot)
        toks_b = self.bucket_pad(np.asarray(prompt, np.int32), 0)
        arenas, hidden, dens = self.paged_extend_fn(
            self.params, jnp.asarray(toks_b)[None], jnp.asarray(pos_b),
            arenas, jnp.asarray(pt16),
            None if pt8 is None else jnp.asarray(pt8),
            None if qmask is None else jnp.asarray(qmask),
            jnp.int32(n0 + M))
        logits = np.asarray(self.logits_fn(self.params, hidden[:, M - 1]))[0]
        return arenas, logits, np.asarray(dens[0], np.float64)

    def paged_decode(self, arenas, toks: Sequence[int], pos: Sequence[int],
                     pt16, pt8, qmask):
        """One decode round for n contexts over the pool: row i advances
        by ``toks[i]`` at its own position ``pos[i]``, batch-bucketed.
        Pad rows get the all-zero page-table row (scratch page) and are
        sliced off the outputs.  -> (arenas', logits [n, V],
        density-mass [n, S])."""
        n = len(toks)
        nb = next(b for b in self.batch_buckets if b >= n)
        toks_b = np.zeros((nb, 1), np.int32)
        toks_b[:n, 0] = toks
        pos_b = np.zeros(nb, np.int32)
        pos_b[:n] = pos
        C = pt16.shape[1]
        pt16_b = np.zeros((nb, C), np.int32)
        pt16_b[:n] = pt16
        pt8_b = qmask_b = None
        if pt8 is not None:
            pt8_b = np.zeros((nb, C), np.int32)
            pt8_b[:n] = pt8
            qmask_b = np.zeros((nb, C), bool)
            qmask_b[:n] = qmask
        arenas, logits, mass = self.paged_decode_fn(
            self.params, jnp.asarray(toks_b), arenas,
            jnp.asarray(pt16_b),
            None if pt8_b is None else jnp.asarray(pt8_b),
            None if qmask_b is None else jnp.asarray(qmask_b),
            jnp.asarray(pos_b))
        return (arenas, np.asarray(logits)[:n],
                np.asarray(mass, np.float64)[:n])

    def run_pipelined(self, feed, toks_b, miss_b, io_pos_b, cache, n_total):
        """Dispatch the layer-pipelined recompute scan, with ``feed``
        published as the active per-layer I/O source for exactly the
        duration of the dispatch (cleared even on failure, so a stale
        feed can never leak into a later retrace or pin chunk buffers)."""
        global _ACTIVE_FEED
        assert _ACTIVE_FEED is None, "re-entrant pipelined restore"
        _ACTIVE_FEED = feed
        try:
            fn = self._get_pipelined_fn()
            cache, _, _ = fn(self.params, jnp.asarray(toks_b)[None],
                             jnp.asarray(miss_b), jnp.asarray(io_pos_b),
                             cache, jnp.int32(n_total))
            # the io_callbacks fire while the dispatch executes; join it
            # before unpublishing the feed
            jax.block_until_ready(cache[self.codec.leaves[0]])
        finally:
            _ACTIVE_FEED = None
        return cache

    def _get_pipelined_fn(self):
        ck = (self._fp, self.cfg.window, self.cfg.n_sinks, "pipelined")
        fn = _jit_cache_get(ck)
        if fn is None:
            fn = jax.jit(
                functools.partial(self.model.recompute_pipelined,
                                  fetch=_feed_fetch,
                                  window=self.cfg.window,
                                  n_sinks=self.cfg.n_sinks))
            _jit_cache_put(ck, fn)
        return fn
