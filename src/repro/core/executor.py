"""Model executor — the jitted entry points of the serving stack.

Layer 1 of the four-layer design (DESIGN.md §1): owns the bf16 working
cache — ``decode_batch`` independent slot caches, so up to B contexts
are simultaneously hot — the power-of-two bucket/padding logic that
keeps jit compilation counts bounded (token buckets for prefill, batch
buckets for the batched decode entry), and the process-wide
``_JIT_CACHE`` shared across service instances of the same
(model-fingerprint, window) so benchmark sweeps don't recompile.
Everything above (residency, scheduler) treats this layer as "run the
model on these tokens/positions"; nothing here knows about
chunks-on-disk, budgets, or apps.

``extend`` (prefill), ``decode`` (one token, one slot) and
``decode_many`` (one token for each of B slots in a single jitted
``[B, 1]`` step) are the stepwise entry points the request/stream
protocol is built on: ``LLMService`` drives one decode round per
``decode_step``/``decode_step_batch`` so the router can slice
generations, batch compatible contexts, and preempt between slices
(DESIGN.md §2).
"""
from __future__ import annotations

import functools
import hashlib
import math
import weakref
from collections import OrderedDict
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunks import ChunkCodec

Array = jax.Array

# (model-fingerprint, window, n_sinks, family, chunk_tokens[, entry])
# -> jitted callables.  Shared process-wide so sweeps over policies /
# budgets reuse compilations.  Keys use a STABLE content fingerprint of
# (config, param treedef/shapes/dtypes) — never ``id(model)``: a dead
# model's id can be reused by a new object, which would silently hand it
# callables closing over the old model — and the cache is LRU-bounded so
# long sweeps over many distinct models can't grow it without bound.
_JIT_CACHE: "OrderedDict[Tuple, Any]" = OrderedDict()
_JIT_CACHE_MAX = 64

# model object -> fingerprint memo.  Weak keys: memoizing must not keep
# retired models (and the params they map to) alive.
_FPRINT_MEMO: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _jit_cache_get(key):
    val = _JIT_CACHE.get(key)
    if val is not None:
        _JIT_CACHE.move_to_end(key)
    return val


def _jit_cache_put(key, val):
    _JIT_CACHE[key] = val
    _JIT_CACHE.move_to_end(key)
    while len(_JIT_CACHE) > _JIT_CACHE_MAX:
        _JIT_CACHE.popitem(last=False)


def model_fingerprint(model, params) -> str:
    """Stable identity of the jitted computation: model class + full
    config + parameter tree structure/shapes/dtypes.  Two models with
    the same fingerprint lower to identical HLO, so sharing their cache
    entries is sound; two models that differ in any of these never
    collide (even if ``id()`` is reused after a GC)."""
    fp = _FPRINT_MEMO.get(model)
    if fp is None:
        leaves, treedef = jax.tree_util.tree_flatten(params)
        sig = (type(model).__name__, repr(model.cfg), str(treedef),
               tuple((tuple(l.shape), str(l.dtype)) for l in leaves))
        fp = hashlib.sha1(repr(sig).encode()).hexdigest()
        _FPRINT_MEMO[model] = fp
    return fp


# The pipelined recompute scan pulls per-layer I/O data through an
# ordered io_callback; the active LayerFeed is published here by the
# residency engine just before dispatch (single-threaded by design —
# the scheduler serializes all model execution) and cleared when the
# dispatch completes, so no stale feed (or the chunk buffers it holds)
# outlives its restore.
_ACTIVE_FEED = None


def _feed_fetch(layer):
    return _ACTIVE_FEED.fetch(layer)


def _pow2_buckets(lo: int, hi: int) -> List[int]:
    out, b = [], lo
    while b < hi:
        out.append(b)
        b *= 2
    out.append(hi)
    return out


class ModelExecutor:
    """Jitted model entry points + bucket/padding helpers (one model)."""

    def __init__(self, model, params, cfg):
        self.model = model
        self.params = params
        self.cfg = cfg
        mc = model.cfg
        self.cs = cfg.chunk_tokens
        self.n_slots = math.ceil(cfg.max_ctx_len / self.cs) * self.cs
        self.codec = ChunkCodec(mc.family, self.cs)
        self.recomputable = mc.family in ("dense", "mla_moe")

        # quant-resident working cache: bf16 recent window + int8 chunk
        # segments the fused decode-attention kernels read in place
        self.quant_resident = bool(getattr(cfg, "quant_resident", False))
        if self.quant_resident and not getattr(
                model, "supports_quant_resident", False):
            raise ValueError(
                f"family {mc.family!r} does not support the quant-resident "
                "working cache (models opt in via supports_quant_resident)")

        # working cache: decode_batch independent slot caches (the
        # paper's working-set lock generalized to a slot table); each
        # slot is a batch-1 cache restored/switched independently, and
        # decode_many stacks the hot slots into one [B, 1] jitted step.
        self.decode_slots = max(1, int(getattr(cfg, "decode_batch", 1) or 1))
        self.can_batch_decode = bool(
            getattr(model, "supports_batched_decode", False))
        self.tok_buckets = _pow2_buckets(self.cs, self.n_slots)
        self.io_buckets = _pow2_buckets(1, max(self.n_slots // self.cs, 1))
        self.batch_buckets = _pow2_buckets(1, self.decode_slots)
        self.s_work = self.n_slots + self.tok_buckets[-1]
        self.pad_slot = self.s_work - 1
        if self.quant_resident:
            self.work_cache = model.init_cache(1, self.s_work,
                                               mixed_quant=True)
        else:
            self.work_cache = model.init_cache(1, self.s_work)
        self._zero_cache = self.work_cache

        self._fp = model_fingerprint(model, params)
        ck = (self._fp, cfg.window, cfg.n_sinks, mc.family, self.cs)
        cached = _jit_cache_get(ck)
        if cached is None:
            cw = dict(window=cfg.window, n_sinks=cfg.n_sinks)
            cached = {
                "extend": jax.jit(functools.partial(
                    model.recompute, want_density=True, **cw)),
                "extend_nod": jax.jit(functools.partial(
                    model.recompute, want_density=False, **cw)),
                "decode": jax.jit(functools.partial(
                    model.decode_step, want_density=True, **cw)),
                "logits": jax.jit(
                    lambda p, h: (h @ model.head_weight(p)
                                  ).astype(jnp.float32)),
                "insert": jax.jit(self.codec.insert),
                "scatter": jax.jit(self.codec.scatter),
                "scatter_quant": jax.jit(self.codec.scatter_quant),
                "setpos": jax.jit(lambda c, p: {**c, "pos": p}),
            }
            _jit_cache_put(ck, cached)
        self.extend_fn = cached["extend"]
        self.extend_nod_fn = cached["extend_nod"]
        self.decode_fn = cached["decode"]
        self.logits_fn = cached["logits"]
        self.insert_fn = cached["insert"]
        self.scatter_fn = cached["scatter"]
        self.scatter_quant_fn = cached["scatter_quant"]
        self.setpos_fn = cached["setpos"]

        shapes = {k: v.shape for k, v in self.work_cache.items()
                  if k in self.codec.leaves}
        self.leaf_shapes = shapes
        self.n_layers = next(iter(shapes.values()))[0]
        if "k" in self.codec.leaves:
            self.leaf_dims = {"k": (mc.n_kv_heads, mc.head_dim),
                              "v": (mc.n_kv_heads, mc.head_dim)}
        else:
            self.leaf_dims = {"ckv": (mc.mla.kv_lora_rank,),
                              "kpe": (mc.mla.qk_rope_head_dim,)}

    @property
    def max_request_tokens(self) -> int:
        """Largest prompt+generation a single request may add: half the
        token window, so one call can never condense its own output."""
        return self.n_slots // 2

    # -- bucket / padding helpers ------------------------------------- #
    def bucket_len(self, n: int) -> int:
        return next(x for x in self.tok_buckets if x >= n)

    def bucket_pad(self, arr: np.ndarray, fill) -> np.ndarray:
        b = self.bucket_len(len(arr))
        if b == len(arr):
            return arr
        return np.concatenate([arr, np.full(b - len(arr), fill, arr.dtype)])

    def chunk_positions(self, idxs: Sequence[int]) -> np.ndarray:
        pos = []
        for i in idxs:
            pos.extend(range(i * self.cs, (i + 1) * self.cs))
        return np.asarray(pos, np.int32)

    # -- model entry points ------------------------------------------- #
    def fresh_cache(self, n_tokens: int):
        return self.setpos_fn(self._zero_cache, jnp.int32(n_tokens))

    def extend(self, cache, prompt: np.ndarray, n0: int):
        """Append ``prompt`` at positions [n0, n0+M) -> (cache, last-token
        logits, per-position density mass)."""
        M = len(prompt)
        pos = np.arange(n0, n0 + M, dtype=np.int32)
        pos_b = self.bucket_pad(pos, self.pad_slot)
        toks_b = self.bucket_pad(prompt, 0)
        cache, hidden, dens = self.extend_fn(
            self.params, jnp.asarray(toks_b)[None], jnp.asarray(pos_b),
            cache, jnp.int32(n0 + M))
        logits = np.asarray(self.logits_fn(self.params, hidden[:, M - 1]))[0]
        cache = self.setpos_fn(cache, jnp.int32(n0 + M))
        return cache, logits, np.asarray(dens[0], np.float64)

    def decode(self, cache, tok: int):
        out, mass = self.decode_fn(
            self.params, jnp.asarray([[tok]], jnp.int32), cache)
        return (out.cache, np.asarray(out.logits[0]),
                np.asarray(mass[0], np.float64))

    # -- multi-context batched decode --------------------------------- #
    def begin_batch(self, caches: Sequence[Any]) -> "BatchRun":
        """Open a persistent batched-decode run over the given slot
        caches (see ``BatchRun``)."""
        assert self.can_batch_decode and len(caches) > 1
        return BatchRun(self, caches)

    def decode_many(self, caches: Sequence[Any], toks: Sequence[int]
                    ) -> List[Tuple[Any, np.ndarray, np.ndarray]]:
        """One decode step for each slot: slot i's cache advances by its
        token ``toks[i]`` at its own position, in a single jitted
        ``[B, 1]`` step.  One-shot convenience over ``begin_batch`` —
        steady-state callers (``LLMService.decode_step_batch``) keep the
        ``BatchRun`` open across rounds instead, so the merge/split
        copies are paid per membership change, not per token.  Models
        without per-row position support fall back to a serial loop.
        -> list of (cache', logits, density-mass) per slot, same order.
        """
        n = len(caches)
        if n == 1 or not self.can_batch_decode:
            return [self.decode(c, t) for c, t in zip(caches, toks)]
        run = self.begin_batch(caches)
        logits, mass = run.step(toks)
        outs = run.split()
        return [(outs[i], logits[i], mass[i]) for i in range(n)]

    def _batch_fns(self, nb: int):
        """(merge, step, split) jitted callables for batch bucket nb."""
        # keyed on quant_resident too: merge/split close over the leaf
        # list of THIS executor's cache structure (mixed caches carry
        # k_q/v_q/scale/quant_mask leaves a plain cache doesn't)
        ck = (self._fp, self.cfg.window, self.cfg.n_sinks,
              self.model.cfg.family, self.cs, self.quant_resident,
              "batch", nb)
        fns = _jit_cache_get(ck)
        if fns is None:
            model = self.model
            cw = dict(window=self.cfg.window, n_sinks=self.cfg.n_sinks)
            # unroll the layer scan in the batched step: XLA CPU's rolled
            # scan shuffles the full multi-row cache every iteration and
            # dominates the step (~5x on the bench model); cap the unroll
            # so very deep models keep bounded compile times
            if getattr(model, "supports_batched_decode", False):
                L = model.cfg.n_layers
                cw["unroll"] = L if L <= 48 else 1
            leaves = [k for k in self._zero_cache if k != "pos"]

            def merge(caches):
                out = {name: jnp.concatenate(
                    [c[name] for c in caches], axis=1) for name in leaves}
                out["pos"] = jnp.stack([c["pos"] for c in caches])
                return out

            def step(params, toks, merged):
                out, mass = model.decode_step(
                    params, toks, merged, want_density=True, **cw)
                return out.cache, out.logits, mass

            def split(merged):
                return tuple(
                    {**{name: merged[name][:, i:i + 1] for name in leaves},
                     "pos": merged["pos"][i]}
                    for i in range(nb))

            fns = (jax.jit(merge), jax.jit(step), jax.jit(split))
            _jit_cache_put(ck, fns)
        return fns

    def run_pipelined(self, feed, toks_b, miss_b, io_pos_b, cache, n_total):
        """Dispatch the layer-pipelined recompute scan, with ``feed``
        published as the active per-layer I/O source for exactly the
        duration of the dispatch (cleared even on failure, so a stale
        feed can never leak into a later retrace or pin chunk buffers)."""
        global _ACTIVE_FEED
        assert _ACTIVE_FEED is None, "re-entrant pipelined restore"
        _ACTIVE_FEED = feed
        try:
            fn = self._get_pipelined_fn()
            cache, _, _ = fn(self.params, jnp.asarray(toks_b)[None],
                             jnp.asarray(miss_b), jnp.asarray(io_pos_b),
                             cache, jnp.int32(n_total))
            # the io_callbacks fire while the dispatch executes; join it
            # before unpublishing the feed
            jax.block_until_ready(cache[self.codec.leaves[0]])
        finally:
            _ACTIVE_FEED = None
        return cache

    def _get_pipelined_fn(self):
        ck = (self._fp, self.cfg.window, self.cfg.n_sinks, "pipelined")
        fn = _jit_cache_get(ck)
        if fn is None:
            fn = jax.jit(
                functools.partial(self.model.recompute_pipelined,
                                  fetch=_feed_fetch,
                                  window=self.cfg.window,
                                  n_sinks=self.cfg.n_sinks))
            _jit_cache_put(ck, fn)
        return fn


class BatchRun:
    """A persistent merged working cache over n decode slots.

    Merging n batch-1 slot caches into one ``[nb, ...]`` cache (padded
    to a power-of-two bucket) costs real copies; a decode round on the
    MERGED cache does not.  Keeping the run open while the batch
    membership is stable makes the steady-state round exactly one jitted
    ``[nb, 1]`` model step — ``split()`` pays the copies back out only
    when a generation leaves the batch (finish/suspend/cancel).
    """

    def __init__(self, exe: ModelExecutor, caches: Sequence[Any]):
        self.exe = exe
        self.n = len(caches)
        self.nb = next(b for b in exe.batch_buckets if b >= self.n)
        self._merge_fn, self._step_fn, self._split_fn = exe._batch_fns(self.nb)
        pad = (exe._zero_cache,) * (self.nb - self.n)
        self.merged = self._merge_fn(tuple(caches) + pad)

    def step(self, toks: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
        """Advance every slot by its token -> (logits [n, V],
        density-mass [n, S])."""
        toks_b = np.zeros((self.nb, 1), np.int32)
        toks_b[:self.n, 0] = toks
        self.merged, logits, mass = self._step_fn(
            self.exe.params, jnp.asarray(toks_b), self.merged)
        return (np.asarray(logits)[:self.n],
                np.asarray(mass, np.float64)[:self.n])

    def split(self) -> List[Any]:
        """Per-slot batch-1 caches reflecting every step so far."""
        return list(self._split_fn(self.merged)[:self.n])
