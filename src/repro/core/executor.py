"""Model executor — the jitted entry points of the serving stack.

Layer 1 of the four-layer design (DESIGN.md §1): owns the bf16 working
cache, the power-of-two bucket/padding logic that keeps jit compilation
counts bounded, and the process-wide ``_JIT_CACHE`` shared across
service instances of the same (model, window) so benchmark sweeps don't
recompile.  Everything above (residency, scheduler) treats this layer
as "run the model on these tokens/positions"; nothing here knows about
chunks-on-disk, budgets, or apps.

``extend`` (prefill) and ``decode`` (one token) are the stepwise entry
points the request/stream protocol is built on: ``LLMService`` drives
one ``decode`` per ``decode_step`` so the router can slice generations
and preempt between slices (DESIGN.md §2).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunks import ChunkCodec

Array = jax.Array

# (model-id, window, n_sinks, family, chunk_tokens) -> jitted callables.
# Shared process-wide so sweeps over policies/budgets reuse compilations.
_JIT_CACHE: Dict[Tuple, Any] = {}

# The pipelined recompute scan pulls per-layer I/O data through an
# ordered io_callback; the active LayerFeed is published here by the
# residency engine just before dispatch (single-threaded by design —
# the scheduler serializes all model execution).
_ACTIVE_FEED = None


def _feed_fetch(layer):
    return _ACTIVE_FEED.fetch(layer)


def _pow2_buckets(lo: int, hi: int) -> List[int]:
    out, b = [], lo
    while b < hi:
        out.append(b)
        b *= 2
    out.append(hi)
    return out


class ModelExecutor:
    """Jitted model entry points + bucket/padding helpers (one model)."""

    def __init__(self, model, params, cfg):
        self.model = model
        self.params = params
        self.cfg = cfg
        mc = model.cfg
        self.cs = cfg.chunk_tokens
        self.n_slots = math.ceil(cfg.max_ctx_len / self.cs) * self.cs
        self.codec = ChunkCodec(mc.family, self.cs)
        self.recomputable = mc.family in ("dense", "mla_moe")

        # working cache: one active context at a time (paper's WS lock)
        self.tok_buckets = _pow2_buckets(self.cs, self.n_slots)
        self.io_buckets = _pow2_buckets(1, max(self.n_slots // self.cs, 1))
        self.s_work = self.n_slots + self.tok_buckets[-1]
        self.pad_slot = self.s_work - 1
        self.work_cache = model.init_cache(1, self.s_work)
        self._zero_cache = self.work_cache

        ck = (id(model), cfg.window, cfg.n_sinks, mc.family, self.cs)
        cached = _JIT_CACHE.get(ck)
        if cached is None:
            cw = dict(window=cfg.window, n_sinks=cfg.n_sinks)
            cached = {
                "extend": jax.jit(functools.partial(
                    model.recompute, want_density=True, **cw)),
                "extend_nod": jax.jit(functools.partial(
                    model.recompute, want_density=False, **cw)),
                "decode": jax.jit(functools.partial(
                    model.decode_step, want_density=True, **cw)),
                "logits": jax.jit(
                    lambda p, h: (h @ model.head_weight(p)
                                  ).astype(jnp.float32)),
                "insert": jax.jit(self.codec.insert),
                "scatter": jax.jit(self.codec.scatter),
                "setpos": jax.jit(lambda c, p: {**c, "pos": p}),
            }
            _JIT_CACHE[ck] = cached
        self.extend_fn = cached["extend"]
        self.extend_nod_fn = cached["extend_nod"]
        self.decode_fn = cached["decode"]
        self.logits_fn = cached["logits"]
        self.insert_fn = cached["insert"]
        self.scatter_fn = cached["scatter"]
        self.setpos_fn = cached["setpos"]

        shapes = {k: v.shape for k, v in self.work_cache.items()
                  if k in self.codec.leaves}
        self.leaf_shapes = shapes
        self.n_layers = next(iter(shapes.values()))[0]
        if "k" in self.codec.leaves:
            self.leaf_dims = {"k": (mc.n_kv_heads, mc.head_dim),
                              "v": (mc.n_kv_heads, mc.head_dim)}
        else:
            self.leaf_dims = {"ckv": (mc.mla.kv_lora_rank,),
                              "kpe": (mc.mla.qk_rope_head_dim,)}

    @property
    def max_request_tokens(self) -> int:
        """Largest prompt+generation a single request may add: half the
        token window, so one call can never condense its own output."""
        return self.n_slots // 2

    # -- bucket / padding helpers ------------------------------------- #
    def bucket_len(self, n: int) -> int:
        return next(x for x in self.tok_buckets if x >= n)

    def bucket_pad(self, arr: np.ndarray, fill) -> np.ndarray:
        b = self.bucket_len(len(arr))
        if b == len(arr):
            return arr
        return np.concatenate([arr, np.full(b - len(arr), fill, arr.dtype)])

    def chunk_positions(self, idxs: Sequence[int]) -> np.ndarray:
        pos = []
        for i in idxs:
            pos.extend(range(i * self.cs, (i + 1) * self.cs))
        return np.asarray(pos, np.int32)

    # -- model entry points ------------------------------------------- #
    def fresh_cache(self, n_tokens: int):
        return self.setpos_fn(self._zero_cache, jnp.int32(n_tokens))

    def extend(self, cache, prompt: np.ndarray, n0: int):
        """Append ``prompt`` at positions [n0, n0+M) -> (cache, last-token
        logits, per-position density mass)."""
        M = len(prompt)
        pos = np.arange(n0, n0 + M, dtype=np.int32)
        pos_b = self.bucket_pad(pos, self.pad_slot)
        toks_b = self.bucket_pad(prompt, 0)
        cache, hidden, dens = self.extend_fn(
            self.params, jnp.asarray(toks_b)[None], jnp.asarray(pos_b),
            cache, jnp.int32(n0 + M))
        logits = np.asarray(self.logits_fn(self.params, hidden[:, M - 1]))[0]
        cache = self.setpos_fn(cache, jnp.int32(n0 + M))
        return cache, logits, np.asarray(dens[0], np.float64)

    def decode(self, cache, tok: int):
        out, mass = self.decode_fn(
            self.params, jnp.asarray([[tok]], jnp.int32), cache)
        return (out.cache, np.asarray(out.logits[0]),
                np.asarray(mass[0], np.float64))

    def run_pipelined(self, feed, toks_b, miss_b, io_pos_b, cache, n_total):
        """Dispatch the layer-pipelined recompute scan, with ``feed``
        published as the active per-layer I/O source."""
        global _ACTIVE_FEED
        _ACTIVE_FEED = feed
        fn = self._get_pipelined_fn()
        cache, _, _ = fn(self.params, jnp.asarray(toks_b)[None],
                         jnp.asarray(miss_b), jnp.asarray(io_pos_b),
                         cache, jnp.int32(n_total))
        return cache

    def _get_pipelined_fn(self):
        ck = (id(self.model), self.cfg.window, self.cfg.n_sinks, "pipelined")
        fn = _JIT_CACHE.get(ck)
        if fn is None:
            fn = jax.jit(
                functools.partial(self.model.recompute_pipelined,
                                  fetch=_feed_fetch,
                                  window=self.cfg.window,
                                  n_sinks=self.cfg.n_sinks))
            _JIT_CACHE[ck] = fn
        return fn
