"""Multi-app scheduler front-end for the LLM service (paper §2-§3).

Layer 4 of the four-layer design (DESIGN.md §1, §4): the paper's LLMaaS
premise is ONE shared model serving MANY apps, so something above the
service must (a) admit requests from concurrent apps, (b) order them by
user-perceived urgency (foreground interactions ahead of background
agents), and (c) exploit the trace history to predict which context
comes next — the §3.4 ahead-of-time swap-out hint.

``ServiceRouter`` owns per-app sessions and an admission priority
queue.  The underlying model execution stays serial (the paper's
working-set lock: one active context at a time), so the router
serializes all service access under one lock; with ``start=True`` a
dispatcher thread drains the queue so app threads only enqueue, with
``start=False`` the queue drains inline (deterministic — used by the
benchmarks and tests).

``NextContextPredictor`` is a first-order transition table over the
observed context-switch history — the same process that generates the
synthetic traces (trace/synth.py markov pattern), so it is the right
minimal predictor.  After every call the router asks it for the likely
next context and passes the answer to ``ResidencyEngine.prepare_switch``
which protects that context's chunks and AoT-flushes everyone else's.
"""
from __future__ import annotations

import heapq
import threading
import time
from collections import Counter, defaultdict
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

FOREGROUND = 0
BACKGROUND = 1
_PRIO_NAMES = {FOREGROUND: "foreground", BACKGROUND: "background"}
_PRIO_BY_NAME = {"foreground": FOREGROUND, "fg": FOREGROUND,
                 "background": BACKGROUND, "bg": BACKGROUND}


def parse_priority(p) -> int:
    if isinstance(p, str):
        return _PRIO_BY_NAME[p.lower()]
    assert p in (FOREGROUND, BACKGROUND), p
    return int(p)


class NextContextPredictor:
    """First-order Markov predictor over the context-switch history."""

    def __init__(self):
        self.trans: Dict[int, Counter] = defaultdict(Counter)
        self.last: Optional[int] = None

    def observe(self, cid: int):
        if self.last is not None:
            self.trans[self.last][cid] += 1
        self.last = cid

    def predict(self, cid: Optional[int] = None) -> Optional[int]:
        """Most likely successor of ``cid`` (default: the latest ctx)."""
        cid = self.last if cid is None else cid
        counts = self.trans.get(cid)
        if not counts:
            return None
        return counts.most_common(1)[0][0]


class AppSession:
    """Per-app handle: all service access goes through the router."""

    def __init__(self, router: "ServiceRouter", name: str, priority: int):
        self.router = router
        self.name = name
        self.priority = priority

    def new_ctx(self, system_prompt=None):
        return self.router.new_ctx(self, system_prompt=system_prompt)

    def del_ctx(self, stub):
        return self.router.del_ctx(self, stub)

    def submit(self, stub, prompt, max_new_tokens: int = 16) -> Future:
        return self.router.submit(self, stub, prompt, max_new_tokens)

    def call(self, stub, prompt, max_new_tokens: int = 16):
        """Synchronous convenience: admit + wait for completion."""
        fut = self.submit(stub, prompt, max_new_tokens)
        if not self.router.started:
            self.router.drain()
        return fut.result()


class ServiceRouter:
    """Admission queue + per-app sessions + next-context prediction."""

    def __init__(self, svc, predict: bool = True, start: bool = False):
        self.svc = svc
        self.predictor = NextContextPredictor() if predict else None
        self.sessions: Dict[str, AppSession] = {}
        self.call_records: List[Dict[str, Any]] = []
        self.prefetch_hints = 0
        self.aot_flushes = 0
        self._pred_next: Optional[int] = None
        self._pred_hits = 0
        self._pred_total = 0

        self._cv = threading.Condition()
        self._queue: List[Tuple[int, int, dict]] = []    # (prio, seq, job)
        self._seq = 0
        self._inflight = 0
        self._stop = False
        self._svc_lock = threading.RLock()   # serializes ALL service access
        self.started = start
        self._worker = None
        if start:
            self._worker = threading.Thread(
                target=self._loop, name="llms-router", daemon=True)
            self._worker.start()

    # -- app/session management ---------------------------------------- #
    def register_app(self, name: str, priority="foreground") -> AppSession:
        sess = AppSession(self, name, parse_priority(priority))
        self.sessions[name] = sess
        return sess

    def new_ctx(self, session: AppSession, system_prompt=None):
        with self._svc_lock:
            return self.svc.newLLMCtx(system_prompt=system_prompt)

    def del_ctx(self, session: AppSession, stub):
        with self._svc_lock:
            return self.svc.delLLMCtx(stub)

    # -- admission ------------------------------------------------------ #
    def submit(self, session: AppSession, stub, prompt,
               max_new_tokens: int = 16) -> Future:
        fut: Future = Future()
        job = {"session": session, "stub": stub, "prompt": prompt,
               "max_new": max_new_tokens, "future": fut,
               "t_enqueue": time.perf_counter()}
        with self._cv:
            if self._stop:
                raise RuntimeError("router is shut down")
            heapq.heappush(self._queue,
                           (session.priority, self._seq, job))
            self._seq += 1
            self._cv.notify()
        return fut

    # -- dispatch -------------------------------------------------------- #
    def _loop(self):
        while True:
            with self._cv:
                while not self._queue and not self._stop:
                    self._cv.wait()
                if self._stop and not self._queue:
                    return
                _, _, job = heapq.heappop(self._queue)
                self._inflight += 1
            try:
                self._execute(job)
            finally:
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()

    def _execute(self, job):
        fut = job["future"]
        if not fut.set_running_or_notify_cancel():
            return
        sess: AppSession = job["session"]
        cid = job["stub"].ctx_id
        t_start = time.perf_counter()
        try:
            with self._svc_lock:
                if self._pred_next is not None:
                    self._pred_total += 1
                    self._pred_hits += self._pred_next == cid
                result = self.svc.callLLM(job["stub"], job["prompt"],
                                          max_new_tokens=job["max_new"])
                # capture under the lock: another session's call must not
                # slip a record in between
                rec = self.svc.records[-1] if self.svc.records else {}
                self._after_call(cid)
        except Exception as e:              # report to the submitting app
            fut.set_exception(e)
            return
        except BaseException as e:          # KeyboardInterrupt/SystemExit:
            fut.set_exception(e)            # fail the job AND abort dispatch
            raise
        t_end = time.perf_counter()
        self.call_records.append({
            "app": sess.name, "priority": sess.priority, "ctx": cid,
            "wait_s": t_start - job["t_enqueue"],
            "service_s": t_end - t_start,
            "switch_s": rec.get("switch_s", 0.0),
        })
        fut.set_result(result)

    def _after_call(self, cid: int):
        """Feed the trace history into the §3.4 AoT swap-out hint."""
        if self.predictor is None:
            return
        self.predictor.observe(cid)
        pred = self.predictor.predict(cid)
        self._pred_next = pred
        if pred is not None:
            self.prefetch_hints += 1
            self.aot_flushes += self.svc.prepare_switch(pred)

    def drain(self):
        """Run (or wait for) every admitted job; returns when idle."""
        if self.started:
            with self._cv:
                while self._queue or self._inflight:
                    self._cv.wait()
            return
        while True:
            with self._cv:
                if not self._queue:
                    return
                _, _, job = heapq.heappop(self._queue)
            self._execute(job)

    def shutdown(self):
        self.drain()
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=10.0)

    # -- reporting ------------------------------------------------------- #
    def stats(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "prefetch_hints": self.prefetch_hints,
            "aot_flushes": self.aot_flushes,
            "pred_hits": self._pred_hits,
            "pred_total": self._pred_total,
        }
        for prio, name in _PRIO_NAMES.items():
            rs = [r for r in self.call_records if r["priority"] == prio]
            if not rs:
                continue
            waits = [r["wait_s"] for r in rs]
            servs = [r["service_s"] for r in rs]
            lats = [w + s for w, s in zip(waits, servs)]
            out[name] = {
                "calls": len(rs),
                "wait_mean_s": float(np.mean(waits)),
                "service_mean_s": float(np.mean(servs)),
                "latency_mean_s": float(np.mean(lats)),
                "latency_p99_s": float(np.percentile(lats, 99)),
            }
        return out
