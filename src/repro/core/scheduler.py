"""Multi-app scheduler front-end for the LLM service (paper §2-§3).

Layer 4 of the four-layer design (DESIGN.md §1, §4): the paper's LLMaaS
premise is ONE shared model serving MANY apps, so something above the
service must (a) admit requests from concurrent apps, (b) order them by
user-perceived urgency (foreground interactions ahead of background
agents), and (c) exploit the trace history to predict which context
comes next — the §3.4 ahead-of-time swap-out hint.

``ServiceRouter`` owns per-app sessions and an admission priority
queue.  Model execution is serialized under one lock (one dispatcher
at a time), but each dispatch round drives up to ``decode_batch``
generations at once through the service's batched decode path; with
``start=True`` a dispatcher thread drains the queue so app threads
only enqueue, with ``start=False`` the queue drains inline
(deterministic — used by the benchmarks and tests).

**Batched decode-slice dispatch.**  Each dispatch round forms a decode
BATCH of up to ``decode_batch`` compatible queued jobs (priority
order; two jobs on one context never share a batch; an ``exclusive``
request runs alone) and runs them in bounded slices of
``slice_steps=K`` decode rounds — one batched model step emits a token
for every live generation, so background apps make progress in the
same wall-clock steps the foreground pays for anyway.  Between slices
the dispatcher re-checks the admission queue: finished slots are
REFILLED from compatible queued jobs, and a waiting strictly-higher-
priority request PREEMPTS the lowest-priority slot — that one partial
generation is switched out through the ResidencyEngine
(``LLMService.suspend_call``), re-queued at its original admission
rank, and the newcomer takes its slot while the REST OF THE BATCH
KEEPS DECODING.  Foreground TTFT is therefore bounded by one slice
plus one context switch instead of somebody else's whole generation.
``slice_steps=0`` is the legacy whole-generation dispatch: the batch
formed at dispatch time runs to completion without re-checking the
queue.

**Continuous batching (paged pool).**  When the service decodes over
the paged KV pool (``svc.paged``), batch membership also changes
MID-slice: a generation that finishes frees its batch row that round
and a compatible queued job joins the next round — joining is one
prefill plus a fresh page-table row, and the survivors' caches are
untouched (no merge/split), so the engine never decodes below
capacity while work is queued.  Preemption still happens only at
slice boundaries (``_rebalance``).

``NextContextPredictor`` is a first-order transition table over the
observed context-switch history — the same process that generates the
synthetic traces (trace/synth.py markov pattern), so it is the right
minimal predictor.  After every call the router asks it for the likely
next context and passes the answer to ``ResidencyEngine.prepare_switch``
which protects that context's chunks and AoT-flushes everyone else's.
"""
from __future__ import annotations

import heapq
import math
import threading
import time
from collections import Counter, defaultdict, deque
from concurrent.futures import Future
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.analysis.markers import requires_lock, requires_serialized
from repro.analysis.runtime import witness_condition, witness_rlock

from repro.core.faults import SwapTimeoutError
from repro.core.requests import (BACKGROUND, FOREGROUND,  # noqa: F401
                                 GenerationRequest, GenerationStream,
                                 SamplingParams)

_PRIO_NAMES = {FOREGROUND: "foreground", BACKGROUND: "background"}
_PRIO_BY_NAME = {"foreground": FOREGROUND, "fg": FOREGROUND,
                 "background": BACKGROUND, "bg": BACKGROUND}


def parse_priority(p) -> int:
    if isinstance(p, str):
        return _PRIO_BY_NAME[p.lower()]
    assert p in (FOREGROUND, BACKGROUND), p
    return int(p)


class NextContextPredictor:
    """First-order Markov predictor over the context-switch history."""

    def __init__(self):
        self.trans: Dict[int, Counter] = defaultdict(Counter)
        self.last: Optional[int] = None

    def observe(self, cid: int):
        if self.last is not None:
            self.trans[self.last][cid] += 1
        self.last = cid

    def predict(self, cid: Optional[int] = None) -> Optional[int]:
        """Most likely successor of ``cid`` (default: the latest ctx)."""
        cid = self.last if cid is None else cid
        counts = self.trans.get(cid)
        if not counts:
            return None
        return counts.most_common(1)[0][0]


class AppSession:
    """Per-app handle: all service access goes through the router."""

    def __init__(self, router: "ServiceRouter", name: str, priority: int,
                 family: Optional[str] = None):
        self.router = router
        self.name = name
        self.priority = priority
        # model family this app's contexts bind to (zoo routing); None
        # keeps the single-model service's default
        self.family = family

    def new_ctx(self, system_prompt=None):
        return self.router.new_ctx(self, system_prompt=system_prompt)

    def del_ctx(self, stub):
        return self.router.del_ctx(self, stub)

    def submit(self, stub, prompt, max_new_tokens: int = 16) -> Future:
        """Legacy whole-result admission: -> Future[(stub, tokens)]."""
        return self.router.submit(self, stub, prompt, max_new_tokens)

    def submit_request(self, stub,
                       request: GenerationRequest) -> GenerationStream:
        return self.router.submit_request(self, stub, request)

    def stream(self, stub, prompt, max_new_tokens: int = 16,
               sampling: Optional[SamplingParams] = None,
               priority: Optional[Union[int, str]] = None,
               deadline: Optional[float] = None) -> GenerationStream:
        """Streaming admission: tokens observable as they decode."""
        req = GenerationRequest(prompt=prompt, max_new_tokens=max_new_tokens,
                                sampling=sampling or SamplingParams(),
                                priority=priority, deadline=deadline)
        return self.router.submit_request(self, stub, req)

    def call(self, stub, prompt, max_new_tokens: int = 16):
        """Synchronous convenience: admit + wait for completion."""
        fut = self.submit(stub, prompt, max_new_tokens)
        if not self.router.started:
            self.router.drain()
        return fut.result()


class ServiceRouter:
    """Admission queue + per-app sessions + decode-slice dispatch +
    next-context prediction."""

    def __init__(self, svc, predict: bool = True, start: bool = False,
                 slice_steps: int = 0,
                 clock: Optional[Callable[[], float]] = None,
                 record_limit: Optional[int] = None):
        self.svc = svc
        self.slice_steps = int(slice_steps)
        self.decode_batch = max(1, int(getattr(svc, "decode_batch", 1)))
        # ``clock`` replaces wall time for ALL QoS timestamps (enqueue,
        # start, stream token times): the loadgen virtual-clock driver
        # injects a simulation clock so scheduling metrics are
        # deterministic in the scenario seed.  None = wall clock.
        self._now: Callable[[], float] = clock or time.perf_counter
        # ``record_limit`` bounds the retained per-call dict records
        # (scale harness: 10^5+ calls would otherwise grow without
        # bound); aggregate stats stay exact via the streaming
        # accumulators below.  None keeps full retention.
        self.call_records: Any = (deque(maxlen=record_limit)
                                  if record_limit else [])
        self.predictor = NextContextPredictor() if predict else None
        self.sessions: Dict[str, AppSession] = {}
        self.prefetch_hints = 0
        self.aot_flushes = 0
        self.preemptions = 0
        self.preemptions_by_prio: Counter = Counter()
        self.watchdog_preempts = 0          # hung swaps turned preemptions
        self.bg_shed = 0                    # degraded-mode bg deferrals
        self.decode_rounds = 0              # batched decode rounds run
        self.decoded_tokens = 0             # tokens emitted across rounds
        self.joins_mid_slice = 0            # continuous-batching joins
        # loadgen hooks (None = zero overhead): called inline from the
        # dispatch path, single-threaded under _svc_lock.
        #   on_begin(job, resumed)  after begin_call/resume_call succeeds
        #   on_round(live_jobs)     after each batched decode round,
        #                           BEFORE tokens are pushed to streams
        #   on_preempt(job)         after a slot is preempted
        #   on_complete(job, cancelled)  after finish_call + records
        self.on_begin: Optional[Callable[[dict, bool], None]] = None
        self.on_round: Optional[Callable[[List[dict]], None]] = None
        self.on_preempt: Optional[Callable[[dict], None]] = None
        self.on_complete: Optional[Callable[[dict, bool], None]] = None
        # streaming per-priority accumulators (bounded-record safe):
        # wait = enqueue->begin admission wait, lat = wait + service.
        self._acc: Dict[int, Dict[str, List[float]]] = defaultdict(
            lambda: {"wait": [], "serv": [], "ttft": [], "tbt": []})
        self._acc_preempts: Counter = Counter()     # completed-call sums
        self._acc_cancelled: Counter = Counter()
        # queue-depth samples, one per decode round, deterministically
        # decimated (stride doubles once the buffer fills) so percentile
        # estimates stay bounded at any scale.
        self._qd_samples: List[int] = []
        self._qd_stride = 1
        self._qd_n = 0
        self._qd_max = 0
        self._qd_sum = 0
        self._pred_next: Optional[int] = None
        self._pred_hits = 0
        self._pred_total = 0

        self._cv = witness_condition("scheduler.cv")
        # (prio, deadline|inf, seq, job): priority, then EDF, then FIFO.
        # Preempted jobs are re-pushed under their ORIGINAL key, so a
        # resumed stream runs ahead of later same-priority arrivals.
        self._queue: List[Tuple[int, float, int, dict]] = []
        self._seq = 0
        self._inflight = 0
        self._stop = False
        # serializes ALL service access (the engine's concurrency
        # model: one dispatcher at a time — analysis COARSE_LOCKS)
        self._svc_lock = witness_rlock("scheduler.svc")
        self.started = start
        self._worker = None
        if start:
            self._worker = threading.Thread(
                target=self._loop, name="llms-router", daemon=True)
            self._worker.start()

    # -- app/session management ---------------------------------------- #
    def register_app(self, name: str, priority="foreground",
                     family: Optional[str] = None) -> AppSession:
        sess = AppSession(self, name, parse_priority(priority),
                          family=family)
        self.sessions[name] = sess
        return sess

    def new_ctx(self, session: AppSession, system_prompt=None):
        """Create a context; a system prompt is encoded THROUGH the
        router's dispatch path (inline, ahead of the queue) so
        ``call_records`` and the §3.4 predictor observe it."""
        with self._svc_lock:
            kw = ({"family": session.family}
                  if getattr(session, "family", None) else {})
            stub = self.svc.newLLMCtx(**kw)
        if system_prompt is not None and len(system_prompt):
            req = GenerationRequest(prompt=list(system_prompt),
                                    max_new_tokens=0)
            job = self._make_job(
                session, stub, req,
                GenerationStream(stub.ctx_id, req, clock=self._now), None)
            self._run_job(job)
            err = job["stream"].error
            if err is not None:
                raise err
        return stub

    def del_ctx(self, session: AppSession, stub):
        with self._svc_lock:
            return self.svc.delLLMCtx(stub)

    # -- admission ------------------------------------------------------ #
    def submit(self, session: AppSession, stub, prompt,
               max_new_tokens: int = 16) -> Future:
        """Legacy Future-based admission (compat shim over the stream
        protocol): the Future resolves to (stub, tokens) and supports
        ``cancel()`` while the job is still queued."""
        request = GenerationRequest(prompt=prompt,
                                    max_new_tokens=max_new_tokens)
        fut: Future = Future()
        self._admit(session, stub, request,
                    GenerationStream(stub.ctx_id, request, clock=self._now),
                    fut)
        return fut

    def submit_request(self, session: AppSession, stub,
                       request: GenerationRequest) -> GenerationStream:
        stream = GenerationStream(stub.ctx_id, request, clock=self._now)
        self._admit(session, stub, request, stream, None)
        return stream

    def _make_job(self, session, stub, request, stream, future) -> dict:
        prio = (session.priority if request.priority is None
                else parse_priority(request.priority))
        dl = math.inf if request.deadline is None else float(request.deadline)
        return {"session": session, "stub": stub, "request": request,
                "stream": stream, "future": future, "state": None,
                "prio": prio, "deadline": dl, "seq": -1,
                "t_enqueue": self._now(), "t_start": None}

    def _admit(self, session, stub, request, stream, future):
        job = self._make_job(session, stub, request, stream, future)
        with self._cv:
            if self._stop:
                raise RuntimeError("router is shut down")
            job["seq"] = self._seq
            self._seq += 1
            heapq.heappush(self._queue,
                           (job["prio"], job["deadline"], job["seq"], job))
            self._cv.notify()

    def _requeue(self, job):
        with self._cv:
            heapq.heappush(self._queue,
                           (job["prio"], job["deadline"], job["seq"], job))
            self._cv.notify()

    def _preemptable_head(self, prio: int, active_cids) -> Optional[dict]:
        """The queue-head job, iff it strictly outranks ``prio`` and
        could actually take the freed slot: not on an active context
        (preempting for it would leave a suspended generation the
        newcomer cannot legally overlap — begin_call refuses — and
        finishing first hands it a warm cache anyway), not a fresh call
        on a context with an earlier generation preempted in the queue
        (same overlap rule: ``_pop_locked`` would refuse to seat it, so
        the eviction would be wasted), and not exclusive (an exclusive
        head waits for the engine to drain; evicting one slot of many
        cannot seat it)."""
        with self._cv:
            head = self._queue[0][3] if self._queue else None
            blocked = (head is not None and head["state"] is None
                       and any(k[3]["state"] is not None
                               and k[3]["stub"].ctx_id
                               == head["stub"].ctx_id
                               for k in self._queue))
        if (head is None or head["prio"] >= prio
                or head["stub"].ctx_id in active_cids
                or blocked
                or getattr(head["request"], "exclusive", False)):
            return None
        return head

    def _higher_priority_waiting(self, prio: int, cid: int) -> bool:
        """B=1 compat form of the preemption predicate."""
        return self._preemptable_head(prio, {cid}) is not None

    # -- dispatch -------------------------------------------------------- #
    def _loop(self):
        while True:
            with self._cv:
                while not self._queue and not self._stop:
                    self._cv.wait()
                if self._stop and not self._queue:
                    return
                jobs = self._pop_locked(self.decode_batch, set())
                self._inflight += 1
            try:
                if jobs:
                    self._run_batch(jobs)
            finally:
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()

    @requires_lock("_cv")
    def _pop_locked(self, limit: int, active_cids: set) -> List[dict]:
        """Pop up to ``limit`` batch-compatible jobs in priority order
        (caller holds ``_cv``).  A job is skipped — left queued, order
        preserved — when its context is already decoding in this batch
        (two generations may never overlap one context), when it is a
        FRESH call on a context whose earlier generation sits preempted
        in the queue (``begin_call`` refuses to overlap the suspended
        state — the old generation must resume and finish first), or
        when exclusivity forbids sharing: an ``exclusive`` request only
        runs as the sole member of an empty batch.

        Degraded storage (ResidencyEngine.degraded, DESIGN.md §6) sheds
        BACKGROUND jobs while any FOREGROUND job waits: every admission
        may cost an evict+recompute, so that bandwidth is reserved for
        the user-facing call.  When only background work remains it is
        admitted normally — the drain must keep making progress (and
        keep ticking the probe that exits degraded mode) or the queue
        would livelock."""
        suspended_cids = {k[3]["stub"].ctx_id for k in self._queue
                          if k[3]["state"] is not None}
        degraded = bool(getattr(getattr(self.svc, "res", None),
                                "degraded", False))
        shed_bg = degraded and any(k[3]["prio"] == FOREGROUND
                                   for k in self._queue)
        taken: List[dict] = []
        skipped: List[Tuple] = []
        while self._queue and len(taken) < limit:
            key = heapq.heappop(self._queue)
            job = key[3]
            cid = job["stub"].ctx_id
            exclusive = getattr(job["request"], "exclusive", False)
            if shed_bg and job["prio"] != FOREGROUND:
                skipped.append(key)
                self.bg_shed += 1
                continue
            if exclusive and (taken or active_cids):
                # an exclusive head WAITS for the engine to drain; stop
                # scanning so nothing behind it jumps the line and the
                # batch shrinks toward the empty engine it needs
                heapq.heappush(self._queue, key)
                break
            if cid in active_cids or (job["state"] is None
                                      and cid in suspended_cids):
                skipped.append(key)
                continue
            taken.append(job)
            active_cids.add(cid)
            if exclusive:
                break
        for key in skipped:
            heapq.heappush(self._queue, key)
        return taken

    def _pop_batch(self, limit: int, active_cids: set) -> List[dict]:
        with self._cv:
            return self._pop_locked(limit, active_cids)

    @requires_lock("_svc_lock")
    def _start_job(self, job, active: List[dict]) -> bool:
        """Admit one popped job into the running batch: begin (or
        resume) its generation so it holds a decode slot.  Returns True
        iff the job joined ``active`` (False: cancelled or failed)."""
        stream: GenerationStream = job["stream"]
        fut: Optional[Future] = job["future"]
        if job["state"] is None:
            # t_start doubles as a "future already running" marker: a
            # watchdog-requeued fresh job must not notify its Future a
            # second time (set_running_or_notify_cancel raises once the
            # Future left PENDING)
            if (job["t_start"] is None and fut is not None
                    and not fut.set_running_or_notify_cancel()):
                stream.finish(cancelled=True)
                return False
            if stream.cancel_requested:          # cancelled while queued
                stream.finish(cancelled=True)
                return False
            if job["t_start"] is None:
                job["t_start"] = self._now()
        try:
            st = job["state"]
            if st is None:
                cid = job["stub"].ctx_id
                if self._pred_next is not None:
                    self._pred_total += 1
                    self._pred_hits += self._pred_next == cid
                job["state"] = self.svc.begin_call(job["stub"],
                                                   job["request"])
                if self.on_begin is not None:
                    self.on_begin(job, False)
            elif st.suspended:
                if stream.cancel_requested:      # cancelled while preempted
                    self._complete(job, cancelled=True)
                    return False
                self.svc.resume_call(st)
                if self.on_begin is not None:
                    self.on_begin(job, True)
            active.append(job)
            return True
        except SwapTimeoutError as e:
            # per-slice watchdog (DESIGN.md §6): the switch-in's swap
            # read exceeded swap_deadline_s.  Turn the hang into a
            # preemption — requeue under the original admission key so
            # the job retries ahead of later arrivals — bounded so a
            # permanently wedged store still fails the call
            job["watchdogs"] = job.get("watchdogs", 0) + 1
            if job["watchdogs"] > 3:
                self._fail(job, e)
            else:
                self.watchdog_preempts += 1
                self._requeue(job)
            return False
        except Exception as e:              # report to the submitting app
            self._fail(job, e)
            return False
        except BaseException as e:          # KeyboardInterrupt/SystemExit:
            self._fail(job, e)              # fail the job AND abort dispatch
            raise

    @requires_lock("_svc_lock")
    def _run_slice(self, active: List[dict], refill: bool = False):
        """One decode slice over the running batch: up to ``slice_steps``
        rounds (K=0: until every member is exhausted), each round one
        batched decode emitting one token per live generation.  Jobs
        that finish or cancel leave ``active`` in place; the survivors
        keep decoding.

        With the paged KV pool, membership is CONTINUOUS: a member that
        finishes frees its row this round and a compatible queued job
        joins the very next round — joining is a prefill plus a new
        page-table row, with no cache merge for the survivors, so there
        is no reason to wait for the slice boundary.  (Slot-cache mode
        keeps boundary-only refill via ``_rebalance``; an exclusive
        queue head still blocks refill because ``_pop_locked`` refuses
        to pop it into a non-empty batch.)"""
        K = self.slice_steps
        cont = (refill and K > 0
                and bool(getattr(self.svc, "paged", False)))
        n = 0
        while active and (K <= 0 or n < K):
            live = []
            for job in list(active):
                if job["stream"].cancel_requested:
                    active.remove(job)
                    self._complete(job, cancelled=True)
                elif job["state"].exhausted:
                    active.remove(job)
                    self._complete(job)
                else:
                    live.append(job)
            if (cont and len(live) < self.decode_batch
                    and not any(getattr(j["request"], "exclusive", False)
                                for j in live)):
                cids = {j["stub"].ctx_id for j in live}
                for job in self._pop_batch(self.decode_batch - len(live),
                                           cids):
                    if self._start_job(job, active):
                        if not job["state"].exhausted:
                            live.append(job)
                        self.joins_mid_slice += 1
            if not live:
                return
            toks = self.svc.decode_step_batch([j["state"] for j in live])
            self.decode_rounds += 1
            self.decoded_tokens += sum(t is not None for t in toks)
            self._sample_queue_depth()
            if self.on_round is not None:
                # hook BEFORE the pushes: a virtual clock advanced here
                # stamps this round's tokens at the post-round instant
                self.on_round(live)
            for job, tok in zip(live, toks):
                if tok is not None:
                    job["stream"].push(tok)
                if job["state"].exhausted:
                    active.remove(job)
                    self._complete(job)
            n += 1

    @requires_lock("_svc_lock")
    def _rebalance(self, active: List[dict]):
        """Between slices: evict slots for strictly-higher-priority
        waiters (preemption suspends ONE generation, the rest of the
        batch keeps decoding), then refill free slots from the queue."""
        while active:
            victim = max(active, key=lambda j: (j["prio"], j["seq"]))
            active_cids = {j["stub"].ctx_id for j in active}
            # a waiter can only be seated by eviction when no slot is
            # free — and a running EXCLUSIVE generation blocks every
            # slot, so it counts as a full engine (else a foreground
            # arrival would wait out its whole generation)
            full = (len(active) >= self.decode_batch
                    or any(getattr(j["request"], "exclusive", False)
                           for j in active))
            if not full or self._preemptable_head(
                    victim["prio"], active_cids) is None:
                break
            # suspend BEFORE dropping the victim from ``active``: if the
            # switch-out throws, _run_batch's handler still owns the job
            # and fails it properly (stream resolves, slot released)
            self.svc.suspend_call(victim["state"])
            active.remove(victim)
            victim["stream"].n_preempts += 1
            self.preemptions += 1
            self.preemptions_by_prio[victim["prio"]] += 1
            if self.on_preempt is not None:
                self.on_preempt(victim)
            self._requeue(victim)
        free = self.decode_batch - len(active)
        if free > 0 and not any(getattr(j["request"], "exclusive", False)
                                for j in active):
            cids = {j["stub"].ctx_id for j in active}
            for job in self._pop_batch(free, cids):
                self._start_job(job, active)

    def _run_batch(self, jobs: List[dict],
                   max_slices: Optional[int] = None,
                   refill: bool = True) -> str:
        """Run a batch of popped jobs until every member finishes, is
        cancelled, or is suspended (-> re-queued).  ``max_slices``
        bounds the slices run THIS call (used by ``pump``: the whole
        surviving batch is then suspended and re-queued); preempted/
        paused jobs keep their state and continue from the interrupted
        decode on a later dispatch.  ``refill=False`` pins the batch to
        the given jobs (the inline system-prompt path must not touch
        the queue).  -> "done" | "paused" | "stopped" | "error"."""
        active: List[dict] = []
        try:
            with self._svc_lock:
                for job in jobs:
                    self._start_job(job, active)
                slices = 0
                while active:
                    self._run_slice(active, refill)
                    if not active:
                        break
                    slices += 1
                    if max_slices is not None and slices >= max_slices:
                        # suspend+requeue one at a time, popping as we
                        # go: a mid-loop failure leaves only the
                        # un-suspended jobs in ``active`` for the error
                        # handler (never a job both queued and failed)
                        while active:
                            job = active[-1]
                            self.svc.suspend_call(job["state"])
                            active.pop()
                            self._requeue(job)
                        return "paused"
                    if self._stop:              # abort mid-batch: cancel
                        while active:
                            job = active.pop()
                            self._complete(job, cancelled=True)
                        return "stopped"
                    if self.slice_steps > 0 and refill:
                        self._rebalance(active)
            return "done"
        except Exception as e:      # a failed batched step fails its batch
            for job in active:
                self._fail(job, e)
            return "error"
        except BaseException as e:          # KeyboardInterrupt/SystemExit:
            for job in active:
                self._fail(job, e)
            raise

    def _run_job(self, job, max_slices: Optional[int] = None) -> str:
        """Run one job inline as a solo batch, outside the queue (the
        system-prompt encode path)."""
        return self._run_batch([job], max_slices=max_slices, refill=False)

    @requires_lock("_svc_lock")
    def _complete(self, job, cancelled: bool = False):
        """finish_call + records + prediction hook (under _svc_lock)."""
        st, stream, fut = job["state"], job["stream"], job["future"]
        sess: AppSession = job["session"]
        cid = job["stub"].ctx_id
        self.svc.finish_call(st)
        # capture under the lock: another session's call must not slip a
        # record in between
        rec = self.svc.records[-1] if self.svc.records else {}
        self._after_call(cid)
        t_end = self._now()
        entry = {
            "app": sess.name, "priority": job["prio"], "ctx": cid,
            "wait_s": job["t_start"] - job["t_enqueue"],
            "service_s": t_end - job["t_start"],
            "switch_s": rec.get("switch_s", 0.0),
            "n_preempts": stream.n_preempts,
            "cancelled": cancelled,
        }
        if stream.t_first_token is not None:
            entry["ttft_s"] = stream.t_first_token - job["t_enqueue"]
            tbts = stream.tbt()
            if tbts:
                entry["tbt_mean_s"] = float(np.mean(tbts))
        acc = self._acc[job["prio"]]
        acc["wait"].append(entry["wait_s"])
        acc["serv"].append(entry["service_s"])
        if "ttft_s" in entry:
            acc["ttft"].append(entry["ttft_s"])
        if "tbt_mean_s" in entry:
            acc["tbt"].append(entry["tbt_mean_s"])
        self._acc_preempts[job["prio"]] += stream.n_preempts
        self._acc_cancelled[job["prio"]] += bool(cancelled)
        self.call_records.append(entry)
        stream.finish(cancelled=cancelled)
        if self.on_complete is not None:
            self.on_complete(job, cancelled)
        if fut is not None:
            fut.set_result((job["stub"], list(stream.tokens)))

    def _fail(self, job, err: BaseException):
        st = job["state"]
        if st is not None and not st.done:
            try:                    # best-effort: commit what was decoded
                with self._svc_lock:
                    self.svc.finish_call(st)
            except Exception:
                pass
        job["stream"].finish(error=err)
        if job["future"] is not None:
            job["future"].set_exception(err)

    @requires_lock("_svc_lock")
    def _after_call(self, cid: int):
        """Feed the trace history into the §3.4 AoT swap-out hint."""
        if self.predictor is None:
            return
        self.predictor.observe(cid)
        pred = self.predictor.predict(cid)
        self._pred_next = pred
        if pred is not None:
            self.prefetch_hints += 1
            self.aot_flushes += self.svc.prepare_switch(pred)

    @requires_lock("_svc_lock")
    def _sample_queue_depth(self):
        """One queue-depth sample per decode round.  The sample buffer is
        decimated deterministically (keep-every-2nd, stride doubles) once
        it fills, so percentiles stay available at 10^6-round scale."""
        with self._cv:
            qd = len(self._queue)
        self._qd_n += 1
        self._qd_sum += qd
        if qd > self._qd_max:
            self._qd_max = qd
        if self._qd_n % self._qd_stride == 0:
            self._qd_samples.append(qd)
            if len(self._qd_samples) > 65536:
                self._qd_samples = self._qd_samples[::2]
                self._qd_stride *= 2

    def pump(self, max_slices: int = 1) -> bool:
        """Inline dispatch of at most ``max_slices`` decode slices of a
        batch formed from the highest-priority compatible jobs, then
        return (unfinished members suspend and re-queue).  Deterministic
        building block for tests that need to interleave admissions with
        running generations.  A stopped router never dispatches: after
        ``abort()`` the work it promised to cancel must not run."""
        assert not self.started, "pump() is for inline (start=False) mode"
        if self._stop:
            return False
        jobs = self._pop_batch(self.decode_batch, set())
        if not jobs:
            return False
        self._run_batch(jobs, max_slices=max_slices)
        return True

    def drain(self):
        """Run (or wait for) every admitted job; returns when idle."""
        if self.started:
            with self._cv:
                while self._queue or self._inflight:
                    self._cv.wait()
            return
        while True:
            with self._cv:
                if not self._queue:
                    return
                jobs = self._pop_locked(self.decode_batch, set())
            if jobs:
                self._run_batch(jobs)

    def shutdown(self):
        if self._stop and not self._queue:
            return
        self.drain()
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=10.0)

    def abort(self):
        """Stop WITHOUT draining: queued jobs are cancelled (futures
        cancel, streams finish cancelled), the worker stops after its
        current job.  Used by ``__exit__`` on an exception so unwinding
        doesn't first execute the whole remaining queue."""
        with self._cv:
            self._stop = True
            pending = [j for _, _, _, j in self._queue]
            self._queue.clear()
            self._cv.notify_all()
        for job in pending:
            st = job["state"]
            if st is not None and not st.done:   # suspended mid-generation:
                try:                             # release its context
                    with self._svc_lock:
                        self.svc.finish_call(st)
                except Exception:
                    pass
            if job["future"] is not None:
                job["future"].cancel()
            job["stream"].finish(cancelled=True)
        if self._worker is not None:
            self._worker.join(timeout=10.0)

    def __enter__(self) -> "ServiceRouter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.abort()
        else:
            self.shutdown()

    # -- reporting ------------------------------------------------------- #
    def stats(self) -> Dict[str, Any]:
        """Aggregate QoS stats.  Per-priority sections come from the
        STREAMING accumulators (exact for every completed call even when
        ``record_limit`` bounds the retained per-call dicts)."""
        out: Dict[str, Any] = {
            "prefetch_hints": self.prefetch_hints,
            "aot_flushes": self.aot_flushes,
            "preemptions": self.preemptions,
            "preemptions_by_priority": {
                name: int(self.preemptions_by_prio.get(prio, 0))
                for prio, name in _PRIO_NAMES.items()},
            "watchdog_preempts": self.watchdog_preempts,
            "bg_shed": self.bg_shed,
            "pred_hits": self._pred_hits,
            "pred_total": self._pred_total,
            "decode_batch": self.decode_batch,
            "decode_rounds": self.decode_rounds,
            "decoded_tokens": self.decoded_tokens,
            "joins_mid_slice": self.joins_mid_slice,
            "tokens_per_round": (self.decoded_tokens / self.decode_rounds
                                 if self.decode_rounds else 0.0),
        }
        if self._qd_n:
            qs = self._qd_samples or [0]
            out["queue_depth"] = {
                "samples": self._qd_n,
                "mean": self._qd_sum / self._qd_n,
                "max": self._qd_max,
                "p50": float(np.percentile(qs, 50)),
                "p95": float(np.percentile(qs, 95)),
                "p99": float(np.percentile(qs, 99)),
            }
        for prio, name in _PRIO_NAMES.items():
            acc = self._acc.get(prio)
            if not acc or not acc["wait"]:
                continue
            waits, servs = acc["wait"], acc["serv"]
            lats = [w + s for w, s in zip(waits, servs)]
            out[name] = {
                "calls": len(waits),
                "wait_mean_s": float(np.mean(waits)),
                "wait_p50_s": float(np.percentile(waits, 50)),
                "wait_p95_s": float(np.percentile(waits, 95)),
                "wait_p99_s": float(np.percentile(waits, 99)),
                "service_mean_s": float(np.mean(servs)),
                "latency_mean_s": float(np.mean(lats)),
                "latency_p99_s": float(np.percentile(lats, 99)),
                "preempts": int(self._acc_preempts.get(prio, 0)),
                "cancelled": int(self._acc_cancelled.get(prio, 0)),
            }
            ttfts, tbts = acc["ttft"], acc["tbt"]
            if ttfts:
                out[name]["ttft_mean_s"] = float(np.mean(ttfts))
                out[name]["ttft_p50_s"] = float(np.percentile(ttfts, 50))
                out[name]["ttft_p95_s"] = float(np.percentile(ttfts, 95))
                out[name]["ttft_p99_s"] = float(np.percentile(ttfts, 99))
            if tbts:
                out[name]["tbt_mean_s"] = float(np.mean(tbts))
                out[name]["tbt_p50_s"] = float(np.percentile(tbts, 50))
                out[name]["tbt_p95_s"] = float(np.percentile(tbts, 95))
                out[name]["tbt_p99_s"] = float(np.percentile(tbts, 99))
        return out

    @requires_serialized
    def reset_stats(self):
        """Clear per-call records AND the streaming accumulators (warm
        pass -> measured pass); cumulative counters restart too."""
        self.call_records.clear()
        self._acc.clear()
        self._acc_preempts.clear()
        self._acc_cancelled.clear()
        self.preemptions = 0
        self.preemptions_by_prio.clear()
        self.watchdog_preempts = 0
        self.bg_shed = 0
        self.decode_rounds = 0
        self.decoded_tokens = 0
        self.joins_mid_slice = 0
        self._qd_samples = []
        self._qd_stride = 1
        self._qd_n = self._qd_max = self._qd_sum = 0
