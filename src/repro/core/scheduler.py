"""Multi-app scheduler front-end for the LLM service (paper §2-§3).

Layer 4 of the four-layer design (DESIGN.md §1, §4): the paper's LLMaaS
premise is ONE shared model serving MANY apps, so something above the
service must (a) admit requests from concurrent apps, (b) order them by
user-perceived urgency (foreground interactions ahead of background
agents), and (c) exploit the trace history to predict which context
comes next — the §3.4 ahead-of-time swap-out hint.

``ServiceRouter`` owns per-app sessions and an admission priority
queue.  The underlying model execution stays serial (the paper's
working-set lock: one active context at a time), so the router
serializes all service access under one lock; with ``start=True`` a
dispatcher thread drains the queue so app threads only enqueue, with
``start=False`` the queue drains inline (deterministic — used by the
benchmarks and tests).

**Decode-slice dispatch.**  With ``slice_steps=K`` a generation runs
in bounded slices of K decode steps; between slices the dispatcher
re-checks the admission queue, and a waiting higher-priority request
PREEMPTS the in-flight stream: the partial generation is switched out
through the ResidencyEngine (``LLMService.suspend_call``), the job is
re-queued at its original admission rank, and the foreground request
runs — so foreground TTFT is bounded by one slice plus one context
switch instead of somebody else's whole generation.  ``slice_steps=0``
is the legacy whole-generation dispatch.

``NextContextPredictor`` is a first-order transition table over the
observed context-switch history — the same process that generates the
synthetic traces (trace/synth.py markov pattern), so it is the right
minimal predictor.  After every call the router asks it for the likely
next context and passes the answer to ``ResidencyEngine.prepare_switch``
which protects that context's chunks and AoT-flushes everyone else's.
"""
from __future__ import annotations

import heapq
import math
import threading
import time
from collections import Counter, defaultdict
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.requests import (BACKGROUND, FOREGROUND,  # noqa: F401
                                 GenerationRequest, GenerationStream,
                                 SamplingParams)

_PRIO_NAMES = {FOREGROUND: "foreground", BACKGROUND: "background"}
_PRIO_BY_NAME = {"foreground": FOREGROUND, "fg": FOREGROUND,
                 "background": BACKGROUND, "bg": BACKGROUND}


def parse_priority(p) -> int:
    if isinstance(p, str):
        return _PRIO_BY_NAME[p.lower()]
    assert p in (FOREGROUND, BACKGROUND), p
    return int(p)


class NextContextPredictor:
    """First-order Markov predictor over the context-switch history."""

    def __init__(self):
        self.trans: Dict[int, Counter] = defaultdict(Counter)
        self.last: Optional[int] = None

    def observe(self, cid: int):
        if self.last is not None:
            self.trans[self.last][cid] += 1
        self.last = cid

    def predict(self, cid: Optional[int] = None) -> Optional[int]:
        """Most likely successor of ``cid`` (default: the latest ctx)."""
        cid = self.last if cid is None else cid
        counts = self.trans.get(cid)
        if not counts:
            return None
        return counts.most_common(1)[0][0]


class AppSession:
    """Per-app handle: all service access goes through the router."""

    def __init__(self, router: "ServiceRouter", name: str, priority: int):
        self.router = router
        self.name = name
        self.priority = priority

    def new_ctx(self, system_prompt=None):
        return self.router.new_ctx(self, system_prompt=system_prompt)

    def del_ctx(self, stub):
        return self.router.del_ctx(self, stub)

    def submit(self, stub, prompt, max_new_tokens: int = 16) -> Future:
        """Legacy whole-result admission: -> Future[(stub, tokens)]."""
        return self.router.submit(self, stub, prompt, max_new_tokens)

    def submit_request(self, stub,
                       request: GenerationRequest) -> GenerationStream:
        return self.router.submit_request(self, stub, request)

    def stream(self, stub, prompt, max_new_tokens: int = 16,
               sampling: Optional[SamplingParams] = None,
               priority: Optional[Union[int, str]] = None,
               deadline: Optional[float] = None) -> GenerationStream:
        """Streaming admission: tokens observable as they decode."""
        req = GenerationRequest(prompt=prompt, max_new_tokens=max_new_tokens,
                                sampling=sampling or SamplingParams(),
                                priority=priority, deadline=deadline)
        return self.router.submit_request(self, stub, req)

    def call(self, stub, prompt, max_new_tokens: int = 16):
        """Synchronous convenience: admit + wait for completion."""
        fut = self.submit(stub, prompt, max_new_tokens)
        if not self.router.started:
            self.router.drain()
        return fut.result()


class ServiceRouter:
    """Admission queue + per-app sessions + decode-slice dispatch +
    next-context prediction."""

    def __init__(self, svc, predict: bool = True, start: bool = False,
                 slice_steps: int = 0):
        self.svc = svc
        self.slice_steps = int(slice_steps)
        self.predictor = NextContextPredictor() if predict else None
        self.sessions: Dict[str, AppSession] = {}
        self.call_records: List[Dict[str, Any]] = []
        self.prefetch_hints = 0
        self.aot_flushes = 0
        self.preemptions = 0
        self._pred_next: Optional[int] = None
        self._pred_hits = 0
        self._pred_total = 0

        self._cv = threading.Condition()
        # (prio, deadline|inf, seq, job): priority, then EDF, then FIFO.
        # Preempted jobs are re-pushed under their ORIGINAL key, so a
        # resumed stream runs ahead of later same-priority arrivals.
        self._queue: List[Tuple[int, float, int, dict]] = []
        self._seq = 0
        self._inflight = 0
        self._stop = False
        self._svc_lock = threading.RLock()   # serializes ALL service access
        self.started = start
        self._worker = None
        if start:
            self._worker = threading.Thread(
                target=self._loop, name="llms-router", daemon=True)
            self._worker.start()

    # -- app/session management ---------------------------------------- #
    def register_app(self, name: str, priority="foreground") -> AppSession:
        sess = AppSession(self, name, parse_priority(priority))
        self.sessions[name] = sess
        return sess

    def new_ctx(self, session: AppSession, system_prompt=None):
        """Create a context; a system prompt is encoded THROUGH the
        router's dispatch path (inline, ahead of the queue) so
        ``call_records`` and the §3.4 predictor observe it."""
        with self._svc_lock:
            stub = self.svc.newLLMCtx()
        if system_prompt is not None and len(system_prompt):
            req = GenerationRequest(prompt=list(system_prompt),
                                    max_new_tokens=0)
            job = self._make_job(session, stub, req,
                                 GenerationStream(stub.ctx_id, req), None)
            self._run_job(job)
            err = job["stream"].error
            if err is not None:
                raise err
        return stub

    def del_ctx(self, session: AppSession, stub):
        with self._svc_lock:
            return self.svc.delLLMCtx(stub)

    # -- admission ------------------------------------------------------ #
    def submit(self, session: AppSession, stub, prompt,
               max_new_tokens: int = 16) -> Future:
        """Legacy Future-based admission (compat shim over the stream
        protocol): the Future resolves to (stub, tokens) and supports
        ``cancel()`` while the job is still queued."""
        request = GenerationRequest(prompt=prompt,
                                    max_new_tokens=max_new_tokens)
        fut: Future = Future()
        self._admit(session, stub, request,
                    GenerationStream(stub.ctx_id, request), fut)
        return fut

    def submit_request(self, session: AppSession, stub,
                       request: GenerationRequest) -> GenerationStream:
        stream = GenerationStream(stub.ctx_id, request)
        self._admit(session, stub, request, stream, None)
        return stream

    def _make_job(self, session, stub, request, stream, future) -> dict:
        prio = (session.priority if request.priority is None
                else parse_priority(request.priority))
        dl = math.inf if request.deadline is None else float(request.deadline)
        return {"session": session, "stub": stub, "request": request,
                "stream": stream, "future": future, "state": None,
                "prio": prio, "deadline": dl, "seq": -1,
                "t_enqueue": time.perf_counter(), "t_start": None}

    def _admit(self, session, stub, request, stream, future):
        job = self._make_job(session, stub, request, stream, future)
        with self._cv:
            if self._stop:
                raise RuntimeError("router is shut down")
            job["seq"] = self._seq
            self._seq += 1
            heapq.heappush(self._queue,
                           (job["prio"], job["deadline"], job["seq"], job))
            self._cv.notify()

    def _requeue(self, job):
        with self._cv:
            heapq.heappush(self._queue,
                           (job["prio"], job["deadline"], job["seq"], job))
            self._cv.notify()

    def _higher_priority_waiting(self, prio: int, cid: int) -> bool:
        """A strictly higher-priority job is queued — unless it targets
        the SAME context: preempting for it would leave a suspended
        generation the newcomer cannot legally overlap (begin_call
        refuses), and finishing first hands it a warm cache anyway."""
        with self._cv:
            if not self._queue or self._queue[0][0] >= prio:
                return False
            return self._queue[0][3]["stub"].ctx_id != cid

    # -- dispatch -------------------------------------------------------- #
    def _loop(self):
        while True:
            with self._cv:
                while not self._queue and not self._stop:
                    self._cv.wait()
                if self._stop and not self._queue:
                    return
                _, _, _, job = heapq.heappop(self._queue)
                self._inflight += 1
            try:
                self._run_job(job)
            finally:
                with self._cv:
                    self._inflight -= 1
                    self._cv.notify_all()

    def _run_job(self, job, max_slices: Optional[int] = None) -> str:
        """Run one job until it finishes, is cancelled, or is preempted
        (-> re-queued).  ``max_slices`` bounds the slices run THIS call
        (used by ``pump``); preempted/paused jobs keep their state and
        continue from the interrupted decode on the next dispatch.
        -> "done" | "cancelled" | "preempted" | "paused" | "error"."""
        stream: GenerationStream = job["stream"]
        fut: Optional[Future] = job["future"]
        K = self.slice_steps
        if job["state"] is None:
            if fut is not None and not fut.set_running_or_notify_cancel():
                stream.finish(cancelled=True)
                return "cancelled"
            if stream.cancel_requested:          # cancelled while queued
                stream.finish(cancelled=True)
                return "cancelled"
            job["t_start"] = time.perf_counter()
        try:
            with self._svc_lock:
                st = job["state"]
                if st is None:
                    cid = job["stub"].ctx_id
                    if self._pred_next is not None:
                        self._pred_total += 1
                        self._pred_hits += self._pred_next == cid
                    st = job["state"] = self.svc.begin_call(
                        job["stub"], job["request"])
                elif st.suspended:
                    if stream.cancel_requested:  # cancelled while preempted
                        self._complete(job, cancelled=True)
                        return "cancelled"
                    self.svc.resume_call(st)

                slices = 0
                while True:
                    n = 0
                    while K <= 0 or n < K:       # one slice (K=0: no bound)
                        if stream.cancel_requested:
                            self._complete(job, cancelled=True)
                            return "cancelled"
                        tok = self.svc.decode_step(st)
                        if tok is None:
                            break
                        stream.push(tok)
                        n += 1
                    if st.exhausted:
                        self._complete(job)
                        return "done"
                    slices += 1
                    if max_slices is not None and slices >= max_slices:
                        self.svc.suspend_call(st)
                        self._requeue(job)
                        return "paused"
                    if K > 0 and self._higher_priority_waiting(
                            job["prio"], job["stub"].ctx_id):
                        self.svc.suspend_call(st)
                        stream.n_preempts += 1
                        self.preemptions += 1
                        self._requeue(job)
                        return "preempted"
        except Exception as e:              # report to the submitting app
            self._fail(job, e)
            return "error"
        except BaseException as e:          # KeyboardInterrupt/SystemExit:
            self._fail(job, e)              # fail the job AND abort dispatch
            raise

    def _complete(self, job, cancelled: bool = False):
        """finish_call + records + prediction hook (under _svc_lock)."""
        st, stream, fut = job["state"], job["stream"], job["future"]
        sess: AppSession = job["session"]
        cid = job["stub"].ctx_id
        self.svc.finish_call(st)
        # capture under the lock: another session's call must not slip a
        # record in between
        rec = self.svc.records[-1] if self.svc.records else {}
        self._after_call(cid)
        t_end = time.perf_counter()
        entry = {
            "app": sess.name, "priority": job["prio"], "ctx": cid,
            "wait_s": job["t_start"] - job["t_enqueue"],
            "service_s": t_end - job["t_start"],
            "switch_s": rec.get("switch_s", 0.0),
            "n_preempts": stream.n_preempts,
            "cancelled": cancelled,
        }
        if stream.t_first_token is not None:
            entry["ttft_s"] = stream.t_first_token - job["t_enqueue"]
            tbts = stream.tbt()
            if tbts:
                entry["tbt_mean_s"] = float(np.mean(tbts))
        self.call_records.append(entry)
        stream.finish(cancelled=cancelled)
        if fut is not None:
            fut.set_result((job["stub"], list(stream.tokens)))

    def _fail(self, job, err: BaseException):
        st = job["state"]
        if st is not None and not st.done:
            try:                    # best-effort: commit what was decoded
                with self._svc_lock:
                    self.svc.finish_call(st)
            except Exception:
                pass
        job["stream"].finish(error=err)
        if job["future"] is not None:
            job["future"].set_exception(err)

    def _after_call(self, cid: int):
        """Feed the trace history into the §3.4 AoT swap-out hint."""
        if self.predictor is None:
            return
        self.predictor.observe(cid)
        pred = self.predictor.predict(cid)
        self._pred_next = pred
        if pred is not None:
            self.prefetch_hints += 1
            self.aot_flushes += self.svc.prepare_switch(pred)

    def pump(self, max_slices: int = 1) -> bool:
        """Inline dispatch of at most ``max_slices`` decode slices of the
        highest-priority job, then return (the job re-queues if it isn't
        finished).  Deterministic building block for tests that need to
        interleave admissions with a running generation."""
        assert not self.started, "pump() is for inline (start=False) mode"
        with self._cv:
            if not self._queue:
                return False
            _, _, _, job = heapq.heappop(self._queue)
        self._run_job(job, max_slices=max_slices)
        return True

    def drain(self):
        """Run (or wait for) every admitted job; returns when idle."""
        if self.started:
            with self._cv:
                while self._queue or self._inflight:
                    self._cv.wait()
            return
        while True:
            with self._cv:
                if not self._queue:
                    return
                _, _, _, job = heapq.heappop(self._queue)
            self._run_job(job)

    def shutdown(self):
        if self._stop and not self._queue:
            return
        self.drain()
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=10.0)

    def abort(self):
        """Stop WITHOUT draining: queued jobs are cancelled (futures
        cancel, streams finish cancelled), the worker stops after its
        current job.  Used by ``__exit__`` on an exception so unwinding
        doesn't first execute the whole remaining queue."""
        with self._cv:
            self._stop = True
            pending = [j for _, _, _, j in self._queue]
            self._queue.clear()
            self._cv.notify_all()
        for job in pending:
            st = job["state"]
            if st is not None and not st.done:   # suspended mid-generation:
                try:                             # release its context
                    with self._svc_lock:
                        self.svc.finish_call(st)
                except Exception:
                    pass
            if job["future"] is not None:
                job["future"].cancel()
            job["stream"].finish(cancelled=True)
        if self._worker is not None:
            self._worker.join(timeout=10.0)

    def __enter__(self) -> "ServiceRouter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.abort()
        else:
            self.shutdown()

    # -- reporting ------------------------------------------------------- #
    def stats(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "prefetch_hints": self.prefetch_hints,
            "aot_flushes": self.aot_flushes,
            "preemptions": self.preemptions,
            "pred_hits": self._pred_hits,
            "pred_total": self._pred_total,
        }
        for prio, name in _PRIO_NAMES.items():
            rs = [r for r in self.call_records if r["priority"] == prio]
            if not rs:
                continue
            waits = [r["wait_s"] for r in rs]
            servs = [r["service_s"] for r in rs]
            lats = [w + s for w, s in zip(waits, servs)]
            out[name] = {
                "calls": len(rs),
                "wait_mean_s": float(np.mean(waits)),
                "service_mean_s": float(np.mean(servs)),
                "latency_mean_s": float(np.mean(lats)),
                "latency_p99_s": float(np.percentile(lats, 99)),
                "preempts": int(sum(r.get("n_preempts", 0) for r in rs)),
            }
            ttfts = [r["ttft_s"] for r in rs if "ttft_s" in r]
            if ttfts:
                out[name]["ttft_mean_s"] = float(np.mean(ttfts))
                out[name]["ttft_p50_s"] = float(np.percentile(ttfts, 50))
                out[name]["ttft_p95_s"] = float(np.percentile(ttfts, 95))
                out[name]["ttft_p99_s"] = float(np.percentile(ttfts, 99))
            tbts = [r["tbt_mean_s"] for r in rs if "tbt_mean_s" in r]
            if tbts:
                out[name]["tbt_mean_s"] = float(np.mean(tbts))
                out[name]["tbt_p95_s"] = float(np.percentile(tbts, 95))
        return out
