"""Chunk lifecycle management (paper §3.4).

LCTRU queue — "Least Compression-Tolerable and Recently-Used" — is a
concatenation of per-compression-level sub-queues, heaviest (least
compressed) level first, each ordered by last access (LRU at the front).
Eviction pops from the heavy end: heavy chunks free the most memory per
eviction AND are the best swapping-recompute pipeline candidates
(Eq. 4: pipeline delay falls with the number of missing chunks at a
given byte size).

AoT swap-out and the working-set lock live in the service; this module
owns only the eviction order plus the Claim/Reclaim bookkeeping.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, Optional, Set, Tuple

from repro.analysis.markers import requires_serialized

Key = Tuple[int, int]              # (ctx_id, chunk_idx)

# heaviest first: uncompressed, then 8-bit, 4-bit, 2-bit
LEVEL_ORDER = (16, 8, 4, 2)


class LCTRUQueue:
    def __init__(self, lru_only: bool = False):
        """lru_only=True degrades to a flat LRU (the VLLM-S/SQ baselines)."""
        self.lru_only = lru_only
        self.queues: Dict[int, OrderedDict] = {
            lvl: OrderedDict() for lvl in LEVEL_ORDER}
        self.flat: OrderedDict = OrderedDict()
        self.level_of: Dict[Key, int] = {}

    def touch(self, key: Key, level: int):
        """Record an access (moves to the recently-used end)."""
        old = self.level_of.get(key)
        if old is not None:
            self.queues[old].pop(key, None)
            self.flat.pop(key, None)
        self.level_of[key] = level
        self.queues[level][key] = None
        self.flat[key] = None

    def remove(self, key: Key):
        lvl = self.level_of.pop(key, None)
        if lvl is not None:
            self.queues[lvl].pop(key, None)
            self.flat.pop(key, None)

    def pop(self, skip: Optional[Callable[[Key], bool]] = None
            ) -> Optional[Key]:
        """Pop the next eviction victim; ``skip`` protects locked keys."""
        if self.lru_only:
            for key in self.flat:
                if skip is None or not skip(key):
                    self.remove(key)
                    return key
            return None
        for lvl in LEVEL_ORDER:
            for key in self.queues[lvl]:
                if skip is None or not skip(key):
                    self.remove(key)
                    return key
        return None

    def __len__(self):
        return len(self.level_of)


class MemoryManager:
    """Byte-budget accounting over in-memory (compressed) chunks."""

    def __init__(self, budget: int, queue: LCTRUQueue):
        self.budget = budget
        self.used = 0
        self.queue = queue
        self._sizes: Dict[Key, int] = {}

    @requires_serialized
    def register(self, key: Key, nbytes: int, level: int):
        if key in self._sizes:
            self.used -= self._sizes[key]
        self._sizes[key] = nbytes
        self.used += nbytes
        self.queue.touch(key, level)

    @requires_serialized
    def unregister(self, key: Key):
        n = self._sizes.pop(key, None)
        if n is not None:
            self.used -= n
        self.queue.remove(key)

    def over_budget(self, extra: int = 0) -> bool:
        return self.used + extra > self.budget

    @requires_serialized
    def reclaim(self, need: int, evict: Callable[[Key], None],
                locked: Set[int]) -> int:
        """Evict until ``need`` extra bytes fit.  ``evict`` drops the chunk
        (clean chunks are free to drop thanks to AoT swap-out).  Returns
        bytes freed."""
        freed = 0
        while self.used + need > self.budget:
            key = self.queue.pop(skip=lambda k: k[0] in locked)
            if key is None:
                break                               # nothing evictable
            n = self._sizes.get(key, 0)
            evict(key)
            self.unregister(key)
            freed += n
        return freed
