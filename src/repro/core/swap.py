"""Disk swap tier (paper §3.1 primitives; pickle-backed like the paper's
prototype) with an async writer used by AoT swapping (§3.4).

On a real TPU pod this is the host-DRAM/remote-store offload tier; the
interface is the same (DESIGN.md §3).  All I/O happens on a dedicated
thread pool so ``callLLM`` returns without waiting for swap-out — only
``flush()`` (or a later read of the same key) synchronizes.
"""
from __future__ import annotations

import os
import pickle
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional, Tuple

Key = Tuple[int, Any]              # (ctx_id, chunk_idx | "state")


class DiskStore:
    """Pickle-per-key chunk store with byte accounting."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._bytes: Dict[Key, int] = {}
        self._lock = threading.Lock()

    def _path(self, key: Key) -> str:
        ctx, idx = key
        return os.path.join(self.root, f"ctx{ctx}_chunk{idx}.pkl")

    def write(self, key: Key, obj: Any) -> int:
        from repro.core.restore import _throttle, count_io
        blob = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
        tmp = self._path(key) + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        os.replace(tmp, self._path(key))          # atomic
        count_io("write", len(blob))
        _throttle(len(blob))
        with self._lock:
            self._bytes[key] = len(blob)
        return len(blob)

    def read(self, key: Key) -> Any:
        from repro.core.restore import _throttle, count_io
        with open(self._path(key), "rb") as f:
            blob = f.read()
        count_io("read", len(blob))
        _throttle(len(blob))
        return pickle.loads(blob)

    def delete(self, key: Key):
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass
        with self._lock:
            self._bytes.pop(key, None)

    def nbytes(self, key: Key) -> Optional[int]:
        return self._bytes.get(key)

    @property
    def total_bytes(self) -> int:
        # snapshot under the lock: concurrent writers mutate the dict
        # mid-sum otherwise (RuntimeError / torn totals)
        with self._lock:
            return sum(self._bytes.values())


class AsyncSwapper:
    """AoT swap-out executor + pipelined swap-in reads."""

    def __init__(self, store: DiskStore, workers: int = 2):
        self.store = store
        self.pool = ThreadPoolExecutor(max_workers=workers,
                                       thread_name_prefix="llms-io")
        self._pending: Dict[Key, Future] = {}
        self._lock = threading.Lock()

    def submit(self, key: Key, fn, *args) -> Future:
        """Track an arbitrary I/O job under ``key`` so flush() waits.

        Same-key jobs are SERIALIZED (a later write must not be overtaken
        by an earlier in-flight one) but never block the submitting
        thread: the new job is chained onto the previous future via a
        done-callback instead of ``prev.result()``, so AoT swap-out stays
        asynchronous even under same-key write bursts (paper §3.4)."""
        out: Future = Future()
        with self._lock:
            prev = self._pending.get(key)
            self._pending[key] = out

        def _start(_=None):
            try:
                inner = self.pool.submit(fn, *args)
            except RuntimeError as e:              # pool already shut down
                out.set_exception(e)
                return

            def _copy(f: Future):
                err = f.exception()
                if err is not None:
                    out.set_exception(err)
                else:
                    out.set_result(f.result())
            inner.add_done_callback(_copy)

        def _done(_):
            with self._lock:
                if self._pending.get(key) is out:
                    del self._pending[key]
        out.add_done_callback(_done)
        if prev is None:
            _start()
        else:
            prev.add_done_callback(_start)         # chain, don't block
        return out

    def write_async(self, key: Key, obj: Any) -> Future:
        return self.submit(key, self.store.write, key, obj)

    def read(self, key: Key) -> Any:
        """Synchronous read; blocks the CALLER (never a pool worker) on
        any in-flight same-key write."""
        with self._lock:
            fut = self._pending.get(key)
        if fut is not None:
            fut.result()                           # wait for in-flight write
        return self.store.read(key)

    def wait(self, key: Key):
        """Block the CALLER (never a pool worker) until any in-flight
        same-key job completes.  A failed write surfaces here, like the
        blocking ``read``."""
        with self._lock:
            fut = self._pending.get(key)
        if fut is not None:
            fut.result()

    def read_async(self, key: Key) -> Future:
        """Read on the pool, AFTER any in-flight same-key write.

        The read is chained off the pending write future (like same-key
        writes in ``submit``), never submitted as a worker that blocks
        on it: a worker parked in ``fut.result()`` while the chained
        write sits queued behind it deadlocks the pool outright with
        ``workers=1`` (and with N workers, N concurrent blocking reads).
        """
        with self._lock:
            prev = self._pending.get(key)
        if prev is None:
            return self.pool.submit(self.store.read, key)
        out: Future = Future()

        def _start(f: Future):
            werr = f.exception()
            if werr is not None:
                # parity with the blocking ``read`` (whose fut.result()
                # raises): a failed write must surface, not be papered
                # over with whatever stale bytes are on disk
                out.set_exception(werr)
                return
            try:
                inner = self.pool.submit(self.store.read, key)
            except RuntimeError as e:              # pool already shut down
                out.set_exception(e)
                return

            def _copy(f: Future):
                err = f.exception()
                if err is not None:
                    out.set_exception(err)
                else:
                    out.set_result(f.result())
            inner.add_done_callback(_copy)

        prev.add_done_callback(_start)             # chain, don't block
        return out

    def flush(self):
        with self._lock:
            futs = list(self._pending.values())
        for f in futs:
            f.result()

    def shutdown(self):
        self.flush()
        self.pool.shutdown(wait=True)
