"""Disk swap tier (paper §3.1 primitives; pickle-backed like the paper's
prototype) with an async writer used by AoT swapping (§3.4).

On a real TPU pod this is the host-DRAM/remote-store offload tier; the
interface is the same (DESIGN.md §3).  All I/O happens on a dedicated
thread pool so ``callLLM`` returns without waiting for swap-out — only
``flush()`` (or a later read of the same key) synchronizes.

Fault tolerance (DESIGN.md §6): every file carries a checksummed
preamble (magic, version, CRC32, payload length) so torn writes and
bit-flips surface as ``ChunkCorruptError`` instead of unpickling
garbage; worker jobs retry transient IO errors with bounded exponential
backoff; ``wait``/``flush`` take a watchdog timeout; startup sweeps
orphaned ``*.tmp`` files left by a crash between temp-write and the
atomic replace.
"""
from __future__ import annotations

import os
import pickle
import struct
import time
import zlib
from concurrent.futures import Future, InvalidStateError, ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutTimeout
from typing import Any, Callable, Dict, Optional, Tuple

from repro.analysis.markers import requires_lock
from repro.analysis.runtime import witness_lock
from repro.core.faults import (FAULTS, ChunkCorruptError, SwapTimeoutError,
                               with_retries)

Key = Tuple[int, Any]              # (ctx_id, chunk_idx | "state")

# pickle-blob envelope: magic, version, reserved, CRC32(payload), length
_MAGIC = b"LLMP"
_VERSION = 1
_PREAMBLE = struct.Struct("<4sHHIQ")


def seal_blob(blob: bytes) -> bytes:
    return _PREAMBLE.pack(_MAGIC, _VERSION, 0, zlib.crc32(blob),
                          len(blob)) + blob


def open_blob(raw: bytes, what: str) -> bytes:
    """Verify the envelope; raises ChunkCorruptError on any mismatch."""
    if len(raw) < _PREAMBLE.size:
        raise ChunkCorruptError(f"{what}: truncated preamble "
                                f"({len(raw)} bytes)")
    magic, ver, _, crc, plen = _PREAMBLE.unpack_from(raw)
    if magic != _MAGIC:
        raise ChunkCorruptError(f"{what}: bad magic {magic!r}")
    if ver != _VERSION:
        raise ChunkCorruptError(f"{what}: unknown version {ver}")
    blob = raw[_PREAMBLE.size:]
    if len(blob) != plen:
        raise ChunkCorruptError(f"{what}: truncated payload "
                                f"({len(blob)} of {plen} bytes)")
    if zlib.crc32(blob) != crc:
        raise ChunkCorruptError(f"{what}: CRC32 mismatch")
    return blob


def sweep_tmp_files(root: str) -> int:
    """Remove orphaned ``*.tmp`` files (a crash between temp-write and
    ``os.replace`` leaves one; it must never be read)."""
    swept = 0
    try:
        names = os.listdir(root)
    except FileNotFoundError:
        return 0
    for fn in names:
        if fn.endswith(".tmp"):
            try:
                os.remove(os.path.join(root, fn))
                swept += 1
            except OSError:
                pass
    return swept


class DiskStore:
    """Pickle-per-key chunk store with byte accounting and a checksummed
    file envelope."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.tmp_swept = sweep_tmp_files(root)
        self.delete_errors = 0
        self._bytes: Dict[Key, int] = {}
        self._lock = witness_lock("store.bytes")

    def _path(self, key: Key) -> str:
        ctx, idx = key
        return os.path.join(self.root, f"ctx{ctx}_chunk{idx}.pkl")

    def write(self, key: Key, obj: Any) -> int:
        from repro.core.restore import _throttle, count_io
        FAULTS.check("disk.write", key)
        raw = seal_blob(pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL))
        tmp = self._path(key) + ".tmp"
        with open(tmp, "wb") as f:
            f.write(raw)
        action = FAULTS.corrupt_action(key)
        if action is not None:
            from repro.core.faults import corrupt_file
            corrupt_file(tmp, action)
        os.replace(tmp, self._path(key))          # atomic
        FAULTS.note_write_ok(key)
        count_io("write", len(raw))
        _throttle(len(raw))
        with self._lock:
            self._bytes[key] = len(raw)
        return len(raw)

    def read(self, key: Key) -> Any:
        from repro.core.restore import _throttle, count_io
        FAULTS.check("disk.read", key)
        with open(self._path(key), "rb") as f:
            raw = f.read()
        count_io("read", len(raw))
        _throttle(len(raw))
        return pickle.loads(open_blob(raw, f"state {key}"))

    def delete(self, key: Key):
        try:
            FAULTS.check("disk.delete", key)
            os.remove(self._path(key))
        except FileNotFoundError:
            pass
        except OSError:
            # best-effort: a failed delete only leaks a file; the byte
            # accounting below still drops the key
            with self._lock:
                self.delete_errors += 1
        with self._lock:
            self._bytes.pop(key, None)

    def set_bytes(self, key: Key, n: int):
        """Record ``key``'s on-disk size (accounting only — callers that
        write through a path other than ``write()``, e.g. the chunk-file
        envelope writers, report their byte count here)."""
        with self._lock:
            self._bytes[key] = n

    def drop_bytes(self, key: Key):
        with self._lock:
            self._bytes.pop(key, None)

    def nbytes(self, key: Key) -> Optional[int]:
        return self._bytes.get(key)

    @property
    def total_bytes(self) -> int:
        # snapshot under the lock: concurrent writers mutate the dict
        # mid-sum otherwise (RuntimeError / torn totals)
        with self._lock:
            return sum(self._bytes.values())


class AsyncSwapper:
    """AoT swap-out executor + pipelined swap-in reads.

    Worker jobs classify IO errors and retry transient ones with
    bounded exponential backoff (``retries`` attempts per op); counters
    ``io_retries`` / ``io_recovered`` / ``io_failed`` feed the service
    fault stats.  ``on_job_error`` (if set) is invoked with
    ``(key, err)`` when a job exhausts its budget — the residency layer
    uses it to flip into degraded mode on ENOSPC."""

    def __init__(self, store: DiskStore, workers: int = 2,
                 retries: int = 3, retry_base_s: float = 0.002):
        self.store = store
        self.retries = max(1, int(retries))
        self.retry_base_s = retry_base_s
        self.pool = ThreadPoolExecutor(max_workers=workers,
                                       thread_name_prefix="llms-io")
        self._pending: Dict[Key, Future] = {}
        self._lock = witness_lock("swap.pending")
        self._shutdown = False
        self.on_job_error: Optional[Callable[[Key, BaseException],
                                             None]] = None
        self.io_retries = 0
        self.io_recovered = 0
        self.io_failed = 0

    # -- io-stat counters (shared: workers + router fault stats) -------- #
    @requires_lock("_lock")
    def _note_retries_locked(self, tries: int, recovered: bool = False,
                             failed: bool = False):
        self.io_retries += tries
        if recovered:
            self.io_recovered += 1
        if failed:
            self.io_failed += 1

    def note_retry(self):
        """One transient-IO retry observed OUTSIDE a pool job (the
        residency layer's own retry loops report through here)."""
        with self._lock:
            self.io_retries += 1

    def note_io_failure(self):
        """One exhausted-retry failure observed outside a pool job."""
        with self._lock:
            self.io_failed += 1

    # -- retry wrapper (runs ON a pool worker) -------------------------- #
    def _run_job(self, key: Key, fn, args):
        tries = 0

        def _once():
            FAULTS.check("swap.worker", key)
            return fn(*args)

        def _on_retry(_k, _e):
            nonlocal tries
            tries += 1

        try:
            out = with_retries(_once, attempts=self.retries,
                               base_s=self.retry_base_s,
                               on_retry=_on_retry)
        except Exception as e:
            with self._lock:
                self._note_retries_locked(tries, failed=True)
            cb = self.on_job_error
            if cb is not None:
                try:
                    cb(key, e)
                except Exception:
                    pass
            raise
        with self._lock:
            self._note_retries_locked(tries, recovered=bool(tries))
        return out

    @staticmethod
    def _settle(out: Future, f: Future):
        """Copy a finished inner future into ``out``, tolerating an
        ``out`` that shutdown() already cancelled."""
        try:
            err = f.exception()
            if err is not None:
                out.set_exception(err)
            else:
                out.set_result(f.result())
        except InvalidStateError:
            pass

    def submit(self, key: Key, fn, *args) -> Future:
        """Track an arbitrary I/O job under ``key`` so flush() waits.

        Same-key jobs are SERIALIZED (a later write must not be overtaken
        by an earlier in-flight one) but never block the submitting
        thread: the new job is chained onto the previous future via a
        done-callback instead of ``prev.result()``, so AoT swap-out stays
        asynchronous even under same-key write bursts (paper §3.4)."""
        out: Future = Future()
        with self._lock:
            prev = self._pending.get(key)
            self._pending[key] = out

        def _start(_=None):
            if out.cancelled():
                return
            if self._shutdown:
                out.cancel()
                return
            out._llms_started = True
            try:
                inner = self.pool.submit(self._run_job, key, fn, args)
            except RuntimeError as e:              # pool already shut down
                self._settle_err(out, e)
                return
            inner.add_done_callback(lambda f: self._settle(out, f))

        def _done(_):
            with self._lock:
                if self._pending.get(key) is out:
                    del self._pending[key]
        out.add_done_callback(_done)
        if prev is None:
            _start()
        else:
            prev.add_done_callback(_start)         # chain, don't block
        return out

    @staticmethod
    def _settle_err(out: Future, e: BaseException):
        try:
            out.set_exception(e)
        except InvalidStateError:
            pass

    def write_async(self, key: Key, obj: Any) -> Future:
        return self.submit(key, self.store.write, key, obj)

    def read(self, key: Key, timeout: Optional[float] = None) -> Any:
        """Synchronous read; blocks the CALLER (never a pool worker) on
        any in-flight same-key write.  Transient IO errors on the read
        itself are retried with the worker budget."""
        self.wait(key, timeout=timeout)
        tries = 0

        def _on_retry(_k, _e):
            nonlocal tries
            tries += 1
        try:
            out = with_retries(lambda: self.store.read(key),
                               attempts=self.retries,
                               base_s=self.retry_base_s,
                               on_retry=_on_retry)
        finally:
            with self._lock:
                self._note_retries_locked(tries)
        if tries:
            with self._lock:
                self.io_recovered += 1
        return out

    def wait(self, key: Key, timeout: Optional[float] = None):
        """Block the CALLER (never a pool worker) until any in-flight
        same-key job completes.  A failed write surfaces here, like the
        blocking ``read``; a wedged job surfaces as SwapTimeoutError
        once ``timeout`` (the watchdog deadline) expires."""
        with self._lock:
            fut = self._pending.get(key)
        if fut is not None:
            try:
                fut.result(timeout)
            except _FutTimeout:
                raise SwapTimeoutError(
                    f"swap wait exceeded {timeout}s for {key}") from None

    def read_async(self, key: Key) -> Future:
        """Read on the pool, AFTER any in-flight same-key write.

        The read is chained off the pending write future (like same-key
        writes in ``submit``), never submitted as a worker that blocks
        on it: a worker parked in ``fut.result()`` while the chained
        write sits queued behind it deadlocks the pool outright with
        ``workers=1`` (and with N workers, N concurrent blocking reads).
        """
        with self._lock:
            prev = self._pending.get(key)
        if prev is None:
            return self.pool.submit(self._run_job, key, self.store.read,
                                    (key,))
        out: Future = Future()

        def _start(f: Future):
            if out.cancelled():
                return
            if f.cancelled():
                out.cancel()
                return
            werr = f.exception()
            if werr is not None:
                # parity with the blocking ``read`` (whose fut.result()
                # raises): a failed write must surface, not be papered
                # over with whatever stale bytes are on disk
                self._settle_err(out, werr)
                return
            out._llms_started = True
            try:
                inner = self.pool.submit(self._run_job, key,
                                         self.store.read, (key,))
            except RuntimeError as e:              # pool already shut down
                self._settle_err(out, e)
                return
            inner.add_done_callback(lambda g: self._settle(out, g))

        prev.add_done_callback(_start)             # chain, don't block
        return out

    def flush(self, timeout: Optional[float] = None,
              raise_errors: bool = True):
        """Wait for every pending job.  ``timeout`` bounds the TOTAL
        wait (SwapTimeoutError past the deadline); with
        ``raise_errors=False`` failed jobs are swallowed (their errors
        were already counted/classified on the worker)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            futs = list(self._pending.values())
        for f in futs:
            left = None
            if deadline is not None:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise SwapTimeoutError(
                        f"flush exceeded {timeout}s "
                        f"({len(futs)} jobs pending)")
            try:
                f.result(left)
            except _FutTimeout:
                raise SwapTimeoutError(
                    f"flush exceeded {timeout}s") from None
            except Exception:
                if raise_errors:
                    raise

    def shutdown(self, timeout: Optional[float] = None):
        """Flush (bounded by ``timeout``), then CANCEL chained jobs that
        never started rather than orphaning them behind a wedged
        predecessor, and stop the pool."""
        wedged = False
        try:
            self.flush(timeout=timeout, raise_errors=False)
        except SwapTimeoutError:
            wedged = True
        self._shutdown = True
        with self._lock:
            pending = list(self._pending.values())
        for f in pending:
            if not getattr(f, "_llms_started", False):
                f.cancel()
        self.pool.shutdown(wait=not wedged)
