"""Swapping-recompute pipelined restore (paper §3.3, Fig. 8).

The paper overlaps disk I/O with recompute at LAYER granularity: "the
computation thread proceeds to the next layer only after the I/O thread
for the current layer has completed".  To make that real (not
whole-chunk-then-compute), chunk files are written in a **layer-major
segmented format**: a pickled header + per-layer raw segments, so the
I/O thread can stream layer l of every swapped chunk, dequantize it in
numpy, and publish it while layer l-1 is still being recomputed.  The
jitted recompute scan pulls layer l's I/O data through an ordered
``jax.experimental.io_callback`` (``LayerFeed.fetch``).

Layout per chunk file:
    [preamble][u64 header_len][pickle header][layer 0 segment]...
    preamble   = magic "LLMK", version, CRC32(header region), body length
    segment l  = for each leaf: packed[(F_l rows) x T'] bytes
                 + scales[F_l] fp32 bytes
where packed is stored TRANSPOSED (F, T') so a layer's rows are
contiguous on disk.  The header carries per-layer segment CRC32s, so
both the whole-file read path and the layer-streaming pipelined path
detect torn writes and bit-flips as ``ChunkCorruptError`` (DESIGN.md
§6) instead of decoding garbage.
"""
from __future__ import annotations

import os
import pickle
import struct
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.chunks import CompressedChunk, QuantResidentChunk
from repro.core.faults import FAULTS, ChunkCorruptError, corrupt_file
from repro.analysis.markers import requires_lock
from repro.analysis.runtime import witness_lock

# ----------------------------------------------------------------------- #
# Disk throttle: benchmarks emulate a mobile storage tier (the paper's
# UFS/SATA) since the container's page cache would make I/O free.  Sleeps
# happen on the I/O threads, so pipeline overlap dynamics stay realistic.
# ----------------------------------------------------------------------- #
_BW = None          # bytes/sec, None = unthrottled
_LAT = 0.0          # per-op seconds


def set_disk_throttle(bw_bytes_per_s=None, lat_s=0.0):
    global _BW, _LAT
    _BW, _LAT = bw_bytes_per_s, lat_s


# Cumulative swap-tier traffic (process-global, thread-safe): every
# chunk/whole-state byte that crosses the disk tier passes a _throttle
# call site, so these counters are the ground truth for the scale
# harness's bytes-moved-per-token metric.  Snapshot with io_counters()
# and difference around a measured region.
_IO_LOCK = witness_lock("restore.io")
_IO = {"read": 0, "write": 0}


@requires_lock("_IO_LOCK")
def _bump_io_locked(kind: str, nbytes: int):
    _IO[kind] += int(nbytes)


def count_io(kind: str, nbytes: int):
    with _IO_LOCK:
        _bump_io_locked(kind, nbytes)


def io_counters() -> Dict[str, int]:
    with _IO_LOCK:
        return dict(_IO)


def reset_io_counters():
    with _IO_LOCK:
        _IO["read"] = _IO["write"] = 0


def _throttle(nbytes: int):
    if _BW:
        import time as _t
        _t.sleep(_LAT + nbytes / _BW)


# --------------------------------------------------------------------- #
# numpy codec (mirror of kernels/ref.py, for the I/O thread)
# --------------------------------------------------------------------- #
def np_dequantize(packed: np.ndarray, scale: np.ndarray, bits: int,
                  n_tokens: int) -> np.ndarray:
    """packed (T', F) int8 (or fp16 when bits=16) -> (T, F) fp32."""
    if bits == 16:
        return packed.astype(np.float32)
    if bits == 8:
        return packed.astype(np.float32) * scale
    per = 8 // bits
    u = packed.view(np.uint8)
    mask = (1 << bits) - 1
    half = 1 << (bits - 1)
    outs = []
    for j in range(per):
        c = ((u >> (bits * j)) & mask).astype(np.int32)
        c = np.where(c >= half, c - (1 << bits), c)
        outs.append(c)
    codes = np.stack(outs, axis=1).reshape(n_tokens, packed.shape[1])
    return codes.astype(np.float32) * scale


# --------------------------------------------------------------------- #
# segmented chunk file format
# --------------------------------------------------------------------- #
# preamble: magic, version, reserved, CRC32 of [u64 hlen][pickle header],
# total body length ([u64 hlen] + header + all segments)
_CH_MAGIC = b"LLMK"
_CH_VERSION = 2
_CH_PREAMBLE = struct.Struct("<4sHHIQ")


def write_chunk_file(path: str, cc, n_layers: int) -> int:
    """Serialize layer-major.  F must be layer-major (it is: the codec
    flattens (L, B, heads, hd) with L outermost).  Accepts both storage
    grids: CompressedChunk (per-channel scales, header grid "channel")
    and QuantResidentChunk (per-(token, kv-head) scales stored as
    (Fs, T') f32 rows per layer, header grid "token_head")."""
    grid = "token_head" if isinstance(cc, QuantResidentChunk) else "channel"
    header = {"bits": cc.bits, "n_tokens": cc.n_tokens, "n_layers": n_layers,
              "grid": grid, "leaves": {}}
    segs: List[bytes] = [b""] * n_layers
    for name, (packed, scale) in cc.data.items():
        Tp, F = packed.shape
        assert F % n_layers == 0, (name, F, n_layers)
        Fl = F // n_layers
        isz = packed.dtype.itemsize
        ssz = 0 if cc.bits == 16 else 4
        meta = {"Tp": Tp, "F": F, "Fl": Fl, "isz": isz,
                "ssz": ssz, "shape": cc.shapes[name]}
        if grid == "token_head":
            Fs = scale.shape[1]
            assert Fs % n_layers == 0, (name, Fs, n_layers)
            meta["Fs"] = Fs
            meta["Fsl"] = Fs // n_layers
            meta["sbytes"] = 4 * meta["Fsl"] * Tp
            st = np.ascontiguousarray(scale.T, dtype=np.float32)  # (Fs, T')
        header["leaves"][name] = meta
        pt = np.ascontiguousarray(packed.T)         # (F, T')
        for l in range(n_layers):
            segs[l] = segs[l] + pt[l * Fl:(l + 1) * Fl].tobytes()
            if grid == "token_head":
                Fsl = meta["Fsl"]
                segs[l] = segs[l] + st[l * Fsl:(l + 1) * Fsl].tobytes()
            elif cc.bits != 16:
                segs[l] = segs[l] + np.ascontiguousarray(
                    scale[l * Fl:(l + 1) * Fl], dtype=np.float32).tobytes()
    header["seg_crc"] = [zlib.crc32(s) for s in segs]
    FAULTS.check("disk.write", path)
    hdr = pickle.dumps(header, protocol=pickle.HIGHEST_PROTOCOL)
    hregion = struct.pack("<Q", len(hdr)) + hdr
    body_len = len(hregion) + sum(len(s) for s in segs)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(_CH_PREAMBLE.pack(_CH_MAGIC, _CH_VERSION, 0,
                                  zlib.crc32(hregion), body_len))
        f.write(hregion)
        for s in segs:
            f.write(s)
    action = FAULTS.corrupt_action(path)
    if action is not None:
        corrupt_file(tmp, action)
    os.replace(tmp, path)
    FAULTS.note_write_ok(path)
    total = _CH_PREAMBLE.size + body_len
    count_io("write", total)
    _throttle(total)
    return total


def _read_header(f) -> Tuple[dict, int]:
    """Parse + VERIFY the preamble and pickled header.  Detects torn
    files (size mismatch vs the recorded body length) and header
    corruption (CRC mismatch) before unpickling anything."""
    pre = f.read(_CH_PREAMBLE.size)
    if len(pre) < _CH_PREAMBLE.size:
        raise ChunkCorruptError("chunk file: truncated preamble")
    magic, ver, _, hcrc, body_len = _CH_PREAMBLE.unpack(pre)
    if magic != _CH_MAGIC:
        raise ChunkCorruptError(f"chunk file: bad magic {magic!r}")
    if ver != _CH_VERSION:
        raise ChunkCorruptError(f"chunk file: unknown version {ver}")
    size = os.fstat(f.fileno()).st_size
    if size != _CH_PREAMBLE.size + body_len:
        raise ChunkCorruptError(
            f"chunk file: torn ({size} of {_CH_PREAMBLE.size + body_len} "
            f"bytes)")
    hlen_raw = f.read(8)
    (hlen,) = struct.unpack("<Q", hlen_raw)
    hdr = f.read(hlen)
    if zlib.crc32(hlen_raw + hdr) != hcrc:
        raise ChunkCorruptError("chunk file: header CRC32 mismatch")
    header = pickle.loads(hdr)
    return header, _CH_PREAMBLE.size + 8 + hlen


def verify_chunk_file(path: str):
    """Cheap structural check (preamble, size, header CRC) without
    reading segment payloads — the pipelined restore pre-validates its
    inputs with this so a guaranteed-bad file is routed to recompute
    instead of poisoning the whole layer feed."""
    with open(path, "rb") as f:
        _read_header(f)


def _segment_size(header: dict) -> int:
    return sum(m["Fl"] * m["Tp"] * m.get("isz", 1)
               + m.get("sbytes", m.get("ssz", 4) * m["Fl"])
               for m in header["leaves"].values())


def read_chunk_layer(f, header: dict, base: int, layer: int
                     ) -> Dict[str, np.ndarray]:
    """-> leaf -> dequantized (T, Fl) fp32 for one layer."""
    seg = _segment_size(header)
    f.seek(base + layer * seg)
    buf = f.read(seg)
    count_io("read", seg)
    _throttle(seg)
    crcs = header.get("seg_crc")
    if crcs is not None and zlib.crc32(buf) != crcs[layer]:
        raise ChunkCorruptError(
            f"chunk file: layer {layer} segment CRC32 mismatch")
    out, off = {}, 0
    bits, T = header["bits"], header["n_tokens"]
    token_head = header.get("grid", "channel") == "token_head"
    for name, m in header["leaves"].items():
        dt = np.float16 if bits == 16 else np.int8
        nb = m["Fl"] * m["Tp"] * m.get("isz", 1)
        pt = np.frombuffer(buf[off:off + nb], dt).reshape(m["Fl"], m["Tp"])
        off += nb
        if token_head:
            ns = m["sbytes"]
            sc = np.frombuffer(buf[off:off + ns], np.float32
                               ).reshape(m["Fsl"], m["Tp"])
            off += ns
            codes = np.ascontiguousarray(pt.T)                  # (T, Fl)
            hd = m["Fl"] // m["Fsl"]
            out[name] = (codes.reshape(T, m["Fsl"], hd).astype(np.float32)
                         * sc.T[..., None]).reshape(T, m["Fl"])
        else:
            ns = m.get("ssz", 4) * m["Fl"]
            sc = np.frombuffer(buf[off:off + ns], np.float32)
            off += ns
            out[name] = np_dequantize(np.ascontiguousarray(pt.T), sc,
                                      bits, T)
    return out


def read_chunk_file(path: str):
    """Whole-chunk read (non-pipelined swap-in path).  Returns the
    payload in its storage grid: CompressedChunk for "channel" files,
    QuantResidentChunk for "token_head" files."""
    FAULTS.check("disk.read", path)
    with open(path, "rb") as f:
        header, base = _read_header(f)
        L = header["n_layers"]
        token_head = header.get("grid", "channel") == "token_head"
        data, shapes = {}, {}
        per_leaf_packed = {n: [] for n in header["leaves"]}
        per_leaf_scale = {n: [] for n in header["leaves"]}
        seg = _segment_size(header)
        f.seek(base)
        buf = f.read(seg * L)
        count_io("read", seg * L)
        _throttle(seg * L)
        crcs = header.get("seg_crc")
        dt = np.float16 if header["bits"] == 16 else np.int8
        for l in range(L):
            off = l * seg
            if crcs is not None and \
                    zlib.crc32(buf[off:off + seg]) != crcs[l]:
                raise ChunkCorruptError(
                    f"chunk file: layer {l} segment CRC32 mismatch")
            for name, m in header["leaves"].items():
                nb = m["Fl"] * m["Tp"] * m.get("isz", 1)
                pt = np.frombuffer(buf[off:off + nb], dt
                                   ).reshape(m["Fl"], m["Tp"])
                off += nb
                if token_head:
                    ns = m["sbytes"]
                    sc = np.frombuffer(buf[off:off + ns], np.float32
                                       ).reshape(m["Fsl"], m["Tp"])
                else:
                    ns = m.get("ssz", 4) * m["Fl"]
                    sc = np.frombuffer(buf[off:off + ns], np.float32)
                off += ns
                per_leaf_packed[name].append(pt)
                per_leaf_scale[name].append(sc)
        for name, m in header["leaves"].items():
            packed = np.concatenate(per_leaf_packed[name], axis=0).T
            scale = np.concatenate(per_leaf_scale[name], axis=0)
            if token_head:
                scale = scale.T                              # (T, Fs)
            data[name] = (np.ascontiguousarray(packed),
                          np.ascontiguousarray(scale))
            shapes[name] = tuple(m["shape"])
    if token_head:
        return QuantResidentChunk(n_tokens=header["n_tokens"], data=data,
                                  shapes=shapes)
    return CompressedChunk(bits=header["bits"], n_tokens=header["n_tokens"],
                           data=data, shapes=shapes)


# --------------------------------------------------------------------- #
# LayerFeed: the I/O thread publishing per-layer KV for the scan
# --------------------------------------------------------------------- #
class LayerFeed:
    """Streams layer-l KV of every I/O chunk, one layer ahead of compute.

    paths: chunk files in POSITION order; pad_chunks: extra zero chunks
    appended so the assembled arrays match the jit bucket size.
    """

    def __init__(self, paths: Sequence[str], leaves: Sequence[str],
                 n_layers: int, chunk_tokens: int,
                 leaf_dims: Dict[str, Tuple[int, ...]],
                 pad_chunks: int = 0,
                 pool: Optional[ThreadPoolExecutor] = None):
        self.paths = list(paths)
        self.leaves = list(leaves)
        self.n_layers = n_layers
        self.cs = chunk_tokens
        self.leaf_dims = leaf_dims          # leaf -> per-token dims e.g. (KV, hd)
        self.pad = pad_chunks
        self._ready: List[Optional[Dict[str, np.ndarray]]] = \
            [None] * n_layers
        self._events = [threading.Event() for _ in range(n_layers)]
        self._error: Optional[BaseException] = None
        self._pool = pool or ThreadPoolExecutor(max_workers=1)
        self._own_pool = pool is None
        self._fut = self._pool.submit(self._run)

    def _run(self):
        files, headers, bases = [], [], []
        try:
            for p in self.paths:
                FAULTS.check("disk.read", p)
                f = open(p, "rb")
                h, b = _read_header(f)
                files.append(f)
                headers.append(h)
                bases.append(b)
            n_tok = (len(self.paths) + self.pad) * self.cs
            for l in range(self.n_layers):
                assembled = {
                    name: np.zeros((n_tok,) + tuple(np.atleast_1d(
                        self.leaf_dims[name])), np.float32)
                    for name in self.leaves}
                for ci, (f, h, b) in enumerate(zip(files, headers, bases)):
                    got = read_chunk_layer(f, h, b, l)
                    for name in self.leaves:
                        blk = got[name]          # (T, Fl) layer-major slice
                        shaped = blk.reshape(
                            (self.cs,) + tuple(np.atleast_1d(
                                self.leaf_dims[name])))
                        assembled[name][ci * self.cs:(ci + 1) * self.cs] = \
                            shaped
                self._ready[l] = assembled
                self._events[l].set()
        except BaseException as err:
            self._error = err                # fetch() chains this cause
            raise
        finally:
            for f in files:
                f.close()
            for e in self._events:           # unblock on failure
                if not e.is_set():
                    e.set()

    def fetch(self, layer: int) -> Dict[str, np.ndarray]:
        l = int(layer)
        self._events[l].wait()
        out = self._ready[l]
        if out is None:
            raise RuntimeError("LayerFeed I/O failed") from self._error
        self._ready[l] = None                # free as consumed
        return out

    def close(self, raise_errors: bool = True):
        try:
            self._fut.result()
        except BaseException:
            if raise_errors:
                raise
        finally:
            if self._own_pool:
                self._pool.shutdown(wait=False)
