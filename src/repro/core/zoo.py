"""ZooService — heterogeneous model zoo under one router (DESIGN.md §4).

One process, MANY model families: each family gets its own
``LLMService`` (executor + context store + residency engine, all
capability-driven by the family's ``KVSpec``), but every member shares
ONE substrate — a single ``DiskStore``/``AsyncSwapper`` swap tier, a
single ``LCTRUQueue`` eviction order, a single ``MemoryManager`` byte
budget, one context-id space, and one records stream.  The zoo exposes
the exact service surface ``ServiceRouter`` drives (``newLLMCtx`` /
``begin_call`` / ``decode_step_batch`` / ``finish_call`` / ...), so a
router scheduling a dense chat model, an MLA long-context model and a
constant-state RWKV agent is the SAME router that schedules one model —
it never learns which family a context belongs to.

Routing is by context ownership: ``newLLMCtx(family=...)`` binds the
new context to a member, and every later call on its stub dispatches to
that member.  A batched decode round groups states by owner and runs
one member-batched step per group (results scattered back in order).

Cross-family reclaim: the shared LCTRU queue means a reclaim started by
member A may pop a chunk key owned by member B.  A's ``evict`` does not
know the context, so it forwards the key through ``res.route_evict`` —
wired here to look up the owner and re-dispatch to ITS engine (which
bumps ITS epoch).  Keys of deleted contexts are dropped; the
``MemoryManager`` already unregistered their bytes.
"""
from __future__ import annotations

import tempfile
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.markers import requires_serialized
from repro.core.context_store import LLMCtxStub
from repro.core.lifecycle import LCTRUQueue, MemoryManager
from repro.core.service import GenerationState, LLMSConfig, LLMService
from repro.core.swap import AsyncSwapper, DiskStore


class _ZooResView:
    """The router's ``svc.res`` probe surface: degraded iff ANY member's
    swap tier is degraded (the store is shared, so normally all agree)."""

    def __init__(self, zoo: "ZooService"):
        self._zoo = zoo

    @property
    def degraded(self) -> bool:
        return any(m.res.degraded for m in self._zoo.members.values())


class ZooService:
    """≥2 family services behind one router, one byte budget, one disk.

    ``members`` maps family name -> (model, params, LLMSConfig); the
    first entry is the default family for ``newLLMCtx`` calls that do
    not name one.  Per-member ``memory_budget``/``swap_dir``/
    ``record_limit`` fields are ignored — the zoo's single budget, swap
    root and records stream replace them.
    """

    def __init__(self, members: Mapping[str, Tuple[Any, Any, LLMSConfig]],
                 *, memory_budget: Optional[int] = None,
                 swap_dir: Optional[str] = None):
        assert members, "a zoo needs at least one member family"
        cfgs = [cfg for _, _, cfg in members.values()]
        first = cfgs[0]
        root = swap_dir or tempfile.mkdtemp(prefix="llms_zoo_")
        self.store = DiskStore(root)
        self.swapper = AsyncSwapper(self.store, retries=first.io_retries,
                                    retry_base_s=first.io_retry_base_s)
        self.queue = LCTRUQueue(lru_only=not any(c.use_lctru for c in cfgs))
        budget = (first.memory_budget if memory_budget is None
                  else int(memory_budget))
        self.mem = MemoryManager(budget, self.queue)
        self.records: List[Dict[str, Any]] = []
        self._next_cid = 0
        self.members: Dict[str, LLMService] = {}
        for fam, (model, params, cfg) in members.items():
            svc = LLMService(model, params, cfg, store=self.store,
                             swapper=self.swapper, queue=self.queue,
                             mem=self.mem, cid_alloc=self._alloc_cid,
                             records=self.records)
            svc.res.route_evict = self._route_evict
            self.members[fam] = svc
        self.default_family = next(iter(self.members))
        self._owner: Dict[int, LLMService] = {}     # cid -> member
        self._owner_fam: Dict[int, str] = {}        # cid -> family name
        self.res = _ZooResView(self)
        # the zoo-level batched round groups by member; continuous
        # mid-slice joins are a single-pool notion, so the router sees
        # a non-paged service even when a member pages internally
        self.paged = False
        self._deadline = first.swap_deadline_s
        self._closed = False

    # -- substrate ------------------------------------------------------ #
    def _alloc_cid(self) -> int:
        cid = self._next_cid
        self._next_cid += 1
        return cid

    def _route_evict(self, key: Tuple[int, int]):
        """Shared-budget reclaim popped a key the reclaiming member does
        not own: re-dispatch to the owner.  A key whose context is gone
        everywhere is dropped — ``MemoryManager.reclaim`` unregisters
        the bytes either way, and forwarding it again would recurse."""
        svc = self._owner.get(key[0])
        if svc is not None and key[0] in svc.ctxs.contexts:
            svc.res.evict(key)

    def _member_of(self, cid: int) -> LLMService:
        try:
            return self._owner[cid]
        except KeyError:
            raise KeyError(f"ctx {cid} is not owned by any zoo member "
                           f"(families: {tuple(self.members)})") from None

    # -- the ServiceRouter surface -------------------------------------- #
    @property
    def decode_batch(self) -> int:
        return max(m.decode_batch for m in self.members.values())

    @requires_serialized
    def newLLMCtx(self, system_prompt=None,
                  family: Optional[str] = None) -> LLMCtxStub:
        fam = family or self.default_family
        if fam not in self.members:
            raise ValueError(f"unknown family {fam!r} "
                             f"(zoo has: {tuple(self.members)})")
        svc = self.members[fam]
        stub = svc.newLLMCtx(system_prompt)
        self._owner[stub.ctx_id] = svc
        self._owner_fam[stub.ctx_id] = fam
        return stub

    @requires_serialized
    def delLLMCtx(self, stub: LLMCtxStub):
        svc = self._member_of(stub.ctx_id)
        svc.delLLMCtx(stub)             # raises on busy: ownership kept
        self._owner.pop(stub.ctx_id, None)
        self._owner_fam.pop(stub.ctx_id, None)

    def bindLLMService(self, app: Any = None) -> "ZooService":
        return self

    @requires_serialized
    def begin_call(self, stub: LLMCtxStub, request) -> GenerationState:
        return self._member_of(stub.ctx_id).begin_call(stub, request)

    @requires_serialized
    def decode_step(self, st: GenerationState) -> Optional[int]:
        return self.decode_step_batch([st])[0]

    @requires_serialized
    def decode_step_batch(self, sts: Sequence[GenerationState]
                          ) -> List[Optional[int]]:
        """One zoo decode round: group the states by owning member and
        run one member-batched step per family, scattering the emitted
        tokens back into input order."""
        out: List[Optional[int]] = [None] * len(sts)
        groups: Dict[int, Tuple[LLMService, List[int]]] = {}
        for i, st in enumerate(sts):
            svc = self._member_of(st.ctx.cid)
            groups.setdefault(id(svc), (svc, []))[1].append(i)
        for svc, idxs in groups.values():
            toks = svc.decode_step_batch([sts[i] for i in idxs])
            for i, tok in zip(idxs, toks):
                out[i] = tok
        return out

    @requires_serialized
    def suspend_call(self, st: GenerationState):
        self._member_of(st.ctx.cid).suspend_call(st)

    @requires_serialized
    def resume_call(self, st: GenerationState):
        self._member_of(st.ctx.cid).resume_call(st)

    @requires_serialized
    def finish_call(self, st: GenerationState) -> List[int]:
        return self._member_of(st.ctx.cid).finish_call(st)

    @requires_serialized
    def callLLM(self, stub: LLMCtxStub, new_prompt, max_new_tokens: int = 16,
                sampling=None):
        return self._member_of(stub.ctx_id).callLLM(
            stub, new_prompt, max_new_tokens=max_new_tokens,
            sampling=sampling)

    @requires_serialized
    def prepare_switch(self, predicted_cid: int) -> int:
        """§3.4 AoT hint across the zoo: the predicted context's owner
        protects it and flushes its other dirty contexts; every other
        member just flushes (cid -1 never matches a context)."""
        target = self._owner.get(predicted_cid)
        n = 0
        for svc in self.members.values():
            n += svc.prepare_switch(predicted_cid if svc is target else -1)
        return n

    @requires_serialized
    def profile_pipeline(self, n_points: Tuple[int, ...] = (1, 2, 4)):
        for svc in self.members.values():
            svc.profile_pipeline(n_points)

    def family_of(self, cid: int) -> str:
        return self._owner_fam[cid]

    # -- reporting ------------------------------------------------------ #
    def stats(self) -> Dict[str, Any]:
        """Router-compatible aggregate + a per-family breakdown.  The
        shared-substrate figures (mem_used, disk bytes, switch timings
        over the shared records) are zoo-level facts; capability
        counters sum across members."""
        sw = [r["switch_s"] for r in self.records]
        out: Dict[str, Any] = {
            "calls": len(sw),
            "total_calls": sum(m.total_calls for m in self.members.values()),
            "switch_mean_s": float(np.mean(sw)) if sw else 0.0,
            "switch_p99_s": float(np.percentile(sw, 99)) if sw else 0.0,
            "switch_total_s": sum(m._t_switch_sum
                                  for m in self.members.values()),
            "mem_used": self.mem.used,
            "disk_bytes": self.store.total_bytes,
            "decode_slots": self.decode_batch,
            "decode_ready_contexts": sum(m.decode_ready_contexts()
                                         for m in self.members.values()),
            "quant_resident_chunks": sum(
                1 for m in self.members.values()
                for ctx in m.contexts.values()
                for cm in ctx.chunks.values() if cm.in_memory and cm.quant),
            "paged_pool": False,
            "zoo_families": tuple(self.members),
        }
        # fault stats: engine-local detect/recover counters sum across
        # members; swapper/store/global-injection counters are SHARED
        # substrate — take them once (summing would multiply by the
        # member count)
        fault_sum = next(iter(self.members.values())).res.fault_stats()
        for k in ("degraded_entries", "degraded_exits",
                  "chunks_recovered_recompute", "chunks_corrupt_detected",
                  "io_errors_detected", "evict_dropped", "recover_failed"):
            fault_sum[k] = sum(m.res.fault_stats()[k]
                               for m in self.members.values())
        fault_sum["degraded_mode"] = int(self.res.degraded)
        out.update(fault_sum)
        out["families"] = {
            fam: {"contexts": len(m.contexts),
                  "total_calls": m.total_calls,
                  "resident_bytes": sum(
                      m.mem._sizes.get((cid, i), 0)
                      for cid, ctx in m.contexts.items()
                      for i in list(ctx.chunks) + [-1])}
            for fam, m in self.members.items()}
        return out

    def close(self):
        """Members first (they never touch the shared swapper), then the
        zoo drains + shuts the one swap tier.  Idempotent."""
        if self._closed:
            return
        self._closed = True
        for m in self.members.values():
            m.close()
        self.swapper.shutdown(timeout=self._deadline)

    def __enter__(self) -> "ZooService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
