"""Swapping-recompute pipeline planner (paper §3.3, Eq. 4).

The paper restores missing chunks through TWO channels at once: disk I/O
and recompute-from-text.  Profiling fits linear models

    T_re(x)  = re_base + re_per_chunk * x        (x = chunks recomputed)
    T_IO(m)  = io_base + io_per_byte  * m        (m = bytes read)

and the planner picks the recompute set minimizing
``max(T_re, T_IO)`` subject to "recompute only what is recomputable"
(Eq. 4).  Because T_re depends on the COUNT and T_IO on the BYTES, the
exact greedy is: recompute the heaviest chunks first (matches the
paper's principle ii — heavy chunks are the best pipeline candidates).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np


@dataclass
class PipelineProfile:
    re_base: float = 5e-3          # jit dispatch overhead
    re_per_chunk: float = 1e-3
    io_base: float = 2e-4
    io_per_byte: float = 1e-9      # ~1 GB/s default

    def t_re(self, n_chunks: int) -> float:
        return 0.0 if n_chunks == 0 else self.re_base + self.re_per_chunk * n_chunks

    def t_io(self, nbytes: int) -> float:
        return 0.0 if nbytes == 0 else self.io_base + self.io_per_byte * nbytes


def fit_linear(xs: Sequence[float], ts: Sequence[float]
               ) -> Tuple[float, float]:
    """least-squares (base, slope) with non-negative clamping."""
    A = np.stack([np.ones(len(xs)), np.asarray(xs, np.float64)], axis=1)
    coef, *_ = np.linalg.lstsq(A, np.asarray(ts, np.float64), rcond=None)
    base, slope = float(coef[0]), float(coef[1])
    return max(base, 0.0), max(slope, 1e-12)


def profile_io(store, swapper, sample_chunk, sizes=(1, 2, 4, 8)
               ) -> Tuple[float, float]:
    """One-shot installation-time measurement (paper §3.3.i)."""
    xs, ts = [], []
    for n in sizes:
        keys = [(-1, f"probe{j}") for j in range(n)]
        for k in keys:
            store.write(k, sample_chunk)
        t0 = time.perf_counter()
        for k in keys:
            store.read(k)
        ts.append(time.perf_counter() - t0)
        xs.append(sum(store.nbytes(k) for k in keys))
        for k in keys:
            store.delete(k)
    return fit_linear(xs, ts)


def plan_split(miss: List[Tuple[int, int, bool]], prof: PipelineProfile,
               enable_recompute: bool = True
               ) -> Tuple[List[int], List[int], float]:
    """miss: [(chunk_idx, io_bytes, recomputable)].

    Returns (recompute_idxs, io_idxs, predicted_delay).  Exact greedy on
    Eq. 4: move the largest-byte recomputable chunk from the I/O channel
    to the recompute channel while the pipeline delay improves.
    """
    io = sorted(miss, key=lambda t: -t[1])
    re: List[Tuple[int, int, bool]] = []
    io_bytes = sum(b for _, b, _ in io)

    def delay(n_re: int, m_io: int) -> float:
        return max(prof.t_re(n_re), prof.t_io(m_io))

    best = delay(0, io_bytes)
    if enable_recompute:
        i = 0
        while i < len(io):
            if not io[i][2]:
                i += 1
                continue
            cand = delay(len(re) + 1, io_bytes - io[i][1])
            if cand < best - 1e-12:
                c = io.pop(i)
                re.append(c)
                io_bytes -= c[1]
                best = cand
            else:
                i += 1
    return [c[0] for c in re], [c[0] for c in io], best
