"""LLMService — the LLMaaS system service (paper Table 1, §3).

Implements the full LLMS design plus every baseline the paper compares
against, as POLICIES of one context manager so the benchmarks measure
like-for-like:

  policy="llms"        chunked + tolerance-aware compression (8/4/2 @ 50%)
                       + swapping-recompute pipeline + LCTRU/AoT lifecycle
  policy="vllm_sq"     chunked swapping + static INT8 (VLLM-SQ baseline)
  policy="vllm_s"      chunked swapping, uncompressed (VLLM-S baseline)
  policy="swap"        whole-context swapping (Swapping baseline)
  policy="lmk"         low-memory-killer: contexts are killed under
                       pressure and recomputed from text on return
  ablations:           "llms_nocomp" / "llms_nopipe" / "llms_nolife"

The measured *context switching latency* (paper Fig. 9) is the time of
``_switch_in`` — making the context memory-resident again — exactly the
paper's QoS metric.  Token generation afterwards is ordinary inference.

Memory model (paper Fig. 4): persistent context state is the COMPRESSED
chunk store (counted against the budget); the bf16 working cache exists
only for the active context (the paper's working-set lock) and is not
charged.  "Uncompressed" chunks are fp16.
"""
from __future__ import annotations

import functools
import math
import os
import tempfile
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp
from repro.core.chunks import ChunkCodec, ChunkMeta, CompressedChunk
from repro.core.lifecycle import LCTRUQueue, MemoryManager
from repro.core.pipeline import PipelineProfile, fit_linear, plan_split
from repro.core.restore import LayerFeed, read_chunk_file, write_chunk_file
from repro.core.swap import AsyncSwapper, DiskStore
from repro.models.api import ModelBase

Array = jax.Array

POLICIES = ("llms", "llms_nocomp", "llms_nopipe", "llms_nolife",
            "vllm_s", "vllm_sq", "swap", "lmk")

# policy -> (compression, use_pipeline, use_lctru, use_aot, chunked, use_disk)
_POLICY_FLAGS = {
    "llms":        ("tolerance", True, True, True, True, True),
    "llms_nocomp": ("none", True, True, True, True, True),
    "llms_nopipe": ("tolerance", False, True, True, True, True),
    "llms_nolife": ("tolerance", True, False, False, True, True),
    "vllm_s":      ("none", False, False, False, True, True),
    "vllm_sq":     ("static8", False, False, False, True, True),
    "swap":        ("none", False, False, False, False, True),
    "lmk":         ("none", False, False, False, False, False),
}


@dataclass
class LLMSConfig:
    policy: str = "llms"
    chunk_tokens: int = 16
    levels: Tuple[Tuple[int, float], ...] = comp.DEFAULT_LEVELS
    ratio_global: float = 0.5
    memory_budget: int = 64 << 20
    max_ctx_len: int = 512
    max_contexts_per_app: int = 8          # K in the paper
    swap_dir: Optional[str] = None
    window: int = 0
    n_sinks: int = 0

    compression: str = ""
    use_pipeline: bool = False
    use_lctru: bool = False
    use_aot: bool = False
    chunked: bool = False
    use_disk: bool = False

    def __post_init__(self):
        assert self.policy in POLICIES, self.policy
        (self.compression, self.use_pipeline, self.use_lctru, self.use_aot,
         self.chunked, self.use_disk) = _POLICY_FLAGS[self.policy]


@dataclass
class LLMCtxStub:
    """Table 1: the opaque handle apps hold."""
    ctx_id: int


@dataclass
class Context:
    cid: int
    tokens: np.ndarray                      # resident text (paper Fig. 4)
    n_tokens: int = 0
    chunks: Dict[int, ChunkMeta] = field(default_factory=dict)
    payload: Dict[int, CompressedChunk] = field(default_factory=dict)
    whole: Optional[Dict[str, np.ndarray]] = None   # non-chunked policies
    whole_tokens: int = 0
    alive: bool = True                      # lmk: killed => False
    density_sum: Optional[np.ndarray] = None
    density_cnt: Optional[np.ndarray] = None


_JIT_CACHE: Dict[Tuple, Dict[str, Any]] = {}
_ACTIVE_FEED = None


def _feed_fetch(layer):
    return _ACTIVE_FEED.fetch(layer)


def _pow2_buckets(lo: int, hi: int) -> List[int]:
    out, b = [], lo
    while b < hi:
        out.append(b)
        b *= 2
    out.append(hi)
    return out


class LLMService:
    """One shared model + per-app persistent contexts (LLMaaS)."""

    def __init__(self, model: ModelBase, params, cfg: LLMSConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        mc = model.cfg
        self.cs = cfg.chunk_tokens
        self.n_slots = math.ceil(cfg.max_ctx_len / self.cs) * self.cs
        self.codec = ChunkCodec(mc.family, self.cs)
        root = cfg.swap_dir or tempfile.mkdtemp(prefix="llms_swap_")
        self.store = DiskStore(root)
        self.swapper = AsyncSwapper(self.store)
        self.queue = LCTRUQueue(lru_only=not cfg.use_lctru)
        self.mem = MemoryManager(cfg.memory_budget, self.queue)
        self.profile = PipelineProfile()
        self._profiled = False
        self._recomputable = mc.family in ("dense", "mla_moe")
        self._pipelined_fn = None
        self._current_feed = None

        self.contexts: Dict[int, Context] = {}
        self._next_cid = 0
        self.records: List[Dict[str, Any]] = []
        # working-cache reuse: (cid, cache, epoch) of the last active ctx
        self._active: Optional[Tuple[int, Any, int]] = None
        self._epoch = 0                     # bumped on any eviction

        # working cache: one active context at a time (paper's WS lock)
        self._tok_buckets = _pow2_buckets(self.cs, self.n_slots)
        self._io_buckets = _pow2_buckets(1, max(self.n_slots // self.cs, 1))
        self.s_work = self.n_slots + self._tok_buckets[-1]
        self._pad_slot = self.s_work - 1
        self.work_cache = model.init_cache(1, self.s_work)
        self._zero_cache = self.work_cache

        # jitted entry points are shared across service instances of the
        # same (model, window) so benchmark sweeps don't recompile
        ck = (id(model), cfg.window, cfg.n_sinks, mc.family, self.cs)
        cached = _JIT_CACHE.get(ck)
        if cached is None:
            cw = dict(window=cfg.window, n_sinks=cfg.n_sinks)
            cached = {
                "extend": jax.jit(functools.partial(
                    model.recompute, want_density=True, **cw)),
                "extend_nod": jax.jit(functools.partial(
                    model.recompute, want_density=False, **cw)),
                "decode": jax.jit(functools.partial(
                    model.decode_step, want_density=True, **cw)),
                "logits": jax.jit(
                    lambda p, h: (h @ model.head_weight(p)
                                  ).astype(jnp.float32)),
                "insert": jax.jit(self.codec.insert),
                "scatter": jax.jit(self.codec.scatter),
                "setpos": jax.jit(lambda c, p: {**c, "pos": p}),
            }
            _JIT_CACHE[ck] = cached
        self._jit_extend = cached["extend"]
        self._jit_extend_nod = cached["extend_nod"]
        self._jit_decode = cached["decode"]
        self._jit_logits = cached["logits"]
        self._jit_insert = cached["insert"]
        self._jit_scatter = cached["scatter"]
        self._jit_setpos = cached["setpos"]

        shapes = {k: v.shape for k, v in self.work_cache.items()
                  if k in self.codec.leaves}
        self._leaf_shapes = shapes
        self.n_layers = next(iter(shapes.values()))[0]
        mcfg = model.cfg
        if "k" in self.codec.leaves:
            self.leaf_dims = {"k": (mcfg.n_kv_heads, mcfg.head_dim),
                              "v": (mcfg.n_kv_heads, mcfg.head_dim)}
        else:
            self.leaf_dims = {"ckv": (mcfg.mla.kv_lora_rank,),
                              "kpe": (mcfg.mla.qk_rope_head_dim,)}

    # ------------------------------------------------------------------ #
    # Table-1 API
    # ------------------------------------------------------------------ #
    def newLLMCtx(self, system_prompt: Optional[Sequence[int]] = None
                  ) -> LLMCtxStub:
        cid = self._next_cid
        self._next_cid += 1
        self.contexts[cid] = Context(
            cid=cid, tokens=np.zeros(self.s_work, np.int32),
            density_sum=np.zeros(self.s_work, np.float64),
            density_cnt=np.zeros(self.s_work, np.float64))
        stub = LLMCtxStub(cid)
        if system_prompt is not None and len(system_prompt):
            self.callLLM(stub, system_prompt, max_new_tokens=0)
        return stub

    def delLLMCtx(self, stub: LLMCtxStub):
        ctx = self.contexts.pop(stub.ctx_id, None)
        if ctx is None:
            return
        for idx in list(ctx.chunks):
            self.mem.unregister((ctx.cid, idx))
            self.store.delete((ctx.cid, idx))
        self.mem.unregister((ctx.cid, -1))
        self.store.delete((ctx.cid, -1))

    def bindLLMService(self, app: Any = None) -> "LLMService":
        return self

    def callLLM(self, stub: LLMCtxStub, new_prompt: Sequence[int],
                max_new_tokens: int = 16) -> Tuple[LLMCtxStub, List[int]]:
        ctx = self.contexts[stub.ctx_id]
        total_new = len(new_prompt) + max_new_tokens
        assert total_new <= self.n_slots // 2, "request exceeds half window"
        if ctx.n_tokens + total_new > self.n_slots:
            self._condense(ctx, keep=self.n_slots // 2)

        # -- context switching (the measured QoS metric) ----------------- #
        # Restoring MISSING state (I/O + recompute) is switching latency;
        # assembling the bf16 working cache from RESIDENT compressed
        # chunks stands in for the fused dequant a TPU attention kernel
        # does per iteration (kernels/decode_qattn.py) and is charged to
        # inference (paper: switching == making chunks memory-resident).
        t0 = time.perf_counter()
        reuse = (self._active is not None and self._active[0] == ctx.cid
                 and self._active[2] == self._epoch)
        if reuse:
            cache = self._active[1]
            t_switch = time.perf_counter() - t0
            t_assemble = 0.0
        else:
            cache, t_switch = self._switch_in_timed(ctx)
            t_assemble = time.perf_counter() - t0 - t_switch

        # -- inference: extend with the new prompt, then decode ----------- #
        t1 = time.perf_counter()
        prompt = np.asarray(new_prompt, np.int32)
        cache, logits = self._extend(ctx, cache, prompt)
        ctx.n_tokens += len(prompt)
        generated: List[int] = []
        if max_new_tokens > 0:
            tok = int(np.argmax(logits))
            for step in range(max_new_tokens):
                generated.append(tok)
                ctx.tokens[ctx.n_tokens] = tok
                ctx.n_tokens += 1
                if step == max_new_tokens - 1:
                    break
                out, mass = self._jit_decode(
                    self.params, jnp.asarray([[tok]], jnp.int32), cache)
                cache = out.cache
                self._acc_density(ctx, np.asarray(mass[0], np.float64),
                                  ctx.n_tokens)
                tok = int(np.argmax(np.asarray(out.logits[0])))
        t_infer = time.perf_counter() - t1

        # -- compress / AoT swap-out / reclaim (paper §3.2 + §3.4) -------- #
        t2 = time.perf_counter()
        self._compress_and_swap_out(ctx, cache)
        self.mem.reclaim(0, self._evict, locked=set())
        t_out = time.perf_counter() - t2

        self._active = (ctx.cid, cache, self._epoch)
        self.records.append({
            "ctx": ctx.cid, "switch_s": t_switch,
            "infer_s": t_infer + t_assemble, "assemble_s": t_assemble,
            "swapout_s": t_out, "new_tokens": len(prompt) + len(generated),
            "mem_used": self.mem.used,
        })
        return stub, generated

    # ------------------------------------------------------------------ #
    # switch-in: restore every chunk to memory (Load primitive)
    # ------------------------------------------------------------------ #
    def _switch_in_timed(self, ctx: Context):
        """-> (cache, switch_seconds).  Missing-chunk restore (reclaim +
        I/O + recompute) is the timed QoS path; resident-chunk assembly
        into the bf16 working cache is not (see callLLM comment)."""
        cache = self._jit_setpos(self._zero_cache, jnp.int32(ctx.n_tokens))
        if ctx.n_tokens == 0:
            return cache, 0.0
        if not self.cfg.chunked:
            return self._restore_whole_timed(ctx, cache)

        # ---- assembly of resident chunks (inference-side cost) -------- #
        by_bits: Dict[int, List[int]] = {}
        for i, m in sorted(ctx.chunks.items()):
            if m.in_memory:
                by_bits.setdefault(m.bits, []).append(i)
                self.queue.touch((ctx.cid, i), m.bits)
                m.last_access = time.time()
        for bits, idxs in by_bits.items():
            blocks = {name: jnp.concatenate(
                [self._payload_blocks(ctx.payload[i])[name] for i in idxs])
                for name in self.codec.leaves}
            pos = self._chunk_positions(idxs)
            pos_b = self._bucket_pad(pos, self._pad_slot)
            if len(pos_b) != len(pos):
                pad = len(pos_b) - len(pos)
                blocks = {k: jnp.concatenate(
                    [v, jnp.zeros((pad,) + v.shape[1:], v.dtype)])
                    for k, v in blocks.items()}
            cache = self._jit_scatter(cache, jnp.asarray(pos_b), blocks)
        jax.block_until_ready(cache[self.codec.leaves[0]])

        # ---- timed: reclaim + restore of missing chunks ---------------- #
        t0 = time.perf_counter()
        missing = sorted(i for i, m in ctx.chunks.items() if not m.in_memory)
        need = sum(ctx.chunks[i].nbytes for i in missing)
        self.mem.reclaim(need, self._evict, locked={ctx.cid})
        if missing:
            re_idx, io_idx = self._plan_restore(ctx, missing)
            cache = self._restore_chunks(ctx, cache, re_idx, io_idx)
            jax.block_until_ready(cache[self.codec.leaves[0]])
        return cache, time.perf_counter() - t0

    def _plan_restore(self, ctx, missing: List[int]
                      ) -> Tuple[List[int], List[int]]:
        if not (self.cfg.use_pipeline and self._recomputable):
            return [], missing
        plan_in = [(i, ctx.chunks[i].nbytes, True) for i in missing]
        if self._profiled:
            re_idx, io_idx, _ = plan_split(plan_in, self.profile, True)
        else:   # unprofiled fallback: split heaviest half to recompute
            order = sorted(missing, key=lambda i: -ctx.chunks[i].nbytes)
            re_idx = order[:len(order) // 2]
            io_idx = [i for i in missing if i not in set(re_idx)]
        return sorted(re_idx), sorted(io_idx)

    def _restore_chunks(self, ctx: Context, cache, re_idx: List[int],
                        io_idx: List[int]):
        """Fig. 8 restore.  dense + recompute-set: per-layer pipelined scan;
        otherwise: async whole-chunk reads (+ recompute second phase)."""
        use_pipe = (bool(re_idx) and self.model.cfg.family == "dense")
        if use_pipe:
            nio_b = next(x for x in self._io_buckets
                         if x >= max(len(io_idx), 1))
            pad_chunks = nio_b - len(io_idx)
            io_pos_b = np.concatenate(
                [self._chunk_positions(io_idx),
                 np.full(pad_chunks * self.cs, self._pad_slot, np.int32)])
            paths = [self.store._path((ctx.cid, i)) for i in io_idx]
            feed = LayerFeed(paths, self.codec.leaves, self.n_layers,
                             self.cs, self.leaf_dims, pad_chunks=pad_chunks,
                             pool=self.swapper.pool)
            miss_pos = self._chunk_positions(re_idx)
            miss_b = self._bucket_pad(miss_pos, self._pad_slot)
            toks_b = self._bucket_pad(ctx.tokens[miss_pos], 0)
            global _ACTIVE_FEED
            _ACTIVE_FEED = feed
            fn = self._get_pipelined_fn()
            cache, _, _ = fn(self.params, jnp.asarray(toks_b)[None],
                             jnp.asarray(miss_b), jnp.asarray(io_pos_b),
                             cache, jnp.int32(ctx.n_tokens))
            jax.block_until_ready(cache[self.codec.leaves[0]])
            feed.close()
            for i in io_idx:
                self._mark_loaded(ctx, i, payload=None)
        else:
            # async whole-chunk reads, insert as they land
            futs = {i: self.swapper.pool.submit(
                read_chunk_file, self.store._path((ctx.cid, i)))
                for i in io_idx}
            for i in io_idx:
                cc = futs[i].result()
                cache = self._jit_insert(cache, jnp.int32(i * self.cs),
                                         self._payload_blocks(cc))
                self._mark_loaded(ctx, i, payload=cc)
            if re_idx:   # second phase (exact: I/O chunks now resident)
                miss_pos = self._chunk_positions(re_idx)
                miss_b = self._bucket_pad(miss_pos, self._pad_slot)
                toks_b = self._bucket_pad(ctx.tokens[miss_pos], 0)
                cache, _, _ = self._jit_extend_nod(
                    self.params, jnp.asarray(toks_b)[None],
                    jnp.asarray(miss_b), cache, jnp.int32(ctx.n_tokens))

        # recomputed chunks: re-encode payload at their assigned level
        for i in re_idx:
            m = ctx.chunks[i]
            ctx.payload[i] = self._make_payload(cache, i, m.bits)
            m.in_memory, m.dirty = True, False    # already on disk
            self.mem.register((ctx.cid, i), m.nbytes, m.bits)
        return cache

    def _mark_loaded(self, ctx, i: int, payload):
        if payload is None:
            payload = read_chunk_file(self.store._path((ctx.cid, i)))
        ctx.payload[i] = payload
        m = ctx.chunks[i]
        m.in_memory, m.dirty = True, False
        self.mem.register((ctx.cid, i), m.nbytes, m.bits)

    def _get_pipelined_fn(self):
        ck = (id(self.model), self.cfg.window, self.cfg.n_sinks, "pipelined")
        fn = _JIT_CACHE.get(ck)
        if fn is None:
            fn = jax.jit(
                functools.partial(self.model.recompute_pipelined,
                                  fetch=_feed_fetch,
                                  window=self.cfg.window,
                                  n_sinks=self.cfg.n_sinks))
            _JIT_CACHE[ck] = fn
        return fn

    # -- whole-context policies (swap / lmk) ----------------------------- #
    def _restore_whole_timed(self, ctx: Context, cache):
        t_switch = 0.0
        if ctx.whole is not None:
            pass                                       # resident
        elif self.cfg.use_disk and self.store.nbytes((ctx.cid, -1)):
            t0 = time.perf_counter()
            self.mem.reclaim(self.store.nbytes((ctx.cid, -1)) or 0,
                             self._evict, locked={ctx.cid})
            ctx.whole = self.swapper.read((ctx.cid, -1))
            t_switch = time.perf_counter() - t0
            ctx.whole_tokens = ctx.n_tokens
            self.mem.register((ctx.cid, -1), self._whole_bytes(ctx), 16)
            self.queue.touch((ctx.cid, -1), 16)
        else:
            # LMK: killed — recompute the whole context from its text
            t0 = time.perf_counter()
            self.mem.reclaim(0, self._evict, locked={ctx.cid})
            pos = np.arange(ctx.n_tokens, dtype=np.int32)
            pos_b = self._bucket_pad(pos, self._pad_slot)
            toks_b = self._bucket_pad(ctx.tokens[:ctx.n_tokens], 0)
            cache, _, dens = self._jit_extend(
                self.params, jnp.asarray(toks_b)[None], jnp.asarray(pos_b),
                self._jit_setpos(cache, jnp.int32(0)),
                jnp.int32(ctx.n_tokens))
            jax.block_until_ready(cache[self.codec.leaves[0]])
            t_switch = time.perf_counter() - t0
            self._acc_density(ctx, np.asarray(dens[0], np.float64),
                              ctx.n_tokens)
            ctx.whole = self._extract_whole(cache, ctx.n_tokens)
            ctx.whole_tokens = ctx.n_tokens
            ctx.alive = True
            self.mem.register((ctx.cid, -1), self._whole_bytes(ctx), 16)
            return (self._jit_setpos(cache, jnp.int32(ctx.n_tokens)),
                    t_switch)
        blocks = {k: jnp.asarray(v) for k, v in ctx.whole.items()}
        cache = self._jit_insert(cache, jnp.int32(0), blocks)
        self.queue.touch((ctx.cid, -1), 16)
        return self._jit_setpos(cache, jnp.int32(ctx.n_tokens)), t_switch

    def _extract_whole(self, cache, n_tokens: int) -> Dict[str, np.ndarray]:
        hi = self._bucket_len(n_tokens)
        return {k: np.asarray(v, np.float16)
                for k, v in self.codec.extract(cache, 0, hi).items()}

    def _bucket_len(self, n: int) -> int:
        return next(x for x in self._tok_buckets if x >= n)

    def _whole_bytes(self, ctx) -> int:
        return sum(v.nbytes for v in (ctx.whole or {}).values())

    # ------------------------------------------------------------------ #
    # helpers
    # ------------------------------------------------------------------ #
    def _chunk_positions(self, idxs: Sequence[int]) -> np.ndarray:
        pos = []
        for i in idxs:
            pos.extend(range(i * self.cs, (i + 1) * self.cs))
        return np.asarray(pos, np.int32)

    def _bucket_pad(self, arr: np.ndarray, fill) -> np.ndarray:
        b = self._bucket_len(len(arr))
        if b == len(arr):
            return arr
        return np.concatenate([arr, np.full(b - len(arr), fill, arr.dtype)])

    def _payload_blocks(self, cc: CompressedChunk) -> Dict[str, Array]:
        if cc.bits == 16:
            return {k: jnp.asarray(p).astype(jnp.bfloat16)
                    for k, (p, _) in cc.data.items()}
        return self.codec.decompress(cc)

    def _make_payload(self, cache, i: int, bits: int) -> CompressedChunk:
        lo, hi = i * self.cs, (i + 1) * self.cs
        if bits == 16:
            blocks = self.codec.extract(cache, lo, hi)
            return CompressedChunk(
                bits=16, n_tokens=self.cs,
                data={k: (np.asarray(v, np.float16), np.zeros(0, np.float32))
                      for k, v in blocks.items()},
                shapes={k: tuple(v.shape) for k, v in blocks.items()})
        return self.codec.compress(cache, lo, hi, bits)

    def _extend(self, ctx: Context, cache, prompt: np.ndarray):
        n0 = ctx.n_tokens
        M = len(prompt)
        ctx.tokens[n0:n0 + M] = prompt
        pos = np.arange(n0, n0 + M, dtype=np.int32)
        pos_b = self._bucket_pad(pos, self._pad_slot)
        toks_b = self._bucket_pad(prompt, 0)
        cache, hidden, dens = self._jit_extend(
            self.params, jnp.asarray(toks_b)[None], jnp.asarray(pos_b),
            cache, jnp.int32(n0 + M))
        self._acc_density(ctx, np.asarray(dens[0], np.float64), n0 + M)
        logits = np.asarray(self._jit_logits(self.params,
                                             hidden[:, M - 1]))[0]
        cache = self._jit_setpos(cache, jnp.int32(n0 + M))
        return cache, logits

    def _acc_density(self, ctx, mass: np.ndarray, n_visible: int):
        ctx.density_sum[:len(mass)] += mass
        ctx.density_cnt[:n_visible] += 1

    # ------------------------------------------------------------------ #
    # compress + AoT swap-out (Reclaim is then free)
    # ------------------------------------------------------------------ #
    def _compress_and_swap_out(self, ctx: Context, cache):
        cfg = self.cfg
        if not cfg.chunked:
            ctx.whole = self._extract_whole(cache, ctx.n_tokens)
            ctx.whole_tokens = ctx.n_tokens
            self.mem.register((ctx.cid, -1), self._whole_bytes(ctx), 16)
            return

        n_chunks = math.ceil(ctx.n_tokens / self.cs)
        if cfg.compression == "tolerance":
            D = comp.chunk_density(ctx.density_sum, ctx.density_cnt,
                                   ctx.n_tokens, self.cs)
            bits = comp.plan_buckets(D, cfg.ratio_global, cfg.levels)
        elif cfg.compression == "static8":
            D = np.zeros(n_chunks)
            bits = np.full(n_chunks, 8, np.int64)
        else:
            D = np.zeros(n_chunks)
            bits = np.full(n_chunks, 16, np.int64)

        for i in range(n_chunks):
            m = ctx.chunks.get(i)
            if m is None:
                m = ChunkMeta(idx=i)
                ctx.chunks[i] = m
            want = int(bits[i])
            m.density = float(D[i])
            if m.dirty or want != m.bits or i not in ctx.payload:
                cc = self._make_payload(cache, i, want)
                ctx.payload[i] = cc
                m.bits, m.nbytes = want, cc.nbytes
                m.dirty, m.in_memory, m.on_disk = True, True, False
            self.mem.register((ctx.cid, i), m.nbytes, m.bits)
            m.last_access = time.time()

        if cfg.use_aot and cfg.use_disk:
            for i, m in ctx.chunks.items():
                if m.dirty:
                    self._write_chunk_async(ctx.cid, i, ctx.payload[i])
                    m.dirty, m.on_disk = False, True

    def _write_chunk_async(self, cid: int, idx: int, cc: CompressedChunk):
        key = (cid, idx)
        path = self.store._path(key)

        def work():
            n = write_chunk_file(path, cc, self.n_layers)
            with self.store._lock:
                self.store._bytes[key] = n
        self.swapper.submit(key, work)

    # ------------------------------------------------------------------ #
    # eviction (Reclaim primitive)
    # ------------------------------------------------------------------ #
    def _evict(self, key):
        cid, idx = key
        self._epoch += 1
        ctx = self.contexts.get(cid)
        if ctx is None:
            return
        if idx == -1:
            if self.cfg.use_disk and ctx.whole is not None:
                self.store.write((cid, -1), ctx.whole)   # sync: paper's
            ctx.whole = None                             # reclaim-time cost
            ctx.alive = False
            return
        m = ctx.chunks.get(idx)
        if m is None:
            return
        if m.dirty:                         # no-AoT policies pay here (sync)
            n = write_chunk_file(self.store._path(key), ctx.payload[idx],
                                 self.n_layers)
            with self.store._lock:
                self.store._bytes[key] = n
            m.dirty = False
        m.on_disk, m.in_memory = True, False
        ctx.payload.pop(idx, None)

    # ------------------------------------------------------------------ #
    def _condense(self, ctx: Context, keep: int):
        """Context overflow: keep the most recent ``keep`` tokens re-encoded
        at positions [0, keep) (sliding-window reset, paper §4's streaming)."""
        keep = max(self.cs, min((keep // self.cs) * self.cs,
                                ((ctx.n_tokens) // self.cs) * self.cs))
        tail = ctx.tokens[ctx.n_tokens - keep:ctx.n_tokens].copy()
        for idx in list(ctx.chunks):
            self.mem.unregister((ctx.cid, idx))
            self.store.delete((ctx.cid, idx))
        self.mem.unregister((ctx.cid, -1))
        ctx.chunks.clear()
        ctx.payload.clear()
        ctx.whole = None
        ctx.tokens[:] = 0
        ctx.n_tokens = 0
        ctx.density_sum[:] = 0
        ctx.density_cnt[:] = 0
        self._active = None
        cache = self._jit_setpos(self._zero_cache, jnp.int32(0))
        cache, _ = self._extend(ctx, cache, tail)
        ctx.n_tokens = keep
        self._compress_and_swap_out(ctx, cache)

    # ------------------------------------------------------------------ #
    def profile_pipeline(self, n_points: Tuple[int, ...] = (1, 2, 4)):
        """Paper §3.3.i: one-shot installation-time profiling of T_re/T_IO."""
        if not self._recomputable:
            return
        toks = np.ones(self.n_slots, np.int32)
        cache = self._jit_setpos(self._zero_cache, jnp.int32(0))
        xs, ts = [], []
        for x in n_points:
            M = x * self.cs
            pos_b = self._bucket_pad(np.arange(M, dtype=np.int32),
                                     self._pad_slot)
            toks_b = self._bucket_pad(toks[:M], 0)
            args = (self.params, jnp.asarray(toks_b)[None],
                    jnp.asarray(pos_b), cache, jnp.int32(M))
            out = self._jit_extend_nod(*args)            # compile
            jax.block_until_ready(out[0][self.codec.leaves[0]])
            t0 = time.perf_counter()
            out = self._jit_extend_nod(*args)
            jax.block_until_ready(out[0][self.codec.leaves[0]])
            ts.append(time.perf_counter() - t0)
            xs.append(x)
        self.profile.re_base, self.profile.re_per_chunk = fit_linear(xs, ts)

        cc = self._make_payload(self.work_cache, 0, 8)
        ios_x, ios_t = [], []
        for n in (1, 2, 4):
            paths = [self.store._path((-2, f"probe{j}")) for j in range(n)]
            for p in paths:
                write_chunk_file(p, cc, self.n_layers)
            t0 = time.perf_counter()
            for p in paths:
                read_chunk_file(p)
            ios_t.append(time.perf_counter() - t0)
            ios_x.append(n * cc.nbytes)
            for p in paths:
                os.remove(p)
        self.profile.io_base, self.profile.io_per_byte = \
            fit_linear(ios_x, ios_t)
        self._profiled = True

    # ------------------------------------------------------------------ #
    def stats(self) -> Dict[str, float]:
        sw = [r["switch_s"] for r in self.records]
        return {
            "calls": len(sw),
            "switch_mean_s": float(np.mean(sw)) if sw else 0.0,
            "switch_p99_s": float(np.percentile(sw, 99)) if sw else 0.0,
            "mem_used": self.mem.used,
            "disk_bytes": self.store.total_bytes,
        }

    def close(self):
        self.swapper.shutdown()
