"""LLMService — the LLMaaS system service (paper Table 1, §3).

Thin facade over the four-layer serving stack (DESIGN.md §1):
``executor.ModelExecutor`` (jitted entry points + bucket/padding),
``context_store.ContextStore`` (persistent contexts, Fig. 4),
``residency.ResidencyEngine`` (switch-in/out, compression, AoT,
eviction), with ``scheduler.ServiceRouter`` as the multi-app front-end
on top.  The paper's full design plus every baseline it compares
against (VLLM-S/SQ, whole-context Swapping, LMK, and the three
ablations) are POLICIES of this one facade so benchmarks measure
like-for-like.  The measured *context switching latency* (Fig. 9) is
the time of ``ResidencyEngine.switch_in`` — the paper's QoS metric.
"""
from __future__ import annotations

import tempfile
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import compression as comp
from repro.core.context_store import Context, ContextStore, LLMCtxStub  # noqa: F401 (re-export)
from repro.core.executor import ModelExecutor
from repro.core.lifecycle import LCTRUQueue, MemoryManager
from repro.core.residency import ResidencyEngine
from repro.core.swap import AsyncSwapper, DiskStore
from repro.models.api import ModelBase

POLICIES = ("llms", "llms_nocomp", "llms_nopipe", "llms_nolife",
            "vllm_s", "vllm_sq", "swap", "lmk")

# policy -> (compression, use_pipeline, use_lctru, use_aot, chunked, use_disk)
_POLICY_FLAGS = {
    "llms":        ("tolerance", True, True, True, True, True),
    "llms_nocomp": ("none", True, True, True, True, True),
    "llms_nopipe": ("tolerance", False, True, True, True, True),
    "llms_nolife": ("tolerance", True, False, False, True, True),
    "vllm_s":      ("none", False, False, False, True, True),
    "vllm_sq":     ("static8", False, False, False, True, True),
    "swap":        ("none", False, False, False, False, True),
    "lmk":         ("none", False, False, False, False, False),
}


@dataclass
class LLMSConfig:
    policy: str = "llms"
    chunk_tokens: int = 16
    levels: Tuple[Tuple[int, float], ...] = comp.DEFAULT_LEVELS
    ratio_global: float = 0.5
    memory_budget: int = 64 << 20
    max_ctx_len: int = 512
    max_contexts_per_app: int = 8          # K in the paper
    swap_dir: Optional[str] = None
    window: int = 0
    n_sinks: int = 0
    compression: str = ""
    use_pipeline: bool = False
    use_lctru: bool = False
    use_aot: bool = False
    chunked: bool = False
    use_disk: bool = False

    def __post_init__(self):
        assert self.policy in POLICIES, self.policy
        (self.compression, self.use_pipeline, self.use_lctru, self.use_aot,
         self.chunked, self.use_disk) = _POLICY_FLAGS[self.policy]


class LLMService:
    """One shared model + per-app persistent contexts (LLMaaS)."""

    def __init__(self, model: ModelBase, params, cfg: LLMSConfig):
        self.model, self.params, self.cfg = model, params, cfg
        self.exe = ModelExecutor(model, params, cfg)
        root = cfg.swap_dir or tempfile.mkdtemp(prefix="llms_swap_")
        self.store = DiskStore(root)
        self.swapper = AsyncSwapper(self.store)
        self.queue = LCTRUQueue(lru_only=not cfg.use_lctru)
        self.mem = MemoryManager(cfg.memory_budget, self.queue)
        self.ctxs = ContextStore(self.mem, self.store, self.exe.s_work)
        self.res = ResidencyEngine(self.exe, self.ctxs, self.store,
                                   self.swapper, self.queue, self.mem, cfg)
        self.records: List[Dict[str, Any]] = []
        # (cid, cache, epoch) of the last active ctx: working-cache reuse
        self._active: Optional[Tuple[int, Any, int]] = None

    @property
    def contexts(self) -> Dict[int, Context]:
        return self.ctxs.contexts

    @property
    def n_slots(self) -> int:
        return self.exe.n_slots

    def newLLMCtx(self, system_prompt: Optional[Sequence[int]] = None
                  ) -> LLMCtxStub:
        ctx = self.ctxs.create()
        stub = LLMCtxStub(ctx.cid)
        if system_prompt is not None and len(system_prompt):
            self.callLLM(stub, system_prompt, max_new_tokens=0)
        return stub

    def delLLMCtx(self, stub: LLMCtxStub):
        self.ctxs.delete(stub.ctx_id)

    def bindLLMService(self, app: Any = None) -> "LLMService":
        return self

    def callLLM(self, stub: LLMCtxStub, new_prompt: Sequence[int],
                max_new_tokens: int = 16) -> Tuple[LLMCtxStub, List[int]]:
        ctx = self.ctxs.get(stub.ctx_id)
        total_new = len(new_prompt) + max_new_tokens
        assert total_new <= self.exe.n_slots // 2, "exceeds half window"
        if ctx.n_tokens + total_new > self.exe.n_slots:
            self._condense(ctx, keep=self.exe.n_slots // 2)

        # context switching (the measured QoS metric): missing-state
        # restore is timed; resident assembly is inference (DESIGN.md §2)
        t0 = time.perf_counter()
        reuse = (self._active is not None and self._active[0] == ctx.cid
                 and self._active[2] == self.res.epoch)
        if reuse:
            cache = self._active[1]
            t_switch = time.perf_counter() - t0
            t_assemble = 0.0
        else:
            cache, t_switch = self.res.switch_in(ctx)
            t_assemble = time.perf_counter() - t0 - t_switch

        # inference: extend with the new prompt, then decode
        t1 = time.perf_counter()
        prompt = np.asarray(new_prompt, np.int32)
        n0 = ctx.n_tokens
        ctx.tokens[n0:n0 + len(prompt)] = prompt
        cache, logits, dens = self.exe.extend(cache, prompt, n0)
        self.ctxs.acc_density(ctx, dens, n0 + len(prompt))
        ctx.n_tokens += len(prompt)
        generated: List[int] = []
        if max_new_tokens > 0:
            tok = int(np.argmax(logits))
            for step in range(max_new_tokens):
                generated.append(tok)
                ctx.tokens[ctx.n_tokens] = tok
                ctx.n_tokens += 1
                if step == max_new_tokens - 1:
                    break
                cache, step_logits, mass = self.exe.decode(cache, tok)
                self.ctxs.acc_density(ctx, mass, ctx.n_tokens)
                tok = int(np.argmax(step_logits))
        t_infer = time.perf_counter() - t1

        # compress / AoT swap-out / reclaim (paper §3.2 + §3.4)
        t2 = time.perf_counter()
        self.res.compress_and_swap_out(ctx, cache)
        self.mem.reclaim(0, self.res.evict, locked=set())
        t_out = time.perf_counter() - t2

        self._active = (ctx.cid, cache, self.res.epoch)
        self.records.append({
            "ctx": ctx.cid, "switch_s": t_switch,
            "infer_s": t_infer + t_assemble, "assemble_s": t_assemble,
            "swapout_s": t_out, "new_tokens": len(prompt) + len(generated),
            "mem_used": self.mem.used,
        })
        return stub, generated

    # scheduler hook (§3.4 prediction-driven AoT swap-out)
    def prepare_switch(self, predicted_cid: int) -> int:
        return self.res.prepare_switch(predicted_cid)

    def _condense(self, ctx: Context, keep: int):
        """Context overflow: re-encode the recent tail at [0, keep)."""
        tail = self.ctxs.reset_for_condense(ctx, keep, self.exe.cs)
        self._active = None
        cache = self.exe.fresh_cache(0)
        ctx.tokens[:len(tail)] = tail
        cache, _, dens = self.exe.extend(cache, tail, 0)
        self.ctxs.acc_density(ctx, dens, len(tail))
        ctx.n_tokens = len(tail)
        self.res.compress_and_swap_out(ctx, cache)

    def profile_pipeline(self, n_points: Tuple[int, ...] = (1, 2, 4)):
        self.res.profile_pipeline(n_points)

    def stats(self) -> Dict[str, float]:
        sw = [r["switch_s"] for r in self.records]
        return {
            "calls": len(sw),
            "switch_mean_s": float(np.mean(sw)) if sw else 0.0,
            "switch_p99_s": float(np.percentile(sw, 99)) if sw else 0.0,
            "mem_used": self.mem.used,
            "disk_bytes": self.store.total_bytes,
        }

    def close(self):
        self.swapper.shutdown()
