"""LLMService — the LLMaaS system service (paper Table 1, §3).

Thin facade over the four-layer serving stack (DESIGN.md §1):
``executor.ModelExecutor`` (jitted entry points + bucket/padding),
``context_store.ContextStore`` (persistent contexts, Fig. 4),
``residency.ResidencyEngine`` (switch-in/out, compression, AoT,
eviction), with ``scheduler.ServiceRouter`` as the multi-app front-end
on top.  The paper's full design plus every baseline it compares
against (VLLM-S/SQ, whole-context Swapping, LMK, and the three
ablations) are POLICIES of this one facade so benchmarks measure
like-for-like.  The measured *context switching latency* (Fig. 9) is
the time of ``ResidencyEngine.switch_in`` — the paper's QoS metric.

The request path is stepwise (DESIGN.md §2): ``begin_call`` claims a
decode slot, switches the context in and prefills the prompt;
``decode_step`` emits one token; ``decode_step_batch`` emits one token
for EACH of up to ``decode_batch`` resident generations through a
single jitted batched step; ``finish_call`` compresses/AoT-swaps the
result out and parks the slot.  The router runs generations in bounded
decode slices and may ``suspend_call`` / ``resume_call`` between
slices — preemption evicts one slot (a real, measured context switch
riding the ResidencyEngine) while the rest of the batch keeps
decoding.  ``callLLM`` is the Table-1 compat shim over the same path;
with ``decode_batch=1`` and default ``SamplingParams`` (temperature=0
greedy) it is token-for-token identical to the pre-batch serial
implementation (the singleton path routes through the very same jitted
``decode`` callable).
"""
from __future__ import annotations

import tempfile
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.markers import requires_serialized
from repro.core import compression as comp
from repro.core.context_store import Context, ContextStore, LLMCtxStub  # noqa: F401 (re-export)
from repro.core.executor import ModelExecutor
from repro.core.lifecycle import LCTRUQueue, MemoryManager
from repro.core.requests import GenerationRequest, SamplingParams
from repro.core.residency import ResidencyEngine
from repro.core.swap import AsyncSwapper, DiskStore
from repro.models.api import ModelBase

POLICIES = ("llms", "llms_nocomp", "llms_nopipe", "llms_nolife",
            "vllm_s", "vllm_sq", "swap", "lmk")

# policy -> (compression, use_pipeline, use_lctru, use_aot, chunked, use_disk)
_POLICY_FLAGS = {
    "llms":        ("tolerance", True, True, True, True, True),
    "llms_nocomp": ("none", True, True, True, True, True),
    "llms_nopipe": ("tolerance", False, True, True, True, True),
    "llms_nolife": ("tolerance", True, False, False, True, True),
    "vllm_s":      ("none", False, False, False, True, True),
    "vllm_sq":     ("static8", False, False, False, True, True),
    "swap":        ("none", False, False, False, False, True),
    "lmk":         ("none", False, False, False, False, False),
}


@dataclass
class LLMSConfig:
    policy: str = "llms"
    decode_batch: int = 1                  # working-cache decode slots (B)
    # quant-resident decode (DESIGN.md §2): compressed chunks stay int8
    # in the working cache and attention dequantizes in place (fused
    # kernel), instead of materializing bf16 copies at switch-in.
    # 8-bit (Eq. 3) chunks become directly decodable payloads; 4/2-bit
    # chunks stay packed and re-grid behind the same kernel.  Requires a
    # chunked policy and a family whose KVSpec declares quant_resident.
    quant_resident: bool = False
    # paged, unified KV pool (DESIGN.md §1/§4): dense-family contexts
    # decode as page-table views into one global chunk-granular page
    # arena — switch-in for a pool-resident context is a table read, and
    # batch membership changes cost a table-row swap (true continuous
    # batching).  On by default; families/policies that can't page fall
    # back to slot caches transparently.  pool_pages_* override the
    # arena sizes in pages (0 = auto).
    paged_pool: bool = True
    pool_pages_16: int = 0
    pool_pages_8: int = 0
    chunk_tokens: int = 16
    # bound the retained per-call timing records (scale harness: 10^5+
    # calls would grow ``records`` without bound).  None = keep all;
    # stats percentiles then cover the retained window while ``calls``
    # stays cumulative.
    record_limit: Optional[int] = None
    levels: Tuple[Tuple[int, float], ...] = comp.DEFAULT_LEVELS
    ratio_global: float = 0.5
    memory_budget: int = 64 << 20
    max_ctx_len: int = 512
    max_contexts_per_app: int = 8          # K in the paper
    swap_dir: Optional[str] = None
    # fault tolerance (DESIGN.md §6): transient-IO retry budget per op,
    # and the per-swap watchdog deadline (seconds; None = wait forever)
    # that turns a wedged swap into a SwapTimeoutError the router
    # converts into a preemption
    io_retries: int = 3
    io_retry_base_s: float = 0.002
    swap_deadline_s: Optional[float] = None
    window: int = 0
    n_sinks: int = 0
    compression: str = ""
    use_pipeline: bool = False
    use_lctru: bool = False
    use_aot: bool = False
    chunked: bool = False
    use_disk: bool = False

    def __post_init__(self):
        assert self.policy in POLICIES, self.policy
        assert self.decode_batch >= 1, self.decode_batch
        (self.compression, self.use_pipeline, self.use_lctru, self.use_aot,
         self.chunked, self.use_disk) = _POLICY_FLAGS[self.policy]
        if self.quant_resident and not self.chunked:
            raise ValueError(
                f"quant_resident requires a chunked policy, not "
                f"{self.policy!r} (whole-state caches have no chunk "
                "segments to leave quantized)")
        if not self.chunked:
            self.paged_pool = False     # pages ARE chunks


@dataclass
class GenerationState:
    """One in-flight generation between ``begin_call`` and
    ``finish_call``.  While ``suspended`` the working cache is swapped
    out (``cache is None``) and the pending sampled token plus the
    request's RNG live here, so ``resume_call`` continues the exact
    decode the preemption interrupted."""
    ctx: Context
    request: GenerationRequest
    sampler: Any
    prompt_len: int
    cache: Any = None
    slot: Optional[int] = None              # decode slot while resident
    next_tok: Optional[int] = None          # sampled, not yet emitted
    generated: List[int] = field(default_factory=list)
    t_switch: float = 0.0
    t_assemble: float = 0.0
    t_infer: float = 0.0
    t_swapout: float = 0.0
    n_preempts: int = 0
    suspended: bool = False
    done: bool = False

    @property
    def exhausted(self) -> bool:
        """No more tokens to emit (budget reached or max_new == 0)."""
        return self.next_tok is None


class LLMService:
    """One shared model + per-app persistent contexts (LLMaaS)."""

    def __init__(self, model: ModelBase, params, cfg: LLMSConfig, *,
                 store: Optional[DiskStore] = None,
                 swapper: Optional[AsyncSwapper] = None,
                 queue: Optional[LCTRUQueue] = None,
                 mem: Optional[MemoryManager] = None,
                 cid_alloc: Any = None,
                 records: Any = None):
        # the keyword-only substrate arguments let a ZooService run
        # several family executors against ONE disk store / swapper /
        # LCTRU queue / byte budget / cid space / records stream
        # (DESIGN.md §4); standalone construction builds private ones.
        self.model, self.params, self.cfg = model, params, cfg
        self.exe = ModelExecutor(model, params, cfg)
        if store is None:
            root = cfg.swap_dir or tempfile.mkdtemp(prefix="llms_swap_")
            store = DiskStore(root)
        self.store = store
        self._owns_swapper = swapper is None
        self.swapper = swapper if swapper is not None else AsyncSwapper(
            self.store, retries=cfg.io_retries,
            retry_base_s=cfg.io_retry_base_s)
        self.queue = (queue if queue is not None
                      else LCTRUQueue(lru_only=not cfg.use_lctru))
        self.mem = (mem if mem is not None
                    else MemoryManager(cfg.memory_budget, self.queue))
        self.ctxs = ContextStore(self.mem, self.store, self.exe.s_work,
                                 cid_alloc=cid_alloc)
        self.res = ResidencyEngine(self.exe, self.ctxs, self.store,
                                   self.swapper, self.queue, self.mem, cfg)
        self.records: Any = (records if records is not None
                             else (deque(maxlen=cfg.record_limit)
                                   if cfg.record_limit else []))
        self.total_calls = 0                  # cumulative (records may be
        self._t_switch_sum = 0.0              # a bounded window)
        # cid -> (cache, epoch) of parked decode slots: working-cache
        # reuse, one entry per idle slot (MRU last).  Mirrors
        # ``res.slots.idle`` — the SlotAllocator decides WHICH parked
        # slot to reclaim, this holds WHAT it cached.
        self._reuse: "OrderedDict[int, Tuple[Any, int]]" = OrderedDict()
        # paged mode: generations are views into the unified KV pool
        # (st.cache stays None); batch membership is carried by
        # page-table rows, so there is no merged-cache state to manage
        self.paged = self.exe.paged
        self._closed = False

    @property
    def decode_batch(self) -> int:
        """Number of working-cache decode slots (B)."""
        return self.exe.decode_slots

    @property
    def _active(self) -> Optional[Tuple[int, Any, int]]:
        """Compat view of the most-recently-parked slot as the old
        single-entry (cid, cache, epoch) reuse tuple."""
        if not self._reuse:
            return None
        cid, (cache, epoch) = next(reversed(self._reuse.items()))
        return (cid, cache, epoch)

    def _drop_reuse(self, cid: int):
        self._reuse.pop(cid, None)

    @property
    def contexts(self) -> Dict[int, Context]:
        return self.ctxs.contexts

    @property
    def n_slots(self) -> int:
        return self.exe.n_slots

    @requires_serialized
    def newLLMCtx(self, system_prompt: Optional[Sequence[int]] = None
                  ) -> LLMCtxStub:
        ctx = self.ctxs.create()
        stub = LLMCtxStub(ctx.cid)
        if system_prompt is not None and len(system_prompt):
            self.callLLM(stub, system_prompt, max_new_tokens=0)
        return stub

    @requires_serialized
    def delLLMCtx(self, stub: LLMCtxStub):
        self.ctxs.delete(stub.ctx_id)   # raises on busy: nothing changed
        # give the slot back and drop its reuse entry: a stale cache for
        # a deleted context would pin a full bf16 slot in memory
        self._drop_reuse(stub.ctx_id)
        self.res.slots.release(stub.ctx_id)
        if self.paged:                  # return its pages + page table
            self.res.pool.drop(stub.ctx_id)

    def bindLLMService(self, app: Any = None) -> "LLMService":
        return self

    # ------------------------------------------------------------------ #
    # stepwise request path: begin / decode / (suspend / resume) / finish
    # ------------------------------------------------------------------ #
    @requires_serialized
    def begin_call(self, stub: LLMCtxStub,
                   request: GenerationRequest) -> GenerationState:
        """Admit one request on a context: condense on overflow, switch
        the context in (the measured QoS path), prefill the prompt, and
        sample the first token.  Nothing is emitted yet — the first
        ``decode_step`` emits it."""
        ctx = self.ctxs.get(stub.ctx_id)
        if ctx.busy:
            # a suspended (slice-preempted) generation owns this context's
            # token tail; starting another call would let condense/append
            # rewrite state out from under it.  The router avoids this
            # ordering (same-context arrivals don't preempt); reaching it
            # means the app raced two requests on one context.
            raise RuntimeError(
                f"ctx {ctx.cid} has a suspended in-flight generation; "
                "await or cancel its stream before a new call")
        prompt = np.asarray(request.prompt, np.int32)
        total_new = len(prompt) + request.max_new_tokens
        assert total_new <= self.exe.max_request_tokens, "exceeds half window"
        if ctx.n_tokens + total_new > self.exe.n_slots:
            self._condense(ctx, keep=self.exe.n_slots // 2)

        st = GenerationState(ctx=ctx, request=request,
                             sampler=request.sampling.make_sampler(),
                             prompt_len=len(prompt))
        self._switch_in(st)
        try:
            # inference: extend with the new prompt (prefill)
            t1 = time.perf_counter()
            n0 = ctx.n_tokens
            ctx.tokens[n0:n0 + len(prompt)] = prompt
            if self.paged:
                pool = self.res.pool
                cs = self.exe.cs
                self.res.ensure_extend_range(
                    ctx, n0 // cs, (n0 + len(prompt) - 1) // cs)
                pt16, pt8, qmask = pool.rows([ctx.cid])
                pool.arenas, logits, dens = self.exe.paged_extend(
                    pool.arenas, prompt, n0, pt16, pt8, qmask)
            else:
                cache, logits, dens = self.exe.extend(st.cache, prompt, n0)
                st.cache = cache
            self.ctxs.acc_density(ctx, dens, n0 + len(prompt))
            ctx.n_tokens += len(prompt)
            if request.max_new_tokens > 0:
                st.next_tok = st.sampler(logits)
            st.t_infer += time.perf_counter() - t1
            ctx.busy += 1
        except BaseException:       # failed prefill must not leak the slot
            self.res.slots.release(ctx.cid)
            st.slot = st.cache = None
            raise
        return st

    @requires_serialized
    def decode_step(self, st: GenerationState) -> Optional[int]:
        """Emit the pending token and (if budget remains) run one decode
        step to sample the next.  -> the emitted token, or None when the
        generation is exhausted."""
        return self.decode_step_batch([st])[0]

    @requires_serialized
    def decode_step_batch(self, sts: Sequence[GenerationState]
                          ) -> List[Optional[int]]:
        """One decode round over up to ``decode_batch`` resident
        generations: emit each state's pending token, then run a single
        batched decode step for every state with budget remaining (a
        lone survivor routes through the serial ``decode`` — so with
        decode_batch=1 this IS the serial path, token for token).
        -> emitted tokens parallel to ``sts`` (None where exhausted)."""
        t1 = time.perf_counter()
        out: List[Optional[int]] = []
        live: List[GenerationState] = []
        fed: List[int] = []
        for st in sts:
            if st.done or st.next_tok is None:
                out.append(None)
                continue
            assert not st.suspended, "resume_call before decode_step"
            ctx = st.ctx
            tok = st.next_tok
            st.generated.append(tok)
            ctx.tokens[ctx.n_tokens] = tok
            ctx.n_tokens += 1
            out.append(tok)
            if len(st.generated) >= st.request.max_new_tokens:
                # the final emitted token is appended to the text but
                # never fed (no decode round left): its KV row stays
                # zero.  Track the hole so recompute-based fault
                # recovery skips the token too (DESIGN.md §6).
                ctx.kv_holes.add(ctx.n_tokens - 1)
                st.next_tok = None
            else:
                live.append(st)
                fed.append(tok)
        if live:
            if self.paged:
                self._decode_round_paged(live, fed)
            else:
                # slot mode decodes members serially: the pool carries
                # the batched path, and non-paged families don't support
                # per-row positions in one jitted step
                for st, tok in zip(live, fed):
                    cache, logits, mass = self.exe.decode(st.cache, tok)
                    st.cache = cache
                    self.ctxs.acc_density(st.ctx, mass, st.ctx.n_tokens)
                    st.next_tok = st.sampler(logits)
        n_stepped = sum(tok is not None for tok in out)
        if n_stepped:
            share = (time.perf_counter() - t1) / n_stepped
            for st, tok in zip(sts, out):
                if tok is not None:
                    st.t_infer += share
        return out

    def _decode_round_paged(self, live: List[GenerationState],
                            fed: List[int]):
        """One continuous-batching round over the pool: each live
        generation contributes its page-table row and its own position —
        membership changes between rounds swap table rows, never caches
        (no merge/split)."""
        pool = self.res.pool
        cs = self.exe.cs
        pos = []
        for st in live:
            p = st.ctx.n_tokens - 1         # the just-emitted token
            self.res.ensure_tail(st.ctx, p // cs)
            pool.touch(st.ctx.cid)
            pos.append(p)
        pt16, pt8, qmask = pool.rows([st.ctx.cid for st in live])
        pool.arenas, logits, mass = self.exe.paged_decode(
            pool.arenas, fed, pos, pt16, pt8, qmask)
        for i, st in enumerate(live):
            self.ctxs.acc_density(st.ctx, mass[i], st.ctx.n_tokens)
            st.next_tok = st.sampler(logits[i])

    @requires_serialized
    def suspend_call(self, st: GenerationState):
        """Preempt an in-flight generation: commit the partial result
        (compress + AoT swap-out, exactly a switch-out) and park its
        decode slot — the rest of a batch keeps decoding.  The
        sampled-but-unemitted token stays in the state, so resume
        continues the interrupted decode."""
        assert not (st.suspended or st.done)
        t2 = time.perf_counter()
        self.res.compress_and_swap_out(st.ctx, st.cache)
        self.mem.reclaim(0, self.res.evict, locked=set())
        st.t_swapout += time.perf_counter() - t2
        self._park(st)
        st.suspended = True
        st.n_preempts += 1

    @requires_serialized
    def _park(self, st: GenerationState):
        """Slot held -> idle.  Slot mode keeps the cache for exact-reuse
        resume; paged-persist mode records only the epoch — the pages
        themselves stay in the pool, so the entry just marks the context
        warm (decode-ready) until an eviction invalidates it."""
        if not self.paged:
            self._reuse[st.ctx.cid] = (st.cache, self.res.epoch)
            self._reuse.move_to_end(st.ctx.cid)
        elif self.res.pool_persist and not self.res.force_dequant:
            self._reuse[st.ctx.cid] = (None, self.res.epoch)
            self._reuse.move_to_end(st.ctx.cid)
        self.res.slots.park(st.ctx.cid)
        st.cache = None
        st.slot = None

    @requires_serialized
    def resume_call(self, st: GenerationState):
        """Switch a suspended generation's context back in — a real,
        measured context switch (accumulated into the call's switch_s)."""
        assert st.suspended and not st.done
        st.suspended = False
        try:
            self._switch_in(st)
        except BaseException:
            # stay suspended: the router may requeue and retry the
            # resume (e.g. after a watchdog preemption) — a state that
            # claims residency without a slot would misroute it
            st.suspended = True
            raise

    @requires_serialized
    def finish_call(self, st: GenerationState) -> List[int]:
        """Compress / AoT swap-out / reclaim (paper §3.2 + §3.4) and
        append the per-call timing record.  Safe on a suspended state
        (cancel-after-preempt): the partial result is already out.  The
        busy/record bookkeeping runs even if the swap-out fails, so an
        errored call never bricks its context."""
        ctx = st.ctx
        try:
            if not st.suspended:
                t2 = time.perf_counter()
                self.res.compress_and_swap_out(ctx, st.cache)
                self.mem.reclaim(0, self.res.evict, locked=set())
                st.t_swapout += time.perf_counter() - t2
                self._park(st)
        finally:
            if st.slot is not None:     # park failed: free the slot
                self.res.slots.release(ctx.cid)
                st.slot = None
            st.cache = None
            st.done = True
            ctx.busy -= 1
            self.total_calls += 1
            self._t_switch_sum += st.t_switch
            self.records.append({
                "ctx": ctx.cid, "switch_s": st.t_switch,
                "infer_s": st.t_infer + st.t_assemble,
                "assemble_s": st.t_assemble,
                "swapout_s": st.t_swapout,
                "new_tokens": st.prompt_len + len(st.generated),
                "n_preempts": st.n_preempts,
                "mem_used": self.mem.used,
            })
        return st.generated

    @requires_serialized
    def _switch_in(self, st: GenerationState):
        """Claim a decode slot and switch the context in (the measured
        QoS metric): missing-state restore is timed; resident assembly
        is inference (DESIGN.md §2).  A parked slot still caching this
        context (and untouched by eviction since — epoch match) is the
        zero-restore fast path."""
        ctx = st.ctx
        t0 = time.perf_counter()
        entry = self._reuse.pop(ctx.cid, None)
        st.slot = self.res.slots.acquire(ctx.cid, self._drop_reuse)
        # paged mode never short-circuits: pages may have been dropped
        # on re-encode at swap-out, and switch_in is where stale table
        # entries are re-admitted — it is already near-free when the
        # pages survived (a table read)
        if (not self.paged and entry is not None
                and entry[1] == self.res.epoch):
            st.cache = entry[0]
            st.t_switch += time.perf_counter() - t0
        else:
            try:
                cache, t_sw = self.res.switch_in(ctx)
            except BaseException:
                self.res.slots.release(ctx.cid)
                st.slot = None
                raise
            st.cache = cache
            st.t_switch += t_sw
            st.t_assemble += time.perf_counter() - t0 - t_sw

    # ------------------------------------------------------------------ #
    # Table-1 compat shim: one blocking call over the stepwise path
    # ------------------------------------------------------------------ #
    @requires_serialized
    def callLLM(self, stub: LLMCtxStub, new_prompt: Sequence[int],
                max_new_tokens: int = 16,
                sampling: Optional[SamplingParams] = None
                ) -> Tuple[LLMCtxStub, List[int]]:
        request = GenerationRequest(prompt=new_prompt,
                                    max_new_tokens=max_new_tokens,
                                    sampling=sampling or SamplingParams())
        st = self.begin_call(stub, request)
        while self.decode_step(st) is not None:
            pass
        self.finish_call(st)
        return stub, st.generated

    # scheduler hook (§3.4 prediction-driven AoT swap-out)
    @requires_serialized
    def prepare_switch(self, predicted_cid: int) -> int:
        return self.res.prepare_switch(predicted_cid)

    @requires_serialized
    def _condense(self, ctx: Context, keep: int):
        """Context overflow: re-encode the recent tail at [0, keep)."""
        tail = self.ctxs.reset_for_condense(ctx, keep, self.exe.cs)
        # the rebuilt state invalidates any parked slot cache of THIS ctx
        self._drop_reuse(ctx.cid)
        self.res.slots.release(ctx.cid)
        ctx.tokens[:len(tail)] = tail
        if self.paged:
            # the rebuilt state also invalidates every page this ctx held
            pool = self.res.pool
            pool.drop(ctx.cid)
            self.res.ensure_extend_range(ctx, 0,
                                         (len(tail) - 1) // self.exe.cs)
            pt16, pt8, qmask = pool.rows([ctx.cid])
            pool.arenas, _, dens = self.exe.paged_extend(
                pool.arenas, np.asarray(tail, np.int32), 0,
                pt16, pt8, qmask)
            self.ctxs.acc_density(ctx, dens, len(tail))
            ctx.n_tokens = len(tail)
            self.res.compress_and_swap_out(ctx, None)
        else:
            cache = self.exe.fresh_cache(0)
            cache, _, dens = self.exe.extend(cache, tail, 0)
            self.ctxs.acc_density(ctx, dens, len(tail))
            ctx.n_tokens = len(tail)
            self.res.compress_and_swap_out(ctx, cache)

    @requires_serialized
    def profile_pipeline(self, n_points: Tuple[int, ...] = (1, 2, 4)):
        self.res.profile_pipeline(n_points)

    def decode_ready_contexts(self) -> int:
        """Contexts whose next switch-in needs neither dequantization
        nor disk I/O: generations holding a slot, parked slots whose
        cached state survived every eviction since (epoch match), and —
        with the quant-resident tier on — every context whose chunks
        are all in memory (assembly is then a pure int8 scatter)."""
        ready = set(self.res.slots.held)
        for cid, (_, epoch) in self._reuse.items():
            if epoch == self.res.epoch:
                ready.add(cid)
        if self.exe.quant_resident and not self.res.force_dequant:
            for cid, ctx in self.contexts.items():
                # scatter-ready means every chunk's decode-grid codes
                # already exist: as the payload itself (m.quant) or as
                # the AoT re-grid memo — a packed chunk freshly restored
                # from disk has neither until its next switch-out
                if (ctx.n_tokens and ctx.chunks
                        and all(m.in_memory and m.bits != 16
                                and (m.quant or i in ctx.qmemo)
                                for i, m in ctx.chunks.items())):
                    ready.add(cid)
        return len(ready)

    def stats(self) -> Dict[str, float]:
        from repro.core.restore import io_counters
        sw = [r["switch_s"] for r in self.records]
        n_quant = sum(1 for ctx in self.contexts.values()
                      for m in ctx.chunks.values()
                      if m.in_memory and m.quant)
        io = io_counters()
        out = {
            "calls": len(sw),
            "total_calls": self.total_calls,
            "switch_mean_s": float(np.mean(sw)) if sw else 0.0,
            "switch_p99_s": float(np.percentile(sw, 99)) if sw else 0.0,
            "switch_total_s": self._t_switch_sum,
            "mem_used": self.mem.used,
            "disk_bytes": self.store.total_bytes,
            "disk_bytes_read": io["read"],        # process-cumulative
            "disk_bytes_written": io["write"],    # (see restore.count_io)
            "decode_slots": self.decode_batch,
            "slots_held": len(self.res.slots.held),
            "decode_ready_contexts": self.decode_ready_contexts(),
            "quant_resident_chunks": n_quant,
            "paged_pool": bool(self.paged),
        }
        if self.paged:
            out.update(self.res.pool.stats())
        # fault/detect/recover/degrade counters (DESIGN.md §6); the
        # per-kind injection breakdown stays nested under
        # "faults_injected"
        out.update(self.res.fault_stats())
        return out

    def close(self):
        """Idempotent; flushes pending AoT writes before shutdown so an
        interrupted swap-out never loses committed chunks.  Failed jobs
        were already classified/counted on the workers, and a wedged job
        is abandoned at the watchdog deadline — close never raises or
        hangs on a storage fault."""
        if self._closed:
            return
        self._closed = True
        if self._owns_swapper:      # a zoo shuts the shared swapper once
            self.swapper.shutdown(timeout=self.cfg.swap_deadline_s)

    def __enter__(self) -> "LLMService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
