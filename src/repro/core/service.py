"""LLMService — the LLMaaS system service (paper Table 1, §3).

Thin facade over the four-layer serving stack (DESIGN.md §1):
``executor.ModelExecutor`` (jitted entry points + bucket/padding),
``context_store.ContextStore`` (persistent contexts, Fig. 4),
``residency.ResidencyEngine`` (switch-in/out, compression, AoT,
eviction), with ``scheduler.ServiceRouter`` as the multi-app front-end
on top.  The paper's full design plus every baseline it compares
against (VLLM-S/SQ, whole-context Swapping, LMK, and the three
ablations) are POLICIES of this one facade so benchmarks measure
like-for-like.  The measured *context switching latency* (Fig. 9) is
the time of ``ResidencyEngine.switch_in`` — the paper's QoS metric.

The request path is stepwise (DESIGN.md §2): ``begin_call`` switches
the context in and prefills the prompt, ``decode_step`` emits one
token, ``finish_call`` compresses/AoT-swaps the result out.  The
router runs generations in bounded decode slices and may
``suspend_call`` / ``resume_call`` between slices — preemption is a
real, measured context switch riding the ResidencyEngine.  ``callLLM``
is the Table-1 compat shim over the same path; with default
``SamplingParams`` (temperature=0 greedy) it is token-for-token
identical to the pre-stream blocking implementation.
"""
from __future__ import annotations

import tempfile
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import compression as comp
from repro.core.context_store import Context, ContextStore, LLMCtxStub  # noqa: F401 (re-export)
from repro.core.executor import ModelExecutor
from repro.core.lifecycle import LCTRUQueue, MemoryManager
from repro.core.requests import GenerationRequest, SamplingParams
from repro.core.residency import ResidencyEngine
from repro.core.swap import AsyncSwapper, DiskStore
from repro.models.api import ModelBase

POLICIES = ("llms", "llms_nocomp", "llms_nopipe", "llms_nolife",
            "vllm_s", "vllm_sq", "swap", "lmk")

# policy -> (compression, use_pipeline, use_lctru, use_aot, chunked, use_disk)
_POLICY_FLAGS = {
    "llms":        ("tolerance", True, True, True, True, True),
    "llms_nocomp": ("none", True, True, True, True, True),
    "llms_nopipe": ("tolerance", False, True, True, True, True),
    "llms_nolife": ("tolerance", True, False, False, True, True),
    "vllm_s":      ("none", False, False, False, True, True),
    "vllm_sq":     ("static8", False, False, False, True, True),
    "swap":        ("none", False, False, False, False, True),
    "lmk":         ("none", False, False, False, False, False),
}


@dataclass
class LLMSConfig:
    policy: str = "llms"
    chunk_tokens: int = 16
    levels: Tuple[Tuple[int, float], ...] = comp.DEFAULT_LEVELS
    ratio_global: float = 0.5
    memory_budget: int = 64 << 20
    max_ctx_len: int = 512
    max_contexts_per_app: int = 8          # K in the paper
    swap_dir: Optional[str] = None
    window: int = 0
    n_sinks: int = 0
    compression: str = ""
    use_pipeline: bool = False
    use_lctru: bool = False
    use_aot: bool = False
    chunked: bool = False
    use_disk: bool = False

    def __post_init__(self):
        assert self.policy in POLICIES, self.policy
        (self.compression, self.use_pipeline, self.use_lctru, self.use_aot,
         self.chunked, self.use_disk) = _POLICY_FLAGS[self.policy]


@dataclass
class GenerationState:
    """One in-flight generation between ``begin_call`` and
    ``finish_call``.  While ``suspended`` the working cache is swapped
    out (``cache is None``) and the pending sampled token plus the
    request's RNG live here, so ``resume_call`` continues the exact
    decode the preemption interrupted."""
    ctx: Context
    request: GenerationRequest
    sampler: Any
    prompt_len: int
    cache: Any = None
    next_tok: Optional[int] = None          # sampled, not yet emitted
    generated: List[int] = field(default_factory=list)
    t_switch: float = 0.0
    t_assemble: float = 0.0
    t_infer: float = 0.0
    t_swapout: float = 0.0
    n_preempts: int = 0
    suspended: bool = False
    done: bool = False

    @property
    def exhausted(self) -> bool:
        """No more tokens to emit (budget reached or max_new == 0)."""
        return self.next_tok is None


class LLMService:
    """One shared model + per-app persistent contexts (LLMaaS)."""

    def __init__(self, model: ModelBase, params, cfg: LLMSConfig):
        self.model, self.params, self.cfg = model, params, cfg
        self.exe = ModelExecutor(model, params, cfg)
        root = cfg.swap_dir or tempfile.mkdtemp(prefix="llms_swap_")
        self.store = DiskStore(root)
        self.swapper = AsyncSwapper(self.store)
        self.queue = LCTRUQueue(lru_only=not cfg.use_lctru)
        self.mem = MemoryManager(cfg.memory_budget, self.queue)
        self.ctxs = ContextStore(self.mem, self.store, self.exe.s_work)
        self.res = ResidencyEngine(self.exe, self.ctxs, self.store,
                                   self.swapper, self.queue, self.mem, cfg)
        self.records: List[Dict[str, Any]] = []
        # (cid, cache, epoch) of the last active ctx: working-cache reuse
        self._active: Optional[Tuple[int, Any, int]] = None
        self._closed = False

    @property
    def contexts(self) -> Dict[int, Context]:
        return self.ctxs.contexts

    @property
    def n_slots(self) -> int:
        return self.exe.n_slots

    def newLLMCtx(self, system_prompt: Optional[Sequence[int]] = None
                  ) -> LLMCtxStub:
        ctx = self.ctxs.create()
        stub = LLMCtxStub(ctx.cid)
        if system_prompt is not None and len(system_prompt):
            self.callLLM(stub, system_prompt, max_new_tokens=0)
        return stub

    def delLLMCtx(self, stub: LLMCtxStub):
        self.ctxs.delete(stub.ctx_id)   # raises on busy: nothing changed
        # drop the working-cache reuse tuple: a stale (cid, cache, epoch)
        # for a deleted context would pin the full bf16 cache in memory
        if self._active is not None and self._active[0] == stub.ctx_id:
            self._active = None

    def bindLLMService(self, app: Any = None) -> "LLMService":
        return self

    # ------------------------------------------------------------------ #
    # stepwise request path: begin / decode / (suspend / resume) / finish
    # ------------------------------------------------------------------ #
    def begin_call(self, stub: LLMCtxStub,
                   request: GenerationRequest) -> GenerationState:
        """Admit one request on a context: condense on overflow, switch
        the context in (the measured QoS path), prefill the prompt, and
        sample the first token.  Nothing is emitted yet — the first
        ``decode_step`` emits it."""
        ctx = self.ctxs.get(stub.ctx_id)
        if ctx.busy:
            # a suspended (slice-preempted) generation owns this context's
            # token tail; starting another call would let condense/append
            # rewrite state out from under it.  The router avoids this
            # ordering (same-context arrivals don't preempt); reaching it
            # means the app raced two requests on one context.
            raise RuntimeError(
                f"ctx {ctx.cid} has a suspended in-flight generation; "
                "await or cancel its stream before a new call")
        prompt = np.asarray(request.prompt, np.int32)
        total_new = len(prompt) + request.max_new_tokens
        assert total_new <= self.exe.max_request_tokens, "exceeds half window"
        if ctx.n_tokens + total_new > self.exe.n_slots:
            self._condense(ctx, keep=self.exe.n_slots // 2)

        st = GenerationState(ctx=ctx, request=request,
                             sampler=request.sampling.make_sampler(),
                             prompt_len=len(prompt))
        self._switch_in(st)

        # inference: extend with the new prompt (prefill)
        t1 = time.perf_counter()
        n0 = ctx.n_tokens
        ctx.tokens[n0:n0 + len(prompt)] = prompt
        cache, logits, dens = self.exe.extend(st.cache, prompt, n0)
        self.ctxs.acc_density(ctx, dens, n0 + len(prompt))
        ctx.n_tokens += len(prompt)
        st.cache = cache
        if request.max_new_tokens > 0:
            st.next_tok = st.sampler(logits)
        st.t_infer += time.perf_counter() - t1
        ctx.busy += 1
        return st

    def decode_step(self, st: GenerationState) -> Optional[int]:
        """Emit the pending token and (if budget remains) run one decode
        step to sample the next.  -> the emitted token, or None when the
        generation is exhausted."""
        if st.done or st.next_tok is None:
            return None
        assert not st.suspended, "resume_call before decode_step"
        ctx = st.ctx
        t1 = time.perf_counter()
        tok = st.next_tok
        st.generated.append(tok)
        ctx.tokens[ctx.n_tokens] = tok
        ctx.n_tokens += 1
        if len(st.generated) >= st.request.max_new_tokens:
            st.next_tok = None
        else:
            cache, logits, mass = self.exe.decode(st.cache, tok)
            st.cache = cache
            self.ctxs.acc_density(ctx, mass, ctx.n_tokens)
            st.next_tok = st.sampler(logits)
        st.t_infer += time.perf_counter() - t1
        return tok

    def suspend_call(self, st: GenerationState):
        """Preempt an in-flight generation: commit the partial result
        (compress + AoT swap-out, exactly a switch-out) and drop the
        cache reference.  The sampled-but-unemitted token stays in the
        state, so resume continues the interrupted decode."""
        assert not (st.suspended or st.done)
        t2 = time.perf_counter()
        self.res.compress_and_swap_out(st.ctx, st.cache)
        self.mem.reclaim(0, self.res.evict, locked=set())
        st.t_swapout += time.perf_counter() - t2
        self._active = (st.ctx.cid, st.cache, self.res.epoch)
        st.cache = None
        st.suspended = True
        st.n_preempts += 1

    def resume_call(self, st: GenerationState):
        """Switch a suspended generation's context back in — a real,
        measured context switch (accumulated into the call's switch_s)."""
        assert st.suspended and not st.done
        st.suspended = False
        self._switch_in(st)

    def finish_call(self, st: GenerationState) -> List[int]:
        """Compress / AoT swap-out / reclaim (paper §3.2 + §3.4) and
        append the per-call timing record.  Safe on a suspended state
        (cancel-after-preempt): the partial result is already out.  The
        busy/record bookkeeping runs even if the swap-out fails, so an
        errored call never bricks its context."""
        ctx = st.ctx
        try:
            if not st.suspended:
                t2 = time.perf_counter()
                self.res.compress_and_swap_out(ctx, st.cache)
                self.mem.reclaim(0, self.res.evict, locked=set())
                st.t_swapout += time.perf_counter() - t2
                self._active = (ctx.cid, st.cache, self.res.epoch)
        finally:
            st.cache = None
            st.done = True
            ctx.busy -= 1
            self.records.append({
                "ctx": ctx.cid, "switch_s": st.t_switch,
                "infer_s": st.t_infer + st.t_assemble,
                "assemble_s": st.t_assemble,
                "swapout_s": st.t_swapout,
                "new_tokens": st.prompt_len + len(st.generated),
                "n_preempts": st.n_preempts,
                "mem_used": self.mem.used,
            })
        return st.generated

    def _switch_in(self, st: GenerationState):
        """Context switching (the measured QoS metric): missing-state
        restore is timed; resident assembly is inference (DESIGN.md §2).
        The working-cache reuse fast path skips the restore entirely."""
        ctx = st.ctx
        t0 = time.perf_counter()
        reuse = (self._active is not None and self._active[0] == ctx.cid
                 and self._active[2] == self.res.epoch)
        if reuse:
            st.cache = self._active[1]
            st.t_switch += time.perf_counter() - t0
        else:
            cache, t_sw = self.res.switch_in(ctx)
            st.cache = cache
            st.t_switch += t_sw
            st.t_assemble += time.perf_counter() - t0 - t_sw

    # ------------------------------------------------------------------ #
    # Table-1 compat shim: one blocking call over the stepwise path
    # ------------------------------------------------------------------ #
    def callLLM(self, stub: LLMCtxStub, new_prompt: Sequence[int],
                max_new_tokens: int = 16,
                sampling: Optional[SamplingParams] = None
                ) -> Tuple[LLMCtxStub, List[int]]:
        request = GenerationRequest(prompt=new_prompt,
                                    max_new_tokens=max_new_tokens,
                                    sampling=sampling or SamplingParams())
        st = self.begin_call(stub, request)
        while self.decode_step(st) is not None:
            pass
        self.finish_call(st)
        return stub, st.generated

    # scheduler hook (§3.4 prediction-driven AoT swap-out)
    def prepare_switch(self, predicted_cid: int) -> int:
        return self.res.prepare_switch(predicted_cid)

    def _condense(self, ctx: Context, keep: int):
        """Context overflow: re-encode the recent tail at [0, keep)."""
        tail = self.ctxs.reset_for_condense(ctx, keep, self.exe.cs)
        self._active = None
        cache = self.exe.fresh_cache(0)
        ctx.tokens[:len(tail)] = tail
        cache, _, dens = self.exe.extend(cache, tail, 0)
        self.ctxs.acc_density(ctx, dens, len(tail))
        ctx.n_tokens = len(tail)
        self.res.compress_and_swap_out(ctx, cache)

    def profile_pipeline(self, n_points: Tuple[int, ...] = (1, 2, 4)):
        self.res.profile_pipeline(n_points)

    def stats(self) -> Dict[str, float]:
        sw = [r["switch_s"] for r in self.records]
        return {
            "calls": len(sw),
            "switch_mean_s": float(np.mean(sw)) if sw else 0.0,
            "switch_p99_s": float(np.percentile(sw, 99)) if sw else 0.0,
            "mem_used": self.mem.used,
            "disk_bytes": self.store.total_bytes,
        }

    def close(self):
        """Idempotent; flushes pending AoT writes before shutdown so an
        interrupted swap-out never loses committed chunks."""
        if self._closed:
            return
        self._closed = True
        self.swapper.flush()
        self.swapper.shutdown()

    def __enter__(self) -> "LLMService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
