"""Deterministic, stateless synthetic data pipeline.

Every batch is a pure function of (step, shard) — the straggler/elastic
story depends on this: a replacement worker (or a different data-parallel
world size) regenerates exactly the batches it owes, no data state to
checkpoint (DESIGN.md §6).

The corpus is a seeded first-order Markov language (each token has 8
plausible successors) so models LEARN from it — the Fig.-12 compression
benchmark needs a model whose perplexity means something.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def markov_table(vocab: int, branch: int = 8, seed: int = 1234
                 ) -> np.ndarray:
    """(vocab, branch) successor table, deterministic in seed."""
    rng = np.random.RandomState(seed)
    return rng.randint(0, vocab, size=(vocab, branch)).astype(np.int32)


def markov_sample(table: np.ndarray, length: int, rng: np.random.RandomState
                  ) -> np.ndarray:
    vocab, branch = table.shape
    out = np.empty(length, np.int32)
    t = rng.randint(vocab)
    choices = rng.randint(0, branch, size=length)
    for i in range(length):
        out[i] = t
        t = table[t, choices[i]]
    return out


@dataclass
class SyntheticLM:
    vocab: int
    seq: int
    batch: int
    n_shards: int = 1
    shard: int = 0
    branch: int = 8
    seed: int = 1234

    def __post_init__(self):
        self.table = markov_table(self.vocab, self.branch, self.seed)

    def batch_for_step(self, step: int):
        rng = np.random.RandomState(
            (step * 1_000_003 + self.shard * 7919 + self.seed) % (2**31 - 1))
        toks = np.stack([markov_sample(self.table, self.seq + 1, rng)
                         for _ in range(self.batch)])
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
