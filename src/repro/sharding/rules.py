"""GSPMD sharding rules for every family, keyed by parameter leaf name.

Strategy (DESIGN.md §6):
  * 2D weight sharding: tensor-parallel over "model" on the output
    (heads / ffn-hidden / vocab / experts) and FSDP over "data" on the
    contracting d_model dim — XLA inserts the per-layer all-gathers.
  * Batch over ("pod","data"); KV caches shard the SEQUENCE over "model"
    for decode (flash-decoding style partial-softmax reductions are tiny:
    the B=128 decode_32k cell's per-layer all-reduce is (B,H,1) scalars,
    not (B,H,S) scores).
  * long-context (batch < data axis) shards the cache sequence over ALL
    axes.

Rules are name-based over the param pytree (`jax.tree_util` paths); any
leaf without a rule replicates — small norms/biases, exactly what you
want.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec

# production axis sizes (kept in sync with launch/mesh.py); used to DROP
# a sharded axis whose dim isn't divisible (e.g. whisper's 51865 vocab)
AXIS_SIZE = {"pod": 2, "data": 16, "model": 16}


def _axsize(entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for e in entry:
            n *= AXIS_SIZE[e]
        return n
    return AXIS_SIZE[entry]


def sanitize(spec: P, shape) -> P:
    entries = list(tuple(spec)) + [None] * (len(shape) - len(tuple(spec)))
    out = []
    for dim, e in zip(shape, entries):
        out.append(e if dim % _axsize(e) == 0 else None)
    return P(*out)

# leaves whose LAST dim is the "wide" output (shard model), second-to-last
# is d_model-like (shard data/fsdp)
IN_PROJ = {"wq", "wk", "wv", "w_gate", "w_up", "w_x", "wck", "wcr", "wr",
           "wg", "w1", "s_gate", "s_up", "w_dkv", "embed_proj"}
# leaves whose LAST dim is d_model-like (shard data), second-to-last wide
OUT_PROJ = {"wo", "w_down", "w_out", "wcv", "w2", "s_down"}


def _leaf_name(path) -> str:
    for e in reversed(path):
        if isinstance(e, jax.tree_util.DictKey):
            return e.key
    return ""


def param_pspecs(cfg: ModelConfig, params_tree, *, fsdp: str = "data",
                 tp: str = "model", mode: str = "train"):
    """params_tree: pytree of arrays or ShapeDtypeStructs -> pytree of P.

    mode="train"/"prefill": 2D FSDPxTP weights — per-layer weight
    all-gathers amortize over many tokens.
    mode="decode": WEIGHT-STATIONARY — dense projections are TP-sharded
    and replicated over the data axis (no per-token weight gathers; the
    collectives become activation-sized partial-sum all-reduces), and
    MoE experts shard 2D as (experts x ffn-hidden) over (model x data).
    This is the §Perf fix for the collective-bound decode cells
    (EXPERIMENTS.md §Perf iteration 1)."""
    decode = mode == "decode"
    expert2d = mode == "train_expert2d"

    def rule(path, leaf):
        name = _leaf_name(path)
        nd = len(leaf.shape)
        lead = (None,) * (nd - 2)
        if name == "embed":
            return P(tp, fsdp)
        if name == "head":
            return P(fsdp, tp)
        if name == "projector":
            return P(fsdp, tp)
        if name == "pos_dec":
            return P(None, fsdp)
        if name in ("w_gate", "w_up") and nd == 4:      # MoE experts
            return P(None, tp, None, fsdp) if (decode or expert2d) \
                else P(None, tp, fsdp, None)
        if name == "w_down" and nd == 4:
            return P(None, tp, fsdp, None) if (decode or expert2d) \
                else P(None, tp, None, fsdp)
        if name in ("w_uk", "w_uv"):
            return P(None, None, tp)
        if name in ("gate_a_w", "gate_x_w"):
            return P(None, None, None, tp)
        if name == "conv_k":
            return P(None, None, tp)
        if name in ("conv_b", "lam"):
            return P(None, tp)
        if name == "w_a":
            return P(None, fsdp, None)
        if name == "w_b":
            return P(None, None, fsdp)
        if name == "mix_w1":
            return P(None, fsdp, None)
        if name == "mix_w2":
            return P(None, None, None, fsdp)
        if name == "router":
            return P()                                   # small, replicated
        if name in IN_PROJ and nd >= 2:
            return P(*lead, None, tp) if decode else P(*lead, fsdp, tp)
        if name in OUT_PROJ and nd >= 2:
            return P(*lead, tp, None) if decode else P(*lead, tp, fsdp)
        return P()                                       # norms, biases, u

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: sanitize(rule(path, leaf), leaf.shape),
        params_tree)


def batch_pspecs(batch_tree, dp: Tuple[str, ...]):
    def rule(path, leaf):
        nd = len(leaf.shape)
        return P(dp, *(None,) * (nd - 1))
    return jax.tree_util.tree_map_with_path(rule, batch_tree)


def cache_pspecs(cfg: ModelConfig, cache_tree, shape: ShapeSpec,
                 dp: Tuple[str, ...], tp: str = "model"):
    """Decode-cache shardings.  Leaves are (L, B, S, ...) for seq caches,
    family-specific for states.  B >= |dp| => batch over dp + seq over tp;
    tiny batch (long_500k) => seq over (dp..., tp)."""
    # |dp| isn't known here without the mesh; use the shape heuristic:
    big_batch = shape.global_batch >= 16

    seq_shard = (tp,) if big_batch else tuple(dp) + (tp,)
    bspec = dp if big_batch else None

    def rule(path, leaf):
        name = _leaf_name(path)
        nd = len(leaf.shape)
        if name == "pos":
            return P()
        if name in ("k", "v", "ckv", "kpe", "k_scale", "v_scale"):
            rest = (None,) * (nd - 3)                     # (L,B,S,...)
            return P(None, bspec, seq_shard, *rest)
        if name in ("xk", "xv"):                          # (L,B,F,H,hd)
            return P(None, bspec, None, None, tp)
        if name == "wkv":                                 # (L,B,H,hk,hv)
            return P(None, bspec, tp, None, None)
        if name in ("tm", "cm"):                          # (L,B,d)
            return P(None, bspec, tp)
        if name == "conv":                                # (L,B,cw-1,w)
            return P(None, bspec, None, tp)
        if name == "lru":                                 # (L,B,w)
            return P(None, bspec, tp)
        return P()

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: sanitize(rule(path, leaf), leaf.shape),
        cache_tree)


def state_pspecs(cfg: ModelConfig, state_tree, *, fsdp: str = "data",
                 tp: str = "model", mode: str = "train"):
    """Train state: params + optimizer moments share the param rules.

    state = {"params": ..., "mu": ..., "nu": ..., (quantized variants),
             "step": scalar}.  Moment trees mirror params, so reuse
    param_pspecs leaf-wise by name.
    """
    p_specs = param_pspecs(cfg, state_tree["params"], fsdp=fsdp, tp=tp,
                           mode=mode)
    out = {}
    for k, sub in state_tree.items():
        if k == "step":
            out[k] = P()
        elif k in ("mu_scale", "nu_scale"):
            # per-row scales: the param's spec minus its last dim
            out[k] = jax.tree_util.tree_map(
                lambda s: P(*tuple(s)[:-1]), p_specs,
                is_leaf=lambda x: isinstance(x, P))
        else:
            out[k] = p_specs
    return out
