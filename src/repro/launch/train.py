"""Training driver: pjit'd train_step factory + CLI entry point with
checkpoint/restart (fault tolerance) and deterministic data sharding.

``make_train_step`` is consumed both by the real trainer below and by
the dry-run (launch/dryrun.py) which lowers it against
ShapeDtypeStructs on the production mesh.
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models.api import ModelBase
from repro.models.registry import build_model
from repro.train.optimizer import OptConfig, apply_updates, init_state

PyTree = Any


def make_train_step(model: ModelBase, opt_cfg: OptConfig, n_micro: int = 1,
                    dp=None):
    """n_micro > 1: microbatched gradient accumulation (lax.scan over
    batch splits, fp32 accumulator sharded like the params) — the
    standard memory lever for the deep/wide assigned archs."""

    def grad_of(params, batch):
        return jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)

    def train_step(state: Dict[str, PyTree], batch: Dict[str, jax.Array]
                   ) -> Tuple[Dict[str, PyTree], Dict[str, jax.Array]]:
        if n_micro == 1:
            (_, metrics), grads = grad_of(state["params"], batch)
        else:
            def resplit(a):
                b = a.reshape(n_micro, a.shape[0] // n_micro, *a.shape[1:])
                if dp is not None:
                    from jax.sharding import PartitionSpec as P
                    b = jax.lax.with_sharding_constraint(
                        b, P(None, dp, *([None] * (a.ndim - 1))))
                return b

            mb = jax.tree.map(resplit, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state["params"])

            def micro(carry, b):
                gsum, _ = carry
                (_, metrics), g = grad_of(state["params"], b)
                gsum = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gsum, g)
                return (gsum, metrics), None

            m0 = {"loss": jnp.float32(0), "acc": jnp.float32(0)}
            (gsum, metrics), _ = jax.lax.scan(micro, (g0, m0), mb)
            grads = jax.tree.map(lambda a: a / n_micro, gsum)
        new_state, opt_metrics = apply_updates(state, grads, opt_cfg)
        return new_state, {**metrics, **opt_metrics}

    return train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-sized config (CPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--quantized-opt", action="store_true")
    args = ap.parse_args()

    from repro.data.pipeline import SyntheticLM
    from repro.train.checkpoint import latest_step, restore, save_async

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cfg = cfg.with_overrides(max_seq=max(cfg.max_seq, args.seq))
    model = build_model(cfg)
    opt_cfg = OptConfig(lr=args.lr, quantized=args.quantized_opt)
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    data = SyntheticLM(vocab=cfg.vocab, seq=args.seq, batch=args.batch,
                       n_shards=1, shard=0)

    start = 0
    if args.resume and (s := latest_step(args.ckpt_dir)) is not None:
        state = restore(args.ckpt_dir, s)
        start = int(state["step"]) if "step" in state else s
        print(f"[train] resumed from step {start}")
    else:
        params = model.init(jax.random.PRNGKey(0))
        state = init_state(params, opt_cfg)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = data.batch_for_step(step)
        state, metrics = step_fn(state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            print(f"[train] step {step:5d} loss={m['loss']:.4f} "
                  f"acc={m['acc']:.3f} gnorm={m['grad_norm']:.2f} "
                  f"({time.time()-t0:.1f}s)")
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            save_async(args.ckpt_dir, step + 1, state)
    print("[train] done; final loss", float(metrics["loss"]))


if __name__ == "__main__":
    main()
