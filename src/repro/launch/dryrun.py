import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the model + ShapeDtypeStruct inputs (input_specs — zero
     allocation),
  2. jits the right entry point (train_step / prefill / decode_step)
     with the production in_shardings,
  3. ``.lower().compile()`` on the 16x16 (single-pod) or 2x16x16
     (multi-pod) mesh,
  4. records memory_analysis(), cost_analysis(), and the per-device
     collective bytes parsed from the post-SPMD HLO,
and writes a JSON report consumed by benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import functools
import json
import re
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, SHAPES, get_config, shape_applicability
from repro.launch.mesh import data_axes, make_production_mesh
from repro.models import common as mcommon
from repro.launch.train import make_train_step
from repro.models.registry import build_model
from repro.sharding.rules import (batch_pspecs, cache_pspecs, param_pspecs,
                                  state_pspecs)
from repro.train.optimizer import OptConfig, init_state

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}
# ring-algorithm byte multipliers per collective kind (per device)
_COLL_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}
_COLL_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes moved through collectives, from post-SPMD HLO."""
    out = {k: 0.0 for k in _COLL_FACTOR}
    n_ops = 0
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind] += n * _DTYPE_BYTES[dtype] * _COLL_FACTOR[kind]
        n_ops += 1
    out["total"] = sum(out.values())
    out["n_ops"] = n_ops
    return out


def big_arch(cfg) -> bool:
    return cfg.param_count() > 2e10


def micro_steps(cfg, shape, multi_pod: bool) -> int:
    """Gradient-accumulation factor: keep per-microbatch activation
    residency ~<= a few GiB/chip.  Heuristic: one sequence per device per
    microstep for d_model >= 4096, else split by activation volume."""
    n_data = 32 if multi_pod else 16
    seqs_per_dev = max(shape.global_batch // n_data, 1)
    S = shape.seq_len
    # per-sequence residency: saved-x (bf16, ~L/sqrt spread) + flash-bwd
    # block transients (fp32 p/ds at block=1024 across heads)
    per_seq = 2 * cfg.d_model * S + 8 * cfg.n_heads * S * 1024
    target = 4 << 30
    micro_seqs = max(1, min(seqs_per_dev, target // max(per_seq, 1)))
    while seqs_per_dev % micro_seqs:
        micro_seqs -= 1
    return max(1, seqs_per_dev // micro_seqs)


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               decode_sharding: str = "fsdp", kv_dtype: str = "bf16",
               train_sharding: str = "fsdp"):
    """-> (fn, example_args tuple of SDS, in_shardings, out_shardings)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, note = shape_applicability(cfg, shape)
    if not ok:
        return None, note
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = data_axes(multi_pod)
    mcommon.set_batch_axes(dp)
    entry, kwargs = model.input_specs(shape)
    ns = lambda spec: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, P))

    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_pspecs(cfg, params_struct)

    window, sinks = model.streaming_window(shape)

    if entry == "train":
        opt = OptConfig(quantized=big_arch(cfg))
        state_struct = jax.eval_shape(
            functools.partial(init_state, cfg=opt), params_struct)
        mode = "train_expert2d" if train_sharding == "expert2d" else "train"
        s_specs = state_pspecs(cfg, state_struct, mode=mode)
        b_specs = batch_pspecs(kwargs["batch"], dp)
        n_micro = micro_steps(cfg, shape, multi_pod)
        fn = make_train_step(model, opt, n_micro=n_micro, dp=dp)
        return (fn, (state_struct, kwargs["batch"]),
                (ns(s_specs), ns(b_specs)), (ns(s_specs), None)), note
    if entry == "prefill":
        want_density = model.kv_spec().density
        fn = functools.partial(model.prefill, want_density=want_density,
                               window=window, n_sinks=sinks)
        b_specs = batch_pspecs(kwargs["batch"], dp)
        return (fn, (params_struct, kwargs["batch"]),
                (ns(p_specs), ns(b_specs)), None), note
    # decode
    fn = functools.partial(model.decode_step, window=window, n_sinks=sinks)
    n_data = 32 if multi_pod else 16
    tok_spec = P(dp, None) if shape.global_batch >= n_data else P(None, None)
    if decode_sharding == "stationary":
        p_specs = param_pspecs(cfg, params_struct, mode="decode")
    cache_struct = kwargs["cache"]
    if kv_dtype == "int8" and model.kv_spec().int8_serving:
        cache_struct = model.cache_specs(shape, dtype=jnp.int8)
    c_specs = cache_pspecs(cfg, cache_struct, shape, dp)
    return (fn, (params_struct, kwargs["tokens"], cache_struct),
            (ns(p_specs), NamedSharding(mesh, tok_spec), ns(c_specs)),
            None), note


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = "reports",
             decode_sharding: str = "fsdp", kv_dtype: str = "bf16",
             tag: str = "", train_sharding: str = "fsdp") -> Dict[str, Any]:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "variant": tag or "baseline"}
    t0 = time.time()
    try:
        built, note = build_cell(arch, shape_name, multi_pod,
                                 decode_sharding, kv_dtype, train_sharding)
        rec["note"] = note
        if built is None:
            rec["status"] = "skipped"
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
                  f"SKIP ({note})")
            if out_dir:
                os.makedirs(out_dir, exist_ok=True)
                fname = f"dryrun_{arch}_{shape_name}_{mesh_name}.json"
                with open(os.path.join(out_dir, fname), "w") as f:
                    json.dump(rec, f, indent=1)
            return rec
        fn, args, in_sh, out_sh = built
        mesh = make_production_mesh(multi_pod=multi_pod)
        shape = SHAPES[shape_name]
        donate = (2,) if shape.kind == "decode" else ()
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        with mesh:
            lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                k: int(getattr(mem, k, 0)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes")
            },
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "collectives": coll,
        })
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"args={rec['memory']['argument_size_in_bytes']/2**30:.2f}GiB "
              f"temp={rec['memory']['temp_size_in_bytes']/2**30:.2f}GiB "
              f"flops={rec['flops']:.3g} coll={coll['total']/2**20:.1f}MiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print("  memory_analysis:", rec["memory"])
        print("  cost_analysis: flops=%.4g bytes=%.4g" %
              (rec["flops"], rec["bytes_accessed"]))
    except Exception as e:          # noqa: BLE001 — report failures per cell
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAIL {e}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fname = f"dryrun_{arch}_{shape_name}_{mesh_name}{suffix}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="reports")
    ap.add_argument("--decode-sharding", default="fsdp",
                    choices=("fsdp", "stationary"))
    ap.add_argument("--train-sharding", default="fsdp",
                    choices=("fsdp", "expert2d"))
    ap.add_argument("--kv-dtype", default="bf16", choices=("bf16", "int8"))
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cells = []
    archs = list(ASSIGNED) if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None \
        else [args.shape]
    for a in archs:
        for s in shapes:
            cells.append((a, s))
    results = [run_cell(a, s, args.multi_pod, args.out,
                        args.decode_sharding, args.kv_dtype, args.tag,
                        args.train_sharding)
               for a, s in cells]
    ok = sum(r["status"] == "ok" for r in results)
    skip = sum(r["status"] == "skipped" for r in results)
    fail = sum(r["status"] == "fail" for r in results)
    print(f"[dryrun] done: {ok} ok / {skip} skipped / {fail} failed "
          f"of {len(results)}")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
