"""Production mesh factory.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis composes with "data" for batch/context sharding and gradient
reduction (DCN-ish), "model" stays intra-pod (ICI).

A FUNCTION, not a module constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)}; run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512")
    from jax.sharding import AxisType
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes),
                         devices=devs[:n])


def data_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def make_debug_mesh(n_data: int = 2, n_model: int = 1):
    """Tiny mesh over however many local devices exist (tests)."""
    devs = jax.devices()[:n_data * n_model]
    return jax.make_mesh((len(devs) // n_model, n_model),
                         ("data", "model"),
                         devices=np.asarray(devs).reshape(-1, n_model))
