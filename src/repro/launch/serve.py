"""Serving driver: replay a synthesized context-switching trace through
the multi-app ServiceRouter (compressed-time: arrival gaps are bookkept,
not slept).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --policy llms --contexts 4 --calls 24 --concurrency 2 --slice-steps 4

``--concurrency N`` registers N app sessions with the router; each app
submits its share of the trace from its own thread, so admission is
genuinely concurrent while model execution stays serial (the paper's
working-set lock).  ``--priority-mix a:b`` assigns priorities to apps
round-robin (a foreground apps, then b background apps, repeating);
the router admits foreground calls ahead of queued background ones and
reports per-priority latency (queue wait + service) plus TTFT/TBT
percentiles from the stream timestamps.

``--slice-steps K`` enables decode-slice dispatch: generations run in
bounded K-step slices and a newly arrived foreground request preempts
an in-flight background stream mid-generation.  A/B the flag (0 =
whole-generation dispatch) to see foreground TTFT drop.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import threading
import time

import jax

from repro.configs import get_config, reduced
from repro.core.scheduler import ServiceRouter
from repro.core.service import LLMSConfig, LLMService, POLICIES
from repro.models.registry import build_model
from repro.trace.synth import PATTERNS, synthesize


def parse_priority_mix(mix: str, n_apps: int):
    """"a:b" -> per-app priority names, fg-first round-robin."""
    try:
        fg, bg = (int(x) for x in mix.split(":"))
        if fg < 0 or bg < 0 or fg + bg == 0:
            raise ValueError(mix)
    except ValueError:
        raise SystemExit(
            f"--priority-mix must be 'FG:BG' with FG+BG > 0, got {mix!r}") from None
    cycle = ["foreground"] * fg + ["background"] * bg
    return [cycle[i % len(cycle)] for i in range(n_apps)]


def run_trace(router: ServiceRouter, events, n_apps: int = 1,
              priority_mix: str = "1:1", max_new: int = 8, verbose=False,
              pace: float = 0.0):
    """Replay ``events`` through ``router`` with ``n_apps`` submitting
    apps; contexts are assigned to apps round-robin.  ``pace`` replays
    the trace's Poisson arrival gaps in real time (wall seconds per
    trace second, 0 = submit everything immediately) — with a threaded
    router and ``slice_steps`` set, paced foreground arrivals land
    mid-generation and preempt in-flight background streams."""
    apps = [router.register_app(f"app{i}", prio) for i, prio in
            enumerate(parse_priority_mix(priority_mix, n_apps))]
    session_of = {}                 # ctx_id -> AppSession
    stubs = {}
    for ev in events:
        if ev.ctx_id not in stubs:
            sess = apps[ev.ctx_id % n_apps]
            session_of[ev.ctx_id] = sess
            stubs[ev.ctx_id] = sess.new_ctx()

    streams = []
    t0 = time.perf_counter()

    def submit_all(sess):
        for ev in events:
            if session_of[ev.ctx_id] is sess:
                if pace > 0:
                    lag = ev.time * pace - (time.perf_counter() - t0)
                    if lag > 0:
                        time.sleep(lag)
                streams.append(sess.stream(stubs[ev.ctx_id],
                                           ev.prompt.tolist(),
                                           max_new_tokens=max_new))

    if router.started and n_apps > 1:
        threads = [threading.Thread(target=submit_all, args=(s,))
                   for s in apps]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    else:
        for sess in apps:
            submit_all(sess)
    router.drain()
    errors = [s.error for s in streams if s.error is not None]
    for e in errors[:3]:
        print(f"  !! dropped call: {type(e).__name__}: {e}")

    if verbose:
        for r in router.call_records:
            ttft = r.get("ttft_s")
            print(f"  {r['app']:6s} prio={r['priority']} ctx={r['ctx']}"
                  f" wait={r['wait_s']*1e3:7.2f}ms"
                  f" switch={r['switch_s']*1e3:7.2f}ms"
                  f" service={r['service_s']*1e3:7.1f}ms"
                  + (f" ttft={ttft*1e3:7.2f}ms" if ttft is not None else "")
                  + (f" preempts={r['n_preempts']}"
                     if r.get("n_preempts") else ""))
    stats = router.svc.stats()
    stats["router"] = router.stats()
    stats["failed_calls"] = len(errors)
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="llms", choices=POLICIES)
    ap.add_argument("--pattern", default="markov", choices=PATTERNS)
    ap.add_argument("--contexts", type=int, default=4)
    ap.add_argument("--calls", type=int, default=24)
    ap.add_argument("--max-ctx", type=int, default=256)
    ap.add_argument("--budget-mib", type=float, default=2.0)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--concurrency", type=int, default=1,
                    help="number of app sessions submitting the trace")
    ap.add_argument("--priority-mix", default="1:1",
                    help="fg:bg app ratio, assigned round-robin")
    ap.add_argument("--slice-steps", type=int, default=0,
                    help="decode-slice length K (0 = whole-generation "
                         "dispatch; >0 enables mid-generation preemption)")
    ap.add_argument("--decode-batch", type=int, default=1,
                    help="working-cache decode slots B: up to B queued "
                         "generations decode as one jitted batch "
                         "(1 = the serial paper-prototype path)")
    ap.add_argument("--paged-pool", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="decode over the unified paged KV pool "
                         "(switch-in = a page-table read; "
                         "--no-paged-pool restores per-slot caches)")
    ap.add_argument("--quant-resident", action="store_true",
                    help="attend over quantized chunks in place: 8-bit "
                         "chunks stay int8 in the working cache behind "
                         "the fused decode kernel, 4/2-bit re-grid at "
                         "assembly (requires a chunked policy + dense "
                         "family)")
    ap.add_argument("--pace", type=float, default=0.0,
                    help="wall seconds per trace second when replaying "
                         "arrival gaps (0 = compressed time)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sc = LLMSConfig(policy=args.policy, max_ctx_len=args.max_ctx,
                    memory_budget=int(args.budget_mib * 2**20),
                    decode_batch=args.decode_batch,
                    quant_resident=args.quant_resident,
                    paged_pool=args.paged_pool,
                    swap_dir=tempfile.mkdtemp(prefix="llms_serve_"))
    events = synthesize(args.contexts, args.calls, cfg.vocab,
                        pattern=args.pattern, scale=0.1, seed=args.seed)
    with LLMService(model, params, sc) as svc:
        if sc.use_pipeline:
            svc.profile_pipeline()
        with ServiceRouter(svc, predict=True, start=args.concurrency > 1,
                           slice_steps=args.slice_steps) as router:
            t0 = time.time()
            stats = run_trace(router, events,
                              n_apps=max(1, args.concurrency),
                              priority_mix=args.priority_mix,
                              max_new=args.max_new, verbose=True,
                              pace=args.pace)
            stats["wall_s"] = time.time() - t0
            print(json.dumps(stats, indent=1))


if __name__ == "__main__":
    main()
