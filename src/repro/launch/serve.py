"""Serving driver: replay a synthesized context-switching trace through
the multi-app ServiceRouter (compressed-time: arrival gaps are bookkept,
not slept).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --policy llms --contexts 4 --calls 24 --concurrency 2

``--concurrency N`` registers N app sessions with the router; each app
submits its share of the trace from its own thread, so admission is
genuinely concurrent while model execution stays serial (the paper's
working-set lock).  ``--priority-mix a:b`` assigns priorities to apps
round-robin (a foreground apps, then b background apps, repeating);
the router admits foreground calls ahead of queued background ones and
reports per-priority latency (queue wait + service).
"""
from __future__ import annotations

import argparse
import json
import tempfile
import threading
import time

import jax

from repro.configs import get_config, reduced
from repro.core.scheduler import ServiceRouter
from repro.core.service import LLMSConfig, LLMService, POLICIES
from repro.models.registry import build_model
from repro.trace.synth import PATTERNS, synthesize


def parse_priority_mix(mix: str, n_apps: int):
    """"a:b" -> per-app priority names, fg-first round-robin."""
    try:
        fg, bg = (int(x) for x in mix.split(":"))
        if fg < 0 or bg < 0 or fg + bg == 0:
            raise ValueError(mix)
    except ValueError:
        raise SystemExit(
            f"--priority-mix must be 'FG:BG' with FG+BG > 0, got {mix!r}")
    cycle = ["foreground"] * fg + ["background"] * bg
    return [cycle[i % len(cycle)] for i in range(n_apps)]


def run_trace(router: ServiceRouter, events, n_apps: int = 1,
              priority_mix: str = "1:1", max_new: int = 8, verbose=False):
    """Replay ``events`` through ``router`` with ``n_apps`` submitting
    apps; contexts are assigned to apps round-robin."""
    apps = [router.register_app(f"app{i}", prio) for i, prio in
            enumerate(parse_priority_mix(priority_mix, n_apps))]
    session_of = {}                 # ctx_id -> AppSession
    stubs = {}
    for ev in events:
        if ev.ctx_id not in stubs:
            sess = apps[ev.ctx_id % n_apps]
            session_of[ev.ctx_id] = sess
            stubs[ev.ctx_id] = sess.new_ctx()

    futs = []

    def submit_all(sess):
        for ev in events:
            if session_of[ev.ctx_id] is sess:
                futs.append(sess.submit(stubs[ev.ctx_id], ev.prompt.tolist(),
                                        max_new_tokens=max_new))

    if router.started and n_apps > 1:
        threads = [threading.Thread(target=submit_all, args=(s,))
                   for s in apps]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    else:
        for sess in apps:
            submit_all(sess)
    router.drain()
    errors = [f.exception() for f in futs if f.exception() is not None]
    for e in errors[:3]:
        print(f"  !! dropped call: {type(e).__name__}: {e}")

    if verbose:
        for r in router.call_records:
            print(f"  {r['app']:6s} prio={r['priority']} ctx={r['ctx']}"
                  f" wait={r['wait_s']*1e3:7.2f}ms"
                  f" switch={r['switch_s']*1e3:7.2f}ms"
                  f" service={r['service_s']*1e3:7.1f}ms")
    stats = router.svc.stats()
    stats["router"] = router.stats()
    stats["failed_calls"] = len(errors)
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="llms", choices=POLICIES)
    ap.add_argument("--pattern", default="markov", choices=PATTERNS)
    ap.add_argument("--contexts", type=int, default=4)
    ap.add_argument("--calls", type=int, default=24)
    ap.add_argument("--max-ctx", type=int, default=256)
    ap.add_argument("--budget-mib", type=float, default=2.0)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--concurrency", type=int, default=1,
                    help="number of app sessions submitting the trace")
    ap.add_argument("--priority-mix", default="1:1",
                    help="fg:bg app ratio, assigned round-robin")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sc = LLMSConfig(policy=args.policy, max_ctx_len=args.max_ctx,
                    memory_budget=int(args.budget_mib * 2**20),
                    swap_dir=tempfile.mkdtemp(prefix="llms_serve_"))
    svc = LLMService(model, params, sc)
    if sc.use_pipeline:
        svc.profile_pipeline()
    events = synthesize(args.contexts, args.calls, cfg.vocab,
                        pattern=args.pattern, scale=0.1, seed=args.seed)
    router = ServiceRouter(svc, predict=True, start=args.concurrency > 1)
    t0 = time.time()
    stats = run_trace(router, events, n_apps=max(1, args.concurrency),
                      priority_mix=args.priority_mix,
                      max_new=args.max_new, verbose=True)
    stats["wall_s"] = time.time() - t0
    print(json.dumps(stats, indent=1))
    router.shutdown()
    svc.close()


if __name__ == "__main__":
    main()
