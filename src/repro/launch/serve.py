"""Serving driver: replay a synthesized context-switching trace through
the LLMService (compressed-time: arrival gaps are bookkept, not slept).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --policy llms --contexts 4 --calls 24
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.service import LLMSConfig, LLMService, POLICIES
from repro.models.registry import build_model
from repro.trace.synth import PATTERNS, synthesize


def run_trace(svc: LLMService, events, max_new: int = 8, verbose=False):
    stubs = {}
    for ev in events:
        if ev.ctx_id not in stubs:
            stubs[ev.ctx_id] = svc.newLLMCtx()
        svc.callLLM(stubs[ev.ctx_id], ev.prompt.tolist(),
                    max_new_tokens=max_new)
        if verbose:
            r = svc.records[-1]
            print(f"  t={ev.time:9.1f}s ctx={ev.ctx_id} ds={ev.dataset:14s}"
                  f" switch={r['switch_s']*1e3:7.2f}ms"
                  f" infer={r['infer_s']*1e3:7.1f}ms"
                  f" mem={r['mem_used']/2**20:6.1f}MiB")
    return svc.stats()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--policy", default="llms", choices=POLICIES)
    ap.add_argument("--pattern", default="markov", choices=PATTERNS)
    ap.add_argument("--contexts", type=int, default=4)
    ap.add_argument("--calls", type=int, default=24)
    ap.add_argument("--max-ctx", type=int, default=256)
    ap.add_argument("--budget-mib", type=float, default=2.0)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sc = LLMSConfig(policy=args.policy, max_ctx_len=args.max_ctx,
                    memory_budget=int(args.budget_mib * 2**20),
                    swap_dir=tempfile.mkdtemp(prefix="llms_serve_"))
    svc = LLMService(model, params, sc)
    if sc.use_pipeline:
        svc.profile_pipeline()
    events = synthesize(args.contexts, args.calls, cfg.vocab,
                        pattern=args.pattern, scale=0.1, seed=args.seed)
    t0 = time.time()
    stats = run_trace(svc, events, max_new=args.max_new, verbose=True)
    stats["wall_s"] = time.time() - t0
    print(json.dumps(stats, indent=1))
    svc.close()


if __name__ == "__main__":
    main()
