"""Llama-3.2-Vision style VLM: dense text backbone with tanh-gated
cross-attention layers every ``cross_attn_every`` layers.

The vision tower is a STUB per the assignment: the batch provides
precomputed patch embeddings ``patches`` (B, n_image_tokens, d_vision);
the model owns only the projector and cross-attention layers.

Layer layout (100 layers, cross every 5th): 20 blocks of
[4 self-attn layers -> 1 gated cross-attn layer]; both scanned.

LLMS applicability: self-attn KV chunks get the full treatment; the
cross-attn KV depends on image embeddings (not recomputable from text),
so its chunks are swap-only — see DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSpec
from repro.models import common as C
from repro.models.api import DecodeOut, PrefillOut
from repro.models.dense import DenseModel, blockwise_ce
from repro.models.kvspec import KVSpec

Array = jax.Array


class VLMModel(DenseModel):

    def kv_spec(self) -> KVSpec:
        cfg = self.cfg
        kv_dims = (cfg.n_kv_heads, cfg.head_dim)
        return KVSpec(
            family=cfg.family,
            # self-attn K/V is token-indexed and chunkable; the
            # cross-attn image blocks (xk/xv) are constant-size state —
            # derived from image embeddings, NOT recomputable from text
            seq_leaves=("k", "v"),
            leaf_dims={"k": kv_dims, "v": kv_dims},
            state_leaves=("xk", "xv"),
            servable=False,           # prefill needs patches: no text-only
            chunkable=True,           # recompute/extend path in the executor
            recomputable=False,
            batched_decode=False,
            quant_resident=False,
            paged=False,
            pipelined_restore=False,
            # image-conditioned chunks carry no cross-head redundancy
            # the Eq.-3 planner can exploit: floor at 8-bit
            tolerance_class="image",
            min_bits=8,
            int8_serving=True,
            streaming_long=True,
        )

    def _counts(self):
        cfg = self.cfg
        every = cfg.vision.cross_attn_every
        n_cross = cfg.n_layers // every
        n_self = cfg.n_layers - n_cross
        per_block = every - 1
        return n_self, n_cross, per_block

    def init(self, key) -> Dict:
        cfg = self.cfg
        vis = cfg.vision
        n_self, n_cross, _ = self._counts()
        d, H, KV, hd, ff = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim, cfg.d_ff)
        ks = jax.random.split(key, 20)
        lin = C.init_linear
        self_layers = {
            "ln_attn": jnp.ones((n_self, d), jnp.float32),
            "ln_ffn": jnp.ones((n_self, d), jnp.float32),
            "wq": lin(ks[0], (n_self, d, H * hd)),
            "wk": lin(ks[1], (n_self, d, KV * hd)),
            "wv": lin(ks[2], (n_self, d, KV * hd)),
            "wo": lin(ks[3], (n_self, H * hd, d)),
            "w_gate": lin(ks[4], (n_self, d, ff)),
            "w_up": lin(ks[5], (n_self, d, ff)),
            "w_down": lin(ks[6], (n_self, ff, d)),
        }
        cross_layers = {
            "ln_attn": jnp.ones((n_cross, d), jnp.float32),
            "ln_ffn": jnp.ones((n_cross, d), jnp.float32),
            "wq": lin(ks[7], (n_cross, d, H * hd)),
            "wk": lin(ks[8], (n_cross, d, KV * hd)),
            "wv": lin(ks[9], (n_cross, d, KV * hd)),
            "wo": lin(ks[10], (n_cross, H * hd, d)),
            "q_norm": jnp.ones((n_cross, hd), jnp.float32),
            "k_norm": jnp.ones((n_cross, hd), jnp.float32),
            "gate_attn": jnp.zeros((n_cross,), jnp.float32),
            "gate_ffn": jnp.zeros((n_cross,), jnp.float32),
            "w_gate": lin(ks[11], (n_cross, d, ff)),
            "w_up": lin(ks[12], (n_cross, d, ff)),
            "w_down": lin(ks[13], (n_cross, ff, d)),
        }
        return {
            "embed": lin(ks[14], (cfg.vocab, d)),
            "head": lin(ks[15], (d, cfg.vocab)),
            "ln_f": jnp.ones((d,), jnp.float32),
            "projector": lin(ks[16], (vis.d_vision, d)),
            "self_layers": self_layers,
            "cross_layers": cross_layers,
        }

    # -- cross-attention layer ------------------------------------------- #
    def _cross_kv(self, pc, img):
        """img: (B, I, d) projected patches -> K/V (B, I, KV, hd)."""
        cfg = self.cfg
        B, I, _ = img.shape
        k = (img @ pc["wk"]).reshape(B, I, cfg.n_kv_heads, cfg.head_dim)
        v = (img @ pc["wv"]).reshape(B, I, cfg.n_kv_heads, cfg.head_dim)
        k = C.rms_norm(k, pc["k_norm"], cfg.norm_eps)
        return k, v

    def _cross_layer(self, pc, x, xk, xv):
        cfg = self.cfg
        B, S, _ = x.shape
        h = C.rms_norm(x, pc["ln_attn"], cfg.norm_eps)
        q = (h @ pc["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        q = C.rms_norm(q, pc["q_norm"], cfg.norm_eps)
        mask = jnp.ones((S, xk.shape[1]), bool)
        ao = C.gqa_attention(q, xk, xv, mask)
        x = x + jnp.tanh(pc["gate_attn"]).astype(x.dtype) * (
            ao.out.reshape(B, S, -1) @ pc["wo"])
        h = C.rms_norm(x, pc["ln_ffn"], cfg.norm_eps)
        y = C.swiglu(h, pc["w_gate"], pc["w_up"], pc["w_down"])
        return x + jnp.tanh(pc["gate_ffn"]).astype(x.dtype) * y

    # -- stacked forward --------------------------------------------------- #
    def _forward_full(self, params, tokens, patches, *, window=0, n_sinks=0,
                      want_density=False, return_kv=False, remat=False):
        cfg = self.cfg
        n_self, n_cross, per = self._counts()
        x = C.constrain_batch(params["embed"][tokens].astype(jnp.bfloat16))
        img = C.constrain_batch(
            patches.astype(jnp.bfloat16) @ params["projector"])
        S = tokens.shape[1]
        positions = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
        sp = jax.tree.map(
            lambda a: a.reshape(n_cross, per, *a.shape[1:]),
            params["self_layers"])

        def block(x, inp):
            ps, pc = inp
            extras_k, extras_v, dens = [], [], []
            for j in range(per):
                pl = jax.tree.map(lambda a, j=j: a[j], ps)
                x, ex = self._layer_full(pl, x, positions, window, n_sinks,
                                         want_density, return_kv)
                if return_kv:
                    extras_k.append(ex["k"])
                    extras_v.append(ex["v"])
                if want_density:
                    dens.append(ex["density"])
            xk, xv = self._cross_kv(pc, img)
            x = C.constrain_batch(self._cross_layer(pc, x, xk, xv))
            ys = {}
            if return_kv:
                ys["k"] = jnp.stack(extras_k)
                ys["v"] = jnp.stack(extras_v)
                ys["xk"], ys["xv"] = xk, xv
            if want_density:
                ys["density"] = jnp.stack(dens)
            return x, ys

        if remat:
            block = jax.checkpoint(block)
        x, ys = jax.lax.scan(block, x, (sp, params["cross_layers"]))
        x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
        return x, ys

    # -- entry points -------------------------------------------------------- #
    def loss(self, params, batch):
        x, _ = self._forward_full(params, batch["tokens"], batch["patches"],
                                  remat=True)
        return blockwise_ce(x, self.head_weight(params), batch["targets"],
                            batch.get("mask"))

    def prefill(self, params, batch, want_density=False, window=0, n_sinks=0):
        tokens = batch["tokens"]
        x, ys = self._forward_full(params, tokens, batch["patches"],
                                   window=window, n_sinks=n_sinks,
                                   want_density=want_density, return_kv=True)
        logits = (x[:, -1] @ self.head_weight(params)).astype(jnp.float32)
        n_self, n_cross, per = self._counts()
        k = ys["k"].reshape(n_self, *ys["k"].shape[2:])
        v = ys["v"].reshape(n_self, *ys["v"].shape[2:])
        cache = {"k": k, "v": v, "xk": ys["xk"], "xv": ys["xv"],
                 "pos": jnp.int32(tokens.shape[1])}
        density = None
        if want_density:
            density = jnp.mean(ys["density"], axis=(0, 1))
        return PrefillOut(logits, cache, density)

    def decode_step(self, params, tokens, cache, window=0, n_sinks=0,
                    want_density=False):
        cfg = self.cfg
        n_self, n_cross, per = self._counts()
        x = C.constrain_batch(params["embed"][tokens].astype(jnp.bfloat16))
        pos = cache["pos"]
        positions = pos[None]
        sp = jax.tree.map(
            lambda a: a.reshape(n_cross, per, *a.shape[1:]),
            params["self_layers"])
        kb = cache["k"].reshape(n_cross, per, *cache["k"].shape[1:])
        vb = cache["v"].reshape(n_cross, per, *cache["v"].shape[1:])

        def block(x, inp):
            ps, pc, k_cb, v_cb, xk, xv = inp
            k_out, v_out = [], []
            for j in range(per):
                pl = jax.tree.map(lambda a, j=j: a[j], ps)
                h = C.rms_norm(x, pl["ln_attn"], cfg.norm_eps)
                q, k, v = self._qkv(pl, h)
                q, k = self._rope(q, k, positions)
                k_c = C.ring_update(k_cb[j], k, pos)
                v_c = C.ring_update(v_cb[j], v, pos)
                out = C.decode_attention(q, k_c, v_c, pos + 1,
                                         window=window, n_sinks=n_sinks)
                x = x + out.reshape(*x.shape[:2], -1) @ pl["wo"]
                x = self._ffn(pl, x)
                k_out.append(k_c)
                v_out.append(v_c)
            x = C.constrain_batch(self._cross_layer(pc, x, xk, xv))
            return x, (jnp.stack(k_out), jnp.stack(v_out))

        x, (k_new, v_new) = jax.lax.scan(
            block, x, (sp, params["cross_layers"], kb, vb,
                       cache["xk"], cache["xv"]))
        x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = (x[:, 0] @ self.head_weight(params)).astype(jnp.float32)
        cache_out = {
            "k": k_new.reshape(n_self, *cache["k"].shape[1:]),
            "v": v_new.reshape(n_self, *cache["v"].shape[1:]),
            "xk": cache["xk"], "xv": cache["xv"], "pos": pos + 1,
        }
        out = DecodeOut(logits, cache_out)
        if want_density:
            # density is tracked at prefill granularity for VLM; the
            # accumulator tolerates a short zero row
            return out, jnp.zeros((tokens.shape[0], 1), jnp.float32)
        return out

    def _build_cache(self, batch, seq, dtype, layout):
        cfg = self.cfg
        n_self, n_cross, _ = self._counts()
        vis = cfg.vision
        shape = (n_self, batch, seq, cfg.n_kv_heads, cfg.head_dim)
        xshape = (n_cross, batch, vis.n_image_tokens, cfg.n_kv_heads,
                  cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "xk": jnp.zeros(xshape, dtype), "xv": jnp.zeros(xshape, dtype),
                "pos": jnp.int32(0)}

    def batch_specs(self, shape: ShapeSpec):
        cfg = self.cfg
        specs = super().batch_specs(shape)
        specs["patches"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.vision.n_image_tokens,
             cfg.vision.d_vision), jnp.bfloat16)
        return specs
