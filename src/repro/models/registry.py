"""Family -> model-class registry.  ``build_model(cfg)`` is the single
construction point used by the trainer, server, dry-run and tests, and
``family_spec(cfg)`` the single capability-query surface: anything that
needs to know what a family's cache can do asks for its KVSpec here
instead of string-matching ``cfg.family`` (the analysis ``familycheck``
pass bans family-string dispatch everywhere else)."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.api import ModelBase
from repro.models.kvspec import KVSpec
from repro.models.dense import DenseModel
from repro.models.encdec import EncDecModel
from repro.models.mla import MLAModel
from repro.models.moe import MoEModel
from repro.models.rglru import RGLRUModel
from repro.models.rwkv6 import RWKV6Model
from repro.models.vlm import VLMModel

FAMILY_CLASSES = {
    "dense": DenseModel,
    "moe": MoEModel,
    "mla_moe": MLAModel,
    "rglru_hybrid": RGLRUModel,
    "rwkv6": RWKV6Model,
    "encdec": EncDecModel,
    "vlm": VLMModel,
}


FAMILIES = tuple(FAMILY_CLASSES)


def build_model(cfg: ModelConfig) -> ModelBase:
    return FAMILY_CLASSES[cfg.family](cfg)


def family_spec(cfg: ModelConfig) -> KVSpec:
    """The family's declarative cache adapter for this config.  Cheap:
    model construction allocates no parameters."""
    return build_model(cfg).kv_spec()
