"""MoE decoder-only transformer (llama4-maverick family).

Dense attention stack (GQA + RoPE) with every FFN replaced by a routed
MoE (top-1 over 128 experts for llama4) plus one always-on shared expert.
Inherits all attention / cache / recompute machinery from DenseModel —
only ``init`` and ``_ffn`` change.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.models import common as C
from repro.models.dense import DenseModel
from repro.models.kvspec import KVSpec
from repro.models.moe_layer import init_moe_params, moe_ffn


class MoEModel(DenseModel):

    def kv_spec(self) -> KVSpec:
        # dense attention cache, but recompute replays expert routing —
        # too expensive for restore planning / paged recovery until the
        # expert-aware switch-in lands (ROADMAP follow-on)
        return dataclasses.replace(super().kv_spec(),
                                   recomputable=False, paged=False,
                                   pipelined_restore=False)

    def init(self, key):
        cfg = self.cfg
        assert cfg.moe is not None
        base = super().init(key)
        layers = base["layers"]
        # drop the dense FFN weights; install MoE ones
        for name in ("w_gate", "w_up", "w_down"):
            del layers[name]
        kmoe = jax.random.fold_in(key, 1337)
        layers.update(init_moe_params(kmoe, cfg.d_model, cfg.moe,
                                      n_layers=cfg.n_layers))
        return base

    def _ffn(self, pl, x):
        h = C.rms_norm(x, pl["ln_ffn"], self.cfg.norm_eps)
        moe_keys = ("router", "w_gate", "w_up", "w_down", "s_gate", "s_up",
                    "s_down")
        p = {k: pl[k] for k in moe_keys if k in pl}
        y, _ = moe_ffn(h, p, self.cfg.moe)
        return x + y
