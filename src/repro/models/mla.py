"""DeepSeek-V2 style Multi-head Latent Attention + MoE (deepseek-v2-lite).

MLA caches a single compressed latent per token — ``ckv`` (kv_lora_rank)
plus a shared roped key ``kpe`` (qk_rope_head_dim) — instead of per-head
K/V.  Prefill uses the naive up-projection form (efficient when S tokens
share the up-projection); decode uses the **absorbed** form (q is folded
through W_uk, attention runs directly against the rank-512 latent), the
standard MLA serving trick.

LLMS applicability: chunks store (ckv, kpe) slices — the paper's
compression/swapping applies to the latent directly, and ``recompute``
restores missing latent chunks exactly (global RoPE on kpe).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as C
from repro.models.api import DecodeOut, PrefillOut
from repro.models.dense import DenseModel
from repro.models.moe_layer import init_moe_params, moe_ffn

Array = jax.Array


class MLAModel(DenseModel):
    # overrides init_cache/decode_step/recompute without the mixed
    # bf16+int8 cache: do not inherit the dense opt-in
    supports_quant_resident = False

    def init(self, key):
        cfg = self.cfg
        m, moe = cfg.mla, cfg.moe
        assert m is not None and moe is not None
        d, H, L = cfg.d_model, cfg.n_heads, cfg.n_layers
        qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
        ks = jax.random.split(key, 12)
        lin = C.init_linear
        layers = {
            "ln_attn": jnp.ones((L, d), jnp.float32),
            "ln_ffn": jnp.ones((L, d), jnp.float32),
            "ln_kv": jnp.ones((L, m.kv_lora_rank), jnp.float32),
            "wq": lin(ks[0], (L, d, H * qk_hd)),
            "w_dkv": lin(ks[1], (L, d, m.kv_lora_rank + m.qk_rope_head_dim)),
            "w_uk": lin(ks[2], (L, m.kv_lora_rank, H * m.qk_nope_head_dim)),
            "w_uv": lin(ks[3], (L, m.kv_lora_rank, H * m.v_head_dim)),
            "wo": lin(ks[4], (L, H * m.v_head_dim, d)),
        }
        layers.update(init_moe_params(jax.random.fold_in(key, 7),
                                      d, moe, n_layers=L))
        return {
            "embed": lin(ks[5], (cfg.vocab, d)),
            "head": lin(ks[6], (d, cfg.vocab)),
            "ln_f": jnp.ones((d,), jnp.float32),
            "layers": layers,
        }

    def _ffn(self, pl, x):
        h = C.rms_norm(x, pl["ln_ffn"], self.cfg.norm_eps)
        moe_keys = ("router", "w_gate", "w_up", "w_down", "s_gate", "s_up",
                    "s_down")
        y, _ = moe_ffn(h, {k: pl[k] for k in moe_keys if k in pl},
                       self.cfg.moe)
        return x + y

    # -- latent computation shared by prefill / recompute --------------- #
    def _latents(self, pl, h, positions):
        """h: (B,S,d) -> (ckv (B,S,rank), kpe (B,S,rope)) roped."""
        m = self.cfg.mla
        kv = h @ pl["w_dkv"]
        ckv, kpe = kv[..., :m.kv_lora_rank], kv[..., m.kv_lora_rank:]
        ckv = C.rms_norm(ckv, pl["ln_kv"], self.cfg.norm_eps)
        cos, sin = C.rope_angles(positions, m.qk_rope_head_dim, self.cfg.rope_theta)
        kpe = C.apply_rope(kpe[..., None, :], cos, sin)[..., 0, :]
        return ckv, kpe

    def _queries(self, pl, h, positions):
        m, cfg = self.cfg.mla, self.cfg
        B, S, _ = h.shape
        qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
        q = (h @ pl["wq"]).reshape(B, S, cfg.n_heads, qk_hd)
        q_nope, q_pe = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
        cos, sin = C.rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
        q_pe = C.apply_rope(q_pe, cos, sin)
        return q_nope, q_pe

    def _expand_kv(self, pl, ckv, kpe):
        """Latent -> per-head K (nope+rope) and V.  ckv (B,S,rank)."""
        m, H = self.cfg.mla, self.cfg.n_heads
        B, S, _ = ckv.shape
        k_nope = (ckv @ pl["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
        v = (ckv @ pl["w_uv"]).reshape(B, S, H, m.v_head_dim)
        kpe_h = jnp.broadcast_to(kpe[:, :, None, :],
                                 (B, S, H, m.qk_rope_head_dim))
        k = jnp.concatenate([k_nope, kpe_h.astype(k_nope.dtype)], axis=-1)
        return k, v

    # -- full-sequence layer -------------------------------------------- #
    def _layer_full(self, pl, x, positions, window, n_sinks, want_density,
                    return_kv):
        h = C.rms_norm(x, pl["ln_attn"], self.cfg.norm_eps)
        q_nope, q_pe = self._queries(pl, h, positions)
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        ckv, kpe = self._latents(pl, h, positions)
        k, v = self._expand_kv(pl, ckv, kpe)
        S = x.shape[1]
        if (S > 2048 or window) and not want_density:
            out = C.flash_attention(q, k, v, 0, 1024, window, n_sinks)
            ao = C.AttnOut(out, None)
        elif S > 2048 or window:
            ao = C.blocked_causal_attention(q, k, v, block=1024, window=window,
                                            n_sinks=n_sinks,
                                            want_density=want_density)
        else:
            mask = C.causal_window_mask(positions, positions, window, n_sinks)
            ao = C.gqa_attention(q, k, v, mask, want_density=want_density)
        x = x + ao.out.reshape(*x.shape[:2], -1) @ pl["wo"]
        x = self._ffn(pl, x)
        extras = {}
        if want_density:
            extras["density"] = ao.key_density
        if return_kv:
            extras["ckv"], extras["kpe"] = ckv, kpe
        return x, extras

    def prefill(self, params, batch, want_density=False, window=0, n_sinks=0):
        tokens = batch["tokens"]
        x, extras = self._stack_full(
            params, tokens, window=window, n_sinks=n_sinks,
            want_density=want_density, return_kv=True)
        logits = (x[:, -1] @ self.head_weight(params)).astype(jnp.float32)
        cache = {"ckv": extras["ckv"], "kpe": extras["kpe"],
                 "pos": jnp.int32(tokens.shape[1])}
        density = None
        if want_density:
            density = jnp.mean(extras["density"], axis=0)
        return PrefillOut(logits, cache, density)

    # -- absorbed decode ------------------------------------------------- #
    def decode_step(self, params, tokens, cache, window=0, n_sinks=0):
        cfg, m = self.cfg, self.cfg.mla
        H = cfg.n_heads
        x = C.constrain_batch(
            params["embed"][tokens].astype(jnp.bfloat16))      # (B,1,d)
        pos = cache["pos"]
        positions = pos[None]
        qk_scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_head_dim
                                              + m.qk_rope_head_dim))

        def body(x, layer_in):
            pl, ckv_c, kpe_c = layer_in
            h = C.rms_norm(x, pl["ln_attn"], cfg.norm_eps)
            q_nope, q_pe = self._queries(pl, h, positions)      # (B,1,H,*)
            ckv_t, kpe_t = self._latents(pl, h, positions)
            ckv_c = C.ring_update(ckv_c, ckv_t, pos)            # (B,S,rank)
            kpe_c = C.ring_update(kpe_c, kpe_t, pos)
            # absorb W_uk into q:  q_abs (B,1,H,rank)
            w_uk = pl["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
            q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)
            s = (jnp.einsum("bqhr,bsr->bhqs", q_abs, ckv_c,
                            preferred_element_type=jnp.float32)
                 + jnp.einsum("bqhr,bsr->bhqs", q_pe, kpe_c,
                              preferred_element_type=jnp.float32)) * qk_scale
            S = ckv_c.shape[1]
            k_pos = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
            valid = k_pos[None, :] < (pos + 1)
            if window:
                valid = valid & ((k_pos[None, :] >= pos + 1 - window)
                                 | (k_pos[None, :] < n_sinks))
            s = jnp.where(valid[:, None, None, :], s, C.NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            ctx = jnp.einsum("bhqs,bsr->bqhr", p.astype(ckv_c.dtype), ckv_c)
            w_uv = pl["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
            out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv)
            x = x + out.reshape(*x.shape[:2], -1) @ pl["wo"]
            x = C.constrain_batch(self._ffn(pl, x))
            return x, (ckv_c, kpe_c)

        x, (ckv_new, kpe_new) = jax.lax.scan(
            body, x, (params["layers"], cache["ckv"], cache["kpe"]))
        x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = (x[:, 0] @ self.head_weight(params)).astype(jnp.float32)
        return DecodeOut(logits,
                         {"ckv": ckv_new, "kpe": kpe_new, "pos": pos + 1})

    def init_cache(self, batch, seq, dtype=jnp.bfloat16):
        cfg, m = self.cfg, self.cfg.mla
        return {
            "ckv": jnp.zeros((cfg.n_layers, batch, seq, m.kv_lora_rank), dtype),
            "kpe": jnp.zeros((cfg.n_layers, batch, seq, m.qk_rope_head_dim),
                             dtype),
            "pos": jnp.int32(0),
        }

    # -- Fig. 7 recompute over latent chunks ----------------------------- #
    def recompute(self, params, miss_tokens, miss_pos, cache, seq_len,
                  window: int = 0, n_sinks: int = 0, want_density=False):
        cfg = self.cfg
        x = C.constrain_batch(
            params["embed"][miss_tokens].astype(jnp.bfloat16))
        S = cache["ckv"].shape[2]
        k_pos_all = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)

        def body(x, layer_in):
            pl, ckv_c, kpe_c = layer_in
            h = C.rms_norm(x, pl["ln_attn"], cfg.norm_eps)
            q_nope, q_pe = self._queries(pl, h, miss_pos)
            q = jnp.concatenate([q_nope, q_pe], axis=-1)
            ckv_t, kpe_t = self._latents(pl, h, miss_pos)
            ckv_c = ckv_c.at[:, miss_pos].set(ckv_t.astype(ckv_c.dtype))
            kpe_c = kpe_c.at[:, miss_pos].set(kpe_t.astype(kpe_c.dtype))
            k, v = self._expand_kv(pl, ckv_c.astype(x.dtype),
                                   kpe_c.astype(x.dtype))
            mask = C.causal_window_mask(miss_pos, k_pos_all, window, n_sinks)
            mask = mask & (k_pos_all < seq_len)[None, :]
            ao = C.gqa_attention(q, k, v, mask, want_density=want_density)
            x = x + ao.out.reshape(*x.shape[:2], -1) @ pl["wo"]
            x = C.constrain_batch(self._ffn(pl, x))
            ys = {"ckv": ckv_c, "kpe": kpe_c}
            if want_density:
                ys["density"] = ao.key_density
            return x, ys

        x, ys = jax.lax.scan(
            body, x, (params["layers"], cache["ckv"], cache["kpe"]))
        x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
        density = jnp.mean(ys["density"], axis=0) if want_density else None
        return ({"ckv": ys["ckv"], "kpe": ys["kpe"], "pos": cache["pos"]},
                x, density)
