"""DeepSeek-V2 style Multi-head Latent Attention + MoE (deepseek-v2-lite).

MLA caches a single compressed latent per token — ``ckv`` (kv_lora_rank)
plus a shared roped key ``kpe`` (qk_rope_head_dim) — instead of per-head
K/V.  Prefill uses the naive up-projection form (efficient when S tokens
share the up-projection); decode uses the **absorbed** form (q is folded
through W_uk, attention runs directly against the rank-512 latent), the
standard MLA serving trick.

LLMS applicability: chunks store (ckv, kpe) slices — the paper's
compression/swapping applies to the latent directly, and ``recompute``
restores missing latent chunks exactly (global RoPE on kpe).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common as C
from repro.models.api import DecodeOut, PrefillOut
from repro.models.dense import DenseModel
from repro.models.kvspec import KVSpec, LAYOUT_MIXED, LAYOUT_WINDOW
from repro.models.moe_layer import init_moe_params, moe_ffn

Array = jax.Array

# mixed-precision (quant-resident) latent leaves: int8 codes + per-
# (token, layer) scales over the whole rank vector, riding along the
# bf16 window exactly like dense's k_q/v_q tier
_LATENT_QUANT_LEAVES = ("ckv_q", "kpe_q", "ckv_scale", "kpe_scale")


def _latent_select(c, q, s, qm0):
    """Per-position select between the bf16 window and the dequantized
    int8 resident segment.  c (B,S,r) bf16; q (B,S,r) int8; s (B,S)
    fp32; qm0 (B,S) bool.  Matches the residency dequantize path
    bit-for-bit at 8-bit (codes * scale, rounded once to c.dtype)."""
    deq = (q.astype(jnp.float32) * s[..., None]).astype(c.dtype)
    return jnp.where(qm0[..., None], deq, c)


class MLAModel(DenseModel):

    def kv_spec(self) -> KVSpec:
        cfg, m = self.cfg, self.cfg.mla
        return KVSpec(
            family=cfg.family,
            seq_leaves=("ckv", "kpe"),
            leaf_dims={"ckv": (m.kv_lora_rank,),
                       "kpe": (m.qk_rope_head_dim,)},
            servable=True,
            chunkable=True,
            recomputable=True,
            batched_decode=False,
            quant_resident=True,
            paged=False,
            pipelined_restore=False,
            layouts=(LAYOUT_WINDOW, LAYOUT_MIXED),
            # the rank-512 latent carries no cross-head redundancy: the
            # Eq.-3 planner stops at 8-bit (where dense K/V may drop to
            # 4/2), so every swapped chunk is quant-resident eligible
            tolerance_class="latent",
            min_bits=8,
            streaming_long=True,
        )

    def init(self, key):
        cfg = self.cfg
        m, moe = cfg.mla, cfg.moe
        assert m is not None and moe is not None
        d, H, L = cfg.d_model, cfg.n_heads, cfg.n_layers
        qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
        ks = jax.random.split(key, 12)
        lin = C.init_linear
        layers = {
            "ln_attn": jnp.ones((L, d), jnp.float32),
            "ln_ffn": jnp.ones((L, d), jnp.float32),
            "ln_kv": jnp.ones((L, m.kv_lora_rank), jnp.float32),
            "wq": lin(ks[0], (L, d, H * qk_hd)),
            "w_dkv": lin(ks[1], (L, d, m.kv_lora_rank + m.qk_rope_head_dim)),
            "w_uk": lin(ks[2], (L, m.kv_lora_rank, H * m.qk_nope_head_dim)),
            "w_uv": lin(ks[3], (L, m.kv_lora_rank, H * m.v_head_dim)),
            "wo": lin(ks[4], (L, H * m.v_head_dim, d)),
        }
        layers.update(init_moe_params(jax.random.fold_in(key, 7),
                                      d, moe, n_layers=L))
        return {
            "embed": lin(ks[5], (cfg.vocab, d)),
            "head": lin(ks[6], (d, cfg.vocab)),
            "ln_f": jnp.ones((d,), jnp.float32),
            "layers": layers,
        }

    def _ffn(self, pl, x):
        h = C.rms_norm(x, pl["ln_ffn"], self.cfg.norm_eps)
        moe_keys = ("router", "w_gate", "w_up", "w_down", "s_gate", "s_up",
                    "s_down")
        y, _ = moe_ffn(h, {k: pl[k] for k in moe_keys if k in pl},
                       self.cfg.moe)
        return x + y

    # -- latent computation shared by prefill / recompute --------------- #
    def _latents(self, pl, h, positions):
        """h: (B,S,d) -> (ckv (B,S,rank), kpe (B,S,rope)) roped."""
        m = self.cfg.mla
        kv = h @ pl["w_dkv"]
        ckv, kpe = kv[..., :m.kv_lora_rank], kv[..., m.kv_lora_rank:]
        ckv = C.rms_norm(ckv, pl["ln_kv"], self.cfg.norm_eps)
        cos, sin = C.rope_angles(positions, m.qk_rope_head_dim, self.cfg.rope_theta)
        kpe = C.apply_rope(kpe[..., None, :], cos, sin)[..., 0, :]
        return ckv, kpe

    def _queries(self, pl, h, positions):
        m, cfg = self.cfg.mla, self.cfg
        B, S, _ = h.shape
        qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
        q = (h @ pl["wq"]).reshape(B, S, cfg.n_heads, qk_hd)
        q_nope, q_pe = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
        cos, sin = C.rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
        q_pe = C.apply_rope(q_pe, cos, sin)
        return q_nope, q_pe

    def _expand_kv(self, pl, ckv, kpe):
        """Latent -> per-head K (nope+rope) and V.  ckv (B,S,rank)."""
        m, H = self.cfg.mla, self.cfg.n_heads
        B, S, _ = ckv.shape
        k_nope = (ckv @ pl["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
        v = (ckv @ pl["w_uv"]).reshape(B, S, H, m.v_head_dim)
        kpe_h = jnp.broadcast_to(kpe[:, :, None, :],
                                 (B, S, H, m.qk_rope_head_dim))
        k = jnp.concatenate([k_nope, kpe_h.astype(k_nope.dtype)], axis=-1)
        return k, v

    # -- full-sequence layer -------------------------------------------- #
    def _layer_full(self, pl, x, positions, window, n_sinks, want_density,
                    return_kv):
        h = C.rms_norm(x, pl["ln_attn"], self.cfg.norm_eps)
        q_nope, q_pe = self._queries(pl, h, positions)
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        ckv, kpe = self._latents(pl, h, positions)
        k, v = self._expand_kv(pl, ckv, kpe)
        S = x.shape[1]
        if (S > 2048 or window) and not want_density:
            out = C.flash_attention(q, k, v, 0, 1024, window, n_sinks)
            ao = C.AttnOut(out, None)
        elif S > 2048 or window:
            ao = C.blocked_causal_attention(q, k, v, block=1024, window=window,
                                            n_sinks=n_sinks,
                                            want_density=want_density)
        else:
            mask = C.causal_window_mask(positions, positions, window, n_sinks)
            ao = C.gqa_attention(q, k, v, mask, want_density=want_density)
        x = x + ao.out.reshape(*x.shape[:2], -1) @ pl["wo"]
        x = self._ffn(pl, x)
        extras = {}
        if want_density:
            extras["density"] = ao.key_density
        if return_kv:
            extras["ckv"], extras["kpe"] = ckv, kpe
        return x, extras

    def prefill(self, params, batch, want_density=False, window=0, n_sinks=0):
        tokens = batch["tokens"]
        x, extras = self._stack_full(
            params, tokens, window=window, n_sinks=n_sinks,
            want_density=want_density, return_kv=True)
        logits = (x[:, -1] @ self.head_weight(params)).astype(jnp.float32)
        cache = {"ckv": extras["ckv"], "kpe": extras["kpe"],
                 "pos": jnp.int32(tokens.shape[1])}
        density = None
        if want_density:
            density = jnp.mean(extras["density"], axis=0)
        return PrefillOut(logits, cache, density)

    # -- absorbed decode ------------------------------------------------- #
    def decode_step(self, params, tokens, cache, window=0, n_sinks=0,
                    want_density=False):
        cfg, m = self.cfg, self.cfg.mla
        H = cfg.n_heads
        x = C.constrain_batch(
            params["embed"][tokens].astype(jnp.bfloat16))      # (B,1,d)
        pos = cache["pos"]
        positions = pos[None]
        qk_scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_head_dim
                                              + m.qk_rope_head_dim))

        mixed = "ckv_q" in cache         # bf16 window + int8 latent tier
        if mixed:
            # the new token lands in the bf16 window: clear its
            # quant-mask bit once (the mask is shared across layers)
            S = cache["ckv"].shape[2]
            s_pos = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
            idx = pos[None] if pos.ndim == 0 else pos
            qm = cache["quant_mask"] & ~(s_pos[None, :] == idx[:, None])[None]

        def body(x, layer_in):
            ckvq_c = kpeq_c = ckvs_c = kpes_c = None
            if mixed:
                pl, ckv_c, kpe_c, ckvq_c, kpeq_c, ckvs_c, kpes_c = layer_in
            else:
                pl, ckv_c, kpe_c = layer_in
            h = C.rms_norm(x, pl["ln_attn"], cfg.norm_eps)
            q_nope, q_pe = self._queries(pl, h, positions)      # (B,1,H,*)
            ckv_t, kpe_t = self._latents(pl, h, positions)
            ckv_c = C.ring_update(ckv_c, ckv_t, pos)            # (B,S,rank)
            kpe_c = C.ring_update(kpe_c, kpe_t, pos)
            if mixed:
                ckv_att = _latent_select(ckv_c, ckvq_c, ckvs_c, qm[0])
                kpe_att = _latent_select(kpe_c, kpeq_c, kpes_c, qm[0])
            else:
                ckv_att, kpe_att = ckv_c, kpe_c
            # absorb W_uk into q:  q_abs (B,1,H,rank)
            w_uk = pl["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
            q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)
            s = (jnp.einsum("bqhr,bsr->bhqs", q_abs, ckv_att,
                            preferred_element_type=jnp.float32)
                 + jnp.einsum("bqhr,bsr->bhqs", q_pe, kpe_att,
                              preferred_element_type=jnp.float32)) * qk_scale
            S = ckv_c.shape[1]
            k_pos = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
            valid = k_pos[None, :] < (pos + 1)
            if window:
                valid = valid & ((k_pos[None, :] >= pos + 1 - window)
                                 | (k_pos[None, :] < n_sinks))
            s = jnp.where(valid[:, None, None, :], s, C.NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            ctx = jnp.einsum("bhqs,bsr->bqhr", p.astype(ckv_att.dtype),
                             ckv_att)
            w_uv = pl["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
            out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv)
            x = x + out.reshape(*x.shape[:2], -1) @ pl["wo"]
            x = C.constrain_batch(self._ffn(pl, x))
            ys = {"ckv": ckv_c, "kpe": kpe_c}
            if want_density:
                # Eq.-1 key mass at the decoded position: head-mean of
                # the softmax row over the latent sequence
                ys["mass"] = jnp.mean(p[:, :, 0, :], axis=1)    # (B, S)
            return x, ys

        xs = (params["layers"], cache["ckv"], cache["kpe"])
        if mixed:
            xs = xs + tuple(cache[n] for n in _LATENT_QUANT_LEAVES)
        x, ys = jax.lax.scan(body, x, xs)
        x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = (x[:, 0] @ self.head_weight(params)).astype(jnp.float32)
        new_cache = {"ckv": ys["ckv"], "kpe": ys["kpe"], "pos": pos + 1}
        if mixed:
            for n in _LATENT_QUANT_LEAVES:
                new_cache[n] = cache[n]
            new_cache["quant_mask"] = qm
        out = DecodeOut(logits, new_cache)
        if want_density:
            return out, jnp.mean(ys["mass"], axis=0)            # (B, S)
        return out

    def _build_cache(self, batch, seq, dtype, layout):
        cfg, m = self.cfg, self.cfg.mla
        L = cfg.n_layers
        cache = {
            "ckv": jnp.zeros((L, batch, seq, m.kv_lora_rank), dtype),
            "kpe": jnp.zeros((L, batch, seq, m.qk_rope_head_dim), dtype),
            "pos": jnp.int32(0),
        }
        if layout == LAYOUT_MIXED:
            # mixed-precision working cache: bf16 latent window + int8
            # quant-resident segments with per-(token, layer) scales
            # over the whole rank vector, selected by quant_mask (dummy
            # leading axis: axis 1 stays the batch axis on every leaf)
            cache["ckv_q"] = jnp.zeros((L, batch, seq, m.kv_lora_rank),
                                       jnp.int8)
            cache["kpe_q"] = jnp.zeros((L, batch, seq, m.qk_rope_head_dim),
                                       jnp.int8)
            cache["ckv_scale"] = jnp.zeros((L, batch, seq), jnp.float32)
            cache["kpe_scale"] = jnp.zeros((L, batch, seq), jnp.float32)
            cache["quant_mask"] = jnp.zeros((1, batch, seq), bool)
        return cache

    # -- Fig. 7 recompute over latent chunks ----------------------------- #
    def recompute(self, params, miss_tokens, miss_pos, cache, seq_len,
                  window: int = 0, n_sinks: int = 0, want_density=False):
        cfg = self.cfg
        x = C.constrain_batch(
            params["embed"][miss_tokens].astype(jnp.bfloat16))
        S = cache["ckv"].shape[2]
        k_pos_all = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
        mixed = "ckv_q" in cache
        if mixed:
            # recomputed positions land in the bf16 window; resident
            # quant latents are read THROUGH during attention
            qm = cache["quant_mask"] & ~jnp.any(
                k_pos_all[None, :] == miss_pos[:, None], axis=0)[None, None]

        def body(x, layer_in):
            ckvq_c = kpeq_c = ckvs_c = kpes_c = None
            if mixed:
                pl, ckv_c, kpe_c, ckvq_c, kpeq_c, ckvs_c, kpes_c = layer_in
            else:
                pl, ckv_c, kpe_c = layer_in
            h = C.rms_norm(x, pl["ln_attn"], cfg.norm_eps)
            q_nope, q_pe = self._queries(pl, h, miss_pos)
            q = jnp.concatenate([q_nope, q_pe], axis=-1)
            ckv_t, kpe_t = self._latents(pl, h, miss_pos)
            ckv_c = ckv_c.at[:, miss_pos].set(ckv_t.astype(ckv_c.dtype))
            kpe_c = kpe_c.at[:, miss_pos].set(kpe_t.astype(kpe_c.dtype))
            if mixed:
                ckv_att = _latent_select(ckv_c, ckvq_c, ckvs_c, qm[0])
                kpe_att = _latent_select(kpe_c, kpeq_c, kpes_c, qm[0])
            else:
                ckv_att, kpe_att = ckv_c, kpe_c
            k, v = self._expand_kv(pl, ckv_att.astype(x.dtype),
                                   kpe_att.astype(x.dtype))
            mask = C.causal_window_mask(miss_pos, k_pos_all, window, n_sinks)
            mask = mask & (k_pos_all < seq_len)[None, :]
            ao = C.gqa_attention(q, k, v, mask, want_density=want_density)
            x = x + ao.out.reshape(*x.shape[:2], -1) @ pl["wo"]
            x = C.constrain_batch(self._ffn(pl, x))
            ys = {"ckv": ckv_c, "kpe": kpe_c}
            if want_density:
                ys["density"] = ao.key_density
            return x, ys

        xs = (params["layers"], cache["ckv"], cache["kpe"])
        if mixed:
            xs = xs + tuple(cache[n] for n in _LATENT_QUANT_LEAVES)
        x, ys = jax.lax.scan(body, x, xs)
        x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
        density = jnp.mean(ys["density"], axis=0) if want_density else None
        new_cache = {"ckv": ys["ckv"], "kpe": ys["kpe"], "pos": cache["pos"]}
        if mixed:
            for n in _LATENT_QUANT_LEAVES:
                new_cache[n] = cache[n]
            new_cache["quant_mask"] = qm
        return new_cache, x, density
