"""Whisper-style encoder-decoder (audio frontend STUBBED per assignment).

The conv/mel frontend is a stub: the batch provides precomputed frame
embeddings ``frames`` of shape (B, n_frames, d_model).  The encoder adds
sinusoidal positions and runs pre-LN self-attention blocks; the decoder
uses learned positions (capped at cfg.max_seq = 448), causal self-attn,
and cross-attn over the encoder output.

LLMS applicability: decoder self-attn KV is chunk-managed; the encoder
output (and the cross K/V derived from it) is a single resident block.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeSpec
from repro.models import common as C
from repro.models.api import DecodeOut, ModelBase, PrefillOut
from repro.models.dense import blockwise_ce
from repro.models.kvspec import KVSpec

Array = jax.Array


class EncDecModel(ModelBase):

    def kv_spec(self) -> KVSpec:
        cfg = self.cfg
        kv_dims = (cfg.n_heads, cfg.head_dim)   # MHA, not GQA
        return KVSpec(
            family=cfg.family,
            # decoder self-attn K/V is token-indexed; cross K/V derives
            # from the encoder output (audio) — a constant-size block
            # that cannot be rebuilt from decoder text
            seq_leaves=("k", "v"),
            leaf_dims={"k": kv_dims, "v": kv_dims},
            state_leaves=("xk", "xv"),
            servable=False,           # prefill needs audio frames
            chunkable=True,
            recomputable=False,
            batched_decode=False,
            quant_resident=False,
            paged=False,
            pipelined_restore=False,
            tolerance_class="kv",
            min_bits=8,
            clamp_to_max_seq=True,    # learned decoder positions: 448 cap
        )

    def init(self, key) -> Dict:
        cfg = self.cfg
        enc = cfg.encoder
        d, ff, H, hd = cfg.d_model, cfg.d_ff, cfg.n_heads, cfg.head_dim
        Le, Ld = enc.n_layers, cfg.n_layers
        ks = jax.random.split(key, 24)
        lin = C.init_linear

        def attn_block(k0, L):
            kk = jax.random.split(k0, 4)
            return {
                "wq": lin(kk[0], (L, d, H * hd)),
                "wk": lin(kk[1], (L, d, H * hd)),
                "wv": lin(kk[2], (L, d, H * hd)),
                "wo": lin(kk[3], (L, H * hd, d)),
                "bq": jnp.zeros((L, H * hd), jnp.float32),
                "bv": jnp.zeros((L, H * hd), jnp.float32),
                "bo": jnp.zeros((L, d), jnp.float32),
            }

        def mlp_block(k0, L):
            kk = jax.random.split(k0, 2)
            return {
                "w1": lin(kk[0], (L, d, ff)), "b1": jnp.zeros((L, ff), jnp.float32),
                "w2": lin(kk[1], (L, ff, d)), "b2": jnp.zeros((L, d), jnp.float32),
            }

        def norms(L, n):
            return {f"ln{i}": jnp.ones((L, d), jnp.float32) for i in range(n)} | \
                   {f"ln{i}_b": jnp.zeros((L, d), jnp.float32) for i in range(n)}

        enc_layers = {"attn": attn_block(ks[0], Le), "mlp": mlp_block(ks[1], Le)}
        enc_layers.update(norms(Le, 2))
        dec_layers = {"self": attn_block(ks[2], Ld),
                      "cross": attn_block(ks[3], Ld),
                      "mlp": mlp_block(ks[4], Ld)}
        dec_layers.update(norms(Ld, 3))
        return {
            "embed": lin(ks[5], (cfg.vocab, d)),
            "pos_dec": lin(ks[6], (cfg.max_seq, d)),
            "ln_enc": jnp.ones((d,), jnp.float32),
            "ln_enc_b": jnp.zeros((d,), jnp.float32),
            "ln_dec": jnp.ones((d,), jnp.float32),
            "ln_dec_b": jnp.zeros((d,), jnp.float32),
            "enc": enc_layers,
            "dec": dec_layers,
        }

    def head_weight(self, params):
        return params["embed"].T          # whisper ties output to embedding

    # -- attention helpers -------------------------------------------------- #
    def _proj_qkv(self, pa, hq, hkv):
        cfg = self.cfg
        B, Sq, _ = hq.shape
        Sk = hkv.shape[1]
        H, hd = cfg.n_heads, cfg.head_dim
        q = (hq @ pa["wq"] + pa["bq"].astype(hq.dtype)).reshape(B, Sq, H, hd)
        k = (hkv @ pa["wk"]).reshape(B, Sk, H, hd)
        v = (hkv @ pa["wv"] + pa["bv"].astype(hkv.dtype)).reshape(B, Sk, H, hd)
        return q, k, v

    def _attn_out(self, pa, x, out):
        B, S = x.shape[:2]
        return x + (out.reshape(B, S, -1) @ pa["wo"]
                    + pa["bo"].astype(x.dtype))

    def _mlp(self, pm, lns, lnb, x):
        h = C.layer_norm(x, lns, lnb, self.cfg.norm_eps)
        h = jax.nn.gelu(h @ pm["w1"] + pm["b1"].astype(x.dtype),
                        approximate=True)
        return x + (h @ pm["w2"] + pm["b2"].astype(x.dtype))

    # -- encoder ------------------------------------------------------------ #
    def encode(self, params, frames):
        cfg = self.cfg
        x = C.constrain_batch(frames.astype(jnp.bfloat16))
        x = x + C.sinusoidal_positions(x.shape[1], cfg.d_model
                                       ).astype(x.dtype)[None]

        def body(x, pl):
            h = C.layer_norm(x, pl["ln0"], pl["ln0_b"], cfg.norm_eps)
            q, k, v = self._proj_qkv(pl["attn"], h, h)
            S = x.shape[1]
            mask = jnp.ones((S, S), bool)
            ao = C.gqa_attention(q, k, v, mask)
            x = self._attn_out(pl["attn"], x, ao.out)
            x = C.constrain_batch(
                self._mlp(pl["mlp"], pl["ln1"], pl["ln1_b"], x))
            return x, None

        x, _ = jax.lax.scan(body, x, params["enc"])
        return C.layer_norm(x, params["ln_enc"], params["ln_enc_b"],
                            cfg.norm_eps)

    # -- decoder (full sequence) --------------------------------------------- #
    def _decode_full(self, params, tokens, enc_out, want_density=False,
                     return_kv=False, remat=False):
        cfg = self.cfg
        B, S = tokens.shape
        x = C.constrain_batch(params["embed"][tokens].astype(jnp.bfloat16))
        x = x + params["pos_dec"][:S].astype(x.dtype)[None]
        positions = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)

        def body(x, pl):
            # causal self-attention
            h = C.layer_norm(x, pl["ln0"], pl["ln0_b"], cfg.norm_eps)
            q, k, v = self._proj_qkv(pl["self"], h, h)
            mask = C.causal_window_mask(positions, positions)
            ao = C.gqa_attention(q, k, v, mask, want_density=want_density)
            x = self._attn_out(pl["self"], x, ao.out)
            # cross-attention
            h = C.layer_norm(x, pl["ln1"], pl["ln1_b"], cfg.norm_eps)
            qx, kx, vx = self._proj_qkv(pl["cross"], h, enc_out)
            maskx = jnp.ones((S, enc_out.shape[1]), bool)
            aox = C.gqa_attention(qx, kx, vx, maskx)
            x = self._attn_out(pl["cross"], x, aox.out)
            x = C.constrain_batch(
                self._mlp(pl["mlp"], pl["ln2"], pl["ln2_b"], x))
            extras = {}
            if want_density:
                extras["density"] = ao.key_density
            if return_kv:
                extras["k"], extras["v"] = k, v
                extras["xk"], extras["xv"] = kx, vx
            return x, extras

        if remat:
            body = jax.checkpoint(body)
        x, extras = jax.lax.scan(body, x, params["dec"])
        x = C.layer_norm(x, params["ln_dec"], params["ln_dec_b"], cfg.norm_eps)
        return x, extras

    # -- entry points --------------------------------------------------------- #
    def loss(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        x, _ = self._decode_full(params, batch["tokens"], enc_out, remat=True)
        return blockwise_ce(x, self.head_weight(params), batch["targets"],
                            batch.get("mask"))

    def prefill(self, params, batch, want_density=False, window=0, n_sinks=0):
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        x, extras = self._decode_full(params, tokens, enc_out,
                                      want_density=want_density,
                                      return_kv=True)
        logits = (x[:, -1] @ self.head_weight(params)).astype(jnp.float32)
        cache = {"k": extras["k"], "v": extras["v"],
                 "xk": extras["xk"], "xv": extras["xv"],
                 "pos": jnp.int32(tokens.shape[1])}
        density = None
        if want_density:
            density = jnp.mean(extras["density"], axis=0)
        return PrefillOut(logits, cache, density)

    def decode_step(self, params, tokens, cache, window=0, n_sinks=0,
                    want_density=False):
        cfg = self.cfg
        B = tokens.shape[0]
        pos = cache["pos"]
        x = params["embed"][tokens].astype(jnp.bfloat16)
        x = x + jnp.take(params["pos_dec"], pos[None], axis=0
                         ).astype(x.dtype)[None]

        def body(x, inp):
            pl, k_c, v_c, xk, xv = inp
            h = C.layer_norm(x, pl["ln0"], pl["ln0_b"], cfg.norm_eps)
            q, k, v = self._proj_qkv(pl["self"], h, h)
            k_c = C.ring_update(k_c, k, pos)
            v_c = C.ring_update(v_c, v, pos)
            out = C.decode_attention(q, k_c, v_c, pos + 1)
            x = self._attn_out(pl["self"], x, out)
            h = C.layer_norm(x, pl["ln1"], pl["ln1_b"], cfg.norm_eps)
            H, hd = cfg.n_heads, cfg.head_dim
            qx = (h @ pl["cross"]["wq"] + pl["cross"]["bq"].astype(h.dtype)
                  ).reshape(B, 1, H, hd)
            outx = C.decode_attention(qx, xk, xv, xk.shape[1])
            x = self._attn_out(pl["cross"], x, outx)
            x = C.constrain_batch(
                self._mlp(pl["mlp"], pl["ln2"], pl["ln2_b"], x))
            return x, (k_c, v_c)

        x, (k_new, v_new) = jax.lax.scan(
            body, x, (params["dec"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        x = C.layer_norm(x, params["ln_dec"], params["ln_dec_b"], cfg.norm_eps)
        logits = (x[:, 0] @ self.head_weight(params)).astype(jnp.float32)
        out = DecodeOut(logits, {"k": k_new, "v": v_new, "xk": cache["xk"],
                                 "xv": cache["xv"], "pos": pos + 1})
        if want_density:
            return out, jnp.zeros((tokens.shape[0], 1), jnp.float32)
        return out

    def _build_cache(self, batch, seq, dtype, layout):
        cfg = self.cfg
        # base init_cache already clamped seq via spec.clamp_to_max_seq
        L, H, hd = cfg.n_layers, cfg.n_heads, cfg.head_dim
        F = cfg.encoder.n_frames
        return {
            "k": jnp.zeros((L, batch, seq, H, hd), dtype),
            "v": jnp.zeros((L, batch, seq, H, hd), dtype),
            "xk": jnp.zeros((L, batch, F, H, hd), dtype),
            "xv": jnp.zeros((L, batch, F, H, hd), dtype),
            "pos": jnp.int32(0),
        }

    # -- dry-run specs: audio frames + clamped decoder length ---------------- #
    def batch_specs(self, shape: ShapeSpec):
        cfg = self.cfg
        B = shape.global_batch
        T = min(shape.seq_len, cfg.max_seq)
        return {
            "frames": jax.ShapeDtypeStruct(
                (B, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((B, T), jnp.int32),
            "targets": jax.ShapeDtypeStruct((B, T), jnp.int32),
        }
