"""Shared model primitives: norms, rotary embeddings, attention.

Everything here is written for GSPMD-friendliness:
  * masks and rotary tables are built ON THE FLY from ``broadcasted_iota``
    (never as materialized constants -- a 32k x 32k boolean mask constant
    would explode compile memory);
  * GQA never materializes repeated K/V heads (grouped einsums);
  * long-sequence prefill uses a blocked online-softmax (flash-style) scan
    so the per-layer temp is one (B, H, Sq, block) tile, not (B, H, Sq, Sk).

``key_density`` is the paper's Eq. (1) information-density statistic: the
mean attention mass each key token receives from the queries that can see
it, averaged over heads (the caller accumulates layers and chunks).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
NEG_INF = -0.7 * float(np.finfo(np.float32).max)


# --------------------------------------------------------------------- #
# Activation sharding constraints.  GSPMD propagation can drop the batch
# sharding across a layer scan (the embed table is (model, data)-sharded,
# so the scan carry's initial sharding is ambiguous and everything
# downstream silently replicates -- x16 activation memory on the 16x16
# mesh).  Launchers opt in via set_batch_axes(("data",)) /
# (("pod","data")); the default (None) is a no-op so single-device tests
# and the CPU service never see a mesh requirement.
# --------------------------------------------------------------------- #
_BATCH_AXES = None


def set_batch_axes(axes):
    global _BATCH_AXES
    _BATCH_AXES = tuple(axes) if axes else None


def constrain_batch(x: Array) -> Array:
    """Pin dim 0 of an activation to the data axes (no-op by default)."""
    if _BATCH_AXES is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(_BATCH_AXES, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------- #
def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def group_norm_heads(x: Array, scale: Array, bias: Array, n_heads: int,
                     eps: float = 1e-5) -> Array:
    """GroupNorm with one group per head over the last dim (RWKV ln_x)."""
    dt = x.dtype
    *lead, d = x.shape
    x = x.astype(jnp.float32).reshape(*lead, n_heads, d // n_heads)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = ((x - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, d)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------- #
# Rotary position embeddings (computed on the fly from positions)
# --------------------------------------------------------------------- #
def rope_angles(positions: Array, head_dim: int, theta: float) -> Tuple[Array, Array]:
    """positions: (...,) int -> cos/sin of shape (..., head_dim//2), fp32."""
    half = head_dim // 2
    freqs = jnp.exp(
        jnp.arange(half, dtype=jnp.float32) * (-np.log(theta) / half)
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (..., n_heads, head_dim); cos/sin broadcastable to (..., 1, hd//2).

    Rotate-half convention (llama): pairs are (x[:d/2], x[d/2:]).
    """
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(dt)


def sinusoidal_positions(n_pos: int, d_model: int) -> Array:
    """Whisper-style fixed sinusoidal embeddings, built from iota."""
    pos = jax.lax.broadcasted_iota(jnp.float32, (n_pos, 1), 0)
    half = d_model // 2
    i = jax.lax.broadcasted_iota(jnp.float32, (1, half), 1)
    inv = jnp.exp(i * (-np.log(10000.0) / max(half - 1, 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------- #
# Masks (built from iota; never materialized as host constants)
# --------------------------------------------------------------------- #
def causal_window_mask(q_pos: Array, k_pos: Array, window: int = 0,
                       n_sinks: int = 0) -> Array:
    """Boolean (..., Sq, Sk) mask. True == attend.

    window > 0 enables the paper's streaming mode: each query sees the
    last `window` tokens plus the first `n_sinks` sink tokens
    (StreamingLLM, paper section 4).
    """
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    m = k <= q
    if window > 0:
        in_window = k > (q - window)
        is_sink = k < n_sinks
        m = m & (in_window | is_sink)
    return m


# --------------------------------------------------------------------- #
# Grouped-query attention (full materialization; small/medium sequences)
# --------------------------------------------------------------------- #
class AttnOut(NamedTuple):
    out: Array                       # (B, Sq, H, hd)
    key_density: Optional[Array]     # (B, Sk) fp32 or None


def gqa_attention(q: Array, k: Array, v: Array, mask: Array,
                  want_density: bool = False,
                  softcap: float = 0.0) -> AttnOut:
    """q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd); mask: bool broadcastable
    (B?,1?,Sq,Sk).  Never repeats KV heads."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqngd,bknd->bngqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    maskb = mask[None] if mask.ndim == 2 else mask           # (B|1, Sq, Sk)
    s = jnp.where(maskb[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngqk,bknd->bqngd", p.astype(v.dtype), v)
    out = out.reshape(B, Sq, H, v.shape[-1])
    density = None
    if want_density:
        # Eq. (1): per key, mean attention received over valid (row) queries
        mass = jnp.sum(p, axis=(1, 2, 3))                         # (B, Sk)
        nvalid = jnp.maximum(jnp.sum(maskb, axis=1), 1)           # (B|1, Sk)
        density = (mass / (H * nvalid)).astype(jnp.float32)
    return AttnOut(out, density)


# --------------------------------------------------------------------- #
# Blocked (flash-style) causal attention via lax.scan over key blocks.
# Temp footprint: one (B, KV, G, Sq, block) tile instead of (..., Sq, Sk).
# --------------------------------------------------------------------- #
def blocked_causal_attention(q: Array, k: Array, v: Array,
                             q_offset: int = 0,
                             block: int = 1024,
                             window: int = 0,
                             n_sinks: int = 0,
                             want_density: bool = False) -> AttnOut:
    """Causal GQA over long sequences.  q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd).
    q token i has absolute position q_offset + i; k token j has position j.
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    nblk = (Sk + block - 1) // block
    pad = nblk * block - Sk
    if pad:
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        kp, vp = k, v
    kb = kp.reshape(B, nblk, block, KV, k.shape[-1]).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nblk, block, KV, v.shape[-1]).transpose(1, 0, 2, 3, 4)

    qg = q.reshape(B, Sq, KV, G, hd)
    scale = 1.0 / np.sqrt(hd)
    q_pos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (Sq,), 0)

    vd = v.shape[-1]
    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Sq, KV, G, vd), jnp.float32)

    def step(carry, blk):
        m, l, acc, idx = carry[0], carry[1], carry[2], carry[3]
        kblk, vblk = blk
        k_pos = idx * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
        s = jnp.einsum("bqngd,bknd->bngqk", qg, kblk,
                       preferred_element_type=jnp.float32) * scale
        valid = causal_window_mask(q_pos, k_pos, window, n_sinks)
        valid = valid & (k_pos < Sk)[None, :]
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bngqk,bknd->bqngd", p.astype(vblk.dtype), vblk)
        acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new, idx + 1), None

    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, acc0, jnp.int32(0)),
                                     (kb, vb))
    l_t = l.transpose(0, 3, 1, 2)[..., None]
    out = (acc / jnp.maximum(l_t, 1e-30)).astype(q.dtype).reshape(B, Sq, H, vd)

    density = None
    if want_density:
        # second pass: accumulate normalized attention mass per key
        def dstep(idx, _):
            kblk = kb[idx]
            k_pos = idx * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
            s = jnp.einsum("bqngd,bknd->bngqk", qg, kblk,
                           preferred_element_type=jnp.float32) * scale
            valid = causal_window_mask(q_pos, k_pos, window, n_sinks)
            valid = valid & (k_pos < Sk)[None, :]
            s = jnp.where(valid[None, None, None], s, NEG_INF)
            p = jnp.exp(s - m[..., None]) / jnp.maximum(l[..., None], 1e-30)
            mass = jnp.sum(p, axis=(1, 2, 3))                      # (B, blk)
            nvalid = jnp.maximum(jnp.sum(valid, axis=0), 1)        # (blk,)
            return (mass / (H * nvalid[None, :])).astype(jnp.float32)

        idxs = jnp.arange(nblk)
        masses = jax.lax.map(lambda i: dstep(i, None), idxs)        # (nblk,B,blk)
        density = masses.transpose(1, 0, 2).reshape(B, nblk * block)[:, :Sk]
    return AttnOut(out, density)


# --------------------------------------------------------------------- #
# Flash attention with a custom VJP (training path).
#
# Differentiating through the blocked-attention scan makes XLA save the
# per-step softmax carries for backward — ~4 GiB * n_blocks per layer at
# 4k context, the dominant train-memory term (EXPERIMENTS.md §Perf).
# The custom backward recomputes scores block-by-block from the saved
# (q, k, v, out, m, l): standard flash backward, O(block) temporaries.
# --------------------------------------------------------------------- #
def _flash_blocks(k, v, block):
    B, Sk, KV = k.shape[:3]
    nblk = (Sk + block - 1) // block
    pad = nblk * block - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(B, nblk, block, KV, k.shape[-1]).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, block, KV, v.shape[-1]).transpose(1, 0, 2, 3, 4)
    return kb, vb, nblk


def _flash_fwd_impl(q, k, v, q_offset, block, window, n_sinks):
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    kb, vb, nblk = _flash_blocks(k, v, block)
    qg = q.reshape(B, Sq, KV, G, hd)
    scale = 1.0 / np.sqrt(hd)
    q_pos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (Sq,), 0)

    m0 = jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Sq, KV, G, v.shape[-1]), jnp.float32)

    def step(carry, blk):
        m, l, acc, idx = carry
        kblk, vblk = blk
        k_pos = idx * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
        s = jnp.einsum("bqngd,bknd->bngqk", qg, kblk,
                       preferred_element_type=jnp.float32) * scale
        valid = causal_window_mask(q_pos, k_pos, window, n_sinks)
        valid = valid & (k_pos < Sk)[None, :]
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bngqk,bknd->bqngd", p.astype(vblk.dtype), vblk)
        acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new, idx + 1), None

    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, acc0, jnp.int32(0)),
                                     (kb, vb))
    l_t = l.transpose(0, 3, 1, 2)[..., None]
    out = (acc / jnp.maximum(l_t, 1e-30)).astype(q.dtype)  # (B,Sq,KV,G,vd)
    return out.reshape(B, Sq, H, v.shape[-1]), m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, q_offset=0, block=1024, window=0, n_sinks=0):
    out, _, _ = _flash_fwd_impl(q, k, v, q_offset, block, window, n_sinks)
    return out


def _flash_vjp_fwd(q, k, v, q_offset, block, window, n_sinks):
    out, m, l = _flash_fwd_impl(q, k, v, q_offset, block, window, n_sinks)
    return out, (q, k, v, out, m, l)


def _flash_vjp_bwd(q_offset, block, window, n_sinks, res, dout):
    q, k, v, out, m, l = res
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    vd = v.shape[-1]
    kb, vb, nblk = _flash_blocks(k, v, block)
    qg = q.reshape(B, Sq, KV, G, hd).astype(jnp.float32)
    do = dout.reshape(B, Sq, KV, G, vd).astype(jnp.float32)
    og = out.reshape(B, Sq, KV, G, vd).astype(jnp.float32)
    scale = 1.0 / np.sqrt(hd)
    q_pos = q_offset + jax.lax.broadcasted_iota(jnp.int32, (Sq,), 0)
    # delta = rowsum(dout * out): (B,KV,G,Sq)
    delta = jnp.sum(do * og, axis=-1).transpose(0, 2, 3, 1)
    lsafe = jnp.maximum(l, 1e-30)

    def step(dq, blk):
        kblk, vblk, idx = blk
        k_pos = idx * block + jax.lax.broadcasted_iota(jnp.int32, (block,), 0)
        s = jnp.einsum("bqngd,bknd->bngqk", qg, kblk.astype(jnp.float32),
                       preferred_element_type=jnp.float32) * scale
        valid = causal_window_mask(q_pos, k_pos, window, n_sinks)
        valid = valid & (k_pos < Sk)[None, :]
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        p = jnp.exp(s - m[..., None]) / lsafe[..., None]      # (B,n,g,q,k)
        dv = jnp.einsum("bngqk,bqngd->bknd", p, do)
        dp = jnp.einsum("bqngd,bknd->bngqk", do,
                        vblk.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bngqk,bknd->bqngd", ds,
                             kblk.astype(jnp.float32))
        dk = jnp.einsum("bngqk,bqngd->bknd", ds, qg)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, Sq, KV, G, hd), jnp.float32)
    idxs = jnp.arange(nblk, dtype=jnp.int32)
    dq, (dkb, dvb) = jax.lax.scan(step, dq0, (kb, vb, idxs))
    dk = dkb.transpose(1, 0, 2, 3, 4).reshape(B, nblk * block, KV, hd)[:, :Sk]
    dv = dvb.transpose(1, 0, 2, 3, 4).reshape(B, nblk * block, KV, vd)[:, :Sk]
    return (dq.reshape(B, Sq, H, hd).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# --------------------------------------------------------------------- #
# Decode attention against a (possibly quantized) KV cache
# --------------------------------------------------------------------- #
def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     cur_pos: Array,
                     k_scale: Optional[Array] = None,
                     v_scale: Optional[Array] = None,
                     window: int = 0, n_sinks: int = 0,
                     want_density: bool = False):
    """One-step attention.  q: (B,1,H,hd); caches: (B,S,KV,hd) in bf16 or
    int8 (with per (B,S,KV) scales).  cur_pos: () or (B,) -- number of
    valid cache entries; the new token attends to cache[:cur_pos].
    """
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    if k_scale is not None:
        k = (k_cache.astype(jnp.float32) * k_scale[..., None]).astype(q.dtype)
        v = (v_cache.astype(jnp.float32) * v_scale[..., None]).astype(q.dtype)
    else:
        k, v = k_cache, v_cache
    qg = q.reshape(B, 1, KV, G, hd)
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bqngd,bknd->bngqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    k_pos = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
    pos = jnp.asarray(cur_pos)
    pos_b = pos if pos.ndim else pos[None].repeat(B, 0)
    valid = k_pos[None, :] < pos_b[:, None]                    # (B, S)
    if window > 0:
        in_win = k_pos[None, :] >= (pos_b[:, None] - window)
        sink = k_pos[None, :] < n_sinks
        valid = valid & (in_win | sink)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bngqk,bknd->bqngd", p.astype(v.dtype), v)
    out = out.reshape(B, 1, H, v.shape[-1])
    if want_density:
        mass = (jnp.sum(p, axis=(1, 2, 3)) / H).astype(jnp.float32)  # (B, S)
        return out, mass
    return out


# --------------------------------------------------------------------- #
# Mixed-precision decode attention: bf16 recent window + int8
# quant-resident chunk segments (fused dequant, selected per position).
#
# The dequantized value of a quant position is computed THROUGH the
# cache dtype — ``(code * scale) -> bf16`` — i.e. exactly the value a
# full dequantization would have materialized into the bf16 cache, so
# quant-resident decode is bit-identical to the full-dequant path
# (tests/test_quant_resident.py asserts token identity).
# --------------------------------------------------------------------- #

# above this many cache positions the CPU path switches from the
# plain select (bitwise-identical to ``decode_attention``) to the
# blocked online-softmax scan, which dequantizes one key block at a
# time and never materializes the full dequantized cache
MIXED_BLOCKED_MIN_S = 4096


def dequant_select(x_cache: Array, x_q: Array, x_scale: Array,
                   quant_mask: Array) -> Array:
    """Per-position select between the bf16 cache and the fused-dequant
    int8 segments.  x_cache (B,S,KV,hd); x_q int8; x_scale (B,S,KV);
    quant_mask (B,S) bool."""
    dq = (x_q.astype(jnp.float32) * x_scale[..., None]).astype(x_cache.dtype)
    return jnp.where(quant_mask[:, :, None, None], dq, x_cache)


def mixed_decode_attention_blocked(q: Array, k_cache: Array, v_cache: Array,
                                   k_q: Array, v_q: Array, k_scale: Array,
                                   v_scale: Array, quant_mask: Array,
                                   cur_pos: Array, window: int = 0,
                                   n_sinks: int = 0,
                                   want_density: bool = False,
                                   block: int = 1024):
    """Blocked-jnp fused-dequant reference: online softmax over key
    blocks, dequantizing one (B, block, KV, hd) tile at a time — the
    memory-bounded long-context form of ``mixed_decode_attention`` and
    the CPU mirror of the Pallas kernel (kernels/decode_qattn.py::
    decode_mqattn; oracle kernels/ref.py::decode_mqattn_ref)."""
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    nblk = (S + block - 1) // block
    pad = nblk * block - S
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_cache = jnp.pad(k_cache, padw)
        v_cache = jnp.pad(v_cache, padw)
        k_q = jnp.pad(k_q, padw)
        v_q = jnp.pad(v_q, padw)
        k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
        quant_mask = jnp.pad(quant_mask, ((0, 0), (0, pad)))

    def blks(a):
        r = a.reshape((B, nblk, block) + a.shape[2:])
        return r.transpose((1, 0, 2) + tuple(range(3, r.ndim)))

    kb, vb = blks(k_cache), blks(v_cache)
    kqb, vqb = blks(k_q), blks(v_q)
    ksb, vsb = blks(k_scale), blks(v_scale)
    qmb = quant_mask.reshape(B, nblk, block).transpose(1, 0, 2)

    qg = q.reshape(B, 1, KV, G, hd)
    scale = 1.0 / np.sqrt(hd)
    pos = jnp.asarray(cur_pos)
    pos_b = pos if pos.ndim else pos[None].repeat(B, 0)    # (B,)

    m0 = jnp.full((B, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G), jnp.float32)
    acc0 = jnp.zeros((B, KV, G, hd), jnp.float32)

    def step(carry, blk):
        m, l, acc, idx = carry
        kc, vc, kq, vq, ks, vs, qm = blk
        kf = dequant_select(kc, kq, ks, qm).astype(jnp.float32)
        vf = dequant_select(vc, vq, vs, qm).astype(jnp.float32)
        s = jnp.einsum("bqngd,bknd->bngqk", qg, kf,
                       preferred_element_type=jnp.float32)[:, :, :, 0] * scale
        k_pos = idx * block + jax.lax.broadcasted_iota(
            jnp.int32, (block,), 0)
        valid = (k_pos[None, :] < pos_b[:, None]) & (k_pos < S)[None, :]
        if window > 0:
            in_win = k_pos[None, :] >= (pos_b[:, None] - window)
            sink = k_pos[None, :] < n_sinks
            valid = valid & (in_win | sink)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bngk,bknd->bngd", p, vf)
        return (m_new, l_new, acc_new, idx + 1), None

    (m, l, acc, _), _ = jax.lax.scan(
        step, (m0, l0, acc0, jnp.int32(0)), (kb, vb, kqb, vqb, ksb, vsb, qmb))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    out = out.reshape(B, 1, H, hd)
    if want_density:
        # second pass over blocks: normalized attention mass per key
        def dstep(idx):
            kf = dequant_select(kb[idx], kqb[idx], ksb[idx],
                                qmb[idx]).astype(jnp.float32)
            s = jnp.einsum("bqngd,bknd->bngqk", qg, kf,
                           preferred_element_type=jnp.float32
                           )[:, :, :, 0] * scale
            k_pos = idx * block + jax.lax.broadcasted_iota(
                jnp.int32, (block,), 0)
            valid = (k_pos[None, :] < pos_b[:, None]) & (k_pos < S)[None, :]
            if window > 0:
                in_win = k_pos[None, :] >= (pos_b[:, None] - window)
                sink = k_pos[None, :] < n_sinks
                valid = valid & (in_win | sink)
            s = jnp.where(valid[:, None, None, :], s, NEG_INF)
            p = jnp.exp(s - m[..., None]) / jnp.maximum(l, 1e-30)[..., None]
            return (jnp.sum(p, axis=(1, 2)) / H).astype(jnp.float32)

        masses = jax.lax.map(dstep, jnp.arange(nblk))       # (nblk, B, blk)
        mass = masses.transpose(1, 0, 2).reshape(B, nblk * block)[:, :S]
        return out, mass
    return out


def mixed_decode_attention(q: Array, k_cache: Array, v_cache: Array,
                           k_q: Array, v_q: Array, k_scale: Array,
                           v_scale: Array, quant_mask: Array, cur_pos: Array,
                           window: int = 0, n_sinks: int = 0,
                           want_density: bool = False):
    """One-step attention over a mixed cache.  q: (B,1,H,hd); k/v bf16 and
    k_q/v_q int8 caches (B,S,KV,hd); scales (B,S,KV); quant_mask (B,S).

    Dispatch: Pallas fused kernel on TPU (density falls back to the
    blocked path), blocked online-softmax scan for long caches, plain
    select + ``decode_attention`` numerics otherwise (bit-identical to
    the full-dequant bf16 path)."""
    S = k_cache.shape[1]
    if jax.default_backend() == "tpu" and not want_density:
        from repro.kernels import ops as kops
        pos = jnp.asarray(cur_pos)
        out = kops.decode_mqattn(q[:, 0], k_cache, v_cache, k_q, v_q,
                                 k_scale, v_scale, quant_mask, pos,
                                 window, n_sinks)
        return out[:, None]
    if S >= MIXED_BLOCKED_MIN_S:
        return mixed_decode_attention_blocked(
            q, k_cache, v_cache, k_q, v_q, k_scale, v_scale, quant_mask,
            cur_pos, window, n_sinks, want_density)
    k = dequant_select(k_cache, k_q, k_scale, quant_mask)
    v = dequant_select(v_cache, v_q, v_scale, quant_mask)
    return decode_attention(q, k, v, cur_pos, window=window,
                            n_sinks=n_sinks, want_density=want_density)


# --------------------------------------------------------------------- #
# Paged KV pool: dense cache view over page arenas
# --------------------------------------------------------------------- #
def paged_cache_view(arenas, leaves, pt16, pt8=None, quant_chunks=None,
                     pos=None):
    """Materialize the dense slot-cache view of a paged KV pool.

    ``arenas`` holds per-leaf page arenas: ``<leaf>16`` (L, P16, cs,
    ...) bf16 and — in quant-resident mode — ``<leaf>8`` int8 codes
    plus ``<leaf>8s`` per-(token, kv-head) fp32 scales (L, P8, cs,
    ...).  ``pt16``/``pt8`` are (B, C) page-table rows (one chunk per
    entry, page 0 = scratch); ``quant_chunks`` (B, C) bool marks which
    chunks live in the int8 arena.  The gather produces exactly the
    (L, B, S, ...) mixed-cache layout ``decode_step``/``recompute``
    consume, so every downstream attention op — and therefore every
    emitted token — is bit-identical to the slot-cache path.
    """
    from repro.kernels.paged import gather_pages
    cache = {"pos": pos}
    for n in leaves:
        cache[n] = gather_pages(arenas[n + "16"], pt16)
    if pt8 is not None:
        for n in leaves:
            cache[n + "_q"] = gather_pages(arenas[n + "8"], pt8)
            cache[n + "_scale"] = gather_pages(arenas[n + "8s"], pt8)
        B, C = quant_chunks.shape
        cs = arenas[leaves[0] + "16"].shape[2]
        qm = jnp.broadcast_to(quant_chunks[:, :, None], (B, C, cs))
        # dummy leading axis: axis 1 stays the batch axis for every leaf
        cache["quant_mask"] = qm.reshape(B, C * cs)[None]
    return cache


# --------------------------------------------------------------------- #
# FFN
# --------------------------------------------------------------------- #
def swiglu(x: Array, w_gate: Array, w_up: Array, w_down: Array) -> Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x: Array, w_up: Array, b_up: Array, w_down: Array,
             b_down: Array) -> Array:
    h = jax.nn.gelu(x @ w_up + b_up, approximate=True)
    return h @ w_down + b_down


# --------------------------------------------------------------------- #
# Cache update helper
# --------------------------------------------------------------------- #
def ring_update(cache: Array, new: Array, pos: Array, ring: bool = False) -> Array:
    """Write `new` (B,1,...) into cache (B,S,...) at seq index pos.

    pos is a scalar int array (every batch row decodes at the same
    position: the serial working cache) or a (B,) vector of per-row
    positions (multi-context batched decode: row b is an independent
    slot writing at its own offset).  With ring=True the index wraps
    (sliding-window cache)."""
    S = cache.shape[1]
    pos = jnp.asarray(pos)
    if pos.ndim:                       # per-slot positions: row-wise write
        idx = pos % S if ring else pos
        # masked select, not .at[] scatter: elementwise select vectorizes
        # ~5x better than gather/scatter machinery on the CPU backend
        s_pos = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
        mask = s_pos[None, :] == idx[:, None]              # (B, S)
        mask = mask.reshape(mask.shape + (1,) * (cache.ndim - 2))
        return jnp.where(mask, new.astype(cache.dtype), cache)
    idx = pos % S if ring else pos
    start = [jnp.asarray(0, jnp.int32)] * cache.ndim
    start[1] = jnp.asarray(idx, jnp.int32)
    return jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype),
                                        tuple(start))


def init_linear(key, shape, scale: float = 0.02, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
