"""KVSpec — the declarative per-family cache adapter (DESIGN.md §2).

Every model family publishes one :class:`KVSpec` via
``ModelBase.kv_spec()``.  The serving layers (``core/executor.py``,
``core/residency.py``, ``core/pagepool.py``) consume ONLY this spec:
no ``supports_*`` class booleans, no ``family == "dense"`` string
dispatch, no per-family ``init_cache`` kwarg forks.  A family joins the
service by describing its cache, not by being special-cased:

* ``seq_leaves`` + ``leaf_dims`` describe the token-indexed cache
  arrays the chunk codec slices along ``TOKEN_AXIS`` (dense ``k/v``,
  MLA latent ``ckv/kpe``, ...).
* ``state_leaves`` describe constant-size recurrent state (RWKV6
  ``wkv/tm/cm``, rglru ``conv/lru``, enc-dec cross blocks): whole-state
  snapshot/restore, charged to the same byte budget as chunks.
* capability bits (``chunkable``, ``recomputable``, ``batched_decode``,
  ``quant_resident``, ``paged``, ``pipelined_restore``) replace the old
  executor/residency family gates one-for-one.
* ``tolerance_class`` + ``min_bits`` feed the Eq.-3 switch-out planner:
  the planner never compresses a chunk below the family's floor (MLA
  latents and VLM image chunks carry no cross-head redundancy, so they
  stop at 8-bit where dense K/V may drop to 4/2).

The spec is immutable and cheap to build (no params needed), so
``registry.family_spec(cfg)`` is the capability-query surface for
tools, tests, and the router.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Tuple

# canonical cache layout names accepted by ``ModelBase.init_cache``
LAYOUT_WINDOW = "window"        # plain bf16 (or int8+scale) ring cache
LAYOUT_MIXED = "mixed"          # bf16 window + int8 quant-resident leaves


@dataclass(frozen=True)
class KVSpec:
    """Declarative cache/capability descriptor for one model family."""

    family: str
    # token-indexed cache leaves, sliced by ChunkCodec along TOKEN_AXIS
    seq_leaves: Tuple[str, ...] = ()
    # per-leaf trailing dims after (layers, batch, seq), e.g.
    # {"k": (n_kv_heads, head_dim)} or {"ckv": (kv_lora_rank,)}
    leaf_dims: Dict[str, Tuple[int, ...]] = field(default_factory=dict)
    # constant-size (token-count-independent) state leaves, handled by
    # whole-state snapshot/restore (WholeStateCodec)
    state_leaves: Tuple[str, ...] = ()
    # the executor can serve this family (it has a recompute/extend
    # entry usable as the prefill-append path)
    servable: bool = False
    # cache can be sliced into chunk payloads (LCTRU-managed tier)
    chunkable: bool = False
    # residency may REBUILD missing/corrupt chunks from resident text
    # (restore planning Eq. 4, fault recovery) — distinct from servable
    recomputable: bool = False
    # [B,1] batched decode entry exists and is token-identical to serial
    batched_decode: bool = False
    # 8-bit chunks may stay int8 in the working cache (mixed layout)
    quant_resident: bool = False
    # may decode over the unified paged KV pool
    paged: bool = False
    # restore may overlap chunk IO with recompute (Eq. 4 pipeline)
    pipelined_restore: bool = False
    # bucket-padding the prefill with dummy tokens is harmless (pure
    # KV families).  False for recurrent state: a pad token would be
    # folded into the carried state, so extends run at exact length.
    pad_safe: bool = True
    # cache layouts init_cache accepts; requesting anything else is a
    # clean ValueError
    layouts: Tuple[str, ...] = (LAYOUT_WINDOW,)
    # Eq.-3 planner class: "kv" (redundant dense K/V), "latent"
    # (MLA compressed latents), "image" (VLM cross-attention image
    # tokens), "state" (recurrent state — never chunk-quantized)
    tolerance_class: str = "kv"
    # compression floor (bits) the tolerance planner must respect
    min_bits: int = 2
    # init_cache clamps seq to cfg.max_seq (learned-position decoders)
    clamp_to_max_seq: bool = False
    # decode/prefill emit the Eq.-1 attention-density statistic
    density: bool = True
    # an int8(+scale) serving-cache variant exists for dry-run A/Bs
    int8_serving: bool = False
    # the §4 streaming long-context window applies to this family
    streaming_long: bool = False

    def __post_init__(self):
        if self.chunkable and not self.seq_leaves:
            raise ValueError(
                f"KVSpec({self.family}): chunkable requires seq_leaves")
        if self.servable and not (self.seq_leaves or self.state_leaves):
            raise ValueError(
                f"KVSpec({self.family}): servable requires cache leaves")
        if self.quant_resident and LAYOUT_MIXED not in self.layouts:
            raise ValueError(
                f"KVSpec({self.family}): quant_resident requires the "
                f"'{LAYOUT_MIXED}' layout")
        if self.paged and not (self.chunkable and self.batched_decode):
            raise ValueError(
                f"KVSpec({self.family}): paged requires chunkable + "
                "batched_decode")
        if self.pipelined_restore and not (self.chunkable
                                           and self.recomputable):
            raise ValueError(
                f"KVSpec({self.family}): pipelined_restore requires "
                "chunkable + recomputable")
        missing = [n for n in self.seq_leaves if n not in self.leaf_dims]
        if missing:
            raise ValueError(
                f"KVSpec({self.family}): leaf_dims missing {missing}")
