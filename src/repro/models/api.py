"""Uniform model protocol consumed by the trainer, the server, the LLMS
context manager, and the dry-run driver.

Every family implements:

  init(key) -> params                        (pytree of stacked-layer arrays)
  loss(params, batch) -> (scalar, metrics)   (next-token CE; remat inside)
  prefill(params, batch, want_density) -> PrefillOut
  decode_step(params, tokens, cache, ..., want_density) -> DecodeOut
  kv_spec() -> KVSpec                        (declarative cache adapter)
  _build_cache(batch, seq, dtype, layout) -> cache  (pytree incl. 'pos')
  input_specs(shape) -> (entry_name, kwargs of ShapeDtypeStruct)

**The cache adapter protocol.**  ``kv_spec()`` returns the family's
:class:`~repro.models.kvspec.KVSpec`: cache leaf names/dims, chunkability,
recompute/batched/paged/quant capabilities, tolerance class and
compression floor for the Eq.-3 planner, and constant-size recurrent
state.  The serving layers consume ONLY the spec — there is no family
string dispatch and no per-family ``init_cache`` fork.  ``init_cache``
is concrete here: it validates the requested ``layout`` against
``spec.layouts`` (clean ``ValueError`` for undeclared capabilities),
applies ``spec.clamp_to_max_seq``, and delegates the allocation to the
family's ``_build_cache``.

The legacy ``supports_batched_decode`` / ``supports_quant_resident`` /
``supports_paged_pool`` class booleans are deprecation shims for one
release: reading them emits ``DeprecationWarning`` and answers from the
spec; an external family that still defines them as plain class
attributes gets a spec synthesized from those booleans by the default
``kv_spec()``.

Layer parameters are STACKED on a leading axis and consumed by
``jax.lax.scan`` so the lowered HLO stays one-layer-sized regardless of
depth (95-layer deepseek compiles as fast as 6-layer whisper).

Caches are plain pytrees with an integer ``pos`` leaf; ``decode_step``
returns the cache with ``pos + 1``. This makes the cache a first-class
jit argument: the dry-run lowers ``decode_step`` against a
ShapeDtypeStruct cache without allocating it.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models.kvspec import KVSpec, LAYOUT_MIXED, LAYOUT_WINDOW

Array = jax.Array
PyTree = Any


class PrefillOut(NamedTuple):
    logits: Array                  # (B, vocab) -- last position only
    cache: PyTree
    density: Optional[PyTree]      # per-token Eq.-1 density, family-specific


class DecodeOut(NamedTuple):
    logits: Array                  # (B, vocab)
    cache: PyTree


def cross_entropy(logits: Array, targets: Array, mask: Optional[Array] = None
                  ) -> Tuple[Array, Dict[str, Array]]:
    """Token-mean CE in fp32. logits (B,S,V), targets (B,S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == targets) * mask) / denom
    return loss, {"loss": loss, "acc": acc}


class _LegacyCapabilityFlag:
    """Deprecation shim: ``model.supports_*`` reads answer from the
    KVSpec and warn.  Subclasses that still assign a plain bool shadow
    the descriptor — the default ``kv_spec()`` picks those up."""

    def __init__(self, name: str, getter):
        self.name = name
        self.getter = getter

    def __get__(self, obj, objtype=None):
        warnings.warn(
            f"{self.name} is deprecated; query model.kv_spec() (or "
            "registry.family_spec(cfg)) instead", DeprecationWarning,
            stacklevel=2)
        if obj is None:
            return False
        return self.getter(obj.kv_spec())


def _legacy_flag(cls: type, name: str) -> bool:
    """A plain-bool ``supports_*`` override on a subclass (pre-KVSpec
    external family), skipping ModelBase's descriptors."""
    for klass in cls.__mro__:
        if klass is ModelBase:
            break
        val = klass.__dict__.get(name)
        if isinstance(val, bool):
            return val
    return False


class ModelBase:
    """Common plumbing; families override the layer stack."""

    # deprecation shims (one release): reads warn and proxy to kv_spec()
    supports_batched_decode = _LegacyCapabilityFlag(
        "supports_batched_decode", lambda s: s.batched_decode)
    supports_quant_resident = _LegacyCapabilityFlag(
        "supports_quant_resident", lambda s: s.quant_resident)
    supports_paged_pool = _LegacyCapabilityFlag(
        "supports_paged_pool", lambda s: s.paged)

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- cache adapter ------------------------------------------------- #
    def kv_spec(self) -> KVSpec:
        """The family's declarative cache descriptor.  The default
        synthesizes a dense-shaped spec from legacy ``supports_*`` class
        booleans so external pre-KVSpec families keep working; every
        in-tree family overrides this."""
        cfg = self.cfg
        return KVSpec(
            family=cfg.family,
            seq_leaves=("k", "v"),
            leaf_dims={"k": (cfg.n_kv_heads, cfg.head_dim),
                       "v": (cfg.n_kv_heads, cfg.head_dim)},
            servable=hasattr(self, "recompute"),
            chunkable=True,
            recomputable=hasattr(self, "recompute"),
            batched_decode=_legacy_flag(type(self),
                                        "supports_batched_decode"),
            quant_resident=_legacy_flag(type(self),
                                        "supports_quant_resident"),
            paged=_legacy_flag(type(self), "supports_paged_pool"),
            layouts=((LAYOUT_WINDOW, LAYOUT_MIXED)
                     if _legacy_flag(type(self), "supports_quant_resident")
                     else (LAYOUT_WINDOW,)),
        )

    def init_cache(self, batch: int, seq: int, dtype=jnp.bfloat16,
                   layout: str = LAYOUT_WINDOW,
                   mixed_quant: Optional[bool] = None) -> PyTree:
        """Allocate a decode cache.  ``layout`` must be declared in
        ``kv_spec().layouts``; the legacy ``mixed_quant=`` kwarg maps to
        ``layout="mixed"`` with a DeprecationWarning."""
        if mixed_quant is not None:
            warnings.warn(
                "init_cache(mixed_quant=...) is deprecated; pass "
                "layout='mixed' / layout='window'", DeprecationWarning,
                stacklevel=2)
            layout = LAYOUT_MIXED if mixed_quant else LAYOUT_WINDOW
        spec = self.kv_spec()
        if layout not in spec.layouts:
            raise ValueError(
                f"family {spec.family!r} does not support cache layout "
                f"{layout!r} (declared layouts: {spec.layouts})")
        if spec.clamp_to_max_seq:
            seq = min(seq, self.cfg.max_seq)
        return self._build_cache(batch, seq, dtype, layout)

    def _build_cache(self, batch: int, seq: int, dtype, layout: str
                     ) -> PyTree:
        raise NotImplementedError

    # -- entry points ------------------------------------------------- #
    def init(self, key) -> PyTree:
        raise NotImplementedError

    def loss(self, params, batch) -> Tuple[Array, Dict[str, Array]]:
        raise NotImplementedError

    def prefill(self, params, batch, want_density: bool = False) -> PrefillOut:
        raise NotImplementedError

    def decode_step(self, params, tokens, cache) -> DecodeOut:
        raise NotImplementedError

    # -- dry-run specs ------------------------------------------------- #
    def batch_specs(self, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for the data batch of this shape."""
        B, S = shape.global_batch, self.clamp_seq(shape.seq_len)
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return {"tokens": tok, "targets": tok}

    def clamp_seq(self, seq: int) -> int:
        if self.kv_spec().clamp_to_max_seq:
            return min(seq, self.cfg.max_seq)
        return seq

    def decode_seq(self, shape: ShapeSpec) -> int:
        return self.clamp_seq(shape.seq_len)

    def cache_specs(self, shape: ShapeSpec, dtype=jnp.bfloat16) -> PyTree:
        cache = jax.eval_shape(
            lambda: self.init_cache(shape.global_batch, self.decode_seq(shape),
                                    dtype))
        return cache

    def input_specs(self, shape: ShapeSpec
                    ) -> Tuple[str, Dict[str, Any]]:
        """(entry_point_name, kwargs-of-ShapeDtypeStruct) for the dry-run."""
        if shape.kind == "train":
            return "train", dict(batch=self.batch_specs(shape))
        if shape.kind == "prefill":
            b = self.batch_specs(shape)
            b.pop("targets")
            return "prefill", dict(batch=b)
        # decode: one new token against a seq_len-deep cache
        B = shape.global_batch
        return "decode", dict(
            tokens=jax.ShapeDtypeStruct((B, 1), jnp.int32),
            cache=self.cache_specs(shape),
        )

    # -- streaming (paper §4: sliding window + attention sinks) -------- #
    def streaming_window(self, shape: ShapeSpec) -> Tuple[int, int]:
        """(window, n_sinks) for this shape; (0, 0) = full attention."""
        cfg = self.cfg
        if shape.name == "long_500k" and self.kv_spec().streaming_long:
            return 8192, cfg.n_sink_tokens
        if cfg.sliding_window:
            return cfg.sliding_window, cfg.n_sink_tokens
        return 0, 0
