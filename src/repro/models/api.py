"""Uniform model protocol consumed by the trainer, the server, the LLMS
context manager, and the dry-run driver.

Every family implements:

  init(key) -> params                        (pytree of stacked-layer arrays)
  loss(params, batch) -> (scalar, metrics)   (next-token CE; remat inside)
  prefill(params, batch, want_density) -> PrefillOut
  decode_step(params, tokens, cache) -> DecodeOut
  init_cache(batch, seq, dtype) -> cache     (pytree incl. integer 'pos')
  input_specs(shape) -> (entry_name, kwargs of ShapeDtypeStruct)

Layer parameters are STACKED on a leading axis and consumed by
``jax.lax.scan`` so the lowered HLO stays one-layer-sized regardless of
depth (95-layer deepseek compiles as fast as 6-layer whisper).

Caches are plain pytrees with an integer ``pos`` leaf; ``decode_step``
returns the cache with ``pos + 1``. This makes the cache a first-class
jit argument: the dry-run lowers ``decode_step`` against a
ShapeDtypeStruct cache without allocating it.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec

Array = jax.Array
PyTree = Any


class PrefillOut(NamedTuple):
    logits: Array                  # (B, vocab) -- last position only
    cache: PyTree
    density: Optional[PyTree]      # per-token Eq.-1 density, family-specific


class DecodeOut(NamedTuple):
    logits: Array                  # (B, vocab)
    cache: PyTree


def cross_entropy(logits: Array, targets: Array, mask: Optional[Array] = None
                  ) -> Tuple[Array, Dict[str, Array]]:
    """Token-mean CE in fp32. logits (B,S,V), targets (B,S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == targets) * mask) / denom
    return loss, {"loss": loss, "acc": acc}


class ModelBase:
    """Common plumbing; families override the layer stack."""

    # True when ``decode_step`` accepts a cache whose ``pos`` leaf is a
    # (B,) vector of per-row positions (each batch row an independent
    # decode slot).  Families opt in once their cache update / attention
    # handle per-row offsets; the executor falls back to a serial loop
    # over slots otherwise.
    supports_batched_decode = False

    # True when ``init_cache(mixed_quant=True)`` builds a mixed-precision
    # working cache (bf16 window + int8 quant-resident segments with
    # per-(token, kv-head) scales + quant_mask) and ``decode_step`` /
    # ``recompute`` attend through it (DESIGN.md §2 quant-resident tier).
    supports_quant_resident = False

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- entry points ------------------------------------------------- #
    def init(self, key) -> PyTree:
        raise NotImplementedError

    def loss(self, params, batch) -> Tuple[Array, Dict[str, Array]]:
        raise NotImplementedError

    def prefill(self, params, batch, want_density: bool = False) -> PrefillOut:
        raise NotImplementedError

    def decode_step(self, params, tokens, cache) -> DecodeOut:
        raise NotImplementedError

    def init_cache(self, batch: int, seq: int, dtype=jnp.bfloat16) -> PyTree:
        raise NotImplementedError

    # -- dry-run specs ------------------------------------------------- #
    def batch_specs(self, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for the data batch of this shape."""
        B, S = shape.global_batch, self.clamp_seq(shape.seq_len)
        tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return {"tokens": tok, "targets": tok}

    def clamp_seq(self, seq: int) -> int:
        return min(seq, self.cfg.max_seq) if self.cfg.family == "encdec" else seq

    def decode_seq(self, shape: ShapeSpec) -> int:
        return self.clamp_seq(shape.seq_len)

    def cache_specs(self, shape: ShapeSpec, dtype=jnp.bfloat16) -> PyTree:
        cache = jax.eval_shape(
            lambda: self.init_cache(shape.global_batch, self.decode_seq(shape),
                                    dtype))
        return cache

    def input_specs(self, shape: ShapeSpec
                    ) -> Tuple[str, Dict[str, Any]]:
        """(entry_point_name, kwargs-of-ShapeDtypeStruct) for the dry-run."""
        if shape.kind == "train":
            return "train", dict(batch=self.batch_specs(shape))
        if shape.kind == "prefill":
            b = self.batch_specs(shape)
            b.pop("targets")
            return "prefill", dict(batch=b)
        # decode: one new token against a seq_len-deep cache
        B = shape.global_batch
        return "decode", dict(
            tokens=jax.ShapeDtypeStruct((B, 1), jnp.int32),
            cache=self.cache_specs(shape),
        )

    # -- streaming (paper §4: sliding window + attention sinks) -------- #
    def streaming_window(self, shape: ShapeSpec) -> Tuple[int, int]:
        """(window, n_sinks) for this shape; (0, 0) = full attention."""
        cfg = self.cfg
        if shape.name == "long_500k" and cfg.family in (
                "dense", "moe", "mla_moe", "vlm"):
            return 8192, cfg.n_sink_tokens
        if cfg.sliding_window:
            return cfg.sliding_window, cfg.n_sink_tokens
        return 0, 0
