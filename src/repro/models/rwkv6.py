"""RWKV-6 "Finch": attention-free, data-dependent per-channel decay.

Recurrence per head (state S: (hd_k, hd_v) fp32):
    o_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
with w_t = exp(-exp(w0 + LoRA_w(x_t))) a data-dependent decay, and
token-shift "ddlerp" mixes (mu + LoRA) producing r,k,v,g,w inputs.

Prefill runs the **chunked-parallel** form: within a chunk of length
``chunk_len`` the contribution is a decay-weighted triangular matmul;
across chunks the state is carried by a scan.  Numerical safety: the
factorized intra-chunk decay uses exp(+L) terms bounded by clamping the
per-step log-decay at LOG_DECAY_FLOOR = -5.0 (a decay < e^-5 per step is
indistinguishable from 0 after two steps); with chunk_len = 16 the
largest exponent is 80 < fp32 max (~88).  DESIGN.md records this.

LLMS applicability: the context state is CONSTANT-size (one blob), so the
paper's chunk-granularity techniques degenerate to whole-state
swap/quantize + recompute-from-text (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import common as C
from repro.models.api import DecodeOut, ModelBase, PrefillOut
from repro.models.dense import blockwise_ce
from repro.models.kvspec import KVSpec

Array = jax.Array
LOG_DECAY_FLOOR = -5.0


class RWKV6Model(ModelBase):

    def kv_spec(self) -> KVSpec:
        return KVSpec(
            family=self.cfg.family,
            seq_leaves=(),
            state_leaves=("wkv", "tm", "cm"),
            servable=True,
            chunkable=False,          # constant-size state: one blob
            recomputable=True,        # state rebuilds from resident text
            batched_decode=False,
            quant_resident=False,
            paged=False,
            pipelined_restore=False,
            # a pad token folds into the carried recurrence — extends
            # must run at exact length, never bucket-padded
            pad_safe=False,
            tolerance_class="state",
            min_bits=16,              # fp16 snapshot only; never chunk-quant
            density=False,            # attention-free: no Eq.-1 statistic
        )

    def init(self, key) -> Dict:
        cfg = self.cfg
        r = cfg.rwkv
        d, ff, L = cfg.d_model, cfg.d_ff, cfg.n_layers
        H = cfg.n_heads
        hd = r.head_dim
        assert H * hd == d
        ks = jax.random.split(key, 24)
        lin = C.init_linear
        layers = {
            "ln1": jnp.ones((L, d), jnp.float32),
            "ln1_b": jnp.zeros((L, d), jnp.float32),
            "ln2": jnp.ones((L, d), jnp.float32),
            "ln2_b": jnp.zeros((L, d), jnp.float32),
            # ddlerp token-shift mixes
            "mu_x": jnp.full((L, d), 0.5, jnp.float32),
            "mix_w1": lin(ks[0], (L, d, 5 * r.mix_lora), 0.01),
            "mix_w2": lin(ks[1], (L, 5, r.mix_lora, d), 0.01),
            "mu_rkvgw": jnp.full((L, 5, d), 0.5, jnp.float32),
            # decay
            "w0": jnp.full((L, d), -0.6, jnp.float32),   # exp(-exp(-0.6))~.58
            "w_a": lin(ks[2], (L, d, r.decay_lora), 0.01),
            "w_b": lin(ks[3], (L, r.decay_lora, d), 0.01),
            "u": lin(ks[4], (L, H, hd), 0.3),
            # time-mix projections
            "wr": lin(ks[5], (L, d, d)),
            "wk": lin(ks[6], (L, d, d)),
            "wv": lin(ks[7], (L, d, d)),
            "wg": lin(ks[8], (L, d, d)),
            "wo": lin(ks[9], (L, d, d)),
            "lnx": jnp.ones((L, d), jnp.float32),
            "lnx_b": jnp.zeros((L, d), jnp.float32),
            # channel-mix
            "mu_ck": jnp.full((L, d), 0.5, jnp.float32),
            "mu_cr": jnp.full((L, d), 0.5, jnp.float32),
            "wck": lin(ks[10], (L, d, ff)),
            "wcv": lin(ks[11], (L, ff, d)),
            "wcr": lin(ks[12], (L, d, d)),
        }
        return {
            "embed": lin(ks[13], (cfg.vocab, d)),
            "head": lin(ks[14], (d, cfg.vocab)),
            "ln_f": jnp.ones((d,), jnp.float32),
            "ln_f_b": jnp.zeros((d,), jnp.float32),
            "layers": layers,
        }

    def head_weight(self, params):
        return params["head"]

    # -- ddlerp token shift ---------------------------------------------- #
    def _ddlerp(self, pl, x, x_prev):
        """x, x_prev: (B,S,d) -> five mixed inputs (5,B,S,d)."""
        xx = x_prev - x
        x_x = x + xx * pl["mu_x"].astype(x.dtype)
        lora = jnp.tanh(x_x @ pl["mix_w1"])                      # (B,S,5*ml)
        B, S, _ = x.shape
        ml = pl["mix_w2"].shape[1]
        lora = lora.reshape(B, S, 5, ml).transpose(2, 0, 1, 3)   # (5,B,S,ml)
        mix = jnp.einsum("fbsm,fmd->fbsd", lora, pl["mix_w2"])
        mix = mix + pl["mu_rkvgw"].astype(x.dtype)[:, None, None]
        return x[None] + xx[None] * mix                          # (5,B,S,d)

    def _time_mix_inputs(self, pl, x, x_prev):
        cfg, rw = self.cfg, self.cfg.rwkv
        H, hd = cfg.n_heads, rw.head_dim
        B, S, d = x.shape
        xr, xk, xv, xg, xw = self._ddlerp(pl, x, x_prev)
        r = (xr @ pl["wr"]).reshape(B, S, H, hd)
        k = (xk @ pl["wk"]).reshape(B, S, H, hd)
        v = (xv @ pl["wv"]).reshape(B, S, H, hd)
        g = xg @ pl["wg"]
        logw = pl["w0"].astype(jnp.float32) + \
            jnp.tanh(xw.astype(jnp.float32) @ pl["w_a"].astype(jnp.float32)) \
            @ pl["w_b"].astype(jnp.float32)
        log_decay = jnp.maximum(-jnp.exp(logw), LOG_DECAY_FLOOR)
        log_decay = log_decay.reshape(B, S, H, hd)
        return r, k, v, g, log_decay

    # -- chunked-parallel wkv --------------------------------------------- #
    def _wkv_chunked(self, r, k, v, log_decay, u, state0):
        """r/k/v/log_decay: (B,S,H,hd) ; u: (H,hd); state0: (B,H,hd,hd) fp32.
        Returns (out (B,S,H,hd) fp32, state (B,H,hd,hd))."""
        B, S, H, hd = r.shape
        c = min(self.cfg.rwkv.chunk_len, S)
        nc = (S + c - 1) // c
        pad = nc * c - S
        f32 = jnp.float32
        if pad:
            zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
            r, k, v = zpad(r), zpad(k), zpad(v)
            # pad decay with 0 (= decay 1.0): padded steps are the
            # IDENTITY on the carried state (k=v=0 contribute nothing)
            log_decay = jnp.pad(log_decay,
                                ((0, 0), (0, pad), (0, 0), (0, 0)))
        resh = lambda a: a.astype(f32).reshape(B, nc, c, H, hd) \
                          .transpose(1, 0, 2, 3, 4)              # (nc,B,c,H,hd)
        rc, kc, vc, ldc = resh(r), resh(k), resh(v), resh(log_decay)

        def chunk_step(S0, inp):
            rb, kb, vb, ld = inp                                 # (B,c,H,hd)
            L = jnp.cumsum(ld, axis=1)                           # inclusive
            L_prev = L - ld                                      # exclusive
            L_last = L[:, -1:]                                   # (B,1,H,hd)
            r_in = rb * jnp.exp(L_prev)                          # <= |r|
            k_out = kb * jnp.exp(L_last - L)                     # <= |k|
            k_in = kb * jnp.exp(-L)                              # bounded: c*5<88
            # inter-chunk: queries read the incoming state
            out = jnp.einsum("bchk,bhkv->bchv", r_in, S0)
            # intra-chunk strict-lower-triangular attention
            scores = jnp.einsum("bihk,bjhk->bhij", r_in, k_in)
            ii = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
            jj = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
            scores = jnp.where((jj < ii)[None, None], scores, 0.0)
            out = out + jnp.einsum("bhij,bjhv->bihv", scores, vb)
            # diagonal bonus term
            diag = jnp.einsum("bchk,hk,bchk->bch", rb, u, kb)
            out = out + diag[..., None] * vb
            # state update
            S1 = jnp.exp(L_last[:, 0, :, :, None]) * S0 \
                + jnp.einsum("bjhk,bjhv->bhkv", k_out, vb)
            return S1, out

        state, outs = jax.lax.scan(chunk_step, state0.astype(f32),
                                   (rc, kc, vc, ldc))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nc * c, H, hd)
        return out[:, :S], state

    def _time_mix_full(self, pl, x, tm_prev, state0):
        """Full-sequence time-mix.  tm_prev: (B,d) last token before x."""
        cfg, rw = self.cfg, self.cfg.rwkv
        B, S, d = x.shape
        xs = C.layer_norm(x, pl["ln1"], pl["ln1_b"], cfg.norm_eps)
        x_prev = jnp.concatenate([tm_prev[:, None].astype(xs.dtype),
                                  xs[:, :-1]], axis=1)
        r, k, v, g, ld = self._time_mix_inputs(pl, xs, x_prev)
        out, state = self._wkv_chunked(r, k, v, ld, pl["u"].astype(jnp.float32),
                                       state0)
        out = out.reshape(B, S, d)
        out = C.group_norm_heads(out.astype(x.dtype), pl["lnx"], pl["lnx_b"],
                                 cfg.n_heads)
        out = (out * jax.nn.silu(g)) @ pl["wo"]
        return x + out, xs[:, -1], state

    def _channel_mix_full(self, pl, x, cm_prev):
        cfg = self.cfg
        xs = C.layer_norm(x, pl["ln2"], pl["ln2_b"], cfg.norm_eps)
        x_prev = jnp.concatenate([cm_prev[:, None].astype(xs.dtype),
                                  xs[:, :-1]], axis=1)
        xx = x_prev - xs
        xk = xs + xx * pl["mu_ck"].astype(xs.dtype)
        xr = xs + xx * pl["mu_cr"].astype(xs.dtype)
        kk = jnp.square(jax.nn.relu(xk @ pl["wck"]))
        out = jax.nn.sigmoid(xr @ pl["wcr"]) * (kk @ pl["wcv"])
        return x + out, xs[:, -1]

    def _forward_full(self, params, tokens, state=None, remat=False):
        cfg = self.cfg
        B, S = tokens.shape
        x = C.constrain_batch(params["embed"][tokens].astype(jnp.bfloat16))
        L = cfg.n_layers
        if state is None:
            H, hd = cfg.n_heads, cfg.rwkv.head_dim
            wkv0 = jnp.zeros((L, B, H, hd, hd), jnp.float32)
            tm0 = jnp.zeros((L, B, cfg.d_model), jnp.bfloat16)
            cm0 = jnp.zeros((L, B, cfg.d_model), jnp.bfloat16)
        else:
            wkv0, tm0, cm0 = state["wkv"], state["tm"], state["cm"]

        def body(x, inp):
            pl, w0, t0, c0 = inp
            x, tm_new, w_new = self._time_mix_full(pl, x, t0, w0)
            x, cm_new = self._channel_mix_full(pl, x, c0)
            return C.constrain_batch(x), {"wkv": w_new, "tm": tm_new,
                                          "cm": cm_new}

        if remat:
            body = jax.checkpoint(body)
        x, ys = jax.lax.scan(body, x, (params["layers"], wkv0, tm0, cm0))
        x = C.layer_norm(x, params["ln_f"], params["ln_f_b"], cfg.norm_eps)
        return x, ys

    # -- entry points ------------------------------------------------------ #
    def loss(self, params, batch):
        x, _ = self._forward_full(params, batch["tokens"], remat=True)
        return blockwise_ce(x, self.head_weight(params), batch["targets"],
                            batch.get("mask"))

    def prefill(self, params, batch, want_density=False, window=0, n_sinks=0):
        tokens = batch["tokens"]
        x, ys = self._forward_full(params, tokens)
        logits = (x[:, -1] @ self.head_weight(params)).astype(jnp.float32)
        cache = {"wkv": ys["wkv"], "tm": ys["tm"], "cm": ys["cm"],
                 "pos": jnp.int32(tokens.shape[1])}
        return PrefillOut(logits, cache, None)   # attention-free: no Eq.-1

    def decode_step(self, params, tokens, cache, window=0, n_sinks=0,
                    want_density=False):
        cfg, rw = self.cfg, self.cfg.rwkv
        H, hd, d = cfg.n_heads, rw.head_dim, cfg.d_model
        x = C.constrain_batch(
            params["embed"][tokens].astype(jnp.bfloat16))     # (B,1,d)

        def body(x, inp):
            pl, S0, t0, c0 = inp
            xs = C.layer_norm(x, pl["ln1"], pl["ln1_b"], cfg.norm_eps)
            x_prev = t0[:, None].astype(xs.dtype)
            r, k, v, g, ld = self._time_mix_inputs(pl, xs, x_prev)
            B = x.shape[0]
            rf, kf, vf = (a.astype(jnp.float32)[:, 0] for a in (r, k, v))
            kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
            att = S0 + pl["u"].astype(jnp.float32)[None, :, :, None] * kv
            out = jnp.einsum("bhk,bhkv->bhv", rf, att).reshape(B, 1 * d)
            S1 = jnp.exp(ld.astype(jnp.float32))[:, 0, :, :, None] * S0 + kv
            out = C.group_norm_heads(out.astype(x.dtype), pl["lnx"],
                                     pl["lnx_b"], H).reshape(B, 1, d)
            out = (out * jax.nn.silu(g)) @ pl["wo"]
            x = x + out
            x, cm_new = self._channel_mix_full(pl, x, c0)
            return C.constrain_batch(x), {"wkv": S1, "tm": xs[:, -1],
                                          "cm": cm_new}

        x, ys = jax.lax.scan(body, x, (params["layers"], cache["wkv"],
                                       cache["tm"], cache["cm"]))
        x = C.layer_norm(x, params["ln_f"], params["ln_f_b"], cfg.norm_eps)
        logits = (x[:, 0] @ self.head_weight(params)).astype(jnp.float32)
        out = DecodeOut(logits, {"wkv": ys["wkv"], "tm": ys["tm"],
                                 "cm": ys["cm"], "pos": cache["pos"] + 1})
        if want_density:
            # attention-free: no Eq.-1 key mass; the accumulator is
            # length-tolerant, so a (B, 1) zero row is a clean no-op
            return out, jnp.zeros((tokens.shape[0], 1), jnp.float32)
        return out

    def recompute(self, params, miss_tokens, miss_pos, cache, seq_len,
                  window=0, n_sinks=0, want_density=False):
        """Constant-state 'recompute': run the text through the
        recurrence continuing from the state carried in ``cache`` (a
        zero state rebuilds from scratch).  ``miss_pos`` must be the
        contiguous append range — recurrent state has no random access,
        so there are no mid-sequence hole fills."""
        x, ys = self._forward_full(params, miss_tokens,
                                   state={"wkv": cache["wkv"],
                                          "tm": cache["tm"],
                                          "cm": cache["cm"]})
        new_cache = {"wkv": ys["wkv"], "tm": ys["tm"], "cm": ys["cm"],
                     "pos": cache["pos"]}
        density = (jnp.zeros(miss_tokens.shape, jnp.float32)
                   if want_density else None)
        return new_cache, x, density

    def _build_cache(self, batch, seq, dtype, layout):
        cfg, rw = self.cfg, self.cfg.rwkv
        L, H, hd, d = cfg.n_layers, cfg.n_heads, rw.head_dim, cfg.d_model
        # seq-independent: the state is one constant-size blob
        return {
            "wkv": jnp.zeros((L, batch, H, hd, hd), jnp.float32),
            "tm": jnp.zeros((L, batch, d), jnp.bfloat16),
            "cm": jnp.zeros((L, batch, d), jnp.bfloat16),
            "pos": jnp.int32(0),
        }
