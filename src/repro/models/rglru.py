"""RecurrentGemma (Griffin) — RG-LRU recurrent blocks + local MQA, 1:2.

Block pattern (rec, rec, attn) repeating; 26 layers = 8 triples + 2
trailing recurrent layers.  Every block is followed by a GeGLU MLP.

RG-LRU recurrence (per channel):
    r_t = sigmoid(blockdiag(x_t; W_a))          recurrence gate
    i_t = sigmoid(blockdiag(x_t; W_x))          input gate
    log a_t = -c * softplus(lambda) * r_t       c = 8.0
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Prefill evaluates the linear recurrence with ``jax.lax.associative_scan``
(parallel over sequence); decode is the single-step form.  Both support
an incoming state h0, which is what lets LLMS snapshot/restore contexts
for this family (DESIGN.md §Arch-applicability).

Attention layers use a 2048-token local window with a single KV head
(MQA).  Their KV is cache-managed by LLMS like any dense model's.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as C
from repro.models.api import DecodeOut, ModelBase, PrefillOut
from repro.models.dense import blockwise_ce
from repro.models.kvspec import KVSpec

Array = jax.Array
RG_C = 8.0


def _block_counts(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """(n_rec, n_attn, n_triples, n_trailing_rec)."""
    pat = cfg.rglru.block_pattern
    kinds = [pat[i % len(pat)] for i in range(cfg.n_layers)]
    n_rec = sum(1 for k in kinds if k == "rec")
    n_attn = len(kinds) - n_rec
    n_triples = cfg.n_layers // 3
    n_trail = cfg.n_layers - 3 * n_triples
    assert n_trail in (0, 2), "pattern assumes rec,rec,attn triples"
    return n_rec, n_attn, n_triples, n_trail


def block_diag_apply(x: Array, w: Array, b: Array) -> Array:
    """x (..., w_total); w (nb, blk, blk); b (nb, blk)."""
    nb, blk, _ = w.shape
    xs = x.reshape(*x.shape[:-1], nb, blk)
    y = jnp.einsum("...nk,nkj->...nj", xs, w) + b
    return y.reshape(*x.shape)


class RGLRUModel(ModelBase):

    def kv_spec(self) -> KVSpec:
        cfg = self.cfg
        kv_dims = (cfg.n_kv_heads, cfg.head_dim)
        return KVSpec(
            family=cfg.family,
            # hybrid: local-MQA K/V is token-indexed (leading axis is
            # n_attn, not n_layers, but the codec only slices along
            # TOKEN_AXIS); conv/lru recurrence is constant-size state
            seq_leaves=("k", "v"),
            leaf_dims={"k": kv_dims, "v": kv_dims},
            state_leaves=("conv", "lru"),
            servable=False,           # no incremental append entry yet
            chunkable=True,
            recomputable=False,
            batched_decode=False,
            quant_resident=False,
            paged=False,
            pipelined_restore=False,
            pad_safe=False,           # pads fold into the recurrence
            tolerance_class="state",
            min_bits=16,
        )

    def init(self, key) -> Dict:
        cfg = self.cfg
        g = cfg.rglru
        d, w, ff = cfg.d_model, g.lru_width, cfg.d_ff
        n_rec, n_attn, _, _ = _block_counts(cfg)
        nb = cfg.n_heads                     # block-diag groups for gates
        blk = w // nb
        ks = jax.random.split(key, 20)
        lin = C.init_linear
        rec = {
            "ln": jnp.ones((n_rec, d), jnp.float32),
            "w_x": lin(ks[0], (n_rec, d, w)),
            "w_gate": lin(ks[1], (n_rec, d, w)),
            "conv_k": lin(ks[2], (n_rec, g.conv_width, w), 0.1),
            "conv_b": jnp.zeros((n_rec, w), jnp.float32),
            "gate_a_w": lin(ks[3], (n_rec, nb, blk, blk)),
            "gate_a_b": jnp.zeros((n_rec, nb, blk), jnp.float32),
            "gate_x_w": lin(ks[4], (n_rec, nb, blk, blk)),
            "gate_x_b": jnp.zeros((n_rec, nb, blk), jnp.float32),
            # lambda init so that a^c in (0.9, 0.999) at r=1 (Griffin)
            "lam": jnp.full((n_rec, w), 0.7, jnp.float32),
            "w_out": lin(ks[5], (n_rec, w, d)),
        }
        attn = {
            "ln": jnp.ones((n_attn, d), jnp.float32),
            "wq": lin(ks[6], (n_attn, d, cfg.n_heads * cfg.head_dim)),
            "wk": lin(ks[7], (n_attn, d, cfg.n_kv_heads * cfg.head_dim)),
            "wv": lin(ks[8], (n_attn, d, cfg.n_kv_heads * cfg.head_dim)),
            "wo": lin(ks[9], (n_attn, cfg.n_heads * cfg.head_dim, d)),
        }
        mlp = {
            "ln": jnp.ones((cfg.n_layers, d), jnp.float32),
            "w_gate": lin(ks[10], (cfg.n_layers, d, ff)),
            "w_up": lin(ks[11], (cfg.n_layers, d, ff)),
            "w_down": lin(ks[12], (cfg.n_layers, ff, d)),
        }
        return {
            "embed": lin(ks[13], (cfg.vocab, d)),
            "ln_f": jnp.ones((d,), jnp.float32),
            "rec": rec, "attn": attn, "mlp": mlp,
        }

    def head_weight(self, params):
        return params["embed"].T            # gemma ties embeddings

    # -- pieces ---------------------------------------------------------- #
    def _mlp(self, pm, x):
        h = C.rms_norm(x, pm["ln"], self.cfg.norm_eps)
        h = jax.nn.gelu(h @ pm["w_gate"], approximate=True) * (h @ pm["w_up"])
        return x + h @ pm["w_down"]

    def _rglru_gates(self, pr, xc):
        """xc: conv output (..., w) -> (log_a, gated_input)."""
        r = jax.nn.sigmoid(block_diag_apply(xc.astype(jnp.float32),
                                            pr["gate_a_w"], pr["gate_a_b"]))
        i = jax.nn.sigmoid(block_diag_apply(xc.astype(jnp.float32),
                                            pr["gate_x_w"], pr["gate_x_b"]))
        log_a = -RG_C * jax.nn.softplus(pr["lam"]) * r
        beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
        return log_a, beta * (i * xc.astype(jnp.float32))

    def _rec_block_full(self, pr, x, conv_state, h0):
        """Full-sequence recurrent block.  x (B,S,d); conv_state (B,cw-1,w);
        h0 (B,w) fp32.  Returns (x', new_conv_state, new_h)."""
        g = self.cfg.rglru
        h = C.rms_norm(x, pr["ln"], self.cfg.norm_eps)
        xb = h @ pr["w_x"]                                     # (B,S,w)
        gate = jax.nn.gelu(h @ pr["w_gate"], approximate=True)
        # causal depthwise conv over time, seeded with conv_state
        ext = jnp.concatenate([conv_state.astype(xb.dtype), xb], axis=1)
        cw = g.conv_width
        xc = sum(ext[:, i:i + xb.shape[1]] * pr["conv_k"][cw - 1 - i]
                 .astype(xb.dtype) for i in range(cw))
        xc = xc + pr["conv_b"].astype(xb.dtype)
        new_conv = ext[:, ext.shape[1] - (cw - 1):]
        log_a, b = self._rglru_gates(pr, xc)                   # (B,S,w) fp32
        # linear recurrence via associative scan (+ h0 contribution)
        a = jnp.exp(log_a)

        def op(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        a_cum, h_seq = jax.lax.associative_scan(op, (a, b), axis=1)
        h_seq = h_seq + a_cum * h0[:, None, :]
        new_h = h_seq[:, -1]
        y = (h_seq.astype(x.dtype) * gate) @ pr["w_out"]
        return x + y, new_conv, new_h

    def _rec_block_step(self, pr, x, conv_state, h0):
        """One-token recurrent block.  x (B,1,d)."""
        g = self.cfg.rglru
        h = C.rms_norm(x, pr["ln"], self.cfg.norm_eps)
        xb = h @ pr["w_x"]                                     # (B,1,w)
        gate = jax.nn.gelu(h @ pr["w_gate"], approximate=True)
        cw = g.conv_width
        ext = jnp.concatenate([conv_state.astype(xb.dtype), xb], axis=1)
        taps = [ext[:, -(i + 1)] * pr["conv_k"][cw - 1 - i].astype(xb.dtype)
                for i in range(cw)]
        xc = (sum(taps) + pr["conv_b"].astype(xb.dtype))[:, None]
        new_conv = ext[:, 1:]
        log_a, b = self._rglru_gates(pr, xc)
        new_h = jnp.exp(log_a[:, 0]) * h0 + b[:, 0]
        y = (new_h[:, None].astype(x.dtype) * gate) @ pr["w_out"]
        return x + y, new_conv, new_h

    def _attn_block(self, pa, x, positions, k_ctx, v_ctx, want_density):
        """Full-seq local attention.  k_ctx/v_ctx: caches to return."""
        cfg = self.cfg
        g = cfg.rglru
        h = C.rms_norm(x, pa["ln"], cfg.norm_eps)
        B, S, _ = x.shape
        q = (h @ pa["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
        k = (h @ pa["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ pa["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
        cos, sin = C.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
        q, k = C.apply_rope(q, cos, sin), C.apply_rope(k, cos, sin)
        if S > 2048 and not want_density:
            out = C.flash_attention(q, k, v, 0, 1024, g.window, 0)
            ao = C.AttnOut(out, None)
        elif S > 2048:
            ao = C.blocked_causal_attention(q, k, v, window=g.window,
                                            want_density=want_density)
        else:
            mask = C.causal_window_mask(positions, positions, g.window)
            ao = C.gqa_attention(q, k, v, mask, want_density=want_density)
        x = x + ao.out.reshape(B, S, -1) @ pa["wo"]
        return x, k, v, ao.key_density

    # -- stacked forward -------------------------------------------------- #
    def _forward_full(self, params, tokens, want_density=False,
                      return_cache=False, remat=False, state=None):
        cfg = self.cfg
        n_rec, n_attn, n_tri, n_trail = _block_counts(cfg)
        B, S = tokens.shape
        d = cfg.d_model
        x = C.constrain_batch(
            params["embed"][tokens].astype(jnp.bfloat16)
            * jnp.sqrt(jnp.bfloat16(d)))
        positions = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
        g = self.cfg.rglru
        if state is None:
            conv0 = jnp.zeros((n_rec, B, g.conv_width - 1, g.lru_width),
                              jnp.bfloat16)
            lru0 = jnp.zeros((n_rec, B, g.lru_width), jnp.float32)
        else:
            conv0, lru0 = state

        take = lambda t, i: jax.tree.map(lambda a: a[i], t)
        rec_p, attn_p, mlp_p = params["rec"], params["attn"], params["mlp"]
        # stage triples for scan
        tri_rec = jax.tree.map(
            lambda a: a[:2 * n_tri].reshape(n_tri, 2, *a.shape[1:]), rec_p)
        tri_attn = jax.tree.map(lambda a: a[:n_tri], attn_p)
        tri_mlp = jax.tree.map(
            lambda a: a[:3 * n_tri].reshape(n_tri, 3, *a.shape[1:]), mlp_p)
        tri_conv = conv0[:2 * n_tri].reshape(n_tri, 2, *conv0.shape[1:])
        tri_lru = lru0[:2 * n_tri].reshape(n_tri, 2, *lru0.shape[1:])

        def triple(x, inp):
            pr2, pa, pm3, cv2, lr2 = inp
            outs_cv, outs_lr = [], []
            for j in range(2):
                x, cv, lr = self._rec_block_full(take(pr2, j), x, cv2[j],
                                                 lr2[j])
                x = self._mlp(take(pm3, j), x)
                outs_cv.append(cv)
                outs_lr.append(lr)
            x, k, v, dens = self._attn_block(pa, x, positions, None, None,
                                             want_density)
            x = C.constrain_batch(self._mlp(take(pm3, 2), x))
            ys = {"conv": jnp.stack(outs_cv), "lru": jnp.stack(outs_lr)}
            if return_cache:
                ys["k"], ys["v"] = k, v
            if want_density:
                ys["density"] = dens
            return x, ys

        if remat:
            triple = jax.checkpoint(triple)
        x, ys = jax.lax.scan(triple, x,
                             (tri_rec, tri_attn, tri_mlp, tri_conv, tri_lru))
        convs = ys["conv"].reshape(2 * n_tri, B, g.conv_width - 1, g.lru_width)
        lrus = ys["lru"].reshape(2 * n_tri, B, g.lru_width)
        # trailing rec layers
        trail_cv, trail_lr = [], []
        for t in range(n_trail):
            i_rec = 2 * n_tri + t
            i_mlp = 3 * n_tri + t
            x, cv, lr = self._rec_block_full(take(rec_p, i_rec), x,
                                             conv0[i_rec], lru0[i_rec])
            x = self._mlp(take(mlp_p, i_mlp), x)
            trail_cv.append(cv)
            trail_lr.append(lr)
        if n_trail:
            convs = jnp.concatenate([convs, jnp.stack(trail_cv)])
            lrus = jnp.concatenate([lrus, jnp.stack(trail_lr)])
        x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
        out = {"x": x, "conv": convs, "lru": lrus}
        if return_cache:
            out["k"], out["v"] = ys["k"], ys["v"]
        if want_density:
            out["density"] = jnp.mean(ys["density"], axis=0)
        return out

    # -- entry points ------------------------------------------------------ #
    def loss(self, params, batch):
        out = self._forward_full(params, batch["tokens"], remat=True)
        return blockwise_ce(out["x"], self.head_weight(params),
                            batch["targets"], batch.get("mask"))

    def prefill(self, params, batch, want_density=False, window=0, n_sinks=0):
        tokens = batch["tokens"]
        out = self._forward_full(params, tokens, want_density=want_density,
                                 return_cache=True)
        logits = (out["x"][:, -1] @ self.head_weight(params)).astype(jnp.float32)
        cache = {"k": out["k"], "v": out["v"], "conv": out["conv"],
                 "lru": out["lru"], "pos": jnp.int32(tokens.shape[1])}
        return PrefillOut(logits, cache, out.get("density"))

    def decode_step(self, params, tokens, cache, window=0, n_sinks=0,
                    want_density=False):
        cfg = self.cfg
        g = cfg.rglru
        n_rec, n_attn, n_tri, n_trail = _block_counts(cfg)
        x = C.constrain_batch(
            params["embed"][tokens].astype(jnp.bfloat16)
            * jnp.sqrt(jnp.bfloat16(cfg.d_model)))
        pos = cache["pos"]
        positions = pos[None]
        take = lambda t, i: jax.tree.map(lambda a: a[i], t)
        rec_p, attn_p, mlp_p = params["rec"], params["attn"], params["mlp"]

        tri_rec = jax.tree.map(
            lambda a: a[:2 * n_tri].reshape(n_tri, 2, *a.shape[1:]), rec_p)
        tri_attn = jax.tree.map(lambda a: a[:n_tri], attn_p)
        tri_mlp = jax.tree.map(
            lambda a: a[:3 * n_tri].reshape(n_tri, 3, *a.shape[1:]), mlp_p)
        cv = cache["conv"]
        lr = cache["lru"]
        tri_cv = cv[:2 * n_tri].reshape(n_tri, 2, *cv.shape[1:])
        tri_lr = lr[:2 * n_tri].reshape(n_tri, 2, *lr.shape[1:])

        def triple(x, inp):
            pr2, pa, pm3, cv2, lr2, k_c, v_c = inp
            new_cv, new_lr = [], []
            for j in range(2):
                x, c2, l2 = self._rec_block_step(take(pr2, j), x, cv2[j],
                                                 lr2[j])
                x = self._mlp(take(pm3, j), x)
                new_cv.append(c2)
                new_lr.append(l2)
            # local attention decode
            h = C.rms_norm(x, pa["ln"], cfg.norm_eps)
            B = x.shape[0]
            q = (h @ pa["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
            k = (h @ pa["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
            v = (h @ pa["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
            cos, sin = C.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
            q, k = C.apply_rope(q, cos, sin), C.apply_rope(k, cos, sin)
            k_c = C.ring_update(k_c, k, pos)
            v_c = C.ring_update(v_c, v, pos)
            out = C.decode_attention(q, k_c, v_c, pos + 1, window=g.window)
            x = x + out.reshape(B, 1, -1) @ pa["wo"]
            x = C.constrain_batch(self._mlp(take(pm3, 2), x))
            return x, {"conv": jnp.stack(new_cv), "lru": jnp.stack(new_lr),
                       "k": k_c, "v": v_c}

        x, ys = jax.lax.scan(
            triple, x, (tri_rec, tri_attn, tri_mlp, tri_cv, tri_lr,
                        cache["k"], cache["v"]))
        convs = ys["conv"].reshape(2 * n_tri, *cv.shape[1:])
        lrus = ys["lru"].reshape(2 * n_tri, *lr.shape[1:])
        trail_cv, trail_lr = [], []
        for t in range(n_trail):
            i_rec, i_mlp = 2 * n_tri + t, 3 * n_tri + t
            x, c2, l2 = self._rec_block_step(take(rec_p, i_rec), x,
                                             cv[i_rec], lr[i_rec])
            x = self._mlp(take(mlp_p, i_mlp), x)
            trail_cv.append(c2)
            trail_lr.append(l2)
        if n_trail:
            convs = jnp.concatenate([convs, jnp.stack(trail_cv)])
            lrus = jnp.concatenate([lrus, jnp.stack(trail_lr)])
        x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = (x[:, 0] @ self.head_weight(params)).astype(jnp.float32)
        out = DecodeOut(logits, {"k": ys["k"], "v": ys["v"], "conv": convs,
                                 "lru": lrus, "pos": pos + 1})
        if want_density:
            # density tracked at prefill granularity for the hybrid
            return out, jnp.zeros((tokens.shape[0], 1), jnp.float32)
        return out

    def _build_cache(self, batch, seq, dtype, layout):
        cfg = self.cfg
        g = cfg.rglru
        n_rec, n_attn, _, _ = _block_counts(cfg)
        return {
            "k": jnp.zeros((n_attn, batch, seq, cfg.n_kv_heads, cfg.head_dim),
                           dtype),
            "v": jnp.zeros((n_attn, batch, seq, cfg.n_kv_heads, cfg.head_dim),
                           dtype),
            "conv": jnp.zeros((n_rec, batch, g.conv_width - 1, g.lru_width),
                              jnp.bfloat16),
            "lru": jnp.zeros((n_rec, batch, g.lru_width), jnp.float32),
            "pos": jnp.int32(0),
        }
