"""Dense decoder-only transformer (llama / qwen / opt / smollm family).

Covers: llama2-7b, opt-6.7b (paper's models), deepseek-67b, qwen3-32b,
qwen2.5-14b, smollm-360m.  Optional qk-norm (qwen3) and qkv-bias (qwen2.5).

Layer params are stacked (L, ...) and consumed by ``lax.scan``; training
wraps the layer body in ``jax.checkpoint``.  The LM head loss is computed
in sequence blocks so (B, S, vocab) logits are never materialized.

This module also implements the paper's **interleaved-chunk recompute**
entry (`recompute`): given a KV cache with holes and the original tokens
of the missing slots, it recomputes exactly those positions with global
RoPE and an iota-built ``k_pos <= q_pos`` mask (paper Fig. 7), reusing
the same layer weights/scan.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import io_callback

from repro.models import common as C
from repro.models.api import DecodeOut, ModelBase, PrefillOut
from repro.models.kvspec import KVSpec, LAYOUT_MIXED, LAYOUT_WINDOW

Array = jax.Array


# --------------------------------------------------------------------- #
# Blockwise LM head CE: never materializes (B, S, V)
# --------------------------------------------------------------------- #
def blockwise_ce(x: Array, head: Array, targets: Array,
                 mask: Optional[Array] = None, block: int = 512
                 ) -> Tuple[Array, Dict[str, Array]]:
    """x: (B,S,d) final hidden; head: (d,V); targets (B,S)."""
    B, S, d = x.shape
    nb = (S + block - 1) // block
    pad = nb * block - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        m = jnp.pad(jnp.ones((B, S), jnp.float32) if mask is None
                    else mask.astype(jnp.float32), ((0, 0), (0, pad)))
    else:
        m = jnp.ones((B, S), jnp.float32) if mask is None else mask.astype(jnp.float32)
    xb = x.reshape(B, nb, block, d).transpose(1, 0, 2, 3)
    tb = targets.reshape(B, nb, block).transpose(1, 0, 2)
    mb = m.reshape(B, nb, block).transpose(1, 0, 2)

    def step(carry, inp):
        nll_sum, acc_sum, cnt = carry
        xx, tt, mm = inp
        logits = (xx @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tt[..., None], axis=-1)[..., 0]
        nll_sum = nll_sum + jnp.sum((logz - ll) * mm)
        acc_sum = acc_sum + jnp.sum((jnp.argmax(logits, -1) == tt) * mm)
        return (nll_sum, acc_sum, cnt + jnp.sum(mm)), None

    (nll, acc, cnt), _ = jax.lax.scan(
        step, (jnp.float32(0), jnp.float32(0), jnp.float32(0)), (xb, tb, mb))
    cnt = jnp.maximum(cnt, 1.0)
    loss = nll / cnt
    return loss, {"loss": loss, "acc": acc / cnt}


# the mixed-precision (quant-resident) cache leaves that ride along the
# bf16 k/v through every entry point but are never written by them
_QUANT_LEAVES = ("k_q", "v_q", "k_scale", "v_scale")


def _quant_scan_xs(cache, xs):
    """Append the per-layer quant-segment leaves to a layer-scan input."""
    return xs + tuple(cache[n] for n in _QUANT_LEAVES)


def _carry_quant_leaves(new_cache, cache, qm):
    """Decode/recompute never write the quant segments: alias them (and
    the updated quant mask) into the output cache."""
    for n in _QUANT_LEAVES:
        new_cache[n] = cache[n]
    new_cache["quant_mask"] = qm
    return new_cache


def _inner_group(L: int) -> int:
    """Divisor of L nearest sqrt(L) (inner layer count for 2-level remat)."""
    best, target = L, L ** 0.5
    for k in range(1, L + 1):
        if L % k == 0 and abs(k - target) < abs(best - target):
            best = k
    return best


class DenseModel(ModelBase):

    def kv_spec(self) -> KVSpec:
        cfg = self.cfg
        kv_dims = (cfg.n_kv_heads, cfg.head_dim)
        return KVSpec(
            family=cfg.family,
            seq_leaves=("k", "v"),
            leaf_dims={"k": kv_dims, "v": kv_dims},
            servable=True,
            chunkable=True,
            recomputable=True,
            batched_decode=True,
            quant_resident=True,
            paged=True,
            pipelined_restore=True,
            layouts=(LAYOUT_WINDOW, LAYOUT_MIXED),
            tolerance_class="kv",
            min_bits=2,
            int8_serving=True,
            streaming_long=True,
        )

    # ------------------------------------------------------------------ #
    def init(self, key) -> Dict:
        cfg = self.cfg
        H, KV, hd, d, ff = (cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
                            cfg.d_model, cfg.d_ff)
        L = cfg.n_layers
        ks = jax.random.split(key, 16)
        lin = C.init_linear
        layers = {
            "ln_attn": jnp.ones((L, d), jnp.float32),
            "ln_ffn": jnp.ones((L, d), jnp.float32),
            "wq": lin(ks[0], (L, d, H * hd)),
            "wk": lin(ks[1], (L, d, KV * hd)),
            "wv": lin(ks[2], (L, d, KV * hd)),
            "wo": lin(ks[3], (L, H * hd, d)),
            "w_gate": lin(ks[4], (L, d, ff)),
            "w_up": lin(ks[5], (L, d, ff)),
            "w_down": lin(ks[6], (L, ff, d)),
        }
        if cfg.qkv_bias:
            layers["bq"] = jnp.zeros((L, H * hd), jnp.float32)
            layers["bk"] = jnp.zeros((L, KV * hd), jnp.float32)
            layers["bv"] = jnp.zeros((L, KV * hd), jnp.float32)
        if cfg.qk_norm:
            layers["q_norm"] = jnp.ones((L, hd), jnp.float32)
            layers["k_norm"] = jnp.ones((L, hd), jnp.float32)
        params = {
            "embed": lin(ks[7], (cfg.vocab, d)),
            "ln_f": jnp.ones((d,), jnp.float32),
            "layers": layers,
        }
        if not cfg.tie_embeddings:
            params["head"] = lin(ks[8], (d, cfg.vocab))
        return params

    def head_weight(self, params) -> Array:
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["head"]

    # -- per-layer pieces ---------------------------------------------- #
    def _qkv(self, pl, h):
        cfg = self.cfg
        B, S, _ = h.shape
        q = h @ pl["wq"]
        k = h @ pl["wk"]
        v = h @ pl["wv"]
        if cfg.qkv_bias:
            q = q + pl["bq"].astype(q.dtype)
            k = k + pl["bk"].astype(k.dtype)
            v = v + pl["bv"].astype(v.dtype)
        q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
        k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
        v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
        if cfg.qk_norm:
            q = C.rms_norm(q, pl["q_norm"], cfg.norm_eps)
            k = C.rms_norm(k, pl["k_norm"], cfg.norm_eps)
        return q, k, v

    def _rope(self, q, k, positions):
        cfg = self.cfg
        cos, sin = C.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
        return C.apply_rope(q, cos, sin), C.apply_rope(k, cos, sin)

    def _ffn(self, pl, x):
        h = C.rms_norm(x, pl["ln_ffn"], self.cfg.norm_eps)
        return x + C.swiglu(h, pl["w_gate"], pl["w_up"], pl["w_down"])

    # -- full-sequence layer (train / prefill) -------------------------- #
    def _layer_full(self, pl, x, positions, window, n_sinks, want_density,
                    return_kv):
        h = C.rms_norm(x, pl["ln_attn"], self.cfg.norm_eps)
        q, k, v = self._qkv(pl, h)
        q, k = self._rope(q, k, positions)
        S = x.shape[1]
        if (S > 2048 or window) and not want_density:
            out = C.flash_attention(q, k, v, 0, 1024, window, n_sinks)
            ao = C.AttnOut(out, None)
        elif S > 2048 or window:
            ao = C.blocked_causal_attention(
                q, k, v, q_offset=0, block=1024, window=window,
                n_sinks=n_sinks, want_density=want_density)
        else:
            mask = C.causal_window_mask(positions, positions, window, n_sinks)
            ao = C.gqa_attention(q, k, v, mask, want_density=want_density)
        x = x + ao.out.reshape(*x.shape[:2], -1) @ pl["wo"]
        x = self._ffn(pl, x)
        extras = {}
        if want_density:
            extras["density"] = ao.key_density
        if return_kv:
            extras["k"], extras["v"] = k, v
        return x, extras

    def _stack_full(self, params, tokens, *, window=0, n_sinks=0,
                    want_density=False, return_kv=False, remat=False):
        cfg = self.cfg
        x = C.constrain_batch(params["embed"][tokens].astype(jnp.bfloat16))
        S = tokens.shape[1]
        positions = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)

        def body(x, pl):
            x, extras = self._layer_full(pl, x, positions, window, n_sinks,
                                         want_density, return_kv)
            return C.constrain_batch(x), extras

        L = cfg.n_layers
        if remat and L >= 16:
            # two-level remat: scan over layer GROUPS (group inputs saved)
            # with a checkpointed per-layer body inside — peak residency is
            # G + k activations plus ONE layer's transients, instead of L.
            k = _inner_group(L)
            G = L // k
            grouped = jax.tree.map(
                lambda a: a.reshape(G, k, *a.shape[1:]), params["layers"])
            inner = jax.checkpoint(body)

            def group(x, pg):
                return jax.lax.scan(inner, x, pg)

            x, extras = jax.lax.scan(jax.checkpoint(group), x, grouped)
            extras = jax.tree.map(
                lambda a: a.reshape(G * k, *a.shape[2:]), extras)
        else:
            if remat:
                body = jax.checkpoint(body)
            x, extras = jax.lax.scan(body, x, params["layers"])
        x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
        return x, extras

    # -- entry points ---------------------------------------------------- #
    def loss(self, params, batch):
        x, _ = self._stack_full(params, batch["tokens"], remat=True)
        return blockwise_ce(x, self.head_weight(params), batch["targets"],
                            batch.get("mask"))

    def prefill(self, params, batch, want_density=False, window=0, n_sinks=0):
        tokens = batch["tokens"]
        x, extras = self._stack_full(
            params, tokens, window=window, n_sinks=n_sinks,
            want_density=want_density, return_kv=True)
        logits = (x[:, -1] @ self.head_weight(params)).astype(jnp.float32)
        cache = {
            "k": extras["k"],            # (L, B, S, KV, hd)
            "v": extras["v"],
            "pos": jnp.int32(tokens.shape[1]),
        }
        density = None
        if want_density:
            density = jnp.mean(extras["density"], axis=0)   # (B, S) over layers
        return PrefillOut(logits, cache, density)

    def decode_step(self, params, tokens, cache, window=0, n_sinks=0,
                    want_density=False, unroll: int = 1):
        """``unroll`` feeds ``lax.scan(..., unroll=)`` over the layers.
        The batched decode entry passes the full layer count: XLA CPU's
        rolled scan emits per-iteration buffer shuffles that dominate a
        multi-row step (~5x on the bench model), while the unrolled body
        fuses clean.  The serial (B=1) path keeps the rolled scan — its
        one-layer-sized HLO — and is numerically unaffected either way."""
        cfg = self.cfg
        x = C.constrain_batch(
            params["embed"][tokens].astype(jnp.bfloat16))  # (B, 1, d)
        pos = cache["pos"]
        # scalar pos: all rows decode at one position (serial working
        # cache).  (B,) pos: per-row slot positions (batched decode) —
        # rope needs a (B, 1) position table so each row rotates at its
        # own offset.
        positions = pos[None] if pos.ndim == 0 else pos[:, None]

        mixed = "k_q" in cache               # bf16 window + int8 segments
        quantized = "k_scale" in cache and not mixed   # all-int8 cache

        if mixed:
            # the new token lands in the bf16 window: clear its
            # quant-mask bit once (the mask is shared across layers)
            S = cache["k"].shape[2]
            s_pos = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
            idx = pos[None] if pos.ndim == 0 else pos
            qm = cache["quant_mask"] & ~(s_pos[None, :] == idx[:, None])[None]

        def body(x, layer_in):
            kq_c = vq_c = ks_c = vs_c = None
            if mixed:
                pl, k_c, v_c, kq_c, vq_c, ks_c, vs_c = layer_in
            elif quantized:
                pl, k_c, v_c, ks_c, vs_c = layer_in
            else:
                pl, k_c, v_c = layer_in
            h = C.rms_norm(x, pl["ln_attn"], cfg.norm_eps)
            q, k, v = self._qkv(pl, h)
            q, k = self._rope(q, k, positions)
            # keep heads replicated so the SEQUENCE-sharded cache is never
            # re-gathered: attention runs S-local with a tiny partial-
            # softmax all-reduce (flash-decoding; EXPERIMENTS.md §Perf)
            q, k, v = (C.constrain_batch(t) for t in (q, k, v))
            if quantized:
                # per-(token, kv-head) symmetric scales; the attention
                # kernel dequantizes in VMEM (kernels/decode_qattn.py)
                ks = jnp.max(jnp.abs(k.astype(jnp.float32)), -1) / 127.0
                vs = jnp.max(jnp.abs(v.astype(jnp.float32)), -1) / 127.0
                ks = jnp.maximum(ks, 1e-8)
                vs = jnp.maximum(vs, 1e-8)
                kq = jnp.clip(jnp.round(k / ks[..., None]), -127, 127
                              ).astype(jnp.int8)
                vq = jnp.clip(jnp.round(v / vs[..., None]), -127, 127
                              ).astype(jnp.int8)
                k_c = C.ring_update(k_c, kq, pos)
                v_c = C.ring_update(v_c, vq, pos)
                ks_c = C.ring_update(ks_c, ks, pos)
                vs_c = C.ring_update(vs_c, vs, pos)
                out = C.decode_attention(q, k_c, v_c, pos + 1,
                                         k_scale=ks_c, v_scale=vs_c,
                                         window=window, n_sinks=n_sinks,
                                         want_density=want_density)
            elif mixed:
                k_c = C.ring_update(k_c, k, pos)
                v_c = C.ring_update(v_c, v, pos)
                out = C.mixed_decode_attention(
                    q, k_c, v_c, kq_c, vq_c, ks_c, vs_c, qm[0], pos + 1,
                    window=window, n_sinks=n_sinks,
                    want_density=want_density)
            else:
                k_c = C.ring_update(k_c, k, pos)
                v_c = C.ring_update(v_c, v, pos)
                out = C.decode_attention(q, k_c, v_c, pos + 1,
                                         window=window, n_sinks=n_sinks,
                                         want_density=want_density)
            ys = {"k": k_c, "v": v_c}
            if quantized:
                ys["k_scale"], ys["v_scale"] = ks_c, vs_c
            if want_density:
                out, mass = out
                ys["mass"] = mass
            x = x + out.reshape(*x.shape[:2], -1) @ pl["wo"]
            x = C.constrain_batch(self._ffn(pl, x))
            return x, ys

        xs = (params["layers"], cache["k"], cache["v"])
        if mixed:
            xs = _quant_scan_xs(cache, xs)
        elif quantized:
            xs = xs + (cache["k_scale"], cache["v_scale"])
        x, ys = jax.lax.scan(body, x, xs, unroll=max(1, int(unroll)))
        x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = (x[:, 0] @ self.head_weight(params)).astype(jnp.float32)
        new_cache = {"k": ys["k"], "v": ys["v"], "pos": pos + 1}
        if mixed:
            _carry_quant_leaves(new_cache, cache, qm)
        elif quantized:
            new_cache["k_scale"] = ys["k_scale"]
            new_cache["v_scale"] = ys["v_scale"]
        out = DecodeOut(logits, new_cache)
        if want_density:
            return out, jnp.mean(ys["mass"], axis=0)        # (B, S)
        return out

    def _build_cache(self, batch, seq, dtype, layout):
        cfg = self.cfg
        shape = (cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.head_dim)
        cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                 "pos": jnp.int32(0)}
        if dtype == jnp.int8:       # quantized serving cache (+ scales)
            cache["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
            cache["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
        elif layout == LAYOUT_MIXED:
            # mixed-precision working cache: bf16 recent window + int8
            # quant-resident chunk segments with per-(token, kv-head)
            # scales, selected per position by quant_mask.  The mask
            # carries a dummy leading axis so axis 1 is the batch axis
            # for every leaf (the paged gather stacks rows on axis 1).
            cache["k_q"] = jnp.zeros(shape, jnp.int8)
            cache["v_q"] = jnp.zeros(shape, jnp.int8)
            cache["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
            cache["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
            cache["quant_mask"] = jnp.zeros((1, batch, seq), bool)
        return cache

    # ------------------------------------------------------------------ #
    # Paper Fig. 7: recompute missing chunks at scattered positions.
    # ------------------------------------------------------------------ #
    def recompute(self, params, miss_tokens: Array, miss_pos: Array,
                  cache, seq_len, window: int = 0, n_sinks: int = 0,
                  want_density: bool = False):
        """miss_tokens: (B, M) original text of the missing slots;
        miss_pos: (M,) absolute positions; cache: KV with holes at those
        positions; seq_len: number of valid context tokens INCLUDING the
        missing ones.  Returns (cache', hidden (B,M,d), density (B,S)|None)
        — the cache with the missing K/V recomputed exactly (global RoPE
        + on-the-fly causal mask, attending over resident + recomputed KV).

        This same entry point serves as the chunked **prefill-append**:
        append T new tokens by passing miss_pos = [S0, S0+T) against a
        cache holding the first S0 tokens.
        """
        cfg = self.cfg
        x = params["embed"][miss_tokens].astype(jnp.bfloat16)    # (B, M, d)
        S = cache["k"].shape[2]
        k_pos_all = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
        mixed = "k_q" in cache
        if mixed:
            # recomputed positions land in the bf16 window; resident
            # quant segments are read THROUGH during attention (mixed-
            # precision prefill-read; quant-resident prefill-WRITE is a
            # deferred open item, ROADMAP.md)
            qm = cache["quant_mask"] & ~jnp.any(
                k_pos_all[None, :] == miss_pos[:, None], axis=0)[None, None]

        def body(x, layer_in):
            kq_c = vq_c = ks_c = vs_c = None
            if mixed:
                pl, k_c, v_c, kq_c, vq_c, ks_c, vs_c = layer_in
            else:
                pl, k_c, v_c = layer_in
            h = C.rms_norm(x, pl["ln_attn"], cfg.norm_eps)
            q, k, v = self._qkv(pl, h)
            q, k = self._rope(q, k, miss_pos)
            # scatter the recomputed K/V into the resident cache
            k_c = k_c.at[:, miss_pos].set(k.astype(k_c.dtype))
            v_c = v_c.at[:, miss_pos].set(v.astype(v_c.dtype))
            if mixed:
                k_att = C.dequant_select(k_c, kq_c, ks_c, qm[0])
                v_att = C.dequant_select(v_c, vq_c, vs_c, qm[0])
            else:
                k_att, v_att = k_c, v_c
            # attend: q at miss_pos over all valid tokens <= its position
            mask = C.causal_window_mask(miss_pos, k_pos_all, window, n_sinks)
            mask = mask & (k_pos_all < seq_len)[None, :]
            ao = C.gqa_attention(q, k_att.astype(q.dtype),
                                 v_att.astype(q.dtype),
                                 mask, want_density=want_density)
            x = x + ao.out.reshape(*x.shape[:2], -1) @ pl["wo"]
            x = C.constrain_batch(self._ffn(pl, x))
            ys = {"k": k_c, "v": v_c}
            if want_density:
                ys["density"] = ao.key_density
            return x, ys

        xs = (params["layers"], cache["k"], cache["v"])
        if mixed:
            xs = _quant_scan_xs(cache, xs)
        x, ys = jax.lax.scan(body, x, xs)
        x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
        density = jnp.mean(ys["density"], axis=0) if want_density else None
        new_cache = {"k": ys["k"], "v": ys["v"], "pos": cache["pos"]}
        if mixed:
            _carry_quant_leaves(new_cache, cache, qm)
        return new_cache, x, density

    # ------------------------------------------------------------------ #
    # Paged KV pool entries: decode/prefill directly over page arenas.
    # Both gather the per-slot page rows into the SAME dense mixed-cache
    # layout the slot entries consume, run the unchanged decode_step /
    # recompute body, and scatter only the newly written tokens back
    # into their bf16 tail pages — so slots are views into the pool and
    # the emitted tokens are bit-identical to the slot-cache path.
    # ------------------------------------------------------------------ #
    def decode_paged(self, params, tokens, arenas, pt16, pt8, quant_chunks,
                     pos, window: int = 0, n_sinks: int = 0,
                     want_density: bool = False, unroll: int = 1):
        """One [B, 1] decode round over the pool.  tokens (B, 1);
        pt16/pt8 (B, C) page-table rows; quant_chunks (B, C) bool (None
        with pt8=None outside quant-resident mode); pos (B,) per-slot
        decode positions.  -> (arenas', logits[, mass]).  Batch
        membership is carried entirely by the page-table rows: joining
        or leaving the batch changes only pt16/pt8/pos, never copies
        cache state (no merge/split)."""
        cs = arenas["k16"].shape[2]
        cache = C.paged_cache_view(arenas, ("k", "v"), pt16, pt8,
                                   quant_chunks, pos)
        out = self.decode_step(params, tokens, cache, window, n_sinks,
                               want_density, unroll)
        mass = None
        if want_density:
            out, mass = out
        rows = jnp.arange(tokens.shape[0])
        pages = pt16[rows, pos // cs]
        offs = pos % cs
        new_arenas = dict(arenas)
        for n in ("k", "v"):
            val = out.cache[n][:, rows, pos]            # (L, B, KV, hd)
            new_arenas[n + "16"] = arenas[n + "16"].at[
                :, pages, offs].set(val)
        if want_density:
            return new_arenas, out.logits, mass
        return new_arenas, out.logits

    def extend_paged(self, params, miss_tokens, miss_pos, arenas, pt16,
                     pt8, quant_chunks, seq_len, window: int = 0,
                     n_sinks: int = 0, want_density: bool = False):
        """Chunked prefill-append over the pool (B = 1): the paged form
        of ``recompute``'s append mode.  miss_pos positions must map to
        bf16 pages already allocated in pt16 (padding positions map to
        the scratch page 0).  -> (arenas', hidden (1, M, d), density)."""
        cs = arenas["k16"].shape[2]
        cache = C.paged_cache_view(arenas, ("k", "v"), pt16, pt8,
                                   quant_chunks, jnp.int32(0))
        new_cache, x, density = self.recompute(
            params, miss_tokens, miss_pos, cache, seq_len, window,
            n_sinks, want_density)
        pages = pt16[0, miss_pos // cs]
        offs = miss_pos % cs
        new_arenas = dict(arenas)
        for n in ("k", "v"):
            val = new_cache[n][:, 0, miss_pos]          # (L, M, KV, hd)
            new_arenas[n + "16"] = arenas[n + "16"].at[
                :, pages, offs].set(val)
        return new_arenas, x, density

    # ------------------------------------------------------------------ #
    # Paper Fig. 8: swapping-recompute PIPELINED restore.  The scan body
    # pulls layer l's disk-loaded chunk K/V through an ordered
    # io_callback while recomputing the complementary chunk set — the
    # I/O thread (core/restore.py LayerFeed) runs one layer ahead.
    # ------------------------------------------------------------------ #
    def recompute_pipelined(self, params, miss_tokens: Array,
                            miss_pos: Array, io_pos: Array, cache, seq_len,
                            fetch, window: int = 0, n_sinks: int = 0,
                            want_density: bool = False):
        """miss_*: chunks restored by recompute; io_pos (Mio,): token
        positions of chunks arriving from disk, fetched per layer via
        ``fetch(layer) -> {leaf: (Mio, KV, hd) fp32}``."""
        cfg = self.cfg
        x = params["embed"][miss_tokens].astype(jnp.bfloat16)
        S = cache["k"].shape[2]
        Mio = io_pos.shape[0]
        k_pos_all = jax.lax.broadcasted_iota(jnp.int32, (S,), 0)
        io_shape = {
            "k": jax.ShapeDtypeStruct(
                (Mio, cfg.n_kv_heads, cfg.head_dim), jnp.float32),
            "v": jax.ShapeDtypeStruct(
                (Mio, cfg.n_kv_heads, cfg.head_dim), jnp.float32),
        }
        mixed = "k_q" in cache
        if mixed:
            # recomputed AND disk-restored positions materialize in the
            # bf16 window; surviving quant segments are read through
            restored = (jnp.any(k_pos_all[None, :] == miss_pos[:, None], 0)
                        | jnp.any(k_pos_all[None, :] == io_pos[:, None], 0))
            qm = cache["quant_mask"] & ~restored[None, None]

        def body(x, layer_in):
            kq_c = vq_c = ks_c = vs_c = None
            if mixed:
                l_idx, pl, k_c, v_c, kq_c, vq_c, ks_c, vs_c = layer_in
            else:
                l_idx, pl, k_c, v_c = layer_in
            io = io_callback(fetch, io_shape, l_idx, ordered=True)
            k_c = k_c.at[:, io_pos].set(io["k"][None].astype(k_c.dtype))
            v_c = v_c.at[:, io_pos].set(io["v"][None].astype(v_c.dtype))
            h = C.rms_norm(x, pl["ln_attn"], cfg.norm_eps)
            q, k, v = self._qkv(pl, h)
            q, k = self._rope(q, k, miss_pos)
            k_c = k_c.at[:, miss_pos].set(k.astype(k_c.dtype))
            v_c = v_c.at[:, miss_pos].set(v.astype(v_c.dtype))
            if mixed:
                k_att = C.dequant_select(k_c, kq_c, ks_c, qm[0])
                v_att = C.dequant_select(v_c, vq_c, vs_c, qm[0])
            else:
                k_att, v_att = k_c, v_c
            mask = C.causal_window_mask(miss_pos, k_pos_all, window, n_sinks)
            mask = mask & (k_pos_all < seq_len)[None, :]
            ao = C.gqa_attention(q, k_att.astype(q.dtype),
                                 v_att.astype(q.dtype),
                                 mask, want_density=want_density)
            x = x + ao.out.reshape(*x.shape[:2], -1) @ pl["wo"]
            x = C.constrain_batch(self._ffn(pl, x))
            ys = {"k": k_c, "v": v_c}
            if want_density:
                ys["density"] = ao.key_density
            return x, ys

        layer_ids = jnp.arange(cfg.n_layers, dtype=jnp.int32)
        xs = (layer_ids, params["layers"], cache["k"], cache["v"])
        if mixed:
            xs = _quant_scan_xs(cache, xs)
        x, ys = jax.lax.scan(body, x, xs)
        x = C.rms_norm(x, params["ln_f"], cfg.norm_eps)
        density = jnp.mean(ys["density"], axis=0) if want_density else None
        new_cache = {"k": ys["k"], "v": ys["v"], "pos": cache["pos"]}
        if mixed:
            _carry_quant_leaves(new_cache, cache, qm)
        return new_cache, x, density
