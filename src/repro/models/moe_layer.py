"""Mixture-of-experts FFN with grouped, capacity-based top-k dispatch.

GSPMD-friendly formulation: tokens are processed in groups (the group
axis shards over "data"), experts dispatch via one-hot einsums (the
expert axis shards over "model"), so XLA lowers the dispatch/combine to
all-to-all-style collectives on the production mesh.

Capacity C = ceil(group_size * top_k * capacity_factor / n_experts);
overflowing tokens are dropped (their combine weight is zero) — the
standard Mesh-TF/GShard discipline.  The auxiliary load-balancing loss
follows Switch-Transformer: E * mean_e(frac_tokens_e * mean_prob_e).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig

Array = jax.Array


def capacity(gs: int, moe: MoEConfig) -> int:
    c = math.ceil(gs * moe.top_k * moe.capacity_factor / moe.n_experts)
    return max(4, min(c, gs))


def moe_dispatch(gates: Array, top_k: int, cap: int
                 ) -> Tuple[Array, Array, Array]:
    """gates: (G, gs, E) router probabilities.

    Returns (dispatch (G,gs,E,C) bool-ish, combine (G,gs,E,C), aux_loss).
    """
    G, gs, E = gates.shape
    remaining = gates
    # per-expert running token count across the k iterations
    count_so_far = jnp.zeros((G, 1, E), jnp.float32)
    dispatch = jnp.zeros((G, gs, E, cap), jnp.float32)
    combine = jnp.zeros((G, gs, E, cap), jnp.float32)
    frac_routed = jnp.zeros((G, E), jnp.float32)
    for _ in range(top_k):
        idx = jnp.argmax(remaining, axis=-1)                     # (G, gs)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)       # (G, gs, E)
        gate_k = jnp.sum(remaining * onehot, axis=-1)            # (G, gs)
        remaining = remaining * (1.0 - onehot)
        pos = jnp.cumsum(onehot, axis=1) - onehot + count_so_far  # (G, gs, E)
        count_so_far = count_so_far + jnp.sum(onehot, axis=1, keepdims=True)
        pos_in_e = jnp.sum(pos * onehot, axis=-1)                # (G, gs)
        keep = (pos_in_e < cap).astype(jnp.float32)
        slot = jax.nn.one_hot(pos_in_e.astype(jnp.int32), cap,
                              dtype=jnp.float32)                 # (G, gs, C)
        d_k = onehot[..., None] * slot[..., None, :] * keep[..., None, None]
        dispatch = dispatch + d_k
        combine = combine + d_k * gate_k[..., None, None]
        frac_routed = frac_routed + jnp.mean(onehot, axis=1)
    # Switch aux loss: E * sum_e frac_e * mean-prob_e (averaged over groups)
    mean_prob = jnp.mean(gates, axis=1)                           # (G, E)
    aux = E * jnp.mean(jnp.sum((frac_routed / top_k) * mean_prob, axis=-1))
    return dispatch, combine, aux


def moe_ffn(x: Array, p: Dict[str, Array], moe: MoEConfig
            ) -> Tuple[Array, Dict[str, Array]]:
    """x: (..., d).  p: router (d,E), w_gate/w_up (E,d,fe), w_down (E,fe,d),
    optional s_gate/s_up (d,ds), s_down (ds,d) fused shared expert.
    Returns (out (..., d), metrics)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)
    T = xt.shape[0]
    gs = min(moe.group_size, T)
    G = (T + gs - 1) // gs
    pad = G * gs - T
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xg = xt.reshape(G, gs, d)

    logits = (xg.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)                      # (G, gs, E)
    cap = capacity(gs, moe)
    dispatch, combine, aux = moe_dispatch(gates, moe.top_k, cap)

    expert_in = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    out_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), out_e)
    y = y.reshape(G * gs, d)
    if pad:
        y = y[:T]
    y = y.reshape(orig_shape)
    if "s_gate" in p:
        y = y + (jax.nn.silu(x @ p["s_gate"]) * (x @ p["s_up"])) @ p["s_down"]
    metrics = {"moe_aux": aux}
    return y, metrics


def init_moe_params(key, d: int, moe: MoEConfig, dtype=jnp.bfloat16,
                    n_layers: int = 1) -> Dict[str, Array]:
    """Stacked (L, ...) MoE FFN params."""
    ks = jax.random.split(key, 6)
    E, fe = moe.n_experts, moe.d_expert
    L = n_layers
    scale = 0.02

    def lin(k, shape):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dtype)

    p = {
        "router": lin(ks[0], (L, d, E)).astype(jnp.float32),
        "w_gate": lin(ks[1], (L, E, d, fe)),
        "w_up": lin(ks[2], (L, E, d, fe)),
        "w_down": lin(ks[3], (L, E, fe, d)),
    }
    if moe.d_shared:
        p["s_gate"] = lin(ks[4], (L, d, moe.d_shared))
        p["s_up"] = lin(ks[5], (L, d, moe.d_shared))
        p["s_down"] = lin(ks[4], (L, moe.d_shared, d))
    return p
