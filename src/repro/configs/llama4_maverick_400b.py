"""llama4-maverick-400b-a17b [moe] — MoE, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Note: Llama-4 interleaves dense and MoE FFN layers; we model every layer
as MoE (top-1 routed + one always-on shared expert of d_ff), which matches
the assigned spec's "MoE 128e top-1" and keeps the layer stack homogeneous
for lax.scan.  See DESIGN.md §Arch-notes.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    rope_theta=500000.0,
    max_seq=524288,
    moe=MoEConfig(
        n_experts=128,
        top_k=1,
        d_expert=8192,
        n_shared=1,
        d_shared=8192,
        capacity_factor=1.25,
        group_size=1024,
    ),
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
