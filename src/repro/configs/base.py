"""Model / shape configuration system for the LLMS reproduction.

Every assigned architecture is expressed as a :class:`ModelConfig`.  The
config is a *pure description*: model code in ``repro.models`` consumes it,
the sharding rules in ``repro.sharding`` map its parameters onto the mesh,
and ``repro.launch.dryrun`` lowers every (arch x shape) cell from it.

Families
--------
``dense``        decoder-only transformer (GQA / MHA)
``moe``          decoder-only transformer with mixture-of-experts FFN
``mla_moe``      DeepSeek-style Multi-head Latent Attention + MoE
``rglru_hybrid`` RecurrentGemma: RG-LRU recurrent blocks + local attention
``rwkv6``        RWKV-6 "Finch": attention-free, data-dependent decay
``encdec``       Whisper-style encoder-decoder (audio frontend stubbed)
``vlm``          Llama-3.2-Vision-style: self-attn + interleaved cross-attn
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Literal, Optional, Tuple

Family = Literal[
    "dense", "moe", "mla_moe", "rglru_hybrid", "rwkv6", "encdec", "vlm"
]


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (dropping/capacity dispatch)."""

    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden size
    n_shared: int = 0             # number of always-on shared experts
    d_shared: int = 0             # hidden size of the fused shared expert
    capacity_factor: float = 1.25
    # tokens are dispatched in groups of this size; a key perf lever --
    # smaller groups shrink the one-hot dispatch tensors (see DESIGN.md)
    group_size: int = 1024
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => full-rank Q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU recurrent block."""

    lru_width: int = 2560
    conv_width: int = 4
    window: int = 2048            # local-attention window for attn blocks
    block_pattern: Tuple[str, ...] = ("rec", "rec", "attn")


@dataclass(frozen=True)
class RWKV6Config:
    """RWKV-6 (Finch) time-mix / channel-mix."""

    head_dim: int = 64
    decay_lora: int = 64          # rank of the data-dependent decay LoRA
    mix_lora: int = 32            # rank of the token-shift mix LoRA
    chunk_len: int = 16           # chunked prefill length (16*5 < ln(fp32max))


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder.  The conv/audio frontend is a STUB: the
    runtime provides precomputed frame embeddings of shape
    (batch, n_frames, d_model)."""

    n_layers: int = 6
    n_frames: int = 1500


@dataclass(frozen=True)
class VisionConfig:
    """Cross-attention VLM plumbing.  The vision tower is a STUB: the
    runtime provides precomputed patch embeddings of shape
    (batch, n_image_tokens, d_vision)."""

    n_image_tokens: int = 1601
    d_vision: int = 7680
    cross_attn_every: int = 5     # one cross-attn layer per this many layers


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 => d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    max_seq: int = 4096
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # streaming / long-context: sliding-window attention with attention
    # sinks (the paper applies exactly this -- StreamingLLM [71] -- to run
    # LLM inference over unbounded contexts, see paper section 4).
    sliding_window: int = 0       # 0 => full attention
    n_sink_tokens: int = 128
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rglru: Optional[RGLRUConfig] = None
    rwkv: Optional[RWKV6Config] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    source: str = ""              # provenance note from the assignment

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- analytic parameter counting (used by roofline MODEL_FLOPS) ----- #
    def param_count(self) -> int:
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)

    def kv_bytes_per_token(self, bytes_per_elem: float = 2.0) -> float:
        """KV-cache bytes for ONE token across all layers (context memory)."""
        if self.family == "rwkv6":
            return 0.0  # constant-size state, not per-token
        if self.family == "mla_moe" and self.mla is not None:
            d = self.mla.kv_lora_rank + self.mla.qk_rope_head_dim
            return self.n_layers * d * bytes_per_elem
        if self.family == "rglru_hybrid" and self.rglru is not None:
            pat = self.rglru.block_pattern
            n_attn = sum(1 for _ in range(self.n_layers)
                         if pat[_ % len(pat)] == "attn")
            return n_attn * 2 * self.n_kv_heads * self.head_dim * bytes_per_elem
        n = self.n_layers
        if self.family == "encdec" and self.encoder is not None:
            n = self.n_layers  # decoder self-attn layers only
        return n * 2 * self.n_kv_heads * self.head_dim * bytes_per_elem


def _ffn_params(cfg: ModelConfig) -> int:
    """Gated (SwiGLU) FFN parameter count per layer."""
    return 3 * cfg.d_model * cfg.d_ff


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.head_dim
    q = cfg.d_model * cfg.n_heads * hd
    kv = 2 * cfg.d_model * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * cfg.d_model
    return q + kv + o


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    emb = cfg.vocab * cfg.d_model
    head = 0 if cfg.tie_embeddings else cfg.vocab * cfg.d_model
    total = emb + head
    if cfg.family == "rwkv6":
        assert cfg.rwkv is not None
        d = cfg.d_model
        per_layer = (
            5 * d * d                       # r,k,v,g,o time-mix projections
            + d * cfg.rwkv.decay_lora * 2   # decay LoRA
            + 5 * d * cfg.rwkv.mix_lora * 2 # token-shift mix LoRAs
            + 2 * d * cfg.d_ff              # channel-mix (k, v)... r below
            + d * d                         # channel-mix receptance
        )
        return total + cfg.n_layers * per_layer

    if cfg.family == "rglru_hybrid":
        assert cfg.rglru is not None
        w = cfg.rglru.lru_width
        d = cfg.d_model
        rec = 2 * d * w + w * d + 2 * w * cfg.rglru.conv_width + 2 * w
        attn = _attn_params(cfg)
        pat = cfg.rglru.block_pattern
        n_attn = sum(1 for i in range(cfg.n_layers) if pat[i % len(pat)] == "attn")
        n_rec = cfg.n_layers - n_attn
        total += n_rec * (rec + _ffn_params(cfg)) + n_attn * (attn + _ffn_params(cfg))
        return total

    if cfg.family == "mla_moe":
        assert cfg.mla is not None and cfg.moe is not None
        m = cfg.mla
        d = cfg.d_model
        qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
        attn = (d * cfg.n_heads * qk_hd                      # q proj
                + d * (m.kv_lora_rank + m.qk_rope_head_dim)  # kv down
                + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                + cfg.n_heads * m.v_head_dim * d)            # o proj
        moe = cfg.moe
        expert = 3 * d * moe.d_expert
        shared = 3 * d * moe.d_shared if moe.d_shared else 0
        router = d * moe.n_experts
        per_layer = attn + moe.n_experts * expert + shared + router
        total += cfg.n_layers * per_layer
        if active_only:
            active_per_layer = attn + moe.top_k * expert + shared + router
            return emb + head + cfg.n_layers * active_per_layer
        return total

    if cfg.family == "moe":
        assert cfg.moe is not None
        moe = cfg.moe
        d = cfg.d_model
        expert = 3 * d * moe.d_expert
        shared = 3 * d * moe.d_shared if moe.d_shared else 0
        router = d * moe.n_experts
        per_layer = _attn_params(cfg) + moe.n_experts * expert + shared + router
        total += cfg.n_layers * per_layer
        if active_only:
            active = _attn_params(cfg) + moe.top_k * expert + shared + router
            return emb + head + cfg.n_layers * active
        return total

    if cfg.family == "encdec":
        assert cfg.encoder is not None
        enc_layer = _attn_params(cfg) + 2 * cfg.d_model * cfg.d_ff
        dec_layer = 2 * _attn_params(cfg) + 2 * cfg.d_model * cfg.d_ff
        total += cfg.encoder.n_layers * enc_layer + cfg.n_layers * dec_layer
        return total

    if cfg.family == "vlm":
        assert cfg.vision is not None
        n_cross = cfg.n_layers // cfg.vision.cross_attn_every
        n_self = cfg.n_layers - n_cross
        cross = _attn_params(cfg) + _ffn_params(cfg)
        self_l = _attn_params(cfg) + _ffn_params(cfg)
        total += n_self * self_l + n_cross * cross
        total += cfg.vision.d_vision * cfg.d_model  # projector
        return total

    # dense
    total += cfg.n_layers * (_attn_params(cfg) + _ffn_params(cfg))
    return total


# ---------------------------------------------------------------------- #
# Input shapes assigned to this paper.
# ---------------------------------------------------------------------- #
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicability(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """(runnable, note).  See DESIGN.md section 'Arch-applicability'.

    ``long_500k`` requires sub-quadratic context handling.  SSM / hybrid
    archs run natively.  Full-attention archs run in the paper's own
    streaming mode (sliding window + attention sinks, paper section 4)
    EXCEPT whisper, whose decoder context is architecturally capped.
    """
    if shape.name == "long_500k":
        if cfg.family in ("rwkv6", "rglru_hybrid"):
            return True, "native (constant-size / windowed state)"
        if cfg.family == "encdec":
            return False, "skip: enc-dec decoder context architecturally capped"
        return True, "streaming mode: sliding window 8192 + 128 sink tokens"
    return True, ""
