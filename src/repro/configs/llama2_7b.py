"""llama2-7b — the paper's primary evaluation model (paper §4).

32L d_model=4096 32H (MHA) d_ff=11008 vocab=32000, 4k context window.
[arXiv:2307.09288]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=32000,
    head_dim=128,
    rope_theta=10000.0,
    max_seq=4096,
    source="arXiv:2307.09288 (paper's own model)",
)
