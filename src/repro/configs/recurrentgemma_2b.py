"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 1:2.

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.
[arXiv:2402.19427; hf]

Block pattern (rec, rec, attn): 26 layers = 8 full triples + 2 trailing
recurrent layers.  Local attention window = 2048 with a single KV head
(MQA).  The recurrent state is constant-size, so long_500k runs natively.
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="rglru_hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    rope_theta=10000.0,
    max_seq=524288,
    rglru=RGLRUConfig(
        lru_width=2560,
        conv_width=4,
        window=2048,
        block_pattern=("rec", "rec", "attn"),
    ),
    source="arXiv:2402.19427; hf",
)
