"""smollm-360m [dense] — llama-arch small.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
[hf:HuggingFaceTB/SmolLM-135M; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab=49152,
    head_dim=64,
    tie_embeddings=True,
    rope_theta=10000.0,
    max_seq=2048,
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
)
