"""Architecture registry: ``--arch <id>`` resolves here.

``REGISTRY`` maps the assignment's architecture ids (and the paper's own
evaluation models) to :class:`repro.configs.base.ModelConfig` instances.
``reduced(cfg)`` derives a CPU-sized config of the same family for smoke
tests (small layers/width/experts/vocab; full configs are only exercised
via the dry-run's ShapeDtypeStructs).
"""
from __future__ import annotations


from repro.configs.base import (
    EncoderConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    RWKV6Config,
    ShapeSpec,
    SHAPES,
    VisionConfig,
    shape_applicability,
)

from repro.configs.llama4_maverick_400b import CONFIG as _llama4
from repro.configs.deepseek_v2_lite import CONFIG as _dsv2lite
from repro.configs.deepseek_67b import CONFIG as _ds67
from repro.configs.qwen3_32b import CONFIG as _qwen3
from repro.configs.smollm_360m import CONFIG as _smollm
from repro.configs.qwen2_5_14b import CONFIG as _qwen25
from repro.configs.recurrentgemma_2b import CONFIG as _rgemma
from repro.configs.rwkv6_1p6b import CONFIG as _rwkv6
from repro.configs.whisper_base import CONFIG as _whisper
from repro.configs.llama3_2_vision_90b import CONFIG as _llamav
from repro.configs.llama2_7b import CONFIG as _llama2
from repro.configs.opt_6_7b import CONFIG as _opt

# The 10 assigned architectures (dry-run / roofline cells) ...
ASSIGNED = {
    c.name: c
    for c in [
        _llama4, _dsv2lite, _ds67, _qwen3, _smollm,
        _qwen25, _rgemma, _rwkv6, _whisper, _llamav,
    ]
}
# ... plus the paper's own evaluation models (used by the LLMS benchmarks).
REGISTRY = dict(ASSIGNED)
REGISTRY[_llama2.name] = _llama2
REGISTRY[_opt.name] = _opt


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-reduced",
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128,
        vocab=512,
        head_dim=16,
        max_seq=256,
    )
    if cfg.family == "moe":
        kw["moe"] = MoEConfig(n_experts=4, top_k=cfg.moe.top_k if cfg.moe.top_k <= 2 else 2,
                              d_expert=96, n_shared=cfg.moe.n_shared,
                              d_shared=96 if cfg.moe.d_shared else 0,
                              capacity_factor=2.0, group_size=32)
    if cfg.family == "mla_moe":
        kw["moe"] = MoEConfig(n_experts=4, top_k=2, d_expert=96, n_shared=2,
                              d_shared=96, capacity_factor=2.0, group_size=32)
        kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
    if cfg.family == "rglru_hybrid":
        kw["n_layers"] = 5  # rec,rec,attn,rec,rec
        kw["rglru"] = RGLRUConfig(lru_width=64, conv_width=4, window=64,
                                  block_pattern=cfg.rglru.block_pattern)
        kw["head_dim"] = 32
        kw["n_kv_heads"] = 1
    if cfg.family == "rwkv6":
        kw["n_heads"] = 4
        kw["n_kv_heads"] = 4
        kw["rwkv"] = RWKV6Config(head_dim=16, decay_lora=8, mix_lora=4,
                                 chunk_len=16)
    if cfg.family == "encdec":
        kw["encoder"] = EncoderConfig(n_layers=2, n_frames=32)
        kw["n_layers"] = 2
    if cfg.family == "vlm":
        kw["n_layers"] = 10
        kw["vision"] = VisionConfig(n_image_tokens=16, d_vision=48,
                                    cross_attn_every=5)
    return cfg.with_overrides(**kw)


__all__ = [
    "ASSIGNED", "REGISTRY", "SHAPES", "ShapeSpec", "ModelConfig",
    "get_config", "reduced", "shape_applicability",
]
