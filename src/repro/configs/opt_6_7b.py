"""opt-6.7b — the paper's second evaluation model (paper §4).

32L d_model=4096 32H (MHA) d_ff=16384 vocab=50272, 2k context window.
OPT uses learned positional embeddings and ReLU FFN; we model it in the
same llama-style backbone with its own dims (noted in DESIGN.md).
[arXiv:2205.01068]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="opt-6.7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=16384,
    vocab=50272,
    head_dim=128,
    rope_theta=10000.0,
    max_seq=2048,
    source="arXiv:2205.01068 (paper's own model)",
)
