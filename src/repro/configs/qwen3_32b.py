"""qwen3-32b [dense] — qk_norm, GQA.

64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936.
[hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1000000.0,
    max_seq=32768,
    source="hf:Qwen/Qwen3-8B; hf",
)
