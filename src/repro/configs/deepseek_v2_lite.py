"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, shared+routed MoE.

27L d_model=2048 16H d_ff=1408 vocab=102400, MoE 64e top-6.
[arXiv:2405.04434; hf]

The assignment header specifies 64 routed experts top-6 with 2 shared
experts (the HF checkpoint's 66-expert layout); d_ff=1408 is the routed
expert hidden size.  MLA caches the compressed KV latent
(kv_lora_rank + qk_rope_head_dim = 576 dims/token) instead of full K/V.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="mla_moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,   # MLA: per-head K/V reconstructed from shared latent
    d_ff=1408,
    vocab=102400,
    head_dim=128,
    rope_theta=10000.0,
    max_seq=163840,
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared=2,
        d_shared=2816,   # 2 shared experts fused: 2 * 1408
        capacity_factor=1.4,
        group_size=512,
    ),
    source="arXiv:2405.04434; hf",
)
