"""llama-3.2-vision-90b [vlm] — cross-attn image layers.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision tower is a STUB: ``input_specs`` provides precomputed patch
embeddings (batch, 1601, 7680); the model owns only the projector and the
cross-attention layers.  100 layers = 20 blocks of (4 self-attn layers +
1 cross-attn layer), i.e. cross-attention every 5th layer.
"""
from repro.configs.base import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    rope_theta=500000.0,
    max_seq=131072,
    vision=VisionConfig(n_image_tokens=1601, d_vision=7680, cross_attn_every=5),
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
