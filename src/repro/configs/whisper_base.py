"""whisper-base [audio] — enc-dec, conv frontend (stub).

6L d_model=512 8H d_ff=2048 vocab=51865.
[arXiv:2212.04356; unverified]

The assignment specifies the transformer BACKBONE only: the conv/mel
frontend is a stub; ``input_specs`` provides precomputed frame embeddings
(batch, 1500, 512).  n_layers refers to the decoder; the encoder has 6
layers as well.  The decoder's learned positional embedding is sized to
the requested shape (the backbone is parameterizable; the real model caps
at 448 positions — noted in DESIGN.md).
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    head_dim=64,
    max_seq=448,
    norm_eps=1e-5,
    encoder=EncoderConfig(n_layers=6, n_frames=1500),
    source="arXiv:2212.04356; unverified",
)
