"""rwkv6-1.6b [ssm] — Finch, data-dependent decay, attention-free.

24L d_model=2048 d_ff=7168 vocab=65536.
[arXiv:2404.05892; unverified]

State per layer: (heads, head_dim, head_dim) wkv matrix + token-shift
buffers; total context state is constant in sequence length, so all
decode shapes (including long_500k) run natively.
"""
from repro.configs.base import ModelConfig, RWKV6Config

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="rwkv6",
    n_layers=24,
    d_model=2048,
    n_heads=32,          # 2048 / 64 head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab=65536,
    head_dim=64,
    max_seq=524288,
    rwkv=RWKV6Config(head_dim=64, decay_lora=64, mix_lora=32, chunk_len=16),
    source="arXiv:2404.05892; unverified",
)
