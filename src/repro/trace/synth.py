"""Context-switching trace synthesis (paper §4, Eq. 5 + Table 3).

Trace = {(Time_i, CtxtID_i, Prompt_i, groundTruth_i)} with Poisson
arrivals and three switching patterns:

  Random    uniform over active contexts
  Markov    first-order chain favouring recently-used contexts
  Gaussian  preference for contexts with moderate delta-length workload

The paper derives prompts from 6 public datasets; offline we synthesize
token sequences from the same seeded Markov language as the training
pipeline, with each "dataset" keeping Table 3's delta-length range.
Traces are deterministic in (seed, pattern, n_contexts, calls).

**Scenario-parameterized synthesis** (the loadgen scale harness,
DESIGN.md "Scale harness"): ``arrival_times`` generates seeded arrival
processes beyond plain Poisson — bursty foreground-over-background,
diurnal ramps, thundering herds, uniform churn — and
``synthesize_mixed`` composes an arrival process with a context-
selection pattern (including the adversarial ``sweep``), mixed
prompt/output-length distributions, and a per-app priority mix into
one deterministic event list.  Everything is a plain dict/ndarray
interface so ``repro.loadgen`` stays the only layer that knows about
scenario specs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.pipeline import markov_sample, markov_table

# Table 3: dataset -> (delta_lo, delta_hi) in tokens.  Scaled by
# ``scale`` for reduced-model benchmarks (the paper's are 0.01k-2k).
TABLE3 = {
    "agnews": (200, 500),
    "xsum": (1000, 2000),
    "samsum": (100, 300),
    "cnn_dailymail": (500, 1000),
    "wmt17_de_en": (100, 500),
    "sst2": (10, 100),
}
PATTERNS = ("random", "markov", "gaussian")
# context-selection patterns for scenario synthesis: the classic three
# plus "sweep" — strict round-robin over ALL contexts, the adversarial
# case for LRU/LCTRU (every touch is the coldest context, so every
# switch-in misses)
CTX_PATTERNS = PATTERNS + ("sweep",)
ARRIVALS = ("poisson", "uniform", "bursty", "diurnal", "herd")


@dataclass
class TraceEvent:
    time: float
    ctx_id: int
    prompt: np.ndarray          # int32 tokens
    ground_truth: np.ndarray    # int32 tokens (ideal output)
    dataset: str
    # scenario extensions (defaults keep classic synthesize() events
    # working everywhere): per-event priority/app assignment and output
    # budget, filled in by synthesize_mixed
    priority: Optional[str] = None
    max_new: int = 4
    app: str = ""


def synthesize(n_contexts: int, n_calls: int, vocab: int,
               pattern: str = "random", rate_per_s: float = 1 / 300.0,
               scale: float = 1.0, seed: int = 0,
               datasets: Tuple[str, ...] = tuple(TABLE3)) -> List[TraceEvent]:
    """rate_per_s: Poisson calling rate (paper default: 1 per 5 min)."""
    assert pattern in PATTERNS, pattern
    rng = np.random.RandomState(seed)
    table = markov_table(vocab, seed=seed + 77)
    ctx_dataset = [datasets[i % len(datasets)] for i in range(n_contexts)]
    # per-context mean delta (for the gaussian preference pattern)
    deltas = np.array([np.mean(TABLE3[d]) * scale for d in ctx_dataset])
    target = np.median(deltas)
    gauss_w = np.exp(-0.5 * ((deltas - target) / (deltas.std() + 1e-9)) ** 2)
    gauss_w /= gauss_w.sum()

    events: List[TraceEvent] = []
    t = 0.0
    prev = rng.randint(n_contexts)
    for _ in range(n_calls):
        t += rng.exponential(1.0 / rate_per_s)
        if pattern == "random":
            cid = rng.randint(n_contexts)
        elif pattern == "gaussian":
            cid = rng.choice(n_contexts, p=gauss_w)
        else:  # markov: stay with recently-used w.p. 0.5, else uniform
            if rng.rand() < 0.5:
                cid = prev
            else:
                cid = rng.randint(n_contexts)
        prev = cid
        lo, hi = TABLE3[ctx_dataset[cid]]
        n = max(2, int(rng.randint(int(lo * scale), int(hi * scale) + 1)))
        n_prompt = max(1, int(n * 0.8))
        seqtoks = markov_sample(table, n, rng)
        events.append(TraceEvent(
            time=t, ctx_id=cid, prompt=seqtoks[:n_prompt],
            ground_truth=seqtoks[n_prompt:], dataset=ctx_dataset[cid]))
    return events


# --------------------------------------------------------------------- #
# scenario-parameterized synthesis (loadgen scale harness)
# --------------------------------------------------------------------- #
def arrival_times(kind: str, n_calls: int, rate_per_s: float,
                  rng: np.random.RandomState,
                  params: Optional[Dict] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Seeded arrival process -> (times (n,) float64 ascending,
    burst_flags (n,) bool).  ``burst_flags`` marks arrivals that belong
    to a burst/herd (the scenario layer routes those to foreground
    apps).  All processes are deterministic in (kind, n, rate, rng).

      poisson   homogeneous Poisson at ``rate_per_s``
      uniform   evenly spaced 1/rate apart (steady churn baseline)
      bursty    Poisson base load + periodic high-rate bursts
                (``burst_every_s``, ``burst_size``, ``burst_rate_per_s``)
      diurnal   inhomogeneous Poisson, sinusoidal rate
                ``rate * (1 + amplitude * sin(2 pi t / period_s))``
                via thinning
      herd      ``herd_size`` simultaneous arrivals every
                ``herd_every_s`` (thundering-herd restores)
    """
    assert kind in ARRIVALS, kind
    p = params or {}
    if kind == "poisson":
        times = np.cumsum(rng.exponential(1.0 / rate_per_s, n_calls))
        return times, np.zeros(n_calls, bool)
    if kind == "uniform":
        times = (np.arange(n_calls, dtype=np.float64) + 1.0) / rate_per_s
        return times, np.zeros(n_calls, bool)
    if kind == "bursty":
        burst_every = float(p.get("burst_every_s", 60.0))
        burst_size = int(p.get("burst_size", max(4, n_calls // 8)))
        burst_rate = float(p.get("burst_rate_per_s", rate_per_s * 50.0))
        n_burst = min(n_calls - 1, int(p.get(
            "burst_frac", 0.5) * n_calls))
        n_base = n_calls - n_burst
        base = np.cumsum(rng.exponential(1.0 / rate_per_s, n_base))
        bursts, t0 = [], burst_every
        while len(bursts) < n_burst:
            k = min(burst_size, n_burst - len(bursts))
            bursts.extend(t0 + np.cumsum(rng.exponential(1.0 / burst_rate,
                                                         k)))
            t0 += burst_every
        bursts = np.asarray(bursts[:n_burst])
        times = np.concatenate([base, bursts])
        flags = np.concatenate([np.zeros(n_base, bool),
                                np.ones(n_burst, bool)])
        order = np.argsort(times, kind="stable")
        return times[order], flags[order]
    if kind == "diurnal":
        period = float(p.get("period_s", 86400.0))
        amp = min(0.999, float(p.get("amplitude", 0.8)))
        peak = rate_per_s * (1.0 + amp)
        times = np.empty(n_calls)
        t, i = 0.0, 0
        while i < n_calls:
            t += rng.exponential(1.0 / peak)
            lam = rate_per_s * (1.0 + amp * np.sin(2 * np.pi * t / period))
            if rng.rand() * peak < lam:
                times[i] = t
                i += 1
        return times, np.zeros(n_calls, bool)
    # herd: bunches of simultaneous arrivals separated by idle gaps
    herd_every = float(p.get("herd_every_s", 1.0 / rate_per_s))
    herd_size = int(p.get("herd_size", max(2, n_calls // 8)))
    times = np.empty(n_calls)
    flags = np.ones(n_calls, bool)
    t0, i = herd_every, 0
    while i < n_calls:
        k = min(herd_size, n_calls - i)
        times[i:i + k] = t0
        i += k
        t0 += herd_every
    return times, flags


def sample_lengths(spec: Dict, n: int, rng: np.random.RandomState
                   ) -> np.ndarray:
    """Seeded per-event lengths from a distribution spec dict:

      {"dist": "fixed",     "n": 8}
      {"dist": "uniform",   "lo": 4, "hi": 16}
      {"dist": "lognormal", "median": 12, "sigma": 0.6,
                            "lo": 2, "hi": 256}
      {"dist": "bimodal",   "short": [4, 8], "long": [48, 96],
                            "p_long": 0.2}
    """
    dist = spec.get("dist", "fixed")
    if dist == "fixed":
        return np.full(n, int(spec.get("n", 8)), np.int64)
    if dist == "uniform":
        lo, hi = int(spec["lo"]), int(spec["hi"])
        return rng.randint(lo, hi + 1, size=n).astype(np.int64)
    if dist == "lognormal":
        med = float(spec.get("median", 12.0))
        sigma = float(spec.get("sigma", 0.6))
        lo = int(spec.get("lo", 1))
        hi = int(spec.get("hi", 4 * med))
        draw = np.exp(rng.normal(np.log(med), sigma, size=n))
        return np.clip(np.round(draw), lo, hi).astype(np.int64)
    if dist == "bimodal":
        s_lo, s_hi = (int(x) for x in spec.get("short", (4, 8)))
        l_lo, l_hi = (int(x) for x in spec.get("long", (48, 96)))
        p_long = float(spec.get("p_long", 0.2))
        is_long = rng.rand(n) < p_long
        out = rng.randint(s_lo, s_hi + 1, size=n)
        out[is_long] = rng.randint(l_lo, l_hi + 1, size=int(is_long.sum()))
        return out.astype(np.int64)
    raise ValueError(f"unknown length dist {dist!r}")


def _select_contexts(pattern: str, n_contexts: int, n_calls: int,
                     rng: np.random.RandomState) -> np.ndarray:
    assert pattern in CTX_PATTERNS, pattern
    if pattern == "sweep":
        return (np.arange(n_calls) % n_contexts).astype(np.int64)
    if pattern == "random":
        return rng.randint(n_contexts, size=n_calls).astype(np.int64)
    if pattern == "gaussian":
        # moderate-index preference, mirroring classic synthesize's
        # delta-length shaping without the Table-3 datasets
        idx = np.arange(n_contexts)
        w = np.exp(-0.5 * ((idx - n_contexts / 2) /
                           (0.25 * n_contexts + 1e-9)) ** 2)
        w /= w.sum()
        return rng.choice(n_contexts, size=n_calls, p=w).astype(np.int64)
    # markov: stay with the previous context w.p. 0.5
    out = np.empty(n_calls, np.int64)
    prev = rng.randint(n_contexts)
    stay = rng.rand(n_calls) < 0.5
    jumps = rng.randint(n_contexts, size=n_calls)
    for i in range(n_calls):
        prev = prev if stay[i] else jumps[i]
        out[i] = prev
    return out


def synthesize_mixed(n_contexts: int, n_calls: int, vocab: int, *,
                     arrival: Optional[Dict] = None,
                     ctx_pattern: str = "markov",
                     prompt_len: Optional[Dict] = None,
                     output_len: Optional[Dict] = None,
                     apps: Optional[Sequence[Dict]] = None,
                     prompt_source: str = "markov",
                     seed: int = 0) -> List[TraceEvent]:
    """Scenario-parameterized trace: one arrival process x one context
    pattern x length distributions x a per-app priority mix, all from
    one seed.  Burst/herd-flagged arrivals go to foreground apps and
    the rest to background apps (when both exist) — the load shape the
    scheduler's preemption is built for.  An app dict may carry its own
    ``prompt_len``/``output_len`` spec overriding the global one (e.g.
    long-running background agents under short foreground taps).
    Plain-dict parameters so any layer (loadgen specs, tests, ad-hoc
    scripts) can drive it."""
    arrival = arrival or {"kind": "poisson", "rate_per_s": 1 / 300.0}
    prompt_len = prompt_len or {"dist": "uniform", "lo": 4, "hi": 16}
    output_len = output_len or {"dist": "fixed", "n": 4}
    apps = list(apps or ({"name": "app0", "priority": "foreground",
                          "weight": 1.0},))
    rng = np.random.RandomState(seed)
    times, flags = arrival_times(arrival.get("kind", "poisson"), n_calls,
                                 float(arrival.get("rate_per_s", 1 / 300.0)),
                                 rng, arrival)
    cids = _select_contexts(ctx_pattern, n_contexts, n_calls, rng)
    p_lens = sample_lengths(prompt_len, n_calls, rng)
    o_lens = sample_lengths(output_len, n_calls, rng)

    w = np.asarray([float(a.get("weight", 1.0)) for a in apps])
    w = w / w.sum()
    fg_idx = [i for i, a in enumerate(apps)
              if str(a.get("priority", "foreground")).startswith(("f", "F"))]
    bg_idx = [i for i in range(len(apps)) if i not in fg_idx]
    app_choice = rng.choice(len(apps), size=n_calls, p=w)
    if flags.any() and fg_idx and bg_idx:
        wf = w[fg_idx] / w[fg_idx].sum()
        wb = w[bg_idx] / w[bg_idx].sum()
        n_f, n_b = int(flags.sum()), int((~flags).sum())
        app_choice[flags] = np.asarray(fg_idx)[
            rng.choice(len(fg_idx), size=n_f, p=wf)]
        app_choice[~flags] = np.asarray(bg_idx)[
            rng.choice(len(bg_idx), size=n_b, p=wb)]

    # per-app length overrides (drawn for every call up front so the
    # rng stream — and thus the whole trace — stays deterministic
    # regardless of which calls each app ends up with)
    for j, a in enumerate(apps):
        if "prompt_len" in a:
            over = sample_lengths(a["prompt_len"], n_calls, rng)
            p_lens = np.where(app_choice == j, over, p_lens)
        if "output_len" in a:
            over = sample_lengths(a["output_len"], n_calls, rng)
            o_lens = np.where(app_choice == j, over, o_lens)

    table = (markov_table(vocab, seed=seed + 77)
             if prompt_source == "markov" else None)
    events: List[TraceEvent] = []
    for i in range(n_calls):
        n = int(p_lens[i])
        if table is not None:
            prompt = markov_sample(table, n, rng)
        else:
            prompt = rng.randint(1, vocab, size=n).astype(np.int32)
        a = apps[int(app_choice[i])]
        events.append(TraceEvent(
            time=float(times[i]), ctx_id=int(cids[i]), prompt=prompt,
            ground_truth=np.empty(0, np.int32), dataset="scenario",
            priority=str(a.get("priority", "foreground")),
            max_new=int(o_lens[i]), app=str(a.get("name", "app0"))))
    return events
