"""Context-switching trace synthesis (paper §4, Eq. 5 + Table 3).

Trace = {(Time_i, CtxtID_i, Prompt_i, groundTruth_i)} with Poisson
arrivals and three switching patterns:

  Random    uniform over active contexts
  Markov    first-order chain favouring recently-used contexts
  Gaussian  preference for contexts with moderate delta-length workload

The paper derives prompts from 6 public datasets; offline we synthesize
token sequences from the same seeded Markov language as the training
pipeline, with each "dataset" keeping Table 3's delta-length range.
Traces are deterministic in (seed, pattern, n_contexts, calls).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from repro.data.pipeline import markov_sample, markov_table

# Table 3: dataset -> (delta_lo, delta_hi) in tokens.  Scaled by
# ``scale`` for reduced-model benchmarks (the paper's are 0.01k-2k).
TABLE3 = {
    "agnews": (200, 500),
    "xsum": (1000, 2000),
    "samsum": (100, 300),
    "cnn_dailymail": (500, 1000),
    "wmt17_de_en": (100, 500),
    "sst2": (10, 100),
}
PATTERNS = ("random", "markov", "gaussian")


@dataclass
class TraceEvent:
    time: float
    ctx_id: int
    prompt: np.ndarray          # int32 tokens
    ground_truth: np.ndarray    # int32 tokens (ideal output)
    dataset: str


def synthesize(n_contexts: int, n_calls: int, vocab: int,
               pattern: str = "random", rate_per_s: float = 1 / 300.0,
               scale: float = 1.0, seed: int = 0,
               datasets: Tuple[str, ...] = tuple(TABLE3)) -> List[TraceEvent]:
    """rate_per_s: Poisson calling rate (paper default: 1 per 5 min)."""
    assert pattern in PATTERNS, pattern
    rng = np.random.RandomState(seed)
    table = markov_table(vocab, seed=seed + 77)
    ctx_dataset = [datasets[i % len(datasets)] for i in range(n_contexts)]
    # per-context mean delta (for the gaussian preference pattern)
    deltas = np.array([np.mean(TABLE3[d]) * scale for d in ctx_dataset])
    target = np.median(deltas)
    gauss_w = np.exp(-0.5 * ((deltas - target) / (deltas.std() + 1e-9)) ** 2)
    gauss_w /= gauss_w.sum()

    events: List[TraceEvent] = []
    t = 0.0
    prev = rng.randint(n_contexts)
    for _ in range(n_calls):
        t += rng.exponential(1.0 / rate_per_s)
        if pattern == "random":
            cid = rng.randint(n_contexts)
        elif pattern == "gaussian":
            cid = rng.choice(n_contexts, p=gauss_w)
        else:  # markov: stay with recently-used w.p. 0.5, else uniform
            if rng.rand() < 0.5:
                cid = prev
            else:
                cid = rng.randint(n_contexts)
        prev = cid
        lo, hi = TABLE3[ctx_dataset[cid]]
        n = max(2, int(rng.randint(int(lo * scale), int(hi * scale) + 1)))
        n_prompt = max(1, int(n * 0.8))
        seqtoks = markov_sample(table, n, rng)
        events.append(TraceEvent(
            time=t, ctx_id=cid, prompt=seqtoks[:n_prompt],
            ground_truth=seqtoks[n_prompt:], dataset=ctx_dataset[cid]))
    return events
