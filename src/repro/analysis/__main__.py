"""CLI: ``python -m repro.analysis [paths...]``.

Exit status: 0 = no unbaselined findings, 1 = new findings (or a
selftest expectation failed), 2 = usage error.  Runs on a bare Python
— no jax, no third-party imports.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import baseline as baseline_mod
from repro.analysis import runner


def _selftest() -> int:
    """Assert the analyzer still catches the shipped bug
    reproductions (PR 3 pool self-deadlock, PR 6 restore race, and the
    pre-PR-10 executor family-string dispatch)."""
    fixdir = Path(__file__).resolve().parent / "fixtures"
    expect = {
        "pr3_deadlock.py": ("lock", "blocking-in-worker"),
        "pr6_restore_race.py": ("lock", "unordered-store-read"),
        "family_dispatch.py": ("family", "string-dispatch"),
    }
    failures = []
    for fname, (checker, rule) in sorted(expect.items()):
        path = fixdir / fname
        findings = runner.analyze_source(
            path.read_text(), relpath=f"fixtures/{fname}",
            modname=f"fixture.{fname[:-3]}")
        hits = [f for f in findings
                if f.checker == checker and f.rule == rule]
        if hits:
            print(f"selftest: {fname}: OK "
                  f"({checker}/{rule} x{len(hits)})")
        else:
            failures.append(fname)
            print(f"selftest: {fname}: MISSED expected "
                  f"{checker}/{rule}; got:")
            for f in findings:
                print(f"  {f.render()}")
    if failures:
        print(f"selftest FAILED: {', '.join(failures)}")
        return 1
    print("selftest passed: all regression fixtures flagged")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Concurrency & jit discipline static analyzer "
                    "(stdlib-only).")
    ap.add_argument("paths", nargs="*", type=Path,
                    help="files to scan (default: src/repro, minus "
                         "the analyzer itself)")
    ap.add_argument("--baseline", type=Path,
                    default=runner.DEFAULT_BASELINE,
                    help="baseline file (default: "
                         "analysis_baseline.json at the repo root)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="re-grandfather: write ALL current findings "
                         "to the baseline and exit 0")
    ap.add_argument("--json", type=Path, metavar="OUT",
                    help="also dump findings as JSON (CI artifact)")
    ap.add_argument("--selftest", action="store_true",
                    help="check the known-bad fixtures are flagged")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="also print grandfathered findings")
    args = ap.parse_args(argv)

    if args.selftest:
        return _selftest()

    if args.paths:
        findings = runner.analyze_paths(args.paths)
        baselined = baseline_mod.load(args.baseline)
        new, old = baseline_mod.diff(findings, baselined)
    else:
        findings = runner.run_checks(runner.build_program())
        baselined = baseline_mod.load(args.baseline)
        new, old = baseline_mod.diff(findings, baselined)

    if args.write_baseline:
        baseline_mod.write(args.baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    if args.json:
        payload = {"new": [f.to_dict() for f in new],
                   "grandfathered": [f.to_dict() for f in old]}
        args.json.write_text(json.dumps(payload, indent=2) + "\n")

    if args.verbose and old:
        print(f"-- {len(old)} grandfathered finding(s):")
        for f in old:
            print(f"   {f.render()}")
    if new:
        print(f"{len(new)} NEW finding(s) not in baseline:")
        for f in new:
            print(f"  {f.render()}")
        print("fix them, allowlist with a justification "
              "(repro/analysis/config.py), or re-baseline with "
              "--write-baseline")
        return 1
    suffix = f" ({len(old)} grandfathered)" if old else ""
    print(f"analysis clean: 0 new findings{suffix}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
