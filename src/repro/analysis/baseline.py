"""Baseline load/diff/write: grandfathered findings pass, new ones
fail.  Format (``analysis_baseline.json`` at the repo root)::

    {"version": 1,
     "findings": [{"checker": ..., "rule": ..., "file": ..., "line":
                   ..., "scope": ..., "message": ..., "fingerprint":
                   ...}, ...]}

Only the fingerprint participates in the diff (line numbers are
excluded from it, so code motion doesn't churn the file); the rest is
kept for human readers.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Iterable, List, Set, Tuple

from repro.analysis.findings import Finding


def load(path: Path) -> Set[str]:
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return {f["fingerprint"] for f in data.get("findings", [])}


def write(path: Path, findings: Iterable[Finding]):
    items = sorted(findings, key=lambda f: f.sort_key())
    payload = {"version": 1,
               "findings": [f.to_dict() for f in items]}
    path.write_text(json.dumps(payload, indent=2) + "\n")


def diff(findings: Iterable[Finding],
         baselined: Set[str]) -> Tuple[List[Finding], List[Finding]]:
    """-> (new, grandfathered)."""
    new: List[Finding] = []
    old: List[Finding] = []
    for f in findings:
        (old if f.fingerprint in baselined else new).append(f)
    return new, old
