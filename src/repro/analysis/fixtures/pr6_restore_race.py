"""Reproduction of the PR 6 restore-vs-AoT race (fixed in the real
tree): a restore path calls ``read_chunk_file`` on a store path while
a same-key ahead-of-time write may still be in flight — the read can
catch the file mid-``os.replace``.  The fixed code orders the read
behind ``self.swapper.wait(key)`` (or routes it through
``swapper.submit`` so the pool's same-key chaining orders it).  The
analyzer must flag the read as ``lock/unordered-store-read``.

Fixture module: never imported by the engine.
"""


def read_chunk_file(path):
    with open(path, "rb") as f:        # fixture stand-in
        return f.read()


class BadRestore:
    def __init__(self, store, swapper):
        self.store = store
        self.swapper = swapper

    def restore_chunk(self, key):
        # BUG (PR 6): no `self.swapper.wait(key)` before the read —
        # an in-flight AoT write's os.replace races this open().
        raw = read_chunk_file(self.store._path(key))
        return raw

    def restore_chunk_fixed(self, key):
        self.swapper.wait(key)
        raw = read_chunk_file(self.store._path(key))
        return raw
