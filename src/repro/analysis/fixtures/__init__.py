"""KNOWN-BAD fixture reproductions of shipped bugs, kept as analyzer
regression tests.  Excluded from the default scan; exercised by
``python -m repro.analysis --selftest`` and tests/test_analysis.py.
These modules are never imported by the engine."""
