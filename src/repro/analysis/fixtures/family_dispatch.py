"""KNOWN-BAD reproduction of the pre-PR-10 family string dispatch.

Before the KVSpec redesign, core/executor.py gated chunked caches and
recompute on the family NAME (the old :121/:201 gates below), so every
new model family meant editing the executor, the residency engine, and
the init_cache kwarg forks in lockstep.  The family checker must flag
every one of these shapes (family/string-dispatch)."""


class OldExecutor:
    def __init__(self, model, cfg):
        self.model = model
        self.cfg = cfg

    def init_cache(self, mixed_quant=False):
        mc = self.model.cfg
        # old executor.py:121 — chunked cache only for the families the
        # author remembered to list
        if mc.family in ("dense", "moe", "mla_moe", "vlm"):
            chunked = True
        else:
            chunked = False
        # old executor.py:201 — quant-resident fork keyed by name
        if mc.family == "mla_moe" and mixed_quant:
            return self._latent_cache()
        if mc.family != "rwkv6":
            return self._kv_cache(chunked)
        return self._state_cache()

    def can_recompute(self):
        fam = self.model.cfg.family
        return fam not in ("rwkv6", "rglru_hybrid", "encdec")

    def _latent_cache(self):
        return {}

    def _kv_cache(self, chunked):
        return {"chunked": chunked}

    def _state_cache(self):
        return {}
