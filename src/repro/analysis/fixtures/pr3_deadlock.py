"""Reproduction of the PR 3 AsyncSwapper self-deadlock (fixed in the
real tree): a single-worker pool job body blocks in ``prev.result()``
waiting for a future whose job is QUEUED BEHIND the very worker doing
the waiting.  The analyzer must flag the ``result()`` call inside the
submitted body as ``lock/blocking-in-worker``.

This module is a fixture: syntactically valid, never imported by the
engine, structurally faithful to the original bug.
"""
import threading
from concurrent.futures import ThreadPoolExecutor


class BadSwapper:
    """Same-key write chaining done WRONG: the dependency wait happens
    inside the pool instead of via ``add_done_callback`` chaining."""

    def __init__(self):
        self.pool = ThreadPoolExecutor(max_workers=1)
        self._lock = threading.Lock()
        self._pending = {}

    def submit(self, key, payload):
        with self._lock:
            prev = self._pending.get(key)

            def job():
                if prev is not None:
                    # BUG (PR 3): this runs ON the single pool worker;
                    # if prev's job hasn't started yet it never will,
                    # because the only worker is parked right here.
                    prev.result()
                return self._do_write(key, payload)

            fut = self.pool.submit(job)
            self._pending[key] = fut
            return fut

    def _do_write(self, key, payload):
        return (key, len(payload))
